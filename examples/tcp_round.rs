//! TCP transport walkthrough: the paper's three-party topology over real
//! sockets, inside one process for convenience — two server threads run
//! exactly what `fsl serve` runs (accept loop + remote command loop on
//! ephemeral loopback ports), and the driver connects to them with
//! `FslRuntimeBuilder::connect`, exactly as it would connect to two
//! separate machines.
//!
//! ```sh
//! cargo run --release --example tcp_round
//! ```
//!
//! For a real multi-process deployment, run the same three pieces in
//! three terminals:
//!
//! ```sh
//! fsl serve party=0 listen=127.0.0.1:7100
//! fsl serve party=1 listen=127.0.0.1:7101
//! fsl ssa m=32768 c=0.1 clients=4 connect=127.0.0.1:7100,127.0.0.1:7101 --json
//! ```

use anyhow::Result;
use fsl::coordinator::{serve, FslRuntimeBuilder, ServeOptions};
use fsl::crypto::rng::Rng;
use fsl::hashing::CuckooParams;
use fsl::net::transport::tcp::{TcpAcceptor, TcpOptions};
use fsl::protocol::SessionParams;
use std::net::TcpListener;

fn main() -> Result<()> {
    let m = 4096u64;
    let k = 64usize;
    let n_clients = 3usize;

    // ----- Two standalone servers on ephemeral loopback ports ------------
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for party in 0..2u8 {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        println!("S{party} listening on {addr}");
        addrs.push(addr);
        handles.push(std::thread::spawn(move || {
            let acceptor = TcpAcceptor::new(listener, TcpOptions::default());
            serve::<u64>(&acceptor, &ServeOptions::new(party))
        }));
    }

    // ----- The driver connects exactly as it would across machines -------
    let mut rt = FslRuntimeBuilder::new(SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default(),
    })
    .max_clients(n_clients)
    .connect::<u64>(&addrs[0], &addrs[1])?;
    println!(
        "connected: control + {n_clients} client links per server, S0<->S1 peer link dialled"
    );

    let mut rng = Rng::new(7);
    let weights: Vec<u64> = (0..m).map(|_| rng.next_u64() >> 1).collect();
    rt.set_weights(weights.clone())?;

    // One PSR round over TCP.
    let selections: Vec<Vec<u64>> = (0..n_clients).map(|_| rng.sample_distinct(k, m)).collect();
    let psr = rt.psr(&selections, &mut rng)?;
    for (sel, got) in selections.iter().zip(&psr.submodels) {
        for (i, &s) in sel.iter().enumerate() {
            assert_eq!(got[i], weights[s as usize]);
        }
    }
    println!("PSR over TCP: all submodels verified ✓\n  {}", psr.report.to_json());

    // One SSA round over TCP.
    let clients: Vec<(Vec<u64>, Vec<u64>)> = selections
        .iter()
        .map(|sel| (sel.clone(), sel.iter().map(|&s| s + 1).collect()))
        .collect();
    let ssa = rt.ssa(&clients, &mut rng)?;
    let mut expected = vec![0u64; m as usize];
    for (sel, dl) in &clients {
        for (&s, &d) in sel.iter().zip(dl) {
            expected[s as usize] = expected[s as usize].wrapping_add(d);
        }
    }
    assert_eq!(ssa.delta, expected, "Δw reconstructed exactly over TCP");
    println!(
        "SSA over TCP: Δw lossless ✓ (S0<->S1 exchanged {} bytes)\n  {}",
        ssa.report.server_exchange_bytes,
        ssa.report.to_json()
    );

    // Shutting the runtime down tells both server processes to exit.
    rt.shutdown()?;
    for (party, h) in handles.into_iter().enumerate() {
        h.join().expect("server thread")?;
        println!("S{party} exited cleanly");
    }
    println!("tcp_round OK");
    Ok(())
}
