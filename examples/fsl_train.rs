//! End-to-end driver: secure FSL training of the ~1.9M-parameter MLP on
//! the synthetic image task — all three layers composed:
//!
//!  L1/L2: `mlp_grad` / `mlp_infer` HLO artifacts (Pallas matmul inside),
//!         executed through PJRT from rust;
//!  L3:    top-k sparsification → DPF/cuckoo SSA over two server threads
//!         with metered channels → FedAvg apply.
//!
//! Logs the loss curve and accuracy; EXPERIMENTS.md records a run.
//!
//! ```sh
//! cargo run --release --example fsl_train -- rounds=20 clients=8 c=0.1
//! ```

use anyhow::Result;
use fsl::coordinator::{run_fsl_training, FslConfig};
use fsl::crypto::rng::Rng;
use fsl::data::{partition_iid, ImageDataset, IMAGE_CLASSES};
use fsl::runtime::Executor;
use std::collections::HashMap;

fn kv() -> HashMap<String, String> {
    std::env::args()
        .skip(1)
        .filter_map(|a| a.split_once('=').map(|(k, v)| (k.to_string(), v.to_string())))
        .collect()
}

fn get<T: std::str::FromStr>(kv: &HashMap<String, String>, key: &str, default: T) -> T {
    kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let kv = kv();
    let cfg = FslConfig {
        num_clients: get(&kv, "clients", 8),
        participation: get(&kv, "participation", 1.0),
        rounds: get(&kv, "rounds", 20),
        local_iters: get(&kv, "local_iters", 1),
        lr: get(&kv, "lr", 0.05),
        compression: get(&kv, "c", 0.10),
        seed: get(&kv, "seed", 42),
        eval_every: get(&kv, "eval_every", 5),
        ..FslConfig::default()
    };
    let artifacts: String = get(&kv, "artifacts", "artifacts".to_string());
    let exec = Executor::new(&artifacts)?;
    let m = exec.manifest().int("mlp_grad", "params")? as usize;
    let batch = exec.manifest().int("mlp_grad", "batch")? as usize;

    let (train, test) = ImageDataset::synthesize_split(
        get(&kv, "train_n", 1500),
        get(&kv, "test_n", 400),
        cfg.seed,
        1.0,
    );
    let mut rng = Rng::new(cfg.seed);
    let shards = partition_iid(train.n, cfg.num_clients, &mut rng);

    // He init (seeded) for the flat parameter vector.
    let layers = [(784usize, 1024usize), (1024, 1024), (1024, 10)];
    let mut prng = Rng::new(cfg.seed ^ 0x1111);
    let mut params = Vec::with_capacity(m);
    for (i, o) in layers {
        let s = (2.0 / i as f64).sqrt() as f32;
        params.extend((0..i * o).map(|_| prng.gen_normal() as f32 * s));
        params.extend(std::iter::repeat(0f32).take(o));
    }

    println!("# secure FSL end-to-end: m={m} clients={} rounds={} c={:.1}% seed={}",
        cfg.num_clients, cfg.rounds, cfg.compression * 100.0, cfg.seed);
    println!("round,loss,upload_mb_per_client,gen_ms,server_ms,train_ms,accuracy");
    let log = run_fsl_training(
        &exec,
        &cfg,
        "mlp_grad",
        params,
        |client, _it, r| {
            let shard = &shards[client];
            let idx: Vec<usize> = (0..batch)
                .map(|_| shard[r.gen_range(shard.len() as u64) as usize])
                .collect();
            train.batch(&idx)
        },
        |p| {
            let mut correct = 0usize;
            let mut total = 0usize;
            for chunk in (0..test.n).collect::<Vec<_>>().chunks(batch) {
                let mut idx = chunk.to_vec();
                while idx.len() < batch {
                    idx.push(chunk[0]);
                }
                let (x, _) = test.batch(&idx);
                let logits = exec.infer("mlp_infer", p, &x)?;
                for (row, &i) in chunk.iter().enumerate() {
                    let rl = &logits[row * IMAGE_CLASSES..(row + 1) * IMAGE_CLASSES];
                    let pred = rl
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    correct += usize::from(pred == test.y[i] as usize);
                    total += 1;
                }
            }
            Ok(correct as f32 / total.max(1) as f32)
        },
        |s| {
            println!(
                "{},{:.4},{:.3},{:.0},{:.0},{:.0},{}",
                s.round,
                s.mean_loss,
                s.upload_mb_per_client,
                s.gen_time.as_secs_f64() * 1e3,
                s.server_time.as_secs_f64() * 1e3,
                s.train_time.as_secs_f64() * 1e3,
                s.accuracy.map(|a| format!("{:.4}", a)).unwrap_or_default()
            );
        },
    )?;
    println!(
        "# final accuracy: {:.2}%  (loss {:.4} → {:.4})",
        log.last_accuracy().unwrap_or(0.0) * 100.0,
        log.rounds.first().map(|r| r.mean_loss).unwrap_or(0.0),
        log.rounds.last().map(|r| r.mean_loss).unwrap_or(0.0),
    );
    Ok(())
}
