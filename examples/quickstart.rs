//! Quickstart: one PSR retrieval and one SSA aggregation round, tiny
//! parameters, every step spelled out.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::{anyhow, Result};
use fsl::coordinator::run_ssa_round;
use fsl::crypto::rng::Rng;
use fsl::group::{fixed_decode, fixed_encode};
use fsl::hashing::CuckooParams;
use fsl::metrics::mb;
use fsl::protocol::{psr, RetrievalEngine, Session, SessionParams};
use std::time::Duration;

fn main() -> Result<()> {
    // ----- System setup (Fig. 4 "System Setup") --------------------------
    let m = 4096u64; // global model size
    let k = 64usize; // submodel size per client
    let session = Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default(),
    });
    println!(
        "setup: m={m}, k={k}, B={} bins, Θ={} (⌈log Θ⌉ = {})",
        session.simple.num_bins(),
        session.theta(),
        session.log_theta()
    );

    let mut rng = Rng::new(1);
    // Servers hold the previously-aggregated model w ∈ G^m.
    let weights: Vec<u64> = (0..m).map(|i| fixed_encode(i as f32 * 0.01)).collect();

    // ----- PSR: the client privately retrieves its submodel --------------
    let selections = rng.sample_distinct(k, m);
    let (ctx, batch) =
        psr::client_query::<u64>(&session, &selections, &mut rng).map_err(|e| anyhow!("{e}"))?;
    println!(
        "PSR: client uploads {:.1} KB of DPF keys (vs {:.1} KB full download)",
        batch.upload_bits() as f64 / 8.0 / 1024.0,
        m as f64 * 8.0 / 1024.0
    );
    // Each server answers through the sharded retrieval engine (serial
    // here; `RetrievalEngine::new(n)` shards over n workers).
    let engine = RetrievalEngine::serial();
    let ans0 = engine.answer_keys(&session, &weights, &batch.server_keys(0));
    let ans1 = engine.answer_keys(&session, &weights, &batch.server_keys(1));
    let submodel = psr::client_reconstruct(&ctx, session.simple.num_bins(), &selections, &ans0, &ans1);
    for (i, &s) in selections.iter().enumerate() {
        assert_eq!(submodel[i], weights[s as usize]);
    }
    println!("PSR: retrieved all {k} weights correctly, servers saw only DPF keys ✓");

    // ----- Local training stand-in: make some updates ---------------------
    let deltas: Vec<u64> = selections
        .iter()
        .map(|&s| fixed_encode((s as f32).sin() * 0.1))
        .collect();

    // ----- SSA: three clients aggregate through the two servers ----------
    let clients: Vec<(Vec<u64>, Vec<u64>)> = (0..3)
        .map(|_| {
            let sel = rng.sample_distinct(k, m);
            let dl = sel.iter().map(|&s| fixed_encode((s as f32).sin() * 0.1)).collect();
            (sel, dl)
        })
        .collect();
    let _ = deltas;
    let res = run_ssa_round(&session, &clients, &mut rng, Duration::ZERO)?;
    println!(
        "SSA: 3 clients, upload {:.3} MB/client, server eval+agg {:?}",
        mb(res.client_upload_bytes) / 3.0,
        res.server_time
    );

    // Spot-check: the reconstructed Δw matches the plaintext sum.
    let mut expected = vec![0i64; m as usize];
    for (sel, dl) in &clients {
        for (&s, &d) in sel.iter().zip(dl) {
            expected[s as usize] = expected[s as usize].wrapping_add(d as i64);
        }
    }
    for (i, &e) in expected.iter().enumerate() {
        assert_eq!(res.delta[i] as i64, e, "position {i}");
    }
    let nonzero = res.delta.iter().filter(|&&d| d != 0).count();
    println!(
        "SSA: Δw reconstructed exactly (lossless); {} touched positions, e.g. Δw[{}] = {:.4}",
        nonzero,
        clients[0].0[0],
        fixed_decode(res.delta[clients[0].0[0] as usize])
    );
    println!("quickstart OK");
    Ok(())
}
