//! Quickstart: one persistent runtime serving a PSR retrieval round and
//! an SSA aggregation round, tiny parameters, every step spelled out.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use fsl::coordinator::FslRuntimeBuilder;
use fsl::crypto::rng::Rng;
use fsl::group::{fixed_decode, fixed_encode};
use fsl::hashing::CuckooParams;
use fsl::metrics::mb;
use fsl::protocol::SessionParams;

fn main() -> Result<()> {
    // ----- System setup (Fig. 4 "System Setup") --------------------------
    let m = 4096u64; // global model size
    let k = 64usize; // submodel size per client
    let n_clients = 3usize;

    // One builder call replaces the per-round free functions: it fixes the
    // session parameters, spawns both server threads, and keeps the
    // metered topology + engines alive for every round that follows.
    let mut rt = FslRuntimeBuilder::new(SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default(),
    })
    .max_clients(n_clients)
    .build::<u64>()?;
    println!(
        "setup: m={m}, k={k}, B={} bins, Θ={} (⌈log Θ⌉ = {})",
        rt.session().simple.num_bins(),
        rt.session().theta(),
        rt.session().log_theta()
    );

    let mut rng = Rng::new(1);
    // Servers hold the previously-aggregated model w ∈ G^m — installed
    // once, reused by every PSR round.
    let weights: Vec<u64> = (0..m).map(|i| fixed_encode(i as f32 * 0.01)).collect();
    rt.set_weights(weights.clone())?;

    // ----- PSR: clients privately retrieve their submodels ---------------
    let selections: Vec<Vec<u64>> = (0..n_clients).map(|_| rng.sample_distinct(k, m)).collect();
    let psr = rt.psr(&selections, &mut rng)?;
    for (sel, got) in selections.iter().zip(&psr.submodels) {
        for (i, &s) in sel.iter().enumerate() {
            assert_eq!(got[i], weights[s as usize]);
        }
    }
    println!(
        "PSR: {} clients retrieved all {k} weights each; upload {:.1} KB/client \
         (vs {:.1} KB full download), servers saw only DPF keys ✓",
        psr.report.clients,
        psr.report.client_upload_bytes as f64 / psr.report.clients as f64 / 1024.0,
        m as f64 * 8.0 / 1024.0
    );

    // ----- SSA: the same clients aggregate through the same servers ------
    let clients: Vec<(Vec<u64>, Vec<u64>)> = selections
        .iter()
        .map(|sel| {
            let dl = sel.iter().map(|&s| fixed_encode((s as f32).sin() * 0.1)).collect();
            (sel.clone(), dl)
        })
        .collect();
    let ssa = rt.ssa(&clients, &mut rng)?;
    println!(
        "SSA: {} clients, upload {:.3} MB/client, server eval+agg {:?} (wall {:?})",
        ssa.report.clients,
        mb(ssa.report.client_upload_bytes) / ssa.report.clients as f64,
        ssa.report.server_time,
        ssa.report.wall_time,
    );

    // Spot-check: the reconstructed Δw matches the plaintext sum.
    let mut expected = vec![0i64; m as usize];
    for (sel, dl) in &clients {
        for (&s, &d) in sel.iter().zip(dl) {
            expected[s as usize] = expected[s as usize].wrapping_add(d as i64);
        }
    }
    for (i, &e) in expected.iter().enumerate() {
        assert_eq!(ssa.delta[i] as i64, e, "position {i}");
    }
    let nonzero = ssa.delta.iter().filter(|&&d| d != 0).count();
    println!(
        "SSA: Δw reconstructed exactly (lossless); {} touched positions, e.g. Δw[{}] = {:.4}",
        nonzero,
        clients[0].0[0],
        fixed_decode(ssa.delta[clients[0].0[0] as usize])
    );
    rt.shutdown()?;
    println!("quickstart OK");
    Ok(())
}
