fn main() {
    use std::time::Instant;
    let seeds: Vec<[u8;16]> = (0..1u64<<16).map(|i| {let mut s=[0u8;16]; s[..8].copy_from_slice(&i.to_le_bytes()); s}).collect();
    let mut out = Vec::new();
    let t0 = Instant::now();
    for _ in 0..4 { fsl::crypto::prg::expand_many(&seeds, false, &mut out); }
    println!("batched: {:?} for 256K blocks", t0.elapsed());
    let t1 = Instant::now();
    let mut acc = 0u8;
    for _ in 0..4 { for s in &seeds { acc ^= fsl::crypto::prg::expand_one(s, false).seed[3]; } }
    println!("scalar:  {:?} for 256K blocks (acc {acc})", t1.elapsed());
}
