//! Mega-element FSL on the TREC-shaped text task (§6, §7.4, Tables 8/9).
//!
//! The embedding-bag model's table rows (τ = 18 weights each) are the
//! natural mega-elements. Per round, each client:
//!  1. (round 0) privately retrieves its vocabulary's embedding rows via
//!     mega-PSR — one DPF per cuckoo bin, payload = a whole row;
//!  2. locally trains (L2 `embbag_grad` artifact via PJRT);
//!  3. selects top-k *rows* by summed |Δ| (the paper's §7.4 grouping);
//!  4. uploads Δ-rows via mega-SSA; the dense non-embedding parameters go
//!     through the trivial-SA baseline, mirroring the §7.5 cost split.
//!
//! Prints the Table 9 census, per-round loss, and final accuracy.
//!
//! ```sh
//! cargo run --release --example mega_element -- rounds=25 c=0.1
//! ```

use anyhow::Result;
use fsl::baseline::trivial_sa;
use fsl::coordinator::{top_k_groups, FslRuntimeBuilder};
use fsl::crypto::rng::Rng;
use fsl::data::{TextDataset, TrecCensus};
use fsl::group::{fixed_decode, fixed_encode, MegaElem};
use fsl::hashing::CuckooParams;
use fsl::metrics::{bits_to_mb, mb};
use fsl::protocol::{mega, Session, SessionParams};
use fsl::runtime::Executor;
use std::collections::HashMap;

const TAU: usize = 18; // embedding dim = mega-element size

fn kv() -> HashMap<String, String> {
    std::env::args()
        .skip(1)
        .filter_map(|a| a.split_once('=').map(|(k, v)| (k.to_string(), v.to_string())))
        .collect()
}

fn get<T: std::str::FromStr>(kv: &HashMap<String, String>, key: &str, default: T) -> T {
    kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let kv = kv();
    let artifacts: String = get(&kv, "artifacts", "artifacts".to_string());
    let rounds: usize = get(&kv, "rounds", 60);
    let c: f64 = get(&kv, "c", 0.10); // compression over embedding rows
    let lr: f32 = get(&kv, "lr", 1.0);
    let seed: u64 = get(&kv, "seed", 7);

    let exec = Executor::new(&artifacts)?;
    let m_total = exec.manifest().int("embbag_grad", "params")? as usize;
    let m_emb = exec.manifest().int("embbag_grad", "embedding_params")? as usize;
    let vocab = exec.manifest().int("embbag_grad", "vocab")? as usize;
    let batch = exec.manifest().int("embbag_grad", "batch")? as usize;
    let classes = 6usize;
    let rows = vocab; // one mega-element per vocabulary row
    let k_rows = ((rows as f64 * c).round() as usize).max(1);

    // Table 9 census + data.
    let census = TrecCensus::default();
    println!("# Table 9 census: vocab={} clients={} train={} per-client words={} samples={}",
        census.vocab, census.clients, census.train_samples,
        census.words_per_client, census.samples_per_client);
    let data = TextDataset::synthesize(census, seed);

    // Seeded init of the flat parameter vector.
    let mut prng = Rng::new(seed ^ 0x22);
    let mut params: Vec<f32> = Vec::with_capacity(m_total);
    params.extend((0..m_emb).map(|_| prng.gen_normal() as f32 * 0.05));
    let shapes = [(TAU, 64), (64usize, 0usize), (64, classes), (classes, 0)];
    for (a, b) in shapes {
        if b > 0 {
            let s = (2.0 / a as f64).sqrt() as f32;
            params.extend((0..a * b).map(|_| prng.gen_normal() as f32 * s));
        } else {
            params.extend(std::iter::repeat(0f32).take(a));
        }
    }
    assert_eq!(params.len(), m_total);

    // One persistent mega-element runtime for the whole run: the payload
    // mode is just the group parameter (`MegaElem<TAU>` rows instead of
    // scalars), and per-round public parameters are installed with
    // `set_session` while the server threads stay alive.
    let mega_weights: Vec<MegaElem<TAU>> = mega::group_weights::<TAU>(
        &params[..m_emb].iter().map(|&f| fixed_encode(f)).collect::<Vec<_>>(),
    );
    let client_rows: Vec<u64> = data.client_vocab[0].iter().map(|&w| w as u64).collect();
    let psr_session = Session::new_full(SessionParams {
        m: rows as u64,
        k: client_rows.len(),
        cuckoo: CuckooParams::default().with_seed(seed ^ 0x77),
    });
    let mut rng = Rng::new(seed);
    // threads = 0: the co-located default (half the cores per server —
    // both servers answer concurrently in-process).
    let mut rt = FslRuntimeBuilder::from_session(psr_session)
        .threads(0)
        .max_clients(census.clients)
        .build::<MegaElem<TAU>>()?;
    rt.set_weights(mega_weights.clone())?;

    // --- Round-0 demonstration: mega-PSR retrieval of client 0's rows ---
    let psr_round = rt.psr(std::slice::from_ref(&client_rows), &mut rng)?;
    for (i, &r) in client_rows.iter().enumerate() {
        assert_eq!(psr_round.submodels[0][i], mega_weights[r as usize]);
    }
    println!(
        "# mega-PSR: client 0 retrieved {} embedding rows ({:.3} MB keys vs {:.3} MB full download)",
        client_rows.len(),
        mb(psr_round.report.client_upload_bytes),
        bits_to_mb(m_emb * 64),
    );

    // ------------------------------ training ----------------------------
    println!("# round,loss,emb_upload_mb,other_upload_mb,accuracy");
    let mut accuracy = 0.0f32;
    for round in 0..rounds {
        let mut rng = Rng::new(seed ^ (round as u64 + 1).wrapping_mul(0x9e37));
        // New public parameters for the round (re-seeded cuckoo table),
        // installed on the living servers.
        rt.set_session(Session::new_full(SessionParams {
            m: rows as u64,
            k: k_rows,
            cuckoo: CuckooParams::default().with_seed(seed ^ round as u64),
        }))?;

        let mut mega_clients: Vec<(Vec<u64>, Vec<MegaElem<TAU>>)> = Vec::new();
        let mut other_uploads: Vec<trivial_sa::TrivialUpload<u64>> = Vec::new();
        let mut loss_sum = 0.0f32;

        for cidx in 0..census.clients {
            // Local batch from this client's examples.
            let examples: Vec<(u8, Vec<u32>)> = data
                .client_examples(cidx)
                .map(|(_, l, w)| (*l, w.clone()))
                .collect();
            let items: Vec<(u8, Vec<u32>)> = (0..batch)
                .map(|_| examples[rng.gen_range(examples.len() as u64) as usize].clone())
                .collect();
            let (bow, y) = data.batch(&items);
            let step = exec.train_step("embbag_grad", &params, &bow, &y)?;
            loss_sum += step.loss;

            // Dense local delta = -lr * grad (one local iteration).
            let delta: Vec<f32> = step.grad.iter().map(|g| -lr * g).collect();

            // Embedding rows: group top-k by summed magnitude (§7.4).
            let emb_delta = &delta[..m_emb];
            let sel_rows = top_k_groups(emb_delta, TAU, k_rows);
            let payloads: Vec<MegaElem<TAU>> = sel_rows
                .iter()
                .map(|&r| {
                    let mut e = [0u64; TAU];
                    for (d, slot) in e.iter_mut().enumerate() {
                        let idx = r as usize * TAU + d;
                        if idx < m_emb {
                            *slot = fixed_encode(emb_delta[idx]);
                        }
                    }
                    MegaElem(e)
                })
                .collect();
            mega_clients.push((sel_rows, payloads));

            // Non-embedding parameters: dense trivial SA (the §7.5 split).
            let other = &delta[m_emb..];
            let other_sel: Vec<u64> = (0..other.len() as u64).collect();
            let other_deltas: Vec<u64> = other.iter().map(|&f| fixed_encode(f)).collect();
            other_uploads.push(trivial_sa::client_upload(
                other.len(),
                &other_sel,
                &other_deltas,
                rng.gen_seed(),
            ));
        }

        // Server side: mega-SSA through the runtime for embeddings +
        // trivial SA for the rest.
        let ssa_round = rt.ssa(&mega_clients, &mut rng)?;
        let mega_delta = ssa_round.delta;
        let other_delta = trivial_sa::aggregate(m_total - m_emb, &other_uploads);

        // FedAvg apply.
        let scale = 1.0 / census.clients as f32;
        for (r, e) in mega_delta.iter().enumerate() {
            for (d, &v) in e.0.iter().enumerate() {
                let idx = r * TAU + d;
                if v != 0 && idx < m_emb {
                    params[idx] += fixed_decode(v) * scale;
                }
            }
        }
        for (i, &v) in other_delta.iter().enumerate() {
            if v != 0 {
                params[m_emb + i] += fixed_decode(v) * scale;
            }
        }

        // Communication accounting (per client, measured wire bytes).
        let emb_mb = mb(ssa_round.report.client_upload_bytes) / census.clients as f64;
        let other_mb = bits_to_mb(trivial_sa::upload_bits::<u64>(m_total - m_emb));

        // Accuracy every 5 rounds and at the end.
        let evaluate = (round + 1) % 5 == 0 || round + 1 == rounds;
        if evaluate {
            let mut correct = 0usize;
            for chunk in data.test.chunks(batch) {
                let mut items = chunk.to_vec();
                while items.len() < batch {
                    items.push(chunk[0].clone());
                }
                let (bow, _) = data.batch(&items);
                let logits = exec.infer("embbag_infer", &params, &bow)?;
                for (row, (label, _)) in chunk.iter().enumerate() {
                    let rl = &logits[row * classes..(row + 1) * classes];
                    let pred = rl
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    correct += usize::from(pred == *label as usize);
                }
            }
            accuracy = correct as f32 / data.test.len() as f32;
        }
        println!(
            "{},{:.4},{:.3},{:.3},{}",
            round,
            loss_sum / census.clients as f32,
            emb_mb,
            other_mb,
            if evaluate { format!("{accuracy:.4}") } else { String::new() }
        );
    }
    rt.shutdown()?;
    println!(
        "# final accuracy {:.2}% at c={:.2}% row compression (mega-element τ={TAU})",
        accuracy * 100.0,
        c * 100.0
    );
    Ok(())
}
