//! PSU-optimised SSA round (§6, Table 2 row 2) plus the U-DPF
//! fixed-submodel flow (row 3) — the two scenario optimisations, end to
//! end on one workload.
//!
//! Scenario: n clients whose selections cluster in a small region of a
//! large model (`|∪ s^(i)| ≪ m`). The PSU reveals the union; the simple
//! table is rebuilt over it, shrinking Θ and every DPF key. Then the same
//! clients run five fixed-submodel rounds, paying full keys once and
//! `k·l`-bit U-DPF hints afterwards.
//!
//! ```sh
//! cargo run --release --example psu_round
//! ```

use anyhow::{anyhow, Result};
use fsl::crypto::rng::Rng;
use fsl::hashing::CuckooParams;
use fsl::metrics::bits_to_mb;
use fsl::protocol::{
    psr, psu, ssa, udpf_ssa, AggregationEngine, RetrievalEngine, Session, SessionParams,
};

fn main() -> Result<()> {
    let m = 1u64 << 20;
    let k = 256usize;
    let n_clients = 6usize;
    let mut rng = Rng::new(99);

    // Clients select from a hot region of ~4096 indices.
    let hot: Vec<u64> = rng.sample_distinct(4096, m);
    let client_sets: Vec<Vec<u64>> = (0..n_clients)
        .map(|_| {
            let mut s: Vec<u64> = (0..k)
                .map(|_| hot[rng.gen_range(hot.len() as u64) as usize])
                .collect();
            s.sort_unstable();
            s.dedup();
            s
        })
        .collect();

    // ---------------- PSU: reveal the union, nothing else ----------------
    let psu_key = [42u8; 16];
    let params = |seed| SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default().with_seed(seed),
    };
    // PSU + union-domain session in one step; Θ shrinks vs full-domain.
    let reduced = psu::run_psu_session(&psu_key, params(1), &client_sets, &mut rng);
    let union = reduced.domain.clone().expect("union session has a domain");
    println!(
        "PSU: {} clients, union |∪s| = {} ≪ m = {m}",
        n_clients,
        union.len()
    );
    let full = Session::new_full(params(1));
    println!(
        "Θ full-domain = {} (⌈log⌉ {}), Θ union = {} (⌈log⌉ {})",
        full.theta(),
        full.log_theta(),
        reduced.theta(),
        reduced.log_theta()
    );
    assert!(reduced.theta() < full.theta());

    // SSA over the union domain.
    let clients: Vec<(Vec<u64>, Vec<u64>)> = client_sets
        .iter()
        .map(|s| (s.clone(), s.iter().map(|&x| x + 1).collect()))
        .collect();
    let batches = clients
        .iter()
        .map(|(sel, dl)| ssa::client_update::<u64>(&reduced, sel, dl, &mut rng).map_err(|e| anyhow!("{e}")))
        .collect::<Result<Vec<_>>>()?;
    let engine = AggregationEngine::auto();
    let sh0 = engine.aggregate_keys(&reduced, &batches.iter().map(|b| b.server_keys(0)).collect::<Vec<_>>());
    let sh1 = engine.aggregate_keys(&reduced, &batches.iter().map(|b| b.server_keys(1)).collect::<Vec<_>>());
    let delta = ssa::reconstruct(&sh0, &sh1);

    // Verify against plaintext.
    for (pos, &idx) in union.iter().enumerate() {
        let expect: u64 = clients
            .iter()
            .flat_map(|(sel, dl)| sel.iter().zip(dl).filter(|(s, _)| **s == idx).map(|(_, d)| *d))
            .fold(0u64, |a, b| a.wrapping_add(b));
        assert_eq!(delta[pos], expect);
    }
    let full_bits = full.simple.num_bins() * (full.log_theta() * 130 + 64) + 256;
    let red_bits = reduced.simple.num_bins() * (reduced.log_theta() * 130 + 64) + 256;
    println!(
        "SSA upload/client: {:.4} MB over union vs {:.4} MB full-domain ({}% saved) ✓ lossless",
        bits_to_mb(red_bits),
        bits_to_mb(full_bits),
        ((1.0 - red_bits as f64 / full_bits as f64) * 100.0).round()
    );

    // ---------- PSR over the union: retrieve before training -------------
    // The read path takes the *global* m-sized weight vector even on the
    // reduced session; all clients are answered in one shard plan.
    let weights: Vec<u64> = (0..m).map(|x| x.wrapping_mul(0x9e37_79b9)).collect();
    let r_engine = RetrievalEngine::auto();
    let mut q_ctxs = Vec::new();
    let mut q_keys0 = Vec::new();
    let mut q_keys1 = Vec::new();
    for (sel, _) in &clients {
        let (ctx, batch) =
            psr::client_query::<u64>(&reduced, sel, &mut rng).map_err(|e| anyhow!("{e}"))?;
        q_ctxs.push(ctx);
        q_keys0.push(batch.server_keys(0));
        q_keys1.push(batch.server_keys(1));
    }
    let ans0 = r_engine.answer_batch_keys(&reduced, &weights, &q_keys0);
    let ans1 = r_engine.answer_batch_keys(&reduced, &weights, &q_keys1);
    for (((ctx, (sel, _)), a0), a1) in q_ctxs.iter().zip(&clients).zip(&ans0).zip(&ans1) {
        let got = psr::client_reconstruct(ctx, reduced.simple.num_bins(), sel, a0, a1);
        for (i, &s) in sel.iter().enumerate() {
            assert_eq!(got[i], weights[s as usize]);
        }
    }
    println!(
        "PSR over union: {} clients served in one shard plan ({} workers) ✓ lossless",
        clients.len(),
        r_engine.threads()
    );

    // ------------- U-DPF: fixed submodels across five epochs -------------
    let (client, mut sk0, mut sk1) = udpf_ssa::client_setup::<u64>(
        &reduced,
        &clients[0].0,
        &clients[0].1,
        &mut rng,
    )
    .map_err(|e| anyhow!("{e}"))?;
    let first_round_bits = red_bits; // full keys
    for epoch in 1..5u64 {
        let new_deltas: Vec<u64> = clients[0].1.iter().map(|d| d + epoch).collect();
        let hints = client.epoch_hints(&reduced, &clients[0].0, &new_deltas, epoch);
        sk0.apply_hints(&hints);
        sk1.apply_hints(&hints);
        let mut a0 = vec![0u64; reduced.domain_size()];
        let mut a1 = vec![0u64; reduced.domain_size()];
        sk0.aggregate_into(&reduced, epoch, &mut a0);
        sk1.aggregate_into(&reduced, epoch, &mut a1);
        let dw = ssa::reconstruct(&a0, &a1);
        for (j, &idx) in clients[0].0.iter().enumerate() {
            let pos = reduced.domain_index_of(idx).unwrap() as usize;
            assert_eq!(dw[pos], new_deltas[j], "epoch {epoch}");
        }
    }
    println!(
        "U-DPF: round-1 upload {:.4} MB, later rounds {:.4} MB (hints only), 4 epochs verified ✓",
        bits_to_mb(first_round_bits),
        bits_to_mb(client.hint_bits()),
    );
    println!("psu_round OK");
    Ok(())
}
