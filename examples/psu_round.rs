//! PSU-optimised rounds (§6, Table 2 row 2) plus the U-DPF
//! fixed-submodel flow (row 3) — the two scenario optimisations, end to
//! end on one workload, all through the persistent runtime.
//!
//! Scenario: n clients whose selections cluster in a small region of a
//! large model (`|∪ s^(i)| ≪ m`). [`FslRuntime::psu_align`] reveals the
//! union over the wire and installs the rebuilt session on both living
//! servers, shrinking Θ and every DPF key for all later rounds. A second
//! runtime in `KeyMode::Udpf` then runs fixed-submodel rounds: full keys
//! once, `k·l`-bit hints afterwards.
//!
//! ```sh
//! cargo run --release --example psu_round
//! ```

use anyhow::Result;
use fsl::coordinator::{FslRuntimeBuilder, KeyMode};
use fsl::crypto::rng::Rng;
use fsl::hashing::CuckooParams;
use fsl::metrics::mb;
use fsl::protocol::{Session, SessionParams};

fn main() -> Result<()> {
    let m = 1u64 << 20;
    let k = 256usize;
    let n_clients = 6usize;
    let mut rng = Rng::new(99);

    // Clients select from a hot region of ~4096 indices.
    let hot: Vec<u64> = rng.sample_distinct(4096, m);
    let client_sets: Vec<Vec<u64>> = (0..n_clients)
        .map(|_| {
            let mut s: Vec<u64> = (0..k)
                .map(|_| hot[rng.gen_range(hot.len() as u64) as usize])
                .collect();
            s.sort_unstable();
            s.dedup();
            s
        })
        .collect();

    let params = SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default().with_seed(1),
    };
    let mut rt = FslRuntimeBuilder::new(params.clone())
        .max_clients(n_clients)
        .build::<u64>()?;
    let full_theta = (rt.session().theta(), rt.session().log_theta());

    // ---------------- PSU: reveal the union, nothing else ----------------
    let psu_key = [42u8; 16];
    let psu = rt.psu_align(&psu_key, &client_sets, &mut rng)?;
    println!(
        "PSU: {} clients, union |∪s| = {} ≪ m = {m} ({} wire bytes client↔server)",
        n_clients,
        psu.union_len,
        psu.report.client_upload_bytes + psu.report.client_download_bytes,
    );
    println!(
        "Θ full-domain = {} (⌈log⌉ {}), Θ union = {} (⌈log⌉ {})",
        full_theta.0,
        full_theta.1,
        rt.session().theta(),
        rt.session().log_theta()
    );
    assert!(rt.session().theta() < full_theta.0);
    let union = rt.session().domain.clone().expect("union session has a domain");

    // ------------- SSA over the union-domain session ---------------------
    let clients: Vec<(Vec<u64>, Vec<u64>)> = client_sets
        .iter()
        .map(|s| (s.clone(), s.iter().map(|&x| x + 1).collect()))
        .collect();
    let ssa = rt.ssa(&clients, &mut rng)?;

    // Verify against plaintext.
    for (pos, &idx) in union.iter().enumerate() {
        let expect: u64 = clients
            .iter()
            .flat_map(|(sel, dl)| sel.iter().zip(dl).filter(|(s, _)| **s == idx).map(|(_, d)| *d))
            .fold(0u64, |a, b| a.wrapping_add(b));
        assert_eq!(ssa.delta[pos], expect);
    }
    let full_session = Session::new_full(params);
    let full_bits = full_session.simple.num_bins() * (full_session.log_theta() * 130 + 64) + 256;
    let measured_mb = mb(ssa.report.client_upload_bytes) / n_clients as f64;
    println!(
        "SSA upload/client: {measured_mb:.4} MB measured over union vs {:.4} MB full-domain model \
         ({}% saved) ✓ lossless",
        full_bits as f64 / 8.0 / (1024.0 * 1024.0),
        ((1.0 - measured_mb / (full_bits as f64 / 8.0 / (1024.0 * 1024.0))) * 100.0).round()
    );

    // ---------- PSR over the union: retrieve before training -------------
    // The read path takes the *global* m-sized weight vector even on the
    // reduced session; all clients are answered in one shard plan.
    let weights: Vec<u64> = (0..m).map(|x| x.wrapping_mul(0x9e37_79b9)).collect();
    rt.set_weights(weights.clone())?;
    let psr = rt.psr(&client_sets, &mut rng)?;
    for (sel, got) in client_sets.iter().zip(&psr.submodels) {
        for (i, &s) in sel.iter().enumerate() {
            assert_eq!(got[i], weights[s as usize]);
        }
    }
    println!(
        "PSR over union: {} clients served by the living servers \
         (download {:.4} MB/client) ✓ lossless",
        psr.report.clients,
        mb(psr.report.client_download_bytes) / n_clients as f64,
    );

    // ------------- U-DPF: fixed submodels across five epochs -------------
    // A second runtime over the same reduced session, in U-DPF key mode:
    // epoch 0 ships full key sets that both servers retain; every later
    // epoch ships only per-bin hints.
    let mut udpf_rt = FslRuntimeBuilder::from_session(rt.session().clone())
        .key_mode(KeyMode::Udpf)
        .max_clients(1)
        .build::<u64>()?;
    let mut setup_bytes = 0u64;
    let mut hint_bytes = 0u64;
    for epoch in 0..5u64 {
        let new_deltas: Vec<u64> = clients[0].1.iter().map(|d| d + epoch).collect();
        let round = udpf_rt.ssa(&[(clients[0].0.clone(), new_deltas.clone())], &mut rng)?;
        if epoch == 0 {
            setup_bytes = round.report.client_upload_bytes;
        } else {
            hint_bytes = round.report.client_upload_bytes;
        }
        for (j, &idx) in clients[0].0.iter().enumerate() {
            let pos = udpf_rt.session().domain_index_of(idx).unwrap() as usize;
            assert_eq!(round.delta[pos], new_deltas[j], "epoch {epoch}");
        }
    }
    // Wire hints are (epoch tag + ⌈log 𝔾⌉ CW) per slot vs full per-level
    // key material for re-keying; the advantage grows with ⌈log Θ⌉.
    assert!(hint_bytes * 4 < setup_bytes, "hints must be far smaller than re-keying");
    println!(
        "U-DPF: round-1 upload {:.4} MB, later rounds {:.4} MB (hints only), 4 epochs verified ✓",
        mb(setup_bytes),
        mb(hint_bytes),
    );
    udpf_rt.shutdown()?;
    rt.shutdown()?;
    println!("psu_round OK");
    Ok(())
}
