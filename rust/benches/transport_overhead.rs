//! Transport overhead: the same PSR + SSA rounds over the in-process
//! channel transport vs loopback TCP (two real server threads behind
//! real sockets, as `fsl serve` runs them).
//!
//! Both drivers consume identical rng streams, so the retrieved
//! submodels and the reconstructed delta are asserted bit-identical —
//! the transport must never change a result, only its cost. The
//! datapoint is appended to `artifacts/HISTORY.jsonl` (see
//! [`fsl::metrics::history`]) with both transports' per-party bytes
//! (client upload/download, `S_0 ↔ S_1` exchange) and wall times; TCP
//! bytes include its 7-byte-per-message framing, which is the honest
//! wire truth. `cargo run -p xtask -- bench-diff` fails on any wire-byte
//! regression between the two newest datapoints.
//!
//! `FSL_FULL=1` widens the grid; `FSL_THREADS` follows the shared bench
//! convention (unset → serial engines, so timings are reproducible).

use fsl::coordinator::{serve, FslRuntime, FslRuntimeBuilder, RoundReport, ServeOptions};
use fsl::crypto::rng::Rng;
use fsl::hashing::CuckooParams;
use fsl::net::transport::tcp::{TcpAcceptor, TcpOptions};
use fsl::protocol::{Session, SessionParams};
use std::net::TcpListener;
use std::time::Duration;

fn spawn_server(party: u8, threads: usize) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().expect("local addr").to_string();
    let handle = std::thread::spawn(move || {
        let acceptor = TcpAcceptor::new(listener, TcpOptions::default());
        let mut opts = ServeOptions::new(party);
        opts.threads = threads;
        serve::<u64>(&acceptor, &opts).expect("serve");
    });
    (addr, handle)
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn report_metrics(metrics: &mut fsl::metrics::json::JsonObj, tag: &str, r: &RoundReport) {
    metrics
        .field_f64(&format!("{tag}_wall_ms"), ms(r.wall_time), 3)
        .field_u64(&format!("{tag}_client_upload_bytes"), r.client_upload_bytes)
        .field_u64(&format!("{tag}_client_download_bytes"), r.client_download_bytes)
        .field_u64(&format!("{tag}_server_exchange_bytes"), r.server_exchange_bytes);
}

fn main() {
    let full = std::env::var("FSL_FULL").is_ok();
    let m: u64 = if full { 1 << 16 } else { 1 << 13 };
    let k: usize = if full { 512 } else { 128 };
    let clients: usize = 3;
    let threads: usize = std::env::var("FSL_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);

    let session = Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default().with_seed(0x7C9),
    });
    let weights: Vec<u64> = {
        let mut rng = Rng::new(0x5EED);
        (0..m).map(|_| rng.next_u64()).collect()
    };
    println!("# transport overhead: m={m}, k={k}, {clients} clients, {threads} engine workers");

    // One PSR + one SSA round through a given runtime; identical rng
    // streams make the results transport-independent by construction.
    let drive = |mut rt: FslRuntime<u64>| {
        let mut rng = Rng::new(0xFEED);
        rt.set_weights(weights.clone()).expect("set_weights");
        let sels: Vec<Vec<u64>> = (0..clients).map(|_| rng.sample_distinct(k, m)).collect();
        let psr = rt.psr(&sels, &mut rng).expect("psr round");
        let updates: Vec<(Vec<u64>, Vec<u64>)> = (0..clients)
            .map(|c| {
                let sel = rng.sample_distinct(k, m);
                let dl = sel.iter().map(|&x| x * 5 + c as u64 + 1).collect();
                (sel, dl)
            })
            .collect();
        let ssa = rt.ssa(&updates, &mut rng).expect("ssa round");
        rt.shutdown().expect("shutdown");
        (psr, ssa)
    };

    // In-process transport.
    let rt = FslRuntimeBuilder::from_session(session.clone())
        .threads(threads)
        .max_clients(clients)
        .build::<u64>()
        .expect("in-proc build");
    let (psr_inproc, ssa_inproc) = drive(rt);

    // Loopback TCP: two real server threads behind real sockets.
    let (addr0, h0) = spawn_server(0, threads);
    let (addr1, h1) = spawn_server(1, threads);
    let rt = FslRuntimeBuilder::from_session(session.clone())
        .max_clients(clients)
        .connect::<u64>(&addr0, &addr1)
        .expect("tcp connect");
    let (psr_tcp, ssa_tcp) = drive(rt);
    h0.join().expect("S0 thread");
    h1.join().expect("S1 thread");

    // The transport must not change results.
    assert_eq!(
        psr_inproc.submodels, psr_tcp.submodels,
        "PSR results must be bit-identical across transports"
    );
    assert_eq!(
        ssa_inproc.delta, ssa_tcp.delta,
        "SSA delta must be bit-identical across transports"
    );

    println!(
        "transport,round,wall_ms,client_upload_bytes,client_download_bytes,server_exchange_bytes"
    );
    for (transport, r) in [
        ("in-proc", &psr_inproc.report),
        ("tcp", &psr_tcp.report),
    ] {
        println!(
            "{transport},psr,{:.3},{},{},{}",
            ms(r.wall_time),
            r.client_upload_bytes,
            r.client_download_bytes,
            r.server_exchange_bytes
        );
    }
    for (transport, r) in [
        ("in-proc", &ssa_inproc.report),
        ("tcp", &ssa_tcp.report),
    ] {
        println!(
            "{transport},ssa,{:.3},{},{},{}",
            ms(r.wall_time),
            r.client_upload_bytes,
            r.client_download_bytes,
            r.server_exchange_bytes
        );
    }

    let path = fsl::metrics::history::default_path();
    match fsl::metrics::history::append_with(&path, "transport_overhead", |metrics| {
        metrics
            .field_u64("m", m)
            .field_u64("k", k as u64)
            .field_u64("clients", clients as u64)
            .field_u64("workers", threads as u64);
        report_metrics(metrics, "inproc_psr", &psr_inproc.report);
        report_metrics(metrics, "tcp_psr", &psr_tcp.report);
        report_metrics(metrics, "inproc_ssa", &ssa_inproc.report);
        report_metrics(metrics, "tcp_ssa", &ssa_tcp.report);
    }) {
        Ok(line) => println!("# appended to {}: {line}", path.display()),
        Err(e) => eprintln!("# could not append to {}: {e}", path.display()),
    }
}
