//! One-shot vs persistent runtime across a stream of SSA rounds — the
//! amortisation the `FslRuntime` API exists for.
//!
//! The one-shot path is what the deprecated `run_ssa_round` wrappers do:
//! per round, spawn both server threads, rebuild the metered topology,
//! serve once, tear everything down. The persistent path builds one
//! runtime and drives the same rounds through its living command loop.
//! Both paths consume identical rng streams, so the reconstructed deltas
//! are asserted bit-identical round by round; the datapoint is appended
//! to `artifacts/HISTORY.jsonl` (see [`fsl::metrics::history`]), where
//! `cargo run -p xtask -- bench-diff` watches the trajectory.
//!
//! `FSL_FULL=1` widens the grid; `FSL_THREADS` follows the shared bench
//! convention (unset → serial engines, so timings are reproducible).

use fsl::coordinator::FslRuntimeBuilder;
use fsl::crypto::rng::Rng;
use fsl::hashing::{scale_factor_for, CuckooParams};
use fsl::protocol::{Session, SessionParams};
use std::time::{Duration, Instant};

const ROUNDS: usize = 8;

fn client_inputs(session: &Session, n: usize, rng: &mut Rng) -> Vec<(Vec<u64>, Vec<u64>)> {
    let (m, k) = (session.params.m, session.params.k);
    (0..n)
        .map(|c| {
            let sel = rng.sample_distinct(k, m);
            let dl = sel.iter().map(|&x| x * 3 + c as u64 + 1).collect();
            (sel, dl)
        })
        .collect()
}

fn main() {
    let full = std::env::var("FSL_FULL").is_ok();
    let m: u64 = if full { 1 << 16 } else { 1 << 13 };
    let k: usize = if full { 512 } else { 128 };
    let clients: usize = 4;
    let threads: usize = std::env::var("FSL_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);

    let session = Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams {
            epsilon: scale_factor_for(m as usize),
            hash_seed: 0x2024,
            ..CuckooParams::default()
        },
    });
    println!(
        "# SSA round stream: m={m}, k={k}, {clients} clients, {ROUNDS} rounds, \
         {threads} engine workers"
    );

    // One-shot: a fresh runtime per round (what the deprecated wrappers
    // do), including thread spawn + topology + engine construction.
    let mut rng = Rng::new(0x600d);
    let t0 = Instant::now();
    let mut oneshot_rounds = Vec::with_capacity(ROUNDS);
    let mut oneshot_deltas = Vec::with_capacity(ROUNDS);
    for _ in 0..ROUNDS {
        let inputs = client_inputs(&session, clients, &mut rng);
        let t = Instant::now();
        let mut rt = FslRuntimeBuilder::from_session(session.clone())
            .threads(threads)
            .max_clients(clients)
            .build::<u64>()
            .expect("build one-shot runtime");
        let out = rt.ssa(&inputs, &mut rng).expect("one-shot round");
        drop(rt);
        oneshot_rounds.push(t.elapsed());
        oneshot_deltas.push(out.delta);
    }
    let oneshot_total = t0.elapsed();

    // Persistent: one runtime serves the whole stream.
    let mut rng = Rng::new(0x600d);
    let t1 = Instant::now();
    let mut rt = FslRuntimeBuilder::from_session(session.clone())
        .threads(threads)
        .max_clients(clients)
        .build::<u64>()
        .expect("build persistent runtime");
    let mut persistent_rounds = Vec::with_capacity(ROUNDS);
    for (round, oneshot_delta) in oneshot_deltas.iter().enumerate() {
        let inputs = client_inputs(&session, clients, &mut rng);
        let t = Instant::now();
        let out = rt.ssa(&inputs, &mut rng).expect("persistent round");
        persistent_rounds.push(t.elapsed());
        assert_eq!(
            &out.delta, oneshot_delta,
            "round {round}: persistent delta must be bit-identical to one-shot"
        );
    }
    rt.shutdown().expect("clean shutdown");
    let persistent_total = t1.elapsed();

    let ms = |d: Duration| d.as_secs_f64() * 1e3;
    let mean = |v: &[Duration]| ms(v.iter().sum::<Duration>()) / v.len() as f64;
    let oneshot_ms = mean(&oneshot_rounds);
    let persistent_ms = mean(&persistent_rounds);
    println!("mode,mean_round_ms,total_ms");
    println!("one-shot,{oneshot_ms:.3},{:.3}", ms(oneshot_total));
    println!("persistent,{persistent_ms:.3},{:.3}", ms(persistent_total));
    println!(
        "# per-round setup amortised by the persistent runtime: {:.3} ms",
        oneshot_ms - persistent_ms
    );

    let path = fsl::metrics::history::default_path();
    match fsl::metrics::history::append_with(&path, "round_runtime", |metrics| {
        metrics
            .field_u64("m", m)
            .field_u64("k", k as u64)
            .field_u64("clients", clients as u64)
            .field_u64("rounds", ROUNDS as u64)
            .field_u64("workers", threads as u64)
            .field_f64("oneshot_mean_round_ms", oneshot_ms, 3)
            .field_f64("persistent_mean_round_ms", persistent_ms, 3)
            .field_f64("oneshot_total_ms", ms(oneshot_total), 3)
            .field_f64("persistent_total_ms", ms(persistent_total), 3)
            .field_f64("amortised_ms_per_round", oneshot_ms - persistent_ms, 3);
    }) {
        Ok(line) => println!("# appended to {}: {line}", path.display()),
        Err(e) => eprintln!("# could not append to {}: {e}", path.display()),
    }
}
