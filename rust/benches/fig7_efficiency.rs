//! Figure 7 — protocol efficiency at m = 2^15 across compression rates
//! 10% … 100%: client Gen time, server Eval+Agg time, and upload size.
//!
//! The paper's observation to reproduce: server runtime is almost flat in
//! c (bins grow but each bin's Θ shrinks), client Gen is linear in c.

use fsl::crypto::rng::Rng;
use fsl::hashing::{scale_factor_for, CuckooParams};
use fsl::metrics::bits_to_mb;
use fsl::protocol::{ssa, AggregationEngine, Session, SessionParams};
use std::time::Instant;

fn main() {
    let m = 1u64 << 15;
    let engine = AggregationEngine::from_env();
    println!("# Figure 7 series at m=2^15: c,gen_ms,server_ms,upload_mb(l=128 model)");
    println!("# engine workers: {} (set FSL_THREADS to shard)", engine.threads());
    println!("c,gen_ms,server_ms,upload_mb");
    let mut first_server = None;
    let mut last_server = None;
    for pct in (10..=100).step_by(10) {
        let c = pct as f64 / 100.0;
        let k = ((m as f64 * c) as usize).max(1).min(m as usize);
        let session = Session::new_full(SessionParams {
            m,
            k,
            cuckoo: CuckooParams {
                epsilon: scale_factor_for(m as usize),
                hash_seed: 0x717,
                ..CuckooParams::default()
            },
        });
        let mut rng = Rng::new(pct as u64);
        let sel = rng.sample_distinct(k, m);
        let dl: Vec<u64> = sel.iter().map(|&x| x + 1).collect();

        let t0 = Instant::now();
        let batch = ssa::client_update(&session, &sel, &dl, &mut rng).unwrap();
        let gen_ms = t0.elapsed().as_secs_f64() * 1e3;

        let keys = batch.server_keys(0);
        let t1 = Instant::now();
        let acc = engine.aggregate_keys(&session, std::slice::from_ref(&keys));
        let server_ms = t1.elapsed().as_secs_f64() * 1e3;
        std::hint::black_box(&acc);

        let upload = bits_to_mb(session.simple.num_bins() * (session.log_theta() * 130 + 128) + 256);
        println!("{c:.1},{gen_ms:.3},{server_ms:.3},{upload:.3}");
        if pct == 10 {
            first_server = Some(server_ms);
        }
        if pct == 100 {
            last_server = Some(server_ms);
        }
    }
    if let (Some(a), Some(b)) = (first_server, last_server) {
        println!(
            "# server runtime flatness: c=100% / c=10% = {:.2}x (paper: ≈1, client-side linear) {}",
            b / a,
            if b / a < 4.0 { "✓" } else { "✗" }
        );
    }
}
