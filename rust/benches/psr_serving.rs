//! PSR serving throughput — the read-path counterpart of the Table-5
//! computation bench: a batch of concurrent client queries answered
//! serially vs through the sharded [`RetrievalEngine`].
//!
//! Every deployed client retrieves its submodel before it trains, so this
//! is the path a production service hammers hardest; the datapoint is
//! appended to `artifacts/HISTORY.jsonl` (see [`fsl::metrics::history`])
//! so the retrieval perf trajectory persists across revisions —
//! `cargo run -p xtask -- bench-diff` compares the two newest datapoints.
//!
//! Defaults: m = 2^14, k = 512 (B ≈ 650 bins), 8 clients — comfortably
//! above the ≥ 8 bins × ≥ 4 clients floor where sharding must win.
//! `FSL_FULL=1` widens the grid; `FSL_THREADS=N` picks the sharded width
//! (unset/0 → one worker per core, so the speedup datapoint exists even
//! under the benches' serial-default convention).

use fsl::crypto::rng::Rng;
use fsl::hashing::{scale_factor_for, CuckooParams};
use fsl::protocol::{psr, RetrievalEngine, Session, SessionParams};
use std::time::{Duration, Instant};

fn best_of<T>(reps: usize, mut f: impl FnMut() -> T) -> (Duration, T) {
    let mut best = Duration::MAX;
    let mut out = None;
    for _ in 0..reps {
        let t = Instant::now();
        let v = f();
        best = best.min(t.elapsed());
        out = Some(v);
    }
    (best, out.expect("reps >= 1"))
}

fn main() {
    let full = std::env::var("FSL_FULL").is_ok();
    let m: u64 = if full { 1 << 17 } else { 1 << 14 };
    let k: usize = 512;
    let clients: usize = if full { 16 } else { 8 };
    let reps = if full { 5 } else { 3 };

    let session = Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams {
            epsilon: scale_factor_for(m as usize),
            hash_seed: 0x9512,
            ..CuckooParams::default()
        },
    });
    let mut rng = Rng::new(0x9512);
    let weights: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
    let keys0: Vec<_> = (0..clients)
        .map(|_| {
            let sel = rng.sample_distinct(k, m);
            let (_ctx, batch) =
                psr::client_query::<u64>(&session, &sel, &mut rng).expect("cuckoo build");
            batch.server_keys(0)
        })
        .collect();
    let bins = session.simple.num_bins();

    let serial = RetrievalEngine::serial();
    // Unset defaults to one worker per core (this bench exists to show the
    // speedup); when set, the shared FSL_THREADS convention applies
    // (0 → auto, N → N, non-numeric → warn and run serial).
    let sharded = match std::env::var("FSL_THREADS") {
        Err(_) => RetrievalEngine::auto(),
        Ok(_) => RetrievalEngine::from_env(),
    };
    println!("# PSR serving: m={m}, k={k}, B={bins} bins, {clients} clients, best of {reps}");
    println!(
        "# serial baseline = 1 worker; sharded = {} workers (FSL_THREADS to override)",
        sharded.threads()
    );

    let (t_serial, base) = best_of(reps, || serial.answer_batch_keys(&session, &weights, &keys0));
    let (t_sharded, got) = best_of(reps, || sharded.answer_batch_keys(&session, &weights, &keys0));
    assert_eq!(got, base, "sharded answers must be bit-identical to serial");

    let serial_ms = t_serial.as_secs_f64() * 1e3;
    let sharded_ms = t_sharded.as_secs_f64() * 1e3;
    let speedup = serial_ms / sharded_ms.max(1e-9);
    println!("mode,workers,ms");
    println!("serial,1,{serial_ms:.2}");
    println!("sharded,{},{sharded_ms:.2}", sharded.threads());
    println!("# speedup: {speedup:.2}x");

    let path = fsl::metrics::history::default_path();
    let workers = sharded.threads() as u64;
    match fsl::metrics::history::append_with(&path, "psr_serving", |metrics| {
        metrics
            .field_u64("m", m)
            .field_u64("k", k as u64)
            .field_u64("clients", clients as u64)
            .field_u64("bins", bins as u64)
            .field_u64("workers", workers)
            .field_f64("serial_ms", serial_ms, 3)
            .field_f64("sharded_ms", sharded_ms, 3)
            .field_f64("speedup", speedup, 3);
    }) {
        Ok(line) => println!("# appended to {}: {line}", path.display()),
        Err(e) => eprintln!("# could not append to {}: {e}", path.display()),
    }
}
