//! Figure 6 — computation efficiency curves: DPF Gen / Eval+Agg wall
//! time as the number of weights grows, at c ∈ {10%, 20%, 30%}.
//!
//! Emits CSV series (one row per (m, c)) — the same data Figure 6 plots.
//! Default sweep: m = 2^10 … 2^18 (FSL_FULL=1 extends to 2^20).

use fsl::crypto::rng::Rng;
use fsl::hashing::{scale_factor_for, CuckooParams};
use fsl::protocol::{ssa, AggregationEngine, Session, SessionParams};
use std::time::Instant;

fn main() {
    let full = std::env::var("FSL_FULL").is_ok();
    let max_log = if full { 20 } else { 18 };
    let engine = AggregationEngine::from_env();
    println!("# Figure 6 series: m,c,gen_ms,server_ms (client DPF Gen; server full-domain eval+agg)");
    println!("# engine workers: {} (set FSL_THREADS to shard)", engine.threads());
    println!("m,c,gen_ms,server_ms");
    for log_m in (10..=max_log).step_by(2) {
        let m = 1u64 << log_m;
        for &c in &[0.10, 0.20, 0.30] {
            let k = ((m as f64 * c) as usize).max(1);
            let session = Session::new_full(SessionParams {
                m,
                k,
                cuckoo: CuckooParams {
                    epsilon: scale_factor_for(m as usize),
                    hash_seed: 0xF16,
                    ..CuckooParams::default()
                },
            });
            let mut rng = Rng::new(log_m as u64 ^ 0x5EED);
            let sel = rng.sample_distinct(k, m);
            let dl: Vec<u64> = sel.iter().map(|&x| x + 1).collect();

            let t0 = Instant::now();
            let batch = ssa::client_update(&session, &sel, &dl, &mut rng).unwrap();
            let gen_ms = t0.elapsed().as_secs_f64() * 1e3;

            let keys = batch.server_keys(0);
            let t1 = Instant::now();
            let acc = engine.aggregate_keys(&session, std::slice::from_ref(&keys));
            let server_ms = t1.elapsed().as_secs_f64() * 1e3;
            std::hint::black_box(&acc);

            println!("{m},{c},{gen_ms:.3},{server_ms:.3}");
        }
    }
    println!("# shape: both series grow ~linearly in m; Gen scales with c, server side barely does.");
}
