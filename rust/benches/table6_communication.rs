//! Table 6 — communication efficiency of basic SSA vs naïve two-server
//! secure aggregation, plus the §6 advantage-rate table (Table 2
//! scenarios).
//!
//! Reports three numbers per cell: the paper's analytic model at l = 128
//! with fixed ⌈log Θ⌉ = 9 (what Table 6 prints), the same model with the
//! *adaptive* Θ our implementation uses, and the bytes actually measured
//! on the wire by the channel meters.

use fsl::baseline::trivial_sa;
use fsl::coordinator::FslRuntimeBuilder;
use fsl::crypto::rng::Rng;
use fsl::hashing::{scale_factor_for, CuckooParams};
use fsl::metrics::bits_to_mb;
use fsl::protocol::{mega, Session, SessionParams};

fn paper_model_mb(bins: usize, log_theta: usize, l: usize) -> f64 {
    bits_to_mb(bins * (log_theta * (128 + 2) + l) + 2 * 128)
}

fn main() {
    println!("# Table 6: client upload (MB). paper @2^15: SA 0.5; ours 0.063/0.317/0.633 (1/5/10%)");
    println!(
        "{:>8} {:>5} {:>12} {:>12} {:>12} {:>12}",
        "m", "c", "paper(l128)", "adaptiveΘ", "measured", "trivial SA"
    );
    for &m in &[1u64 << 10, 1 << 15, 1 << 20] {
        for &c in &[0.01, 0.05, 0.10] {
            let k = ((m as f64 * c) as usize).max(1);
            let session = Session::new_full(SessionParams {
                m,
                k,
                cuckoo: CuckooParams {
                    epsilon: scale_factor_for(m as usize),
                    hash_seed: 0xA11CE,
                    ..CuckooParams::default()
                },
            });
            let bins = session.simple.num_bins();
            let paper = paper_model_mb(bins, 9, 128);
            let adaptive = paper_model_mb(bins, session.log_theta(), 128);
            // Measured: run the protocol (l = 64 ring) and scale to l=128
            // for comparability (payload bits double, CW bits identical).
            let mut rng = Rng::new(3);
            let sel = rng.sample_distinct(k, m);
            let dl: Vec<u64> = sel.iter().map(|&x| x + 1).collect();
            let mut rt = FslRuntimeBuilder::from_session(session.clone())
                .build::<u64>()
                .unwrap();
            let res = rt.ssa(&[(sel, dl)], &mut rng).unwrap();
            let measured_l128 =
                fsl::metrics::mb(res.report.client_upload_bytes) + bits_to_mb(bins * 64);
            let trivial = bits_to_mb(trivial_sa::upload_bits::<u128>(m as usize));
            println!(
                "{:>8} {:>5} {:>12.3} {:>12.3} {:>12.3} {:>12.3}",
                format!("2^{}", m.trailing_zeros()),
                format!("{}%", (c * 100.0) as u32),
                paper,
                adaptive,
                measured_l128,
                trivial
            );
        }
    }

    println!("\n# §6 advantage rates R(π) (< 1 ⇒ non-trivial), paper constants ε=1.25 l=λ=128 ⌈logΘ⌉=9:");
    println!("{:>28} {:>8} {:>8} {:>8}", "scenario", "c=5%", "c=7.8%", "c=13%");
    let basic = |c| mega::advantage_rate_basic(c, 1.25, 9, 128, 128);
    let psu = |c| mega::advantage_rate_basic(c, 1.25, 5, 128, 128);
    let mega18 = |c| mega::advantage_rate_mega(c, 1.25, 9, 128, 128, 18);
    for (name, f) in [
        ("basic (Table 2 row 1)", &basic as &dyn Fn(f64) -> f64),
        ("basic + PSU (⌈logΘ⌉=5)", &psu),
        ("mega-element τ=18", &mega18),
    ] {
        println!(
            "{:>28} {:>8.3} {:>8.3} {:>8.3}",
            name,
            f(0.05),
            f(0.078),
            f(0.13)
        );
    }
    println!("# paper crossovers: basic ≈ 7.8%, PSU ≈ 13.4% (exact Eq.1: 13.2%), mega τ=18 ≈ 53.1%");
    println!(
        "# mega τ=18 crossover check: R(0.53) = {:.3}, R(0.55) = {:.3}",
        mega18(0.53),
        mega18(0.55)
    );
}
