//! Table 8 — FSL accuracy with top-k *mega-element* (embedding-row)
//! selection on the text task, across very aggressive compression rates.
//!
//! Paper: 84.73 (0.0125%) / 88.60 (0.1%) / 89.67 (1%) / 89.73 (10%) —
//! robust down to extreme compression, collapsing only at the very
//! bottom. The sweep runs the embedding-bag model with top-k rows (τ=18)
//! over the embedding layer only (the paper computes c w.r.t. the
//! embedding layer). Plaintext FedAvg loop (provably equal to the secure
//! path; see `secure_equals_plain`). FSL_FULL=1 widens the sweep.

use anyhow::Result;
use fsl::coordinator::top_k_groups;
use fsl::crypto::rng::Rng;
use fsl::data::{TextDataset, TrecCensus};
use fsl::runtime::Executor;

const TAU: usize = 18;

fn main() -> Result<()> {
    let full = std::env::var("FSL_FULL").is_ok();
    let exec = Executor::new("artifacts")?;
    let m_total = exec.manifest().int("embbag_grad", "params")? as usize;
    let m_emb = exec.manifest().int("embbag_grad", "embedding_params")? as usize;
    let batch = exec.manifest().int("embbag_grad", "batch")? as usize;
    let classes = 6usize;
    let rows = m_emb / TAU;

    let rates: Vec<f64> = if full {
        vec![0.000125, 0.001, 0.01, 0.10, 1.0]
    } else {
        vec![0.001, 0.01, 0.10]
    };
    let rounds = if full { 150 } else { 60 };
    let census = TrecCensus::default();
    let data = TextDataset::synthesize(census, 5);

    println!("# Table 8 (text task): accuracy vs mega-element compression (over embedding layer)");
    println!("# paper TREC: 84.73 (0.0125%) / 88.60 (0.1%) / 89.67 (1%) / 89.73 (10%)");
    println!("{:>10} {:>10}", "c(emb)", "accuracy");

    for &c in &rates {
        let k_rows = ((rows as f64 * c).round() as usize).max(1);
        let seed = 7u64;
        let mut prng = Rng::new(seed ^ 0x22);
        let mut params: Vec<f32> = Vec::with_capacity(m_total);
        params.extend((0..m_emb).map(|_| prng.gen_normal() as f32 * 0.05));
        params.extend((0..TAU * 64).map(|_| prng.gen_normal() as f32 * 0.33));
        params.extend(std::iter::repeat(0f32).take(64));
        params.extend((0..64 * classes).map(|_| prng.gen_normal() as f32 * 0.18));
        params.extend(std::iter::repeat(0f32).take(classes));
        assert_eq!(params.len(), m_total);

        let mut rng = Rng::new(seed);
        for _round in 0..rounds {
            // All 4 clients participate (paper: full participation on TREC).
            let mut sum = vec![0f32; m_total];
            for cidx in 0..census.clients {
                let examples: Vec<(u8, Vec<u32>)> = data
                    .client_examples(cidx)
                    .map(|(_, l, w)| (*l, w.clone()))
                    .collect();
                let items: Vec<(u8, Vec<u32>)> = (0..batch)
                    .map(|_| examples[rng.gen_range(examples.len() as u64) as usize].clone())
                    .collect();
                let (bow, y) = data.batch(&items);
                let step = exec.train_step("embbag_grad", &params, &bow, &y)?;
                let delta: Vec<f32> = step.grad.iter().map(|g| -1.0 * g).collect();
                // Embedding: top-k rows only; other params: dense.
                let sel = top_k_groups(&delta[..m_emb], TAU, k_rows);
                for &r in &sel {
                    for d in 0..TAU {
                        let idx = r as usize * TAU + d;
                        sum[idx] += delta[idx];
                    }
                }
                for i in m_emb..m_total {
                    sum[i] += delta[i];
                }
            }
            let scale = 1.0 / census.clients as f32;
            for (p, s) in params.iter_mut().zip(&sum) {
                *p += s * scale;
            }
        }
        // Evaluate.
        let mut correct = 0usize;
        for chunk in data.test.chunks(batch) {
            let mut items = chunk.to_vec();
            while items.len() < batch {
                items.push(chunk[0].clone());
            }
            let (bow, _) = data.batch(&items);
            let logits = exec.infer("embbag_infer", &params, &bow)?;
            for (row, (label, _)) in chunk.iter().enumerate() {
                let rl = &logits[row * classes..(row + 1) * classes];
                let pred = rl
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct += usize::from(pred == *label as usize);
            }
        }
        let acc = correct as f32 / data.test.len() as f32;
        println!("{:>10} {:>10.2}", format!("{:.4}%", c * 100.0), acc * 100.0);
    }
    println!("# shape: accuracy robust across orders of magnitude of compression, degrading only at the extreme low end.");
    Ok(())
}
