//! Table 4 — maximum simple-table bin size Θ vs (m, compression rate).
//!
//! Reproduces the paper's grid: insert `{1..m}` into the simple table
//! with `B = ⌈ε·c·m⌉` bins and report the max bin size. The paper's
//! conclusion to verify: for m ≤ 2^25 and c ≥ 1%, a fixed ⌈log Θ⌉ = 9
//! (Θ ≤ 512) always suffices. FSL_FULL=1 adds m = 2^25.

use fsl::hashing::{scale_factor_for, CuckooParams, SimpleTable};

fn main() {
    let full = std::env::var("FSL_FULL").is_ok();
    let sizes: Vec<u64> = if full {
        vec![1 << 10, 1 << 15, 1 << 20, 1 << 25]
    } else {
        vec![1 << 10, 1 << 15, 1 << 20]
    };
    let rates = [0.01, 0.10, 0.30, 0.50, 0.70];
    println!("# Table 4: max simple-table bin size Θ (paper at m=2^15: 315/54/36/24/21)");
    print!("{:>6}", "c\\m");
    for &m in &sizes {
        print!(" {:>10}", format!("2^{}", m.trailing_zeros()));
    }
    println!();
    let mut log_theta_max = 0usize;
    for &c in &rates {
        print!("{:>6}", format!("{}%", (c * 100.0) as u32));
        for &m in &sizes {
            let k = ((m as f64 * c) as usize).max(1);
            let params = CuckooParams {
                epsilon: scale_factor_for(m as usize),
                ..CuckooParams::default()
            };
            let bins = params.num_bins(k);
            let table = SimpleTable::build_full(m, bins, &params);
            let theta = table.max_bin_size();
            log_theta_max = log_theta_max.max(fsl::dpf::depth_for(theta.max(2)));
            print!(" {theta:>10}");
        }
        println!();
    }
    println!(
        "# max ⌈log Θ⌉ over the grid = {log_theta_max} (paper: fixed 9 suffices for c ≥ 1%) {}",
        if log_theta_max <= 9 { "✓" } else { "✗" }
    );
}
