//! Table 7 — FSL accuracy vs top-k compression rate.
//!
//! The paper trains MNIST/CIFAR10/TREC models for thousands of rounds at
//! c ∈ {5%,…,100%} and shows accuracy is nearly flat above a small
//! threshold. We reproduce the *curve shape* on the synthetic tasks
//! (DESIGN.md §5 substitution) with the plaintext FedAvg loop — which the
//! `secure_equals_plain` integration test proves is bit-identical to the
//! secure SSA path, so accuracy results transfer exactly.
//!
//! Default: reduced sweep (image task, 3 rates, 1 seed, few rounds) so
//! `cargo bench` stays quick. FSL_FULL=1 runs the wider grid recorded in
//! EXPERIMENTS.md.

use anyhow::Result;
use fsl::coordinator::{run_plain_training, FslConfig};
use fsl::crypto::rng::Rng;
use fsl::data::{partition_iid, ImageDataset, IMAGE_CLASSES};
use fsl::runtime::Executor;

fn eval_acc(exec: &Executor, params: &[f32], test: &ImageDataset, batch: usize) -> Result<f32> {
    let mut correct = 0usize;
    for chunk in (0..test.n).collect::<Vec<_>>().chunks(batch) {
        let mut idx = chunk.to_vec();
        while idx.len() < batch {
            idx.push(chunk[0]);
        }
        let (x, _) = test.batch(&idx);
        let logits = exec.infer("mlp_infer", params, &x)?;
        for (row, &i) in chunk.iter().enumerate() {
            let rl = &logits[row * IMAGE_CLASSES..(row + 1) * IMAGE_CLASSES];
            let pred = rl
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred == test.y[i] as usize);
        }
    }
    Ok(correct as f32 / test.n as f32)
}

fn main() -> Result<()> {
    let full = std::env::var("FSL_FULL").is_ok();
    let exec = Executor::new("artifacts")?;
    let m = exec.manifest().int("mlp_grad", "params")? as usize;
    let batch = exec.manifest().int("mlp_grad", "batch")? as usize;

    let rates: Vec<f64> = if full {
        vec![0.01, 0.05, 0.10, 0.20, 0.40, 0.60, 0.80, 1.00]
    } else {
        vec![0.01, 0.05, 0.20, 1.00]
    };
    let seeds: Vec<u64> = if full { vec![41, 42, 43] } else { vec![42] };
    let rounds = if full { 60 } else { 15 };

    println!("# Table 7 (image task): accuracy vs compression rate");
    println!("# paper MNIST: 97.36 (5%) … 97.47 (100%) — flat curve, ≤0.11% drop at 50× compression");
    println!("{:>6} {:>12} {:>8}", "c", "acc mean", "± std");

    // difficulty 3.0 gives the task headroom so the compression curve is visible
    let (train, test) = ImageDataset::synthesize_split(1200, 300, 1, 3.0);
    let mut results: Vec<(f64, f32)> = Vec::new();
    for &c in &rates {
        let mut accs = Vec::new();
        for &seed in &seeds {
            let cfg = FslConfig {
                num_clients: 4,
                participation: 1.0,
                rounds,
                local_iters: 2,
                lr: 0.1,
                compression: c,
                seed,
                eval_every: 0,
                ..FslConfig::default()
            };
            let mut rng = Rng::new(seed);
            let shards = partition_iid(train.n, cfg.num_clients, &mut rng);
            // Seeded init.
            let layers = [(784usize, 1024usize), (1024, 1024), (1024, 10)];
            let mut prng = Rng::new(seed ^ 0x1111);
            let mut params = Vec::with_capacity(m);
            for (i, o) in layers {
                let s = (2.0 / i as f64).sqrt() as f32;
                params.extend((0..i * o).map(|_| prng.gen_normal() as f32 * s));
                params.extend(std::iter::repeat(0f32).take(o));
            }
            let finalp = run_plain_training(&exec, &cfg, "mlp_grad", params, |client, _it, r| {
                let shard = &shards[client];
                let idx: Vec<usize> = (0..batch)
                    .map(|_| shard[r.gen_range(shard.len() as u64) as usize])
                    .collect();
                train.batch(&idx)
            })?;
            accs.push(eval_acc(&exec, &finalp, &test, batch)?);
        }
        let mean = accs.iter().sum::<f32>() / accs.len() as f32;
        let std = (accs.iter().map(|a| (a - mean).powi(2)).sum::<f32>() / accs.len() as f32).sqrt();
        println!("{:>6} {:>12.2} {:>8.2}", format!("{}%", (c * 100.0) as u32), mean * 100.0, std * 100.0);
        results.push((c, mean));
    }
    // Shape check: accuracy at the smallest rate within a few points of 100%.
    let lo = results.first().unwrap().1;
    let hi = results.last().unwrap().1;
    println!(
        "# drop from c=100% to c={}%: {:.2} pts (paper: flat ≥5%, drop only at extreme c) {}",
        (results[0].0 * 100.0) as u32,
        (hi - lo) * 100.0,
        if (hi - lo) < 0.08 { "✓" } else { "(needs more rounds — run FSL_FULL=1)" }
    );
    Ok(())
}
