//! Table 5 — computation efficiency of the basic SSA protocol.
//!
//! For m ∈ {2^10, 2^15, 2^20} and c ∈ {10%, 20%, 30%}: client DPF key
//! generation time, server DPF (full-domain) evaluation time, and server
//! aggregation time, separated exactly as the paper separates them
//! (Eval = expand every bin's tree; Aggregation = scatter-sum of the leaf
//! shares). l = 64 here (fixed-point ring); the paper uses l = 128 — key
//! sizes differ, AES work does not. FSL_FULL=1 uses the paper's exact
//! grid; default trims m = 2^20 to c = 10% to stay quick.

use fsl::crypto::rng::Rng;
use fsl::dpf;
use fsl::hashing::{scale_factor_for, CuckooParams};
use fsl::protocol::{AggregationEngine, Session, SessionParams};
use std::time::{Duration, Instant};

struct Row {
    m: u64,
    gen: Duration,
    eval: Duration,
    agg: Duration,
    engine: Duration,
}

fn run_cell(m: u64, c: f64, seed: u64, engine: &AggregationEngine) -> Row {
    let k = ((m as f64 * c) as usize).max(1);
    let session = Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams {
            epsilon: scale_factor_for(m as usize),
            hash_seed: seed,
            ..CuckooParams::default()
        },
    });
    let mut rng = Rng::new(seed);
    let sel = rng.sample_distinct(k, m);
    let deltas: Vec<u64> = sel.iter().map(|&x| x + 1).collect();

    // Client: DPF Gen for all bins (the paper's "DPF Gen time").
    let t0 = Instant::now();
    let batch = fsl::protocol::ssa::client_update(&session, &sel, &deltas, &mut rng).unwrap();
    let gen = t0.elapsed();

    // Server: evaluation (full-domain eval of every bin) …
    let keys = batch.server_keys(0);
    let num_bins = session.simple.num_bins();
    let t1 = Instant::now();
    let evals: Vec<Vec<u64>> = keys[..num_bins]
        .iter()
        .enumerate()
        .map(|(j, key)| dpf::full_eval(key, session.simple.bin(j).len()))
        .collect();
    let eval = t1.elapsed();

    // … then aggregation (scatter-sum into the global update share).
    let t2 = Instant::now();
    let mut acc = vec![0u64; m as usize];
    for (j, ev) in evals.iter().enumerate() {
        for (d, &idx) in session.simple.bin(j).iter().enumerate() {
            acc[idx as usize] = acc[idx as usize].wrapping_add(ev[d]);
        }
    }
    let agg = t2.elapsed();
    std::hint::black_box(&acc);

    // The production path: the unified engine does eval + scatter in one
    // sharded pass (stash keys included), reusing per-worker buffers.
    let t3 = Instant::now();
    let share = engine.aggregate_keys(&session, std::slice::from_ref(&keys));
    let eng = t3.elapsed();
    std::hint::black_box(&share);
    let _ = c;
    Row {
        m,
        gen,
        eval,
        agg,
        engine: eng,
    }
}

fn main() {
    let full = std::env::var("FSL_FULL").is_ok();
    let engine = AggregationEngine::from_env();
    println!("# Table 5: computation efficiency of basic SSA (one client / one server), seconds");
    println!("# paper @2^15/10%: Gen 0.838s Eval 0.253s Agg 0.018s (64-core Xeon, l=128)");
    println!(
        "# Engine(s) = unified sharded eval+agg pass, {} worker(s) (set FSL_THREADS)",
        engine.threads()
    );
    println!(
        "{:>8} {:>5} {:>10} {:>10} {:>10} {:>10}",
        "m", "c", "Gen(s)", "Eval(s)", "Agg(s)", "Engine(s)"
    );
    let mut grid: Vec<(u64, f64)> = Vec::new();
    for &m in &[1u64 << 10, 1 << 15, 1 << 20] {
        for &c in &[0.10, 0.20, 0.30] {
            if !full && m == 1 << 20 && c > 0.10 {
                continue;
            }
            grid.push((m, c));
        }
    }
    let mut rows = Vec::new();
    for (m, c) in grid {
        let row = run_cell(m, c, 0xBEEF ^ m, &engine);
        println!(
            "{:>8} {:>5} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
            format!("2^{}", m.trailing_zeros()),
            format!("{}%", (c * 100.0) as u32),
            row.gen.as_secs_f64(),
            row.eval.as_secs_f64(),
            row.agg.as_secs_f64(),
            row.engine.as_secs_f64()
        );
        rows.push(row);
    }
    // Shape checks the paper claims (§7.2).
    let gen_linear = rows
        .iter()
        .filter(|r| r.m == 1 << 15)
        .collect::<Vec<_>>();
    if gen_linear.len() >= 2 {
        let ratio =
            gen_linear.last().unwrap().gen.as_secs_f64() / gen_linear[0].gen.as_secs_f64();
        println!(
            "# client Gen grows ~linearly in c (2^15: 30%/10% ratio = {ratio:.2}, paper 2.04) {}",
            if (1.2..6.0).contains(&ratio) { "✓" } else { "✗" }
        );
    }
    println!("# server Eval+Agg nearly flat in c (bins shrink as Θ grows) — compare columns above.");
}
