//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. full-domain evaluation vs point-wise Eval on the server;
//! 2. adaptive per-bin Θ vs the fixed ⌈log Θ⌉ = 9 of the paper's
//!    communication model;
//! 3. master-seed derivation vs per-bin seeds in client upload;
//! 4. U-DPF hints vs re-keying for fixed submodels.

use fsl::crypto::rng::Rng;
use fsl::dpf;
use fsl::hashing::{scale_factor_for, CuckooParams};
use fsl::metrics::bits_to_mb;
use fsl::protocol::{ssa, Session, SessionParams};
use std::time::Instant;

fn main() {
    let m = 1u64 << 15;
    let c = 0.10;
    let k = (m as f64 * c) as usize;
    let session = Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams {
            epsilon: scale_factor_for(m as usize),
            hash_seed: 0xAB1,
            ..CuckooParams::default()
        },
    });
    let mut rng = Rng::new(0xAB1);
    let sel = rng.sample_distinct(k, m);
    let dl: Vec<u64> = sel.iter().map(|&x| x + 1).collect();
    let batch = ssa::client_update(&session, &sel, &dl, &mut rng).unwrap();
    let keys = batch.server_keys(0);
    let num_bins = session.simple.num_bins();

    // --- 1. full-domain eval vs point-wise walks ------------------------
    let t0 = Instant::now();
    let mut acc_fd = 0u64;
    for (j, key) in keys[..num_bins].iter().enumerate() {
        for v in dpf::full_eval(key, session.simple.bin(j).len()) {
            acc_fd = acc_fd.wrapping_add(v);
        }
    }
    let t_full = t0.elapsed();
    let t1 = Instant::now();
    let mut acc_pw = 0u64;
    for (j, key) in keys[..num_bins].iter().enumerate() {
        for d in 0..session.simple.bin(j).len() as u64 {
            acc_pw = acc_pw.wrapping_add(dpf::eval(key, d));
        }
    }
    let t_point = t1.elapsed();
    assert_eq!(acc_fd, acc_pw);
    println!(
        "1. server eval @ m=2^15 c=10%: full-domain {:?} vs point-wise {:?} ({:.1}x speedup — §7.2 optimisation)",
        t_full,
        t_point,
        t_point.as_secs_f64() / t_full.as_secs_f64()
    );

    // --- 2. adaptive Θ vs fixed ⌈log Θ⌉ = 9 ------------------------------
    let adaptive_bits: usize = batch.publics.iter().map(|p| p.size_bits()).sum::<usize>() + 256;
    let fixed_bits = num_bins * (9 * 130 + 64) + 256;
    println!(
        "2. client upload: adaptive Θ {:.3} MB vs fixed ⌈logΘ⌉=9 {:.3} MB ({:.0}% saved)",
        bits_to_mb(adaptive_bits),
        bits_to_mb(fixed_bits),
        (1.0 - adaptive_bits as f64 / fixed_bits as f64) * 100.0
    );

    // --- 3. master seed vs per-bin seeds ---------------------------------
    let per_bin_bits = adaptive_bits - 256 + num_bins * 2 * 128;
    println!(
        "3. master-seed optimisation: {:.3} MB vs per-bin seeds {:.3} MB ({:.0}% saved)",
        bits_to_mb(adaptive_bits),
        bits_to_mb(per_bin_bits),
        (1.0 - adaptive_bits as f64 / per_bin_bits as f64) * 100.0
    );

    // --- 4. U-DPF hints vs re-keying --------------------------------------
    let hint_bits = num_bins * 64;
    println!(
        "4. fixed submodel, rounds ≥ 2: U-DPF hints {:.4} MB vs re-keying {:.3} MB ({:.0}x cheaper)",
        bits_to_mb(hint_bits),
        bits_to_mb(adaptive_bits),
        adaptive_bits as f64 / hint_bits as f64
    );
}
