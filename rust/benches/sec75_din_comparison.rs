//! §7.5 — comparison with Niu et al. [37] on the DIN recommendation
//! workload: the analytic communication split, plus a *live* mega-element
//! SSA round on the DIN-shaped embedding census to verify the round-time
//! claim ("each client finishes one round within 3s, each server within
//! 1 min" on the paper's testbed).

use fsl::baseline::niu::{niu_upload_mb, ours_upload_mb, DinCensus};
use fsl::crypto::rng::Rng;
use fsl::group::MegaElem;
use fsl::hashing::CuckooParams;
use fsl::protocol::{ssa, AggregationEngine, Session, SessionParams};
use std::time::Instant;

fn main() {
    let census = DinCensus::default();
    println!("# §7.5 DIN workload: {} params, {} embedding ({}%), {} goods + {} category IDs/client",
        census.total_params,
        census.embedding_params,
        (census.embedding_params as f64 / census.total_params as f64 * 100.0).round(),
        census.goods_ids_per_client,
        census.category_ids_per_client
    );
    let niu = niu_upload_mb(&census);
    let (ours_emb, ours_other) = ours_upload_mb(&census, 1.25, 9);
    println!("\n# upload per client per round (MB):");
    println!("{:>34} {:>10}", "scheme", "MB");
    println!("{:>34} {:>10.2}  (paper: ≥1.76, lossy/DP)", "Niu et al. [37] (submodel+PSU)", niu);
    println!(
        "{:>34} {:>10.2}  (paper: 1.4 + 0.98, lossless)",
        "ours (SSA embedding + dense rest)",
        ours_emb + ours_other
    );
    println!("{:>34} {:>10.2}", "  · embedding via basic SSA", ours_emb);
    println!("{:>34} {:>10.2}", "  · other components (dense)", ours_other);

    // Live round: mega-element SSA over the embedding rows (τ = 18).
    // Domain = 197,372 rows; each client updates 418 rows.
    let rows = (census.embedding_params / census.embedding_dim) as u64;
    let k_rows = ((census.goods_ids_per_client + census.category_ids_per_client) as usize).max(1);
    let session = Session::new_full(SessionParams {
        m: rows,
        k: k_rows,
        cuckoo: CuckooParams::default().with_seed(75),
    });
    let mut rng = Rng::new(75);
    let sel = rng.sample_distinct(k_rows, rows);
    let deltas: Vec<MegaElem<18>> = sel.iter().map(|&r| MegaElem([r + 1; 18])).collect();

    let t0 = Instant::now();
    let batch = ssa::client_update(&session, &sel, &deltas, &mut rng).unwrap();
    let gen = t0.elapsed();
    let engine = AggregationEngine::from_env();
    let keys = batch.server_keys(0);
    let t1 = Instant::now();
    let acc = engine.aggregate_keys(&session, std::slice::from_ref(&keys));
    let server = t1.elapsed();
    std::hint::black_box(&acc);
    println!(
        "\n# live mega-SSA round on the DIN embedding shape ({} rows, k={} rows, τ=18):",
        rows, k_rows
    );
    println!(
        "client DPF Gen {:?} (paper: <3s/round)  server eval+agg {:?} (paper: <1min)  {}",
        gen,
        server,
        if gen.as_secs_f64() < 3.0 && server.as_secs_f64() < 60.0 { "✓" } else { "✗" }
    );
}
