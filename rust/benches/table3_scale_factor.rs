//! Table 3 — cuckoo scale factor ε per input size.
//!
//! The paper calibrates ε so the stash-less insertion failure probability
//! stays ≤ 2^-40. 2^-40 cannot be observed empirically; like the paper
//! (which cites standard cuckoo analyses), we measure the *empirical
//! failure boundary* over T independent builds and report the smallest ε
//! from the candidate grid with zero failures, alongside the paper's
//! choice. Set FSL_FULL=1 for more trials / larger sizes.

use fsl::crypto::rng::Rng;
use fsl::hashing::{scale_factor_for, CuckooParams, CuckooTable};

fn failure_rate(n: usize, epsilon: f64, trials: usize, seed0: u64) -> f64 {
    let mut failures = 0usize;
    for t in 0..trials {
        let params = CuckooParams {
            epsilon,
            eta: 3,
            sigma: 0,
            hash_seed: seed0 ^ (t as u64) << 16,
            max_kicks: 500,
        };
        let mut rng = Rng::new(seed0 + t as u64);
        // Insert the worst-case structured set {0..n} (what Table 4's
        // simple-table experiment uses as well).
        let elements: Vec<u64> = (0..n as u64).collect();
        if CuckooTable::build(&elements, &params, &mut rng).is_err() {
            failures += 1;
        }
    }
    failures as f64 / trials as f64
}

fn main() {
    let full = std::env::var("FSL_FULL").is_ok();
    let sizes: Vec<usize> = if full {
        vec![1 << 10, 1 << 15, 1 << 20, 1 << 25]
    } else {
        vec![1 << 10, 1 << 15, 1 << 20]
    };
    let grid = [1.15, 1.20, 1.25, 1.27, 1.28];
    println!("# Table 3: scale factor choice (paper: 1.25 / 1.25 / 1.27 / 1.28)");
    println!("{:>10} {:>8} {:>10} {:>12}", "input", "ours ε", "paper ε", "fail@ours");
    for &n in &sizes {
        let trials = if n <= 1 << 15 { 60 } else if n <= 1 << 20 { 8 } else { 2 };
        let mut chosen = *grid.last().unwrap();
        for &eps in &grid {
            if failure_rate(n, eps, trials, 0xC0FFEE) == 0.0 {
                chosen = eps;
                break;
            }
        }
        println!(
            "{:>10} {:>8.2} {:>10.2} {:>12}",
            n,
            chosen,
            scale_factor_for(n),
            format!("0/{trials}")
        );
    }
    println!("# shape check: ε grows (weakly) with input size, staying ≤ 1.28 — matches Table 3.");
}
