//! `fsl` — Practical and Light-weight Secure Aggregation for Federated
//! Submodel Learning (Cui, Chen, Ye, Wang — 2021).
//!
//! Two-server secure Federated Submodel Learning built from Distributed
//! Point Functions (DPF) and cuckoo hashing:
//!
//! * **PSR** — private submodel retrieval (multi-query PIR over the global
//!   weight vector) — [`protocol::psr`].
//! * **SSA** — secure submodel aggregation (oblivious sparse updates at
//!   hidden positions) — [`protocol::ssa`].
//! * Optimisations: updatable DPF ([`udpf`]), private set union
//!   ([`protocol::psu`]), mega-element grouping ([`protocol::mega`]).
//!
//! Rounds are served by one persistent [`coordinator::FslRuntime`] — a
//! long-lived two-server deployment (living server threads, metered
//! topology, engines) built once through
//! [`coordinator::FslRuntimeBuilder`] and shared by every round type.
//!
//! The crate is the **L3 rust coordinator** of a three-layer stack: the FSL
//! model itself (L2, JAX) and its compute hot-spots (L1, Pallas) are
//! AOT-compiled to HLO text at build time and executed from rust through
//! the PJRT CPU client ([`runtime`]). Python never runs on the round path.

#![forbid(unsafe_code)]

pub mod baseline;
pub mod coordinator;
pub mod crypto;
pub mod data;
pub mod dpf;
pub mod fuzz;
pub mod group;
pub mod hashing;
pub mod metrics;
pub mod net;
pub mod protocol;
pub mod runtime;
pub mod sketch;
pub mod udpf;

pub use group::Group;
