//! `fsl` — CLI launcher for the secure Federated Submodel Learning stack.
//!
//! Subcommands:
//! * `train`  — end-to-end secure FSL training (MLP on the synthetic
//!   image task) with per-round loss/accuracy logging.
//! * `ssa`    — one SSA micro-round at a given (m, c): Table-5-style
//!   timings and Table-6-style communication.
//! * `psr`    — one PSR retrieval round at a given (m, k).
//! * `params` — print cuckoo/table diagnostics for (m, c) (Tables 3/4).
//! * `serve`  — run one server (S0 or S1) as a standalone process bound
//!   to an address; drive it from another process with `connect=`.
//! * `stats`  — scrape a live `fsl serve` process's metrics registry
//!   (Prometheus text by default, `--json` for the JSON document). The
//!   scrape rides an out-of-band `Role::Stats` connection, so it works
//!   mid-round without perturbing lanes.
//!
//! Arguments are `key=value` pairs, e.g.
//! `fsl train rounds=30 clients=10 c=0.1 artifacts=artifacts`.
//! `ssa`/`psr` accept `connect=S0_ADDR,S1_ADDR` to run the round against
//! two `fsl serve` processes over TCP instead of in-process servers,
//! `--json` to emit the round's [`fsl::coordinator::RoundReport`] as one
//! JSON line on stdout (human logs move to stderr), and `trace=PATH` to
//! write the round's per-phase spans as Chrome trace-event JSON (open the
//! file in Perfetto / `chrome://tracing`).

use anyhow::{anyhow, Result};
use fsl::coordinator::wire::{self, ServerCmd, ServerReply};
use fsl::coordinator::{
    run_fsl_training, run_loadgen, serve, ClientOutcome, FslConfig, FslRuntime,
    FslRuntimeBuilder, KeyMode, LoadgenOptions, LoadgenVerify, RoundReport, ServeOptions,
};
use fsl::crypto::rng::Rng;
use fsl::data::{partition_iid, ImageDataset, IMAGE_CLASSES};
use fsl::hashing::{CuckooParams, SimpleTable};
use fsl::metrics::{bits_to_mb, mb};
use fsl::net::transport::tcp::{TcpAcceptor, TcpOptions, TcpTransport};
use fsl::net::transport::{FaultPlan, Hello, Role, Transport as _};
use fsl::protocol::{Session, SessionParams};
use fsl::runtime::Executor;
use std::collections::HashMap;
use std::io::Write as _;
use std::time::{Duration, Instant};

fn parse_kv(args: &[String]) -> HashMap<String, String> {
    args.iter()
        .filter_map(|a| a.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

fn get<T: std::str::FromStr>(kv: &HashMap<String, String>, key: &str, default: T) -> T {
    kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let json = args.iter().any(|a| a == "--json");
    let kv = parse_kv(&args[1.min(args.len())..]);
    match cmd {
        "train" => cmd_train(&kv),
        "ssa" => cmd_ssa(&kv, json),
        "psr" => cmd_psr(&kv, json),
        "params" => cmd_params(&kv),
        "serve" => cmd_serve(&kv),
        "loadgen" => cmd_loadgen(&kv, json),
        "stats" => cmd_stats(&kv, json),
        _ => {
            eprintln!(
                "usage: fsl <train|ssa|psr|params|serve|loadgen|stats> [key=value ...] [--json]\n\
                 examples:\n\
                 \u{20}  fsl train rounds=20 clients=10 c=0.1\n\
                 \u{20}  fsl ssa m=32768 c=0.1 clients=4\n\
                 \u{20}  fsl psr m=32768 k=512 clients=8\n\
                 \u{20}  fsl params m=1048576 c=0.1\n\
                 two-terminal TCP deployment (plus a third for the driver):\n\
                 \u{20}  fsl serve party=0 listen=127.0.0.1:7100\n\
                 \u{20}  fsl serve party=1 listen=127.0.0.1:7101\n\
                 \u{20}  fsl ssa m=32768 c=0.1 clients=4 \
                 connect=127.0.0.1:7100,127.0.0.1:7101 --json\n\
                 scale harness (10^4..10^6 virtual clients over mux lanes):\n\
                 \u{20}  fsl loadgen clients=10000 lanes=64 rounds=1 m=16384 c=0.01 \
                 connect=127.0.0.1:7100,127.0.0.1:7101 --json\n\
                 scrape a live server's metrics (works mid-round):\n\
                 \u{20}  fsl stats connect=127.0.0.1:7100 --prom"
            );
            Ok(())
        }
    }
}

/// Run one standalone server until its deployment ends. `party=0|1`
/// picks S0/S1, `listen=ADDR` the bind address (`:0` picks an ephemeral
/// port, announced on stdout), `group=u64|u128` the payload group (must
/// match the driver's), `threads=N` the engine width (0 = one worker per
/// core), `snapshot=PATH` a recovery snapshot: restored on start when the
/// file exists, rewritten after every state-changing command so a killed
/// process can resume its U-DPF deployment where it left off.
fn cmd_serve(kv: &HashMap<String, String>) -> Result<()> {
    let party: u8 = get(kv, "party", 0);
    anyhow::ensure!(party < 2, "party must be 0 (S0) or 1 (S1)");
    let listen: String = get(kv, "listen", format!("127.0.0.1:{}", 7100 + party as u16));
    let group: String = get(kv, "group", "u64".to_string());
    let mut opts = ServeOptions::new(party);
    opts.threads = get(kv, "threads", 0);
    opts.data_timeout = Duration::from_millis(get(kv, "timeout_ms", 600_000u64));
    opts.snapshot = kv.get("snapshot").map(std::path::PathBuf::from);
    // links= caps concurrent client sockets (clamped to the fd limit);
    // budget_mb= bounds the multiplexed rounds' held-upload window.
    opts.max_client_links = get(kv, "links", opts.max_client_links);
    opts.ingest_budget = get(kv, "budget_mb", opts.ingest_budget >> 20).saturating_mul(1 << 20);
    let acceptor = TcpAcceptor::bind(listen.as_str(), opts.tcp.clone())
        .map_err(|e| e.context(format!("starting a server on {listen}")))?;
    let addr = acceptor.local_addr()?;
    // The bound address goes to stdout (flushed) so scripts binding
    // ephemeral ports can parse it before the first connection arrives.
    println!("S{party} listening on {addr}");
    std::io::stdout().flush()?;
    eprintln!("S{party} serving {group} payloads on {addr} (one deployment, then exit)");
    match group.as_str() {
        "u64" => serve::<u64>(&acceptor, &opts),
        "u128" => serve::<u128>(&acceptor, &opts),
        other => Err(anyhow!(
            "unknown payload group {other:?} (supported: u64, u128)"
        )),
    }
}

/// Drive a multiplexed scale round against two `fsl serve` processes:
/// `clients=N` virtual clients over `lanes=L` mux sockets per server,
/// `rounds=R` times back-to-back (soak mode; per-round wall times land
/// as p50/p95/p99 in the report and in a `loadgen_soak` history
/// datapoint). `m=`/`c=` (or `k=`) shape the session, `deadline_ms=`
/// arms the straggler cut, `jitter_ms=`/`straggle=`/`drop_lanes=`
/// inject faults, `verify=expected|inproc|none` picks the post-round
/// check, and `history=PATH|default` appends bench-diff-gated
/// datapoints.
fn cmd_loadgen(kv: &HashMap<String, String>, json: bool) -> Result<()> {
    let spec: String = get(kv, "connect", "127.0.0.1:7100,127.0.0.1:7101".to_string());
    let (s0, s1) = spec
        .split_once(',')
        .ok_or_else(|| anyhow!("expected two addresses: connect=S0_ADDR,S1_ADDR (got {spec:?})"))?;
    let mut opts = LoadgenOptions::new(s0.trim(), s1.trim());
    opts.clients = get(kv, "clients", 10_000usize).max(1);
    opts.lanes = get(kv, "lanes", 64usize).max(1);
    // rounds>1 = soak mode: the same deployment is re-commanded over the
    // same lane pool; the report carries p50/p95/p99 round walls.
    opts.rounds = get(kv, "rounds", 1usize).max(1);
    opts.m = get(kv, "m", 1u64 << 14);
    let c: f64 = get(kv, "c", 0.01);
    opts.k = get(kv, "k", ((opts.m as f64 * c) as usize).max(1));
    opts.seed = get(kv, "seed", 7);
    opts.deadline = Duration::from_millis(get(kv, "deadline_ms", 30_000u64));
    opts.reply_timeout = Duration::from_millis(get(kv, "reply_timeout_ms", 600_000u64));
    opts.connect_window = Duration::from_millis(get(kv, "retry_ms", 10_000u64));
    opts.jitter = Duration::from_millis(get(kv, "jitter_ms", 0u64));
    opts.straggle = get(kv, "straggle", 0.0);
    opts.drop_lanes = get(kv, "drop_lanes", 0);
    opts.verify = match get(kv, "verify", "expected".to_string()).as_str() {
        "none" => LoadgenVerify::None,
        "expected" => LoadgenVerify::Expected,
        "inproc" => LoadgenVerify::Inproc,
        other => return Err(anyhow!("verify takes expected|inproc|none (got {other:?})")),
    };
    opts.history = kv.get("history").map(|p| {
        if p == "default" {
            fsl::metrics::history::default_path()
        } else {
            std::path::PathBuf::from(p)
        }
    });
    wait_for_listeners(&[opts.s0.as_str(), opts.s1.as_str()], opts.connect_window)?;
    eprintln!(
        "loadgen: {} virtual clients over {} lane pairs (m={} k={}, deadline {:?})",
        opts.clients, opts.lanes, opts.m, opts.k, opts.deadline
    );
    let report = run_loadgen(&opts)?;
    eprintln!(
        "loadgen: {}/{} completed ({} cut, {} dropped) over {} round(s); wall {:?}, \
         server {:?}, gen {:?}, round p50/p95/p99 {:.0}/{:.0}/{:.0} ms, \
         upload {:.1} MB, driver peak RSS {:.1} MB",
        report.completed,
        report.clients,
        report.straggler_cut,
        report.dropped,
        report.rounds,
        report.wall_time,
        report.server_time,
        report.gen_time,
        report.p50_ms,
        report.p95_ms,
        report.p99_ms,
        report.upload_bytes as f64 / 1e6,
        report.peak_rss_mb,
    );
    if json {
        println!("{}", report.to_json());
    }
    Ok(())
}

/// Scrape one live `fsl serve` process at `connect=ADDR`: dial an
/// out-of-band [`Role::Stats`] connection, send the Stats command, and
/// print the reply — Prometheus text exposition by default (also with
/// `--prom`), the JSON document with `--json`. The exposition text is
/// validated before printing so a malformed scrape fails loudly instead
/// of poisoning a collector. Works mid-round: the stats responder never
/// enters the round state machine.
fn cmd_stats(kv: &HashMap<String, String>, json: bool) -> Result<()> {
    let addr: String = get(kv, "connect", "127.0.0.1:7100".to_string());
    let window = Duration::from_millis(get(kv, "retry_ms", 10_000u64));
    wait_for_listeners(&[addr.as_str()], window)?;
    // A scraper addresses a socket, not a party: the stats ack echoes
    // whatever party byte the dialler claims, so 0 always passes.
    let hello = Hello { party: 0, role: Role::Stats };
    let conn = TcpTransport::connect(addr.as_str(), &hello, &TcpOptions::default())
        .map_err(|e| e.context(format!("dialling the stats endpoint at {addr}")))?;
    conn.send(wire::encode_cmd(&ServerCmd::<u64>::Stats))?;
    let raw = conn.recv_timeout(Duration::from_millis(get(kv, "reply_timeout_ms", 10_000u64)))?;
    match wire::decode_reply::<u64>(&raw)? {
        ServerReply::Stats { prom, json: doc } => {
            fsl::metrics::expo::validate_prom(&prom)
                .map_err(|e| anyhow!("{addr} returned invalid exposition text: {e}"))?;
            if json {
                println!("{doc}");
            } else {
                print!("{prom}");
            }
            Ok(())
        }
        ServerReply::Failed(msg) => Err(anyhow!("{addr} refused the scrape: {msg}")),
        _ => Err(anyhow!("{addr}: unexpected reply to a stats scrape")),
    }
}

/// The shared round-shape flags: `keymode=fresh|udpf` picks the SSA key
/// flow, `deadline_ms=N` arms tolerant rounds (straggler/dropout cut at
/// N ms per upload), `reply_timeout_ms=N` bounds how long the driver
/// waits on a server, and `drop=i,j,...` injects a disconnect fault into
/// the listed clients' links (their first upload severs the connection).
fn builder_for(
    session: &Session,
    threads: usize,
    n: usize,
    kv: &HashMap<String, String>,
) -> Result<FslRuntimeBuilder> {
    let mut b = FslRuntimeBuilder::from_session(session.clone())
        .threads(threads)
        .max_clients(n)
        .reply_timeout(Duration::from_millis(get(kv, "reply_timeout_ms", 600_000u64)));
    if get(kv, "keymode", "fresh".to_string()) == "udpf" {
        b = b.key_mode(KeyMode::Udpf);
    }
    let deadline_ms: u64 = get(kv, "deadline_ms", 0);
    if deadline_ms > 0 {
        b = b.upload_deadline(Duration::from_millis(deadline_ms));
    }
    if let Some(list) = kv.get("drop") {
        for tok in list.split(',').filter(|t| !t.trim().is_empty()) {
            let i: usize = tok
                .trim()
                .parse()
                .map_err(|_| anyhow!("drop takes client indices: drop=0,3 (got {tok:?})"))?;
            b = b.client_fault(i, FaultPlan::new().disconnect_after_messages(0));
        }
    }
    Ok(b)
}

/// Connect a configured builder to two `fsl serve` processes at
/// `spec = "S0_ADDR,S1_ADDR"`, waiting up to `window` for their
/// listeners to come up.
fn connect_runtime(
    builder: FslRuntimeBuilder,
    spec: &str,
    window: Duration,
) -> Result<FslRuntime<u64>> {
    let (s0, s1) = spec
        .split_once(',')
        .ok_or_else(|| anyhow!("expected two addresses: S0_ADDR,S1_ADDR (got {spec:?})"))?;
    let (s0, s1) = (s0.trim(), s1.trim());
    wait_for_listeners(&[s0, s1], window)?;
    builder.connect_retry(window).connect::<u64>(s0, s1)
}

/// Build an in-process runtime, or — with `connect=S0,S1` — a runtime
/// driving two standalone `fsl serve` processes (waiting up to
/// `retry_ms` for their listeners to come up).
fn runtime_for(
    session: &Session,
    threads: usize,
    n: usize,
    kv: &HashMap<String, String>,
) -> Result<FslRuntime<u64>> {
    let builder = builder_for(session, threads, n, kv)?;
    match kv.get("connect") {
        None => builder.build::<u64>(),
        Some(spec) => connect_runtime(
            builder,
            spec,
            Duration::from_millis(get(kv, "retry_ms", 10_000u64)),
        ),
    }
}

/// Poll until both server listeners accept TCP (the probe connections
/// are dropped immediately; servers tolerate failed handshakes).
fn wait_for_listeners(addrs: &[&str], window: Duration) -> Result<()> {
    let t0 = Instant::now();
    for addr in addrs {
        loop {
            match std::net::TcpStream::connect(addr) {
                Ok(_probe) => break,
                Err(e) => {
                    if t0.elapsed() > window {
                        return Err(anyhow!(
                            "server at {addr} not reachable after {window:?}: {e}"
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(200));
                }
            }
        }
    }
    Ok(())
}

/// Emit a round report: one JSON line on stdout (`--json`), or nothing
/// (the human-readable summaries are printed by the callers).
fn emit_report(json: bool, report: &RoundReport) {
    if json {
        println!("{}", report.to_json());
    }
}

/// `trace=PATH`: write the round's per-phase spans as Chrome trace-event
/// JSON, directly loadable in Perfetto / `chrome://tracing`. Multi-epoch
/// runs rewrite the file each epoch, so it always holds the latest round.
fn emit_trace(kv: &HashMap<String, String>, report: &RoundReport) -> Result<()> {
    if let Some(path) = kv.get("trace") {
        let path = std::path::Path::new(path);
        report
            .write_trace(path)
            .map_err(|e| anyhow!("writing the round trace to {}: {e}", path.display()))?;
        eprintln!("trace: {} spans → {}", report.spans.len(), path.display());
    }
    Ok(())
}

fn cmd_train(kv: &HashMap<String, String>) -> Result<()> {
    let artifacts: String = get(kv, "artifacts", "artifacts".to_string());
    let cfg = FslConfig {
        num_clients: get(kv, "clients", 10),
        participation: get(kv, "participation", 1.0),
        rounds: get(kv, "rounds", 20),
        local_iters: get(kv, "local_iters", 1),
        lr: get(kv, "lr", 0.05),
        compression: get(kv, "c", 0.10),
        seed: get(kv, "seed", 42),
        eval_every: get(kv, "eval_every", 5),
        ..FslConfig::default()
    };
    cfg.validate()?;
    let exec = Executor::new(&artifacts)?;
    let m = exec.manifest().int("mlp_grad", "params")? as usize;
    let batch = exec.manifest().int("mlp_grad", "batch")? as usize;

    let (train, test) = ImageDataset::synthesize_split(
        get(kv, "train_n", 2000),
        get(kv, "test_n", 500),
        cfg.seed,
        1.0,
    );
    let mut rng = Rng::new(cfg.seed);
    let shards = partition_iid(train.n, cfg.num_clients, &mut rng);

    let params = init_mlp_params(m, cfg.seed);
    println!(
        "secure FSL training: m={m} clients={} rounds={} c={:.1}%",
        cfg.num_clients,
        cfg.rounds,
        cfg.compression * 100.0
    );
    let log = run_fsl_training(
        &exec,
        &cfg,
        "mlp_grad",
        params,
        |client, _it, r| {
            let shard = &shards[client];
            let idx: Vec<usize> = (0..batch)
                .map(|_| shard[r.gen_range(shard.len() as u64) as usize])
                .collect();
            train.batch(&idx)
        },
        |p| eval_mlp(&exec, p, &test, batch),
        |s| {
            println!(
                "round {:>3}  loss {:.4}  up/client {:.3} MB  gen {:?}  srv {:?}{}",
                s.round,
                s.mean_loss,
                s.upload_mb_per_client,
                s.gen_time,
                s.server_time,
                s.accuracy
                    .map(|a| format!("  acc {:.2}%", a * 100.0))
                    .unwrap_or_default()
            );
        },
    )?;
    println!(
        "done; final accuracy {:.2}%",
        log.last_accuracy().unwrap_or(0.0) * 100.0
    );
    Ok(())
}

/// He-style init matching python's mlp_init shapes (seeded, rust-side).
pub fn init_mlp_params(m: usize, seed: u64) -> Vec<f32> {
    let layers = [(784usize, 1024usize), (1024, 1024), (1024, 10)];
    let mut rng = Rng::new(seed ^ 0x1111);
    let mut out = Vec::with_capacity(m);
    for (i, o) in layers {
        let scale = (2.0 / i as f64).sqrt() as f32;
        out.extend((0..i * o).map(|_| rng.gen_normal() as f32 * scale));
        out.extend(std::iter::repeat(0f32).take(o));
    }
    assert_eq!(out.len(), m);
    out
}

/// Batched accuracy of the MLP on a test set.
fn eval_mlp(exec: &Executor, params: &[f32], test: &ImageDataset, batch: usize) -> Result<f32> {
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in (0..test.n).collect::<Vec<_>>().chunks(batch) {
        let mut idx = chunk.to_vec();
        while idx.len() < batch {
            idx.push(chunk[0]); // pad; padded rows excluded below
        }
        let (x, _) = test.batch(&idx);
        let logits = exec.infer("mlp_infer", params, &x)?;
        for (row, &i) in chunk.iter().enumerate() {
            let row_logits = &logits[row * IMAGE_CLASSES..(row + 1) * IMAGE_CLASSES];
            let pred = row_logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred == test.y[i] as usize);
            total += 1;
        }
    }
    Ok(correct as f32 / total.max(1) as f32)
}

/// The delta an SSA round must reconstruct: the exact sum of every
/// *completed* client's sparse update over the session domain. Dropped
/// and straggler-cut clients contribute nothing — that is the tolerant
/// rounds' correctness contract.
fn expected_delta(
    m: u64,
    clients: &[(Vec<u64>, Vec<u64>)],
    outcomes: &[ClientOutcome],
) -> Vec<u64> {
    let mut expected = vec![0u64; m as usize];
    for (i, (sel, dl)) in clients.iter().enumerate() {
        if !matches!(outcomes.get(i), Some(ClientOutcome::Completed)) {
            continue;
        }
        for (&x, &d) in sel.iter().zip(dl) {
            expected[x as usize] = expected[x as usize].wrapping_add(d);
        }
    }
    expected
}

/// One SSA epoch's JSON line: the wrapped report plus the epoch number,
/// whether this epoch ran on a runtime rebuilt from server snapshots,
/// and whether the reconstructed delta matched the surviving cohort.
fn emit_epoch(json: bool, epoch: usize, recovered: bool, verified: bool, report: &RoundReport) {
    if json {
        println!(
            "{{\"epoch\":{epoch},\"recovered\":{recovered},\"verified\":{verified},\"report\":{}}}",
            report.to_json()
        );
    }
}

fn cmd_ssa(kv: &HashMap<String, String>, json: bool) -> Result<()> {
    let m: u64 = get(kv, "m", 1 << 15);
    let c: f64 = get(kv, "c", 0.1);
    let n: usize = get(kv, "clients", 1).max(1);
    let k = ((m as f64 * c) as usize).max(1);
    let epochs: usize = get(kv, "epochs", 1).max(1);
    let pause_ms: u64 = get(kv, "pause_ms", 0);
    let recover = get(kv, "recover", 0u64) != 0;
    let retry = Duration::from_millis(get(kv, "retry_ms", 10_000u64));
    let session = Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default().with_seed(get(kv, "seed", 7)),
    });
    eprintln!(
        "SSA micro-round: m={m} k={k} (c={:.1}%) Θ={} epochs={epochs}",
        c * 100.0,
        session.theta()
    );
    let mut rng = Rng::new(get(kv, "seed", 7));
    // Fixed selections across epochs (the U-DPF contract); per-epoch
    // deltas shift so every epoch's expected sum is distinct.
    let sels: Vec<Vec<u64>> = (0..n).map(|_| rng.sample_distinct(k, m)).collect();
    let updates_for = |epoch: usize| -> Vec<(Vec<u64>, Vec<u64>)> {
        sels.iter()
            .map(|sel| {
                let dl = sel.iter().map(|&x| x + 1 + epoch as u64).collect();
                (sel.clone(), dl)
            })
            .collect()
    };
    let mut rt = runtime_for(&session, 0, n, kv)?;
    let mut epoch = 0usize;
    let mut recovered = false;
    while epoch < epochs {
        let clients = updates_for(epoch);
        match rt.ssa(&clients, &mut rng) {
            Ok(res) => {
                let verified = expected_delta(m, &clients, &res.report.outcomes) == res.delta;
                if epoch == 0 {
                    let paper_bits = session.simple.num_bins() * (9 * 130 + 128) + 256;
                    eprintln!(
                        "gen {:?}  server eval+agg {:?}\nupload/client: measured {:.3} MB, \
                         paper model {:.3} MB, trivial SA {:.3} MB",
                        res.report.gen_time,
                        res.report.server_time,
                        mb(res.report.client_upload_bytes) / n as f64,
                        bits_to_mb(paper_bits),
                        bits_to_mb(m as usize * 128 + 128),
                    );
                } else {
                    eprintln!(
                        "epoch {epoch}: {}/{n} clients completed, server {:?}",
                        res.report.completed(),
                        res.report.server_time
                    );
                }
                if epochs == 1 {
                    emit_report(json, &res.report);
                } else {
                    emit_epoch(json, epoch, recovered, verified, &res.report);
                }
                emit_trace(kv, &res.report)?;
                anyhow::ensure!(
                    verified,
                    "epoch {epoch}: reconstructed delta does not match the surviving cohort"
                );
                recovered = false;
                epoch += 1;
                if pause_ms > 0 && epoch < epochs {
                    std::thread::sleep(Duration::from_millis(pause_ms));
                }
            }
            Err(e) => {
                // One recovery attempt per epoch: export the driver-side
                // U-DPF state, reconnect to the restarted servers (which
                // reload their halves from `snapshot=` files), resume,
                // and retry the same epoch.
                let spec = match kv.get("reconnect") {
                    Some(spec) if recover && !recovered => spec,
                    _ => return Err(e),
                };
                eprintln!("epoch {epoch} failed ({e:#}); reconnecting to {spec} and retrying");
                let state = rt.export_udpf_state();
                rt = connect_runtime(builder_for(&session, 0, n, kv)?, spec, retry)?;
                rt.resume_udpf(state)?;
                recovered = true;
            }
        }
    }
    rt.shutdown()?;
    Ok(())
}

fn cmd_psr(kv: &HashMap<String, String>, json: bool) -> Result<()> {
    let m: u64 = get(kv, "m", 1 << 15);
    let k: usize = get(kv, "k", 512);
    let n: usize = get(kv, "clients", 1).max(1);
    let session = Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default().with_seed(get(kv, "seed", 7)),
    });
    let mut rng = Rng::new(get(kv, "seed", 7));
    let weights: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
    let sels: Vec<Vec<u64>> = (0..n).map(|_| rng.sample_distinct(k, m)).collect();
    // Serve the whole client batch through one persistent runtime. The
    // engine width follows the FSL_THREADS bench convention adapted for
    // two *concurrently* answering servers: unset → serial per server
    // (reproducible timings), 0 → the co-located default (half the cores
    // each, so the pair uses the whole machine without oversubscribing),
    // N → N workers per server, non-numeric → warn and run serial.
    // (Against `connect=` servers the width is each serve process's own
    // threads= setting; FSL_THREADS only shapes the in-process pair.)
    let threads = match std::env::var("FSL_THREADS") {
        Err(_) => 1,
        Ok(v) => match v.parse::<usize>() {
            Ok(t) => t,
            Err(_) => {
                eprintln!("FSL_THREADS={v:?} is not a number; running serial");
                1
            }
        },
    };
    let mut rt = runtime_for(&session, threads, n, kv)?;
    rt.set_weights(weights.clone())?;
    let t0 = Instant::now();
    let res = rt.psr(&sels, &mut rng)?;
    let t_round = t0.elapsed();
    for (sel, got) in sels.iter().zip(&res.submodels) {
        for (i, &s) in sel.iter().enumerate() {
            assert_eq!(got[i], weights[s as usize]);
        }
    }
    eprintln!(
        "PSR m={m} k={k} clients={n}: gen {:?}, server answers {:?} (round {t_round:?}), \
         upload/client {:.3} MB, download/client {:.3} MB, verified ✓",
        res.report.gen_time,
        res.report.server_time,
        mb(res.report.client_upload_bytes) / n as f64,
        mb(res.report.client_download_bytes) / n as f64,
    );
    emit_report(json, &res.report);
    emit_trace(kv, &res.report)?;
    rt.shutdown()?;
    Ok(())
}

fn cmd_params(kv: &HashMap<String, String>) -> Result<()> {
    let m: u64 = get(kv, "m", 1 << 20);
    let c: f64 = get(kv, "c", 0.1);
    let k = ((m as f64 * c) as usize).max(1);
    let params = CuckooParams::default();
    let bins = params.num_bins(k);
    let t0 = Instant::now();
    let table = SimpleTable::build_full(m, bins, &params);
    println!(
        "m={m} k={k} ε={} η={} → B={bins} Θ={} (⌈logΘ⌉={}) built in {:?}",
        params.epsilon,
        params.eta,
        table.max_bin_size(),
        fsl::dpf::depth_for(table.max_bin_size().max(2)),
        t0.elapsed()
    );
    Ok(())
}
