//! Append-only bench history: one JSON line per datapoint in
//! `artifacts/HISTORY.jsonl`, so perf trajectories persist across PRs
//! instead of evaporating as loose `BENCH_*.json` files in the CWD.
//!
//! Line schema (version [`HISTORY_SCHEMA`]):
//!
//! ```json
//! {"schema":1,"bench":"psr_serving","git_rev":"d3a33d3","unix_ts":1754610000,
//!  "metrics":{"serial_ms":12.3,...}}
//! ```
//!
//! `cargo run -p xtask -- bench-diff` compares the two newest datapoints
//! per bench and fails CI on compute or wire-byte regressions.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::metrics::json::JsonObj;

/// Version stamp on every history line; bump on any breaking change to
/// the line layout so `bench-diff` can refuse mixed-schema comparisons.
pub const HISTORY_SCHEMA: u64 = 1;

/// Where datapoints land: `$FSL_HISTORY` if set, else
/// `artifacts/HISTORY.jsonl` under the current directory (the repo root
/// for `cargo bench`).
pub fn default_path() -> PathBuf {
    match std::env::var_os("FSL_HISTORY") {
        Some(p) if !p.is_empty() => PathBuf::from(p),
        _ => PathBuf::from("artifacts/HISTORY.jsonl"),
    }
}

/// Append one schema-versioned datapoint for `bench` to `path`,
/// creating parent directories as needed. `fill` adds the bench's
/// metric fields; the envelope (schema, bench name, git rev, unix
/// timestamp) is stamped here so every producer agrees on it. Returns
/// the appended line.
pub fn append_with(
    path: &Path,
    bench: &str,
    fill: impl FnOnce(&mut JsonObj),
) -> std::io::Result<String> {
    let mut metrics = JsonObj::new();
    fill(&mut metrics);
    let mut line = JsonObj::new();
    line.field_u64("schema", HISTORY_SCHEMA)
        .field_str("bench", bench)
        .field_str("git_rev", &git_rev())
        .field_u64("unix_ts", unix_ts())
        .field_raw("metrics", &metrics.finish());
    let line = line.finish();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{line}")?;
    Ok(line)
}

fn git_rev() -> String {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output();
    match out {
        Ok(o) if o.status.success() => {
            let rev = String::from_utf8_lossy(&o.stdout).trim().to_string();
            if rev.is_empty() {
                "unknown".into()
            } else {
                rev
            }
        }
        _ => "unknown".into(),
    }
}

fn unix_ts() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map_or(0, |d| d.as_secs())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::json;

    #[test]
    fn append_writes_valid_schema_versioned_lines() {
        let dir = std::env::temp_dir().join(format!("fsl_history_{}", std::process::id()));
        let path = dir.join("HISTORY.jsonl");
        let _ = std::fs::remove_file(&path);
        let l1 = append_with(&path, "bench_a", |m| {
            m.field_f64("wall_ms", 12.5, 3);
        })
        .unwrap();
        let l2 = append_with(&path, "bench_a", |m| {
            m.field_f64("wall_ms", 13.5, 3);
        })
        .unwrap();
        assert!(json::validate(&l1), "{l1}");
        assert!(l1.starts_with("{\"schema\":1,\"bench\":\"bench_a\""), "{l1}");
        assert!(l1.contains("\"git_rev\":"), "{l1}");
        assert!(l1.contains("\"metrics\":{\"wall_ms\":12.500}"), "{l1}");
        let on_disk = std::fs::read_to_string(&path).unwrap();
        assert_eq!(on_disk, format!("{l1}\n{l2}\n"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
