//! Live operational metrics: a dependency-free registry of named
//! counters, gauges, and log2-bucketed histograms.
//!
//! Design goals, in order:
//!
//! 1. **Lock-cheap hot paths.** Every metric hands out a pre-registered
//!    handle ([`Counter`], [`Gauge`], [`Histogram`]) wrapping
//!    `Arc<AtomicU64>` cells. Recording is a relaxed atomic op — no
//!    hashing, no map lookup, no lock. The registry's `Mutex` is taken
//!    only at registration time and when a scrape snapshots.
//! 2. **Idempotent registration.** Registering the same `(name, labels)`
//!    pair twice returns a handle onto the *same* cells, so per-round
//!    re-instrumentation (a fresh `FramePump` every mux round, say)
//!    keeps counters cumulative instead of resetting them.
//! 3. **No dependencies.** Cells are `std::sync::atomic`; snapshots are
//!    plain structs rendered by [`crate::metrics::expo`].
//!
//! Naming convention (enforced by the `metric-naming` fsl-lint rule):
//! every registered name matches `fsl_[a-z0-9_]+` and ends in a unit
//! suffix — `_bytes`, `_total` (monotonic event counts), `_seconds`
//! (histograms observed in nanoseconds, scaled at render time), or
//! `_count` (dimensionless gauges/instantaneous counts).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of histogram buckets: bucket `i < 63` covers observations
/// `<= 2^i`; bucket 63 is the overflow (+Inf) bucket.
pub const HISTOGRAM_BUCKETS: usize = 64;

/// What a histogram's raw `u64` observations mean, for rendering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    /// Dimensionless counts (bytes, items). Rendered as-is.
    Count,
    /// Observations are **nanoseconds**; exposition scales bucket
    /// bounds and sums by 1e-9 so scrapes read SI seconds.
    Seconds,
}

/// Which kind of cells a registry entry owns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

/// A monotonically increasing counter handle. Cheap to clone; all
/// clones (and all registrations of the same name+labels) share cells.
#[derive(Debug, Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A counter not attached to any registry (records go nowhere
    /// visible). Used as the mismatched-kind fallback and in tests.
    pub fn detached() -> Self {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value-wins gauge handle.
#[derive(Debug, Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// A gauge not attached to any registry.
    pub fn detached() -> Self {
        Gauge(Arc::new(AtomicU64::new(0)))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if `v` is larger (high-water marks).
    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    /// Saturating decrement (concurrent saturation may transiently
    /// undershoot; gauges here track approximate occupancy).
    pub fn sub(&self, v: u64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(v);
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Shared histogram cells: 64 log2 buckets plus exact sum and count.
#[derive(Debug)]
pub struct HistoCells {
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl HistoCells {
    fn new() -> Self {
        HistoCells {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

/// Index of the log2 bucket covering `v`: bucket `i` holds
/// observations in `(2^(i-1), 2^i]` (bucket 0 holds `0..=1`), clamped
/// into the final overflow bucket.
pub fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        (64 - (v - 1).leading_zeros() as usize).min(HISTOGRAM_BUCKETS - 1)
    }
}

/// The inclusive upper bound of bucket `i`, or `None` for the overflow
/// (+Inf) bucket.
pub fn bucket_bound(i: usize) -> Option<u64> {
    if i >= HISTOGRAM_BUCKETS - 1 {
        None
    } else {
        Some(1u64 << i)
    }
}

/// A log2-bucketed histogram handle.
#[derive(Debug, Clone)]
pub struct Histogram {
    cells: Arc<HistoCells>,
    unit: Unit,
}

impl Histogram {
    /// A histogram not attached to any registry.
    pub fn detached(unit: Unit) -> Self {
        Histogram {
            cells: Arc::new(HistoCells::new()),
            unit,
        }
    }

    /// Record one observation (raw units; nanoseconds for
    /// [`Unit::Seconds`] histograms).
    pub fn observe(&self, v: u64) {
        let c = &self.cells;
        c.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        c.sum.fetch_add(v, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a wall-clock duration (only meaningful for
    /// [`Unit::Seconds`] histograms).
    pub fn observe_duration(&self, d: std::time::Duration) {
        let ns = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        self.observe(ns);
    }

    pub fn count(&self) -> u64 {
        self.cells.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.cells.sum.load(Ordering::Relaxed)
    }

    pub fn unit(&self) -> Unit {
        self.unit
    }

    /// Estimate the `q`-quantile (`0.0..=1.0`) in raw units by a
    /// nearest-rank walk over the buckets with linear interpolation
    /// inside the landing bucket. Returns 0 for an empty histogram.
    /// Accuracy is bounded by the log2 geometry: at most one octave.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.cells.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if seen + n >= rank {
                let (lo, hi) = match bucket_bound(i) {
                    Some(hi) if i == 0 => (0.0, hi as f64),
                    Some(hi) => ((hi / 2) as f64, hi as f64),
                    // Overflow bucket: no upper bound; report its floor.
                    None => return (1u64 << (HISTOGRAM_BUCKETS - 2)) as f64,
                };
                let into = (rank - seen) as f64 / n as f64;
                return lo + (hi - lo) * into;
            }
            seen += n;
        }
        // Unreachable if count/buckets are consistent; be safe anyway.
        0.0
    }

    /// Like [`Histogram::quantile`] but scaled to fractional
    /// milliseconds for [`Unit::Seconds`] histograms.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        match self.unit {
            Unit::Seconds => self.quantile(q) / 1e6,
            Unit::Count => self.quantile(q),
        }
    }

    fn snapshot_buckets(&self) -> [u64; HISTOGRAM_BUCKETS] {
        std::array::from_fn(|i| self.cells.buckets[i].load(Ordering::Relaxed))
    }
}

enum Cells {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    help: String,
    cells: Cells,
}

/// The value half of a [`MetricSnapshot`].
#[derive(Debug, Clone)]
pub enum SnapshotValue {
    Counter(u64),
    Gauge(u64),
    Histogram {
        buckets: [u64; HISTOGRAM_BUCKETS],
        sum: u64,
        count: u64,
        unit: Unit,
    },
}

/// A point-in-time copy of one registry entry, ready for rendering by
/// [`crate::metrics::expo`]. Snapshots are value copies — rendering
/// never holds the registry lock.
#[derive(Debug, Clone)]
pub struct MetricSnapshot {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub help: String,
    pub value: SnapshotValue,
}

/// A registry of named metrics. See the module docs for the design.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Vec<Entry>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// A fresh registry behind an `Arc`, the shape every holder wants.
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::new())
    }

    /// Register (or look up) an unlabelled counter.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        self.counter_with(name, &[], help)
    }

    /// Register (or look up) a labelled counter.
    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Counter {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = find(&inner, name, labels) {
            if let Cells::Counter(c) = &e.cells {
                return c.clone();
            }
            // Kind mismatch: hand back detached cells rather than
            // panicking in instrumentation code.
            return Counter::detached();
        }
        let c = Counter::detached();
        inner.push(entry(name, labels, help, Cells::Counter(c.clone())));
        c
    }

    /// Register (or look up) an unlabelled gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        self.gauge_with(name, &[], help)
    }

    /// Register (or look up) a labelled gauge.
    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Gauge {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = find(&inner, name, labels) {
            if let Cells::Gauge(g) = &e.cells {
                return g.clone();
            }
            return Gauge::detached();
        }
        let g = Gauge::detached();
        inner.push(entry(name, labels, help, Cells::Gauge(g.clone())));
        g
    }

    /// Register (or look up) an unlabelled histogram.
    pub fn histogram(&self, name: &str, help: &str, unit: Unit) -> Histogram {
        self.histogram_with(name, &[], help, unit)
    }

    /// Register (or look up) a labelled histogram.
    pub fn histogram_with(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
        unit: Unit,
    ) -> Histogram {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = find(&inner, name, labels) {
            if let Cells::Histogram(h) = &e.cells {
                return h.clone();
            }
            return Histogram::detached(unit);
        }
        let h = Histogram::detached(unit);
        inner.push(entry(name, labels, help, Cells::Histogram(h.clone())));
        h
    }

    /// Copy every entry's current value out. Sorted by (name, labels)
    /// so renderings are deterministic regardless of registration
    /// order.
    pub fn snapshot(&self) -> Vec<MetricSnapshot> {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut out: Vec<MetricSnapshot> = inner
            .iter()
            .map(|e| MetricSnapshot {
                name: e.name.clone(),
                labels: e.labels.clone(),
                help: e.help.clone(),
                value: match &e.cells {
                    Cells::Counter(c) => SnapshotValue::Counter(c.get()),
                    Cells::Gauge(g) => SnapshotValue::Gauge(g.get()),
                    Cells::Histogram(h) => SnapshotValue::Histogram {
                        buckets: h.snapshot_buckets(),
                        sum: h.sum(),
                        count: h.count(),
                        unit: h.unit(),
                    },
                },
            })
            .collect();
        out.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
        out
    }

    /// Number of registered entries (test/diagnostic aid).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn find<'a>(entries: &'a [Entry], name: &str, labels: &[(&str, &str)]) -> Option<&'a Entry> {
    entries.iter().find(|e| {
        e.name == name
            && e.labels.len() == labels.len()
            && e.labels
                .iter()
                .zip(labels)
                .all(|((k, v), (lk, lv))| k == lk && v == lv)
    })
}

fn entry(name: &str, labels: &[(&str, &str)], help: &str, cells: Cells) -> Entry {
    Entry {
        name: name.to_string(),
        labels: labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect(),
        help: help.to_string(),
        cells,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_exact_at_powers_of_two() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1 << 20), 20);
        assert_eq!(bucket_index((1 << 20) + 1), 21);
        assert_eq!(bucket_index(u64::MAX), HISTOGRAM_BUCKETS - 1);
        // Every value lands in the bucket whose bound covers it.
        for v in [0u64, 1, 2, 7, 100, 4096, 1 << 40] {
            let i = bucket_index(v);
            if let Some(hi) = bucket_bound(i) {
                assert!(v <= hi, "v={v} above bound of bucket {i}");
            }
            if i > 0 {
                let lo = bucket_bound(i - 1).unwrap();
                assert!(v > lo, "v={v} below bucket {i}");
            }
        }
    }

    #[test]
    fn registration_is_idempotent_and_shares_cells() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("fsl_test_frames_total", "help");
        let b = reg.counter("fsl_test_frames_total", "other help ignored");
        a.add(3);
        b.add(4);
        assert_eq!(a.get(), 7);
        assert_eq!(reg.len(), 1);

        let g1 = reg.gauge_with("fsl_test_held_bytes", &[("party", "0")], "h");
        let g2 = reg.gauge_with("fsl_test_held_bytes", &[("party", "1")], "h");
        g1.set(10);
        g2.set(20);
        assert_eq!(g1.get(), 10);
        assert_eq!(g2.get(), 20);
        assert_eq!(reg.len(), 3);

        // Kind mismatch hands back detached cells, never panics.
        let wrong = reg.gauge("fsl_test_frames_total", "h");
        wrong.set(999);
        assert_eq!(a.get(), 7);
    }

    #[test]
    fn gauge_ops() {
        let g = Gauge::detached();
        g.set(5);
        g.add(3);
        assert_eq!(g.get(), 8);
        g.sub(10);
        assert_eq!(g.get(), 0);
        g.set_max(4);
        g.set_max(2);
        assert_eq!(g.get(), 4);
    }

    #[test]
    fn histogram_quantiles_walk_buckets() {
        let h = Histogram::detached(Unit::Count);
        // 100 observations of 100 (bucket 7: (64,128]).
        for _ in 0..100 {
            h.observe(100);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 10_000);
        let p50 = h.quantile(0.5);
        assert!((64.0..=128.0).contains(&p50), "p50={p50}");
        // Bimodal: add 100 observations of 1000 (bucket 10: (512,1024]).
        for _ in 0..100 {
            h.observe(1000);
        }
        let p25 = h.quantile(0.25);
        let p99 = h.quantile(0.99);
        assert!((64.0..=128.0).contains(&p25), "p25={p25}");
        assert!((512.0..=1024.0).contains(&p99), "p99={p99}");
        assert_eq!(h.quantile(0.0), h.quantile(0.001));
        // Empty histogram.
        assert_eq!(Histogram::detached(Unit::Count).quantile(0.5), 0.0);
    }

    #[test]
    fn quantile_ms_scales_seconds_unit() {
        let h = Histogram::detached(Unit::Seconds);
        h.observe(2_000_000); // 2 ms in ns, bucket (2^20, 2^21]
        let p50 = h.quantile_ms(0.5);
        assert!((1.0..=2.2).contains(&p50), "p50_ms={p50}");
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let reg = MetricsRegistry::new();
        reg.counter("fsl_z_last_total", "z").inc();
        reg.gauge("fsl_a_first_count", "a").set(7);
        let h = reg.histogram("fsl_m_mid_seconds", "m", Unit::Seconds);
        h.observe(5);
        let snaps = reg.snapshot();
        let names: Vec<&str> = snaps.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            ["fsl_a_first_count", "fsl_m_mid_seconds", "fsl_z_last_total"]
        );
        match &snaps[1].value {
            SnapshotValue::Histogram {
                sum, count, unit, ..
            } => {
                assert_eq!(*sum, 5);
                assert_eq!(*count, 1);
                assert_eq!(*unit, Unit::Seconds);
            }
            other => panic!("expected histogram, got {other:?}"),
        }
    }

    #[test]
    fn concurrent_hammering_keeps_exact_totals() {
        const THREADS: usize = 8;
        const PER_THREAD: u64 = 10_000;
        let reg = MetricsRegistry::shared();
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let reg = reg.clone();
                s.spawn(move || {
                    // Half the threads register their own handles to
                    // exercise idempotent lookup under contention.
                    let c = reg.counter("fsl_conc_events_total", "h");
                    let h = reg.histogram("fsl_conc_lat_seconds", "h", Unit::Seconds);
                    let g = reg.gauge("fsl_conc_peak_count", "h");
                    for i in 0..PER_THREAD {
                        c.inc();
                        h.observe(i % 1024);
                        g.set_max(t as u64 * PER_THREAD + i);
                    }
                });
            }
        });
        let total = THREADS as u64 * PER_THREAD;
        let snaps = reg.snapshot();
        let counter = snaps
            .iter()
            .find(|s| s.name == "fsl_conc_events_total")
            .unwrap();
        match counter.value {
            SnapshotValue::Counter(v) => assert_eq!(v, total),
            ref other => panic!("expected counter, got {other:?}"),
        }
        let histo = snaps
            .iter()
            .find(|s| s.name == "fsl_conc_lat_seconds")
            .unwrap();
        match &histo.value {
            SnapshotValue::Histogram { buckets, count, .. } => {
                assert_eq!(*count, total);
                assert_eq!(buckets.iter().sum::<u64>(), total);
            }
            other => panic!("expected histogram, got {other:?}"),
        }
        let peak = snaps
            .iter()
            .find(|s| s.name == "fsl_conc_peak_count")
            .unwrap();
        match peak.value {
            SnapshotValue::Gauge(v) => assert_eq!(v, total - 1),
            ref other => panic!("expected gauge, got {other:?}"),
        }
    }
}
