//! Renderers for [`crate::metrics::registry`] snapshots: Prometheus
//! text exposition format (`fsl stats --prom`, the scrape endpoint) and
//! a JSON document (`fsl stats --json`), plus a dependency-free format
//! validator tests and CI use to guard the exposition output.
//!
//! Exposition rules implemented (text format 0.0.4):
//!
//! - one `# HELP` / `# TYPE` pair per metric *family* (same name,
//!   different label sets share one header);
//! - label values escape `\`, `"`, and newline; HELP text escapes `\`
//!   and newline;
//! - histograms render cumulative `_bucket{le="..."}` series up to the
//!   last non-empty bucket plus the mandatory `le="+Inf"`, then
//!   `_sum` and `_count`;
//! - [`Unit::Seconds`] histograms store nanoseconds; bucket bounds and
//!   sums are scaled by 1e-9 here so scrapes read SI seconds.
//!
//! Snapshots arrive pre-sorted from `MetricsRegistry::snapshot`, so
//! both renderings are deterministic — the golden test below pins the
//! exact text.

use super::json::{self, JsonObj};
use super::registry::{bucket_bound, MetricSnapshot, SnapshotValue, Unit};
use std::fmt::Write as _;

/// Escape a HELP line: backslash and newline.
fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Escape a label value: backslash, double-quote, newline.
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\")
        .replace('"', "\\\"")
        .replace('\n', "\\n")
}

/// Render `{k="v",...}` for a label set, with `extra` appended last
/// (the histogram `le` label). Empty label sets render as nothing.
fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut out = String::from("{");
    let mut any = false;
    for (k, v) in labels {
        if any {
            out.push(',');
        }
        any = true;
        let _ = write!(out, "{k}=\"{}\"", escape_label(v));
    }
    if let Some((k, v)) = extra {
        if any {
            out.push(',');
        }
        let _ = write!(out, "{k}=\"{}\"", escape_label(v));
    }
    out.push('}');
    out
}

/// Format an f64 the way Prometheus parsers expect (shortest
/// round-trip representation; integral values keep no fraction).
fn fmt_f64(v: f64) -> String {
    format!("{v}")
}

/// Scale a raw histogram quantity into exposition units.
fn scaled(v: u64, unit: Unit) -> String {
    match unit {
        Unit::Count => v.to_string(),
        Unit::Seconds => fmt_f64(v as f64 / 1e9),
    }
}

/// Render a snapshot list as Prometheus text exposition format.
pub fn render_prom(snaps: &[MetricSnapshot]) -> String {
    let mut out = String::new();
    let mut last_family: Option<&str> = None;
    for s in snaps {
        let type_name = match &s.value {
            SnapshotValue::Counter(_) => "counter",
            SnapshotValue::Gauge(_) => "gauge",
            SnapshotValue::Histogram { .. } => "histogram",
        };
        if last_family != Some(s.name.as_str()) {
            let _ = writeln!(out, "# HELP {} {}", s.name, escape_help(&s.help));
            let _ = writeln!(out, "# TYPE {} {}", s.name, type_name);
            last_family = Some(s.name.as_str());
        }
        match &s.value {
            SnapshotValue::Counter(v) | SnapshotValue::Gauge(v) => {
                let _ = writeln!(out, "{}{} {}", s.name, label_block(&s.labels, None), v);
            }
            SnapshotValue::Histogram {
                buckets,
                sum,
                count,
                unit,
            } => {
                let last_used = buckets.iter().rposition(|&b| b > 0);
                let mut cum = 0u64;
                if let Some(last) = last_used {
                    for (i, b) in buckets.iter().enumerate().take(last + 1) {
                        cum += b;
                        let le = match bucket_bound(i) {
                            Some(hi) => scaled(hi, *unit),
                            None => continue, // overflow bucket handled by +Inf below
                        };
                        let _ = writeln!(
                            out,
                            "{}_bucket{} {}",
                            s.name,
                            label_block(&s.labels, Some(("le", &le))),
                            cum
                        );
                    }
                }
                let _ = writeln!(
                    out,
                    "{}_bucket{} {}",
                    s.name,
                    label_block(&s.labels, Some(("le", "+Inf"))),
                    count
                );
                let _ = writeln!(
                    out,
                    "{}_sum{} {}",
                    s.name,
                    label_block(&s.labels, None),
                    scaled(*sum, *unit)
                );
                let _ = writeln!(
                    out,
                    "{}_count{} {}",
                    s.name,
                    label_block(&s.labels, None),
                    count
                );
            }
        }
    }
    out
}

/// Schema version stamped into [`render_json`] documents.
pub const JSON_SCHEMA: u64 = 1;

/// Render a snapshot list as one JSON document:
/// `{"schema":1,"metrics":[{...},...]}`. Histograms report `sum`,
/// `count`, and p50/p95/p99 estimates (milliseconds for
/// [`Unit::Seconds`], raw units otherwise) rather than raw buckets.
pub fn render_json(snaps: &[MetricSnapshot]) -> String {
    let metrics = snaps.iter().map(|s| {
        let mut o = JsonObj::new();
        o.field_str("name", &s.name);
        if !s.labels.is_empty() {
            let mut lo = JsonObj::new();
            for (k, v) in &s.labels {
                lo.field_str(k, v);
            }
            o.field_raw("labels", &lo.finish());
        }
        match &s.value {
            SnapshotValue::Counter(v) => {
                o.field_str("type", "counter").field_u64("value", *v);
            }
            SnapshotValue::Gauge(v) => {
                o.field_str("type", "gauge").field_u64("value", *v);
            }
            SnapshotValue::Histogram {
                buckets,
                sum,
                count,
                unit,
            } => {
                o.field_str("type", "histogram")
                    .field_u64("count", *count)
                    .field_f64(
                        "sum",
                        match unit {
                            Unit::Seconds => *sum as f64 / 1e9,
                            Unit::Count => *sum as f64,
                        },
                        6,
                    );
                for (label, q) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
                    o.field_f64(label, quantile_of(buckets, *count, *unit, q), 3);
                }
            }
        }
        o.finish()
    });
    let mut doc = JsonObj::new();
    doc.field_u64("schema", JSON_SCHEMA)
        .field_raw("metrics", &json::array(metrics));
    doc.finish()
}

/// Quantile over a raw bucket snapshot (mirrors
/// `Histogram::quantile`, but over copied cells). Seconds-unit values
/// scale to fractional milliseconds.
fn quantile_of(buckets: &[u64], total: u64, unit: Unit, q: f64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let rank = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    let mut raw = 0.0;
    for (i, &n) in buckets.iter().enumerate() {
        if n == 0 {
            continue;
        }
        if seen + n >= rank {
            raw = match bucket_bound(i) {
                Some(hi) if i == 0 => (rank - seen) as f64 / n as f64 * hi as f64,
                Some(hi) => {
                    let lo = (hi / 2) as f64;
                    lo + (hi as f64 - lo) * ((rank - seen) as f64 / n as f64)
                }
                None => (1u64 << 62) as f64 * 2.0,
            };
            break;
        }
        seen += n;
    }
    match unit {
        Unit::Seconds => raw / 1e6,
        Unit::Count => raw,
    }
}

/// Validate Prometheus text exposition format. Returns the first
/// problem found, or `Ok(())`. Checks: line grammar (comments, sample
/// lines `name{labels} value`), metric/label name charsets, every
/// sample preceded by a `# TYPE` for its family, histogram families
/// complete (`+Inf` bucket, `_sum`, `_count`), and parseable values.
pub fn validate_prom(text: &str) -> Result<(), String> {
    let mut typed: Vec<(String, String)> = Vec::new(); // (family, type)
    let mut histo_parts: Vec<(String, [bool; 3])> = Vec::new(); // inf/sum/count
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            if let Some(spec) = rest.strip_prefix("TYPE ") {
                let mut it = spec.splitn(2, ' ');
                let fam = it.next().unwrap_or("");
                let ty = it.next().unwrap_or("");
                if !valid_name(fam) {
                    return Err(format!("line {ln}: bad family name {fam:?}"));
                }
                if !matches!(ty, "counter" | "gauge" | "histogram") {
                    return Err(format!("line {ln}: unknown type {ty:?}"));
                }
                typed.push((fam.to_string(), ty.to_string()));
                if ty == "histogram" {
                    histo_parts.push((fam.to_string(), [false; 3]));
                }
            } else if !rest.starts_with("HELP ") {
                return Err(format!("line {ln}: unknown comment {line:?}"));
            }
            continue;
        }
        if line.starts_with('#') {
            return Err(format!("line {ln}: malformed comment {line:?}"));
        }
        // Sample line: name[{labels}] value
        let (name_labels, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {ln}: no value separator in {line:?}"))?;
        if value.parse::<f64>().is_err() && value != "+Inf" && value != "-Inf" && value != "NaN" {
            return Err(format!("line {ln}: unparseable value {value:?}"));
        }
        let name = match name_labels.split_once('{') {
            Some((n, rest)) => {
                let body = rest
                    .strip_suffix('}')
                    .ok_or_else(|| format!("line {ln}: unterminated label block"))?;
                validate_labels(body).map_err(|e| format!("line {ln}: {e}"))?;
                n
            }
            None => name_labels,
        };
        if !valid_name(name) {
            return Err(format!("line {ln}: bad metric name {name:?}"));
        }
        // Resolve the family: histogram series carry suffixes.
        let family = typed
            .iter()
            .rev()
            .find(|(fam, ty)| {
                name == fam
                    || (ty == "histogram"
                        && [
                            format!("{fam}_bucket"),
                            format!("{fam}_sum"),
                            format!("{fam}_count"),
                        ]
                        .iter()
                        .any(|s| s == name))
            })
            .ok_or_else(|| format!("line {ln}: sample {name:?} has no preceding # TYPE"))?
            .0
            .clone();
        if let Some((_, parts)) = histo_parts.iter_mut().find(|(f, _)| *f == family) {
            if name.ends_with("_bucket") && line.contains("le=\"+Inf\"") {
                parts[0] = true;
            }
            if name == format!("{family}_sum") {
                parts[1] = true;
            }
            if name == format!("{family}_count") {
                parts[2] = true;
            }
        }
    }
    for (fam, [inf, sum, count]) in &histo_parts {
        if !(inf && sum && count) {
            return Err(format!(
                "histogram {fam} incomplete: +Inf={inf} _sum={sum} _count={count}"
            ));
        }
    }
    Ok(())
}

fn valid_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn validate_labels(body: &str) -> Result<(), String> {
    // Split on commas outside quotes; validate k="v" with escape rules.
    let b = body.as_bytes();
    let mut pos = 0;
    while pos < b.len() {
        let eq = body[pos..]
            .find('=')
            .map(|i| pos + i)
            .ok_or_else(|| format!("label without '=' in {body:?}"))?;
        let key = &body[pos..eq];
        if !valid_name(key) {
            return Err(format!("bad label name {key:?}"));
        }
        if b.get(eq + 1) != Some(&b'"') {
            return Err(format!("unquoted label value after {key:?}"));
        }
        let mut i = eq + 2;
        loop {
            match b.get(i) {
                Some(b'\\') => i += 2,
                Some(b'"') => break,
                Some(_) => i += 1,
                None => return Err(format!("unterminated label value for {key:?}")),
            }
        }
        pos = i + 1;
        match b.get(pos) {
            Some(b',') => pos += 1,
            None => break,
            Some(c) => return Err(format!("unexpected {:?} after label value", *c as char)),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::registry::MetricsRegistry;

    fn sample_registry() -> MetricsRegistry {
        let reg = MetricsRegistry::new();
        reg.counter("fsl_frames_total", "Frames pumped").add(42);
        reg.gauge_with(
            "fsl_held_window_bytes",
            &[("party", "0")],
            "Mux held-window occupancy",
        )
        .set(1024);
        reg.gauge_with(
            "fsl_held_window_bytes",
            &[("party", "1")],
            "Mux held-window occupancy",
        )
        .set(2048);
        let h = reg.histogram("fsl_round_seconds", "Round wall time", Unit::Seconds);
        h.observe(1_500_000_000); // 1.5 s → bucket le=2^31 ns
        h.observe(1); // → bucket le=1 ns
        reg.counter_with(
            "fsl_odd_total",
            &[("path", "a\\b\"c\nd")],
            "Hostile\nhelp \\ text",
        )
        .inc();
        reg
    }

    #[test]
    fn exposition_golden() {
        let text = render_prom(&sample_registry().snapshot());
        let expected = "\
# HELP fsl_frames_total Frames pumped
# TYPE fsl_frames_total counter
fsl_frames_total 42
# HELP fsl_held_window_bytes Mux held-window occupancy
# TYPE fsl_held_window_bytes gauge
fsl_held_window_bytes{party=\"0\"} 1024
fsl_held_window_bytes{party=\"1\"} 2048
# HELP fsl_odd_total Hostile\\nhelp \\\\ text
# TYPE fsl_odd_total counter
fsl_odd_total{path=\"a\\\\b\\\"c\\nd\"} 1
# HELP fsl_round_seconds Round wall time
# TYPE fsl_round_seconds histogram
fsl_round_seconds_bucket{le=\"0.000000001\"} 1
fsl_round_seconds_bucket{le=\"0.000000002\"} 1
fsl_round_seconds_bucket{le=\"0.000000004\"} 1
fsl_round_seconds_bucket{le=\"0.000000008\"} 1
fsl_round_seconds_bucket{le=\"0.000000016\"} 1
fsl_round_seconds_bucket{le=\"0.000000032\"} 1
fsl_round_seconds_bucket{le=\"0.000000064\"} 1
fsl_round_seconds_bucket{le=\"0.000000128\"} 1
fsl_round_seconds_bucket{le=\"0.000000256\"} 1
fsl_round_seconds_bucket{le=\"0.000000512\"} 1
fsl_round_seconds_bucket{le=\"0.000001024\"} 1
fsl_round_seconds_bucket{le=\"0.000002048\"} 1
fsl_round_seconds_bucket{le=\"0.000004096\"} 1
fsl_round_seconds_bucket{le=\"0.000008192\"} 1
fsl_round_seconds_bucket{le=\"0.000016384\"} 1
fsl_round_seconds_bucket{le=\"0.000032768\"} 1
fsl_round_seconds_bucket{le=\"0.000065536\"} 1
fsl_round_seconds_bucket{le=\"0.000131072\"} 1
fsl_round_seconds_bucket{le=\"0.000262144\"} 1
fsl_round_seconds_bucket{le=\"0.000524288\"} 1
fsl_round_seconds_bucket{le=\"0.001048576\"} 1
fsl_round_seconds_bucket{le=\"0.002097152\"} 1
fsl_round_seconds_bucket{le=\"0.004194304\"} 1
fsl_round_seconds_bucket{le=\"0.008388608\"} 1
fsl_round_seconds_bucket{le=\"0.016777216\"} 1
fsl_round_seconds_bucket{le=\"0.033554432\"} 1
fsl_round_seconds_bucket{le=\"0.067108864\"} 1
fsl_round_seconds_bucket{le=\"0.134217728\"} 1
fsl_round_seconds_bucket{le=\"0.268435456\"} 1
fsl_round_seconds_bucket{le=\"0.536870912\"} 1
fsl_round_seconds_bucket{le=\"1.073741824\"} 1
fsl_round_seconds_bucket{le=\"2.147483648\"} 2
fsl_round_seconds_bucket{le=\"+Inf\"} 2
fsl_round_seconds_sum 1.500000001
fsl_round_seconds_count 2
";
        assert_eq!(text, expected);
        validate_prom(&text).expect("golden must self-validate");
    }

    #[test]
    fn json_rendering_is_valid_and_quantiled() {
        let doc = render_json(&sample_registry().snapshot());
        assert!(json::validate(&doc), "{doc}");
        assert!(doc.contains("\"schema\":1"), "{doc}");
        assert!(doc.contains("\"name\":\"fsl_round_seconds\""), "{doc}");
        assert!(doc.contains("\"p99\""), "{doc}");
        // Hostile label value must be escaped into valid JSON.
        assert!(doc.contains("a\\\\b\\\"c\\nd"), "{doc}");
    }

    #[test]
    fn validator_rejects_malformed_exposition() {
        for (bad, why) in [
            ("fsl_x_total 1\n", "sample without TYPE"),
            ("# TYPE fsl_x_total counter\nfsl_x_total\n", "no value"),
            (
                "# TYPE fsl_x_total counter\nfsl_x_total abc\n",
                "bad value",
            ),
            (
                "# TYPE fsl_x_total wibble\nfsl_x_total 1\n",
                "unknown type",
            ),
            (
                "# TYPE fsl_x_total counter\nfsl_x_total{p=\"1\" 2\n",
                "unterminated labels",
            ),
            (
                "# TYPE fsl_h_seconds histogram\nfsl_h_seconds_count 1\n",
                "incomplete histogram",
            ),
        ] {
            assert!(validate_prom(bad).is_err(), "accepted {why}: {bad:?}");
        }
        let ok = "# HELP fsl_ok_total fine\n# TYPE fsl_ok_total counter\nfsl_ok_total{a=\"b\",c=\"d\\\"e\"} 3\n";
        validate_prom(ok).expect("valid sample rejected");
    }

    #[test]
    fn empty_snapshot_renders_empty_but_valid() {
        let text = render_prom(&[]);
        assert!(text.is_empty());
        validate_prom(&text).unwrap();
        let doc = render_json(&[]);
        assert!(json::validate(&doc), "{doc}");
    }
}
