//! Per-phase round tracing: a lock-cheap span recorder with a bounded
//! ring buffer, shared by the protocol engines, both server halves and
//! the round driver.
//!
//! Every round phase — keygen / upload / eval / merge / reply — records
//! one [`Span`] tagged with the [`Party`] that did the work and (for
//! sharded evaluation) the shard worker that ran it. Span timestamps are
//! nanoseconds since the recorder's last [`TraceRecorder::reset`], i.e.
//! relative to that party's round start; the three processes of a TCP
//! deployment do not share a clock, so cross-party offsets are relative,
//! not absolute (see docs/ARCHITECTURE.md § Observability).
//!
//! The recorder owns the clock: callers obtain a [`SpanStart`] from
//! [`TraceRecorder::begin`] and close it with [`TraceRecorder::end`], so
//! instrumented protocol code itself contains no time source (keeping
//! the `determinism` lint's no-clocks rule intact for `protocol/`).
//! Recording is a short `Mutex` critical section around a `VecDeque`
//! push — no allocation once the ring is warm — and overflow evicts the
//! oldest span while counting the loss in [`TraceRecorder::dropped`].

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::metrics::json;
use crate::metrics::registry::{Histogram, MetricsRegistry, Unit};

/// Default ring capacity: generous for any realistic round (a 128-way
/// sharded eval across five phases is still well under 1k spans).
pub const DEFAULT_TRACE_CAPACITY: usize = 4096;

/// The round phase a span measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// Client-side DPF/U-DPF key generation (or server-side hint work).
    Keygen,
    /// Receiving the cohort's uploads (server) / sending them (driver).
    Upload,
    /// DPF evaluation over the weight domain, per shard worker.
    Eval,
    /// Combining shard partials (and, on `S_0`, share reconstruction).
    Merge,
    /// Shipping the round result: share exchange and reply assembly.
    Reply,
    /// Accepting and handshaking a deployment's connections (server) /
    /// dialling them (driver) — the reactor's concurrent accept loop.
    Accept,
    /// Streaming mux ingest: demultiplexing virtual-client frames and
    /// absorbing committed uploads into the running accumulator.
    Ingest,
}

impl Phase {
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Keygen => "keygen",
            Phase::Upload => "upload",
            Phase::Eval => "eval",
            Phase::Merge => "merge",
            Phase::Reply => "reply",
            Phase::Accept => "accept",
            Phase::Ingest => "ingest",
        }
    }

    pub(crate) fn to_byte(self) -> u8 {
        match self {
            Phase::Keygen => 0,
            Phase::Upload => 1,
            Phase::Eval => 2,
            Phase::Merge => 3,
            Phase::Reply => 4,
            Phase::Accept => 5,
            Phase::Ingest => 6,
        }
    }

    pub(crate) fn from_byte(b: u8) -> Option<Self> {
        Some(match b {
            0 => Phase::Keygen,
            1 => Phase::Upload,
            2 => Phase::Eval,
            3 => Phase::Merge,
            4 => Phase::Reply,
            5 => Phase::Accept,
            6 => Phase::Ingest,
            _ => return None,
        })
    }
}

/// Which participant recorded a span.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Party {
    /// The round driver acting for the client cohort.
    Client,
    /// The leader server.
    S0,
    /// The worker server.
    S1,
}

impl Party {
    pub fn as_str(self) -> &'static str {
        match self {
            Party::Client => "client",
            Party::S0 => "s0",
            Party::S1 => "s1",
        }
    }

    /// Chrome trace-event `pid` lane for this party.
    pub fn pid(self) -> u64 {
        match self {
            Party::Client => 0,
            Party::S0 => 1,
            Party::S1 => 2,
        }
    }

    pub(crate) fn to_byte(self) -> u8 {
        match self {
            Party::Client => 0,
            Party::S0 => 1,
            Party::S1 => 2,
        }
    }

    pub(crate) fn from_byte(b: u8) -> Option<Self> {
        Some(match b {
            0 => Party::Client,
            1 => Party::S0,
            2 => Party::S1,
            _ => return None,
        })
    }

    /// The party enum for a server index (0 = leader, 1 = worker).
    pub fn server(party: usize) -> Self {
        if party == 0 {
            Party::S0
        } else {
            Party::S1
        }
    }
}

/// One timed phase of one round, tagged with who did the work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub phase: Phase,
    pub party: Party,
    /// Shard worker (Eval) or client index (Keygen); `None` for
    /// whole-phase spans.
    pub worker: Option<u32>,
    /// Nanoseconds since the recorder's round epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// An open span: the instant work began, relative to the recorder's
/// round epoch. Closed by [`TraceRecorder::end`].
#[derive(Debug, Clone, Copy)]
pub struct SpanStart {
    at_ns: u64,
}

/// All phases, indexed by [`Phase::to_byte`]. Keep in sync with the
/// byte codec above.
const ALL_PHASES: [Phase; 7] = [
    Phase::Keygen,
    Phase::Upload,
    Phase::Eval,
    Phase::Merge,
    Phase::Reply,
    Phase::Accept,
    Phase::Ingest,
];

/// Ceiling on distinct `worker` labels for the per-worker eval
/// histogram — indices beyond this clamp into the last slot, bounding
/// scrape cardinality regardless of engine width.
const MAX_WORKER_LABELS: usize = 128;

/// Registry histograms fed from span completions: one per-phase
/// latency histogram family (`fsl_phase_seconds{phase=...}`) plus a
/// lazily grown per-shard-worker family for Eval spans
/// (`fsl_eval_worker_seconds{worker=N}`).
///
/// The span recorder owns the clock, so attaching this to a
/// [`TraceRecorder`] is how the protocol engines' latencies reach the
/// scrape endpoint without `protocol/` ever calling a time source —
/// the `determinism` lint's no-clocks rule stays intact.
#[derive(Clone)]
pub struct PhaseMetrics {
    registry: Arc<MetricsRegistry>,
    phases: [Histogram; ALL_PHASES.len()],
    eval_workers: Arc<Mutex<Vec<Option<Histogram>>>>,
}

impl std::fmt::Debug for PhaseMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PhaseMetrics").finish()
    }
}

impl PhaseMetrics {
    /// Register the per-phase histogram family on `registry` and hand
    /// back the recording handle set.
    pub fn register(registry: &Arc<MetricsRegistry>) -> Self {
        let phases = std::array::from_fn(|i| {
            registry.histogram_with(
                "fsl_phase_seconds",
                &[("phase", ALL_PHASES[i].as_str())],
                "Span latency per round phase",
                Unit::Seconds,
            )
        });
        PhaseMetrics {
            registry: registry.clone(),
            phases,
            eval_workers: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Feed one completed span into the histograms.
    fn observe(&self, span: &Span) {
        self.phases[span.phase.to_byte() as usize].observe(span.dur_ns);
        if span.phase == Phase::Eval {
            if let Some(w) = span.worker {
                self.observe_worker(w as usize, span.dur_ns);
            }
        }
    }

    fn observe_worker(&self, worker: usize, dur_ns: u64) {
        let idx = worker.min(MAX_WORKER_LABELS - 1);
        let mut cache = self.eval_workers.lock().unwrap_or_else(|e| e.into_inner());
        if cache.len() <= idx {
            cache.resize(idx + 1, None);
        }
        let h = cache[idx].get_or_insert_with(|| {
            let label = idx.to_string();
            self.registry.histogram_with(
                "fsl_eval_worker_seconds",
                &[("worker", label.as_str())],
                "Eval span latency per shard worker",
                Unit::Seconds,
            )
        });
        h.observe(dur_ns);
    }
}

struct Inner {
    epoch: Instant,
    spans: VecDeque<Span>,
    dropped: u64,
}

/// Bounded multi-producer span ring. Cheap enough to leave on
/// permanently: recording is one short mutex hold, and a full ring
/// evicts oldest-first rather than blocking or growing.
pub struct TraceRecorder {
    capacity: usize,
    inner: Mutex<Inner>,
    /// Optional live-metrics tap: when attached, every completed span
    /// also lands in the registry histograms. Cumulative across rounds
    /// ([`TraceRecorder::reset`] does not touch it).
    metrics: OnceLock<PhaseMetrics>,
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder")
            .field("capacity", &self.capacity)
            .field("len", &self.len())
            .finish()
    }
}

impl TraceRecorder {
    pub fn new(capacity: usize) -> Self {
        TraceRecorder {
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                epoch: Instant::now(),
                spans: VecDeque::new(),
                dropped: 0,
            }),
            metrics: OnceLock::new(),
        }
    }

    /// Tee every future span into `metrics` histograms (first call
    /// wins). See [`PhaseMetrics`].
    pub fn attach_metrics(&self, metrics: PhaseMetrics) {
        let _ = self.metrics.set(metrics);
    }

    pub fn shared(capacity: usize) -> Arc<Self> {
        Arc::new(Self::new(capacity))
    }

    /// A poisoned mutex only means another recorder panicked mid-push;
    /// the span data itself stays coherent, so tracing keeps working.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Start a new round: clear the ring, zero the loss counter and
    /// re-base the span clock at "now".
    pub fn reset(&self) {
        let mut g = self.lock();
        g.epoch = Instant::now();
        g.spans.clear();
        g.dropped = 0;
    }

    /// Open a span at "now".
    pub fn begin(&self) -> SpanStart {
        let g = self.lock();
        SpanStart {
            at_ns: g.epoch.elapsed().as_nanos() as u64,
        }
    }

    /// Close `start` as a `phase` span for `party` and record it.
    pub fn end(&self, start: SpanStart, phase: Phase, party: Party, worker: Option<u32>) {
        let span = {
            let mut g = self.lock();
            let now = g.epoch.elapsed().as_nanos() as u64;
            let span = Span {
                phase,
                party,
                worker,
                start_ns: start.at_ns,
                dur_ns: now.saturating_sub(start.at_ns),
            };
            push(&mut g, self.capacity, span);
            span
        };
        if let Some(m) = self.metrics.get() {
            m.observe(&span);
        }
    }

    /// Record a pre-built span (used when replaying spans received from
    /// a remote party into the driver's stream).
    pub fn record(&self, span: Span) {
        {
            let mut g = self.lock();
            push(&mut g, self.capacity, span);
        }
        if let Some(m) = self.metrics.get() {
            m.observe(&span);
        }
    }

    /// Remove and return every recorded span, oldest first. The loss
    /// counter survives (see [`Self::dropped`]); `reset` zeroes it.
    pub fn drain(&self) -> Vec<Span> {
        self.lock().spans.drain(..).collect()
    }

    pub fn len(&self) -> usize {
        self.lock().spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans evicted by ring overflow since the last `reset`.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }
}

fn push(g: &mut Inner, capacity: usize, span: Span) {
    if g.spans.len() == capacity {
        g.spans.pop_front();
        g.dropped += 1;
    }
    g.spans.push_back(span);
}

/// Clamp a worker/client index into the span tag domain (indices are
/// bounded well below `u32::MAX` everywhere, but a span tag is never
/// worth a truncation error).
pub fn worker(i: usize) -> Option<u32> {
    Some(u32::try_from(i).unwrap_or(u32::MAX))
}

/// A recorder handle pre-tagged with the recording party, handed to the
/// protocol engines so they need neither a clock nor knowledge of which
/// server they run inside.
#[derive(Clone)]
pub struct TraceSink {
    rec: Arc<TraceRecorder>,
    party: Party,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceSink").field("party", &self.party).finish()
    }
}

impl TraceSink {
    pub fn new(rec: Arc<TraceRecorder>, party: Party) -> Self {
        TraceSink { rec, party }
    }

    pub fn begin(&self) -> SpanStart {
        self.rec.begin()
    }

    pub fn end(&self, start: SpanStart, phase: Phase, worker: Option<u32>) {
        self.rec.end(start, phase, self.party, worker);
    }

    pub fn party(&self) -> Party {
        self.party
    }
}

/// Render spans as a Chrome trace-event JSON document (the `[{…},…]`
/// array form), directly loadable in Perfetto / `chrome://tracing`.
///
/// Lanes: `pid` is the party (0 = client driver, 1 = `S_0`, 2 = `S_1`),
/// `tid` is the shard worker + 1 (0 for whole-phase spans). Timestamps
/// are microseconds from each party's own round start — parties share a
/// time base only in-proc, so compare phase *durations* across parties,
/// not absolute offsets.
pub fn chrome_trace_json(spans: &[Span]) -> String {
    chrome_trace_json_with(spans, &[])
}

/// [`chrome_trace_json`] with caller-supplied extra events appended
/// (pre-rendered JSON objects, e.g. [`counter_event`] points for
/// registry gauges).
pub fn chrome_trace_json_with(spans: &[Span], extra: &[String]) -> String {
    let mut events: Vec<String> = Vec::with_capacity(spans.len() + extra.len() + 3);
    for party in [Party::Client, Party::S0, Party::S1] {
        let mut meta = json::JsonObj::new();
        meta.field_str("ph", "M")
            .field_str("name", "process_name")
            .field_u64("pid", party.pid())
            .field_u64("tid", 0)
            .field_raw(
                "args",
                &json::JsonObj::new().field_str("name", party.as_str()).finish(),
            );
        events.push(meta.finish());
    }
    for s in spans {
        let mut ev = json::JsonObj::new();
        ev.field_str("name", s.phase.as_str())
            .field_str("ph", "X")
            .field_str("cat", "fsl")
            .field_f64("ts", s.start_ns as f64 / 1_000.0, 3)
            .field_f64("dur", s.dur_ns as f64 / 1_000.0, 3)
            .field_u64("pid", s.party.pid())
            .field_u64("tid", s.worker.map_or(0, |w| u64::from(w) + 1));
        events.push(ev.finish());
    }
    events.extend(active_span_counters(spans));
    events.extend(extra.iter().cloned());
    json::array(events)
}

/// One Perfetto counter-track point: `{"ph":"C"}` with a single
/// `value` series, on the party's `pid` lane. `ts_us` is microseconds
/// from that party's round epoch, like the span events.
pub fn counter_event(name: &str, ts_us: f64, party: Party, value: u64) -> String {
    let mut ev = json::JsonObj::new();
    ev.field_str("name", name)
        .field_str("ph", "C")
        .field_str("cat", "fsl")
        .field_f64("ts", ts_us, 3)
        .field_u64("pid", party.pid())
        .field_u64("tid", 0)
        .field_raw(
            "args",
            &json::JsonObj::new().field_u64("value", value).finish(),
        );
    ev.finish()
}

/// Derive a per-party "active spans" counter track from the span list:
/// +1 at each span start, -1 at each end, emitted as cumulative
/// [`counter_event`] points so gauge timelines render alongside the
/// phase spans without any extra wire traffic.
fn active_span_counters(spans: &[Span]) -> Vec<String> {
    let mut out = Vec::new();
    for party in [Party::Client, Party::S0, Party::S1] {
        // (ts_ns, delta), end edges before start edges at equal ts so
        // the track never over-counts at span boundaries.
        let mut edges: Vec<(u64, i64)> = Vec::new();
        for s in spans.iter().filter(|s| s.party == party) {
            edges.push((s.start_ns, 1));
            edges.push((s.start_ns.saturating_add(s.dur_ns), -1));
        }
        if edges.is_empty() {
            continue;
        }
        edges.sort_by_key(|&(ts, delta)| (ts, delta));
        let mut active: i64 = 0;
        for (ts, delta) in edges {
            active += delta;
            out.push(counter_event(
                "fsl_active_spans_count",
                ts as f64 / 1_000.0,
                party,
                u64::try_from(active).unwrap_or(0),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_round_trip_through_recorder() {
        let rec = TraceRecorder::new(16);
        let a = rec.begin();
        rec.end(a, Phase::Eval, Party::S0, Some(3));
        let b = rec.begin();
        rec.end(b, Phase::Merge, Party::S0, None);
        let spans = rec.drain();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].phase, Phase::Eval);
        assert_eq!(spans[0].worker, Some(3));
        assert!(spans[1].start_ns >= spans[0].start_ns);
        assert!(rec.is_empty());
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn ring_overflow_evicts_oldest_and_counts_losses() {
        let rec = TraceRecorder::new(4);
        for i in 0..10u32 {
            rec.record(Span {
                phase: Phase::Eval,
                party: Party::S1,
                worker: Some(i),
                start_ns: u64::from(i),
                dur_ns: 1,
            });
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 6);
        let kept: Vec<u32> = rec.drain().iter().map(|s| s.worker.unwrap()).collect();
        assert_eq!(kept, vec![6, 7, 8, 9]);
        rec.reset();
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn reset_rebases_the_clock() {
        let rec = TraceRecorder::new(8);
        std::thread::sleep(std::time::Duration::from_millis(2));
        rec.reset();
        let s = rec.begin();
        rec.end(s, Phase::Keygen, Party::Client, None);
        let spans = rec.drain();
        // Well under the 2ms pre-reset sleep: the epoch moved.
        assert!(spans[0].start_ns < 2_000_000, "{}", spans[0].start_ns);
    }

    #[test]
    fn phase_and_party_bytes_round_trip() {
        for p in [
            Phase::Keygen,
            Phase::Upload,
            Phase::Eval,
            Phase::Merge,
            Phase::Reply,
            Phase::Accept,
            Phase::Ingest,
        ] {
            assert_eq!(Phase::from_byte(p.to_byte()), Some(p));
        }
        for p in [Party::Client, Party::S0, Party::S1] {
            assert_eq!(Party::from_byte(p.to_byte()), Some(p));
        }
        assert_eq!(Phase::from_byte(9), None);
        assert_eq!(Party::from_byte(9), None);
    }

    #[test]
    fn chrome_trace_is_valid_json_with_expected_lanes() {
        let spans = vec![
            Span {
                phase: Phase::Eval,
                party: Party::S1,
                worker: Some(2),
                start_ns: 1_500,
                dur_ns: 2_000,
            },
            Span {
                phase: Phase::Reply,
                party: Party::Client,
                worker: None,
                start_ns: 4_000,
                dur_ns: 500,
            },
        ];
        let doc = chrome_trace_json(&spans);
        assert!(json::validate(&doc), "{doc}");
        assert!(doc.contains("\"name\":\"eval\""), "{doc}");
        assert!(doc.contains("\"ph\":\"X\""), "{doc}");
        assert!(doc.contains("\"ts\":1.500"), "{doc}");
        assert!(doc.contains("\"pid\":2,\"tid\":3"), "{doc}");
        assert!(doc.contains("process_name"), "{doc}");
    }

    /// Counter-track events ride the same document: one active-spans
    /// step track per party plus caller-appended gauge points.
    #[test]
    fn chrome_trace_includes_counter_tracks() {
        let spans = vec![
            Span {
                phase: Phase::Eval,
                party: Party::S0,
                worker: Some(0),
                start_ns: 1_000,
                dur_ns: 4_000,
            },
            Span {
                phase: Phase::Eval,
                party: Party::S0,
                worker: Some(1),
                start_ns: 2_000,
                dur_ns: 1_000,
            },
        ];
        let extra = vec![counter_event(
            "fsl_trace_spans_dropped_count",
            0.0,
            Party::Client,
            7,
        )];
        let doc = chrome_trace_json_with(&spans, &extra);
        assert!(json::validate(&doc), "{doc}");
        assert!(doc.contains("\"ph\":\"C\""), "{doc}");
        assert!(doc.contains("\"name\":\"fsl_active_spans_count\""), "{doc}");
        // Overlap window [2000,3000]ns has two active spans.
        assert!(doc.contains("\"args\":{\"value\":2}"), "{doc}");
        // All spans closed: the track returns to zero.
        assert!(doc.contains("\"args\":{\"value\":0}"), "{doc}");
        assert!(
            doc.contains("\"name\":\"fsl_trace_spans_dropped_count\""),
            "{doc}"
        );
        assert!(doc.contains("\"args\":{\"value\":7}"), "{doc}");
    }

    /// Spans teed into an attached `PhaseMetrics` land in the phase and
    /// per-worker histograms; `reset` leaves them cumulative.
    #[test]
    fn attached_metrics_observe_spans() {
        let reg = MetricsRegistry::shared();
        let rec = TraceRecorder::new(16);
        rec.attach_metrics(PhaseMetrics::register(&reg));
        let s = rec.begin();
        rec.end(s, Phase::Eval, Party::S0, Some(2));
        rec.record(Span {
            phase: Phase::Merge,
            party: Party::S0,
            worker: None,
            start_ns: 0,
            dur_ns: 5_000,
        });
        rec.reset();
        let eval = reg.histogram_with(
            "fsl_phase_seconds",
            &[("phase", "eval")],
            "",
            Unit::Seconds,
        );
        let merge = reg.histogram_with(
            "fsl_phase_seconds",
            &[("phase", "merge")],
            "",
            Unit::Seconds,
        );
        let w2 = reg.histogram_with(
            "fsl_eval_worker_seconds",
            &[("worker", "2")],
            "",
            Unit::Seconds,
        );
        assert_eq!(eval.count(), 1);
        assert_eq!(merge.count(), 1);
        assert_eq!(merge.sum(), 5_000);
        assert_eq!(w2.count(), 1);
    }
}
