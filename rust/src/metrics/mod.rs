//! Communication and timing meters.
//!
//! Every protocol message is accounted twice: *measured* bytes (what our
//! wire encoding actually ships) and *paper-model* bits (the formulas of
//! §4/§6, e.g. `εk(⌈log Θ⌉(λ+2) + ⌈log 𝔾⌉) + λ`), so the Table 6 bench can
//! report both and show they agree.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub mod history;
pub mod json;
pub mod trace;

/// Direction-tagged byte counters for one party.
#[derive(Debug, Default)]
pub struct CommMeter {
    pub sent_bytes: AtomicU64,
    pub recv_bytes: AtomicU64,
    pub messages: AtomicU64,
}

impl CommMeter {
    /// New zeroed meter behind an `Arc` (shared with channel endpoints).
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Record an outgoing message.
    pub fn record_send(&self, bytes: usize) {
        self.sent_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an incoming message.
    pub fn record_recv(&self, bytes: usize) {
        self.recv_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    /// Total uploaded bytes.
    pub fn sent(&self) -> u64 {
        self.sent_bytes.load(Ordering::Relaxed)
    }

    /// Total downloaded bytes.
    pub fn recv(&self) -> u64 {
        self.recv_bytes.load(Ordering::Relaxed)
    }

    /// Total messages in *both* directions: `record_send` and
    /// `record_recv` each count one. (A long-standing bug counted sends
    /// only, so recv-heavy endpoints under-reported traffic.)
    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// Reset all counters.
    pub fn reset(&self) {
        self.sent_bytes.store(0, Ordering::Relaxed);
        self.recv_bytes.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
    }
}

/// Simple named stopwatch accumulator (per-phase round timings).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
}

impl PhaseTimer {
    /// Time a closure under a phase name.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.phases.push((name.to_string(), t0.elapsed()));
        out
    }

    /// Record an externally measured duration.
    pub fn record(&mut self, name: &str, d: Duration) {
        self.phases.push((name.to_string(), d));
    }

    /// Total duration of all phases with this name.
    pub fn total(&self, name: &str) -> Duration {
        self.phases
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .sum()
    }

    /// All recorded `(phase, duration)` pairs, in order.
    pub fn phases(&self) -> &[(String, Duration)] {
        &self.phases
    }
}

/// Pretty-print bytes as MB with 3 decimals (paper tables use MB).
pub fn mb(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// Bits → MB.
pub fn bits_to_mb(bits: usize) -> f64 {
    bits as f64 / 8.0 / (1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates() {
        let m = CommMeter::shared();
        m.record_send(100);
        m.record_send(24);
        m.record_recv(7);
        assert_eq!(m.sent(), 124);
        assert_eq!(m.recv(), 7);
        m.reset();
        assert_eq!(m.sent(), 0);
        assert_eq!(m.messages(), 0);
    }

    /// Regression: `record_recv` used to skip the message counter, so
    /// `messages()` silently reflected sends only.
    #[test]
    fn meter_counts_messages_in_both_directions() {
        let m = CommMeter::shared();
        m.record_send(10);
        m.record_recv(20);
        m.record_recv(30);
        assert_eq!(m.messages(), 3);
    }

    #[test]
    fn timer_accumulates_by_name() {
        let mut t = PhaseTimer::default();
        t.record("gen", Duration::from_millis(5));
        t.record("gen", Duration::from_millis(7));
        t.record("eval", Duration::from_millis(1));
        assert_eq!(t.total("gen"), Duration::from_millis(12));
        assert_eq!(t.phases().len(), 3);
    }

    #[test]
    fn unit_helpers() {
        assert!((mb(1024 * 1024) - 1.0).abs() < 1e-9);
        assert!((bits_to_mb(8 * 1024 * 1024) - 1.0).abs() < 1e-9);
    }
}
