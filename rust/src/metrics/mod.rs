//! Communication and timing meters.
//!
//! Every protocol message is accounted twice: *measured* bytes (what our
//! wire encoding actually ships) and *paper-model* bits (the formulas of
//! §4/§6, e.g. `εk(⌈log Θ⌉(λ+2) + ⌈log 𝔾⌉) + λ`), so the Table 6 bench can
//! report both and show they agree.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

pub mod expo;
pub mod history;
pub mod json;
pub mod registry;
pub mod trace;

/// Direction-tagged byte counters for one party.
///
/// A meter can additionally *mirror* into live registry counters (see
/// [`CommMeter::mirror_into`]): meters themselves are reset at the
/// start of every round so each [`crate::coordinator::RoundReport`]
/// covers exactly one round, while the mirrored registry counters stay
/// monotonic across rounds — the shape a scrape endpoint needs.
#[derive(Debug, Default)]
pub struct CommMeter {
    pub sent_bytes: AtomicU64,
    pub recv_bytes: AtomicU64,
    pub messages: AtomicU64,
    mirror: OnceLock<(registry::Counter, registry::Counter)>,
}

impl CommMeter {
    /// New zeroed meter behind an `Arc` (shared with channel endpoints).
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Additionally feed every future `record_send` / `record_recv`
    /// into a pair of registry counters. First call wins; the mirror
    /// survives [`CommMeter::reset`] so scraped totals stay monotonic.
    pub fn mirror_into(&self, sent: registry::Counter, recv: registry::Counter) {
        let _ = self.mirror.set((sent, recv));
    }

    /// Record an outgoing message.
    pub fn record_send(&self, bytes: usize) {
        self.sent_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
        if let Some((sent, _)) = self.mirror.get() {
            sent.add(bytes as u64);
        }
    }

    /// Record an incoming message.
    pub fn record_recv(&self, bytes: usize) {
        self.recv_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
        if let Some((_, recv)) = self.mirror.get() {
            recv.add(bytes as u64);
        }
    }

    /// Total uploaded bytes.
    pub fn sent(&self) -> u64 {
        self.sent_bytes.load(Ordering::Relaxed)
    }

    /// Total downloaded bytes.
    pub fn recv(&self) -> u64 {
        self.recv_bytes.load(Ordering::Relaxed)
    }

    /// Total messages in *both* directions: `record_send` and
    /// `record_recv` each count one. (A long-standing bug counted sends
    /// only, so recv-heavy endpoints under-reported traffic.)
    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// Reset all counters.
    pub fn reset(&self) {
        self.sent_bytes.store(0, Ordering::Relaxed);
        self.recv_bytes.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
    }
}

/// Simple named stopwatch accumulator (per-phase round timings).
#[deprecated(
    since = "0.10.0",
    note = "superseded by `trace::TraceRecorder` spans and \
            `registry::Histogram` latency metrics; see the equivalence \
            test `timer_equivalent_to_histogram`"
)]
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
}

// lint: allow(deprecated) — the deprecated timer's own inherent impl
#[allow(deprecated)]
impl PhaseTimer {
    /// Time a closure under a phase name.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.phases.push((name.to_string(), t0.elapsed()));
        out
    }

    /// Record an externally measured duration.
    pub fn record(&mut self, name: &str, d: Duration) {
        self.phases.push((name.to_string(), d));
    }

    /// Total duration of all phases with this name.
    pub fn total(&self, name: &str) -> Duration {
        self.phases
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .sum()
    }

    /// All recorded `(phase, duration)` pairs, in order.
    pub fn phases(&self) -> &[(String, Duration)] {
        &self.phases
    }
}

/// Pretty-print bytes as MB with 3 decimals (paper tables use MB).
pub fn mb(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// Bits → MB.
pub fn bits_to_mb(bits: usize) -> f64 {
    bits as f64 / 8.0 / (1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates() {
        let m = CommMeter::shared();
        m.record_send(100);
        m.record_send(24);
        m.record_recv(7);
        assert_eq!(m.sent(), 124);
        assert_eq!(m.recv(), 7);
        m.reset();
        assert_eq!(m.sent(), 0);
        assert_eq!(m.messages(), 0);
    }

    /// Regression: `record_recv` used to skip the message counter, so
    /// `messages()` silently reflected sends only.
    #[test]
    fn meter_counts_messages_in_both_directions() {
        let m = CommMeter::shared();
        m.record_send(10);
        m.record_recv(20);
        m.record_recv(30);
        assert_eq!(m.messages(), 3);
    }

    /// Labelled equivalence for the deprecated `PhaseTimer`: the same
    /// durations recorded into a per-phase-labelled registry histogram
    /// yield identical totals, so migrating callers lose nothing.
    #[test]
    #[allow(deprecated)]
    fn timer_equivalent_to_histogram() {
        let mut t = PhaseTimer::default();
        let reg = registry::MetricsRegistry::new();
        let gen = reg.histogram_with(
            "fsl_phase_seconds",
            &[("phase", "gen")],
            "h",
            registry::Unit::Seconds,
        );
        let eval = reg.histogram_with(
            "fsl_phase_seconds",
            &[("phase", "eval")],
            "h",
            registry::Unit::Seconds,
        );
        for (name, ms) in [("gen", 5), ("gen", 7), ("eval", 1)] {
            let d = Duration::from_millis(ms);
            t.record(name, d);
            match name {
                "gen" => gen.observe_duration(d),
                _ => eval.observe_duration(d),
            }
        }
        assert_eq!(t.total("gen"), Duration::from_millis(12));
        assert_eq!(t.phases().len(), 3);
        assert_eq!(gen.sum(), 12_000_000); // ns, same total as the timer
        assert_eq!(gen.count(), 2);
        assert_eq!(eval.sum(), 1_000_000);
    }

    /// Mirrored registry counters keep accumulating across the
    /// per-round `reset()` that zeroes the meter itself.
    #[test]
    fn meter_mirror_survives_reset() {
        let reg = registry::MetricsRegistry::new();
        let m = CommMeter::shared();
        m.mirror_into(
            reg.counter("fsl_transport_sent_bytes", "h"),
            reg.counter("fsl_transport_recv_bytes", "h"),
        );
        m.record_send(100);
        m.record_recv(40);
        m.reset();
        m.record_send(1);
        assert_eq!(m.sent(), 1);
        assert_eq!(reg.counter("fsl_transport_sent_bytes", "h").get(), 101);
        assert_eq!(reg.counter("fsl_transport_recv_bytes", "h").get(), 40);
    }

    #[test]
    fn unit_helpers() {
        assert!((mb(1024 * 1024) - 1.0).abs() < 1e-9);
        assert!((bits_to_mb(8 * 1024 * 1024) - 1.0).abs() < 1e-9);
    }
}
