//! A minimal hand-rolled JSON writer shared by `RoundReport::to_json`,
//! the bench datapoints, and the `artifacts/HISTORY.jsonl` history file.
//!
//! The repo vendors no serde; every JSON producer used to interpolate
//! strings straight into `format!` which silently breaks on quotes,
//! backslashes or control characters. `JsonObj` centralises the escaping
//! so every emitter produces valid JSON by construction, and [`validate`]
//! gives tests a dependency-free syntax check for whole documents.

use std::fmt::Write as _;

/// Escape a string for inclusion inside a JSON string literal (the
/// surrounding quotes are the caller's).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Incremental JSON object writer. Fields appear in insertion order;
/// string values are escaped, numeric values are written verbatim.
#[derive(Debug, Default)]
pub struct JsonObj {
    buf: String,
    any: bool,
}

impl JsonObj {
    pub fn new() -> Self {
        JsonObj {
            buf: String::from("{"),
            any: false,
        }
    }

    fn key(&mut self, name: &str) {
        if self.any {
            self.buf.push(',');
        }
        self.any = true;
        let _ = write!(self.buf, "\"{}\":", escape(name));
    }

    pub fn field_str(&mut self, name: &str, value: &str) -> &mut Self {
        self.key(name);
        let _ = write!(self.buf, "\"{}\"", escape(value));
        self
    }

    pub fn field_u64(&mut self, name: &str, value: u64) -> &mut Self {
        self.key(name);
        let _ = write!(self.buf, "{value}");
        self
    }

    /// A float with a fixed number of decimals (JSON has no NaN/Inf:
    /// non-finite values are clamped to 0 rather than corrupting the
    /// document).
    pub fn field_f64(&mut self, name: &str, value: f64, decimals: usize) -> &mut Self {
        self.key(name);
        let v = if value.is_finite() { value } else { 0.0 };
        let _ = write!(self.buf, "{v:.decimals$}");
        self
    }

    pub fn field_bool(&mut self, name: &str, value: bool) -> &mut Self {
        self.key(name);
        let _ = write!(self.buf, "{value}");
        self
    }

    /// A pre-rendered JSON value (nested object, array, …). The caller
    /// vouches that `raw` is itself valid JSON.
    pub fn field_raw(&mut self, name: &str, raw: &str) -> &mut Self {
        self.key(name);
        self.buf.push_str(raw);
        self
    }

    pub fn finish(&mut self) -> String {
        let mut out = std::mem::take(&mut self.buf);
        out.push('}');
        self.any = false;
        out
    }
}

/// Render a list of pre-rendered JSON values as a JSON array.
pub fn array(items: impl IntoIterator<Item = String>) -> String {
    let mut out = String::from("[");
    for (i, item) in items.into_iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&item);
    }
    out.push(']');
    out
}

/// Render a quoted, escaped JSON string literal.
pub fn string(s: &str) -> String {
    format!("\"{}\"", escape(s))
}

/// Check that `s` is one complete, syntactically valid JSON value.
/// Recursive-descent over the grammar; used by tests to guard every
/// hand-rolled emitter in the repo.
pub fn validate(s: &str) -> bool {
    let b = s.as_bytes();
    let mut pos = 0;
    if !value(b, &mut pos) {
        return false;
    }
    skip_ws(b, &mut pos);
    pos == b.len()
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while b.get(*pos).is_some_and(|c| c.is_ascii_whitespace()) {
        *pos += 1;
    }
}

fn value(b: &[u8], pos: &mut usize) -> bool {
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'{') => object(b, pos),
        Some(b'[') => array_val(b, pos),
        Some(b'"') => string_val(b, pos),
        Some(b't') => literal(b, pos, b"true"),
        Some(b'f') => literal(b, pos, b"false"),
        Some(b'n') => literal(b, pos, b"null"),
        Some(c) if *c == b'-' || c.is_ascii_digit() => number(b, pos),
        _ => false,
    }
}

fn literal(b: &[u8], pos: &mut usize, word: &[u8]) -> bool {
    if b[*pos..].starts_with(word) {
        *pos += word.len();
        true
    } else {
        false
    }
}

fn object(b: &[u8], pos: &mut usize) -> bool {
    *pos += 1; // '{'
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return true;
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') || !string_val(b, pos) {
            return false;
        }
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return false;
        }
        *pos += 1;
        if !value(b, pos) {
            return false;
        }
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return true;
            }
            _ => return false,
        }
    }
}

fn array_val(b: &[u8], pos: &mut usize) -> bool {
    *pos += 1; // '['
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return true;
    }
    loop {
        if !value(b, pos) {
            return false;
        }
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return true;
            }
            _ => return false,
        }
    }
}

fn string_val(b: &[u8], pos: &mut usize) -> bool {
    *pos += 1; // '"'
    while let Some(&c) = b.get(*pos) {
        match c {
            b'"' => {
                *pos += 1;
                return true;
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => *pos += 1,
                    Some(b'u') => {
                        *pos += 1;
                        for _ in 0..4 {
                            if !b.get(*pos).is_some_and(u8::is_ascii_hexdigit) {
                                return false;
                            }
                            *pos += 1;
                        }
                    }
                    _ => return false,
                }
            }
            c if c < 0x20 => return false,
            _ => *pos += 1,
        }
    }
    false
}

fn number(b: &[u8], pos: &mut usize) -> bool {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let int_start = *pos;
    while b.get(*pos).is_some_and(u8::is_ascii_digit) {
        *pos += 1;
    }
    if *pos == int_start {
        return false;
    }
    if b.get(*pos) == Some(&b'.') {
        *pos += 1;
        let frac_start = *pos;
        while b.get(*pos).is_some_and(u8::is_ascii_digit) {
            *pos += 1;
        }
        if *pos == frac_start {
            return false;
        }
    }
    if matches!(b.get(*pos), Some(b'e' | b'E')) {
        *pos += 1;
        if matches!(b.get(*pos), Some(b'+' | b'-')) {
            *pos += 1;
        }
        let exp_start = *pos;
        while b.get(*pos).is_some_and(u8::is_ascii_digit) {
            *pos += 1;
        }
        if *pos == exp_start {
            return false;
        }
    }
    *pos > start
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_every_risky_character() {
        assert_eq!(escape("a\"b"), "a\\\"b");
        assert_eq!(escape("a\\b"), "a\\\\b");
        assert_eq!(escape("a\nb\tc\r"), "a\\nb\\tc\\r");
        assert_eq!(escape("\u{01}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn builder_emits_valid_json_with_hostile_strings() {
        let mut o = JsonObj::new();
        o.field_str("kind", "a\"b\\c\nd")
            .field_u64("n", 42)
            .field_f64("ms", 1.23456, 3)
            .field_bool("ok", true)
            .field_raw("list", &array(vec![string("x\"y"), "7".into()]));
        let s = o.finish();
        assert!(validate(&s), "{s}");
        assert!(s.contains("\"ms\":1.235"), "{s}");
    }

    #[test]
    fn empty_object_and_nonfinite_floats() {
        let s = JsonObj::new().finish();
        assert_eq!(s, "{}");
        let mut o = JsonObj::new();
        o.field_f64("bad", f64::NAN, 2);
        let s = o.finish();
        assert!(validate(&s), "{s}");
        assert!(s.contains("0.00"), "{s}");
    }

    #[test]
    fn validator_accepts_and_rejects() {
        for good in [
            "{}",
            "[]",
            "null",
            "-1.5e-3",
            r#"{"a":[1,2,{"b":"c\n"}],"d":false}"#,
            "  {  \"x\" : 1 }  ",
        ] {
            assert!(validate(good), "{good}");
        }
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{'a':1}",
            "1 2",
            "{\"a\":1,}",
            "\"unterminated",
            "01e",
            "nul",
        ] {
            assert!(!validate(bad), "{bad}");
        }
    }
}
