//! Wire encodings for protocol messages.
//!
//! Everything crossing a [`crate::net::Endpoint`] is a length-prefixed
//! byte message built here, so the Table-6 communication numbers come from
//! the real encodings (and are cross-checked against the paper's bit
//! formulas in `metrics`).
//!
//! Stream transports (TCP) have no message boundaries, so every message
//! they carry additionally travels inside a *frame*: a fixed header of
//! magic bytes, a wire-format version, and the payload length, guarded by
//! [`MAX_FRAME_LEN`]. A malformed, foreign, or truncated frame fails with
//! a typed [`FrameError`] at the envelope boundary instead of a confusing
//! decode failure (or worse) deep inside a message decoder. The
//! in-process channels keep their historical raw encodings — `mpsc`
//! already preserves boundaries, and framing there would silently change
//! every measured byte count.

use crate::crypto::Sensitive;
use crate::dpf::{CorrectionWord, DpfKey, MasterKeyBatch, PublicPart};
use crate::group::Group;
use crate::udpf::{Hint, UdpfKey};

// ---- decode-side ceilings ----------------------------------------------
//
// Every length-prefixed decoder checks one of these `MAX_WIRE_*` caps
// *before* its first length-driven allocation (the `xtask` lint enforces
// the pattern). The remaining-bytes checks below already prevent a
// malicious count from out-sizing the payload; the caps additionally pin
// each collection to its protocol-plausible order of magnitude, so a
// hostile-but-well-framed message cannot reserve gigabytes.

/// Ceiling on per-upload public parts (one per cuckoo bin/stash slot).
pub const MAX_WIRE_PUBLICS: usize = 1 << 22;
/// Ceiling on group elements in one share vector (covers a full
/// 2²⁵-element weight install with headroom).
pub const MAX_WIRE_SHARES: usize = 1 << 27;
/// Ceiling on U-DPF keys in one retained key set.
pub const MAX_WIRE_UDPF_KEYS: usize = 1 << 22;
/// Ceiling on per-epoch U-DPF hints (one per bin/stash slot).
pub const MAX_WIRE_HINTS: usize = 1 << 22;
/// Ceiling on indices in one PSU/union message.
pub const MAX_WIRE_INDICES: usize = 1 << 27;

/// LE u32 append — shared with the control-plane codec
/// (`coordinator/wire.rs`), which builds on these primitives.
pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// LE u32 cursor read (`None` on truncation) — shared like [`put_u32`].
pub(crate) fn get_u32(bytes: &[u8], off: &mut usize) -> Option<u32> {
    let v = u32::from_le_bytes(bytes.get(*off..*off + 4)?.try_into().ok()?);
    *off += 4;
    Some(v)
}

// ---- frame envelope (stream transports) --------------------------------

/// Frame magic: the first bytes of every framed message. Chosen to be
/// invalid UTF-8 and an implausible length prefix, so cross-protocol
/// traffic (an HTTP client, a stray TLS hello) fails immediately.
pub const FRAME_MAGIC: [u8; 2] = [0xF5, 0x1D];
/// Wire-format version carried in every frame header. Bump on any
/// incompatible change to the encodings in this module.
pub const FRAME_VERSION: u8 = 1;
/// Frame header layout: magic (2) + version (1) + payload length (4, LE).
pub const FRAME_HEADER_LEN: usize = 7;
/// Hard ceiling on a single frame's payload. Large enough for a full
/// 2²⁵-element weight install, small enough that a corrupted length field
/// cannot OOM the receiver.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// Typed frame-envelope failure. Everything here is detectable from the
/// fixed-size header alone, *before* any payload is read or allocated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The first two bytes are not [`FRAME_MAGIC`] — not our protocol.
    BadMagic([u8; 2]),
    /// Magic matched but the version byte is foreign.
    BadVersion(u8),
    /// The declared payload length exceeds [`MAX_FRAME_LEN`].
    Oversize(usize),
    /// Fewer bytes than a header, or fewer payload bytes than declared.
    Truncated { declared: usize, got: usize },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic(m) => {
                write!(f, "bad frame magic {m:02x?} (expected {FRAME_MAGIC:02x?})")
            }
            FrameError::BadVersion(v) => {
                write!(f, "unsupported frame version {v} (this build speaks {FRAME_VERSION})")
            }
            FrameError::Oversize(len) => {
                write!(f, "frame declares {len} payload bytes (max {MAX_FRAME_LEN})")
            }
            FrameError::Truncated { declared, got } => {
                write!(f, "truncated frame: declared {declared} bytes, got {got}")
            }
        }
    }
}

impl std::error::Error for FrameError {}

/// Wrap a payload in a frame envelope (header + payload, one allocation).
///
/// Panics if `payload` exceeds [`MAX_FRAME_LEN`] — senders build payloads
/// from their own data, so an oversize frame is a programming error, not
/// an input error.
pub fn frame(payload: &[u8]) -> Vec<u8> {
    assert!(
        payload.len() <= MAX_FRAME_LEN,
        "frame payload of {} bytes exceeds MAX_FRAME_LEN",
        payload.len()
    );
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    out.extend_from_slice(&FRAME_MAGIC);
    out.push(FRAME_VERSION);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(payload);
    out
}

/// Validate a frame *header* and return the declared payload length.
/// Stream receivers call this on the first [`FRAME_HEADER_LEN`] bytes to
/// learn how much more to read — the [`MAX_FRAME_LEN`] guard runs here,
/// before any payload allocation.
pub fn frame_payload_len(header: &[u8]) -> Result<usize, FrameError> {
    if header.len() < FRAME_HEADER_LEN {
        return Err(FrameError::Truncated {
            declared: FRAME_HEADER_LEN,
            got: header.len(),
        });
    }
    let magic = [header[0], header[1]];
    if magic != FRAME_MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    if header[2] != FRAME_VERSION {
        return Err(FrameError::BadVersion(header[2]));
    }
    let len = u32::from_le_bytes([header[3], header[4], header[5], header[6]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(FrameError::Oversize(len));
    }
    Ok(len)
}

/// Unwrap one complete frame, returning its payload slice. The frame must
/// span `bytes` exactly — trailing garbage is a truncation of the *next*
/// frame and is reported as such.
pub fn unframe(bytes: &[u8]) -> Result<&[u8], FrameError> {
    let len = frame_payload_len(bytes)?;
    let body = &bytes[FRAME_HEADER_LEN..];
    if body.len() != len {
        return Err(FrameError::Truncated {
            declared: len,
            got: body.len(),
        });
    }
    Ok(body)
}

/// Encode a client's full key upload (master seed for one server + the
/// shared public parts). `include_publics = false` encodes the short
/// message to the second server (just the master seed — the public parts
/// travel once and are forwarded server-to-server, §4 Efficiency).
pub fn encode_key_upload<G: Group>(
    batch: &MasterKeyBatch<G>,
    server: u8,
    include_publics: bool,
) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(server);
    out.extend_from_slice(batch.msk[server as usize].expose());
    out.push(include_publics as u8);
    if include_publics {
        encode_publics(&mut out, &batch.publics);
    }
    out
}

/// Shared publics-region encoding (count + per-bin depth/CWs/output CW),
/// used by both the client key upload and the full master-batch codec.
fn encode_publics<G: Group>(out: &mut Vec<u8>, publics: &[PublicPart<G>]) {
    put_u32(out, publics.len() as u32);
    for p in publics {
        out.push(p.depth as u8);
        for cw in &p.cws {
            out.extend_from_slice(&cw.seed);
            out.push(cw.t_left as u8 | ((cw.t_right as u8) << 1));
        }
        p.cw_out.encode(out);
    }
}

/// Shared publics-region decoding, advancing `off` past the region.
fn decode_publics<G: Group>(bytes: &[u8], off: &mut usize) -> Option<Vec<PublicPart<G>>> {
    let count = get_u32(bytes, off)? as usize;
    // Cap + length sanity BEFORE allocating: each public part is ≥ 1 byte
    // (depth tag).
    if count > MAX_WIRE_PUBLICS || count > bytes.len().saturating_sub(*off) {
        return None;
    }
    let mut publics = Vec::with_capacity(count);
    for _ in 0..count {
        let depth = *bytes.get(*off)? as usize;
        *off += 1;
        let mut cws = Vec::with_capacity(depth);
        for _ in 0..depth {
            let seed: [u8; 16] = bytes.get(*off..*off + 16)?.try_into().ok()?;
            let bits = *bytes.get(*off + 16)?;
            *off += 17;
            cws.push(CorrectionWord {
                seed,
                t_left: bits & 1 == 1,
                t_right: bits & 2 == 2,
            });
        }
        let cw_out = G::decode(bytes.get(*off..)?)?;
        *off += G::byte_len();
        publics.push(PublicPart { depth, cws, cw_out });
    }
    Some(publics)
}

/// Decoded key upload.
pub struct KeyUpload<G: Group> {
    pub server: u8,
    pub msk: [u8; 16],
    pub publics: Option<Vec<PublicPart<G>>>,
}

/// Parse [`encode_key_upload`] output.
pub fn decode_key_upload<G: Group>(bytes: &[u8]) -> Option<KeyUpload<G>> {
    let server = *bytes.first()?;
    let msk: [u8; 16] = bytes.get(1..17)?.try_into().ok()?;
    let has_publics = *bytes.get(17)? == 1;
    let mut off = 18;
    let publics = if has_publics {
        Some(decode_publics(bytes, &mut off)?)
    } else {
        None
    };
    Some(KeyUpload {
        server,
        msk,
        publics,
    })
}

/// Encode a complete [`MasterKeyBatch`] — *both* master seeds plus the
/// shared publics. This never travels client→server (a client ships each
/// server only that server's seed, [`encode_key_upload`]); it exists for
/// the driver→leader control plane of remote verified-SSA rounds, where
/// the driver hands `S_0` adversarial uploads whole, exactly as the
/// in-process API does.
pub fn encode_master_batch<G: Group>(batch: &MasterKeyBatch<G>) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(batch.msk[0].expose());
    out.extend_from_slice(batch.msk[1].expose());
    encode_publics(&mut out, &batch.publics);
    out
}

/// Parse [`encode_master_batch`] output (must span `bytes` exactly).
pub fn decode_master_batch<G: Group>(bytes: &[u8]) -> Option<MasterKeyBatch<G>> {
    let msk0: [u8; 16] = bytes.get(..16)?.try_into().ok()?;
    let msk1: [u8; 16] = bytes.get(16..32)?.try_into().ok()?;
    let mut off = 32;
    let publics = decode_publics(bytes, &mut off)?;
    (off == bytes.len()).then_some(MasterKeyBatch {
        msk: [Sensitive::new(msk0), Sensitive::new(msk1)],
        publics,
    })
}

/// Encode a vector of group elements (PSR answers, SSA share vectors).
pub fn encode_shares<G: Group>(shares: &[G]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + shares.len() * G::byte_len());
    put_u32(&mut out, shares.len() as u32);
    for s in shares {
        s.encode(&mut out);
    }
    out
}

/// Parse [`encode_shares`] output.
pub fn decode_shares<G: Group>(bytes: &[u8]) -> Option<Vec<G>> {
    let mut off = 0;
    let count = get_u32(bytes, &mut off)? as usize;
    // Cap + length sanity BEFORE allocating: a malicious count must not
    // OOM us.
    if count > MAX_WIRE_SHARES
        || count.checked_mul(G::byte_len())? > bytes.len().saturating_sub(off)
    {
        return None;
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(G::decode(bytes.get(off..)?)?);
        off += G::byte_len();
    }
    Some(out)
}

/// Encode one server's retained U-DPF key set (the round-1 upload of the
/// fixed-submodel flow, §6 Table 2 row 3): one length-prefixed
/// [`DpfKey`] encoding per bin/stash slot.
pub fn encode_udpf_keys<G: Group>(keys: &[UdpfKey<G>]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u32(&mut out, keys.len() as u32);
    for k in keys {
        let bytes = k.inner.to_bytes();
        put_u32(&mut out, bytes.len() as u32);
        out.extend_from_slice(&bytes);
    }
    out
}

/// Parse [`encode_udpf_keys`] output.
pub fn decode_udpf_keys<G: Group>(bytes: &[u8]) -> Option<Vec<UdpfKey<G>>> {
    let mut off = 0;
    let count = get_u32(bytes, &mut off)? as usize;
    // Cap + length sanity BEFORE allocating: each key is ≥ 4 bytes (its
    // length prefix).
    if count > MAX_WIRE_UDPF_KEYS || count.checked_mul(4)? > bytes.len().saturating_sub(off) {
        return None;
    }
    let mut keys = Vec::with_capacity(count);
    for _ in 0..count {
        let len = get_u32(bytes, &mut off)? as usize;
        let slice = bytes.get(off..off.checked_add(len)?)?;
        off += len;
        keys.push(UdpfKey {
            inner: DpfKey::from_bytes(slice)?,
        });
    }
    Some(keys)
}

/// Encode one epoch's U-DPF hint vector (one `⌈log 𝔾⌉`-bit output CW per
/// bin/stash slot, plus the epoch tag) — the `k·l`-bit per-round upload
/// of §6's U-DPF row.
pub fn encode_hints<G: Group>(hints: &[Hint<G>]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + hints.len() * (8 + G::byte_len()));
    put_u32(&mut out, hints.len() as u32);
    for h in hints {
        out.extend_from_slice(&h.epoch.to_le_bytes());
        h.cw_out.encode(&mut out);
    }
    out
}

/// Parse [`encode_hints`] output.
pub fn decode_hints<G: Group>(bytes: &[u8]) -> Option<Vec<Hint<G>>> {
    let mut off = 0;
    let count = get_u32(bytes, &mut off)? as usize;
    if count > MAX_WIRE_HINTS
        || count.checked_mul(8 + G::byte_len())? > bytes.len().saturating_sub(off)
    {
        return None;
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let epoch = u64::from_le_bytes(bytes.get(off..off + 8)?.try_into().ok()?);
        off += 8;
        let cw_out = G::decode(bytes.get(off..)?)?;
        off += G::byte_len();
        out.push(Hint { epoch, cw_out });
    }
    Some(out)
}

/// Encode a sorted index list (PSU messages, union broadcasts).
pub fn encode_indices(indices: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + indices.len() * 8);
    put_u32(&mut out, indices.len() as u32);
    for &i in indices {
        out.extend_from_slice(&i.to_le_bytes());
    }
    out
}

/// Parse [`encode_indices`] output.
pub fn decode_indices(bytes: &[u8]) -> Option<Vec<u64>> {
    let mut off = 0;
    let count = get_u32(bytes, &mut off)? as usize;
    if count > MAX_WIRE_INDICES || count.checked_mul(8)? > bytes.len().saturating_sub(off) {
        return None;
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(u64::from_le_bytes(bytes.get(off..off + 8)?.try_into().ok()?));
        off += 8;
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;
    use crate::dpf::{gen_batch_with_master, BinPoint};

    #[test]
    fn key_upload_roundtrip() {
        let mut rng = Rng::new(80);
        let bins: Vec<BinPoint<u128>> = vec![
            BinPoint { depth: 9, point: Some((5, 1)) },
            BinPoint { depth: 9, point: None },
            BinPoint { depth: 4, point: Some((3, 99)) },
        ];
        let batch = gen_batch_with_master(&bins, rng.gen_seed(), rng.gen_seed());
        let long = encode_key_upload(&batch, 0, true);
        let short = encode_key_upload(&batch, 1, false);
        assert!(short.len() < long.len());
        let du = decode_key_upload::<u128>(&long).unwrap();
        assert_eq!(du.msk, *batch.msk[0]);
        let pubs = du.publics.unwrap();
        assert_eq!(pubs.len(), 3);
        assert_eq!(pubs[0].cw_out, batch.publics[0].cw_out);
        let ds = decode_key_upload::<u128>(&short).unwrap();
        assert!(ds.publics.is_none());
        assert_eq!(ds.msk, *batch.msk[1]);
    }

    #[test]
    fn shares_roundtrip() {
        let shares: Vec<u64> = vec![1, u64::MAX, 42];
        assert_eq!(decode_shares::<u64>(&encode_shares(&shares)).unwrap(), shares);
        let empty: Vec<u128> = vec![];
        assert_eq!(decode_shares::<u128>(&encode_shares(&empty)).unwrap(), empty);
    }

    #[test]
    fn indices_roundtrip() {
        let idx = vec![0u64, 7, 1 << 40];
        assert_eq!(decode_indices(&encode_indices(&idx)).unwrap(), idx);
    }

    #[test]
    fn udpf_keys_roundtrip() {
        let mut rng = Rng::new(81);
        let keys: Vec<crate::udpf::UdpfKey<u64>> = (0..3)
            .map(|i| {
                let (k0, _k1, _st) =
                    crate::udpf::gen(4 + i, 3, &99u64, rng.gen_seed(), rng.gen_seed());
                k0
            })
            .collect();
        let enc = encode_udpf_keys(&keys);
        let dec = decode_udpf_keys::<u64>(&enc).unwrap();
        assert_eq!(dec.len(), 3);
        for (a, b) in keys.iter().zip(&dec) {
            assert_eq!(a.inner.to_bytes(), b.inner.to_bytes());
        }
        for cut in [1usize, 5, enc.len() - 1] {
            assert!(decode_udpf_keys::<u64>(&enc[..cut]).is_none(), "cut {cut}");
        }
    }

    #[test]
    fn hints_roundtrip() {
        let hints: Vec<crate::udpf::Hint<u128>> = (0..4)
            .map(|e| crate::udpf::Hint { epoch: e, cw_out: (e as u128) << 80 })
            .collect();
        let enc = encode_hints(&hints);
        assert_eq!(decode_hints::<u128>(&enc).unwrap(), hints);
        assert!(decode_hints::<u128>(&enc[..enc.len() - 1]).is_none());
        assert!(decode_hints::<u64>(&[9, 0, 0, 0, 1]).is_none());
    }

    #[test]
    fn malformed_rejected() {
        assert!(decode_key_upload::<u64>(&[0, 1, 2]).is_none());
        assert!(decode_shares::<u64>(&[9, 0, 0, 0, 1]).is_none());
    }

    #[test]
    fn frame_roundtrip_and_header_checks() {
        let payload = vec![7u8, 8, 9];
        let framed = frame(&payload);
        assert_eq!(framed.len(), FRAME_HEADER_LEN + payload.len());
        assert_eq!(unframe(&framed).unwrap(), &payload[..]);
        assert_eq!(frame_payload_len(&framed).unwrap(), payload.len());
        // Empty payloads frame too (ack-style messages).
        assert_eq!(unframe(&frame(&[])).unwrap(), &[] as &[u8]);

        let mut bad_magic = framed.clone();
        bad_magic[0] ^= 0xff;
        assert!(matches!(unframe(&bad_magic), Err(FrameError::BadMagic(_))));

        let mut bad_version = framed.clone();
        bad_version[2] = FRAME_VERSION + 1;
        assert_eq!(
            unframe(&bad_version),
            Err(FrameError::BadVersion(FRAME_VERSION + 1))
        );

        // Truncations: inside the header and inside the payload.
        for cut in 0..framed.len() {
            assert!(
                matches!(unframe(&framed[..cut]), Err(FrameError::Truncated { .. })),
                "cut {cut}"
            );
        }

        // An oversize declared length is rejected from the header alone.
        let mut oversize = frame(&[1, 2, 3]);
        oversize[3..7].copy_from_slice(&(MAX_FRAME_LEN as u32 + 1).to_le_bytes());
        assert_eq!(
            frame_payload_len(&oversize),
            Err(FrameError::Oversize(MAX_FRAME_LEN + 1))
        );
    }

    #[test]
    fn master_batch_roundtrip() {
        let mut rng = Rng::new(82);
        let bins: Vec<BinPoint<u64>> = vec![
            BinPoint { depth: 6, point: Some((9, 44)) },
            BinPoint { depth: 3, point: None },
        ];
        let batch = gen_batch_with_master(&bins, rng.gen_seed(), rng.gen_seed());
        let enc = encode_master_batch(&batch);
        let dec = decode_master_batch::<u64>(&enc).unwrap();
        assert_eq!(dec.msk, batch.msk);
        assert_eq!(
            encode_master_batch(&dec),
            enc,
            "re-encoding must be byte-identical"
        );
        for cut in 0..enc.len() {
            assert!(decode_master_batch::<u64>(&enc[..cut]).is_none(), "cut {cut}");
        }
        // Trailing garbage is rejected (the batch must span exactly).
        let mut padded = enc.clone();
        padded.push(0);
        assert!(decode_master_batch::<u64>(&padded).is_none());
    }
}
