//! Private Submodel Retrieval (Fig. 4, top half).
//!
//! Client: insert the k selections into a cuckoo table; per bin `j`,
//! generate DPF keys for `f_{pos_j, 1}` (dummy `f_{0,0}` for empty bins);
//! per stash slot, keys over the whole alignment domain. Upload one master
//! seed per server plus the shared public parts.
//!
//! Server `b`: full-domain-evaluate each bin key over its simple bin and
//! answer with the inner products `[w'_j]_b = Σ_d w_{T_simple[j][d]} ·
//! [f(d)]_b`. The two answers sum to exactly the requested weights.
//!
//! The server answer loop itself lives in
//! [`super::retrieve::RetrievalEngine`] (sharded, batched, zero-copy);
//! [`server_answer`] here is a thin wrapper kept for compatibility.

use super::retrieve::RetrievalEngine;
use super::session::Session;
use crate::crypto::rng::Rng;
use crate::dpf::{self, gen_batch_with_master, BinPoint, DpfKey, MasterKeyBatch};
use crate::group::Group;
use crate::hashing::{CuckooError, CuckooTable};

/// Client-side retrieval context kept between query and reconstruct.
pub struct PsrClientCtx {
    pub cuckoo: CuckooTable,
}

/// Build the client's query: the cuckoo table and the batched DPF keys
/// (B bin keys + σ stash keys, in that order).
///
/// Duplicate indices in `selections` are allowed: they retrieve the same
/// weight, so the cuckoo table is built over the distinct set (the read
/// path's counterpart of SSA's duplicate-summing convention) — repeated
/// indices must not fight each other for bins or spuriously overflow the
/// stash.
pub fn client_query<G: Group>(
    session: &Session,
    selections: &[u64],
    rng: &mut Rng,
) -> Result<(PsrClientCtx, MasterKeyBatch<G>), CuckooError> {
    let mut seen = std::collections::HashSet::with_capacity(selections.len());
    let uniq: Vec<u64> = selections.iter().copied().filter(|u| seen.insert(*u)).collect();
    let bins = build_bin_points(session, &uniq, rng, |_u| G::one())?;
    let batch = gen_batch_with_master(&bins.points, rng.gen_seed(), rng.gen_seed());
    Ok((PsrClientCtx { cuckoo: bins.cuckoo }, batch))
}

pub(crate) struct BinPoints<G: Group> {
    pub cuckoo: CuckooTable,
    pub points: Vec<BinPoint<G>>,
}

/// Shared between PSR and SSA: place each selection in its bin and emit
/// one `BinPoint` per bin (+ stash), with payload chosen by `beta_of`.
pub(crate) fn build_bin_points<G: Group>(
    session: &Session,
    selections: &[u64],
    rng: &mut Rng,
    beta_of: impl Fn(u64) -> G,
) -> Result<BinPoints<G>, CuckooError> {
    let cuckoo = CuckooTable::build_with_bins(
        selections,
        session.simple.num_bins(),
        &session.params.cuckoo,
        rng,
    )?;
    let simple = &session.simple;
    assert_eq!(cuckoo.num_bins(), simple.num_bins(), "table misalignment");

    let stash_depth = dpf::depth_for(session.domain_size());
    let mut points = Vec::with_capacity(cuckoo.num_bins() + session.params.cuckoo.sigma);

    for (j, slot) in cuckoo.bins().iter().enumerate() {
        let theta_j = simple.bin(j).len().max(2);
        let depth = dpf::depth_for(theta_j);
        let point = slot.map(|u| {
            // lint: allow(panic) — cuckoo and simple tables are built from
            // the same hash family, so every cuckoo occupant is in the
            // matching simple bin by construction (Fig. 3 alignment).
            let pos = simple
                .position(j, u)
                .expect("alignment invariant: cuckoo element present in simple bin");
            (pos as u64, beta_of(u))
        });
        points.push(BinPoint { depth, point });
    }
    // Stash slots: keys over the whole domain (occupied or dummy), always
    // σ of them so the upload shape is data-independent (Fig. 3).
    for t in 0..session.params.cuckoo.sigma {
        let point = cuckoo.stash().get(t).map(|&u| {
            // lint: allow(panic) — stash elements come from the caller's
            // selections, which the table build already range-checked.
            let pos = session
                .domain_index_of(u)
                .expect("stash element outside domain");
            (pos, beta_of(u))
        });
        points.push(BinPoint {
            depth: stash_depth,
            point,
        });
    }
    Ok(BinPoints { cuckoo, points })
}

/// Server `b` answers a PSR query: one share per bin (then per stash key).
/// `weights[i]` is the group encoding of global weight `i`.
///
/// Thin wrapper over the serial [`RetrievalEngine`], which also fixes the
/// old stash loop's allocating `full_eval` (the engine reuses one
/// workspace + leaf buffer across every slot, bins and stash alike).
#[deprecated(note = "use protocol::retrieve::RetrievalEngine::answer_keys")]
pub fn server_answer<G: Group>(session: &Session, weights: &[G], keys: &[DpfKey<G>]) -> Vec<G> {
    RetrievalEngine::serial().answer_keys(session, weights, keys)
}

/// Client combines the two servers' answers into its submodel, in the
/// order of `selections`.
pub fn client_reconstruct<G: Group>(
    ctx: &PsrClientCtx,
    num_bins: usize,
    selections: &[u64],
    ans0: &[G],
    ans1: &[G],
) -> Vec<G> {
    assert_eq!(ans0.len(), ans1.len());
    selections
        .iter()
        .map(|&s| {
            // lint: allow(panic) — `ctx.cuckoo` was built from these same
            // selections in `client_query`, so lookup cannot miss.
            let slot = match ctx.cuckoo.locate(s).expect("selection not in table") {
                Ok(bin) => bin,
                Err(stash_slot) => num_bins + stash_slot,
            };
            ans0[slot].add(&ans1[slot])
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::CuckooParams;
    use crate::protocol::session::SessionParams;

    fn session(m: u64, k: usize, sigma: usize) -> Session {
        Session::new_full(SessionParams {
            m,
            k,
            cuckoo: CuckooParams::default().with_sigma(sigma),
        })
    }

    fn weights_u64(m: u64, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..m).map(|_| rng.next_u64()).collect()
    }

    /// Server answer through the engine API (what `server_answer` wraps).
    fn answer<G: Group>(s: &Session, w: &[G], keys: &[DpfKey<G>]) -> Vec<G> {
        RetrievalEngine::serial().answer_keys(s, w, keys)
    }

    /// The retained equivalence check against the deprecated
    /// `server_answer` wrapper — every other test in this module goes
    /// through the [`RetrievalEngine`] API directly.
    #[test]
    #[allow(deprecated)]
    fn deprecated_server_answer_matches_the_engine() {
        let s = session(1 << 10, 32, 2);
        let w = weights_u64(1 << 10, 89);
        let mut rng = Rng::new(88);
        let sel = rng.sample_distinct(32, 1 << 10);
        let (_ctx, batch) = client_query::<u64>(&s, &sel, &mut rng).unwrap();
        for party in 0..2u8 {
            let keys = batch.server_keys(party);
            assert_eq!(server_answer(&s, &w, &keys), answer(&s, &w, &keys), "party {party}");
        }
    }

    #[test]
    fn end_to_end_retrieval() {
        let s = session(1 << 12, 64, 0);
        let w = weights_u64(1 << 12, 90);
        let mut rng = Rng::new(91);
        let sel = rng.sample_distinct(64, 1 << 12);
        let (ctx, batch) = client_query::<u64>(&s, &sel, &mut rng).unwrap();
        let a0 = answer(&s, &w, &batch.server_keys(0));
        let a1 = answer(&s, &w, &batch.server_keys(1));
        let got = client_reconstruct(&ctx, s.simple.num_bins(), &sel, &a0, &a1);
        for (i, &sl) in sel.iter().enumerate() {
            assert_eq!(got[i], w[sl as usize], "selection {sl}");
        }
    }

    #[test]
    fn end_to_end_with_stash() {
        // Force stash pressure with a tight table.
        let params = CuckooParams {
            epsilon: 1.05,
            eta: 2,
            sigma: 24,
            hash_seed: 3,
            max_kicks: 30,
        };
        let s = Session::new_full(SessionParams {
            m: 1 << 10,
            k: 100,
            cuckoo: params,
        });
        let w = weights_u64(1 << 10, 92);
        let mut rng = Rng::new(93);
        let sel = rng.sample_distinct(100, 1 << 10);
        let (ctx, batch) = client_query::<u64>(&s, &sel, &mut rng).unwrap();
        assert!(!ctx.cuckoo.stash().is_empty(), "test needs stash pressure");
        let a0 = answer(&s, &w, &batch.server_keys(0));
        let a1 = answer(&s, &w, &batch.server_keys(1));
        let got = client_reconstruct(&ctx, s.simple.num_bins(), &sel, &a0, &a1);
        for (i, &sl) in sel.iter().enumerate() {
            assert_eq!(got[i], w[sl as usize]);
        }
    }

    #[test]
    fn answers_are_proper_shares() {
        // A single server's answer must not equal the plaintext weights.
        let s = session(1 << 10, 32, 0);
        let w = weights_u64(1 << 10, 94);
        let mut rng = Rng::new(95);
        let sel = rng.sample_distinct(32, 1 << 10);
        let (ctx, batch) = client_query::<u64>(&s, &sel, &mut rng).unwrap();
        let a0 = answer(&s, &w, &batch.server_keys(0));
        let hits = sel
            .iter()
            .filter(|&&sl| {
                let j = match ctx.cuckoo.locate(sl).unwrap() {
                    Ok(b) => b,
                    Err(t) => s.simple.num_bins() + t,
                };
                a0[j] == w[sl as usize]
            })
            .count();
        assert!(hits <= 1, "share leaks plaintext ({hits} hits)");
    }

    #[test]
    fn duplicate_selections_retrieve_without_fighting_for_bins() {
        // Heavily repeated indices must neither fail the cuckoo build nor
        // change the per-occurrence reconstruction.
        let s = session(512, 16, 0);
        let w = weights_u64(512, 97);
        let mut rng = Rng::new(98);
        let mut sel = rng.sample_distinct(8, 512);
        let dups: Vec<u64> = sel.iter().copied().collect();
        sel.extend(dups); // every index twice
        let (ctx, batch) = client_query::<u64>(&s, &sel, &mut rng).unwrap();
        let a0 = answer(&s, &w, &batch.server_keys(0));
        let a1 = answer(&s, &w, &batch.server_keys(1));
        let got = client_reconstruct(&ctx, s.simple.num_bins(), &sel, &a0, &a1);
        for (i, &sl) in sel.iter().enumerate() {
            assert_eq!(got[i], w[sl as usize], "occurrence {i} of {sl}");
        }
    }

    #[test]
    fn u128_payloads() {
        let s = session(512, 16, 0);
        let mut rng = Rng::new(96);
        let w: Vec<u128> = (0..512).map(|_| rng.next_u64() as u128).collect();
        let sel = rng.sample_distinct(16, 512);
        let (ctx, batch) = client_query::<u128>(&s, &sel, &mut rng).unwrap();
        let a0 = answer(&s, &w, &batch.server_keys(0));
        let a1 = answer(&s, &w, &batch.server_keys(1));
        let got = client_reconstruct(&ctx, s.simple.num_bins(), &sel, &a0, &a1);
        for (i, &sl) in sel.iter().enumerate() {
            assert_eq!(got[i], w[sl as usize]);
        }
    }
}
