//! Private Submodel Retrieval (Fig. 4, top half).
//!
//! Client: insert the k selections into a cuckoo table; per bin `j`,
//! generate DPF keys for `f_{pos_j, 1}` (dummy `f_{0,0}` for empty bins);
//! per stash slot, keys over the whole alignment domain. Upload one master
//! seed per server plus the shared public parts.
//!
//! Server `b`: full-domain-evaluate each bin key over its simple bin and
//! answer with the inner products `[w'_j]_b = Σ_d w_{T_simple[j][d]} ·
//! [f(d)]_b`. The two answers sum to exactly the requested weights.

use super::session::Session;
use crate::crypto::rng::Rng;
use crate::dpf::{self, gen_batch_with_master, BinPoint, DpfKey, MasterKeyBatch};
use crate::group::Group;
use crate::hashing::{CuckooError, CuckooTable};

/// Client-side retrieval context kept between query and reconstruct.
pub struct PsrClientCtx {
    pub cuckoo: CuckooTable,
}

/// Build the client's query: the cuckoo table and the batched DPF keys
/// (B bin keys + σ stash keys, in that order).
pub fn client_query<G: Group>(
    session: &Session,
    selections: &[u64],
    rng: &mut Rng,
) -> Result<(PsrClientCtx, MasterKeyBatch<G>), CuckooError> {
    let bins = build_bin_points(session, selections, rng, |_u| G::one())?;
    let batch = gen_batch_with_master(&bins.points, rng.gen_seed(), rng.gen_seed());
    Ok((PsrClientCtx { cuckoo: bins.cuckoo }, batch))
}

pub(crate) struct BinPoints<G: Group> {
    pub cuckoo: CuckooTable,
    pub points: Vec<BinPoint<G>>,
}

/// Shared between PSR and SSA: place each selection in its bin and emit
/// one `BinPoint` per bin (+ stash), with payload chosen by `beta_of`.
pub(crate) fn build_bin_points<G: Group>(
    session: &Session,
    selections: &[u64],
    rng: &mut Rng,
    beta_of: impl Fn(u64) -> G,
) -> Result<BinPoints<G>, CuckooError> {
    let cuckoo = CuckooTable::build_with_bins(
        selections,
        session.simple.num_bins(),
        &session.params.cuckoo,
        rng,
    )?;
    let simple = &session.simple;
    assert_eq!(cuckoo.num_bins(), simple.num_bins(), "table misalignment");

    let stash_depth = dpf::depth_for(session.domain_size());
    let mut points = Vec::with_capacity(cuckoo.num_bins() + session.params.cuckoo.sigma);

    for (j, slot) in cuckoo.bins().iter().enumerate() {
        let theta_j = simple.bin(j).len().max(2);
        let depth = dpf::depth_for(theta_j);
        let point = slot.map(|u| {
            let pos = simple
                .position(j, u)
                .expect("alignment invariant: cuckoo element present in simple bin");
            (pos as u64, beta_of(u))
        });
        points.push(BinPoint { depth, point });
    }
    // Stash slots: keys over the whole domain (occupied or dummy), always
    // σ of them so the upload shape is data-independent (Fig. 3).
    for t in 0..session.params.cuckoo.sigma {
        let point = cuckoo.stash().get(t).map(|&u| {
            let pos = session
                .domain_index_of(u)
                .expect("stash element outside domain");
            (pos, beta_of(u))
        });
        points.push(BinPoint {
            depth: stash_depth,
            point,
        });
    }
    Ok(BinPoints { cuckoo, points })
}

/// Server `b` answers a PSR query: one share per bin (then per stash key).
/// `weights[i]` is the group encoding of global weight `i`.
pub fn server_answer<G: Group>(session: &Session, weights: &[G], keys: &[DpfKey<G>]) -> Vec<G> {
    assert_eq!(weights.len(), session.params.m as usize, "weight vector size");
    let num_bins = session.simple.num_bins();
    let sigma = session.params.cuckoo.sigma;
    assert_eq!(keys.len(), num_bins + sigma, "key count");

    let mut answers = Vec::with_capacity(keys.len());
    // Reused workspace + output buffer across bins, then one inner
    // product per bin (the L1 `binned_ip` kernel computes the same slab
    // product on the PJRT path; see `runtime::Executor::binned_ip`).
    let mut ws = dpf::EvalWorkspace::default();
    let mut ev: Vec<G> = Vec::new();
    for (j, key) in keys.iter().take(num_bins).enumerate() {
        let bin = session.simple.bin(j);
        dpf::full_eval_with(key, bin.len(), &mut ws, &mut ev);
        let mut acc = G::zero();
        for (d, &idx) in bin.iter().enumerate() {
            acc.add_assign(&weights[idx as usize].ring_mul(&ev[d]));
        }
        answers.push(acc);
    }
    for key in keys.iter().skip(num_bins) {
        let n = session.domain_size();
        let evals = dpf::full_eval(key, n);
        let mut acc = G::zero();
        for (pos, ev) in evals.iter().enumerate() {
            let idx = session.domain_value(pos);
            acc.add_assign(&weights[idx as usize].ring_mul(ev));
        }
        answers.push(acc);
    }
    answers
}

/// Client combines the two servers' answers into its submodel, in the
/// order of `selections`.
pub fn client_reconstruct<G: Group>(
    ctx: &PsrClientCtx,
    num_bins: usize,
    selections: &[u64],
    ans0: &[G],
    ans1: &[G],
) -> Vec<G> {
    assert_eq!(ans0.len(), ans1.len());
    selections
        .iter()
        .map(|&s| {
            let slot = match ctx.cuckoo.locate(s).expect("selection not in table") {
                Ok(bin) => bin,
                Err(stash_slot) => num_bins + stash_slot,
            };
            ans0[slot].add(&ans1[slot])
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::CuckooParams;
    use crate::protocol::session::SessionParams;

    fn session(m: u64, k: usize, sigma: usize) -> Session {
        Session::new_full(SessionParams {
            m,
            k,
            cuckoo: CuckooParams::default().with_sigma(sigma),
        })
    }

    fn weights_u64(m: u64, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..m).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn end_to_end_retrieval() {
        let s = session(1 << 12, 64, 0);
        let w = weights_u64(1 << 12, 90);
        let mut rng = Rng::new(91);
        let sel = rng.sample_distinct(64, 1 << 12);
        let (ctx, batch) = client_query::<u64>(&s, &sel, &mut rng).unwrap();
        let a0 = server_answer(&s, &w, &batch.server_keys(0));
        let a1 = server_answer(&s, &w, &batch.server_keys(1));
        let got = client_reconstruct(&ctx, s.simple.num_bins(), &sel, &a0, &a1);
        for (i, &sl) in sel.iter().enumerate() {
            assert_eq!(got[i], w[sl as usize], "selection {sl}");
        }
    }

    #[test]
    fn end_to_end_with_stash() {
        // Force stash pressure with a tight table.
        let params = CuckooParams {
            epsilon: 1.05,
            eta: 2,
            sigma: 24,
            hash_seed: 3,
            max_kicks: 30,
        };
        let s = Session::new_full(SessionParams {
            m: 1 << 10,
            k: 100,
            cuckoo: params,
        });
        let w = weights_u64(1 << 10, 92);
        let mut rng = Rng::new(93);
        let sel = rng.sample_distinct(100, 1 << 10);
        let (ctx, batch) = client_query::<u64>(&s, &sel, &mut rng).unwrap();
        assert!(!ctx.cuckoo.stash().is_empty(), "test needs stash pressure");
        let a0 = server_answer(&s, &w, &batch.server_keys(0));
        let a1 = server_answer(&s, &w, &batch.server_keys(1));
        let got = client_reconstruct(&ctx, s.simple.num_bins(), &sel, &a0, &a1);
        for (i, &sl) in sel.iter().enumerate() {
            assert_eq!(got[i], w[sl as usize]);
        }
    }

    #[test]
    fn answers_are_proper_shares() {
        // A single server's answer must not equal the plaintext weights.
        let s = session(1 << 10, 32, 0);
        let w = weights_u64(1 << 10, 94);
        let mut rng = Rng::new(95);
        let sel = rng.sample_distinct(32, 1 << 10);
        let (ctx, batch) = client_query::<u64>(&s, &sel, &mut rng).unwrap();
        let a0 = server_answer(&s, &w, &batch.server_keys(0));
        let hits = sel
            .iter()
            .filter(|&&sl| {
                let j = match ctx.cuckoo.locate(sl).unwrap() {
                    Ok(b) => b,
                    Err(t) => s.simple.num_bins() + t,
                };
                a0[j] == w[sl as usize]
            })
            .count();
        assert!(hits <= 1, "share leaks plaintext ({hits} hits)");
    }

    #[test]
    fn u128_payloads() {
        let s = session(512, 16, 0);
        let mut rng = Rng::new(96);
        let w: Vec<u128> = (0..512).map(|_| rng.next_u64() as u128).collect();
        let sel = rng.sample_distinct(16, 512);
        let (ctx, batch) = client_query::<u128>(&s, &sel, &mut rng).unwrap();
        let a0 = server_answer(&s, &w, &batch.server_keys(0));
        let a1 = server_answer(&s, &w, &batch.server_keys(1));
        let got = client_reconstruct(&ctx, s.simple.num_bins(), &sel, &a0, &a1);
        for (i, &sl) in sel.iter().enumerate() {
            assert_eq!(got[i], w[sl as usize]);
        }
    }
}
