//! The unified server-side aggregation engine (SSA write path) and the
//! shard planner it shares with the retrieval engine (PSR read path).
//!
//! The SSA server path used to exist in three divergent copies
//! (`ssa::server_aggregate_into`, `ssa::server_aggregate_publics`,
//! `ssa::server_aggregate_parallel`), only one of which had the
//! workspace-reuse and zero-key-materialisation optimisations, and the
//! parallel one re-allocated per bin and evaluated stash keys serially
//! after the join. Every server now goes through one
//! [`AggregationEngine`]:
//!
//! * it consumes any [`EvalSource`] — materialised [`DpfKey`]s
//!   ([`KeySource`]), borrowed [`PublicPart`]s plus a master seed
//!   ([`PublicsSource`], the zero-copy path), or the U-DPF keys of
//!   [`super::udpf_ssa`];
//! * work is sharded across a configurable number of threads over the
//!   flattened `clients × (B bins + σ stash slots)` unit space, so stash
//!   keys are load-balanced together with bin keys instead of being
//!   evaluated serially after the join;
//! * each worker reuses one [`EvalWorkspace`] and one output buffer
//!   across all of its units (zero heap churn, §Perf iteration 3) and
//!   accumulates into a private partial share vector; the partials are
//!   merged once at the end, so scatter targets never race and no locking
//!   is needed.
//!
//! The worker-count policy and the unit-space split live in [`Sharding`],
//! shared with the read-path [`super::retrieve::RetrievalEngine`] so both
//! halves of the paper's Fig. 4 scale the same way. This module and
//! `retrieve.rs` are the places future sharding/batching/async work plugs
//! into.

use super::session::Session;
use crate::crypto::prg::{prf_seed, Seed};
use crate::dpf::{self, DpfKey, EvalWorkspace, KeyView, MasterKeyBatch, PublicPart};
use crate::group::Group;
use crate::metrics::trace::{self, Phase, TraceSink};

/// The shard planner shared by the write-path [`AggregationEngine`] and
/// the read-path [`super::retrieve::RetrievalEngine`]: a worker-count
/// policy plus the contiguous split of a flattened unit space (unit =
/// `client · (B + σ) + slot`).
#[derive(Clone, Copy, Debug)]
pub struct Sharding {
    threads: usize,
}

impl Sharding {
    /// Plan with an explicit worker count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Sharding {
            threads: threads.max(1),
        }
    }

    /// Single-threaded plan (deterministic microbenches, tests).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// One worker per available core.
    pub fn auto() -> Self {
        Self::new(std::thread::available_parallelism().map_or(1, |n| n.get()))
    }

    /// Default for one of two co-located servers: half the cores each, so
    /// the two concurrently serving server threads of an in-process round
    /// don't oversubscribe the machine and measured server times stay
    /// honest.
    pub fn per_coloc_server() -> Self {
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        Self::new((cores / 2).max(1))
    }

    /// The `FslConfig::threads` convention: an explicit worker count, or
    /// `0` for the co-located-two-server default
    /// ([`Self::per_coloc_server`]). Kept here so callers can't
    /// accidentally turn the default into "serial".
    pub fn from_config(threads: usize) -> Self {
        if threads == 0 {
            Self::per_coloc_server()
        } else {
            Self::new(threads)
        }
    }

    /// Worker count from the `FSL_THREADS` environment variable (used by
    /// the benches): unset defaults to serial so timings are
    /// reproducible, `0` means one worker per core, and a non-numeric
    /// value warns instead of silently running serial.
    pub fn from_env() -> Self {
        match std::env::var("FSL_THREADS") {
            Ok(v) => match v.parse::<usize>() {
                Ok(0) => Self::auto(),
                Ok(t) => Self::new(t),
                Err(_) => {
                    eprintln!("FSL_THREADS={v:?} is not a number; running serial");
                    Self::serial()
                }
            },
            Err(_) => Self::serial(),
        }
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `work` over the flattened unit space `0..units`, split into at
    /// most `min(threads, units)` contiguous non-empty ranges — one
    /// scoped thread each (no thread is spawned for a single shard).
    /// `work` receives its shard index (`0..busy`) and unit range; the
    /// index tags per-worker trace spans. Per-shard results come back in
    /// unit order, so contiguous per-unit outputs can simply be
    /// concatenated.
    pub fn run<R: Send>(
        &self,
        units: usize,
        work: impl Fn(usize, std::ops::Range<usize>) -> R + Sync,
    ) -> Vec<R> {
        if units == 0 {
            return Vec::new();
        }
        let shards = self.threads.min(units);
        if shards <= 1 {
            return vec![work(0, 0..units)];
        }
        let chunk = units.div_ceil(shards);
        // div_ceil chunking can leave trailing shards empty (units = 9,
        // shards = 8 → chunk = 2 → only 5 busy shards); don't spawn
        // threads — or, on the write path, allocate partials — for them.
        let busy = units.div_ceil(chunk);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..busy)
                .map(|t| {
                    let work = &work;
                    let lo = (t * chunk).min(units);
                    let hi = ((t + 1) * chunk).min(units);
                    scope.spawn(move || work(t, lo..hi))
                })
                .collect();
            handles
                .into_iter()
                // lint: allow(panic) — a panicked shard worker must propagate
                // to the spawning thread, not be silently dropped from the sum.
                .map(|h| h.join().expect("shard worker panicked"))
                .collect()
        })
    }
}

/// One input form the engine can aggregate: anything that can evaluate
/// "client `c`'s key for slot `j`" over a prefix of its domain.
///
/// Slots `0..B` are cuckoo-bin keys (evaluated over the bin's Θ_j
/// positions); slots `B..B+σ` are stash keys (evaluated over the whole
/// alignment domain).
pub trait EvalSource<G: Group>: Sync {
    /// Number of clients in the batch.
    fn num_clients(&self) -> usize;

    /// Evaluate client `client`'s key for `slot` over the first
    /// `num_points` leaves, writing the shares into `out` (cleared
    /// first). `ws` is the worker's reusable frontier storage.
    fn eval_slot(
        &self,
        client: usize,
        slot: usize,
        num_points: usize,
        ws: &mut EvalWorkspace,
        out: &mut Vec<G>,
    );

    /// Panic with a clear message if any client's slot count differs from
    /// the session's `B + σ`.
    fn assert_shape(&self, slots: usize);
}

/// Materialised per-client key sets: `B` bin keys then `σ` stash keys,
/// exactly as [`crate::dpf::MasterKeyBatch::server_keys`] returns them.
pub struct KeySource<'a, G: Group>(pub &'a [Vec<DpfKey<G>>]);

impl<G: Group> EvalSource<G> for KeySource<'_, G> {
    fn num_clients(&self) -> usize {
        self.0.len()
    }

    fn eval_slot(
        &self,
        client: usize,
        slot: usize,
        num_points: usize,
        ws: &mut EvalWorkspace,
        out: &mut Vec<G>,
    ) {
        dpf::full_eval_with(&self.0[client][slot], num_points, ws, out);
    }

    fn assert_shape(&self, slots: usize) {
        for keys in self.0 {
            assert_eq!(keys.len(), slots, "key count");
        }
    }
}

/// A single client's materialised keys (the legacy
/// `server_aggregate_into` / `psr::server_answer` shape).
pub(crate) struct SingleClientKeys<'a, G: Group>(pub(crate) &'a [DpfKey<G>]);

impl<G: Group> EvalSource<G> for SingleClientKeys<'_, G> {
    fn num_clients(&self) -> usize {
        1
    }

    fn eval_slot(
        &self,
        _client: usize,
        slot: usize,
        num_points: usize,
        ws: &mut EvalWorkspace,
        out: &mut Vec<G>,
    ) {
        dpf::full_eval_with(&self.0[slot], num_points, ws, out);
    }

    fn assert_shape(&self, slots: usize) {
        assert_eq!(self.0.len(), slots, "key count");
    }
}

/// One client's zero-copy upload: the decoded public parts plus this
/// server's λ-bit master seed. Slot `j`'s root seed is `PRF(msk, j)`; no
/// correction words are ever cloned (§Perf iteration 5).
#[derive(Clone, Copy)]
pub struct PublicsUpload<'a, G: Group> {
    /// The `B + σ` shared public parts of the client's key batch.
    pub publics: &'a [PublicPart<G>],
    /// This server's master seed for the client.
    pub msk: &'a Seed,
}

/// The zero-copy input form: many clients' [`PublicsUpload`]s, evaluated
/// as party `party`.
pub struct PublicsSource<'a, G: Group> {
    /// One upload per client.
    pub uploads: &'a [PublicsUpload<'a, G>],
    /// The evaluating server b ∈ {0, 1}.
    pub party: u8,
}

impl<G: Group> EvalSource<G> for PublicsSource<'_, G> {
    fn num_clients(&self) -> usize {
        self.uploads.len()
    }

    fn eval_slot(
        &self,
        client: usize,
        slot: usize,
        num_points: usize,
        ws: &mut EvalWorkspace,
        out: &mut Vec<G>,
    ) {
        let up = &self.uploads[client];
        let p = &up.publics[slot];
        let root = prf_seed(up.msk, slot as u64);
        dpf::full_eval_parts(
            KeyView {
                party: self.party,
                depth: p.depth,
                root_seed: &root,
                cws: &p.cws,
                cw_out: &p.cw_out,
            },
            num_points,
            ws,
            out,
        );
    }

    fn assert_shape(&self, slots: usize) {
        for up in self.uploads {
            assert_eq!(up.publics.len(), slots, "public part count");
        }
    }
}

/// Borrow many decoded [`MasterKeyBatch`]es as party `party`'s zero-copy
/// engine input — the coordinator serving paths decode wire uploads into
/// batches and hand the views straight to
/// [`AggregationEngine::aggregate_publics`] /
/// [`super::retrieve::RetrievalEngine::answer_publics`].
pub fn uploads_of<G: Group>(batches: &[MasterKeyBatch<G>], party: u8) -> Vec<PublicsUpload<'_, G>> {
    batches
        .iter()
        .map(|b| PublicsUpload {
            publics: &b.publics,
            msk: b.msk[party as usize].expose(),
        })
        .collect()
}

/// The unified, sharded server-aggregation engine (the paper enables
/// multi-threading for all experiments, §7.2).
#[derive(Clone, Debug)]
pub struct AggregationEngine {
    sharding: Sharding,
    trace: Option<TraceSink>,
}

impl AggregationEngine {
    /// Engine with an explicit worker count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self::with_sharding(Sharding::new(threads))
    }

    /// Engine over an existing shard plan.
    pub fn with_sharding(sharding: Sharding) -> Self {
        AggregationEngine {
            sharding,
            trace: None,
        }
    }

    /// Attach a trace sink: every aggregation records one `eval` span per
    /// shard worker and one `merge` span for the partial-sum fold.
    pub fn with_trace(mut self, sink: TraceSink) -> Self {
        self.trace = Some(sink);
        self
    }

    /// Single-threaded engine (deterministic microbenches, tests).
    pub fn serial() -> Self {
        Self::with_sharding(Sharding::serial())
    }

    /// One worker per available core.
    pub fn auto() -> Self {
        Self::with_sharding(Sharding::auto())
    }

    /// Default for one of two co-located servers — see
    /// [`Sharding::per_coloc_server`].
    pub fn per_coloc_server() -> Self {
        Self::with_sharding(Sharding::per_coloc_server())
    }

    /// The `FslConfig::threads` convention — see
    /// [`Sharding::from_config`].
    pub fn from_config(threads: usize) -> Self {
        Self::with_sharding(Sharding::from_config(threads))
    }

    /// Worker count from `FSL_THREADS` — see [`Sharding::from_env`].
    pub fn from_env() -> Self {
        Self::with_sharding(Sharding::from_env())
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.sharding.threads()
    }

    /// The underlying shard plan (shared with the retrieval engine).
    pub fn sharding(&self) -> Sharding {
        self.sharding
    }

    /// Aggregate every client of `source` into a fresh share vector
    /// (length = the session's domain size).
    pub fn aggregate<G: Group, S: EvalSource<G>>(&self, session: &Session, source: &S) -> Vec<G> {
        let mut acc = vec![G::zero(); session.domain_size()];
        self.aggregate_into(session, source, &mut acc);
        acc
    }

    /// Aggregate every client of `source`, accumulating into `acc`.
    ///
    /// Work units are the flattened `clients × (B + σ)` pairs; each of the
    /// `min(threads, units)` workers takes a contiguous unit range,
    /// accumulates into a private partial vector, and the partials are
    /// merged at the end. With one worker the caller's `acc` is used
    /// directly (no partials, no merge).
    pub fn aggregate_into<G: Group, S: EvalSource<G>>(
        &self,
        session: &Session,
        source: &S,
        acc: &mut [G],
    ) {
        let slots = session.simple.num_bins() + session.params.cuckoo.sigma;
        assert_eq!(acc.len(), session.domain_size(), "accumulator size");
        source.assert_shape(slots);
        let units = source.num_clients() * slots;
        if units == 0 {
            return;
        }
        if self.sharding.threads().min(units) <= 1 {
            let s = self.trace.as_ref().map(|t| t.begin());
            Worker::new(session, source).run_range(0, units, acc);
            if let (Some(t), Some(s)) = (&self.trace, s) {
                t.end(s, Phase::Eval, trace::worker(0));
                // Zero-duration merge keeps the serial span stream the
                // same shape as the sharded one.
                t.end(t.begin(), Phase::Merge, None);
            }
            return;
        }
        let partials = self.sharding.run(units, |w, range| {
            let s = self.trace.as_ref().map(|t| t.begin());
            let mut part = vec![G::zero(); session.domain_size()];
            Worker::new(session, source).run_range(range.start, range.end, &mut part);
            if let (Some(t), Some(s)) = (&self.trace, s) {
                t.end(s, Phase::Eval, trace::worker(w));
            }
            part
        });
        let s = self.trace.as_ref().map(|t| t.begin());
        for part in &partials {
            for (a, v) in acc.iter_mut().zip(part) {
                a.add_assign(v);
            }
        }
        if let (Some(t), Some(s)) = (&self.trace, s) {
            t.end(s, Phase::Merge, None);
        }
    }

    /// Aggregate many clients' materialised key sets.
    pub fn aggregate_keys<G: Group>(
        &self,
        session: &Session,
        clients: &[Vec<DpfKey<G>>],
    ) -> Vec<G> {
        self.aggregate(session, &KeySource(clients))
    }

    /// Aggregate one client's materialised keys into `acc`.
    pub fn aggregate_client_keys_into<G: Group>(
        &self,
        session: &Session,
        keys: &[DpfKey<G>],
        acc: &mut [G],
    ) {
        self.aggregate_into(session, &SingleClientKeys(keys), acc);
    }

    /// Aggregate many clients straight from their public parts + master
    /// seeds (the zero-copy path), evaluating as party `party`.
    pub fn aggregate_publics<G: Group>(
        &self,
        session: &Session,
        party: u8,
        uploads: &[PublicsUpload<'_, G>],
    ) -> Vec<G> {
        self.aggregate(session, &PublicsSource { uploads, party })
    }

    /// [`Self::aggregate_publics`], accumulating into `acc`.
    pub fn aggregate_publics_into<G: Group>(
        &self,
        session: &Session,
        party: u8,
        uploads: &[PublicsUpload<'_, G>],
        acc: &mut [G],
    ) {
        self.aggregate_into(session, &PublicsSource { uploads, party }, acc);
    }
}

/// Per-worker state: one frontier workspace and one leaf-share buffer,
/// reused across every unit the worker processes.
struct Worker<'a, G: Group, S: EvalSource<G>> {
    session: &'a Session,
    source: &'a S,
    num_bins: usize,
    slots: usize,
    ws: EvalWorkspace,
    ev: Vec<G>,
}

impl<'a, G: Group, S: EvalSource<G>> Worker<'a, G, S> {
    fn new(session: &'a Session, source: &'a S) -> Self {
        let num_bins = session.simple.num_bins();
        Worker {
            session,
            source,
            num_bins,
            slots: num_bins + session.params.cuckoo.sigma,
            ws: EvalWorkspace::default(),
            ev: Vec::new(),
        }
    }

    /// Process flattened units `lo..hi` (unit = client · (B+σ) + slot),
    /// scattering every leaf share into `acc`.
    fn run_range(&mut self, lo: usize, hi: usize, acc: &mut [G]) {
        for unit in lo..hi {
            let (client, slot) = (unit / self.slots, unit % self.slots);
            if slot < self.num_bins {
                // Bin key: evaluate over the bin's Θ_j positions and
                // scatter through the aligned simple table.
                let bin = self.session.simple.bin(slot);
                self.source.eval_slot(client, slot, bin.len(), &mut self.ws, &mut self.ev);
                for (d, &idx) in bin.iter().enumerate() {
                    // lint: allow(panic) — simple bins are built from the
                    // session's own domain, so membership is a construction
                    // invariant, not an input-dependent condition.
                    let pos = self
                        .session
                        .domain_index_of(idx)
                        .expect("simple bin element outside domain") as usize;
                    acc[pos].add_assign(&self.ev[d]);
                }
            } else {
                // Stash key: whole-domain evaluation, element-wise add.
                self.source.eval_slot(client, slot, acc.len(), &mut self.ws, &mut self.ev);
                for (pos, v) in self.ev.iter().enumerate() {
                    acc[pos].add_assign(v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;
    use crate::dpf::MasterKeyBatch;
    use crate::hashing::CuckooParams;
    use crate::protocol::session::SessionParams;
    use crate::protocol::ssa;

    fn session(m: u64, k: usize, sigma: usize) -> Session {
        Session::new_full(SessionParams {
            m,
            k,
            cuckoo: CuckooParams {
                sigma,
                ..CuckooParams::default()
            },
        })
    }

    fn sample_clients(s: &Session, n: usize, rng: &mut Rng) -> Vec<MasterKeyBatch<u64>> {
        (0..n)
            .map(|c| {
                let sel = rng.sample_distinct(s.params.k, s.params.m);
                let dl: Vec<u64> = sel.iter().map(|&x| x * 3 + c as u64 + 1).collect();
                ssa::client_update(s, &sel, &dl, rng).unwrap()
            })
            .collect()
    }

    /// The retained write-path equivalence check against the deprecated
    /// `ssa::server_aggregate_parallel` wrapper — every other test in this
    /// module exercises the engine API directly.
    #[test]
    #[allow(deprecated)]
    fn engine_matches_legacy_over_all_three_input_forms() {
        let s = session(1 << 11, 64, 0);
        let mut rng = Rng::new(500);
        let batches = sample_clients(&s, 5, &mut rng);
        let keys0: Vec<Vec<crate::dpf::DpfKey<u64>>> =
            batches.iter().map(|b| b.server_keys(0)).collect();

        let legacy_serial = ssa::server_aggregate(&s, &keys0);

        // Form 1: materialised keys.
        assert_eq!(AggregationEngine::serial().aggregate_keys(&s, &keys0), legacy_serial);
        // Form 2: zero-copy publics + master seed.
        let uploads: Vec<PublicsUpload<'_, u64>> = batches
            .iter()
            .map(|b| PublicsUpload {
                publics: &b.publics,
                msk: b.msk[0].expose(),
            })
            .collect();
        assert_eq!(AggregationEngine::serial().aggregate_publics(&s, 0, &uploads), legacy_serial);
        // Form 3: the legacy parallel entry point (now a wrapper) must be
        // bit-identical to the engine at every width.
        for t in [1usize, 2, 3, 8, 64] {
            assert_eq!(
                ssa::server_aggregate_parallel(&s, &keys0, t),
                legacy_serial,
                "wrapper, {t} threads"
            );
            assert_eq!(
                AggregationEngine::new(t).aggregate_keys(&s, &keys0),
                legacy_serial,
                "engine, {t} threads"
            );
        }
    }

    #[test]
    fn publics_path_matches_keys_path_for_both_parties() {
        let s = session(1 << 10, 32, 2);
        let mut rng = Rng::new(501);
        let batches = sample_clients(&s, 4, &mut rng);
        for party in 0..2u8 {
            let keys: Vec<_> = batches.iter().map(|b| b.server_keys(party)).collect();
            let uploads: Vec<PublicsUpload<'_, u64>> = batches
                .iter()
                .map(|b| PublicsUpload {
                    publics: &b.publics,
                    msk: b.msk[party as usize].expose(),
                })
                .collect();
            let engine = AggregationEngine::new(3);
            assert_eq!(
                engine.aggregate_publics(&s, party, &uploads),
                engine.aggregate_keys(&s, &keys),
                "party {party}"
            );
        }
    }

    #[test]
    fn more_threads_than_bins_or_units() {
        // One client, tiny k: far fewer units than workers. The engine
        // must clamp and still match the serial result exactly.
        let s = session(256, 4, 1);
        let mut rng = Rng::new(502);
        let batches = sample_clients(&s, 1, &mut rng);
        let keys: Vec<_> = batches.iter().map(|b| b.server_keys(0)).collect();
        let serial = AggregationEngine::serial().aggregate_keys(&s, &keys);
        for t in [7, 64, 1000] {
            assert_eq!(AggregationEngine::new(t).aggregate_keys(&s, &keys), serial, "{t} threads");
        }
    }

    #[test]
    fn reconstruction_is_exact_through_the_engine() {
        let s = session(512, 16, 0);
        let mut rng = Rng::new(503);
        let mut expected = vec![0u64; 512];
        let mut batches = Vec::new();
        for c in 0..3u64 {
            let sel = rng.sample_distinct(16, 512);
            let dl: Vec<u64> = sel.iter().map(|&x| x * 10 + c).collect();
            for (&i, &d) in sel.iter().zip(&dl) {
                expected[i as usize] = expected[i as usize].wrapping_add(d);
            }
            batches.push(ssa::client_update(&s, &sel, &dl, &mut rng).unwrap());
        }
        let engine = AggregationEngine::new(4);
        let keys0: Vec<_> = batches.iter().map(|b| b.server_keys(0)).collect();
        let keys1: Vec<_> = batches.iter().map(|b| b.server_keys(1)).collect();
        let dw = ssa::reconstruct(
            &engine.aggregate_keys(&s, &keys0),
            &engine.aggregate_keys(&s, &keys1),
        );
        assert_eq!(dw, expected);
    }

    #[test]
    fn empty_client_set_is_a_no_op() {
        let s = session(128, 4, 0);
        let none: Vec<Vec<crate::dpf::DpfKey<u64>>> = Vec::new();
        assert_eq!(AggregationEngine::new(8).aggregate_keys(&s, &none), vec![0u64; 128]);
    }
}
