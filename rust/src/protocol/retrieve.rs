//! The unified server-side retrieval engine (PSR read path).
//!
//! PSR answers used to be computed on a single thread by
//! `psr::server_answer`, one client at a time, with the stash loop even
//! falling back to the allocating `dpf::full_eval`. In a deployment the
//! read path is the hot one — every client of "millions of users"
//! retrieves its submodel before it trains — so the server answer loop
//! now mirrors the SSA write path exactly: one [`RetrievalEngine`],
//! sharded by the same [`Sharding`] planner over the same flattened
//! `clients × (B bins + σ stash slots)` unit space, consuming any
//! [`EvalSource`] (materialised [`crate::dpf::DpfKey`]s, zero-copy public
//! parts + master seed, or U-DPF epoch keys via
//! [`super::udpf_ssa::server_answer`]).
//!
//! The accumulator shape differs from aggregation, and that is what makes
//! the read path embarrassingly parallel: a write-path unit *scatters*
//! leaf shares into a shared domain-sized vector (hence per-worker
//! partials and a merge), while a read-path unit reduces to exactly one
//! group element — the inner product `Σ_d w[T_simple[j][d]] · [f_j(d)]_b`
//! for a bin slot, or the whole-domain product for a stash slot. Units
//! are disjoint output cells, so each worker just returns its contiguous
//! answer slice and the shards are concatenated — no partials, no merge,
//! and bit-identical answers at every worker count by construction
//! (inner-product accumulation order within a cell never changes).

use super::aggregate::{
    EvalSource, KeySource, PublicsSource, PublicsUpload, Sharding, SingleClientKeys,
};
use super::session::Session;
use crate::dpf::{DpfKey, EvalWorkspace};
use crate::group::Group;
use crate::metrics::trace::{self, Phase, TraceSink};

/// The unified, sharded PSR answer engine — the read-path twin of
/// [`super::aggregate::AggregationEngine`].
#[derive(Clone, Debug)]
pub struct RetrievalEngine {
    sharding: Sharding,
    trace: Option<TraceSink>,
}

impl RetrievalEngine {
    /// Engine with an explicit worker count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self::with_sharding(Sharding::new(threads))
    }

    /// Engine over an existing shard plan (e.g. the one the co-located
    /// aggregation engine already uses).
    pub fn with_sharding(sharding: Sharding) -> Self {
        RetrievalEngine {
            sharding,
            trace: None,
        }
    }

    /// Attach a trace sink: every answered batch records one `eval` span
    /// per shard worker and one `merge` span for the row re-assembly.
    pub fn with_trace(mut self, sink: TraceSink) -> Self {
        self.trace = Some(sink);
        self
    }

    /// Single-threaded engine (deterministic microbenches, tests).
    pub fn serial() -> Self {
        Self::with_sharding(Sharding::serial())
    }

    /// One worker per available core.
    pub fn auto() -> Self {
        Self::with_sharding(Sharding::auto())
    }

    /// Default for one of two co-located servers — see
    /// [`Sharding::per_coloc_server`].
    pub fn per_coloc_server() -> Self {
        Self::with_sharding(Sharding::per_coloc_server())
    }

    /// The `FslConfig::threads` convention — see
    /// [`Sharding::from_config`].
    pub fn from_config(threads: usize) -> Self {
        Self::with_sharding(Sharding::from_config(threads))
    }

    /// Worker count from `FSL_THREADS` — see [`Sharding::from_env`].
    pub fn from_env() -> Self {
        Self::with_sharding(Sharding::from_env())
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.sharding.threads()
    }

    /// The underlying shard plan (shared with the aggregation engine).
    pub fn sharding(&self) -> Sharding {
        self.sharding
    }

    /// Answer a whole batch of concurrent client queries in one shard
    /// plan: `result[c][j]` is client `c`'s answer share for slot `j`
    /// (`B` bin slots then `σ` stash slots). `weights[i]` is the group
    /// encoding of global weight `i`, so `weights` is indexed by model
    /// index even on a PSU-reduced session (stash slots cover the
    /// alignment domain and map positions back through
    /// [`Session::domain_value`]).
    pub fn answer_batch<G: Group, S: EvalSource<G>>(
        &self,
        session: &Session,
        weights: &[G],
        source: &S,
    ) -> Vec<Vec<G>> {
        assert_eq!(weights.len(), session.params.m as usize, "weight vector size");
        let slots = session.simple.num_bins() + session.params.cuckoo.sigma;
        source.assert_shape(slots);
        let clients = source.num_clients();
        let units = clients * slots;
        if units == 0 {
            return vec![Vec::new(); clients];
        }
        let shard_outputs = self.sharding.run(units, |w, range| {
            let s = self.trace.as_ref().map(|t| t.begin());
            let mut worker = AnswerWorker::new(session, weights, source);
            let mut out = Vec::with_capacity(range.len());
            for unit in range {
                out.push(worker.answer_unit(unit));
            }
            if let (Some(t), Some(s)) = (&self.trace, s) {
                t.end(s, Phase::Eval, trace::worker(w));
            }
            out
        });
        // Shards are contiguous unit ranges in order: concatenate, then
        // cut the flat answer vector back into per-client rows.
        let s = self.trace.as_ref().map(|t| t.begin());
        let mut flat = Vec::with_capacity(units);
        for shard in shard_outputs {
            flat.extend(shard);
        }
        let mut rows = Vec::with_capacity(clients);
        let mut it = flat.into_iter();
        for _ in 0..clients {
            rows.push(it.by_ref().take(slots).collect());
        }
        if let (Some(t), Some(s)) = (&self.trace, s) {
            t.end(s, Phase::Merge, None);
        }
        rows
    }

    /// Answer one client's query from its materialised keys (the legacy
    /// `psr::server_answer` shape).
    pub fn answer_keys<G: Group>(
        &self,
        session: &Session,
        weights: &[G],
        keys: &[DpfKey<G>],
    ) -> Vec<G> {
        let mut rows = self.answer_batch(session, weights, &SingleClientKeys(keys));
        // lint: allow(panic) — answer_batch returns exactly one row per
        // client, and SingleClientKeys is by definition one client.
        rows.pop().expect("single-client answer")
    }

    /// Answer many clients' queries from their materialised key sets.
    pub fn answer_batch_keys<G: Group>(
        &self,
        session: &Session,
        weights: &[G],
        clients: &[Vec<DpfKey<G>>],
    ) -> Vec<Vec<G>> {
        self.answer_batch(session, weights, &KeySource(clients))
    }

    /// Answer many clients straight from their public parts + master
    /// seeds (the zero-copy path), evaluating as party `party` — a server
    /// holding only publics never materialises per-bin `DpfKey`s on the
    /// read path either.
    pub fn answer_publics<G: Group>(
        &self,
        session: &Session,
        weights: &[G],
        party: u8,
        uploads: &[PublicsUpload<'_, G>],
    ) -> Vec<Vec<G>> {
        self.answer_batch(session, weights, &PublicsSource { uploads, party })
    }
}

/// Per-worker state: one frontier workspace and one leaf-share buffer,
/// reused across every unit the worker answers.
struct AnswerWorker<'a, G: Group, S: EvalSource<G>> {
    session: &'a Session,
    weights: &'a [G],
    source: &'a S,
    num_bins: usize,
    slots: usize,
    ws: EvalWorkspace,
    ev: Vec<G>,
}

impl<'a, G: Group, S: EvalSource<G>> AnswerWorker<'a, G, S> {
    fn new(session: &'a Session, weights: &'a [G], source: &'a S) -> Self {
        let num_bins = session.simple.num_bins();
        AnswerWorker {
            session,
            weights,
            source,
            num_bins,
            slots: num_bins + session.params.cuckoo.sigma,
            ws: EvalWorkspace::default(),
            ev: Vec::new(),
        }
    }

    /// Answer one flattened unit (unit = client · (B+σ) + slot): evaluate
    /// the slot's key over its domain prefix and reduce to the single
    /// inner-product share the client will combine.
    fn answer_unit(&mut self, unit: usize) -> G {
        let (client, slot) = (unit / self.slots, unit % self.slots);
        let mut acc = G::zero();
        if slot < self.num_bins {
            // Bin slot: Θ_j leaves, weights gathered through the aligned
            // simple table.
            let bin = self.session.simple.bin(slot);
            self.source.eval_slot(client, slot, bin.len(), &mut self.ws, &mut self.ev);
            for (d, &idx) in bin.iter().enumerate() {
                acc.add_assign(&self.weights[idx as usize].ring_mul(&self.ev[d]));
            }
        } else {
            // Stash slot: whole alignment domain, positions mapped back
            // to model indices.
            let n = self.session.domain_size();
            self.source.eval_slot(client, slot, n, &mut self.ws, &mut self.ev);
            for (pos, ev) in self.ev.iter().enumerate() {
                let idx = self.session.domain_value(pos);
                acc.add_assign(&self.weights[idx as usize].ring_mul(ev));
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;
    use crate::hashing::CuckooParams;
    use crate::protocol::psr;
    use crate::protocol::session::SessionParams;

    fn session(m: u64, k: usize, sigma: usize) -> Session {
        Session::new_full(SessionParams {
            m,
            k,
            cuckoo: CuckooParams {
                sigma,
                ..CuckooParams::default()
            },
        })
    }

    fn weights_u64(m: u64, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..m).map(|_| rng.next_u64()).collect()
    }

    /// The retained read-path equivalence check against the deprecated
    /// `psr::server_answer` wrapper — the other tests in this module
    /// compare engine widths against the serial engine directly.
    #[test]
    #[allow(deprecated)]
    fn engine_matches_legacy_over_all_widths() {
        let s = session(1 << 11, 64, 0);
        let w = weights_u64(1 << 11, 700);
        let mut rng = Rng::new(701);
        let clients: Vec<Vec<u64>> = (0..5).map(|_| rng.sample_distinct(64, 1 << 11)).collect();
        let batches: Vec<_> = clients
            .iter()
            .map(|sel| psr::client_query::<u64>(&s, sel, &mut rng).unwrap().1)
            .collect();
        for party in 0..2u8 {
            let keys: Vec<_> = batches.iter().map(|b| b.server_keys(party)).collect();
            let legacy: Vec<Vec<u64>> =
                keys.iter().map(|k| psr::server_answer(&s, &w, k)).collect();
            for t in [1usize, 2, 3, 8, 64] {
                assert_eq!(
                    RetrievalEngine::new(t).answer_batch_keys(&s, &w, &keys),
                    legacy,
                    "party {party}, {t} threads"
                );
            }
        }
    }

    #[test]
    fn publics_path_matches_keys_path_for_both_parties() {
        let s = session(1 << 10, 32, 2);
        let w = weights_u64(1 << 10, 702);
        let mut rng = Rng::new(703);
        let batches: Vec<_> = (0..4)
            .map(|_| {
                let sel = rng.sample_distinct(32, 1 << 10);
                psr::client_query::<u64>(&s, &sel, &mut rng).unwrap().1
            })
            .collect();
        for party in 0..2u8 {
            let keys: Vec<_> = batches.iter().map(|b| b.server_keys(party)).collect();
            let uploads = crate::protocol::aggregate::uploads_of(&batches, party);
            let engine = RetrievalEngine::new(3);
            assert_eq!(
                engine.answer_publics(&s, &w, party, &uploads),
                engine.answer_batch_keys(&s, &w, &keys),
                "party {party}"
            );
        }
    }

    #[test]
    fn occupied_stash_end_to_end_through_the_engine() {
        // Tight table → stash pressure; the stash units must be answered
        // identically to the legacy whole-domain loop.
        let params = CuckooParams {
            epsilon: 1.05,
            eta: 2,
            sigma: 24,
            hash_seed: 3,
            max_kicks: 30,
        };
        let s = Session::new_full(SessionParams {
            m: 1 << 10,
            k: 100,
            cuckoo: params,
        });
        let w = weights_u64(1 << 10, 704);
        let mut rng = Rng::new(705);
        let sel = rng.sample_distinct(100, 1 << 10);
        let (ctx, batch) = psr::client_query::<u64>(&s, &sel, &mut rng).unwrap();
        assert!(!ctx.cuckoo.stash().is_empty(), "test needs stash pressure");
        let engine = RetrievalEngine::new(4);
        let a0 = engine.answer_keys(&s, &w, &batch.server_keys(0));
        let a1 = engine.answer_keys(&s, &w, &batch.server_keys(1));
        assert_eq!(
            a0,
            RetrievalEngine::serial().answer_keys(&s, &w, &batch.server_keys(0))
        );
        let got = psr::client_reconstruct(&ctx, s.simple.num_bins(), &sel, &a0, &a1);
        for (i, &sl) in sel.iter().enumerate() {
            assert_eq!(got[i], w[sl as usize]);
        }
    }

    #[test]
    fn empty_bins_and_tiny_domains() {
        // m barely above B: simple bins can be empty (num_points = 0) or
        // hold a single element (num_points = 1). Scan hash seeds until a
        // session exhibits both shapes, then check the engine answers
        // them exactly like the legacy loop at every width.
        let s = (0..64u64)
            .map(|seed| {
                Session::new_full(SessionParams {
                    m: 8,
                    k: 8,
                    cuckoo: CuckooParams {
                        sigma: 1,
                        hash_seed: seed,
                        ..CuckooParams::default()
                    },
                })
            })
            .find(|s| {
                let bins = 0..s.simple.num_bins();
                bins.clone().any(|j| s.simple.bin(j).is_empty())
                    && bins.clone().any(|j| s.simple.bin(j).len() == 1)
            })
            .expect("no tiny session with empty + singleton bins in 64 seeds");
        let w = weights_u64(8, 706);
        let mut rng = Rng::new(707);
        let sel = rng.sample_distinct(4, 8);
        let (ctx, batch) = psr::client_query::<u64>(&s, &sel, &mut rng).unwrap();
        let serial0 = RetrievalEngine::serial().answer_keys(&s, &w, &batch.server_keys(0));
        for t in [1usize, 2, 8, 64] {
            let engine = RetrievalEngine::new(t);
            let a0 = engine.answer_keys(&s, &w, &batch.server_keys(0));
            let a1 = engine.answer_keys(&s, &w, &batch.server_keys(1));
            assert_eq!(a0, serial0, "{t} threads");
            let got = psr::client_reconstruct(&ctx, s.simple.num_bins(), &sel, &a0, &a1);
            for (i, &sl) in sel.iter().enumerate() {
                assert_eq!(got[i], w[sl as usize], "{t} threads");
            }
        }
    }

    #[test]
    fn empty_client_batch_is_empty() {
        let s = session(128, 4, 0);
        let w = weights_u64(128, 708);
        let none: Vec<Vec<DpfKey<u64>>> = Vec::new();
        assert!(RetrievalEngine::new(8).answer_batch_keys(&s, &w, &none).is_empty());
    }

    #[test]
    fn more_threads_than_units() {
        let s = session(256, 4, 1);
        let w = weights_u64(256, 709);
        let mut rng = Rng::new(710);
        let sel = rng.sample_distinct(4, 256);
        let (_ctx, batch) = psr::client_query::<u64>(&s, &sel, &mut rng).unwrap();
        let keys = batch.server_keys(0);
        let serial = RetrievalEngine::serial().answer_keys(&s, &w, &keys);
        for t in [7usize, 64, 1000] {
            assert_eq!(
                RetrievalEngine::new(t).answer_keys(&s, &w, &keys),
                serial,
                "{t} threads"
            );
        }
    }
}
