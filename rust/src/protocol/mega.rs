//! Mega-element grouping (§6, Fig. 5 and Eq. (1)).
//!
//! Structured models (embedding layers) update whole τ-element rows at
//! once; grouping τ weights into one DPF payload amortises the per-key
//! `⌈log Θ⌉(λ+2)` overhead across τ·l payload bits:
//!
//! `R(π_mega) = c · ε((λ+2)⌈log Θ⌉ + L) / (τ·l)`, `L = τ·l`.
//!
//! With the paper's constants (ε=1.25, l=λ=128, ⌈log Θ⌉=9, τ=18) the
//! protocol stays non-trivial up to c ≈ 53.1% — the Table-2 "allow
//! grouping top-k" row.

use crate::group::{Group, MegaElem};

/// Map a flat weight index to its (mega index, offset within the group).
pub fn to_mega_index(flat: u64, tau: usize) -> (u64, usize) {
    (flat / tau as u64, (flat % tau as u64) as usize)
}

/// Mega-domain size for `m` flat weights.
pub fn mega_domain(m: u64, tau: usize) -> u64 {
    m.div_ceil(tau as u64)
}

/// Group a flat `Z_{2^64}` weight vector into mega-elements (zero-padded
/// tail). `T` must equal the runtime τ.
pub fn group_weights<const T: usize>(weights: &[u64]) -> Vec<MegaElem<T>> {
    weights
        .chunks(T)
        .map(|chunk| {
            let mut e = [0u64; T];
            e[..chunk.len()].copy_from_slice(chunk);
            MegaElem(e)
        })
        .collect()
}

/// Flatten mega-elements back to a weight vector of length `m`.
pub fn ungroup_weights<const T: usize>(mega: &[MegaElem<T>], m: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(mega.len() * T);
    for e in mega {
        out.extend_from_slice(&e.0);
    }
    out.truncate(m);
    out
}

/// Convert a sparse flat update (`indices`, `deltas`) into a sparse mega
/// update: distinct mega indices with dense τ-wide payloads.
pub fn sparsify_mega<const T: usize>(indices: &[u64], deltas: &[u64]) -> (Vec<u64>, Vec<MegaElem<T>>) {
    assert_eq!(indices.len(), deltas.len());
    let mut map: std::collections::BTreeMap<u64, MegaElem<T>> = std::collections::BTreeMap::new();
    for (&i, &d) in indices.iter().zip(deltas) {
        let (mi, off) = to_mega_index(i, T);
        let e = map.entry(mi).or_insert_with(MegaElem::zero);
        e.0[off] = e.0[off].wrapping_add(d);
    }
    map.into_iter().unzip()
}

/// §6 Eq. (1): communication advantage rate of the mega-element SSA
/// protocol versus trivial full-model aggregation (< 1 ⇒ non-trivial).
pub fn advantage_rate_mega(
    c: f64,
    epsilon: f64,
    log_theta: usize,
    lambda: usize,
    l: usize,
    tau: usize,
) -> f64 {
    let big_l = (tau * l) as f64;
    c * epsilon * ((lambda as f64 + 2.0) * log_theta as f64 + big_l) / (tau as f64 * l as f64)
}

/// §6: advantage rate of the *basic* SSA protocol (τ = 1 special case);
/// the paper's `R(π_ssa) ≈ 12.68·c` with default constants.
pub fn advantage_rate_basic(c: f64, epsilon: f64, log_theta: usize, lambda: usize, l: usize) -> f64 {
    advantage_rate_mega(c, epsilon, log_theta, lambda, l, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_roundtrip() {
        let w: Vec<u64> = (0..100).collect();
        let mega = group_weights::<18>(&w);
        assert_eq!(mega.len(), 6);
        assert_eq!(ungroup_weights(&mega, 100), w);
    }

    #[test]
    fn mega_indexing() {
        assert_eq!(to_mega_index(0, 18), (0, 0));
        assert_eq!(to_mega_index(17, 18), (0, 17));
        assert_eq!(to_mega_index(18, 18), (1, 0));
        assert_eq!(mega_domain(100, 18), 6);
        assert_eq!(mega_domain(108, 18), 6);
    }

    #[test]
    fn sparse_mega_conversion() {
        let idx = vec![0u64, 17, 18, 54, 55];
        let dl = vec![1u64, 2, 3, 4, 5];
        let (mi, md) = sparsify_mega::<18>(&idx, &dl);
        assert_eq!(mi, vec![0, 1, 3]);
        assert_eq!(md[0].0[0], 1);
        assert_eq!(md[0].0[17], 2);
        assert_eq!(md[1].0[0], 3);
        assert_eq!(md[2].0[0], 4);
        assert_eq!(md[2].0[1], 5);
    }

    #[test]
    fn paper_rate_numbers() {
        // §6: R(π_ssa) ≈ 12.68·c ⇒ non-trivial iff c ≲ 7.8%.
        let r = advantage_rate_basic(0.078, 1.25, 9, 128, 128);
        assert!((r - 12.68 * 0.078 / 1.0).abs() < 0.03, "rate {r}");
        assert!(advantage_rate_basic(0.077, 1.25, 9, 128, 128) < 1.0);
        assert!(advantage_rate_basic(0.085, 1.25, 9, 128, 128) > 1.0);
        // §6 mega: τ=18 ⇒ non-trivial up to c ≈ 53.1%.
        assert!(advantage_rate_mega(0.53, 1.25, 9, 128, 128, 18) < 1.0);
        assert!(advantage_rate_mega(0.55, 1.25, 9, 128, 128, 18) > 1.0);
        // §6 PSU: ⌈log Θ⌉ = 5 ⇒ non-trivial up to c ≈ 13.2% (the paper
        // rounds this band to "≲ 13.4%"; the exact Eq.(1) crossover with
        // ε=1.25, λ=l=128 is 128/(1.25·778) = 13.16%).
        assert!(advantage_rate_basic(0.131, 1.25, 5, 128, 128) < 1.0);
        assert!(advantage_rate_basic(0.14, 1.25, 5, 128, 128) > 1.0);
    }
}
