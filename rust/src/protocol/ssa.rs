//! Secure Submodel Aggregation (Fig. 4, bottom half).
//!
//! Client: same cuckoo batching as PSR, but bin `j`'s DPF carries
//! `f_{pos_j, Δw_u}` — the weight *update* as payload. Server `b`
//! full-domain-evaluates every bin key and scatters the shares back to
//! global positions: `[Δw]_b[T_simple[j][d]] += [f_j(d)]_b`. Because each
//! domain element appears in exactly its η candidate bins, this scatter is
//! the transpose of the paper's per-position gather
//! `Σ_d Eval(k[h_d(j)], pos_{h_d(j)})` — same sums, one pass, linear time.
//! Finally the servers exchange share vectors and reconstruct `Δw`.
//!
//! The server-side evaluate+scatter loop itself lives in
//! [`super::aggregate::AggregationEngine`]; the `server_aggregate_*`
//! functions here are thin wrappers kept for compatibility.

use super::aggregate::{AggregationEngine, PublicsUpload};
use super::psr::build_bin_points;
use super::session::Session;
use crate::crypto::rng::Rng;
use crate::dpf::{gen_batch_with_master, DpfKey, MasterKeyBatch};
use crate::group::Group;
use crate::hashing::CuckooError;
use std::collections::hash_map::Entry;
use std::collections::HashMap;

/// Build a client's SSA upload. `selections[i]`'s update is `deltas[i]`.
///
/// Duplicate indices in `selections` are allowed and their deltas are
/// **summed**: SSA is additive, so `(u, d1), (u, d2)` is semantically the
/// single update `(u, d1 + d2)`. (Previously duplicates silently kept one
/// arbitrary delta and — worse — were inserted into the cuckoo table
/// once per occurrence, double-counting the survivor.)
pub fn client_update<G: Group>(
    session: &Session,
    selections: &[u64],
    deltas: &[G],
    rng: &mut Rng,
) -> Result<MasterKeyBatch<G>, CuckooError> {
    let (uniq, delta_of) = sum_duplicate_selections(selections, deltas);
    let bins = build_bin_points(session, &uniq, rng, |u| delta_of[&u].clone())?;
    Ok(gen_batch_with_master(&bins.points, rng.gen_seed(), rng.gen_seed()))
}

/// Collapse a `(selections, deltas)` pair into distinct indices with
/// summed deltas, preserving first-occurrence order. Shared by the SSA
/// and U-DPF-SSA client paths so both define duplicates the same way.
pub(crate) fn sum_duplicate_selections<G: Group>(
    selections: &[u64],
    deltas: &[G],
) -> (Vec<u64>, HashMap<u64, G>) {
    assert_eq!(selections.len(), deltas.len());
    let mut delta_of: HashMap<u64, G> = HashMap::with_capacity(selections.len());
    let mut uniq = Vec::with_capacity(selections.len());
    for (&u, d) in selections.iter().zip(deltas) {
        match delta_of.entry(u) {
            Entry::Occupied(mut e) => e.get_mut().add_assign(d),
            Entry::Vacant(e) => {
                e.insert(d.clone());
                uniq.push(u);
            }
        }
    }
    (uniq, delta_of)
}

/// [`sum_duplicate_selections`] without materialising the distinct-index
/// vector — for callers that only look deltas up by index (the per-epoch
/// U-DPF hint path).
pub(crate) fn sum_deltas_by_index<G: Group>(selections: &[u64], deltas: &[G]) -> HashMap<u64, G> {
    assert_eq!(selections.len(), deltas.len());
    let mut delta_of: HashMap<u64, G> = HashMap::with_capacity(selections.len());
    for (&u, d) in selections.iter().zip(deltas) {
        match delta_of.entry(u) {
            Entry::Occupied(mut e) => e.get_mut().add_assign(d),
            Entry::Vacant(e) => {
                e.insert(d.clone());
            }
        }
    }
    delta_of
}

/// Server `b`: evaluate one client's keys and accumulate its share of the
/// global update into `acc` (length = domain size).
#[deprecated(note = "use protocol::aggregate::AggregationEngine::aggregate_client_keys_into")]
pub fn server_aggregate_into<G: Group>(session: &Session, keys: &[DpfKey<G>], acc: &mut [G]) {
    AggregationEngine::serial().aggregate_client_keys_into(session, keys, acc);
}

/// Server `b`: aggregate one client's contribution straight from its
/// decoded public parts + master seed, without materialising `DpfKey`s.
#[deprecated(note = "use protocol::aggregate::AggregationEngine::aggregate_publics_into")]
pub fn server_aggregate_publics<G: Group>(
    session: &Session,
    publics: &[crate::dpf::PublicPart<G>],
    msk: &crate::crypto::prg::Seed,
    party: u8,
    acc: &mut [G],
) {
    let uploads = [PublicsUpload { publics, msk }];
    AggregationEngine::serial().aggregate_publics_into(session, party, &uploads, acc);
}

/// Convenience: aggregate many clients' key sets into a fresh share
/// vector (single-threaded engine; configure an [`AggregationEngine`]
/// directly for the sharded path).
pub fn server_aggregate<G: Group>(session: &Session, clients: &[Vec<DpfKey<G>>]) -> Vec<G> {
    AggregationEngine::serial().aggregate_keys(session, clients)
}

/// Multi-threaded server aggregation.
#[deprecated(note = "use protocol::aggregate::AggregationEngine::aggregate_keys")]
pub fn server_aggregate_parallel<G: Group>(
    session: &Session,
    clients: &[Vec<DpfKey<G>>],
    threads: usize,
) -> Vec<G> {
    AggregationEngine::new(threads).aggregate_keys(session, clients)
}

/// Reconstruct `Δw` from the two servers' share vectors (the final
/// `S_0`/`S_1` exchange in Fig. 4).
pub fn reconstruct<G: Group>(share0: &[G], share1: &[G]) -> Vec<G> {
    assert_eq!(share0.len(), share1.len());
    share0
        .iter()
        .zip(share1)
        .map(|(a, b)| a.add(b))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::CuckooParams;
    use crate::protocol::session::SessionParams;

    fn session(m: u64, k: usize) -> Session {
        Session::new_full(SessionParams {
            m,
            k,
            cuckoo: CuckooParams::default(),
        })
    }

    #[test]
    fn single_client_sparse_update() {
        let s = session(1 << 10, 32);
        let mut rng = Rng::new(100);
        let sel = rng.sample_distinct(32, 1 << 10);
        let deltas: Vec<u64> = (0..32).map(|i| 1000 + i).collect();
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let sh0 = server_aggregate(&s, &[batch.server_keys(0)]);
        let sh1 = server_aggregate(&s, &[batch.server_keys(1)]);
        let dw = reconstruct(&sh0, &sh1);
        for x in 0..(1u64 << 10) {
            match sel.iter().position(|&sl| sl == x) {
                Some(i) => assert_eq!(dw[x as usize], deltas[i], "at {x}"),
                None => assert_eq!(dw[x as usize], 0, "at {x}"),
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let s = session(1 << 11, 64);
        let mut rng = Rng::new(105);
        let mut all0 = Vec::new();
        for _ in 0..6 {
            let sel = rng.sample_distinct(64, 1 << 11);
            let deltas: Vec<u64> = sel.iter().map(|&x| x ^ 0xabc).collect();
            let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
            all0.push(batch.server_keys(0));
        }
        let serial = server_aggregate(&s, &all0);
        for threads in [2, 3, 8, 64] {
            assert_eq!(AggregationEngine::new(threads).aggregate_keys(&s, &all0), serial);
        }
    }

    /// The retained equivalence check against this module's deprecated
    /// wrappers (`server_aggregate_into` / `server_aggregate_publics` /
    /// `server_aggregate_parallel`) — everything else goes through the
    /// [`AggregationEngine`] API directly.
    #[test]
    #[allow(deprecated)]
    fn deprecated_wrappers_match_the_engine() {
        let s = session(512, 16);
        let mut rng = Rng::new(107);
        let sel = rng.sample_distinct(16, 512);
        let deltas: Vec<u64> = sel.iter().map(|&x| x + 9).collect();
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let keys0 = batch.server_keys(0);
        let engine = AggregationEngine::serial();

        let mut legacy_into = vec![0u64; 512];
        server_aggregate_into(&s, &keys0, &mut legacy_into);
        let mut engine_into = vec![0u64; 512];
        engine.aggregate_client_keys_into(&s, &keys0, &mut engine_into);
        assert_eq!(legacy_into, engine_into);

        let mut legacy_publics = vec![0u64; 512];
        server_aggregate_publics(&s, &batch.publics, batch.msk[0].expose(), 0, &mut legacy_publics);
        assert_eq!(legacy_publics, engine_into);

        assert_eq!(
            server_aggregate_parallel(&s, &[keys0.clone()], 4),
            engine.aggregate_keys(&s, &[keys0]),
        );
    }

    #[test]
    fn duplicate_selections_sum_their_deltas() {
        let s = session(256, 8);
        let mut rng = Rng::new(106);
        let sel = vec![5u64, 9, 5, 200, 9, 5];
        let deltas = vec![10u64, 20, 30, 40, 50, 60];
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let dw = reconstruct(
            &server_aggregate(&s, &[batch.server_keys(0)]),
            &server_aggregate(&s, &[batch.server_keys(1)]),
        );
        let mut expected = vec![0u64; 256];
        for (&u, &d) in sel.iter().zip(&deltas) {
            expected[u as usize] = expected[u as usize].wrapping_add(d);
        }
        assert_eq!(dw, expected, "duplicates must sum, everything else 0");
    }

    #[test]
    fn multi_client_overlapping_updates() {
        // Clients with overlapping selections: updates must *sum*.
        let s = session(512, 16);
        let mut rng = Rng::new(101);
        let mut expected = vec![0u64; 512];
        let mut all_keys0 = Vec::new();
        let mut all_keys1 = Vec::new();
        for c in 0..5 {
            let sel = rng.sample_distinct(16, 512);
            let deltas: Vec<u64> = sel.iter().map(|&x| x * 10 + c).collect();
            for (i, &x) in sel.iter().enumerate() {
                expected[x as usize] = expected[x as usize].wrapping_add(deltas[i]);
            }
            let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
            all_keys0.push(batch.server_keys(0));
            all_keys1.push(batch.server_keys(1));
        }
        let dw = reconstruct(
            &server_aggregate(&s, &all_keys0),
            &server_aggregate(&s, &all_keys1),
        );
        assert_eq!(dw, expected);
    }

    #[test]
    fn shares_alone_are_pseudorandom() {
        let s = session(256, 8);
        let mut rng = Rng::new(102);
        let sel = rng.sample_distinct(8, 256);
        let deltas = vec![7u64; 8];
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let sh0 = server_aggregate(&s, &[batch.server_keys(0)]);
        // A single share vector should be dense noise, not sparse.
        let zeros = sh0.iter().filter(|v| **v == 0).count();
        assert!(zeros < 5, "share vector suspiciously sparse: {zeros} zeros");
    }

    #[test]
    fn works_over_union_domain() {
        // PSU-optimised session: domain is a strict subset of {0..m}.
        let m = 1u64 << 12;
        let union: Vec<u64> = (0..m).step_by(3).collect();
        let params = SessionParams {
            m,
            k: 16,
            cuckoo: CuckooParams::default(),
        };
        let s = Session::new_union(params, union.clone()).unwrap();
        let mut rng = Rng::new(103);
        let sel: Vec<u64> = (0..16).map(|i| union[i * 7]).collect();
        let deltas: Vec<u64> = (0..16).map(|i| 5000 + i).collect();
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let dw = reconstruct(
            &server_aggregate(&s, &[batch.server_keys(0)]),
            &server_aggregate(&s, &[batch.server_keys(1)]),
        );
        assert_eq!(dw.len(), union.len());
        for (pos, &idx) in union.iter().enumerate() {
            match sel.iter().position(|&sl| sl == idx) {
                Some(i) => assert_eq!(dw[pos], deltas[i]),
                None => assert_eq!(dw[pos], 0),
            }
        }
    }

    #[test]
    fn fig2_worked_example() {
        // The paper's running example: insert {1,4} into the cuckoo table
        // over domain {1..5}; aggregation must place Δw at positions 1,4.
        let s = Session::new_full(SessionParams {
            m: 6,
            k: 2,
            cuckoo: CuckooParams::default(),
        });
        let mut rng = Rng::new(104);
        let sel = vec![1u64, 4];
        let deltas = vec![10u64, 40];
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let dw = reconstruct(
            &server_aggregate(&s, &[batch.server_keys(0)]),
            &server_aggregate(&s, &[batch.server_keys(1)]),
        );
        assert_eq!(dw, vec![0, 10, 0, 0, 40, 0]);
    }
}
