//! Secure Submodel Aggregation (Fig. 4, bottom half).
//!
//! Client: same cuckoo batching as PSR, but bin `j`'s DPF carries
//! `f_{pos_j, Δw_u}` — the weight *update* as payload. Server `b`
//! full-domain-evaluates every bin key and scatters the shares back to
//! global positions: `[Δw]_b[T_simple[j][d]] += [f_j(d)]_b`. Because each
//! domain element appears in exactly its η candidate bins, this scatter is
//! the transpose of the paper's per-position gather
//! `Σ_d Eval(k[h_d(j)], pos_{h_d(j)})` — same sums, one pass, linear time.
//! Finally the servers exchange share vectors and reconstruct `Δw`.

use super::psr::build_bin_points;
use super::session::Session;
use crate::crypto::rng::Rng;
use crate::dpf::{self, gen_batch_with_master, DpfKey, MasterKeyBatch};
use crate::group::Group;
use crate::hashing::CuckooError;

/// Build a client's SSA upload. `selections[i]`'s update is `deltas[i]`.
pub fn client_update<G: Group>(
    session: &Session,
    selections: &[u64],
    deltas: &[G],
    rng: &mut Rng,
) -> Result<MasterKeyBatch<G>, CuckooError> {
    assert_eq!(selections.len(), deltas.len());
    let delta_of: std::collections::HashMap<u64, G> = selections
        .iter()
        .copied()
        .zip(deltas.iter().cloned())
        .collect();
    let bins = build_bin_points(session, selections, rng, |u| delta_of[&u].clone())?;
    Ok(gen_batch_with_master(&bins.points, rng.gen_seed(), rng.gen_seed()))
}

/// Server `b`: evaluate one client's keys and accumulate its share of the
/// global update into `acc` (length = domain size).
pub fn server_aggregate_into<G: Group>(session: &Session, keys: &[DpfKey<G>], acc: &mut [G]) {
    let num_bins = session.simple.num_bins();
    let sigma = session.params.cuckoo.sigma;
    assert_eq!(keys.len(), num_bins + sigma, "key count");
    assert_eq!(acc.len(), session.domain_size(), "accumulator size");

    // Reused workspace + output buffer: zero heap churn across the B bin
    // evaluations (§Perf iteration 3).
    let mut ws = dpf::EvalWorkspace::default();
    let mut ev: Vec<G> = Vec::new();
    for (j, key) in keys.iter().take(num_bins).enumerate() {
        let bin = session.simple.bin(j);
        dpf::full_eval_with(key, bin.len(), &mut ws, &mut ev);
        for (d, &idx) in bin.iter().enumerate() {
            let pos = session
                .domain_index_of(idx)
                .expect("simple bin element outside domain") as usize;
            acc[pos].add_assign(&ev[d]);
        }
    }
    for key in keys.iter().skip(num_bins) {
        let evals = dpf::full_eval(key, acc.len());
        for (pos, ev) in evals.iter().enumerate() {
            acc[pos].add_assign(ev);
        }
    }
}

/// Server `b`: aggregate one client's contribution straight from its
/// decoded public parts + master seed, without materialising `DpfKey`s
/// (no correction-word clones — §Perf iteration 5). Stash keys are the
/// trailing `σ` parts, evaluated over the whole domain.
pub fn server_aggregate_publics<G: Group>(
    session: &Session,
    publics: &[crate::dpf::PublicPart<G>],
    msk: &crate::crypto::prg::Seed,
    party: u8,
    acc: &mut [G],
) {
    let num_bins = session.simple.num_bins();
    let sigma = session.params.cuckoo.sigma;
    assert_eq!(publics.len(), num_bins + sigma, "public part count");
    assert_eq!(acc.len(), session.domain_size(), "accumulator size");
    let mut ws = dpf::EvalWorkspace::default();
    let mut ev: Vec<G> = Vec::new();
    for (j, p) in publics.iter().enumerate() {
        let root = crate::crypto::prg::prf_seed(msk, j as u64);
        let n = if j < num_bins {
            session.simple.bin(j).len()
        } else {
            session.domain_size()
        };
        dpf::full_eval_parts(party, p.depth, &root, &p.cws, &p.cw_out, n, &mut ws, &mut ev);
        if j < num_bins {
            for (d, &idx) in session.simple.bin(j).iter().enumerate() {
                let pos = session.domain_index_of(idx).expect("in domain") as usize;
                acc[pos].add_assign(&ev[d]);
            }
        } else {
            for (pos, v) in ev.iter().enumerate() {
                acc[pos].add_assign(v);
            }
        }
    }
}

/// Convenience: aggregate many clients' key sets into a fresh share
/// vector.
pub fn server_aggregate<G: Group>(session: &Session, clients: &[Vec<DpfKey<G>>]) -> Vec<G> {
    let mut acc = vec![G::zero(); session.domain_size()];
    for keys in clients {
        server_aggregate_into(session, keys, &mut acc);
    }
    acc
}

/// Multi-threaded server aggregation (the paper enables multi-threading
/// for all experiments, §7.2). Bins are sharded across `threads` workers —
/// each worker walks a disjoint bin range of *every* client's key set, so
/// scatter targets never collide and no locking is needed; per-worker
/// partial accumulators are merged at the end.
pub fn server_aggregate_parallel<G: Group>(
    session: &Session,
    clients: &[Vec<DpfKey<G>>],
    threads: usize,
) -> Vec<G> {
    let threads = threads.max(1);
    if threads == 1 || clients.is_empty() {
        return server_aggregate(session, clients);
    }
    let num_bins = session.simple.num_bins();
    let domain = session.domain_size();
    let chunk = num_bins.div_ceil(threads);
    let mut partials: Vec<Vec<G>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            let lo = (t * chunk).min(num_bins);
            let hi = ((t + 1) * chunk).min(num_bins);
            handles.push(scope.spawn(move || {
                let mut acc = vec![G::zero(); domain];
                for keys in clients {
                    for (j, key) in keys[lo..hi].iter().enumerate() {
                        let bin = session.simple.bin(lo + j);
                        let evals = dpf::full_eval(key, bin.len());
                        for (d, &idx) in bin.iter().enumerate() {
                            let pos =
                                session.domain_index_of(idx).expect("element in domain") as usize;
                            acc[pos].add_assign(&evals[d]);
                        }
                    }
                }
                acc
            }));
        }
        for h in handles {
            partials.push(h.join().expect("aggregation worker panicked"));
        }
    });
    // Merge partials; stash keys (outside the bin range) processed serially.
    let mut acc = partials.pop().unwrap_or_else(|| vec![G::zero(); domain]);
    for p in &partials {
        for (a, v) in acc.iter_mut().zip(p) {
            a.add_assign(v);
        }
    }
    for keys in clients {
        for key in keys.iter().skip(num_bins) {
            let evals = dpf::full_eval(key, domain);
            for (pos, ev) in evals.iter().enumerate() {
                acc[pos].add_assign(ev);
            }
        }
    }
    acc
}

/// Reconstruct `Δw` from the two servers' share vectors (the final
/// `S_0`/`S_1` exchange in Fig. 4).
pub fn reconstruct<G: Group>(share0: &[G], share1: &[G]) -> Vec<G> {
    assert_eq!(share0.len(), share1.len());
    share0
        .iter()
        .zip(share1)
        .map(|(a, b)| a.add(b))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::CuckooParams;
    use crate::protocol::session::SessionParams;

    fn session(m: u64, k: usize) -> Session {
        Session::new_full(SessionParams {
            m,
            k,
            cuckoo: CuckooParams::default(),
        })
    }

    #[test]
    fn single_client_sparse_update() {
        let s = session(1 << 10, 32);
        let mut rng = Rng::new(100);
        let sel = rng.sample_distinct(32, 1 << 10);
        let deltas: Vec<u64> = (0..32).map(|i| 1000 + i).collect();
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let sh0 = server_aggregate(&s, &[batch.server_keys(0)]);
        let sh1 = server_aggregate(&s, &[batch.server_keys(1)]);
        let dw = reconstruct(&sh0, &sh1);
        for x in 0..(1u64 << 10) {
            match sel.iter().position(|&sl| sl == x) {
                Some(i) => assert_eq!(dw[x as usize], deltas[i], "at {x}"),
                None => assert_eq!(dw[x as usize], 0, "at {x}"),
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let s = session(1 << 11, 64);
        let mut rng = Rng::new(105);
        let mut all0 = Vec::new();
        for _ in 0..6 {
            let sel = rng.sample_distinct(64, 1 << 11);
            let deltas: Vec<u64> = sel.iter().map(|&x| x ^ 0xabc).collect();
            let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
            all0.push(batch.server_keys(0));
        }
        let serial = server_aggregate(&s, &all0);
        for threads in [2, 3, 8, 64] {
            assert_eq!(server_aggregate_parallel(&s, &all0, threads), serial);
        }
    }

    #[test]
    fn multi_client_overlapping_updates() {
        // Clients with overlapping selections: updates must *sum*.
        let s = session(512, 16);
        let mut rng = Rng::new(101);
        let mut expected = vec![0u64; 512];
        let mut all_keys0 = Vec::new();
        let mut all_keys1 = Vec::new();
        for c in 0..5 {
            let sel = rng.sample_distinct(16, 512);
            let deltas: Vec<u64> = sel.iter().map(|&x| x * 10 + c).collect();
            for (i, &x) in sel.iter().enumerate() {
                expected[x as usize] = expected[x as usize].wrapping_add(deltas[i]);
            }
            let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
            all_keys0.push(batch.server_keys(0));
            all_keys1.push(batch.server_keys(1));
        }
        let dw = reconstruct(
            &server_aggregate(&s, &all_keys0),
            &server_aggregate(&s, &all_keys1),
        );
        assert_eq!(dw, expected);
    }

    #[test]
    fn shares_alone_are_pseudorandom() {
        let s = session(256, 8);
        let mut rng = Rng::new(102);
        let sel = rng.sample_distinct(8, 256);
        let deltas = vec![7u64; 8];
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let sh0 = server_aggregate(&s, &[batch.server_keys(0)]);
        // A single share vector should be dense noise, not sparse.
        let zeros = sh0.iter().filter(|v| **v == 0).count();
        assert!(zeros < 5, "share vector suspiciously sparse: {zeros} zeros");
    }

    #[test]
    fn works_over_union_domain() {
        // PSU-optimised session: domain is a strict subset of {0..m}.
        let m = 1u64 << 12;
        let union: Vec<u64> = (0..m).step_by(3).collect();
        let params = SessionParams {
            m,
            k: 16,
            cuckoo: CuckooParams::default(),
        };
        let s = Session::new_union(params, union.clone());
        let mut rng = Rng::new(103);
        let sel: Vec<u64> = (0..16).map(|i| union[i * 7]).collect();
        let deltas: Vec<u64> = (0..16).map(|i| 5000 + i).collect();
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let dw = reconstruct(
            &server_aggregate(&s, &[batch.server_keys(0)]),
            &server_aggregate(&s, &[batch.server_keys(1)]),
        );
        assert_eq!(dw.len(), union.len());
        for (pos, &idx) in union.iter().enumerate() {
            match sel.iter().position(|&sl| sl == idx) {
                Some(i) => assert_eq!(dw[pos], deltas[i]),
                None => assert_eq!(dw[pos], 0),
            }
        }
    }

    #[test]
    fn fig2_worked_example() {
        // The paper's running example: insert {1,4} into the cuckoo table
        // over domain {1..5}; aggregation must place Δw at positions 1,4.
        let s = Session::new_full(SessionParams {
            m: 6,
            k: 2,
            cuckoo: CuckooParams::default(),
        });
        let mut rng = Rng::new(104);
        let sel = vec![1u64, 4];
        let deltas = vec![10u64, 40];
        let batch = client_update(&s, &sel, &deltas, &mut rng).unwrap();
        let dw = reconstruct(
            &server_aggregate(&s, &[batch.server_keys(0)]),
            &server_aggregate(&s, &[batch.server_keys(1)]),
        );
        assert_eq!(dw, vec![0, 10, 0, 0, 40, 0]);
    }
}
