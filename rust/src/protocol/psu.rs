//! Private Set Union (§6, "Basic protocol with PSU").
//!
//! The optimisation: before aggregation, all parties learn the *union*
//! `U = ∪_i s^(i)` and rebuild the simple table over `U` instead of
//! `{0..m}` — shrinking Θ (the paper measures 9 → 5 bins bits) and hence
//! every DPF key.
//!
//! Construction (symmetric-key, two-server — in the spirit of \[29\]):
//! clients share a blinding key `K` (derived from common randomness the
//! servers never see). Each client sends `{PRP_K(x) : x ∈ s^(i)}`, padded
//! to exactly k items with client-unique dummies, to `S_0`. `S_0` shuffles
//! the combined multiset (breaking client↔item linkage) and forwards it to
//! `S_1`, which deduplicates and broadcasts the blinded union; clients
//! unblind with `K⁻¹` and drop dummies. Leakage beyond the ideal
//! functionality: the *unlinkable* multiplicity histogram seen by `S_1`
//! (documented; the paper's PSU is likewise leakage-parameterised — it
//! assumes "the leakage of the union set reveals negligible useful
//! information").

use super::session::{Session, SessionParams};
use crate::crypto::prg::expand_stream;
use crate::crypto::rng::Rng;

/// A small-domain PRP over `[0, 2^bits)` via a 4-round Feistel network
/// with AES-CTR round functions, cycle-walked down to `[0, domain)`.
#[derive(Clone, Debug)]
pub struct SmallPrp {
    round_keys: [[u8; 16]; 4],
    bits: u32,
    domain: u64,
}

impl SmallPrp {
    /// Build a PRP on `[0, domain)` from a λ-bit key.
    pub fn new(key: &[u8; 16], domain: u64) -> Self {
        assert!(domain >= 2);
        let bits = 64 - (domain - 1).leading_zeros();
        // Derive 4 independent round keys from the master key.
        let stream = expand_stream(key, 64);
        let mut round_keys = [[0u8; 16]; 4];
        for (i, rk) in round_keys.iter_mut().enumerate() {
            rk.copy_from_slice(&stream[i * 16..(i + 1) * 16]);
        }
        SmallPrp {
            // Even bit count → balanced Feistel halves.
            bits: (bits.max(2) + 1) & !1,
            round_keys,
            domain,
        }
    }

    fn round(&self, r: usize, x: u64) -> u64 {
        let mut seed = self.round_keys[r];
        seed[8..].copy_from_slice(&x.to_le_bytes());
        let out = expand_stream(&seed, 8);
        u64::from_le_bytes([
            out[0], out[1], out[2], out[3], out[4], out[5], out[6], out[7],
        ])
    }

    fn feistel(&self, x: u64, inverse: bool) -> u64 {
        let half = self.bits / 2;
        let mask = (1u64 << half) - 1;
        let (mut l, mut r) = (x >> half, x & mask);
        if !inverse {
            for i in 0..4 {
                let (nl, nr) = (r, l ^ (self.round(i, r) & mask));
                l = nl;
                r = nr;
            }
        } else {
            for i in (0..4).rev() {
                let (nl, nr) = (r ^ (self.round(i, l) & mask), l);
                l = nl;
                r = nr;
            }
        }
        (l << half) | r
    }

    /// Forward permutation (cycle-walking keeps outputs in-domain).
    pub fn permute(&self, x: u64) -> u64 {
        assert!(x < self.domain);
        let mut y = self.feistel(x, false);
        while y >= self.domain {
            y = self.feistel(y, false);
        }
        y
    }

    /// Inverse permutation.
    pub fn invert(&self, y: u64) -> u64 {
        assert!(y < self.domain);
        let mut x = self.feistel(y, true);
        while x >= self.domain {
            x = self.feistel(x, true);
        }
        x
    }
}

/// Blind one client's padded selection set. Dummies are drawn from a
/// client-unique high range `[m, m + k)` of the extended PRP domain, so
/// they never collide with real indices or other clients' dummies.
pub fn client_blind(
    key: &[u8; 16],
    m: u64,
    k: usize,
    client_id: u64,
    selections: &[u64],
) -> Vec<u64> {
    assert!(selections.len() <= k);
    // Extended domain: real indices ∪ per-client dummy slots.
    let n_clients_hint = 1u64 << 20;
    let domain = m + n_clients_hint * k as u64;
    let prp = SmallPrp::new(key, domain);
    let mut out: Vec<u64> = selections.iter().map(|&x| prp.permute(x)).collect();
    for d in 0..(k - selections.len()) {
        out.push(prp.permute(m + client_id * k as u64 + d as u64));
    }
    out
}

/// `S_0`: shuffle the combined blinded multiset (unlinkability).
pub fn server0_shuffle(mut items: Vec<u64>, rng: &mut Rng) -> Vec<u64> {
    rng.shuffle(&mut items);
    items
}

/// `S_1`: deduplicate; the result is the blinded union (plus blinded
/// dummies, which clients drop after unblinding).
pub fn server1_dedup(mut items: Vec<u64>) -> Vec<u64> {
    items.sort_unstable();
    items.dedup();
    items
}

/// Client: unblind the broadcast union, drop dummies, sort.
pub fn client_unblind(key: &[u8; 16], m: u64, k: usize, blinded_union: &[u64]) -> Vec<u64> {
    let n_clients_hint = 1u64 << 20;
    let domain = m + n_clients_hint * k as u64;
    let prp = SmallPrp::new(key, domain);
    let mut out: Vec<u64> = blinded_union
        .iter()
        .map(|&y| prp.invert(y))
        .filter(|&x| x < m)
        .collect();
    out.sort_unstable();
    out
}

/// Run the whole PSU among `n` clients in-process (used by the coordinator
/// and benches); returns the revealed union, ascending.
pub fn run_psu(
    key: &[u8; 16],
    m: u64,
    k: usize,
    client_sets: &[Vec<u64>],
    rng: &mut Rng,
) -> Vec<u64> {
    let mut pooled = Vec::with_capacity(client_sets.len() * k);
    for (cid, set) in client_sets.iter().enumerate() {
        pooled.extend(client_blind(key, m, k, cid as u64, set));
    }
    let shuffled = server0_shuffle(pooled, rng);
    let blinded_union = server1_dedup(shuffled);
    client_unblind(key, m, k, &blinded_union)
}

/// Run the PSU and rebuild the session over the revealed union in one
/// step (§6, Table 2 row 2) — the alignment domain shrinks to `∪ s^(i)`,
/// so Θ and every DPF key shrink with it. The returned session feeds both
/// engines unchanged: the write path
/// ([`super::aggregate::AggregationEngine`]) scatters over union
/// positions, and the read path
/// ([`super::retrieve::RetrievalEngine`]) keeps taking the *global*
/// `m`-sized weight vector, mapping stash positions back through
/// [`Session::domain_value`].
///
/// One-shot wrapper: a persistent deployment runs the PSU over the wire
/// and installs the union session on both living server threads in one
/// call — see `coordinator::FslRuntime::psu_align`.
#[deprecated(note = "build a coordinator::FslRuntime and call .psu_align(..)")]
pub fn run_psu_session(
    key: &[u8; 16],
    params: SessionParams,
    client_sets: &[Vec<u64>],
    rng: &mut Rng,
) -> anyhow::Result<Session> {
    let union = run_psu(key, params.m, params.k, client_sets, rng);
    Session::new_union(params, union)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prp_is_a_permutation() {
        let prp = SmallPrp::new(&[5u8; 16], 1000);
        let mut seen = std::collections::HashSet::new();
        for x in 0..1000 {
            let y = prp.permute(x);
            assert!(y < 1000);
            assert!(seen.insert(y), "collision at {x}");
            assert_eq!(prp.invert(y), x);
        }
    }

    #[test]
    fn prp_nontrivial() {
        let prp = SmallPrp::new(&[6u8; 16], 1 << 16);
        let fixed = (0..1000u64).filter(|&x| prp.permute(x) == x).count();
        assert!(fixed < 5, "{fixed} fixed points");
    }

    #[test]
    fn union_is_exact() {
        let key = [9u8; 16];
        let m = 1u64 << 14;
        let k = 50;
        let mut rng = Rng::new(110);
        let sets: Vec<Vec<u64>> = (0..8)
            .map(|_| rng.sample_distinct(k - 5, m)) // under-filled → dummies
            .collect();
        let mut expected: Vec<u64> = sets.iter().flatten().copied().collect();
        expected.sort_unstable();
        expected.dedup();
        let got = run_psu(&key, m, k, &sets, &mut rng);
        assert_eq!(got, expected);
    }

    #[test]
    fn dummies_never_leak_into_union() {
        let key = [1u8; 16];
        let m = 4096;
        let mut rng = Rng::new(111);
        let sets = vec![vec![1u64, 2, 3], vec![3u64, 4]];
        let got = run_psu(&key, m, 16, &sets, &mut rng);
        assert_eq!(got, vec![1, 2, 3, 4]);
    }

    #[test]
    fn psu_then_psr_over_the_union_domain() {
        // Table 2 row 2, read side: after the PSU shrinks the alignment
        // domain, clients retrieve through the sharded engine over the
        // union session — answers must still be the exact global weights.
        use crate::hashing::CuckooParams;
        use crate::protocol::{psr, RetrievalEngine};
        let m = 1u64 << 12;
        let k = 32;
        let mut rng = Rng::new(112);
        let hot: Vec<u64> = rng.sample_distinct(256, m);
        let sets: Vec<Vec<u64>> = (0..4)
            .map(|_| {
                let mut s: Vec<u64> = (0..k)
                    .map(|_| hot[rng.gen_range(hot.len() as u64) as usize])
                    .collect();
                s.sort_unstable();
                s.dedup();
                s
            })
            .collect();
        let union = run_psu(&[8u8; 16], m, k, &sets, &mut rng);
        let session = Session::new_union(
            SessionParams {
                m,
                k,
                cuckoo: CuckooParams::default(),
            },
            union,
        )
        .unwrap();
        assert!(session.domain_size() < m as usize, "union must shrink the domain");
        let weights: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
        let engine = RetrievalEngine::new(4);
        for sel in &sets {
            let (ctx, batch) = psr::client_query::<u64>(&session, sel, &mut rng).unwrap();
            let a0 = engine.answer_keys(&session, &weights, &batch.server_keys(0));
            let a1 = engine.answer_keys(&session, &weights, &batch.server_keys(1));
            let got = psr::client_reconstruct(&ctx, session.simple.num_bins(), sel, &a0, &a1);
            for (i, &s) in sel.iter().enumerate() {
                assert_eq!(got[i], weights[s as usize], "index {s}");
            }
        }
    }

    #[test]
    fn padded_sizes_are_uniform() {
        // Each client's message has exactly k items regardless of |s|.
        let key = [2u8; 16];
        for len in [0usize, 3, 16] {
            let set: Vec<u64> = (0..len as u64).collect();
            assert_eq!(client_blind(&key, 1 << 12, 16, 7, &set).len(), 16);
        }
    }
}
