//! The paper's two protocols (Figure 4) plus its §6 optimisations.
//!
//! * [`psr`] — Private Submodel Retrieval: multi-query PIR via cuckoo
//!   batching + one DPF-PIR per bin.
//! * [`ssa`] — Secure Submodel Aggregation: the same batching, with the
//!   DPF payload carrying the weight update `Δw_u`.
//! * [`psu`] — Private Set Union: shrink the alignment domain to
//!   `∪_i s^(i)` (§6).
//! * [`mega`] — mega-element grouping: τ weights per DPF payload (§6).
//! * [`session`] — shared per-round state (tables, parameters, domains).
//! * [`udpf_ssa`] — SSA over updatable DPF keys for fixed submodels (§6).
//! * [`aggregate`] — the unified, sharded server-aggregation engine every
//!   server-side evaluate+scatter path routes through (SSA write path),
//!   plus the [`aggregate::Sharding`] planner it shares with…
//! * [`retrieve`] — …the unified, sharded PSR answer engine every
//!   server-side evaluate+inner-product path routes through (read path).

pub mod aggregate;
pub mod mega;
pub mod msg;
pub mod psr;
pub mod psu;
pub mod retrieve;
pub mod session;
pub mod ssa;
pub mod udpf_ssa;

pub use aggregate::{AggregationEngine, Sharding};
pub use retrieve::RetrievalEngine;
pub use session::{Session, SessionParams};
