//! Shared per-round session state.
//!
//! Before either protocol runs, all parties agree on (System Setup,
//! Fig. 4): the counts `(n, m, k)`, the cuckoo parameters `(ε, η, σ)`,
//! `B = ⌈εk⌉` bins, and they deterministically build the aligned simple
//! table over the alignment domain (the full index set `{0..m}`, or the
//! PSU union).

use anyhow::{anyhow, Result};
use crate::hashing::{CuckooParams, SimpleTable};
use std::sync::Arc;

/// Public, agreed-upon round parameters.
#[derive(Clone, Debug)]
pub struct SessionParams {
    /// Global model size m.
    pub m: u64,
    /// Per-client submodel size k.
    pub k: usize,
    /// Cuckoo parameters (ε, η, σ, public hash seed).
    pub cuckoo: CuckooParams,
}

impl SessionParams {
    /// Number of cuckoo/simple bins `B = ⌈εk⌉`.
    pub fn num_bins(&self) -> usize {
        self.cuckoo.num_bins(self.k)
    }
}

/// A session binds parameters to the alignment domain and the (shared,
/// deterministic) simple table. Both servers and all clients hold an
/// identical copy — it is public data.
#[derive(Clone)]
pub struct Session {
    pub params: SessionParams,
    /// Alignment domain, ascending. `None` ⇒ the dense full domain
    /// `{0..m}` (kept implicit to avoid materialising 2^25 u64s).
    pub domain: Option<Arc<Vec<u64>>>,
    pub simple: Arc<SimpleTable>,
}

impl Session {
    /// Full-domain session (basic protocols).
    pub fn new_full(params: SessionParams) -> Self {
        let simple = SimpleTable::build_full(params.m, params.num_bins(), &params.cuckoo);
        Session {
            simple: Arc::new(simple),
            domain: None,
            params,
        }
    }

    /// Union-domain session (PSU optimisation, §6). `union` must be the
    /// ascending, deduplicated output of the PSU protocol, with every
    /// element inside the model domain `[0, m)`.
    ///
    /// Rejects malformed input in release builds too: an unsorted or
    /// duplicated union silently breaks [`Session::domain_index_of`]'s
    /// binary search (every later position lookup is wrong), so it is an
    /// error, not a debug assertion.
    pub fn new_union(params: SessionParams, union: Vec<u64>) -> Result<Self> {
        if let Some(w) = union.windows(2).find(|w| w[0] >= w[1]) {
            return Err(anyhow!(
                "PSU union must be strictly ascending (sorted, deduplicated): \
                 found {} followed by {}; sort + dedup the union before building the session",
                w[0],
                w[1]
            ));
        }
        if let Some(&last) = union.last().filter(|&&last| last >= params.m) {
            return Err(anyhow!(
                "PSU union element {last} is outside the model domain [0, {}): \
                 the union may only contain global model indices",
                params.m
            ));
        }
        let simple = SimpleTable::build(
            union.iter().copied(),
            params.num_bins(),
            &params.cuckoo,
        );
        Ok(Session {
            simple: Arc::new(simple),
            domain: Some(Arc::new(union)),
            params,
        })
    }

    /// Size of the alignment domain (m, or |∪ s^(i)| with PSU).
    pub fn domain_size(&self) -> usize {
        match &self.domain {
            Some(d) => d.len(),
            None => self.params.m as usize,
        }
    }

    /// Position of a model index within the alignment domain, if present.
    pub fn domain_index_of(&self, x: u64) -> Option<u64> {
        match &self.domain {
            Some(d) => d.binary_search(&x).ok().map(|p| p as u64),
            None => (x < self.params.m).then_some(x),
        }
    }

    /// Model index at a domain position.
    pub fn domain_value(&self, pos: usize) -> u64 {
        match &self.domain {
            Some(d) => d[pos],
            None => pos as u64,
        }
    }

    /// Maximum simple-table bin size Θ for this session.
    pub fn theta(&self) -> usize {
        self.simple.max_bin_size()
    }

    /// `⌈log Θ⌉` — the per-bin DPF depth bound the paper's formulas use.
    pub fn log_theta(&self) -> usize {
        crate::dpf::depth_for(self.theta().max(2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::CuckooParams;

    fn params(m: u64, k: usize) -> SessionParams {
        SessionParams {
            m,
            k,
            cuckoo: CuckooParams::default(),
        }
    }

    #[test]
    fn full_session_builds_aligned_table() {
        let s = Session::new_full(params(1 << 12, 128));
        assert_eq!(s.simple.num_bins(), s.params.num_bins());
        assert!(s.theta() > 0);
    }

    #[test]
    fn log_theta_covers_theta() {
        let s = Session::new_full(params(1 << 12, 64));
        assert!(1usize << s.log_theta() >= s.theta());
    }

    #[test]
    fn union_session_smaller_theta() {
        let p = params(1 << 14, 100);
        let full = Session::new_full(p.clone());
        let union: Vec<u64> = (0..(1u64 << 14)).step_by(16).collect();
        let small = Session::new_union(p, union).unwrap();
        assert!(small.theta() <= full.theta());
    }

    #[test]
    fn union_session_rejects_malformed_input() {
        // Unsorted, duplicated, and out-of-domain unions are release-mode
        // errors with actionable messages, not debug assertions.
        let unsorted = Session::new_union(params(1 << 10, 8), vec![5, 3, 9]);
        assert!(unsorted.unwrap_err().to_string().contains("strictly ascending"));
        let duplicated = Session::new_union(params(1 << 10, 8), vec![3, 3, 9]);
        assert!(duplicated.unwrap_err().to_string().contains("strictly ascending"));
        let outside = Session::new_union(params(1 << 10, 8), vec![3, 9, 1 << 10]);
        assert!(outside.unwrap_err().to_string().contains("outside the model domain"));
        assert!(Session::new_union(params(1 << 10, 8), vec![3, 9, (1 << 10) - 1]).is_ok());
        assert!(Session::new_union(params(1 << 10, 8), Vec::new()).is_ok());
    }
}
