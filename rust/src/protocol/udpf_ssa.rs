//! SSA with Updatable DPF for fixed submodels (§6, Table 2 row 3).
//!
//! When a client's selection `s^(i)` is fixed for a whole training task
//! (personalisation / HeteroFL-style setups), round 1 pays the full basic
//! SSA upload, and every later round pays only one `⌈log 𝔾⌉`-bit *hint*
//! per bin — `R^{(1)} = R(π_ssa)`, `R^{(>1)} = c`.

use super::aggregate::{AggregationEngine, EvalSource};
use super::retrieve::RetrievalEngine;
use super::session::Session;
use super::ssa::{sum_deltas_by_index, sum_duplicate_selections};
use crate::crypto::rng::Rng;
use crate::dpf::{self, EvalWorkspace};
use crate::group::Group;
use crate::hashing::{CuckooError, CuckooTable};
use crate::udpf::{self, Hint, UdpfClientState, UdpfKey};

/// Client state for a fixed-submodel training task.
pub struct UdpfSsaClient<G: Group> {
    cuckoo: CuckooTable,
    /// Per-bin U-DPF client state (bins then stash slots).
    states: Vec<UdpfClientState>,
    _marker: std::marker::PhantomData<G>,
}

/// One server's retained key set for a client.
pub struct UdpfSsaServerKeys<G: Group> {
    pub keys: Vec<UdpfKey<G>>,
}

/// Round-1 setup: build cuckoo table + U-DPF keys carrying the first
/// round's deltas (epoch 0). Returns the client handle and both servers'
/// key sets. Duplicate selections are summed, as in
/// [`super::ssa::client_update`].
pub fn client_setup<G: Group>(
    session: &Session,
    selections: &[u64],
    deltas: &[G],
    rng: &mut Rng,
) -> Result<(UdpfSsaClient<G>, UdpfSsaServerKeys<G>, UdpfSsaServerKeys<G>), CuckooError> {
    let (uniq, delta_of) = sum_duplicate_selections(selections, deltas);
    let cuckoo = CuckooTable::build_with_bins(
        &uniq,
        session.simple.num_bins(),
        &session.params.cuckoo,
        rng,
    )?;
    let simple = &session.simple;
    let stash_depth = dpf::depth_for(session.domain_size());

    let mut states = Vec::new();
    let mut keys0 = Vec::new();
    let mut keys1 = Vec::new();
    let mut emit = |depth: usize, point: Option<(u64, &G)>, rng: &mut Rng| {
        let (alpha, beta) = match point {
            Some((a, b)) => (a, b.clone()),
            None => (0, G::zero()),
        };
        let (k0, k1, st) = udpf::gen(depth, alpha, &beta, rng.gen_seed(), rng.gen_seed());
        states.push(st);
        keys0.push(k0);
        keys1.push(k1);
    };

    for (j, slot) in cuckoo.bins().iter().enumerate() {
        let depth = dpf::depth_for(simple.bin(j).len().max(2));
        let point = slot.map(|u| {
            // lint: allow(panic) — cuckoo occupants always land in the
            // matching simple bin (same hash family, Fig. 3 alignment).
            let pos = simple.position(j, u).expect("alignment invariant") as u64;
            (pos, &delta_of[&u])
        });
        emit(depth, point, rng);
    }
    for t in 0..session.params.cuckoo.sigma {
        let point = cuckoo.stash().get(t).map(|&u| {
            (
                // lint: allow(panic) — stash elements were range-checked
                // when the cuckoo table accepted the selections.
                session.domain_index_of(u).expect("stash element in domain"),
                &delta_of[&u],
            )
        });
        emit(stash_depth, point, rng);
    }

    Ok((
        UdpfSsaClient {
            cuckoo,
            states,
            _marker: std::marker::PhantomData,
        },
        UdpfSsaServerKeys { keys: keys0 },
        UdpfSsaServerKeys { keys: keys1 },
    ))
}

impl<G: Group> UdpfSsaClient<G> {
    /// Round `epoch ≥ 1`: produce one hint per bin/stash slot for the new
    /// deltas (dummy bins get β = 0 hints so the message shape is
    /// selection-independent). Duplicate selections are summed, as in
    /// [`client_setup`].
    pub fn epoch_hints(
        &self,
        session: &Session,
        selections: &[u64],
        deltas: &[G],
        epoch: u64,
    ) -> Vec<Hint<G>> {
        let delta_of = sum_deltas_by_index(selections, deltas);
        let num_bins = self.cuckoo.num_bins();
        let mut hints = Vec::with_capacity(self.states.len());
        for (slot, st) in self.states.iter().enumerate() {
            let beta = if slot < num_bins {
                match self.cuckoo.bins()[slot] {
                    Some(u) => delta_of[&u].clone(),
                    None => G::zero(),
                }
            } else {
                match self.cuckoo.stash().get(slot - num_bins) {
                    Some(u) => delta_of[u].clone(),
                    None => G::zero(),
                }
            };
            hints.push(udpf::next_hint(st, &beta, epoch));
        }
        let _ = session;
        hints
    }

    /// Total hint upload in bits for one epoch (the §6 `k·l` figure, up to
    /// the ε padding of dummy bins).
    pub fn hint_bits(&self) -> usize {
        self.states.len() * G::bit_len()
    }
}

impl<G: Group> UdpfSsaServerKeys<G> {
    /// Apply one epoch's hints in place.
    pub fn apply_hints(&mut self, hints: &[Hint<G>]) {
        assert_eq!(hints.len(), self.keys.len());
        for (k, h) in self.keys.iter_mut().zip(hints) {
            udpf::update(k, h);
        }
    }

    /// Evaluate + scatter this client's contribution for `epoch` into the
    /// global share accumulator — routed through the unified
    /// [`AggregationEngine`] (serial; see [`server_aggregate`] for the
    /// sharded multi-client path).
    pub fn aggregate_into(&self, session: &Session, epoch: u64, acc: &mut [G]) {
        AggregationEngine::serial().aggregate_into(
            session,
            &UdpfSource {
                clients: std::slice::from_ref(self),
                epoch,
            },
            acc,
        );
    }
}

/// Aggregate many clients' retained U-DPF key sets for `epoch` with the
/// unified engine (U-DPF keys are the engine's third input form, next to
/// materialised `DpfKey`s and zero-copy public parts).
pub fn server_aggregate<G: Group>(
    engine: &AggregationEngine,
    session: &Session,
    clients: &[UdpfSsaServerKeys<G>],
    epoch: u64,
) -> Vec<G> {
    engine.aggregate(session, &UdpfSource { clients, epoch })
}

/// Answer PSR-style retrieval queries for many clients' retained U-DPF
/// key sets at `epoch` — U-DPF keys are the retrieval engine's third
/// input form, next to materialised `DpfKey`s and zero-copy public
/// parts. A fixed-submodel client whose keys carry β = 1 payloads
/// retrieves its current submodel every round without re-uploading key
/// material. Returns one `B + σ` answer row per client.
pub fn server_answer<G: Group>(
    engine: &RetrievalEngine,
    session: &Session,
    weights: &[G],
    clients: &[UdpfSsaServerKeys<G>],
    epoch: u64,
) -> Vec<Vec<G>> {
    engine.answer_batch(session, weights, &UdpfSource { clients, epoch })
}

/// Engine input form over epoch-keyed U-DPF keys.
struct UdpfSource<'a, G: Group> {
    clients: &'a [UdpfSsaServerKeys<G>],
    epoch: u64,
}

impl<G: Group> EvalSource<G> for UdpfSource<'_, G> {
    fn num_clients(&self) -> usize {
        self.clients.len()
    }

    fn eval_slot(
        &self,
        client: usize,
        slot: usize,
        num_points: usize,
        _ws: &mut EvalWorkspace,
        out: &mut Vec<G>,
    ) {
        // U-DPF evaluation re-hashes every leaf under the epoch oracle, so
        // it has no allocation-free variant yet; the engine's buffer is
        // simply replaced.
        *out = udpf::full_eval(&self.clients[client].keys[slot], num_points, self.epoch);
    }

    fn assert_shape(&self, slots: usize) {
        for c in self.clients {
            assert_eq!(c.keys.len(), slots, "U-DPF key count");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::CuckooParams;
    use crate::protocol::session::SessionParams;
    use crate::protocol::ssa;

    fn session(m: u64, k: usize) -> Session {
        Session::new_full(SessionParams {
            m,
            k,
            cuckoo: CuckooParams::default(),
        })
    }

    #[test]
    fn multi_epoch_fixed_submodel() {
        let s = session(512, 16);
        let mut rng = Rng::new(120);
        let sel = rng.sample_distinct(16, 512);
        let d0: Vec<u64> = (0..16).map(|i| 100 + i).collect();
        let (client, mut sk0, mut sk1) = client_setup(&s, &sel, &d0, &mut rng).unwrap();

        // Epoch 0 straight from setup.
        let mut a0 = vec![0u64; 512];
        let mut a1 = vec![0u64; 512];
        sk0.aggregate_into(&s, 0, &mut a0);
        sk1.aggregate_into(&s, 0, &mut a1);
        let dw = ssa::reconstruct(&a0, &a1);
        for (i, &x) in sel.iter().enumerate() {
            assert_eq!(dw[x as usize], d0[i]);
        }

        // Epochs 1..4 via hints only.
        for epoch in 1..4u64 {
            let de: Vec<u64> = (0..16).map(|i| epoch * 1000 + i).collect();
            let hints = client.epoch_hints(&s, &sel, &de, epoch);
            assert_eq!(hints.len(), s.simple.num_bins());
            sk0.apply_hints(&hints);
            sk1.apply_hints(&hints);
            let mut a0 = vec![0u64; 512];
            let mut a1 = vec![0u64; 512];
            sk0.aggregate_into(&s, epoch, &mut a0);
            sk1.aggregate_into(&s, epoch, &mut a1);
            let dw = ssa::reconstruct(&a0, &a1);
            for x in 0..512u64 {
                match sel.iter().position(|&sl| sl == x) {
                    Some(i) => assert_eq!(dw[x as usize], de[i], "epoch {epoch} x {x}"),
                    None => assert_eq!(dw[x as usize], 0, "epoch {epoch} x {x}"),
                }
            }
        }
    }

    #[test]
    fn engine_aggregate_matches_per_client_into() {
        let s = session(512, 16);
        let mut rng = Rng::new(123);
        let mut all0 = Vec::new();
        for c in 0..4u64 {
            let sel = rng.sample_distinct(16, 512);
            let d: Vec<u64> = sel.iter().map(|&x| x + c + 1).collect();
            let (_cl, sk0, _sk1) = client_setup(&s, &sel, &d, &mut rng).unwrap();
            all0.push(sk0);
        }
        let mut serial = vec![0u64; 512];
        for sk in &all0 {
            sk.aggregate_into(&s, 0, &mut serial);
        }
        for t in [1usize, 3, 8] {
            let engine = AggregationEngine::new(t);
            assert_eq!(server_aggregate(&engine, &s, &all0, 0), serial, "{t} threads");
        }
    }

    #[test]
    fn retrieval_over_udpf_keys_matches_at_every_width() {
        // U-DPF keys carrying β = 1 serve as fixed-submodel retrieval
        // queries; the read engine must answer them consistently at every
        // worker count, and the two servers' answers must reconstruct.
        let s = session(512, 16);
        let mut rng = Rng::new(124);
        let w: Vec<u64> = (0..512).map(|_| rng.next_u64()).collect();
        let mut clients = Vec::new();
        let mut sk0s = Vec::new();
        let mut sk1s = Vec::new();
        for _ in 0..3 {
            let sel = rng.sample_distinct(16, 512);
            let ones = vec![1u64; 16];
            let (cl, sk0, sk1) = client_setup(&s, &sel, &ones, &mut rng).unwrap();
            clients.push((sel, cl));
            sk0s.push(sk0);
            sk1s.push(sk1);
        }
        let serial0 = server_answer(&RetrievalEngine::serial(), &s, &w, &sk0s, 0);
        for t in [2usize, 8, 64] {
            assert_eq!(
                server_answer(&RetrievalEngine::new(t), &s, &w, &sk0s, 0),
                serial0,
                "{t} threads"
            );
        }
        let a1 = server_answer(&RetrievalEngine::new(3), &s, &w, &sk1s, 0);
        for (c, (sel, cl)) in clients.iter().enumerate() {
            for &u in sel {
                let slot = match cl.cuckoo.locate(u).expect("selection present") {
                    Ok(bin) => bin,
                    Err(st) => s.simple.num_bins() + st,
                };
                assert_eq!(
                    serial0[c][slot].wrapping_add(a1[c][slot]),
                    w[u as usize],
                    "client {c} index {u}"
                );
            }
        }
    }

    #[test]
    fn hint_size_is_k_l() {
        let s = session(1 << 12, 64);
        let mut rng = Rng::new(121);
        let sel = rng.sample_distinct(64, 1 << 12);
        let d: Vec<u64> = vec![1; 64];
        let (client, _k0, _k1) = client_setup(&s, &sel, &d, &mut rng).unwrap();
        // εk bins · l bits ≈ the paper's k·l (ε-padded).
        assert_eq!(client.hint_bits(), s.simple.num_bins() * 64);
    }

    #[test]
    fn hints_much_smaller_than_rekeying() {
        let s = session(1 << 12, 64);
        let mut rng = Rng::new(122);
        let sel = rng.sample_distinct(64, 1 << 12);
        let d: Vec<u64> = vec![1; 64];
        let (client, _sk0, _sk1) = client_setup(&s, &sel, &d, &mut rng).unwrap();
        let rekey_bits: usize = s.simple.num_bins() * (s.log_theta() * 130 + 64) + 256;
        assert!(client.hint_bits() * 10 < rekey_bits);
    }
}
