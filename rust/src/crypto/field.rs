//! The prime field 𝔽_p with p = 2^61 − 1 (Mersenne), used by the
//! malicious-secure sketching check (§3.1, following Boneh et al. \[9\]).
//!
//! Sketching works over a prime field (it needs multiplicative structure);
//! the DPF payload group stays a ring. 2^61−1 keeps products inside u128.

/// p = 2^61 − 1.
pub const P: u64 = (1 << 61) - 1;

/// Field element of 𝔽_{2^61−1}, always kept reduced (the wrapped value
/// is the canonical representative in `[0, p)`).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct Fp(pub u64);

#[inline]
fn reduce(x: u128) -> u64 {
    // x < 2^122; fold twice.
    let lo = (x & P as u128) as u64;
    let hi = (x >> 61) as u64;
    let mut r = lo.wrapping_add(hi & P).wrapping_add(hi >> 61);
    if r >= P {
        r -= P;
    }
    if r >= P {
        r -= P;
    }
    r
}

impl Fp {
    /// Canonical embedding of a u64.
    pub fn new(x: u64) -> Self {
        Fp(reduce(x as u128))
    }
    /// Additive identity.
    pub fn zero() -> Self {
        Fp(0)
    }
    /// Multiplicative identity.
    pub fn one() -> Self {
        Fp(1)
    }
    /// Field addition.
    pub fn add(self, o: Fp) -> Fp {
        let mut r = self.0 + o.0;
        if r >= P {
            r -= P;
        }
        Fp(r)
    }
    /// Field subtraction.
    pub fn sub(self, o: Fp) -> Fp {
        Fp(if self.0 >= o.0 {
            self.0 - o.0
        } else {
            self.0 + P - o.0
        })
    }
    /// Field negation.
    pub fn neg(self) -> Fp {
        if self.0 == 0 {
            Fp(0)
        } else {
            Fp(P - self.0)
        }
    }
    /// Field multiplication.
    pub fn mul(self, o: Fp) -> Fp {
        Fp(reduce(self.0 as u128 * o.0 as u128))
    }
    /// Exponentiation by squaring.
    pub fn pow(self, mut e: u64) -> Fp {
        let mut base = self;
        let mut acc = Fp::one();
        while e > 0 {
            if e & 1 == 1 {
                acc = acc.mul(base);
            }
            base = base.mul(base);
            e >>= 1;
        }
        acc
    }
    /// Multiplicative inverse (Fermat).
    pub fn inv(self) -> Fp {
        assert_ne!(self.0, 0, "inverse of zero");
        self.pow(P - 2)
    }
    /// Uniform field element from an RNG.
    pub fn random(rng: &mut super::rng::Rng) -> Fp {
        Fp(rng.gen_range(P))
    }
}

// 𝔽_p is itself a finite Abelian group — DPF payloads over it are what
// the malicious-secure sketching check (§3.1) verifies, since additive
// shares must live in the same algebra the sketch computes in.
impl crate::group::Group for Fp {
    fn zero() -> Self {
        Fp(0)
    }
    fn add(&self, other: &Self) -> Self {
        Fp::add(*self, *other)
    }
    fn neg(&self) -> Self {
        Fp::neg(*self)
    }
    fn ring_mul(&self, other: &Self) -> Self {
        self.mul(*other)
    }
    fn one() -> Self {
        Fp::one()
    }
    fn convert(seed: &[u8; 16]) -> Self {
        Fp::new(u64::from_le_bytes(seed[..8].try_into().unwrap()))
    }
    fn bit_len() -> usize {
        61
    }
    fn byte_len() -> usize {
        8
    }
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.0.to_le_bytes());
    }
    fn decode(bytes: &[u8]) -> Option<Self> {
        Some(Fp::new(u64::from_le_bytes(bytes.get(..8)?.try_into().ok()?)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;

    #[test]
    fn ring_axioms() {
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let a = Fp::random(&mut rng);
            let b = Fp::random(&mut rng);
            let c = Fp::random(&mut rng);
            assert_eq!(a.add(b), b.add(a));
            assert_eq!(a.mul(b), b.mul(a));
            assert_eq!(a.mul(b.add(c)), a.mul(b).add(a.mul(c)));
            assert_eq!(a.sub(a), Fp::zero());
            assert_eq!(a.add(a.neg()), Fp::zero());
        }
    }

    #[test]
    fn inverse() {
        let mut rng = Rng::new(12);
        for _ in 0..50 {
            let a = Fp::random(&mut rng);
            if a.0 != 0 {
                assert_eq!(a.mul(a.inv()), Fp::one());
            }
        }
    }

    #[test]
    fn reduce_edge_cases() {
        assert_eq!(Fp::new(P).0, 0);
        assert_eq!(Fp::new(P + 1).0, 1);
        assert_eq!(Fp::new(u64::MAX).0, reduce(u64::MAX as u128));
        assert_eq!(Fp(P - 1).add(Fp(1)).0, 0);
        assert_eq!(Fp(P - 1).mul(Fp(P - 1)), Fp::one()); // (-1)^2 = 1
    }
}
