//! Deterministic, seedable RNG (xoshiro256** seeded via splitmix64).
//!
//! Everything in the repo that needs randomness — DPF root seeds, cuckoo
//! hash keys, synthetic data, workload generators — draws from this, so
//! experiments are exactly reproducible from a CLI seed.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator deterministically.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next 64 uniform bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire reduction; bound > 0).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// A fresh λ-bit seed.
    pub fn gen_seed(&mut self) -> [u8; 16] {
        let mut out = [0u8; 16];
        out[..8].copy_from_slice(&self.next_u64().to_le_bytes());
        out[8..].copy_from_slice(&self.next_u64().to_le_bytes());
        out
    }

    /// Sample `k` distinct values from `[0, m)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, k: usize, m: u64) -> Vec<u64> {
        assert!(k as u64 <= m, "cannot sample {k} distinct from {m}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (m - k as u64)..m {
            let t = self.gen_range(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.gen_range(17) < 17);
        }
    }

    #[test]
    fn distinct_sampling() {
        let mut r = Rng::new(4);
        let s = r.sample_distinct(100, 1000);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 100);
        assert!(s.iter().all(|&x| x < 1000));
        // Exhaustive case.
        let all = r.sample_distinct(16, 16);
        assert_eq!(all.iter().collect::<std::collections::HashSet<_>>().len(), 16);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gen_normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
