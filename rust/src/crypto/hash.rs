//! Keyed hash functions for cuckoo / simple hashing.
//!
//! The η hash functions `h_d : Z_m → Z_B` are instantiated as independently
//! keyed 64-bit finalisation mixers. All parties derive the same keys from
//! a public per-round seed, which is what keeps the client's cuckoo table
//! and the servers' simple table *aligned* (§4).

/// One keyed hash function `h : u64 → [0, range)`.
#[derive(Clone, Copy, Debug)]
pub struct HashFn {
    k0: u64,
    k1: u64,
    range: u64,
}

#[inline]
fn mix(mut x: u64) -> u64 {
    // murmur3 / splitmix finaliser — full avalanche.
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    x ^= x >> 33;
    x
}

impl HashFn {
    /// Derive a keyed hash with output range `[0, range)`.
    pub fn new(k0: u64, k1: u64, range: u64) -> Self {
        assert!(range > 0);
        HashFn { k0, k1, range }
    }

    /// Evaluate the hash.
    #[inline]
    pub fn eval(&self, x: u64) -> u64 {
        let h = mix(x.wrapping_add(self.k0)) ^ mix(x.rotate_left(32) ^ self.k1);
        ((mix(h) as u128 * self.range as u128) >> 64) as u64
    }

    /// Output range.
    pub fn range(&self) -> u64 {
        self.range
    }
}

/// Derive the η aligned hash functions from a public seed.
pub fn derive_hash_fns(seed: u64, eta: usize, range: u64) -> Vec<HashFn> {
    let mut rng = super::rng::Rng::new(seed ^ 0x9d5f_3c2a_17b4_e681);
    (0..eta)
        .map(|_| HashFn::new(rng.next_u64(), rng.next_u64(), range))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_range_and_deterministic() {
        let h = HashFn::new(1, 2, 97);
        for x in 0..1000 {
            let v = h.eval(x);
            assert!(v < 97);
            assert_eq!(v, h.eval(x));
        }
    }

    #[test]
    fn keys_give_independent_functions() {
        let fns = derive_hash_fns(42, 3, 1 << 20);
        let x = 12345u64;
        assert_ne!(fns[0].eval(x), fns[1].eval(x));
        // Same seed → same functions (alignment property).
        let fns2 = derive_hash_fns(42, 3, 1 << 20);
        for (a, b) in fns.iter().zip(&fns2) {
            for x in 0..100 {
                assert_eq!(a.eval(x), b.eval(x));
            }
        }
    }

    #[test]
    fn roughly_uniform() {
        let h = HashFn::new(7, 8, 16);
        let mut counts = [0usize; 16];
        let n = 160_000;
        for x in 0..n {
            counts[h.eval(x as u64) as usize] += 1;
        }
        let expect = n / 16;
        for &c in &counts {
            assert!(
                (c as f64 - expect as f64).abs() < expect as f64 * 0.05,
                "bucket count {c} vs {expect}"
            );
        }
    }
}
