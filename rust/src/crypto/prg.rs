//! AES-128 based length-doubling PRG for the GGM tree (BGI16 §3), plus a
//! CTR-mode stream expander for `Convert` into wide groups.
//!
//! `G(s) = (AES_{K0}(s) ⊕ s, AES_{K1}(s) ⊕ s)` — the fixed-key
//! Matyas–Meyer–Oseas construction. The two fixed keys are expanded once
//! (`once_cell`-free: `std::sync::OnceLock`), so each tree level costs two
//! AES block calls, hardware-accelerated through the `aes` crate.
//! Control bits `t_L, t_R` are taken from the low bit of each child seed
//! (and then zeroed), exactly as in the reference DPF implementations.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;
use std::sync::OnceLock;

/// λ-bit PRG seed.
pub type Seed = [u8; 16];

fn fixed_ciphers() -> &'static (Aes128, Aes128) {
    static CIPHERS: OnceLock<(Aes128, Aes128)> = OnceLock::new();
    CIPHERS.get_or_init(|| {
        // Nothing-up-my-sleeve fixed keys (digits of π and e).
        let k0 = [
            0x24, 0x3f, 0x6a, 0x88, 0x85, 0xa3, 0x08, 0xd3, 0x13, 0x19, 0x8a, 0x2e, 0x03, 0x70,
            0x73, 0x44,
        ];
        let k1 = [
            0xa4, 0x09, 0x38, 0x22, 0x29, 0x9f, 0x31, 0xd0, 0x08, 0x2e, 0xfa, 0x98, 0xec, 0x4e,
            0x6c, 0x89,
        ];
        (
            Aes128::new_from_slice(&k0).unwrap(),
            Aes128::new_from_slice(&k1).unwrap(),
        )
    })
}

/// One child of the GGM double: seed + control bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Child {
    /// The child's λ-bit seed (low bit of byte 0 cleared).
    pub seed: Seed,
    /// The control bit extracted from the raw child seed.
    pub t: bool,
}

/// Length-doubling PRG: seed ↦ (left child, right child).
#[inline]
pub fn double(seed: &Seed) -> (Child, Child) {
    let (c0, c1) = fixed_ciphers();
    let mut l = aes::Block::clone_from_slice(seed);
    let mut r = aes::Block::clone_from_slice(seed);
    c0.encrypt_block(&mut l);
    c1.encrypt_block(&mut r);
    let mut ls: Seed = l.into();
    let mut rs: Seed = r.into();
    for i in 0..16 {
        ls[i] ^= seed[i];
        rs[i] ^= seed[i];
    }
    let tl = ls[0] & 1 == 1;
    let tr = rs[0] & 1 == 1;
    ls[0] &= 0xfe;
    rs[0] &= 0xfe;
    (Child { seed: ls, t: tl }, Child { seed: rs, t: tr })
}

/// Expand only one child — same output as `double(..).0/.1` but a single
/// AES call. Used by the point-wise `Eval` walk.
#[inline]
pub fn expand_one(seed: &Seed, right: bool) -> Child {
    let (c0, c1) = fixed_ciphers();
    let mut b = aes::Block::clone_from_slice(seed);
    if right {
        c1.encrypt_block(&mut b);
    } else {
        c0.encrypt_block(&mut b);
    }
    let mut s: Seed = b.into();
    for i in 0..16 {
        s[i] ^= seed[i];
    }
    let t = s[0] & 1 == 1;
    s[0] &= 0xfe;
    Child { seed: s, t }
}

/// Batched one-sided expansion: encrypt many independent seeds with the
/// fixed key for `right ∈ {left, right}` in one call, letting the AES-NI
/// units pipeline across blocks (the full-domain-eval hot path expands an
/// entire GGM level at once). `out[i]` = the child of `seeds[i]`.
pub fn expand_many(seeds: &[Seed], right: bool, out: &mut Vec<Child>) {
    let (c0, c1) = fixed_ciphers();
    let cipher = if right { c1 } else { c0 };
    out.clear();
    out.reserve(seeds.len());
    // Stack-resident chunk buffer: no heap traffic on the hot path, and
    // `encrypt_blocks` pipelines the whole chunk through AES-NI.
    const CHUNK: usize = 64;
    let mut buf = [aes::Block::default(); CHUNK];
    for chunk in seeds.chunks(CHUNK) {
        for (b, s) in buf.iter_mut().zip(chunk) {
            b.copy_from_slice(s);
        }
        cipher.encrypt_blocks(&mut buf[..chunk.len()]);
        for (b, seed) in buf.iter().zip(chunk) {
            let mut s: Seed = (*b).into();
            for i in 0..16 {
                s[i] ^= seed[i];
            }
            let t = s[0] & 1 == 1;
            s[0] &= 0xfe;
            out.push(Child { seed: s, t });
        }
    }
}

/// AES-CTR stream expansion of a seed to `n_bytes` pseudorandom bytes
/// (the `Convert` map for wide groups, and the master-seed → per-bin seed
/// derivation PRF).
pub fn expand_stream(seed: &Seed, n_bytes: usize) -> Vec<u8> {
    let cipher = Aes128::new_from_slice(seed).unwrap();
    let mut out = vec![0u8; n_bytes.div_ceil(16) * 16];
    for (ctr, chunk) in out.chunks_exact_mut(16).enumerate() {
        let mut block = [0u8; 16];
        block[..8].copy_from_slice(&(ctr as u64).to_le_bytes());
        let mut b = aes::Block::clone_from_slice(&block);
        cipher.encrypt_block(&mut b);
        chunk.copy_from_slice(&b);
    }
    out.truncate(n_bytes);
    out
}

/// PRF(msk, i) → λ-bit seed, used to derive per-bin DPF root seeds from a
/// single master seed (§4 "Master seed for each client").
pub fn prf_seed(master: &Seed, index: u64) -> Seed {
    let cipher = Aes128::new_from_slice(master).unwrap();
    let mut block = [0u8; 16];
    block[..8].copy_from_slice(&index.to_le_bytes());
    let mut b = aes::Block::clone_from_slice(&block);
    cipher.encrypt_block(&mut b);
    b.into()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn double_deterministic() {
        let s = [42u8; 16];
        assert_eq!(double(&s), double(&s));
    }

    #[test]
    fn double_children_differ_and_low_bit_cleared() {
        let s = [1u8; 16];
        let (l, r) = double(&s);
        assert_ne!(l.seed, r.seed);
        assert_eq!(l.seed[0] & 1, 0);
        assert_eq!(r.seed[0] & 1, 0);
    }

    #[test]
    fn expand_one_matches_double() {
        let s = [9u8; 16];
        let (l, r) = double(&s);
        assert_eq!(expand_one(&s, false), l);
        assert_eq!(expand_one(&s, true), r);
    }

    #[test]
    fn seed_sensitivity() {
        let a = [0u8; 16];
        let mut b = a;
        b[15] = 1;
        assert_ne!(double(&a).0.seed, double(&b).0.seed);
    }

    #[test]
    fn stream_lengths_and_determinism() {
        let s = [7u8; 16];
        for n in [0usize, 1, 15, 16, 17, 100] {
            assert_eq!(expand_stream(&s, n).len(), n);
        }
        assert_eq!(expand_stream(&s, 64), expand_stream(&s, 64));
        assert_eq!(expand_stream(&s, 64)[..32], expand_stream(&s, 32)[..]);
    }

    #[test]
    fn prf_distinct_indices() {
        let msk = [3u8; 16];
        assert_ne!(prf_seed(&msk, 0), prf_seed(&msk, 1));
        assert_eq!(prf_seed(&msk, 5), prf_seed(&msk, 5));
    }
}
