//! [`Sensitive<T>`] — a wrapper for secret key material.
//!
//! The paper's security argument (§5) rests on DPF root seeds and master
//! seeds never leaving the party that owns them: the correction words are
//! public (identical for both servers), but a root seed reconstructs the
//! whole point function. `Sensitive<T>` makes that boundary a *type*:
//!
//! * **Redacted `Debug`** — `format!("{:?}", seed)` prints
//!   `Sensitive(<redacted>)`, so key material cannot leak through logs,
//!   panic messages, or `dbg!` left in by accident. The secret types
//!   themselves (see the `SECRET_TYPES` manifest in `xtask`) do not
//!   implement `Debug`/`Display` at all; this wrapper is the only piece
//!   of them that can ever be formatted.
//! * **Best-effort zeroize-on-drop** — the backing bytes are overwritten
//!   with zeros when the wrapper is dropped, through the [`Zeroize`]
//!   trait. The write is routed through [`std::hint::black_box`] to
//!   discourage dead-store elimination. This is *best effort* (the crate
//!   is `#![forbid(unsafe_code)]`, so no volatile writes or mlock): moves
//!   and clones of the plain inner value still leave copies behind, which
//!   is why the seeds live *inside* the wrapper for their whole lifetime.
//!
//! Access to the inner value is explicit: deref (`*seed` / `&seed`) or
//! [`Sensitive::expose`]. Both read as "I am touching key material here".

use std::ops::{Deref, DerefMut};

/// Overwrite `self` with a neutral value, discouraging the optimiser from
/// eliding the store. Implemented for the fixed-size byte arrays the
/// crate's seeds are made of.
pub trait Zeroize {
    /// Overwrite the contents with zeros (best effort).
    fn zeroize(&mut self);
}

impl<const N: usize> Zeroize for [u8; N] {
    fn zeroize(&mut self) {
        for b in self.iter_mut() {
            *b = 0;
        }
        // Pretend the zeroed bytes are observed so the stores above are
        // not dead: without unsafe/volatile this is the strongest
        // guarantee available on stable.
        std::hint::black_box(&*self);
    }
}

impl<T: Zeroize, const N: usize> Zeroize for [T; N] {
    fn zeroize(&mut self) {
        for x in self.iter_mut() {
            x.zeroize();
        }
    }
}

/// Secret key material. See the module docs for the contract.
#[derive(Clone, PartialEq, Eq)]
pub struct Sensitive<T: Zeroize>(T);

impl<T: Zeroize> Sensitive<T> {
    /// Wrap a secret. The value is zeroized when the wrapper drops.
    pub fn new(value: T) -> Self {
        Sensitive(value)
    }

    /// Borrow the secret. Equivalent to deref, but greppable.
    pub fn expose(&self) -> &T {
        &self.0
    }
}

impl<T: Zeroize> From<T> for Sensitive<T> {
    fn from(value: T) -> Self {
        Sensitive(value)
    }
}

impl<T: Zeroize> Deref for Sensitive<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T: Zeroize> DerefMut for Sensitive<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

impl<T: Zeroize> std::fmt::Debug for Sensitive<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Sensitive(<redacted>)")
    }
}

impl<T: Zeroize> Drop for Sensitive<T> {
    fn drop(&mut self) {
        self.0.zeroize();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    #[test]
    fn debug_is_redacted() {
        let s = Sensitive::new([0xABu8; 16]);
        let shown = format!("{s:?}");
        assert_eq!(shown, "Sensitive(<redacted>)");
        assert!(!shown.contains("AB") && !shown.contains("171"), "{shown}");
    }

    #[test]
    fn zeroize_clears_byte_arrays() {
        let mut bytes = [0x5Au8; 16];
        bytes.zeroize();
        assert_eq!(bytes, [0u8; 16]);
        let mut nested = [[0x5Au8; 16]; 2];
        nested.zeroize();
        assert_eq!(nested, [[0u8; 16]; 2]);
    }

    /// Observable stand-in for key material: records that its buffer was
    /// zeroized (the only safe way to watch a drop without reading freed
    /// memory).
    struct Probe {
        data: [u8; 16],
        wiped: Arc<AtomicBool>,
    }

    impl Zeroize for Probe {
        fn zeroize(&mut self) {
            self.data.zeroize();
            self.wiped.store(self.data == [0u8; 16], Ordering::SeqCst);
        }
    }

    #[test]
    fn drop_zeroizes_the_backing_buffer() {
        let wiped = Arc::new(AtomicBool::new(false));
        let probe = Sensitive::new(Probe {
            data: [7u8; 16],
            wiped: Arc::clone(&wiped),
        });
        assert!(!wiped.load(Ordering::SeqCst));
        drop(probe);
        assert!(wiped.load(Ordering::SeqCst), "drop must zeroize the buffer");
    }

    #[test]
    fn deref_and_expose_agree() {
        let s = Sensitive::new([9u8; 16]);
        assert_eq!(*s, [9u8; 16]);
        assert_eq!(s.expose(), &[9u8; 16]);
        let copied: [u8; 16] = *s; // Seed is Copy; deref-copy is the idiom
        assert_eq!(copied, [9u8; 16]);
    }

    #[test]
    fn clone_is_independent() {
        let a = Sensitive::new([3u8; 16]);
        let b = a.clone();
        drop(a);
        assert_eq!(*b, [3u8; 16]);
    }
}
