//! Light-weight symmetric primitives — the only cryptography the paper
//! needs (its headline claim: no public-key operations on the round path).

pub mod field;
pub mod hash;
pub mod prg;
pub mod rng;
pub mod sensitive;

pub use sensitive::Sensitive;
