//! Malicious-secure sketching (§3.1, following Boneh et al. \[9\]).
//!
//! A malicious *client* can upload DPF keys whose full-domain evaluation is
//! not a point function at all (e.g. two non-zero positions), poisoning the
//! aggregate. The sketching check lets the two servers verify, from their
//! additive shares `v_0, v_1` of the evaluation vector `v = v_0 + v_1`,
//! that `v = β·e_α` for *some* `α` — touching each share once and
//! exchanging O(1) field elements.
//!
//! Identity (over 𝔽_p, p = 2^61−1): sample random `r ∈ 𝔽_p^Θ`, put
//! `z = ⟨r, v⟩` and `z* = ⟨r∘r, v⟩`. If `v = β·e_α` then
//! `z² − β·z* = β²r_α² − β²r_α² = 0`; if `v` has ≥2 non-zeros (or the wrong
//! β) the identity fails except with probability ≤ 2/p over `r`.
//!
//! The cross-term `z_0·z_1` in `z² = z_0² + 2z_0z_1 + z_1²` needs one
//! secure multiplication between the servers. Following the paper — which
//! *omits the sketching round from its evaluation* ("we omit the sketching
//! check by servers") — we expose the check through an idealised
//! [`SecureMul`] oracle (in-process Beaver triple dealt from server-shared
//! randomness that the client never sees). Soundness and the communication
//! account (3 field elements per verification) match \[9\]; the full
//! extractable-DPF machinery is out of the paper's reproduced scope.

use crate::crypto::field::Fp;
use crate::crypto::rng::Rng;

/// Idealised two-server secure multiplication: holds Beaver triples dealt
/// from randomness shared by the two servers only.
pub struct SecureMul {
    rng: Rng,
}

impl SecureMul {
    /// `seed` is the server-server shared randomness (unknown to clients).
    pub fn new(seed: u64) -> Self {
        SecureMul { rng: Rng::new(seed) }
    }

    /// Multiply secret-shared `x = x0+x1`, `y = y0+y1`, returning shares of
    /// `x·y`. Models one Beaver-triple round (2 field elements each way).
    pub fn mul(&mut self, x0: Fp, x1: Fp, y0: Fp, y1: Fp) -> (Fp, Fp) {
        // Deal a triple (a, b, c=ab) as additive shares.
        let a = Fp::random(&mut self.rng);
        let b = Fp::random(&mut self.rng);
        let c = a.mul(b);
        let a0 = Fp::random(&mut self.rng);
        let b0 = Fp::random(&mut self.rng);
        let c0 = Fp::random(&mut self.rng);
        let (a1, b1, c1) = (a.sub(a0), b.sub(b0), c.sub(c0));
        // Open d = x−a, e = y−b (the values actually exchanged).
        let d = x0.add(x1).sub(a);
        let e = y0.add(y1).sub(b);
        // Shares of xy = c + d·b + e·a + d·e (d·e assigned to party 0).
        let z0 = c0.add(d.mul(b0)).add(e.mul(a0)).add(d.mul(e));
        let z1 = c1.add(d.mul(b1)).add(e.mul(a1));
        (z0, z1)
    }
}

/// One server's sketch of its share vector: `z_b = ⟨r, v_b⟩`,
/// `z*_b = ⟨r∘r, v_b⟩`.
#[derive(Clone, Copy, Debug)]
pub struct Sketch {
    pub z: Fp,
    pub zs: Fp,
}

/// Compute one server's sketch of its evaluation-vector share under the
/// coins `r` (sampled *after* the keys are fixed). Shares must live in
/// 𝔽_p — verified keys carry their payload over [`Fp`] (the paper's 𝔾 is
/// generic; sketching needs the field's multiplicative structure, exactly
/// as in Boneh et al. \[9\]).
pub fn sketch_share(share: &[Fp], r: &[Fp]) -> Sketch {
    assert_eq!(share.len(), r.len());
    let mut z = Fp::zero();
    let mut zs = Fp::zero();
    for (x, ri) in share.iter().zip(r) {
        z = z.add(ri.mul(*x));
        zs = zs.add(ri.mul(*ri).mul(*x));
    }
    Sketch { z, zs }
}

/// Sample the per-verification public coins.
pub fn sample_coins(rng: &mut Rng, theta: usize) -> Vec<Fp> {
    (0..theta).map(|_| Fp::random(rng)).collect()
}

/// Joint verification that `v_0 + v_1 = β·e_α` for some α, given each
/// server's sketch and a claimed payload β (β=1 for PSR bins; for SSA the
/// servers check the *unit-vector times secret β* variant by verifying
/// `z²  = z*·(z₊)` with β recovered obliviously — here we take the public-β
/// form used for PSR and the β-agnostic form `z·z − z*·β̂ = 0` with β̂
/// reconstructed from a second random projection for SSA).
pub fn verify(mul: &mut SecureMul, s0: Sketch, s1: Sketch, beta: Fp) -> bool {
    // Shares of z² via one secure multiplication.
    let (q0, q1) = mul.mul(s0.z, s1.z, s0.z, s1.z);
    // Shares of z² − β·z*.
    let d0 = q0.sub(beta.mul(s0.zs));
    let d1 = q1.sub(beta.mul(s1.zs));
    // Servers open the (blinded-zero) difference.
    d0.add(d1) == Fp::zero()
}

/// β-agnostic verification for SSA payloads: checks `z² = z*·β` where β is
/// itself reconstructed from the shares' third projection `⟨1, v⟩ = β`.
/// Requires only that the vector be `β·e_α` for *some* (α, β).
pub fn verify_unknown_beta(
    mul: &mut SecureMul,
    share0: &[Fp],
    share1: &[Fp],
    r: &[Fp],
) -> bool {
    let s0 = sketch_share(share0, r);
    let s1 = sketch_share(share1, r);
    // β shares via the all-ones projection.
    let b0 = share0.iter().fold(Fp::zero(), |acc, v| acc.add(*v));
    let b1 = share1.iter().fold(Fp::zero(), |acc, v| acc.add(*v));
    let (q0, q1) = mul.mul(s0.z, s1.z, s0.z, s1.z); // z²
    let (p0, p1) = mul.mul(b0, b1, s0.zs, s1.zs); // β·z*
    q0.sub(p0).add(q1.sub(p1)) == Fp::zero()
}

/// Verify every bin of one client's SSA upload (𝔽_p payloads): the two
/// servers full-domain-evaluate each bin, sketch their shares under fresh
/// public coins, and run the β-agnostic degree-2 check. Returns `false`
/// if ANY bin fails — the §2.2 malicious-client functionality: a client
/// whose vote predicate rejects is excluded from the aggregate.
pub fn verify_client_bins(
    session: &crate::protocol::Session,
    keys0: &[crate::dpf::DpfKey<Fp>],
    keys1: &[crate::dpf::DpfKey<Fp>],
    rng: &mut Rng,
    mul: &mut SecureMul,
) -> bool {
    assert_eq!(keys0.len(), keys1.len());
    let num_bins = session.simple.num_bins();
    for (j, (k0, k1)) in keys0.iter().zip(keys1).enumerate() {
        let theta = if j < num_bins {
            session.simple.bin(j).len().max(1)
        } else {
            session.domain_size()
        };
        let v0 = crate::dpf::full_eval(k0, theta);
        let v1 = crate::dpf::full_eval(k1, theta);
        let r = sample_coins(rng, theta);
        if !verify_unknown_beta(mul, &v0, &v1, &r) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpf::{full_eval, Dpf};

    fn shares_for(alpha: u64, beta: u64, theta: usize, seed: u64) -> (Vec<Fp>, Vec<Fp>) {
        let mut rng = Rng::new(seed);
        let depth = crate::dpf::depth_for(theta);
        let (k0, k1) =
            Dpf::<Fp>::gen(depth, alpha, &Fp::new(beta), rng.gen_seed(), rng.gen_seed());
        (full_eval(&k0, theta), full_eval(&k1, theta))
    }

    #[test]
    fn secure_mul_is_correct() {
        let mut mul = SecureMul::new(77);
        let mut rng = Rng::new(78);
        for _ in 0..50 {
            let x = Fp::random(&mut rng);
            let y = Fp::random(&mut rng);
            let x0 = Fp::random(&mut rng);
            let y0 = Fp::random(&mut rng);
            let (z0, z1) = mul.mul(x0, x.sub(x0), y0, y.sub(y0));
            assert_eq!(z0.add(z1), x.mul(y));
        }
    }

    #[test]
    fn honest_unit_vector_passes() {
        let (v0, v1) = shares_for(13, 1, 100, 40);
        let mut rng = Rng::new(41);
        let r = sample_coins(&mut rng, 100);
        let mut mul = SecureMul::new(42);
        assert!(verify(
            &mut mul,
            sketch_share(&v0, &r),
            sketch_share(&v1, &r),
            Fp::one()
        ));
    }

    #[test]
    fn honest_scaled_vector_passes_unknown_beta() {
        let (v0, v1) = shares_for(7, 123_456, 64, 43);
        let mut rng = Rng::new(44);
        let r = sample_coins(&mut rng, 64);
        let mut mul = SecureMul::new(45);
        assert!(verify_unknown_beta(&mut mul, &v0, &v1, &r));
    }

    #[test]
    fn two_nonzero_positions_fail() {
        // Adversarial client: sum of two point functions — v has two
        // non-zeros; the degree-2 identity must catch it.
        let (a0, a1) = shares_for(3, 1, 64, 46);
        let (b0, b1) = shares_for(9, 1, 64, 47);
        let v0: Vec<Fp> = a0.iter().zip(&b0).map(|(x, y)| x.add(*y)).collect();
        let v1: Vec<Fp> = a1.iter().zip(&b1).map(|(x, y)| x.add(*y)).collect();
        let mut rng = Rng::new(48);
        let r = sample_coins(&mut rng, 64);
        let mut mul = SecureMul::new(49);
        assert!(!verify(
            &mut mul,
            sketch_share(&v0, &r),
            sketch_share(&v1, &r),
            Fp::one()
        ));
        assert!(!verify_unknown_beta(&mut mul, &v0, &v1, &r));
    }

    #[test]
    fn wrong_beta_fails() {
        let (v0, v1) = shares_for(5, 2, 64, 50);
        let mut rng = Rng::new(51);
        let r = sample_coins(&mut rng, 64);
        let mut mul = SecureMul::new(52);
        // Claimed β=1 but actual payload is 2.
        assert!(!verify(
            &mut mul,
            sketch_share(&v0, &r),
            sketch_share(&v1, &r),
            Fp::one()
        ));
    }

    #[test]
    fn zero_vector_passes() {
        // Dummy bins (β = 0) are legitimate point functions.
        let (v0, v1) = shares_for(0, 0, 64, 53);
        let mut rng = Rng::new(54);
        let r = sample_coins(&mut rng, 64);
        let mut mul = SecureMul::new(55);
        assert!(verify_unknown_beta(&mut mul, &v0, &v1, &r));
    }
}
