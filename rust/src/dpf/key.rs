//! DPF key material and its wire encoding.

use crate::crypto::prg::Seed;
use crate::crypto::Sensitive;
use crate::group::Group;

/// Per-level correction word: a λ-bit seed correction plus two control-bit
/// corrections (left / right).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CorrectionWord {
    /// λ-bit seed correction XORed into the kept child when `t` is set.
    pub seed: Seed,
    /// Control-bit correction for the left child.
    pub t_left: bool,
    /// Control-bit correction for the right child.
    pub t_right: bool,
}

/// One party's DPF key for `f_{α,β} : {0,1}^depth → 𝔾`.
///
/// `cws` + `cw_out` form the *public part* (identical in both keys);
/// `root_seed` is the *private part* (§4 "Efficiency"). The party id `b`
/// fixes the sign convention `(-1)^b` on outputs.
///
/// Deliberately **not** `Debug`: the root seed is the whole privacy
/// budget, and this type is listed in the `SECRET_TYPES` manifest the
/// `xtask` lint enforces. Format the public part by hand if you must.
#[derive(Clone)]
pub struct DpfKey<G: Group> {
    /// Party id b ∈ {0, 1}; fixes the output sign convention `(-1)^b`.
    pub party: u8,
    /// Tree depth n (domain is `{0,1}^n`).
    pub depth: usize,
    /// This party's private λ-bit root seed (redacted in `{:?}`,
    /// zeroized on drop).
    pub root_seed: Sensitive<Seed>,
    /// Per-level correction words (shared by both parties).
    pub cws: Vec<CorrectionWord>,
    /// Output correction word `CW^{(n+1)}` (shared by both parties).
    pub cw_out: G,
}

impl<G: Group> DpfKey<G> {
    /// Total key size in bits: `depth·(λ+2) + λ + ⌈log 𝔾⌉` (paper §3.1).
    pub fn size_bits(&self) -> usize {
        self.public_size_bits() + self.private_size_bits()
    }

    /// Public-part bits: `depth·(λ+2) + ⌈log 𝔾⌉`.
    pub fn public_size_bits(&self) -> usize {
        self.depth * (128 + 2) + G::bit_len()
    }

    /// Private-part bits: the λ-bit root seed.
    pub fn private_size_bits(&self) -> usize {
        128
    }

    /// Wire encoding (party, depth, root seed, CWs, output CW).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 + 2 + 16 + self.cws.len() * 17 + G::byte_len());
        out.push(self.party);
        out.push(self.depth as u8);
        out.extend_from_slice(self.root_seed.expose());
        for cw in &self.cws {
            out.extend_from_slice(&cw.seed);
            out.push(cw.t_left as u8 | ((cw.t_right as u8) << 1));
        }
        self.cw_out.encode(&mut out);
        out
    }

    /// Parse a wire encoding; `None` on malformed input.
    pub fn from_bytes(bytes: &[u8]) -> Option<Self> {
        let party = *bytes.first()?;
        let depth = *bytes.get(1)? as usize;
        if party > 1 {
            return None;
        }
        let mut off = 2;
        let root_seed: Seed = bytes.get(off..off + 16)?.try_into().ok()?;
        off += 16;
        let mut cws = Vec::with_capacity(depth);
        for _ in 0..depth {
            let seed: Seed = bytes.get(off..off + 16)?.try_into().ok()?;
            let bits = *bytes.get(off + 16)?;
            off += 17;
            cws.push(CorrectionWord {
                seed,
                t_left: bits & 1 == 1,
                t_right: bits & 2 == 2,
            });
        }
        let cw_out = G::decode(bytes.get(off..)?)?;
        Some(DpfKey {
            party,
            depth,
            root_seed: Sensitive::new(root_seed),
            cws,
            cw_out,
        })
    }
}
