//! DPF evaluation: single-point walk and full-domain traversal.

use super::key::{CorrectionWord, DpfKey};
use crate::crypto::prg::{double, expand_one, Seed};
use crate::group::Group;

/// `Eval(b, k_b, x)` — one root-to-leaf walk (`depth` AES calls).
pub fn eval<G: Group>(key: &DpfKey<G>, x: u64) -> G {
    debug_assert!(x < (1u64 << key.depth));
    let mut s = *key.root_seed;
    let mut t = key.party == 1;
    for level in 0..key.depth {
        let bit = (x >> (key.depth - 1 - level)) & 1 == 1;
        let child = expand_one(&s, bit);
        let cw = &key.cws[level];
        s = child.seed;
        let mut ct = child.t;
        if t {
            for i in 0..16 {
                s[i] ^= cw.seed[i];
            }
            ct ^= if bit { cw.t_right } else { cw.t_left };
        }
        t = ct;
    }
    leaf_share(key, &s, t)
}

#[inline]
fn leaf_share<G: Group>(key: &DpfKey<G>, s: &Seed, t: bool) -> G {
    // (-1)^b · (Convert(s) + t·CW_out).
    let mut v = G::convert(s);
    if t {
        v.add_assign(&key.cw_out);
    }
    v.cneg(key.party == 1)
}

/// Full-domain evaluation (§7.2 optimisation): one breadth-first traversal
/// shares every internal PRG call across the whole domain — `O(2^depth)`
/// AES doubles instead of `O(depth · 2^depth)` point walks.
///
/// Returns the first `num_points` leaf shares (the simple-hash bin size Θ
/// need not be a power of two).
pub fn full_eval<G: Group>(key: &DpfKey<G>, num_points: usize) -> Vec<G> {
    debug_assert!(num_points <= 1usize << key.depth);
    // Level-order frontier of (seed, t). Prune subtrees that lie entirely
    // beyond num_points so truncated domains don't pay for the full tree.
    // Scalar AES (expand via `double`) measured fastest on this core: the
    // OoO window already pipelines AES-NI across iterations, and wide
    // `encrypt_blocks` batches only added copies (EXPERIMENTS.md §Perf).
    let mut frontier: Vec<(Seed, bool)> = vec![(*key.root_seed, key.party == 1)];
    for level in 0..key.depth {
        let cw = &key.cws[level];
        // Leaves under one node at this level, after expanding.
        let span = 1usize << (key.depth - level - 1);
        let needed = num_points.div_ceil(span).max(1);
        let mut next = Vec::with_capacity((frontier.len() * 2).min(needed + 1));
        'outer: for (s, t) in &frontier {
            let (l, r) = double(s);
            for (bit, child) in [(false, l), (true, r)] {
                if next.len() >= needed {
                    break 'outer;
                }
                let mut cs = child.seed;
                let mut ct = child.t;
                if *t {
                    for i in 0..16 {
                        cs[i] ^= cw.seed[i];
                    }
                    ct ^= if bit { cw.t_right } else { cw.t_left };
                }
                next.push((cs, ct));
            }
        }
        frontier = next;
    }
    frontier
        .iter()
        .take(num_points)
        .map(|(s, t)| leaf_share(key, s, *t))
        .collect()
}


/// Reusable buffers for repeated [`full_eval_with`] calls — the SSA/PSR
/// servers evaluate thousands of small bin trees per client, and per-bin
/// heap churn (frontier + output vectors) measurably costs (§Perf
/// iteration 3). One workspace per server pass amortises it away.
#[derive(Default)]
pub struct EvalWorkspace {
    cur: Vec<(Seed, bool)>,
    next: Vec<(Seed, bool)>,
}

/// Allocation-free variant of [`full_eval`]: leaf shares are appended to
/// `out` (cleared first), frontier storage lives in `ws`.
pub fn full_eval_with<G: Group>(
    key: &DpfKey<G>,
    num_points: usize,
    ws: &mut EvalWorkspace,
    out: &mut Vec<G>,
) {
    full_eval_parts(KeyView::from(key), num_points, ws, out);
}

/// Borrowed view of one DPF key's components — what [`full_eval_parts`]
/// consumes. A [`DpfKey`] converts via `From`; the server hot path instead
/// builds one directly from a client's decoded [`PublicPart`] plus a
/// PRF-derived root seed, so no per-server `DpfKey` is ever materialised
/// (cloning every bin's correction words cost ~20 MB of memcpy per client
/// per server at m ≈ 2·10^6 — §Perf iteration 5).
///
/// [`PublicPart`]: super::master::PublicPart
#[derive(Clone, Copy)]
pub struct KeyView<'a, G: Group> {
    /// Evaluating party b ∈ {0, 1}.
    pub party: u8,
    /// Tree depth.
    pub depth: usize,
    /// This party's root seed.
    pub root_seed: &'a Seed,
    /// Per-level correction words.
    pub cws: &'a [CorrectionWord],
    /// Output correction word.
    pub cw_out: &'a G,
}

impl<'a, G: Group> From<&'a DpfKey<G>> for KeyView<'a, G> {
    fn from(k: &'a DpfKey<G>) -> Self {
        KeyView {
            party: k.party,
            depth: k.depth,
            root_seed: k.root_seed.expose(),
            cws: &k.cws,
            cw_out: &k.cw_out,
        }
    }
}

/// Full-domain evaluation from a borrowed [`KeyView`] — the server-side
/// hot path shared by every [`crate::protocol::aggregate::EvalSource`].
pub fn full_eval_parts<G: Group>(
    key: KeyView<'_, G>,
    num_points: usize,
    ws: &mut EvalWorkspace,
    out: &mut Vec<G>,
) {
    debug_assert!(num_points <= 1usize << key.depth);
    // Breadth-first with reused ping-pong buffers. A DFS variant (only a
    // depth-sized stack) was tried and measured ~25% SLOWER — the
    // level-order loop keeps the AES stream independent across iterations
    // so the OoO core pipelines it; DFS serialises parent→child
    // dependencies (§Perf iteration 6, reverted).
    ws.cur.clear();
    ws.cur.push((*key.root_seed, key.party == 1));
    for (level, cw) in key.cws.iter().enumerate().take(key.depth) {
        let span = 1usize << (key.depth - level - 1);
        let needed = num_points.div_ceil(span).max(1);
        ws.next.clear();
        'outer: for &(s, t) in &ws.cur {
            let (l, r) = double(&s);
            for (bit, child) in [(false, l), (true, r)] {
                if ws.next.len() >= needed {
                    break 'outer;
                }
                let mut cs = child.seed;
                let mut ct = child.t;
                if t {
                    for b in 0..16 {
                        cs[b] ^= cw.seed[b];
                    }
                    ct ^= if bit { cw.t_right } else { cw.t_left };
                }
                ws.next.push((cs, ct));
            }
        }
        std::mem::swap(&mut ws.cur, &mut ws.next);
    }
    let neg = key.party == 1;
    out.clear();
    out.extend(ws.cur.iter().take(num_points).map(|(s, t)| {
        let mut v = G::convert(s);
        if *t {
            v.add_assign(key.cw_out);
        }
        v.cneg(neg)
    }));
}

/// Full-domain evaluation of many keys in one call — the SSA / PSR server
/// path evaluates one DPF per cuckoo bin, with `num_points[j]` bounding
/// bin `j`'s output length (its Θ_j). Returns one share vector per key.
///
/// Deliberately a plain per-key loop over [`full_eval`]: a
/// level-synchronous cross-bin AES batch was prototyped and measured
/// *slower* on this core — see "Why per-key full-domain evaluation" in
/// `docs/ARCHITECTURE.md` for the measurement rationale.
pub fn full_eval_batch<G: Group>(keys: &[DpfKey<G>], num_points: &[usize]) -> Vec<Vec<G>> {
    assert_eq!(keys.len(), num_points.len());
    keys.iter()
        .zip(num_points)
        .map(|(k, &n)| full_eval(k, n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpf::gen;

    fn both_parties(depth: usize, alpha: u64, beta: u64) -> (DpfKey<u64>, DpfKey<u64>) {
        gen(depth, alpha, &beta, [7; 16], [9; 16])
    }

    #[test]
    fn full_eval_zero_points_is_empty() {
        let (k0, k1) = both_parties(4, 3, 42);
        assert!(full_eval(&k0, 0).is_empty());
        let mut ws = EvalWorkspace::default();
        let mut out = vec![0u64; 5];
        full_eval_with(&k1, 0, &mut ws, &mut out);
        assert!(out.is_empty(), "out must be cleared even for 0 points");
    }

    #[test]
    fn full_eval_single_point_is_the_first_leaf() {
        for alpha in [0u64, 5] {
            let (k0, k1) = both_parties(4, alpha, 77);
            let f0 = full_eval(&k0, 1);
            let f1 = full_eval(&k1, 1);
            assert_eq!(f0.len(), 1);
            assert_eq!(f1.len(), 1);
            let sum = f0[0].wrapping_add(f1[0]);
            assert_eq!(sum, if alpha == 0 { 77 } else { 0 }, "alpha {alpha}");
            assert_eq!(f0[0], eval(&k0, 0));
        }
    }

    #[test]
    fn with_variant_matches_allocating_variant() {
        let (k0, _) = both_parties(6, 9, 1234);
        let mut ws = EvalWorkspace::default();
        let mut out = Vec::new();
        for n in [0usize, 1, 2, 37, 64] {
            full_eval_with(&k0, n, &mut ws, &mut out);
            assert_eq!(out, full_eval(&k0, n), "n = {n}");
        }
    }
}
