//! DPF evaluation: single-point walk and full-domain traversal.

use super::key::DpfKey;
use crate::crypto::prg::{double, expand_one, Seed};
use crate::group::Group;

/// `Eval(b, k_b, x)` — one root-to-leaf walk (`depth` AES calls).
pub fn eval<G: Group>(key: &DpfKey<G>, x: u64) -> G {
    debug_assert!(x < (1u64 << key.depth));
    let mut s = key.root_seed;
    let mut t = key.party == 1;
    for level in 0..key.depth {
        let bit = (x >> (key.depth - 1 - level)) & 1 == 1;
        let child = expand_one(&s, bit);
        let cw = &key.cws[level];
        s = child.seed;
        let mut ct = child.t;
        if t {
            for i in 0..16 {
                s[i] ^= cw.seed[i];
            }
            ct ^= if bit { cw.t_right } else { cw.t_left };
        }
        t = ct;
    }
    leaf_share(key, &s, t)
}

#[inline]
fn leaf_share<G: Group>(key: &DpfKey<G>, s: &Seed, t: bool) -> G {
    // (-1)^b · (Convert(s) + t·CW_out).
    let mut v = G::convert(s);
    if t {
        v.add_assign(&key.cw_out);
    }
    v.cneg(key.party == 1)
}

/// Full-domain evaluation (§7.2 optimisation): one breadth-first traversal
/// shares every internal PRG call across the whole domain — `O(2^depth)`
/// AES doubles instead of `O(depth · 2^depth)` point walks.
///
/// Returns the first `num_points` leaf shares (the simple-hash bin size Θ
/// need not be a power of two).
pub fn full_eval<G: Group>(key: &DpfKey<G>, num_points: usize) -> Vec<G> {
    debug_assert!(num_points <= 1usize << key.depth);
    // Level-order frontier of (seed, t). Prune subtrees that lie entirely
    // beyond num_points so truncated domains don't pay for the full tree.
    // Scalar AES (expand via `double`) measured fastest on this core: the
    // OoO window already pipelines AES-NI across iterations, and wide
    // `encrypt_blocks` batches only added copies (EXPERIMENTS.md §Perf).
    let mut frontier: Vec<(Seed, bool)> = vec![(key.root_seed, key.party == 1)];
    for level in 0..key.depth {
        let cw = &key.cws[level];
        // Leaves under one node at this level, after expanding.
        let span = 1usize << (key.depth - level - 1);
        let needed = num_points.div_ceil(span).max(1);
        let mut next = Vec::with_capacity((frontier.len() * 2).min(needed + 1));
        'outer: for (s, t) in &frontier {
            let (l, r) = double(s);
            for (bit, child) in [(false, l), (true, r)] {
                if next.len() >= needed {
                    break 'outer;
                }
                let mut cs = child.seed;
                let mut ct = child.t;
                if *t {
                    for i in 0..16 {
                        cs[i] ^= cw.seed[i];
                    }
                    ct ^= if bit { cw.t_right } else { cw.t_left };
                }
                next.push((cs, ct));
            }
        }
        frontier = next;
    }
    frontier
        .iter()
        .take(num_points)
        .map(|(s, t)| leaf_share(key, s, *t))
        .collect()
}


/// Reusable buffers for repeated [`full_eval_with`] calls — the SSA/PSR
/// servers evaluate thousands of small bin trees per client, and per-bin
/// heap churn (frontier + output vectors) measurably costs (§Perf
/// iteration 3). One workspace per server pass amortises it away.
#[derive(Default)]
pub struct EvalWorkspace {
    cur: Vec<(Seed, bool)>,
    next: Vec<(Seed, bool)>,
}

/// Allocation-free variant of [`full_eval`]: leaf shares are appended to
/// `out` (cleared first), frontier storage lives in `ws`.
pub fn full_eval_with<G: Group>(
    key: &DpfKey<G>,
    num_points: usize,
    ws: &mut EvalWorkspace,
    out: &mut Vec<G>,
) {
    full_eval_parts(
        key.party,
        key.depth,
        &key.root_seed,
        &key.cws,
        &key.cw_out,
        num_points,
        ws,
        out,
    );
}

/// Full-domain evaluation from borrowed key components — the server-side
/// hot path evaluates straight off a client's decoded [`PublicPart`]s plus
/// a PRF-derived root seed, without materialising per-server `DpfKey`s
/// (cloning every bin's correction words cost ~20 MB of memcpy per client
/// per server at m ≈ 2·10^6 — §Perf iteration 5).
///
/// [`PublicPart`]: super::master::PublicPart
#[allow(clippy::too_many_arguments)]
pub fn full_eval_parts<G: Group>(
    party: u8,
    depth: usize,
    root_seed: &Seed,
    cws: &[super::key::CorrectionWord],
    cw_out: &G,
    num_points: usize,
    ws: &mut EvalWorkspace,
    out: &mut Vec<G>,
) {
    debug_assert!(num_points <= 1usize << depth);
    // Breadth-first with reused ping-pong buffers. A DFS variant (only a
    // depth-sized stack) was tried and measured ~25% SLOWER — the
    // level-order loop keeps the AES stream independent across iterations
    // so the OoO core pipelines it; DFS serialises parent→child
    // dependencies (§Perf iteration 6, reverted).
    ws.cur.clear();
    ws.cur.push((*root_seed, party == 1));
    for (level, cw) in cws.iter().enumerate().take(depth) {
        let span = 1usize << (depth - level - 1);
        let needed = num_points.div_ceil(span).max(1);
        ws.next.clear();
        'outer: for i in 0..ws.cur.len() {
            let (s, t) = ws.cur[i];
            let (l, r) = double(&s);
            for (bit, child) in [(false, l), (true, r)] {
                if ws.next.len() >= needed {
                    break 'outer;
                }
                let mut cs = child.seed;
                let mut ct = child.t;
                if t {
                    for b in 0..16 {
                        cs[b] ^= cw.seed[b];
                    }
                    ct ^= if bit { cw.t_right } else { cw.t_left };
                }
                ws.next.push((cs, ct));
            }
        }
        std::mem::swap(&mut ws.cur, &mut ws.next);
    }
    let neg = party == 1;
    out.clear();
    out.extend(ws.cur.iter().take(num_points).map(|(s, t)| {
        let mut v = G::convert(s);
        if *t {
            v.add_assign(cw_out);
        }
        v.cneg(neg)
    }));
}

/// Batched full-domain evaluation of MANY small trees at once — the SSA /
/// PSR server path evaluates one DPF per cuckoo bin, and each bin's tree
/// is tiny (⌈log Θ⌉ ≈ 6–9 levels). Expanding them level-synchronously
/// turns B separate walks into `max_depth` pairs of wide AES batches the
/// AES-NI pipeline can chew through.
///
/// `num_points[j]` bounds bin `j`'s output length (its Θ_j). Returns one
/// share vector per key.
pub fn full_eval_batch<G: Group>(keys: &[DpfKey<G>], num_points: &[usize]) -> Vec<Vec<G>> {
    assert_eq!(keys.len(), num_points.len());
    // Measured on this testbed: a level-synchronous cross-bin AES batch
    // is NOT faster than per-bin walks (scalar AES-NI already saturates
    // via out-of-order pipelining), so the batch API keeps the simple
    // per-key implementation. See EXPERIMENTS.md §Perf iterations 1-2.
    keys.iter()
        .zip(num_points)
        .map(|(k, &n)| full_eval(k, n))
        .collect()
}
