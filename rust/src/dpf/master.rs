//! Master-seed batched key generation (§4 "Master seed for each client").
//!
//! A client producing one DPF per cuckoo bin would naively upload `B` root
//! seeds to each server. Instead it samples two λ-bit master seeds
//! `msk_0, msk_1`, derives bin `j`'s root seeds as `PRF(msk_b, j)`, and
//! uploads only `msk_b` to server `b` plus the (shared) public parts. This
//! cuts client upload to `B·(⌈log Θ⌉(λ+2) + ⌈log 𝔾⌉) + λ` bits per server
//! pair — the formula the paper's §4 Efficiency paragraph reports.

use super::gen::gen;
use super::key::{CorrectionWord, DpfKey};
use crate::crypto::prg::{prf_seed, Seed};
use crate::crypto::Sensitive;
use crate::group::Group;

/// What a client wants to place in one bin: domain depth plus an optional
/// `(α, β)` point (`None` ⇒ dummy key `Gen(1^λ, 0, 0)`, §4).
///
/// Not `Debug`: the `(α, β)` point is exactly the client datum the whole
/// protocol hides (`SECRET_TYPES` manifest).
#[derive(Clone)]
pub struct BinPoint<G: Group> {
    /// DPF tree depth for this bin (covers the bin's Θ positions).
    pub depth: usize,
    /// The `(α, β)` point to share, or `None` for a dummy bin.
    pub point: Option<(u64, G)>,
}

/// The public (seed-free) half of a DPF key — identical for both parties.
#[derive(Clone, Debug)]
pub struct PublicPart<G: Group> {
    /// Tree depth of this bin's key.
    pub depth: usize,
    /// Per-level correction words.
    pub cws: Vec<CorrectionWord>,
    /// Output correction word.
    pub cw_out: G,
}

impl<G: Group> PublicPart<G> {
    /// Size in bits: `depth·(λ+2) + ⌈log 𝔾⌉`.
    pub fn size_bits(&self) -> usize {
        self.depth * (128 + 2) + G::bit_len()
    }
}

/// A client's whole upload for one protocol run: two master seeds plus one
/// public part per bin.
///
/// Not `Debug`: the master seeds derive every root seed
/// (`SECRET_TYPES` manifest).
#[derive(Clone)]
pub struct MasterKeyBatch<G: Group> {
    /// The two per-server master seeds (`msk_b` goes only to server b).
    /// Redacted in `{:?}`, zeroized on drop.
    pub msk: [Sensitive<Seed>; 2],
    /// One public part per bin (identical for both servers).
    pub publics: Vec<PublicPart<G>>,
}

impl<G: Group> MasterKeyBatch<G> {
    /// Reassemble server `b`'s concrete DPF keys from its master seed and
    /// the shared public parts.
    pub fn server_keys(&self, b: u8) -> Vec<DpfKey<G>> {
        assert!(b < 2);
        self.publics
            .iter()
            .enumerate()
            .map(|(j, p)| DpfKey {
                party: b,
                depth: p.depth,
                root_seed: Sensitive::new(prf_seed(&self.msk[b as usize], j as u64)),
                cws: p.cws.clone(),
                cw_out: p.cw_out.clone(),
            })
            .collect()
    }

    /// Client upload in bits for the master-seed scheme: the public parts
    /// (sent once, to one server) plus one λ-bit master seed per server.
    pub fn upload_bits(&self) -> usize {
        self.publics.iter().map(|p| p.size_bits()).sum::<usize>() + 2 * 128
    }
}

/// Generate the batch. Root seeds for bin `j` are `PRF(msk_b, j)`; dummy
/// bins get `Gen(1^λ, 0, 0)` keys, indistinguishable from real ones.
pub fn gen_batch_with_master<G: Group>(
    bins: &[BinPoint<G>],
    msk0: Seed,
    msk1: Seed,
) -> MasterKeyBatch<G> {
    let publics = bins
        .iter()
        .enumerate()
        .map(|(j, bin)| {
            let s0 = prf_seed(&msk0, j as u64);
            let s1 = prf_seed(&msk1, j as u64);
            let (alpha, beta) = match &bin.point {
                Some((a, b)) => (*a, b.clone()),
                None => (0, G::zero()),
            };
            let (k0, _k1) = gen(bin.depth, alpha, &beta, s0, s1);
            PublicPart {
                depth: k0.depth,
                cws: k0.cws,
                cw_out: k0.cw_out,
            }
        })
        .collect();
    MasterKeyBatch {
        msk: [Sensitive::new(msk0), Sensitive::new(msk1)],
        publics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;
    use crate::dpf::{eval, full_eval};

    #[test]
    fn batch_reconstructs_per_bin_points() {
        let mut rng = Rng::new(20);
        let bins: Vec<BinPoint<u64>> = vec![
            BinPoint { depth: 5, point: Some((3, 111)) },
            BinPoint { depth: 5, point: None },
            BinPoint { depth: 7, point: Some((100, 222)) },
            BinPoint { depth: 3, point: Some((0, 333)) },
        ];
        let batch = gen_batch_with_master(&bins, rng.gen_seed(), rng.gen_seed());
        let k0 = batch.server_keys(0);
        let k1 = batch.server_keys(1);
        for (j, bin) in bins.iter().enumerate() {
            let n = 1usize << bin.depth;
            let f0 = full_eval(&k0[j], n);
            let f1 = full_eval(&k1[j], n);
            for x in 0..n {
                let sum = f0[x].add(&f1[x]);
                match &bin.point {
                    Some((a, b)) if *a == x as u64 => assert_eq!(sum, *b),
                    _ => assert_eq!(sum, 0),
                }
            }
        }
    }

    #[test]
    fn master_seed_matches_direct_gen() {
        let mut rng = Rng::new(21);
        let (msk0, msk1) = (rng.gen_seed(), rng.gen_seed());
        let bins = vec![BinPoint { depth: 6, point: Some((9u64, 42u64)) }];
        let batch = gen_batch_with_master(&bins, msk0, msk1);
        let s0 = prf_seed(&msk0, 0);
        let s1 = prf_seed(&msk1, 0);
        let (d0, d1) = crate::dpf::gen(6, 9, &42u64, s0, s1);
        assert_eq!(eval(&batch.server_keys(0)[0], 9), eval(&d0, 9));
        assert_eq!(eval(&batch.server_keys(1)[0], 9), eval(&d1, 9));
    }

    #[test]
    fn upload_accounting() {
        let bins: Vec<BinPoint<u128>> =
            (0..10).map(|_| BinPoint { depth: 9, point: None }).collect();
        let batch = gen_batch_with_master(&bins, [0; 16], [1; 16]);
        // 10 bins · (9·130 + 128) + 2λ.
        assert_eq!(batch.upload_bits(), 10 * (9 * 130 + 128) + 256);
    }
}
