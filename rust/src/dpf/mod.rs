//! Distributed Point Function (BGI16 \[11\], as used in §3.1).
//!
//! A DPF secret-shares the point function `f_{α,β} : {0,1}^n → 𝔾`
//! (`f(α) = β`, `f(x) = 0` elsewhere) into two keys. Each key walks a GGM
//! tree of AES-PRG doubles, applying per-level *correction words*; the two
//! walks agree (and cancel) off the special path and diverge on it, so the
//! leaf shares sum to `β` exactly at `α` and to `0` everywhere else.
//!
//! Key size matches the paper: `n(λ+2) + λ + ⌈log 𝔾⌉` bits — a *public
//! part* (`n(λ+2) + ⌈log 𝔾⌉` bits of correction words, identical in both
//! keys) and a *private part* (the λ-bit root seed, which differs).
//!
//! * [`gen()`] / [`Dpf::gen`] — key generation (client side).
//! * [`eval()`] — single-point evaluation.
//! * [`full_eval`] — full-domain evaluation (server side; the §7.2
//!   "full-domain evaluation" optimisation — one tree traversal instead of
//!   Θ independent walks).
//! * [`gen_batch_with_master`] — master-seed derivation of per-bin root
//!   seeds (§4).

mod eval;
mod gen;
mod key;
mod master;

pub use eval::{
    eval, full_eval, full_eval_batch, full_eval_parts, full_eval_with, EvalWorkspace, KeyView,
};
pub use gen::gen;
pub use key::{CorrectionWord, DpfKey};
pub use master::{gen_batch_with_master, BinPoint, MasterKeyBatch, PublicPart};

use crate::crypto::prg::Seed;
use crate::group::Group;

/// Convenience façade bundling the DPF algorithms for a fixed group.
pub struct Dpf<G: Group>(std::marker::PhantomData<G>);

impl<G: Group> Dpf<G> {
    /// `Gen(1^λ, α, β)` with explicit root seeds (deterministic; callers
    /// draw seeds from [`crate::crypto::rng::Rng`] or a master PRF).
    pub fn gen(depth: usize, alpha: u64, beta: &G, s0: Seed, s1: Seed) -> (DpfKey<G>, DpfKey<G>) {
        gen(depth, alpha, beta, s0, s1)
    }

    /// `Eval(b, k_b, x)`.
    pub fn eval(key: &DpfKey<G>, x: u64) -> G {
        eval(key, x)
    }

    /// Evaluate on the whole domain, truncated to `num_points` outputs.
    pub fn full_eval(key: &DpfKey<G>, num_points: usize) -> Vec<G> {
        full_eval(key, num_points)
    }
}

/// Smallest depth whose domain `2^depth` covers `n` points (depth ≥ 1).
pub fn depth_for(n: usize) -> usize {
    debug_assert!(n >= 1);
    usize::BITS as usize - (n.max(2) - 1).leading_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;

    fn gen_pair<G: Group>(depth: usize, alpha: u64, beta: &G, seed: u64) -> (DpfKey<G>, DpfKey<G>) {
        let mut rng = Rng::new(seed);
        gen(depth, alpha, beta, rng.gen_seed(), rng.gen_seed())
    }

    #[test]
    fn point_function_correctness_u64() {
        for depth in 1..=8 {
            let domain = 1u64 << depth;
            let alpha = domain / 2;
            let beta = 0xabcd_1234_u64;
            let (k0, k1) = gen_pair(depth, alpha, &beta, depth as u64);
            for x in 0..domain {
                let sum = eval(&k0, x).add(&eval(&k1, x));
                if x == alpha {
                    assert_eq!(sum, beta, "depth {depth} at α");
                } else {
                    assert_eq!(sum, 0, "depth {depth} at {x}");
                }
            }
        }
    }

    #[test]
    fn point_function_correctness_u128() {
        let beta = u128::MAX - 12345;
        let (k0, k1) = gen_pair(9, 300, &beta, 7);
        for x in [0u64, 1, 299, 300, 301, 511] {
            let sum = eval(&k0, x).add(&eval(&k1, x));
            assert_eq!(sum, if x == 300 { beta } else { 0 });
        }
    }

    #[test]
    fn point_function_mega_element() {
        use crate::group::MegaElem;
        let beta = MegaElem::<18>([3u64; 18]);
        let (k0, k1) = gen_pair(9, 17, &beta, 8);
        assert_eq!(eval(&k0, 17).add(&eval(&k1, 17)), beta);
        assert_eq!(eval(&k0, 18).add(&eval(&k1, 18)), MegaElem::zero());
    }

    #[test]
    fn full_eval_matches_pointwise() {
        let beta = 999u64;
        let (k0, k1) = gen_pair(9, 123, &beta, 9);
        for key in [&k0, &k1] {
            let fe = full_eval(key, 512);
            for x in 0..512u64 {
                assert_eq!(fe[x as usize], eval(key, x), "x={x}");
            }
        }
        // Truncated domains too (Θ need not be a power of two).
        let fe = full_eval(&k0, 300);
        assert_eq!(fe.len(), 300);
        assert_eq!(fe[200], eval(&k0, 200));
    }

    #[test]
    fn dummy_keys_evaluate_to_zero() {
        // §4 "Handling dummy bins": Gen(1^λ, 0, 0) — shares must cancel on
        // the whole domain, including at α = 0.
        let (k0, k1) = gen_pair(9, 0, &0u64, 10);
        for x in 0..512u64 {
            assert_eq!(eval(&k0, x).add(&eval(&k1, x)), 0);
        }
    }

    #[test]
    fn single_key_reveals_nothing_obvious() {
        // Sanity (not a security proof): one key's full-domain eval should
        // not be the point function in the clear; its values at and off α
        // are pseudorandom non-zeros.
        let beta = 5u64;
        let (k0, _k1) = gen_pair(9, 100, &beta, 11);
        let fe = full_eval(&k0, 512);
        let nonzero = fe.iter().filter(|v| **v != 0).count();
        assert!(nonzero > 500, "share leaks structure: {nonzero} nonzero");
    }

    #[test]
    fn distinct_seeds_distinct_keys() {
        let (a0, _) = gen_pair(9, 5, &1u64, 12);
        let (b0, _) = gen_pair(9, 5, &1u64, 13);
        assert_ne!(a0.to_bytes(), b0.to_bytes());
    }

    #[test]
    fn depth_for_covers() {
        assert_eq!(depth_for(1), 1);
        assert_eq!(depth_for(2), 1);
        assert_eq!(depth_for(3), 2);
        assert_eq!(depth_for(512), 9);
        assert_eq!(depth_for(513), 10);
        for n in 1..200 {
            assert!(1usize << depth_for(n) >= n);
        }
    }

    #[test]
    fn key_size_matches_paper_formula() {
        // n(λ+2) + λ + ⌈log 𝔾⌉ bits.
        let (k0, _) = gen_pair(9, 5, &0u128, 14);
        let expect_bits = 9 * (128 + 2) + 128 + 128;
        assert_eq!(k0.size_bits(), expect_bits);
        assert_eq!(k0.public_size_bits(), 9 * (128 + 2) + 128);
        assert_eq!(k0.private_size_bits(), 128);
    }

    #[test]
    fn serialization_roundtrip() {
        let (k0, k1) = gen_pair::<u128>(9, 77, &42u128, 15);
        for k in [k0, k1] {
            let bytes = k.to_bytes();
            let back = DpfKey::<u128>::from_bytes(&bytes).unwrap();
            assert_eq!(back.to_bytes(), bytes);
            assert_eq!(eval(&back, 77), eval(&k, 77));
            assert_eq!(eval(&back, 78), eval(&k, 78));
        }
    }
}
