//! DPF key generation (BGI16 Gen, fig. 4 of \[11\]).

use super::key::{CorrectionWord, DpfKey};
use crate::crypto::prg::{double, Seed};
use crate::group::Group;

/// `Gen(1^λ, α, β)` with caller-provided root seeds.
///
/// Walks the GGM tree along the path to `α` (MSB-first over `depth` bits),
/// emitting one correction word per level, then pins `β` into the final
/// output correction word. Deterministic in `(s0, s1)` so the master-seed
/// optimisation (PRF-derived seeds, §4) works unchanged.
pub fn gen<G: Group>(
    depth: usize,
    alpha: u64,
    beta: &G,
    s0: Seed,
    s1: Seed,
) -> (DpfKey<G>, DpfKey<G>) {
    assert!(depth >= 1 && depth <= 63, "depth {depth} out of range");
    assert!(
        alpha < (1u64 << depth),
        "α = {alpha} outside domain 2^{depth}"
    );

    let mut seeds = [s0, s1];
    let mut ts = [false, true];
    let mut cws = Vec::with_capacity(depth);

    for level in 0..depth {
        let bit = (alpha >> (depth - 1 - level)) & 1 == 1;
        let (l0, r0) = double(&seeds[0]);
        let (l1, r1) = double(&seeds[1]);

        // Children we "lose" (off the α-path) must collapse to equality
        // after correction; children we "keep" continue the walk.
        let (keep0, keep1, lose0, lose1) = if bit {
            (r0, r1, l0, l1)
        } else {
            (l0, l1, r0, r1)
        };

        let mut cw_seed = lose0.seed;
        for i in 0..16 {
            cw_seed[i] ^= lose1.seed[i];
        }
        let cw = CorrectionWord {
            seed: cw_seed,
            // t-corrections arrange that off-path t's agree and the on-path
            // t's differ (t ⊕ α_i ⊕ 1 on the kept side).
            t_left: l0.t ^ l1.t ^ bit ^ true,
            t_right: r0.t ^ r1.t ^ bit,
        };
        let cw_t_keep = if bit { cw.t_right } else { cw.t_left };
        cws.push(cw);

        for b in 0..2 {
            let keep = if b == 0 { keep0 } else { keep1 };
            let mut s = keep.seed;
            if ts[b] {
                for i in 0..16 {
                    s[i] ^= cw.seed[i];
                }
            }
            let t = keep.t ^ (ts[b] & cw_t_keep);
            seeds[b] = s;
            ts[b] = t;
        }
    }

    // CW^{n+1} = (-1)^{t1} · (β − Convert(s0) + Convert(s1)).
    let conv0 = G::convert(&seeds[0]);
    let conv1 = G::convert(&seeds[1]);
    let cw_out = beta.sub(&conv0).add(&conv1).cneg(ts[1]);

    let mk = |party: u8, root: Seed| DpfKey {
        party,
        depth,
        root_seed: crate::crypto::Sensitive::new(root),
        cws: cws.clone(),
        cw_out: cw_out.clone(),
    };
    (mk(0, s0), mk(1, s1))
}
