//! PSR retrieval round over the metered two-server topology — the
//! download-side counterpart of [`super::server::run_ssa_round`].
//!
//! Each server decodes every client's upload first and then answers the
//! whole batch through one [`RetrievalEngine`] shard plan (multi-client
//! batched serving). Serving stays zero-copy: the decoded public parts +
//! master seed feed the engine directly, so no per-bin `DpfKey` is ever
//! materialised on the read path.

use crate::crypto::rng::Rng;
use crate::group::Group;
use crate::net;
use crate::protocol::aggregate::uploads_of;
use crate::protocol::msg;
use crate::protocol::{psr, RetrievalEngine, Session};
use anyhow::{anyhow, Result};
use std::time::{Duration, Instant};

/// One client's retrieval outcome plus the round's metering.
pub struct PsrRoundResult<G: Group> {
    /// Retrieved weights in `selections` order, per client.
    pub submodels: Vec<Vec<G>>,
    pub client_upload_bytes: u64,
    pub client_download_bytes: u64,
    pub server_time: Duration,
}

/// [`run_psr_round_with`] under the co-located-two-server default engine
/// (half the cores per server — both servers answer concurrently
/// in-process, mirroring [`super::server::run_ssa_round`]).
pub fn run_psr_round<G: Group>(
    session: &Session,
    weights: &[G],
    clients: &[Vec<u64>],
    rng: &mut Rng,
    latency: Duration,
) -> Result<PsrRoundResult<G>> {
    run_psr_round_with(
        session,
        weights,
        clients,
        rng,
        latency,
        &RetrievalEngine::per_coloc_server(),
    )
}

/// Run a PSR round for `clients` (each a selection list) against the
/// servers' weight vector. Servers run on their own threads and serve the
/// whole client batch through `engine`; clients run on the driver thread.
pub fn run_psr_round_with<G: Group>(
    session: &Session,
    weights: &[G],
    clients: &[Vec<u64>],
    rng: &mut Rng,
    latency: Duration,
    engine: &RetrievalEngine,
) -> Result<PsrRoundResult<G>> {
    let n = clients.len();
    let (client_links, server_sides, _inter) = net::topology(n, latency);
    let (eps0, eps1): (Vec<_>, Vec<_>) = server_sides.into_iter().unzip();

    // Client side: build queries, ship keys.
    let mut ctxs = Vec::with_capacity(n);
    for (links, sel) in client_links.iter().zip(clients) {
        let (ctx, batch) =
            psr::client_query::<G>(session, sel, rng).map_err(|e| anyhow!("{e}"))?;
        links.to_s0.send(msg::encode_key_upload(&batch, 0, true))?;
        // PSR sends full key material to both servers (no forwarding
        // needed: the answer flows back on the same link).
        links.to_s1.send(msg::encode_key_upload(&batch, 1, true))?;
        ctxs.push(ctx);
    }
    let client_upload_bytes: u64 = client_links
        .iter()
        .map(|l| l.to_s0.meter.sent() + l.to_s1.meter.sent())
        .sum();

    let serve = |eps: &[net::Endpoint], party: u8| -> Result<Duration> {
        // Decode all uploads, then answer the batch in one shard plan.
        let mut batches = Vec::with_capacity(eps.len());
        for ep in eps {
            let up = msg::decode_key_upload::<G>(&ep.recv()?)
                .ok_or_else(|| anyhow!("S{party}: bad upload"))?;
            let publics = up.publics.ok_or_else(|| anyhow!("S{party}: no publics"))?;
            batches.push(crate::dpf::MasterKeyBatch::<G> {
                msk: [up.msk, up.msk],
                publics,
            });
        }
        let uploads = uploads_of(&batches, party);
        let t = Instant::now();
        let answers = engine.answer_publics(session, weights, party, &uploads);
        let total = t.elapsed();
        for (ep, ans) in eps.iter().zip(&answers) {
            ep.send(msg::encode_shares(ans))?;
        }
        Ok(total)
    };

    let (t0, t1) = std::thread::scope(|scope| -> Result<(Duration, Duration)> {
        let h1 = scope.spawn(move || serve(&eps1, 1));
        let t0 = serve(&eps0, 0)?;
        let t1 = h1.join().map_err(|_| anyhow!("S1 panicked"))??;
        Ok((t0, t1))
    })?;

    // Clients reconstruct.
    let mut submodels = Vec::with_capacity(n);
    for ((links, ctx), sel) in client_links.iter().zip(&ctxs).zip(clients) {
        let a0 = msg::decode_shares::<G>(&links.to_s0.recv()?)
            .ok_or_else(|| anyhow!("bad S0 answer"))?;
        let a1 = msg::decode_shares::<G>(&links.to_s1.recv()?)
            .ok_or_else(|| anyhow!("bad S1 answer"))?;
        submodels.push(psr::client_reconstruct(
            ctx,
            session.simple.num_bins(),
            sel,
            &a0,
            &a1,
        ));
    }
    let client_download_bytes: u64 = client_links
        .iter()
        .map(|l| l.to_s0.meter.recv() + l.to_s1.meter.recv())
        .sum();

    Ok(PsrRoundResult {
        submodels,
        client_upload_bytes,
        client_download_bytes,
        server_time: t0.max(t1),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::CuckooParams;
    use crate::protocol::SessionParams;

    #[test]
    fn multi_client_retrieval_over_channels() {
        let session = Session::new_full(SessionParams {
            m: 2048,
            k: 32,
            cuckoo: CuckooParams::default(),
        });
        let mut rng = Rng::new(900);
        let weights: Vec<u64> = (0..2048).map(|_| rng.next_u64()).collect();
        let clients: Vec<Vec<u64>> = (0..3).map(|_| rng.sample_distinct(32, 2048)).collect();
        let res =
            run_psr_round(&session, &weights, &clients, &mut rng, Duration::ZERO).unwrap();
        for (sel, got) in clients.iter().zip(&res.submodels) {
            for (i, &s) in sel.iter().enumerate() {
                assert_eq!(got[i], weights[s as usize]);
            }
        }
        // Non-triviality: retrieval moved fewer bytes than the database.
        assert!(res.client_download_bytes < 3 * 2048 * 8);
        assert!(res.client_upload_bytes > 0);
    }

    #[test]
    fn engine_width_does_not_change_the_round_result() {
        let session = Session::new_full(SessionParams {
            m: 1024,
            k: 16,
            cuckoo: CuckooParams::default().with_sigma(4),
        });
        let weights: Vec<u64> = {
            let mut rng = Rng::new(901);
            (0..1024).map(|_| rng.next_u64()).collect()
        };
        let clients: Vec<Vec<u64>> = {
            let mut rng = Rng::new(902);
            (0..4).map(|_| rng.sample_distinct(16, 1024)).collect()
        };
        let mut all = Vec::new();
        for threads in [1usize, 8] {
            let mut rng = Rng::new(903);
            let res = run_psr_round_with(
                &session,
                &weights,
                &clients,
                &mut rng,
                Duration::ZERO,
                &RetrievalEngine::new(threads),
            )
            .unwrap();
            all.push(res.submodels);
        }
        assert_eq!(all[0], all[1]);
    }
}
