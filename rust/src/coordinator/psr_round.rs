//! One-shot PSR round wrappers over the persistent runtime.
//!
//! The batched two-server serving path (decode every client's upload,
//! answer the whole batch through one [`RetrievalEngine`] shard plan,
//! ship answers back on the same links) lives in the [`super::runtime`]
//! command loop now. The functions here are kept for compatibility: each
//! builds a runtime, installs the weight vector, runs one round, and
//! drops everything — the per-call cost the persistent API amortises.

use super::runtime::FslRuntimeBuilder;
use crate::crypto::rng::Rng;
use crate::group::Group;
use crate::protocol::{RetrievalEngine, Session};
use anyhow::Result;
use std::time::Duration;

/// One client's retrieval outcome plus the round's metering.
pub struct PsrRoundResult<G: Group> {
    /// Retrieved weights in `selections` order, per client.
    pub submodels: Vec<Vec<G>>,
    pub client_upload_bytes: u64,
    pub client_download_bytes: u64,
    pub server_time: Duration,
}

/// [`run_psr_round_with`] under the co-located-two-server default engine
/// (half the cores per server — both servers answer concurrently
/// in-process).
#[deprecated(note = "build a persistent coordinator::FslRuntime and call .psr(..)")]
pub fn run_psr_round<G: Group>(
    session: &Session,
    weights: &[G],
    clients: &[Vec<u64>],
    rng: &mut Rng,
    latency: Duration,
) -> Result<PsrRoundResult<G>> {
    // (Deprecated items may call each other without tripping the lint.)
    run_psr_round_with(
        session,
        weights,
        clients,
        rng,
        latency,
        &RetrievalEngine::per_coloc_server(),
    )
}

/// Run a PSR round for `clients` (each a selection list) against the
/// servers' weight vector. One-shot wrapper: spawns a fresh runtime,
/// installs `weights`, serves a single round, tears everything down.
#[deprecated(note = "build a persistent coordinator::FslRuntime and call .psr(..)")]
pub fn run_psr_round_with<G: Group>(
    session: &Session,
    weights: &[G],
    clients: &[Vec<u64>],
    rng: &mut Rng,
    latency: Duration,
    engine: &RetrievalEngine,
) -> Result<PsrRoundResult<G>> {
    let mut rt = FslRuntimeBuilder::from_session(session.clone())
        .latency(latency)
        .threads(engine.threads())
        .max_clients(clients.len().max(1))
        .build::<G>()?;
    rt.set_weights(weights.to_vec())?;
    let out = rt.psr(clients, rng)?;
    Ok(PsrRoundResult {
        submodels: out.submodels,
        client_upload_bytes: out.report.client_upload_bytes,
        client_download_bytes: out.report.client_download_bytes,
        server_time: out.report.server_time,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{FslRuntimeBuilder, PsrOutcome};
    use crate::hashing::CuckooParams;
    use crate::protocol::SessionParams;

    fn psr_once(
        session: &Session,
        weights: Vec<u64>,
        clients: &[Vec<u64>],
        rng: &mut Rng,
        threads: usize,
    ) -> PsrOutcome<u64> {
        let mut rt = FslRuntimeBuilder::from_session(session.clone())
            .threads(threads)
            .max_clients(clients.len())
            .build::<u64>()
            .unwrap();
        rt.set_weights(weights).unwrap();
        rt.psr(clients, rng).unwrap()
    }

    #[test]
    fn multi_client_retrieval_over_channels() {
        let session = Session::new_full(SessionParams {
            m: 2048,
            k: 32,
            cuckoo: CuckooParams::default(),
        });
        let mut rng = Rng::new(900);
        let weights: Vec<u64> = (0..2048).map(|_| rng.next_u64()).collect();
        let clients: Vec<Vec<u64>> = (0..3).map(|_| rng.sample_distinct(32, 2048)).collect();
        let res = psr_once(&session, weights.clone(), &clients, &mut rng, 0);
        for (sel, got) in clients.iter().zip(&res.submodels) {
            for (i, &s) in sel.iter().enumerate() {
                assert_eq!(got[i], weights[s as usize]);
            }
        }
        // Non-triviality: retrieval moved fewer bytes than the database.
        assert!(res.report.client_download_bytes < 3 * 2048 * 8);
        assert!(res.report.client_upload_bytes > 0);
    }

    #[test]
    fn engine_width_does_not_change_the_round_result() {
        let session = Session::new_full(SessionParams {
            m: 1024,
            k: 16,
            cuckoo: CuckooParams::default().with_sigma(4),
        });
        let weights: Vec<u64> = {
            let mut rng = Rng::new(901);
            (0..1024).map(|_| rng.next_u64()).collect()
        };
        let clients: Vec<Vec<u64>> = {
            let mut rng = Rng::new(902);
            (0..4).map(|_| rng.sample_distinct(16, 1024)).collect()
        };
        let mut all = Vec::new();
        for threads in [1usize, 8] {
            let mut rng = Rng::new(903);
            all.push(psr_once(&session, weights.clone(), &clients, &mut rng, threads).submodels);
        }
        assert_eq!(all[0], all[1]);
    }

    /// The retained equivalence check against the deprecated one-shot
    /// wrapper: same session + same rng stream ⇒ identical submodels and
    /// byte metering, whichever API served the round.
    #[test]
    #[allow(deprecated)]
    fn deprecated_wrapper_matches_the_runtime() {
        let session = Session::new_full(SessionParams {
            m: 1024,
            k: 16,
            cuckoo: CuckooParams::default(),
        });
        let weights: Vec<u64> = {
            let mut rng = Rng::new(904);
            (0..1024).map(|_| rng.next_u64()).collect()
        };
        let clients: Vec<Vec<u64>> = {
            let mut rng = Rng::new(905);
            (0..3).map(|_| rng.sample_distinct(16, 1024)).collect()
        };
        let legacy = {
            let mut rng = Rng::new(906);
            run_psr_round(&session, &weights, &clients, &mut rng, Duration::ZERO).unwrap()
        };
        let modern = {
            let mut rng = Rng::new(906);
            psr_once(&session, weights, &clients, &mut rng, 0)
        };
        assert_eq!(legacy.submodels, modern.submodels);
        assert_eq!(legacy.client_upload_bytes, modern.report.client_upload_bytes);
        assert_eq!(legacy.client_download_bytes, modern.report.client_download_bytes);
    }
}
