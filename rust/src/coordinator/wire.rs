//! Control-plane protocol between the round driver and a server: the
//! typed commands/replies of the [`super::runtime`] command loop, plus
//! their wire codec.
//!
//! In the single-process runtime these enums travel a typed `mpsc`
//! channel and the codec is never invoked — `Arc`'d payloads are shared,
//! not copied, which is what keeps the in-process fast path bit-identical
//! to the pre-transport code. Against standalone TCP servers the same
//! values are encoded here, framed by the transport, and decoded by the
//! remote command loop ([`super::serve`]). Bulk *client* payloads (key
//! uploads, hints, answers) never travel the control plane — they go over
//! the per-client data links in [`crate::protocol::msg`] encodings, as
//! always.

use super::runtime::ClientOutcome;
use super::verified::VerifiedSsaResult;
use crate::crypto::field::Fp;
use crate::dpf::MasterKeyBatch;
use crate::group::Group;
use crate::hashing::CuckooParams;
use crate::metrics::trace::{Party, Phase, Span};
use crate::protocol::{msg, Session, SessionParams};
use anyhow::{anyhow, bail, ensure, Result};
use std::sync::Arc;
use std::time::Duration;

/// Commands the driver issues to a server (the piece a real deployment
/// carries in an RPC frame). Bulk client payloads never travel here —
/// they go over the metered data links in [`msg`] encodings.
///
/// Round commands carry `deadline_nanos`: `0` runs the round *strict*
/// (any client failure aborts the round, the historical behaviour),
/// non-zero makes the round *tolerant* — the server waits at most that
/// long per client upload and completes the round on the surviving
/// cohort, reporting a per-client [`ClientOutcome`] in its reply.
#[derive(Clone)]
pub enum ServerCmd<G: Group> {
    /// Serve one fresh-key SSA round of `n` clients.
    Ssa { n: usize, deadline_nanos: u64 },
    /// Serve one PSR round of `n` clients from the installed weights.
    Psr { n: usize, deadline_nanos: u64 },
    /// Receive and retain `n` clients' U-DPF key sets, aggregate epoch 0.
    UdpfSetup { n: usize, deadline_nanos: u64 },
    /// Apply `n` clients' epoch hints to the retained keys, aggregate.
    UdpfEpoch {
        n: usize,
        epoch: u64,
        deadline_nanos: u64,
    },
    /// (`S_0` only) verify + aggregate a malicious-model round.
    VerifiedSsa {
        uploads: Arc<Vec<MasterKeyBatch<Fp>>>,
        seed: u64,
    },
    /// Serve one PSU alignment round of `n` clients.
    PsuAlign { n: usize, shuffle_seed: u64 },
    /// Install the servers' weight vector (PSR database).
    SetWeights(Arc<Vec<G>>),
    /// Replace the shared session.
    SetSession(Arc<Session>),
    /// Liveness probe; answered with [`ServerReply::Ack`].
    Ping,
    /// (standalone TCP servers only) dial the peer server's listen
    /// address and establish the `S_0 ↔ S_1` exchange link. The
    /// in-process runtime wires its topology directly and rejects this.
    DialPeer { addr: String },
    /// Snapshot the server's live metrics registry; answered with
    /// [`ServerReply::Stats`]. Not a round: the meters are read, never
    /// reset. (Mid-round TCP scrapes use the out-of-band
    /// `Role::Stats` responder instead — this command path serves the
    /// in-process runtime and idle standalone servers.)
    Stats,
    /// Exit the command loop.
    Shutdown,
}

impl<G: Group> ServerCmd<G> {
    /// Whether this command serves a round (as opposed to an install,
    /// probe, or lifecycle command). Kept next to the enum so a new
    /// round variant cannot be added without this list in view — the
    /// standalone server resets and reports its `S_0 ↔ S_1` meter
    /// exactly for round commands.
    pub fn is_round(&self) -> bool {
        matches!(
            self,
            ServerCmd::Ssa { .. }
                | ServerCmd::Psr { .. }
                | ServerCmd::UdpfSetup { .. }
                | ServerCmd::UdpfEpoch { .. }
                | ServerCmd::VerifiedSsa { .. }
                | ServerCmd::PsuAlign { .. }
        )
    }

    /// The number of client data links this command will read, if any.
    /// The server bounds it against its connected links *before*
    /// dispatch: the in-process driver validates round sizes in its own
    /// process, but a remote driver's `n` arrives off the wire and must
    /// not be able to panic a slice index. (Verified rounds carry their
    /// uploads in the command itself and touch no client links.)
    pub fn client_count(&self) -> Option<usize> {
        match self {
            ServerCmd::Ssa { n, .. }
            | ServerCmd::Psr { n, .. }
            | ServerCmd::UdpfSetup { n, .. }
            | ServerCmd::UdpfEpoch { n, .. }
            | ServerCmd::PsuAlign { n, .. } => Some(*n),
            _ => None,
        }
    }
}

/// A server's answer to one [`ServerCmd`].
pub enum ServerReply<G: Group> {
    /// Install (or ping) acknowledged.
    Ack,
    /// Round served; `delta` is `Some` only from the SSA leader.
    /// `inter_sent` is the server's `S_0 ↔ S_1` bytes for this round —
    /// meaningful only from standalone servers (the in-process runtime
    /// reads its own inter-link meters and leaves this 0). `outcomes` is
    /// one entry per client from a tolerant round (empty from strict
    /// rounds — every client completed or the round failed).
    /// `spans` is the server's per-phase trace for this round
    /// ([`crate::metrics::trace`]), drained by the command loop so remote
    /// rounds produce the same span stream as in-process ones.
    Round {
        server_time: Duration,
        delta: Option<Vec<G>>,
        inter_sent: u64,
        outcomes: Vec<ClientOutcome>,
        spans: Vec<Span>,
    },
    /// Verified round served (leader only).
    Verified {
        result: VerifiedSsaResult,
        server_time: Duration,
    },
    /// The command failed server-side.
    Failed(String),
    /// Live-metrics snapshot ([`ServerCmd::Stats`]): the registry
    /// rendered both ways server-side, so the scraping CLI needs no
    /// registry of its own and the two renderings are of one atomic
    /// snapshot.
    Stats {
        /// Prometheus text exposition format.
        prom: String,
        /// JSON document ([`crate::metrics::expo::render_json`]).
        json: String,
    },
}

impl<G: Group> ServerReply<G> {
    /// Convert a non-success reply into the driver-side error it implies.
    pub fn into_protocol_error(self, what: &str) -> anyhow::Error {
        match self {
            ServerReply::Failed(e) => anyhow!("server failed during {what}: {e}"),
            _ => anyhow!("unexpected server reply during {what}"),
        }
    }
}

// ---- primitive helpers -------------------------------------------------
//
// The u32 primitives are `msg`'s own (one definition crate-wide); the
// u64/slice/block forms are control-plane-only, with Result-typed
// truncation errors instead of msg's Option convention.

use crate::protocol::msg::put_u32;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u32(bytes: &[u8], off: &mut usize) -> Result<u32> {
    crate::protocol::msg::get_u32(bytes, off)
        .ok_or_else(|| anyhow!("truncated control message (u32 at {off})"))
}

fn get_u64(bytes: &[u8], off: &mut usize) -> Result<u64> {
    let s = bytes
        .get(*off..*off + 8)
        .ok_or_else(|| anyhow!("truncated control message (u64 at {off})"))?;
    *off += 8;
    Ok(u64::from_le_bytes([
        s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
    ]))
}

fn get_slice<'a>(bytes: &'a [u8], off: &mut usize, len: usize) -> Result<&'a [u8]> {
    let s = bytes
        .get(*off..*off + len)
        .ok_or_else(|| anyhow!("truncated control message ({len} bytes at {off})"))?;
    *off += len;
    Ok(s)
}

/// Encode a count or length as the wire's u32. Every count routed here
/// is structurally bounded far below `u32::MAX` (cohort sizes are
/// validated into u32 range by the driver's `wire_u32`, span lists are
/// truncated to [`MAX_WIRE_SPANS`], blocks fit the transport frame cap),
/// so the saturating `min` is a belt-and-braces guard that keeps the
/// encoder infallible rather than a path that ever fires.
fn put_count(out: &mut Vec<u8>, n: usize) {
    // lint: allow(cast-truncation) — n is clamped to u32::MAX on the previous expression, so the cast cannot truncate.
    put_u32(out, n.min(u32::MAX as usize) as u32);
}

fn put_block(out: &mut Vec<u8>, block: &[u8]) {
    put_count(out, block.len());
    out.extend_from_slice(block);
}

fn get_block<'a>(bytes: &'a [u8], off: &mut usize) -> Result<&'a [u8]> {
    let len = get_u32(bytes, off)? as usize;
    if len > bytes.len().saturating_sub(*off) {
        bail!(
            "control message block declares {len} bytes but only {} remain",
            bytes.len() - *off
        );
    }
    get_slice(bytes, off, len)
}

fn duration_nanos(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

// ---- session codec -----------------------------------------------------

/// Encode a [`Session`] as its defining public data: the parameters and
/// the alignment domain. The simple table is *not* shipped — it is a
/// deterministic function of both, and the receiving server rebuilds it
/// (the System-Setup step of Fig. 4 run at install time).
pub fn encode_session(s: &Session) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, s.params.m);
    put_u64(&mut out, s.params.k as u64);
    put_u64(&mut out, s.params.cuckoo.epsilon.to_bits());
    put_u64(&mut out, s.params.cuckoo.eta as u64);
    put_u64(&mut out, s.params.cuckoo.sigma as u64);
    put_u64(&mut out, s.params.cuckoo.hash_seed);
    put_u64(&mut out, s.params.cuckoo.max_kicks as u64);
    match &s.domain {
        None => out.push(0),
        Some(union) => {
            out.push(1);
            out.extend_from_slice(&msg::encode_indices(union));
        }
    }
    out
}

/// Ceiling on a wire-installed session's model size. Decoding rebuilds
/// the simple table eagerly — an O(m) allocation — so a remote driver's
/// claimed `m` must be bounded *before* any building happens, and the
/// bound must keep the worst *accepted* build cheap, not merely finite
/// (the codec is fuzzed, and a hostile control frame should cost the
/// server milliseconds, not gigabytes). 2^22 is 64× the largest model
/// any wire deployment here uses (the transport bench's 2^16); the 2^25
/// paper-scale benches run in-process, where no session is wire-decoded
/// and no cap applies.
pub const MAX_WIRE_MODEL: u64 = 1 << 22;
/// Ceiling on the rebuilt table's bin count — guards the bin-header
/// allocation against inflated `ε·k` products (ε arrives as raw f64
/// bits, so infinities and huge exponents are reachable off the wire).
pub const MAX_WIRE_BINS: usize = 1 << 22;
/// Ceiling on client-cohort sizes a control frame may declare (verified
/// upload counts, per-client outcome lists) — far above any deployment
/// here, far below an attacker-sized allocation.
pub const MAX_WIRE_COHORT: usize = 1 << 20;
/// Ceiling on the trace spans one round reply may carry. The recorder's
/// ring ([`crate::metrics::trace::DEFAULT_TRACE_CAPACITY`]) already bounds
/// what a server *produces* per round; this is the decode-side guard
/// against a hostile reply declaring an attacker-sized span list. The
/// encoder truncates to the same bound, so honest peers never hit it.
pub const MAX_WIRE_SPANS: usize = 1 << 16;

/// Rebuild a [`Session`] from [`encode_session`] output (rebuilds the
/// simple table; union domains re-run the [`Session::new_union`]
/// validation, so a tampered control frame cannot install a malformed
/// domain).
///
/// Every parameter is sanity-bounded before the O(m) table build: the
/// codec is reachable by anyone who can speak the handshake, so a
/// decoded session must never be able to panic the process or allocate
/// unboundedly, only to fail with a typed error.
pub fn decode_session(bytes: &[u8]) -> Result<Session> {
    let mut off = 0;
    let m = get_u64(bytes, &mut off)?;
    let k = get_u64(bytes, &mut off)? as usize;
    let epsilon = f64::from_bits(get_u64(bytes, &mut off)?);
    let eta = get_u64(bytes, &mut off)? as usize;
    let sigma = get_u64(bytes, &mut off)? as usize;
    let hash_seed = get_u64(bytes, &mut off)?;
    let max_kicks = get_u64(bytes, &mut off)? as usize;
    ensure!(
        (1..=MAX_WIRE_MODEL).contains(&m),
        "session model size m={m} is outside the wire-installable range [1, {MAX_WIRE_MODEL}]"
    );
    ensure!(
        k >= 1 && k as u64 <= m,
        "session submodel size k={k} must be in [1, m={m}]"
    );
    ensure!(
        epsilon.is_finite() && epsilon > 0.0 && epsilon <= 64.0,
        "session cuckoo scale factor ε={epsilon} is not sane (expected 0 < ε ≤ 64)"
    );
    ensure!(
        (1..=64).contains(&eta),
        "session cuckoo hash count η={eta} is not sane (expected 1 ≤ η ≤ 64)"
    );
    ensure!(
        sigma <= 1 << 20,
        "session cuckoo stash size σ={sigma} is not sane"
    );
    ensure!(
        (1..=1 << 24).contains(&max_kicks),
        "session cuckoo max_kicks={max_kicks} is not sane"
    );
    let params = SessionParams {
        m,
        k,
        cuckoo: CuckooParams {
            epsilon,
            eta,
            sigma,
            hash_seed,
            max_kicks,
        },
    };
    let bins = params.num_bins();
    ensure!(
        bins <= MAX_WIRE_BINS,
        "session table would need {bins} bins (wire cap {MAX_WIRE_BINS})"
    );
    match *bytes
        .get(off)
        .ok_or_else(|| anyhow!("truncated session (domain tag)"))?
    {
        0 => Ok(Session::new_full(params)),
        1 => {
            let union = msg::decode_indices(&bytes[off + 1..])
                .ok_or_else(|| anyhow!("malformed session union domain"))?;
            Session::new_union(params, union)
        }
        t => bail!("unknown session domain tag {t}"),
    }
}

// ---- command codec -----------------------------------------------------

const CMD_SSA: u8 = 1;
const CMD_PSR: u8 = 2;
const CMD_UDPF_SETUP: u8 = 3;
const CMD_UDPF_EPOCH: u8 = 4;
const CMD_VERIFIED: u8 = 5;
const CMD_PSU: u8 = 6;
const CMD_SET_WEIGHTS: u8 = 7;
const CMD_SET_SESSION: u8 = 8;
const CMD_PING: u8 = 9;
const CMD_DIAL_PEER: u8 = 10;
const CMD_SHUTDOWN: u8 = 11;
const CMD_STATS: u8 = 12;

/// Encode a command for the remote control plane.
pub fn encode_cmd<G: Group>(cmd: &ServerCmd<G>) -> Vec<u8> {
    let mut out = Vec::new();
    match cmd {
        ServerCmd::Ssa { n, deadline_nanos } => {
            out.push(CMD_SSA);
            put_count(&mut out, *n);
            put_u64(&mut out, *deadline_nanos);
        }
        ServerCmd::Psr { n, deadline_nanos } => {
            out.push(CMD_PSR);
            put_count(&mut out, *n);
            put_u64(&mut out, *deadline_nanos);
        }
        ServerCmd::UdpfSetup { n, deadline_nanos } => {
            out.push(CMD_UDPF_SETUP);
            put_count(&mut out, *n);
            put_u64(&mut out, *deadline_nanos);
        }
        ServerCmd::UdpfEpoch {
            n,
            epoch,
            deadline_nanos,
        } => {
            out.push(CMD_UDPF_EPOCH);
            put_count(&mut out, *n);
            put_u64(&mut out, *epoch);
            put_u64(&mut out, *deadline_nanos);
        }
        ServerCmd::VerifiedSsa { uploads, seed } => {
            out.push(CMD_VERIFIED);
            put_u64(&mut out, *seed);
            put_count(&mut out, uploads.len());
            for batch in uploads.iter() {
                put_block(&mut out, &msg::encode_master_batch(batch));
            }
        }
        ServerCmd::PsuAlign { n, shuffle_seed } => {
            out.push(CMD_PSU);
            put_count(&mut out, *n);
            put_u64(&mut out, *shuffle_seed);
        }
        ServerCmd::SetWeights(w) => {
            out.push(CMD_SET_WEIGHTS);
            out.extend_from_slice(&msg::encode_shares(w));
        }
        ServerCmd::SetSession(s) => {
            out.push(CMD_SET_SESSION);
            out.extend_from_slice(&encode_session(s));
        }
        ServerCmd::Ping => out.push(CMD_PING),
        ServerCmd::DialPeer { addr } => {
            out.push(CMD_DIAL_PEER);
            put_block(&mut out, addr.as_bytes());
        }
        ServerCmd::Stats => out.push(CMD_STATS),
        ServerCmd::Shutdown => out.push(CMD_SHUTDOWN),
    }
    out
}

/// Decode a remote control-plane command.
pub fn decode_cmd<G: Group>(bytes: &[u8]) -> Result<ServerCmd<G>> {
    let tag = *bytes
        .first()
        .ok_or_else(|| anyhow!("empty control message"))?;
    let mut off = 1;
    Ok(match tag {
        CMD_SSA => ServerCmd::Ssa {
            n: get_u32(bytes, &mut off)? as usize,
            deadline_nanos: get_u64(bytes, &mut off)?,
        },
        CMD_PSR => ServerCmd::Psr {
            n: get_u32(bytes, &mut off)? as usize,
            deadline_nanos: get_u64(bytes, &mut off)?,
        },
        CMD_UDPF_SETUP => ServerCmd::UdpfSetup {
            n: get_u32(bytes, &mut off)? as usize,
            deadline_nanos: get_u64(bytes, &mut off)?,
        },
        CMD_UDPF_EPOCH => {
            let n = get_u32(bytes, &mut off)? as usize;
            let epoch = get_u64(bytes, &mut off)?;
            let deadline_nanos = get_u64(bytes, &mut off)?;
            ServerCmd::UdpfEpoch {
                n,
                epoch,
                deadline_nanos,
            }
        }
        CMD_VERIFIED => {
            let seed = get_u64(bytes, &mut off)?;
            let count = get_u32(bytes, &mut off)? as usize;
            ensure!(
                count <= MAX_WIRE_COHORT,
                "verified-SSA command declares {count} uploads (wire cap {MAX_WIRE_COHORT})"
            );
            let mut uploads = Vec::with_capacity(count.min(bytes.len()));
            for i in 0..count {
                let block = get_block(bytes, &mut off)?;
                uploads.push(
                    msg::decode_master_batch::<Fp>(block)
                        .ok_or_else(|| anyhow!("malformed verified-SSA upload {i}"))?,
                );
            }
            ServerCmd::VerifiedSsa {
                uploads: Arc::new(uploads),
                seed,
            }
        }
        CMD_PSU => {
            let n = get_u32(bytes, &mut off)? as usize;
            let shuffle_seed = get_u64(bytes, &mut off)?;
            ServerCmd::PsuAlign { n, shuffle_seed }
        }
        CMD_SET_WEIGHTS => ServerCmd::SetWeights(Arc::new(
            msg::decode_shares::<G>(&bytes[off..])
                .ok_or_else(|| anyhow!("malformed weight vector"))?,
        )),
        CMD_SET_SESSION => ServerCmd::SetSession(Arc::new(decode_session(&bytes[off..])?)),
        CMD_PING => ServerCmd::Ping,
        CMD_DIAL_PEER => ServerCmd::DialPeer {
            addr: String::from_utf8_lossy(get_block(bytes, &mut off)?).into_owned(),
        },
        CMD_STATS => ServerCmd::Stats,
        CMD_SHUTDOWN => ServerCmd::Shutdown,
        t => bail!("unknown control command tag {t}"),
    })
}

// ---- reply codec -------------------------------------------------------

const REP_ACK: u8 = 1;
const REP_ROUND: u8 = 2;
const REP_VERIFIED: u8 = 3;
const REP_FAILED: u8 = 4;
const REP_STATS: u8 = 5;

/// One byte per [`ClientOutcome`] on the wire.
fn outcome_byte(o: ClientOutcome) -> u8 {
    match o {
        ClientOutcome::Completed => 0,
        ClientOutcome::Dropped => 1,
        ClientOutcome::StragglerCut => 2,
    }
}

fn outcome_of(b: u8) -> Result<ClientOutcome> {
    Ok(match b {
        0 => ClientOutcome::Completed,
        1 => ClientOutcome::Dropped,
        2 => ClientOutcome::StragglerCut,
        t => bail!("unknown client-outcome byte {t}"),
    })
}

/// Encode a server reply for the remote control plane.
pub fn encode_reply<G: Group>(reply: &ServerReply<G>) -> Vec<u8> {
    let mut out = Vec::new();
    match reply {
        ServerReply::Ack => out.push(REP_ACK),
        ServerReply::Round {
            server_time,
            delta,
            inter_sent,
            outcomes,
            spans,
        } => {
            out.push(REP_ROUND);
            put_u64(&mut out, duration_nanos(*server_time));
            put_u64(&mut out, *inter_sent);
            // Outcomes and spans precede the delta: the delta encoding
            // consumes the rest of the message.
            put_count(&mut out, outcomes.len());
            out.extend(outcomes.iter().map(|&o| outcome_byte(o)));
            let spans = &spans[..spans.len().min(MAX_WIRE_SPANS)];
            put_count(&mut out, spans.len());
            for s in spans {
                out.push(s.phase.to_byte());
                out.push(s.party.to_byte());
                match s.worker {
                    None => out.push(0),
                    Some(w) => {
                        out.push(1);
                        put_u32(&mut out, w);
                    }
                }
                put_u64(&mut out, s.start_ns);
                put_u64(&mut out, s.dur_ns);
            }
            match delta {
                None => out.push(0),
                Some(d) => {
                    out.push(1);
                    out.extend_from_slice(&msg::encode_shares(d));
                }
            }
        }
        ServerReply::Verified {
            result,
            server_time,
        } => {
            out.push(REP_VERIFIED);
            put_u64(&mut out, duration_nanos(*server_time));
            let rejected: Vec<u64> = result.rejected.iter().map(|&i| i as u64).collect();
            put_block(&mut out, &msg::encode_indices(&rejected));
            out.extend_from_slice(&msg::encode_shares(&result.delta));
        }
        ServerReply::Failed(e) => {
            out.push(REP_FAILED);
            put_block(&mut out, e.as_bytes());
        }
        ServerReply::Stats { prom, json } => {
            out.push(REP_STATS);
            put_block(&mut out, prom.as_bytes());
            put_block(&mut out, json.as_bytes());
        }
    }
    out
}

/// Decode a remote server reply.
pub fn decode_reply<G: Group>(bytes: &[u8]) -> Result<ServerReply<G>> {
    let tag = *bytes.first().ok_or_else(|| anyhow!("empty server reply"))?;
    let mut off = 1;
    Ok(match tag {
        REP_ACK => ServerReply::Ack,
        REP_ROUND => {
            let server_time = Duration::from_nanos(get_u64(bytes, &mut off)?);
            let inter_sent = get_u64(bytes, &mut off)?;
            let n_outcomes = get_u32(bytes, &mut off)? as usize;
            ensure!(
                n_outcomes <= MAX_WIRE_COHORT,
                "round reply declares {n_outcomes} outcomes (wire cap {MAX_WIRE_COHORT})"
            );
            if n_outcomes > bytes.len().saturating_sub(off) {
                bail!(
                    "round reply declares {n_outcomes} outcomes but only {} bytes remain",
                    bytes.len() - off
                );
            }
            let outcomes = get_slice(bytes, &mut off, n_outcomes)?
                .iter()
                .map(|&b| outcome_of(b))
                .collect::<Result<Vec<_>>>()?;
            let n_spans = get_u32(bytes, &mut off)? as usize;
            ensure!(
                n_spans <= MAX_WIRE_SPANS,
                "round reply declares {n_spans} spans (wire cap {MAX_WIRE_SPANS})"
            );
            let mut spans = Vec::with_capacity(n_spans.min(bytes.len()));
            for i in 0..n_spans {
                let head = get_slice(bytes, &mut off, 3)?;
                let (phase_b, party_b, worker_tag) = (head[0], head[1], head[2]);
                let phase = Phase::from_byte(phase_b)
                    .ok_or_else(|| anyhow!("unknown span phase byte {phase_b} (span {i})"))?;
                let party = Party::from_byte(party_b)
                    .ok_or_else(|| anyhow!("unknown span party byte {party_b} (span {i})"))?;
                let worker = match worker_tag {
                    0 => None,
                    1 => Some(get_u32(bytes, &mut off)?),
                    t => bail!("unknown span worker tag {t} (span {i})"),
                };
                let start_ns = get_u64(bytes, &mut off)?;
                let dur_ns = get_u64(bytes, &mut off)?;
                spans.push(Span {
                    phase,
                    party,
                    worker,
                    start_ns,
                    dur_ns,
                });
            }
            let delta = match *bytes
                .get(off)
                .ok_or_else(|| anyhow!("truncated round reply"))?
            {
                0 => None,
                _ => Some(
                    msg::decode_shares::<G>(&bytes[off + 1..])
                        .ok_or_else(|| anyhow!("malformed round delta"))?,
                ),
            };
            ServerReply::Round {
                server_time,
                delta,
                inter_sent,
                outcomes,
                spans,
            }
        }
        REP_VERIFIED => {
            let server_time = Duration::from_nanos(get_u64(bytes, &mut off)?);
            let rejected = msg::decode_indices(get_block(bytes, &mut off)?)
                .ok_or_else(|| anyhow!("malformed rejection list"))?
                .into_iter()
                .map(|i| i as usize)
                .collect();
            let delta = msg::decode_shares::<Fp>(&bytes[off..])
                .ok_or_else(|| anyhow!("malformed verified delta"))?;
            ServerReply::Verified {
                result: VerifiedSsaResult { delta, rejected },
                server_time,
            }
        }
        REP_FAILED => {
            ServerReply::Failed(String::from_utf8_lossy(get_block(bytes, &mut off)?).into_owned())
        }
        REP_STATS => {
            let prom = String::from_utf8_lossy(get_block(bytes, &mut off)?).into_owned();
            let json = String::from_utf8_lossy(get_block(bytes, &mut off)?).into_owned();
            ServerReply::Stats { prom, json }
        }
        t => bail!("unknown server reply tag {t}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;
    use crate::dpf::{gen_batch_with_master, BinPoint};

    fn session() -> Session {
        Session::new_full(SessionParams {
            m: 4096,
            k: 64,
            cuckoo: CuckooParams::default(),
        })
    }

    #[test]
    fn session_codec_rebuilds_identical_tables() {
        let s = session();
        let back = decode_session(&encode_session(&s)).unwrap();
        assert_eq!(back.params.m, s.params.m);
        assert_eq!(back.params.k, s.params.k);
        assert_eq!(back.simple.num_bins(), s.simple.num_bins());
        assert_eq!(back.theta(), s.theta());
        for j in 0..s.simple.num_bins() {
            assert_eq!(back.simple.bin(j), s.simple.bin(j), "bin {j}");
        }

        let union: Vec<u64> = (0..4096).step_by(7).collect();
        let su = Session::new_union(s.params.clone(), union.clone()).unwrap();
        let back = decode_session(&encode_session(&su)).unwrap();
        assert_eq!(back.domain.as_deref(), Some(&union));
        assert_eq!(back.theta(), su.theta());
    }

    #[test]
    fn session_codec_rejects_tampered_unions() {
        let su =
            Session::new_union(session().params.clone(), vec![1, 5, 9]).unwrap();
        let mut enc = encode_session(&su);
        // Swap two union elements (the u64s live at the tail).
        let tail = enc.len() - 24;
        let (a, b) = (tail, tail + 8);
        for i in 0..8 {
            enc.swap(a + i, b + i);
        }
        assert!(decode_session(&enc).is_err());
    }

    #[test]
    fn cmd_codec_roundtrips() {
        let cases: Vec<ServerCmd<u64>> = vec![
            ServerCmd::Ssa { n: 4, deadline_nanos: 0 },
            ServerCmd::Psr { n: 9, deadline_nanos: 2_000_000_000 },
            ServerCmd::UdpfSetup { n: 2, deadline_nanos: 5 },
            ServerCmd::UdpfEpoch { n: 2, epoch: 77, deadline_nanos: 0 },
            ServerCmd::PsuAlign { n: 3, shuffle_seed: 0xABC },
            ServerCmd::SetWeights(Arc::new(vec![1u64, 2, u64::MAX])),
            ServerCmd::SetSession(Arc::new(session())),
            ServerCmd::Ping,
            ServerCmd::DialPeer { addr: "127.0.0.1:7100".into() },
            ServerCmd::Stats,
            ServerCmd::Shutdown,
        ];
        for cmd in &cases {
            let enc = encode_cmd(cmd);
            let dec = decode_cmd::<u64>(&enc).unwrap();
            // Spot-check the interesting payloads; tags must match.
            assert_eq!(enc[0], encode_cmd(&dec)[0]);
            match (cmd, &dec) {
                (ServerCmd::SetWeights(a), ServerCmd::SetWeights(b)) => assert_eq!(a, b),
                (ServerCmd::DialPeer { addr: a }, ServerCmd::DialPeer { addr: b }) => {
                    assert_eq!(a, b)
                }
                (
                    ServerCmd::UdpfEpoch { n, epoch, deadline_nanos },
                    ServerCmd::UdpfEpoch { n: n2, epoch: e2, deadline_nanos: d2 },
                ) => assert_eq!((n, epoch, deadline_nanos), (n2, e2, d2)),
                (
                    ServerCmd::Psr { deadline_nanos, .. },
                    ServerCmd::Psr { deadline_nanos: d2, .. },
                ) => assert_eq!(deadline_nanos, d2),
                _ => {}
            }
        }
    }

    #[test]
    fn verified_cmd_roundtrips_batches() {
        let mut rng = Rng::new(33);
        let bins: Vec<BinPoint<Fp>> = vec![
            BinPoint { depth: 5, point: Some((3, Fp::new(9))) },
            BinPoint { depth: 4, point: None },
        ];
        let batch = gen_batch_with_master(&bins, rng.gen_seed(), rng.gen_seed());
        let cmd: ServerCmd<u64> = ServerCmd::VerifiedSsa {
            uploads: Arc::new(vec![batch.clone(), batch.clone()]),
            seed: 42,
        };
        match decode_cmd::<u64>(&encode_cmd(&cmd)).unwrap() {
            ServerCmd::VerifiedSsa { uploads, seed } => {
                assert_eq!(seed, 42);
                assert_eq!(uploads.len(), 2);
                assert_eq!(uploads[0].msk, batch.msk);
                assert_eq!(
                    msg::encode_master_batch(&uploads[0]),
                    msg::encode_master_batch(&batch)
                );
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn reply_codec_roundtrips() {
        let cases: Vec<ServerReply<u128>> = vec![
            ServerReply::Ack,
            ServerReply::Round {
                server_time: Duration::from_micros(1234),
                delta: Some(vec![5u128, 6, 7]),
                inter_sent: 999,
                outcomes: vec![],
                spans: vec![],
            },
            ServerReply::Round {
                server_time: Duration::ZERO,
                delta: None,
                inter_sent: 0,
                outcomes: vec![
                    ClientOutcome::Completed,
                    ClientOutcome::Dropped,
                    ClientOutcome::StragglerCut,
                ],
                spans: vec![
                    Span {
                        phase: Phase::Upload,
                        party: Party::S0,
                        worker: None,
                        start_ns: 17,
                        dur_ns: 5_000,
                    },
                    Span {
                        phase: Phase::Eval,
                        party: Party::S1,
                        worker: Some(3),
                        start_ns: u64::MAX,
                        dur_ns: 0,
                    },
                ],
            },
            ServerReply::Verified {
                result: VerifiedSsaResult {
                    delta: vec![Fp::new(3), Fp::new(4)],
                    rejected: vec![1, 7],
                },
                server_time: Duration::from_millis(5),
            },
            ServerReply::Failed("bin count mismatch".into()),
            ServerReply::Stats {
                prom: "# HELP fsl_x_total h\n# TYPE fsl_x_total counter\nfsl_x_total 1\n".into(),
                json: "{\"schema\":1,\"metrics\":[]}".into(),
            },
        ];
        for reply in &cases {
            let enc = encode_reply(reply);
            let dec = decode_reply::<u128>(&enc).unwrap();
            assert_eq!(encode_reply(&dec), enc, "re-encoding must be identical");
        }
    }

    #[test]
    fn truncated_control_messages_are_errors() {
        let cmd: ServerCmd<u64> = ServerCmd::SetWeights(Arc::new(vec![1, 2, 3]));
        let enc = encode_cmd(&cmd);
        for cut in 0..enc.len() {
            assert!(decode_cmd::<u64>(&enc[..cut]).is_err(), "cut {cut}");
        }
        let reply: ServerReply<u64> = ServerReply::Round {
            server_time: Duration::from_secs(1),
            delta: Some(vec![9]),
            inter_sent: 3,
            outcomes: vec![ClientOutcome::Completed, ClientOutcome::Dropped],
            spans: vec![Span {
                phase: Phase::Merge,
                party: Party::S0,
                worker: Some(1),
                start_ns: 2,
                dur_ns: 3,
            }],
        };
        let enc = encode_reply(&reply);
        for cut in 0..enc.len() {
            assert!(decode_reply::<u64>(&enc[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn outcome_bytes_reject_unknowns() {
        let reply: ServerReply<u64> = ServerReply::Round {
            server_time: Duration::ZERO,
            delta: None,
            inter_sent: 0,
            outcomes: vec![ClientOutcome::StragglerCut],
            spans: vec![],
        };
        let mut enc = encode_reply(&reply);
        // The single outcome byte sits just before the empty span list
        // (u32 count) and the trailing delta tag.
        let pos = enc.len() - 6;
        assert_eq!(enc[pos], 2);
        enc[pos] = 9;
        assert!(decode_reply::<u64>(&enc).is_err());
    }

    #[test]
    fn span_bytes_reject_unknowns_and_inflated_counts() {
        let reply: ServerReply<u64> = ServerReply::Round {
            server_time: Duration::ZERO,
            delta: None,
            inter_sent: 0,
            outcomes: vec![],
            spans: vec![Span {
                phase: Phase::Eval,
                party: Party::S1,
                worker: Some(3),
                start_ns: 10,
                dur_ns: 20,
            }],
        };
        let enc = encode_reply(&reply);
        assert!(matches!(
            decode_reply::<u64>(&enc).unwrap(),
            ServerReply::Round { spans, .. } if spans == reply_spans(&reply)
        ));
        // First span byte: tag(1) + server_time(8) + inter(8) +
        // outcome count(4) + span count(4).
        let base = 25;
        for (delta, what) in [(0, "phase"), (1, "party"), (2, "worker tag")] {
            let mut bad = enc.clone();
            bad[base + delta] = 99;
            let err = decode_reply::<u64>(&bad).unwrap_err().to_string();
            assert!(err.contains("span"), "{what}: {err}");
        }
        // Inflate the declared span count past the wire cap.
        let mut bad = enc;
        bad[base - 4..base].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_reply::<u64>(&bad).unwrap_err().to_string();
        assert!(err.contains("wire cap"), "{err}");
    }

    fn reply_spans<G: Group>(r: &ServerReply<G>) -> Vec<Span> {
        match r {
            ServerReply::Round { spans, .. } => spans.clone(),
            _ => vec![],
        }
    }
}
