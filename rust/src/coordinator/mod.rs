//! The FSL round coordinator — two server threads, n clients, the full
//! Figure-1 loop: select → retrieve (PSR / broadcast) → local train (L2
//! artifact via PJRT) → top-k sparsify → SSA upload → reconstruct →
//! apply.
//!
//! Threading model: `S_0` (leader) and `S_1` (worker) each run on their
//! own thread, joined by metered channels ([`crate::net`]); clients run
//! on the driver thread (the paper's clients are sequential mobile
//! devices — their *per-client* times are what Table 5 reports).

mod client;
mod config;
mod psr_round;
mod round;
mod server;
mod topk;
mod verified;

pub use client::{local_train, sparse_delta, ClientRoundOutput};
pub use config::FslConfig;
pub use psr_round::{run_psr_round, run_psr_round_with, PsrRoundResult};
pub use round::{run_fsl_training, run_plain_training, RoundStats, TrainingLog};
pub use server::{run_ssa_round, run_ssa_round_with, SsaRoundResult};
pub use topk::{top_k_groups, top_k_magnitude};
pub use verified::{run_verified_ssa_round, VerifiedSsaResult};
