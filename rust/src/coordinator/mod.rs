//! The FSL round coordinator — two server threads, n clients, the full
//! Figure-1 loop: select → retrieve (PSR / broadcast) → local train (L2
//! artifact via PJRT) → top-k sparsify → SSA upload → reconstruct →
//! apply.
//!
//! Threading model: `S_0` (leader) and `S_1` (worker) each run on their
//! own thread, joined by metered channels ([`crate::net`]); clients run
//! on the driver thread (the paper's clients are sequential mobile
//! devices — their *per-client* times are what Table 5 reports).
//!
//! The two server threads are *persistent*: [`FslRuntimeBuilder`] builds
//! one [`FslRuntime`] whose command loop serves any number of rounds of
//! any type (`psr` / `ssa` / `verified_ssa` / `psu_align`), each
//! returning a uniform [`RoundReport`]. The old per-call `run_*_round`
//! free functions survive as `#[deprecated]` one-shot wrappers.
//!
//! The same runtime also drives *standalone* servers over framed TCP:
//! [`serve()`]/[`serve_addr`] host one `S_0` or `S_1` as its own OS
//! process (the `fsl serve` subcommand), and
//! [`FslRuntimeBuilder::connect`] returns a runtime whose rounds run
//! against two such processes — same protocol code, different
//! [`crate::net::transport::Transport`].

mod client;
mod config;
mod loadgen;
mod psr_round;
mod round;
mod runtime;
mod serve;
mod server;
pub mod snapshot;
mod topk;
mod verified;
pub mod wire;

pub use client::{local_train, sparse_delta, ClientRoundOutput};
pub use config::FslConfig;
pub use loadgen::{run_loadgen, LoadgenOptions, LoadgenReport, LoadgenVerify};
pub use serve::{serve, serve_addr, ServeOptions};
// lint: allow(deprecated) — re-export keeps the legacy round API importable
#[allow(deprecated)]
pub use psr_round::{run_psr_round, run_psr_round_with, PsrRoundResult};
pub use round::{run_fsl_training, run_plain_training, RoundStats, TrainingLog};
pub use runtime::{
    ClientOutcome, FslRuntime, FslRuntimeBuilder, KeyMode, PsrOutcome, PsuOutcome, RoundKind,
    RoundReport, ServerStats, SsaOutcome, UdpfDriverState, VerifiedSsaOutcome,
};
// lint: allow(deprecated) — re-export keeps the legacy round API importable
#[allow(deprecated)]
pub use server::{run_ssa_round, run_ssa_round_with, SsaRoundResult};
pub use topk::{top_k_groups, top_k_magnitude};
// lint: allow(deprecated) — re-export keeps the legacy round API importable
#[allow(deprecated)]
pub use verified::{run_verified_ssa_round, VerifiedSsaResult};
