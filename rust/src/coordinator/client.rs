//! Client-side round work: local training (L2 artifact through PJRT) and
//! sparse-update construction.

use super::topk::top_k_magnitude;
use crate::crypto::rng::Rng;
use crate::group::fixed_encode;
use crate::runtime::Executor;
use anyhow::Result;

/// What a client hands to the SSA layer after local work.
#[derive(Debug, Clone)]
pub struct ClientRoundOutput {
    /// Ascending selected indices (the submodel `s^(i)`).
    pub selections: Vec<u64>,
    /// Fixed-point encoded updates, aligned with `selections`.
    pub deltas: Vec<u64>,
    /// Mean training loss over the local iterations.
    pub loss: f32,
}

/// Run `local_iters` SGD steps on this client's shard and return the
/// dense parameter delta (new − start) plus the mean loss.
///
/// `batch_of` supplies `(x, y_onehot)` for a requested iteration — the
/// datasets differ between tasks, the loop does not.
pub fn local_train(
    exec: &Executor,
    artifact: &str,
    start: &[f32],
    local_iters: usize,
    lr: f32,
    mut batch_of: impl FnMut(usize, &mut Rng) -> (Vec<f32>, Vec<f32>),
    rng: &mut Rng,
) -> Result<(Vec<f32>, f32)> {
    let mut params = start.to_vec();
    let mut loss_sum = 0.0f32;
    for it in 0..local_iters {
        let (x, y) = batch_of(it, rng);
        let step = exec.train_step(artifact, &params, &x, &y)?;
        loss_sum += step.loss;
        for (p, g) in params.iter_mut().zip(&step.grad) {
            *p -= lr * g;
        }
    }
    let delta: Vec<f32> = params
        .iter()
        .zip(start)
        .map(|(new, old)| new - old)
        .collect();
    Ok((delta, loss_sum / local_iters.max(1) as f32))
}

/// Top-k sparsify a dense delta into the SSA client input (selections +
/// fixed-point payloads).
pub fn sparse_delta(delta: &[f32], k: usize) -> ClientRoundOutput {
    let selections = top_k_magnitude(delta, k);
    let deltas = selections
        .iter()
        .map(|&i| fixed_encode(delta[i as usize]))
        .collect();
    ClientRoundOutput {
        selections,
        deltas,
        loss: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::group::fixed_decode;

    #[test]
    fn sparse_delta_roundtrips_values() {
        let delta = vec![0.0f32, 2.5, -0.25, 0.0, 0.125];
        let out = sparse_delta(&delta, 2);
        assert_eq!(out.selections, vec![1, 2]);
        assert!((fixed_decode(out.deltas[0]) - 2.5).abs() < 1e-6);
        assert!((fixed_decode(out.deltas[1]) + 0.25).abs() < 1e-6);
    }
}
