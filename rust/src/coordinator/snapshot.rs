//! Versioned server-state snapshots — what lets a crashed `fsl serve`
//! process restart mid-session without losing the U-DPF epoch keys.
//!
//! A snapshot captures one server's round-spanning state: the installed
//! [`Session`] (as its [`wire::encode_session`] bytes, so restore equals
//! a fresh install), the retained U-DPF key sets with their client link
//! indices, the setup cohort size, and the eviction record. The PSR
//! weight vector is deliberately *not* captured — it is driver-supplied
//! bulk data the driver re-installs in one command, while the U-DPF keys
//! are the accumulated product of every past epoch and cannot be
//! regenerated.
//!
//! **Integrity.** Every section carries a SHA-256 of its payload and the
//! whole file ends with a SHA-256 over everything before it. `load`
//! verifies all hashes *before* constructing anything: a corrupt or
//! truncated snapshot yields a typed [`SnapshotError`] and no partial
//! restore — restarting with bad state would silently corrupt every
//! later epoch, which is strictly worse than failing loudly.
//!
//! **Consistency.** [`super::serve`] writes the snapshot only after a
//! command *succeeds* (and before its reply is sent), so a server that
//! dies mid-round persists the state from the last completed round. Both
//! servers restored from such snapshots sit at the same epoch boundary,
//! and because a U-DPF hint *replaces* its key's output correction word
//! (it is not a delta), retrying the interrupted epoch against restored
//! keys is exact.

use super::wire;
use crate::group::Group;
use crate::protocol::msg;
use crate::udpf::UdpfKey;
use sha2::{Digest, Sha256};
use std::fmt;
use std::path::Path;

const MAGIC: [u8; 4] = *b"FSLS";
const VERSION: u16 = 1;
const HASH_LEN: usize = 32;

/// Why a snapshot failed to load. Every variant means "no state was
/// restored" — there is no partial restore.
#[derive(Debug)]
pub enum SnapshotError {
    /// The file does not start with the snapshot magic.
    BadMagic,
    /// The file's format version is not one this build reads.
    BadVersion(u16),
    /// The file ends before its declared contents do.
    Truncated,
    /// A content hash check failed (names the failing section, or
    /// "file" for the whole-file trailer).
    HashMismatch(String),
    /// Hashes passed but a section's contents do not decode.
    Malformed(String),
    /// The file could not be read or written.
    Io(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::BadMagic => write!(f, "not a snapshot file (bad magic)"),
            SnapshotError::BadVersion(v) => write!(f, "unsupported snapshot version {v}"),
            SnapshotError::Truncated => write!(f, "snapshot file is truncated"),
            SnapshotError::HashMismatch(what) => {
                write!(f, "snapshot hash mismatch in {what} (refusing partial restore)")
            }
            SnapshotError::Malformed(what) => write!(f, "malformed snapshot: {what}"),
            SnapshotError::Io(e) => write!(f, "snapshot io: {e}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

/// One server's persisted round-spanning state.
///
/// Not `Debug`: it holds the retained U-DPF keys, whose root seeds are
/// secret (see the `SECRET_TYPES` manifest in `xtask`).
#[derive(Clone)]
pub struct ServerSnapshot<G: Group> {
    /// Which server this is (`0` leader, `1` worker) — a snapshot must
    /// never be restored into the other party.
    pub party: u8,
    /// The payload group's name ([`std::any::type_name`], the same
    /// string the transport handshake checks).
    pub group: String,
    /// The installed session as [`wire::encode_session`] bytes.
    pub session: Vec<u8>,
    /// Client count of the U-DPF setup round (`0` = no U-DPF state).
    pub udpf_total: usize,
    /// Retained U-DPF key sets: `(client link index, keys)`, survivors
    /// only, in link order.
    pub udpf: Vec<(u32, Vec<UdpfKey<G>>)>,
    /// Eviction record, indexed by client link.
    pub dead: Vec<bool>,
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_block(out: &mut Vec<u8>, block: &[u8]) {
    put_u32(out, block.len() as u32);
    out.extend_from_slice(block);
}

fn sha256(bytes: &[u8]) -> [u8; HASH_LEN] {
    let mut h = Sha256::new();
    h.update(bytes);
    h.finalize().into()
}

/// A cursor over untrusted bytes whose every read is bounds-checked into
/// [`SnapshotError::Truncated`].
struct Reader<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8], SnapshotError> {
        let s = self
            .bytes
            .get(self.off..self.off.checked_add(len).ok_or(SnapshotError::Truncated)?)
            .ok_or(SnapshotError::Truncated)?;
        self.off += len;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, SnapshotError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, SnapshotError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn block(&mut self) -> Result<&'a [u8], SnapshotError> {
        let len = self.u32()? as usize;
        self.take(len)
    }
}

impl<G: Group> ServerSnapshot<G> {
    /// Serialise: header, named+hashed sections, whole-file hash trailer.
    pub fn encode(&self) -> Vec<u8> {
        let mut sections: Vec<(&str, Vec<u8>)> = Vec::new();
        sections.push(("session", self.session.clone()));
        let mut udpf = Vec::new();
        put_u64(&mut udpf, self.udpf_total as u64);
        put_u32(&mut udpf, self.udpf.len() as u32);
        for (link, keys) in &self.udpf {
            put_u32(&mut udpf, *link);
            put_block(&mut udpf, &msg::encode_udpf_keys(keys));
        }
        sections.push(("udpf", udpf));
        let mut dead = Vec::new();
        put_u32(&mut dead, self.dead.len() as u32);
        dead.extend(self.dead.iter().map(|d| *d as u8));
        sections.push(("dead", dead));

        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.party);
        put_block(&mut out, self.group.as_bytes());
        put_u32(&mut out, sections.len() as u32);
        for (name, payload) in &sections {
            put_block(&mut out, name.as_bytes());
            put_block(&mut out, payload);
            out.extend_from_slice(&sha256(payload));
        }
        let trailer = sha256(&out);
        out.extend_from_slice(&trailer);
        out
    }

    /// Parse and verify. All hashes are checked before any section is
    /// decoded; any failure returns a typed error and restores nothing.
    pub fn decode(bytes: &[u8]) -> Result<Self, SnapshotError> {
        // Whole-file hash first: any single corrupted byte anywhere is
        // caught here, before the structure is even looked at.
        if bytes.len() < MAGIC.len() + HASH_LEN {
            return Err(SnapshotError::Truncated);
        }
        if bytes[..4] != MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        let (body, trailer) = bytes.split_at(bytes.len() - HASH_LEN);
        if sha256(body) != *trailer {
            return Err(SnapshotError::HashMismatch("file".into()));
        }
        let mut r = Reader { bytes: body, off: 4 };
        let v = r.take(2)?;
        let version = u16::from_le_bytes([v[0], v[1]]);
        if version != VERSION {
            return Err(SnapshotError::BadVersion(version));
        }
        let party = r.take(1)?[0];
        let group = String::from_utf8(r.block()?.to_vec())
            .map_err(|_| SnapshotError::Malformed("group name is not utf-8".into()))?;
        let n_sections = r.u32()? as usize;
        let mut session: Option<Vec<u8>> = None;
        let mut udpf_total = 0usize;
        let mut udpf: Vec<(u32, Vec<UdpfKey<G>>)> = Vec::new();
        let mut dead: Vec<bool> = Vec::new();
        for _ in 0..n_sections {
            let name = String::from_utf8(r.block()?.to_vec())
                .map_err(|_| SnapshotError::Malformed("section name is not utf-8".into()))?;
            let payload = r.block()?;
            let hash = r.take(HASH_LEN)?;
            if sha256(payload) != *hash {
                return Err(SnapshotError::HashMismatch(format!("section `{name}`")));
            }
            match name.as_str() {
                "session" => {
                    // Validate it parses; the raw bytes are what restore
                    // compares against the driver's install.
                    wire::decode_session(payload)
                        .map_err(|e| SnapshotError::Malformed(format!("session: {e}")))?;
                    session = Some(payload.to_vec());
                }
                "udpf" => {
                    let mut s = Reader { bytes: payload, off: 0 };
                    udpf_total = s.u64()? as usize;
                    let count = s.u32()? as usize;
                    for _ in 0..count {
                        let link = s.u32()?;
                        let keys = msg::decode_udpf_keys::<G>(s.block()?).ok_or_else(|| {
                            SnapshotError::Malformed("undecodable U-DPF key set".into())
                        })?;
                        udpf.push((link, keys));
                    }
                }
                "dead" => {
                    let mut s = Reader { bytes: payload, off: 0 };
                    let n = s.u32()? as usize;
                    dead = s.take(n)?.iter().map(|b| *b != 0).collect();
                }
                // Unknown sections are hash-checked but otherwise
                // skipped: a newer writer may add some.
                _ => {}
            }
        }
        let session = session
            .ok_or_else(|| SnapshotError::Malformed("missing session section".into()))?;
        Ok(ServerSnapshot {
            party,
            group,
            session,
            udpf_total,
            udpf,
            dead,
        })
    }

    /// Write atomically: encode to `<path>.tmp`, then rename over `path`
    /// — a crash mid-write leaves the previous snapshot intact, never a
    /// half-written file.
    pub fn write(&self, path: &Path) -> Result<(), SnapshotError> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.encode()).map_err(|e| SnapshotError::Io(e.to_string()))?;
        std::fs::rename(&tmp, path).map_err(|e| SnapshotError::Io(e.to_string()))
    }

    /// Read and verify a snapshot file.
    pub fn load(path: &Path) -> Result<Self, SnapshotError> {
        let bytes = std::fs::read(path).map_err(|e| SnapshotError::Io(e.to_string()))?;
        Self::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;
    use crate::hashing::CuckooParams;
    use crate::protocol::{udpf_ssa, Session, SessionParams};

    fn sample() -> ServerSnapshot<u64> {
        let session = Session::new_full(SessionParams {
            m: 256,
            k: 8,
            cuckoo: CuckooParams::default(),
        });
        let mut rng = Rng::new(7);
        let (_, k0, _k1) =
            udpf_ssa::client_setup::<u64>(&session, &[1, 5, 9], &[10, 20, 30], &mut rng).unwrap();
        ServerSnapshot {
            party: 0,
            group: std::any::type_name::<u64>().to_string(),
            session: wire::encode_session(&session),
            udpf_total: 4,
            udpf: vec![(2, k0.keys)],
            dead: vec![false, true, false, false],
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let snap = sample();
        let back = ServerSnapshot::<u64>::decode(&snap.encode()).unwrap();
        assert_eq!(back.party, 0);
        assert_eq!(back.group, snap.group);
        assert_eq!(back.session, snap.session);
        assert_eq!(back.udpf_total, 4);
        assert_eq!(back.udpf.len(), 1);
        assert_eq!(back.udpf[0].0, 2);
        assert_eq!(back.udpf[0].1.len(), snap.udpf[0].1.len());
        // Key material (root seed inside its Sensitive wrapper included)
        // must survive the save/restore cycle bit-identically.
        for (a, b) in snap.udpf[0].1.iter().zip(&back.udpf[0].1) {
            assert_eq!(a.inner.to_bytes(), b.inner.to_bytes());
            assert_eq!(*a.inner.root_seed, *b.inner.root_seed);
        }
        assert_eq!(back.dead, snap.dead);
    }

    #[test]
    fn every_single_byte_flip_is_rejected() {
        let enc = sample().encode();
        for i in 0..enc.len() {
            let mut bad = enc.clone();
            bad[i] ^= 0x01;
            assert!(
                ServerSnapshot::<u64>::decode(&bad).is_err(),
                "flip at byte {i} went unnoticed"
            );
        }
    }

    #[test]
    fn every_truncation_is_rejected() {
        let enc = sample().encode();
        for len in 0..enc.len() {
            assert!(
                ServerSnapshot::<u64>::decode(&enc[..len]).is_err(),
                "truncation to {len} bytes went unnoticed"
            );
        }
    }

    #[test]
    fn hash_mismatch_is_typed_not_partial() {
        let enc = sample().encode();
        let mut bad = enc.clone();
        let mid = enc.len() / 2;
        bad[mid] ^= 0xFF;
        match ServerSnapshot::<u64>::decode(&bad) {
            Err(SnapshotError::HashMismatch(_)) => {}
            other => panic!("expected HashMismatch, got {other:?}"),
        }
    }

    #[test]
    fn atomic_write_then_load() {
        let dir = std::env::temp_dir().join(format!("fsl-snap-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s0.snap");
        let snap = sample();
        snap.write(&path).unwrap();
        let back = ServerSnapshot::<u64>::load(&path).unwrap();
        assert_eq!(back.session, snap.session);
        assert!(!path.with_extension("tmp").exists(), "tmp file left behind");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wrong_version_and_magic_are_typed() {
        let enc = sample().encode();
        let mut wrong_magic = enc.clone();
        wrong_magic[0] = b'X';
        assert!(matches!(
            ServerSnapshot::<u64>::decode(&wrong_magic),
            // The file hash covers the magic too, but magic is checked
            // first: either way the load fails before any restore.
            Err(SnapshotError::BadMagic | SnapshotError::HashMismatch(_))
        ));
        assert!(matches!(
            ServerSnapshot::<u64>::decode(b"FS"),
            Err(SnapshotError::Truncated)
        ));
    }
}
