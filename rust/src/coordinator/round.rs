//! The end-to-end FSL training loop (Fig. 1).

use super::client::{local_train, sparse_delta};
use super::config::FslConfig;
use super::runtime::FslRuntimeBuilder;
use crate::crypto::rng::Rng;
use crate::group::fixed_decode;
use crate::runtime::Executor;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Per-round record (printed by the examples, logged in EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct RoundStats {
    pub round: usize,
    pub mean_loss: f32,
    pub upload_mb_per_client: f64,
    pub gen_time: Duration,
    pub server_time: Duration,
    pub train_time: Duration,
    pub accuracy: Option<f32>,
}

/// Whole-run record.
#[derive(Debug, Clone, Default)]
pub struct TrainingLog {
    pub rounds: Vec<RoundStats>,
    pub final_params: Vec<f32>,
}

impl TrainingLog {
    /// Final evaluated accuracy, if any round evaluated.
    pub fn last_accuracy(&self) -> Option<f32> {
        self.rounds.iter().rev().find_map(|r| r.accuracy)
    }
}

/// Drive the full secure-FSL training loop.
///
/// * `batch_of(client, iter, rng)` supplies local batches.
/// * `eval_fn(params)` returns test accuracy when invoked (every
///   `cfg.eval_every` rounds and on the last round).
///
/// Each round: sample participants → local SGD (PJRT train-step artifact)
/// → top-k sparsify → SSA through one persistent [`super::FslRuntime`] →
/// FedAvg apply.
pub fn run_fsl_training(
    exec: &Executor,
    cfg: &FslConfig,
    train_artifact: &str,
    mut params: Vec<f32>,
    mut batch_of: impl FnMut(usize, usize, &mut Rng) -> (Vec<f32>, Vec<f32>),
    mut eval_fn: impl FnMut(&[f32]) -> Result<f32>,
    mut on_round: impl FnMut(&RoundStats),
) -> Result<TrainingLog> {
    let m = params.len();
    let mut log = TrainingLog::default();

    // One runtime per task: the paper reuses T_cuckoo/T_simple across
    // rounds (§4) — the hash functions are public parameters, and
    // rebuilding the simple table per round costs ~0.5 s at m ≈ 2 * 10^6
    // (§Perf iteration 4). The runtime additionally keeps the two server
    // threads, channels, and engines alive for the whole task.
    let mut rt = FslRuntimeBuilder::from_config(cfg, m as u64)?.build::<u64>()?;

    for round in 0..cfg.rounds {
        let mut rng = Rng::new(cfg.seed ^ (round as u64).wrapping_mul(0x9e37_79b9));
        let lr = cfg.lr_at(round);

        // Client selection.
        let p = cfg.participants();
        let participants = rng.sample_distinct(p, cfg.num_clients as u64);

        // Local training + top-k sparsification.
        let k = rt.session().params.k;
        let t_train = Instant::now();
        let mut client_inputs: Vec<(Vec<u64>, Vec<u64>)> = Vec::with_capacity(p);
        let mut loss_sum = 0.0f32;
        for &c in &participants {
            let (delta, loss) = local_train(
                exec,
                train_artifact,
                &params,
                cfg.local_iters,
                lr,
                |it, r| batch_of(c as usize, it, r),
                &mut rng,
            )?;
            loss_sum += loss;
            let out = sparse_delta(&delta, k);
            client_inputs.push((out.selections, out.deltas));
        }
        let train_time = t_train.elapsed();

        // Secure aggregation round over the persistent runtime.
        let res = rt.ssa(&client_inputs, &mut rng)?;

        // FedAvg apply: params += decode(Δw) / P.
        let scale = 1.0 / p as f32;
        for (w, d) in params.iter_mut().zip(&res.delta) {
            if *d != 0 {
                *w += fixed_decode(*d) * scale;
            }
        }

        let do_eval = (cfg.eval_every > 0 && (round + 1) % cfg.eval_every == 0)
            || round + 1 == cfg.rounds;
        let accuracy = if do_eval { Some(eval_fn(&params)?) } else { None };

        let stats = RoundStats {
            round,
            mean_loss: loss_sum / p as f32,
            upload_mb_per_client: crate::metrics::mb(res.report.client_upload_bytes) / p as f64,
            gen_time: res.report.gen_time,
            server_time: res.report.server_time,
            train_time,
            accuracy,
        };
        on_round(&stats);
        log.rounds.push(stats);
    }
    log.final_params = params;
    Ok(log)
}

/// Non-secure reference loop (plaintext FedAvg with the same top-k) —
/// used by tests and the ablation bench to show the secure path is
/// *lossless*: both loops produce bit-identical models given the same
/// seeds, because SSA reconstructs exactly the fixed-point top-k sums.
pub fn run_plain_training(
    exec: &Executor,
    cfg: &FslConfig,
    train_artifact: &str,
    mut params: Vec<f32>,
    mut batch_of: impl FnMut(usize, usize, &mut Rng) -> (Vec<f32>, Vec<f32>),
) -> Result<Vec<f32>> {
    let m = params.len();
    let k = ((m as f64 * cfg.compression).round() as usize).clamp(1, m);
    for round in 0..cfg.rounds {
        let mut rng = Rng::new(cfg.seed ^ (round as u64).wrapping_mul(0x9e37_79b9));
        let lr = cfg.lr_at(round);
        let p = cfg.participants();
        let participants = rng.sample_distinct(p, cfg.num_clients as u64);
        let mut sum = vec![0u64; m];
        for &c in &participants {
            let (delta, _) = local_train(
                exec,
                train_artifact,
                &params,
                cfg.local_iters,
                lr,
                |it, r| batch_of(c as usize, it, r),
                &mut rng,
            )?;
            let out = sparse_delta(&delta, k);
            // Ring addition in Z_2^64: wrapping explicitly — a bare `+`
            // panics on the two's-complement encodings of negative deltas
            // under debug overflow checks.
            for (&i, &d) in out.selections.iter().zip(&out.deltas) {
                sum[i as usize] = sum[i as usize].wrapping_add(d);
            }
        }
        // Burn the same RNG draws the secure path spends on DPF seeds is
        // not needed: SSA randomness does not influence the model.
        let scale = 1.0 / p as f32;
        for (w, d) in params.iter_mut().zip(&sum) {
            if *d != 0 {
                *w += fixed_decode(*d) * scale;
            }
        }
    }
    Ok(params)
}
