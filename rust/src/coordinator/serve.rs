//! Standalone FSL server: one `S_0` or `S_1` as its own OS process,
//! serving a [`super::FslRuntimeBuilder::connect`] driver over framed TCP
//! (the `fsl serve` CLI subcommand is a thin wrapper around [`serve`]).
//!
//! One call to [`serve`] hosts one *deployment*: it accepts the driver's
//! control channel, the client data links, and (for `S_0`) the peer
//! server's exchange link, installs the driver's session, and then runs
//! the same command dispatch as the in-process server threads
//! ([`super::runtime`]'s `ServerHalf::handle`) until the driver shuts the
//! deployment down or disconnects. Connection-level mistakes — wrong
//! server address, payload-group mismatch, stale binary — are rejected at
//! the handshake with a readable reason sent back to the dialler.
//!
//! The accept phase is readiness-driven: every incoming connection is
//! registered with a [`FramePump`] and its handshake frame is collected
//! as it completes, so links may arrive concurrently and **in any
//! order**. The only ordering constraint is semantic: a data link can
//! only be *admitted* once the control handshake has announced the
//! deployment's shape, so early data links are parked and admitted the
//! moment control lands. A connection that stalls mid-handshake, sends
//! garbage, or cannot be acked loses only itself — the deployment keeps
//! accepting.
//!
//! Client links come in two shapes, never mixed within one deployment:
//!
//! * **direct** ([`Role::Client`]) — one socket per client, the
//!   historical per-client topology;
//! * **multiplexed** ([`Role::ClientMux`]) — one socket carries a
//!   contiguous range of virtual clients (`fsl loadgen`'s topology),
//!   letting a cohort of 10⁵–10⁶ clients ride on a bounded socket pool.

use super::runtime::{MuxCohort, MuxLane, ServerHalf, ServerMetrics};
use super::snapshot::ServerSnapshot;
use super::wire::{self, ServerCmd, ServerReply};
use crate::group::Group;
use crate::metrics::expo;
use crate::metrics::registry::{Counter, MetricsRegistry};
use crate::metrics::trace::{self, Party, PhaseMetrics, TraceRecorder, TraceSink};
use crate::metrics::CommMeter;
use crate::net::reactor::{Backoff, FramePump, PumpEvent, PumpMetrics};
use crate::net::transport::tcp::{TcpAcceptor, TcpOptions, TcpTransport};
use crate::net::transport::{BoxTransport, Hello, HelloAck, Role, Transport as _};
use crate::protocol::{msg, udpf_ssa, AggregationEngine, RetrievalEngine, Sharding};
use anyhow::{bail, ensure, Result};
use std::io::Write as _;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Knobs for one standalone server.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Which server this process is (0 = leader, 1 = worker).
    pub party: u8,
    /// Engine workers: an explicit count, or `0` for one per core — a
    /// standalone server owns its whole machine, unlike the co-located
    /// in-process pair.
    pub threads: usize,
    /// Bound on every data-link receive mid-round (a silent client or
    /// peer fails the round, not the deployment).
    pub data_timeout: Duration,
    /// Socket options (handshake timeout, write timeout).
    pub tcp: TcpOptions,
    /// Crash-recovery snapshot file. When set, the server persists its
    /// round-spanning state (session, U-DPF epoch keys, evictions) after
    /// every state-changing command, and restores it at startup if the
    /// file exists — a corrupt snapshot is a typed startup error, never a
    /// partial restore.
    pub snapshot: Option<PathBuf>,
    /// Ceiling on this deployment's *sockets* (direct client links, or
    /// multiplexed lanes). Clamped at accept time against the process's
    /// file-descriptor soft limit (with headroom for the control, peer,
    /// snapshot, and engine fds), so a driver asking for more links than
    /// the OS will grant is rejected with a reasoned ack instead of
    /// failing mid-deployment on `EMFILE`.
    pub max_client_links: u32,
    /// Per-round ingest budget in bytes: the bound on upload payloads
    /// held in memory awaiting commit plus frames in flight through the
    /// pump. Backpressure pauses lane reads at the bound, so a server's
    /// working memory stays O(domain + budget) regardless of cohort
    /// size.
    pub ingest_budget: usize,
}

impl ServeOptions {
    /// Defaults for `party` (auto engine width, 600 s data timeout,
    /// 4096-link ceiling, 64 MiB ingest budget).
    pub fn new(party: u8) -> Self {
        ServeOptions {
            party,
            threads: 0,
            data_timeout: Duration::from_secs(600),
            tcp: TcpOptions::default(),
            snapshot: None,
            max_client_links: 4096,
            ingest_budget: 64 << 20,
        }
    }
}

/// The control handshake's deployment shape.
struct ControlInfo {
    max_clients: usize,
    m: u64,
    k: u64,
}

/// Host one deployment on `acceptor` and serve it to completion.
/// Returns when the driver commands shutdown or its control channel
/// closes; handshake-phase failures (bind-level, not per-connection)
/// return an error.
pub fn serve<G: Group>(acceptor: &TcpAcceptor, opts: &ServeOptions) -> Result<()> {
    // One registry per server process, created before the accept phase
    // so the accept pump's frame counters and `Role::Stats` scrapes work
    // from the very first connection.
    let registry = MetricsRegistry::shared();
    // Load any prior snapshot *before* accepting connections: a corrupt
    // file must fail the restart loudly, not after a driver has dialled
    // in and committed to this process.
    let restored: Option<ServerSnapshot<G>> = match &opts.snapshot {
        Some(path) if path.exists() => {
            let snap = ServerSnapshot::<G>::load(path).map_err(|e| {
                anyhow::Error::new(e)
                    .context(format!("restoring server state from {}", path.display()))
            })?;
            ensure!(
                snap.party == opts.party,
                "snapshot {} belongs to S{} but this process serves S{}",
                path.display(),
                snap.party,
                opts.party
            );
            let ours = std::any::type_name::<G>();
            ensure!(
                snap.group == ours,
                "snapshot {} was written by a {} server, this one serves {ours}",
                path.display(),
                snap.group
            );
            Some(snap)
        }
        _ => None,
    };
    let dep = accept_deployment::<G>(acceptor, opts, &registry)?;
    let Deployment { ctrl, control, eps, mux, inter } = dep;
    // Mirror every link meter into monotonic registry counters (the
    // meters themselves reset per round; the mirrors never do).
    mirror_link(&registry, "ctrl", ctrl.meter());
    for ep in &eps {
        mirror_link(&registry, "client", ep.meter());
    }
    if let Some(inter) = &inter {
        mirror_link(&registry, "peer", inter.meter());
    }

    // The driver's first command installs the session it announced in the
    // control handshake (System Setup, Fig. 4 — run at deploy time).
    let first = ctrl
        .recv_timeout(opts.data_timeout)
        .map_err(|e| e.context("waiting for the driver's session install"))?;
    let session = match wire::decode_cmd::<G>(&first)? {
        ServerCmd::SetSession(s) => s,
        _ => {
            let _ = ctrl.send(wire::encode_reply::<G>(&ServerReply::Failed(
                "the first command must install the session".into(),
            )));
            bail!("driver's first command was not a session install");
        }
    };
    if session.params.m != control.m || session.params.k as u64 != control.k {
        let reason = format!(
            "installed session (m={}, k={}) does not match the control handshake \
             (m={}, k={})",
            session.params.m, session.params.k, control.m, control.k
        );
        let _ = ctrl.send(wire::encode_reply::<G>(&ServerReply::Failed(reason.clone())));
        bail!("{reason}");
    }

    let sharding = if opts.threads == 0 {
        Sharding::auto()
    } else {
        Sharding::new(opts.threads)
    };
    // One recorder per server process; `ServerHalf::handle` resets it at
    // round start and drains it into the round reply, so remote rounds
    // ship the same span stream the in-process runtime collects directly.
    let rec = TraceRecorder::shared(trace::DEFAULT_TRACE_CAPACITY);
    let sink = TraceSink::new(rec.clone(), Party::server(usize::from(opts.party)));
    rec.attach_metrics(PhaseMetrics::register(&registry));
    let metrics = ServerMetrics::register(&registry);
    let mut server = ServerHalf::<G> {
        party: opts.party,
        session,
        agg: AggregationEngine::with_sharding(sharding).with_trace(sink.clone()),
        ret: RetrievalEngine::with_sharding(sharding).with_trace(sink),
        trace: rec,
        eps,
        inter,
        mux,
        weights: None,
        udpf: Vec::new(),
        udpf_links: Vec::new(),
        udpf_total: 0,
        dead: Vec::new(),
        timeout: opts.data_timeout,
        registry: registry.clone(),
        metrics,
    };

    // Adopt the snapshot's retained state — but only if the driver just
    // installed the *same* session the snapshot was taken under (same
    // encoded bytes). A different session means a new deployment: start
    // clean, and the first snapshot write below overwrites the old file.
    if let Some(snap) = restored {
        if snap.session == wire::encode_session(&server.session) {
            ensure!(
                snap.udpf.iter().all(|(l, _)| (*l as usize) < server.eps.len()),
                "snapshot references client links beyond this deployment's capacity"
            );
            server.udpf_total = snap.udpf_total;
            for (link, keys) in snap.udpf {
                server.udpf_links.push(link as usize);
                server.udpf.push(udpf_ssa::UdpfSsaServerKeys { keys });
            }
            server.dead = snap.dead;
        }
    }
    let snap_meter = SnapshotMeter::register(&registry);
    // Persist the adopted-or-fresh state before acking the install: from
    // the driver's point of view, an acked install is always recoverable.
    if let Some(path) = &opts.snapshot {
        write_snapshot(&server, path, &snap_meter).map_err(|e| {
            anyhow::Error::new(e).context(format!("persisting state to {}", path.display()))
        })?;
    }
    ctrl.send(wire::encode_reply::<G>(&ServerReply::Ack))?;

    // Run the command loop under a scoped sidecar that keeps answering
    // `Role::Stats` scrapes on the listener: the loop blocks inside
    // `handle` for a whole round, so a mid-round scrape can only be
    // served out-of-band.
    let done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        scope.spawn(|| stats_responder::<G>(acceptor, &registry, opts, &done));
        let result = command_loop(&ctrl, &mut server, opts, &snap_meter);
        done.store(true, Ordering::Relaxed);
        result
    })
}

/// Registry handles for snapshot-persistence metering.
struct SnapshotMeter {
    writes: Counter,
    bytes: Counter,
}

impl SnapshotMeter {
    fn register(reg: &MetricsRegistry) -> Self {
        SnapshotMeter {
            writes: reg.counter(
                "fsl_snapshot_writes_total",
                "Recovery snapshots persisted by this server",
            ),
            bytes: reg.counter(
                "fsl_snapshot_bytes",
                "Bytes written across all recovery snapshots",
            ),
        }
    }
}

/// Persist `server`'s recovery snapshot to `path`, metering the write.
fn write_snapshot<G: Group>(
    server: &ServerHalf<G>,
    path: &std::path::Path,
    meter: &SnapshotMeter,
) -> Result<(), super::snapshot::SnapshotError> {
    snapshot_of(server).write(path)?;
    meter.writes.inc();
    if let Ok(md) = std::fs::metadata(path) {
        meter.bytes.add(md.len());
    }
    Ok(())
}

/// The remote command loop — the TCP twin of `ServerHalf::run`. Returns
/// when the driver commands shutdown or its control channel closes.
fn command_loop<G: Group>(
    ctrl: &BoxTransport,
    server: &mut ServerHalf<G>,
    opts: &ServeOptions,
    snap_meter: &SnapshotMeter,
) -> Result<()> {
    loop {
        let raw = match ctrl.recv() {
            Ok(raw) => raw,
            Err(_) => break, // driver gone: the deployment is over
        };
        let cmd = match wire::decode_cmd::<G>(&raw) {
            Ok(cmd) => cmd,
            Err(e) => {
                if ctrl
                    .send(wire::encode_reply::<G>(&ServerReply::Failed(e.to_string())))
                    .is_err()
                {
                    break;
                }
                continue;
            }
        };
        let reply = match cmd {
            ServerCmd::Shutdown => break,
            ServerCmd::DialPeer { addr } => {
                let hello = Hello {
                    party: 1 - opts.party,
                    role: Role::Peer,
                };
                match TcpTransport::connect(addr.as_str(), &hello, &opts.tcp) {
                    Ok(conn) => {
                        // Multiplexed rounds drive the exchange through
                        // the readiness pump, which needs its own OS
                        // handle on the peer socket.
                        if let Some(mux) = &mut server.mux {
                            mux.inter_stream = conn.stream_clone().ok();
                        }
                        mirror_link(&server.registry, "peer", conn.meter());
                        server.inter = Some(Box::new(conn));
                        ServerReply::Ack
                    }
                    Err(e) => ServerReply::Failed(format!("dialling peer at {addr}: {e}")),
                }
            }
            cmd => {
                // Rounds report the real S_0 ↔ S_1 bytes back to the
                // driver (which cannot see the peer link): reset the peer
                // meter at round start, stamp its sent-count into the
                // reply.
                let is_round = cmd.is_round();
                let changes_state = is_round || matches!(cmd, ServerCmd::SetSession(_));
                if is_round {
                    if let Some(inter) = &server.inter {
                        inter.meter().reset();
                    }
                }
                let mut reply = server
                    .handle(cmd)
                    .unwrap_or_else(|e| ServerReply::Failed(e.to_string()));
                if is_round {
                    if let ServerReply::Round { inter_sent, .. } = &mut reply {
                        *inter_sent =
                            server.inter.as_ref().map_or(0, |i| i.meter().sent());
                    }
                }
                // Snapshot-on-success, *before* the reply goes out: an
                // acked command is always recoverable, and a failed one
                // never persists tainted state.
                if changes_state && !matches!(reply, ServerReply::Failed(_)) {
                    if let Some(path) = &opts.snapshot {
                        if let Err(e) = write_snapshot(server, path, snap_meter) {
                            reply = ServerReply::Failed(format!(
                                "persisting the recovery snapshot failed: {e}"
                            ));
                        }
                    }
                }
                reply
            }
        };
        if ctrl.send(wire::encode_reply(&reply)).is_err() {
            break;
        }
    }
    Ok(())
}

/// Mirror one link meter into the per-link-class transport counters.
/// Registration is idempotent, so every link of a class feeds the same
/// cumulative pair; the mirror survives the meters' per-round resets.
fn mirror_link(reg: &MetricsRegistry, link: &'static str, meter: &CommMeter) {
    meter.mirror_into(
        reg.counter_with(
            "fsl_transport_sent_bytes",
            &[("link", link)],
            "Bytes sent per link class, cumulative across rounds",
        ),
        reg.counter_with(
            "fsl_transport_recv_bytes",
            &[("link", link)],
            "Bytes received per link class, cumulative across rounds",
        ),
    );
}

/// Answer one decoded command on a stats connection. Only `Stats` is
/// served — the connection has no standing in the deployment, so any
/// other command is refused without touching server state.
fn stats_reply_of<G: Group>(registry: &MetricsRegistry, raw: &[u8]) -> ServerReply<G> {
    match wire::decode_cmd::<G>(raw) {
        Ok(ServerCmd::Stats) => {
            let snaps = registry.snapshot();
            ServerReply::Stats {
                prom: expo::render_prom(&snaps),
                json: expo::render_json(&snaps),
            }
        }
        _ => ServerReply::Failed("only Stats is served on a stats connection".into()),
    }
}

/// Serve one already-handshaken `Role::Stats` connection: ack it
/// (echoing the *dialler's* party byte — a scraper doesn't have to know
/// which server it dialled), answer one `Stats` command, drop. Runs on
/// its own short-lived thread so a stalling scraper can never hold up
/// an accept loop; every read is bounded by the handshake timeout.
fn serve_stats_handshaken<G: Group>(
    stream: TcpStream,
    dialler_party: u8,
    registry: Arc<MetricsRegistry>,
    tcp: TcpOptions,
) {
    let Some(stream) = ack_stream(stream, dialler_party, None, &tcp) else {
        return;
    };
    let Ok(conn) = TcpTransport::from_stream(stream, &tcp) else {
        return;
    };
    let Ok(raw) = conn.recv_timeout(tcp.handshake_timeout) else {
        return;
    };
    let _ = conn.send(wire::encode_reply(&stats_reply_of::<G>(&registry, &raw)));
}

/// The post-accept listener sidecar: once the deployment has assembled,
/// nothing else accepts on the bound address, so this loop keeps serving
/// `Role::Stats` scrapes (mid-round included — the command loop blocks
/// inside `handle` for a whole round) until the deployment ends. Any
/// non-stats dialler is rejected with a reasoned ack.
fn stats_responder<G: Group>(
    acceptor: &TcpAcceptor,
    registry: &Arc<MetricsRegistry>,
    opts: &ServeOptions,
    done: &AtomicBool,
) {
    while !done.load(Ordering::Relaxed) {
        match acceptor.accept_raw() {
            Ok(Some((stream, _from))) => {
                // Read the framed hello directly: one connection at a
                // time here, each read bounded by the handshake timeout.
                let hello = TcpTransport::from_stream(stream, &opts.tcp)
                    .and_then(|conn| {
                        let raw = conn.recv_timeout(opts.tcp.handshake_timeout)?;
                        Ok((conn.stream_clone()?, Hello::decode(&raw)?))
                    });
                let Ok((stream, hello)) = hello else { continue };
                match hello.role {
                    Role::Stats => {
                        let registry = registry.clone();
                        let tcp = opts.tcp.clone();
                        std::thread::spawn(move || {
                            serve_stats_handshaken::<G>(stream, hello.party, registry, tcp);
                        });
                    }
                    _ => reject(
                        stream,
                        opts.party,
                        "this deployment is already assembled — only stats \
                         connections are accepted now"
                            .into(),
                        &opts.tcp,
                    ),
                }
            }
            Ok(None) => std::thread::sleep(Duration::from_millis(25)),
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
}

/// The snapshot of one server's current round-spanning state.
fn snapshot_of<G: Group>(server: &ServerHalf<G>) -> ServerSnapshot<G> {
    ServerSnapshot {
        party: server.party,
        group: std::any::type_name::<G>().to_string(),
        session: wire::encode_session(&server.session),
        udpf_total: server.udpf_total,
        udpf: server
            .udpf
            .iter()
            .zip(&server.udpf_links)
            .map(|(ks, link)| (*link as u32, ks.keys.clone()))
            .collect(),
        dead: server.dead.clone(),
    }
}

/// A fully accepted deployment, ready to serve.
struct Deployment {
    ctrl: BoxTransport,
    control: ControlInfo,
    /// Direct per-client links (empty for a multiplexed deployment).
    eps: Vec<BoxTransport>,
    /// Multiplexed lane cohort (`None` for a direct deployment).
    mux: Option<MuxCohort>,
    /// The peer exchange link (`S_0` only, and only if it arrived during
    /// the accept phase — the driver may instead command `DialPeer`
    /// later, which is the normal path).
    inter: Option<BoxTransport>,
}

/// Which client-link shape this deployment committed to. The first
/// admitted data link decides; mixing is a wiring error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LinkMode {
    Direct,
    Mux,
}

/// Accumulator for the accept phase: connections land in any order and
/// fill this in until [`complete`] says the deployment is whole.
struct PendingDeployment {
    ctrl: Option<BoxTransport>,
    control: Option<ControlInfo>,
    direct: Vec<Option<BoxTransport>>,
    filled: usize,
    lanes: Vec<MuxLane>,
    /// Per-virtual-client coverage map for multiplexed lanes (overlap
    /// detection without sorting lane ranges).
    covered: Vec<bool>,
    covered_count: usize,
    mode: Option<LinkMode>,
    inter: Option<BoxTransport>,
    inter_raw: Option<TcpStream>,
    /// Data links that arrived before the control handshake announced
    /// the deployment's shape: admitted the moment control lands.
    parked: Vec<(TcpStream, Hello)>,
}

/// The process's soft file-descriptor limit, if the platform exposes it
/// (`/proc/self/limits`; `None` elsewhere or for "unlimited").
fn fd_soft_limit() -> Option<u64> {
    let limits = std::fs::read_to_string("/proc/self/limits").ok()?;
    let line = limits.lines().find(|l| l.starts_with("Max open files"))?;
    line["Max open files".len()..]
        .split_whitespace()
        .next()?
        .parse()
        .ok()
}

/// The link ceiling this deployment actually enforces: the configured
/// [`ServeOptions::max_client_links`], clamped to what the process's fd
/// soft limit can honour (keeping 64 fds of headroom for the control
/// link, peer link, listener, snapshot file, and engine internals).
fn effective_link_ceiling(opts: &ServeOptions) -> u32 {
    let requested = opts.max_client_links.max(1);
    match fd_soft_limit() {
        Some(fds) => {
            let headroom = fds.saturating_sub(64).max(16);
            requested.min(u32::try_from(headroom).unwrap_or(u32::MAX))
        }
        None => requested,
    }
}

/// Deliver a handshake ack on a raw accepted stream. Returns the stream
/// only for a successful *accepting* ack: a rejection closes the
/// connection, and a client whose ack cannot be delivered (it hung up,
/// its buffer is wedged) loses only its own connection — the accept
/// loop keeps serving everyone else.
fn ack_stream(
    mut stream: TcpStream,
    party: u8,
    error: Option<String>,
    tcp: &TcpOptions,
) -> Option<TcpStream> {
    let rejecting = error.is_some();
    let ack = HelloAck { party, error };
    if stream
        .set_write_timeout(Some(tcp.handshake_timeout))
        .is_err()
    {
        return None;
    }
    if stream.write_all(&msg::frame(&ack.encode())).is_err() {
        return None;
    }
    if rejecting {
        None
    } else {
        Some(stream)
    }
}

/// Reject a handshake with a reasoned ack and drop the connection.
fn reject(stream: TcpStream, party: u8, reason: String, tcp: &TcpOptions) {
    drop(ack_stream(stream, party, Some(reason), tcp));
}

/// Park a pre-control data link, bounded so a flood of early dials
/// cannot balloon memory while the control handshake is missing.
fn park(
    pend: &mut PendingDeployment,
    ceiling: u32,
    stream: TcpStream,
    hello: Hello,
    opts: &ServeOptions,
) {
    if pend.parked.len() >= ceiling as usize + 16 {
        reject(
            stream,
            opts.party,
            "server busy: too many connections waiting ahead of the control handshake".into(),
            &opts.tcp,
        );
    } else {
        pend.parked.push((stream, hello));
    }
}

/// Admit one handshaken connection into the pending deployment, acking
/// or rejecting it. Per-connection failures never propagate: a link
/// that cannot be acked or wrapped is dropped and the phase continues.
fn admit<G: Group>(
    pend: &mut PendingDeployment,
    ceiling: u32,
    stream: TcpStream,
    hello: Hello,
    opts: &ServeOptions,
) {
    if hello.party != opts.party {
        reject(
            stream,
            opts.party,
            format!(
                "party mismatch: dialled S{} but this process serves S{}",
                hello.party, opts.party
            ),
            &opts.tcp,
        );
        return;
    }
    match hello.role.clone() {
        Role::Control { .. } => {
            if pend.control.is_some() {
                reject(
                    stream,
                    opts.party,
                    "a control connection is already driving this deployment".into(),
                    &opts.tcp,
                );
                return;
            }
            let info = match validate_control::<G>(&hello, opts) {
                Ok(info) => info,
                Err(reason) => {
                    reject(stream, opts.party, reason, &opts.tcp);
                    return;
                }
            };
            let Some(stream) = ack_stream(stream, opts.party, None, &opts.tcp) else {
                return;
            };
            let Ok(conn) = TcpTransport::from_stream(stream, &opts.tcp) else {
                return;
            };
            pend.direct = (0..info.max_clients).map(|_| None).collect();
            pend.covered = vec![false; info.max_clients];
            pend.ctrl = Some(Box::new(conn));
            pend.control = Some(info);
            // Control has announced the shape: everything parked ahead
            // of it can now be judged (parked never holds a Control, so
            // this recursion is one level deep).
            for (s, h) in std::mem::take(&mut pend.parked) {
                admit::<G>(pend, ceiling, s, h, opts);
            }
        }
        Role::Client { id } => {
            if pend.control.is_none() {
                park(pend, ceiling, stream, hello, opts);
                return;
            }
            if pend.mode == Some(LinkMode::Mux) {
                reject(
                    stream,
                    opts.party,
                    "this deployment already uses multiplexed lanes — direct client \
                     links cannot join it"
                        .into(),
                    &opts.tcp,
                );
                return;
            }
            let n = pend.direct.len();
            if n as u64 > u64::from(ceiling) {
                reject(
                    stream,
                    opts.party,
                    format!(
                        "a direct link per client would need {n} sockets, over this \
                         server's link ceiling of {ceiling} — use multiplexed lanes \
                         or raise links="
                    ),
                    &opts.tcp,
                );
                return;
            }
            let id = id as usize;
            let reason = match pend.direct.get(id) {
                None => Some(format!("client id {id} out of range (capacity {n})")),
                Some(Some(_)) => Some(format!("client id {id} already connected")),
                Some(None) => None,
            };
            if let Some(reason) = reason {
                reject(stream, opts.party, reason, &opts.tcp);
                return;
            }
            let Some(stream) = ack_stream(stream, opts.party, None, &opts.tcp) else {
                return;
            };
            let Ok(conn) = TcpTransport::from_stream(stream, &opts.tcp) else {
                return;
            };
            pend.direct[id] = Some(Box::new(conn));
            pend.filled += 1;
            pend.mode = Some(LinkMode::Direct);
        }
        Role::ClientMux { lo, count } => {
            if pend.control.is_none() {
                park(pend, ceiling, stream, hello, opts);
                return;
            }
            if pend.mode == Some(LinkMode::Direct) {
                reject(
                    stream,
                    opts.party,
                    "this deployment already uses direct client links — multiplexed \
                     lanes cannot join it"
                        .into(),
                    &opts.tcp,
                );
                return;
            }
            let n = pend.covered.len();
            let lo_us = lo as usize;
            let count_us = count as usize;
            let reason = if count == 0 {
                Some("a multiplexed lane must carry at least one client".to_string())
            } else if u64::from(lo) + u64::from(count) > n as u64 {
                Some(format!(
                    "lane [{lo}, {}) exceeds the announced cohort of {n}",
                    u64::from(lo) + u64::from(count)
                ))
            } else if pend.lanes.len() >= ceiling as usize {
                Some(format!(
                    "lane count exceeds this server's link ceiling of {ceiling}"
                ))
            } else if pend.covered.iter().skip(lo_us).take(count_us).any(|c| *c) {
                Some(format!(
                    "lane [{lo}, {}) overlaps an already-connected lane",
                    u64::from(lo) + u64::from(count)
                ))
            } else {
                None
            };
            if let Some(reason) = reason {
                reject(stream, opts.party, reason, &opts.tcp);
                return;
            }
            let Some(stream) = ack_stream(stream, opts.party, None, &opts.tcp) else {
                return;
            };
            pend.lanes.push(MuxLane {
                stream: Some(stream),
                lo,
                count,
            });
            for slot in pend.covered.iter_mut().skip(lo_us).take(count_us) {
                *slot = true;
            }
            pend.covered_count += count_us;
            pend.mode = Some(LinkMode::Mux);
        }
        Role::Stats => {
            // Stats connections are intercepted ahead of `admit` by both
            // accept paths (and served off-thread against the registry);
            // reaching here means the caller had none to serve from.
            reject(
                stream,
                hello.party,
                "stats are not served on this path".into(),
                &opts.tcp,
            );
        }
        Role::Peer => {
            if opts.party == 1 {
                reject(
                    stream,
                    opts.party,
                    "S_1 dials the peer link itself — only S_0 accepts one".into(),
                    &opts.tcp,
                );
                return;
            }
            if pend.inter.is_some() {
                reject(
                    stream,
                    opts.party,
                    "a peer exchange link is already connected".into(),
                    &opts.tcp,
                );
                return;
            }
            let Some(stream) = ack_stream(stream, opts.party, None, &opts.tcp) else {
                return;
            };
            let Ok(conn) = TcpTransport::from_stream(stream, &opts.tcp) else {
                return;
            };
            pend.inter_raw = conn.stream_clone().ok();
            pend.inter = Some(Box::new(conn));
        }
    }
}

/// Is the pending deployment whole? Control must have landed, every
/// announced client must be reachable (each direct link connected, or
/// every lane range covered), and `S_0` must hold its peer link.
fn complete(pend: &PendingDeployment, party: u8) -> bool {
    let Some(control) = &pend.control else {
        return false;
    };
    let n = control.max_clients;
    let links_done = match pend.mode {
        Some(LinkMode::Direct) => pend.filled == n,
        Some(LinkMode::Mux) => pend.covered_count == n,
        None => n == 0,
    };
    links_done && (party != 0 || pend.inter.is_some())
}

/// Accept one whole deployment, readiness-driven: raw connections are
/// registered with a [`FramePump`] and admitted as their handshake
/// frames complete, in whatever order they arrive. Bounded overall by
/// `opts.data_timeout` (a driver that died mid-connect leaves this
/// server with an error, never parked forever); accept-level errors are
/// retried under a capped exponential backoff that respects that same
/// bound.
fn accept_deployment<G: Group>(
    acceptor: &TcpAcceptor,
    opts: &ServeOptions,
    registry: &Arc<MetricsRegistry>,
) -> Result<Deployment> {
    let overall = Instant::now() + opts.data_timeout;
    let ceiling = effective_link_ceiling(opts);
    let mut pump = FramePump::new(opts.ingest_budget.max(1 << 16));
    pump.set_metrics(PumpMetrics::register(registry));
    let mut backoff = Backoff::new(Duration::from_millis(5), Duration::from_secs(1));
    let mut next_tag: u64 = 0;
    let mut pend = PendingDeployment {
        ctrl: None,
        control: None,
        direct: Vec::new(),
        filled: 0,
        lanes: Vec::new(),
        covered: Vec::new(),
        covered_count: 0,
        mode: None,
        inter: None,
        inter_raw: None,
        parked: Vec::new(),
    };
    loop {
        if complete(&pend, opts.party) {
            break;
        }
        if Instant::now() >= overall {
            bail!(
                "gave up waiting for the deployment's connections after {:?} \
                 (did the driver die mid-connect?)",
                opts.data_timeout
            );
        }
        // Drain every connection the listener has queued, then sweep the
        // pump for completed handshake frames.
        loop {
            match acceptor.accept_raw() {
                Ok(Some((stream, _from))) => {
                    backoff.reset(Duration::from_millis(5));
                    let deadline = Instant::now() + opts.tcp.handshake_timeout;
                    if pump.register(stream, next_tag, Some(deadline)).is_ok() {
                        next_tag = next_tag.wrapping_add(1);
                    }
                }
                Ok(None) => break,
                Err(_probe) => {
                    // Transient accept errors (EMFILE, a reset mid-queue)
                    // back off exponentially — capped, and never past the
                    // overall deadline — instead of hammering the
                    // listener or sleeping a fixed beat.
                    backoff.sleep(overall.saturating_duration_since(Instant::now()));
                    break;
                }
            }
        }
        if pump.is_empty() {
            // Nothing mid-handshake: the pump would return immediately,
            // so pace the accept polling ourselves.
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        for ev in pump.poll(Duration::from_millis(25)) {
            match ev {
                PumpEvent::Frame { tag, payload } => {
                    let Some(stream) = pump.deregister(tag) else {
                        continue;
                    };
                    match Hello::decode(&payload) {
                        // Stats scrapes have no standing in the deployment
                        // and are answered off-thread even during the
                        // accept phase — a monitoring loop that starts
                        // before the driver must not be rejected.
                        Ok(hello) if matches!(hello.role, Role::Stats) => {
                            let registry = registry.clone();
                            let tcp = opts.tcp.clone();
                            std::thread::spawn(move || {
                                serve_stats_handshaken::<G>(
                                    stream,
                                    hello.party,
                                    registry,
                                    tcp,
                                );
                            });
                        }
                        Ok(hello) => admit::<G>(&mut pend, ceiling, stream, hello, opts),
                        // Foreign traffic (port scan, wrong protocol):
                        // not even a well-formed hello — drop silently.
                        Err(_) => {}
                    }
                }
                // A connection that hung up or stalled out mid-handshake
                // was already dropped by the pump.
                PumpEvent::Closed { .. } | PumpEvent::Expired { .. } => {}
            }
        }
    }
    let (Some(ctrl), Some(control)) = (pend.ctrl.take(), pend.control.take()) else {
        bail!("accept loop finished without a control connection");
    };
    let eps: Vec<BoxTransport> = pend.direct.into_iter().flatten().collect();
    if pend.mode == Some(LinkMode::Direct) {
        ensure!(
            eps.len() == control.max_clients,
            "accept loop finished with {}/{} client links connected",
            eps.len(),
            control.max_clients
        );
    }
    let mux = if pend.mode == Some(LinkMode::Mux) {
        Some(MuxCohort {
            lanes: pend.lanes,
            cohort: control.max_clients,
            budget: opts.ingest_budget,
            inter_stream: pend.inter_raw,
            peak_held_bytes: 0,
            peak_pump_bytes: 0,
        })
    } else {
        None
    };
    Ok(Deployment {
        ctrl,
        control,
        eps,
        mux,
        inter: pend.inter,
    })
}

fn validate_control<G: Group>(
    hello: &Hello,
    opts: &ServeOptions,
) -> std::result::Result<ControlInfo, String> {
    if hello.party != opts.party {
        return Err(format!(
            "party mismatch: dialled S{} but this process serves S{}",
            hello.party, opts.party
        ));
    }
    match &hello.role {
        Role::Control { max_clients, m, k, group } => {
            let ours = std::any::type_name::<G>();
            if group != ours {
                return Err(format!(
                    "payload group mismatch: driver runs {group}, this server serves {ours} \
                     (start it with the matching group=)"
                ));
            }
            // The handshake is unauthenticated, so its `max_clients`
            // must be bounded *before* it sizes any allocation (the
            // same invariant the frame and message decoders enforce).
            // Socket pressure is bounded separately, per link shape, by
            // the fd-derived ceiling in `admit`.
            if *max_clients as usize > wire::MAX_WIRE_COHORT {
                return Err(format!(
                    "max_clients {max_clients} exceeds this server's cohort ceiling of \
                     {} clients",
                    wire::MAX_WIRE_COHORT
                ));
            }
            Ok(ControlInfo {
                max_clients: *max_clients as usize,
                m: *m,
                k: *k,
            })
        }
        other => Err(format!(
            "expected the driver's control connection first, got {other:?}"
        )),
    }
}

/// Convenience wrapper: bind `addr`, host one deployment, return when it
/// ends. This is what `fsl serve` calls.
pub fn serve_addr<G: Group>(addr: &str, opts: &ServeOptions) -> Result<()> {
    let acceptor = TcpAcceptor::bind(addr, opts.tcp.clone())
        .map_err(|e| e.context(format!("starting a server on {addr}")))?;
    serve::<G>(&acceptor, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ClientOutcome;
    use crate::crypto::rng::Rng;
    use crate::hashing::CuckooParams;
    use crate::net::transport::{Transport as _, TRANSPORT_VERSION};
    use crate::protocol::{ssa, Session, SessionParams};
    use std::sync::Arc;

    /// The streaming-ingest bound (acceptance criterion): a multiplexed
    /// SSA round's working memory is O(budget), not O(cohort). The whole
    /// cohort's uploads dwarf the ingest budget, the peer stays silent
    /// long enough that nothing can commit — so the held window must
    /// fill, pause the lanes, and never exceed the budget plus one
    /// pump batch. Asserted against the cohort's byte-accounted
    /// high-water marks, not RSS. Accept-phase noise connections ride
    /// along: each must lose only itself.
    #[test]
    fn mux_ingest_memory_is_bounded_by_the_budget_not_the_cohort() {
        let m = 2048u64;
        let k = 32usize;
        let session = Session::new_full(SessionParams {
            m,
            k,
            cuckoo: CuckooParams::default().with_seed(11),
        });

        // Pre-generate every virtual client's long (publics-bearing)
        // upload so the lane threads only move bytes; size the cohort
        // from a probe upload so the total is ~6x the budget.
        let budget = 1usize << 16;
        let gen = |vid: u32| {
            let mut rng = Rng::new(1000 + u64::from(vid));
            let sel = rng.sample_distinct(k, m);
            let deltas: Vec<u64> = sel.iter().map(|&x| x.wrapping_add(1)).collect();
            let batch = ssa::client_update(&session, &sel, &deltas, &mut rng).unwrap();
            let mut f = vid.to_le_bytes().to_vec();
            f.extend(msg::encode_key_upload(&batch, 0, true));
            f
        };
        let n = (6 * budget / gen(0).len()).clamp(16, 512);
        let n_wire = n as u32;
        let mut total_upload = 0usize;
        let mut max_frame = 0usize;
        let mut frames: Vec<Vec<u8>> = Vec::with_capacity(n);
        for vid in 0..n_wire {
            let f = gen(vid);
            total_upload += f.len();
            max_frame = max_frame.max(f.len());
            frames.push(f);
        }
        assert!(total_upload > 4 * budget, "cohort too small to stress the budget");

        let mut opts = ServeOptions::new(0);
        opts.threads = 1;
        opts.ingest_budget = budget;
        opts.data_timeout = Duration::from_secs(30);
        let acceptor = TcpAcceptor::bind("127.0.0.1:0", opts.tcp.clone()).unwrap();
        let addr = acceptor.local_addr().unwrap();
        let tcp = TcpOptions::default();

        // Accept-phase noise: a port-scan connection spewing unframed
        // garbage and a dialler that hangs up mid-handshake. Each loses
        // only itself — the deployment below must still assemble.
        let noise = std::thread::spawn(move || {
            let mut junk = std::net::TcpStream::connect(addr).unwrap();
            junk.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
            drop(std::net::TcpStream::connect(addr).unwrap());
            junk
        });

        let group = std::any::type_name::<u64>().to_string();
        let ctrl = std::thread::spawn({
            let tcp = tcp.clone();
            move || {
                TcpTransport::connect(
                    addr,
                    &Hello {
                        party: 0,
                        role: Role::Control { max_clients: n_wire, m, k: k as u64, group },
                    },
                    &tcp,
                )
                .unwrap()
            }
        });
        let cut = n / 2;
        let hi = frames.split_off(cut);
        let lanes: Vec<_> = [
            (0u32, cut as u32, frames),
            (cut as u32, (n - cut) as u32, hi),
        ]
        .into_iter()
        .map(|(lo, count, payloads)| {
            let tcp = tcp.clone();
            std::thread::spawn(move || {
                let conn = TcpTransport::connect(
                    addr,
                    &Hello { party: 0, role: Role::ClientMux { lo, count } },
                    &tcp,
                )
                .unwrap();
                for f in payloads {
                    conn.send(f).unwrap();
                }
                conn // the socket must outlive the round
            })
        })
        .collect();
        // The fake S1: silent long enough that the leader's held window
        // must fill (nothing can commit before a HAVE), then a HAVE
        // burst for the whole cohort, then the forwarded publics drain
        // and the commit list is answered with a share vector.
        let domain = session.domain_size();
        let peer = std::thread::spawn({
            let tcp = tcp.clone();
            move || {
                let conn =
                    TcpTransport::connect(addr, &Hello { party: 0, role: Role::Peer }, &tcp)
                        .unwrap();
                std::thread::sleep(Duration::from_millis(800));
                for vid in 0..n_wire {
                    let mut have = vec![1u8]; // MUX_HAVE
                    have.extend_from_slice(&vid.to_le_bytes());
                    conn.send(have).unwrap();
                }
                let mut forwards = 0usize;
                loop {
                    let f = conn.recv_timeout(Duration::from_secs(30)).unwrap();
                    match f.first() {
                        Some(&2) => forwards += 1, // MUX_FWD
                        Some(&3) => break,         // MUX_DONE
                        other => panic!("unexpected exchange frame tag {other:?}"),
                    }
                }
                let mut shares = vec![4u8]; // MUX_SHARES
                shares.extend(msg::encode_shares(&vec![0u64; domain]));
                conn.send(shares).unwrap();
                (conn, forwards)
            }
        });

        let registry = MetricsRegistry::shared();
        let dep = accept_deployment::<u64>(&acceptor, &opts, &registry).unwrap();
        assert!(dep.mux.is_some(), "mux lanes must assemble a multiplexed deployment");
        let rec = TraceRecorder::shared(trace::DEFAULT_TRACE_CAPACITY);
        let sink = TraceSink::new(rec.clone(), Party::server(0));
        let metrics = ServerMetrics::register(&registry);
        let sharding = Sharding::new(1);
        let mut server = ServerHalf::<u64> {
            party: 0,
            session: Arc::new(session),
            agg: AggregationEngine::with_sharding(sharding).with_trace(sink.clone()),
            ret: RetrievalEngine::with_sharding(sharding).with_trace(sink),
            trace: rec,
            eps: dep.eps,
            inter: dep.inter,
            mux: dep.mux,
            weights: None,
            udpf: Vec::new(),
            udpf_links: Vec::new(),
            udpf_total: 0,
            dead: Vec::new(),
            timeout: opts.data_timeout,
            registry: registry.clone(),
            metrics,
        };
        let reply = server
            .handle(ServerCmd::Ssa { n, deadline_nanos: 30_000_000_000 })
            .unwrap();
        match reply {
            ServerReply::Round { delta: Some(delta), outcomes, .. } => {
                assert_eq!(delta.len(), m as usize);
                assert_eq!(outcomes.len(), n);
                assert!(
                    outcomes.iter().all(|o| *o == ClientOutcome::Completed),
                    "every virtual client should commit before the deadline"
                );
            }
            _ => panic!("expected a Round reply carrying S0's delta"),
        }

        let (_peer_conn, forwards) = peer.join().unwrap();
        assert_eq!(forwards, n, "one forwarded publics frame per committed client");
        for lane in lanes {
            drop(lane.join().unwrap());
        }
        drop(ctrl.join().unwrap());
        drop(noise.join().unwrap());

        // The bound itself. The held window may overshoot the pause
        // threshold by at most one poll batch, and a batch is capped by
        // the pump's budget (plus the frame that crossed the cap); the
        // pump's own in-flight accounting never exceeds the budget.
        let mux = server.mux.take().unwrap();
        assert!(
            mux.peak_held_bytes >= budget,
            "the held window never filled ({} of {budget} bytes) — the \
             backpressure path went untested",
            mux.peak_held_bytes
        );
        assert!(
            mux.peak_held_bytes <= 2 * budget + 2 * max_frame,
            "held window peaked at {} bytes against a {budget}-byte budget",
            mux.peak_held_bytes
        );
        assert!(mux.peak_pump_bytes > 0, "the pump never accounted a frame");
        assert!(
            mux.peak_pump_bytes <= budget,
            "pump in-flight peaked at {} bytes against a {budget}-byte budget",
            mux.peak_pump_bytes
        );
        // And the bound meant something: the cohort shipped several
        // budgets' worth of uploads through that window.
        assert!(total_upload > 4 * budget);

        // The same high-water marks are live on the scrape path, in
        // valid exposition.
        let prom = expo::render_prom(&registry.snapshot());
        expo::validate_prom(&prom).unwrap();
        assert!(prom.contains("fsl_mux_held_window_bytes"), "{prom}");
        assert!(prom.contains("fsl_pump_frames_total"), "{prom}");
        assert!(prom.contains("fsl_rounds_completed_total 1"), "{prom}");
    }

    /// A `Role::Stats` dialler is served over TCP while the accept loop
    /// is still assembling the deployment: the scrape needs no knowledge
    /// of the server's party (the ack echoes the dialler's), costs the
    /// deployment nothing, and renders valid exposition. The deployment
    /// then still completes normally.
    #[test]
    fn stats_scrape_is_served_over_tcp_without_joining_the_deployment() {
        let mut opts = ServeOptions::new(1);
        opts.data_timeout = Duration::from_secs(20);
        let acceptor = TcpAcceptor::bind("127.0.0.1:0", opts.tcp.clone()).unwrap();
        let addr = acceptor.local_addr().unwrap();
        let registry = MetricsRegistry::shared();
        registry
            .counter("fsl_rounds_started_total", "rounds dispatched")
            .add(3);
        let tcp = TcpOptions::default();
        std::thread::scope(|scope| {
            let accept =
                scope.spawn(|| accept_deployment::<u64>(&acceptor, &opts, &registry));

            // Scrape mid-accept, dialling as party 0 even though this
            // server is S1 — the stats ack echoes the dialler.
            let conn = TcpTransport::connect(
                addr,
                &Hello { party: 0, role: Role::Stats },
                &tcp,
            )
            .unwrap();
            conn.send(wire::encode_cmd::<u64>(&ServerCmd::Stats)).unwrap();
            let raw = conn.recv_timeout(Duration::from_secs(10)).unwrap();
            match wire::decode_reply::<u64>(&raw).unwrap() {
                ServerReply::Stats { prom, json } => {
                    expo::validate_prom(&prom).unwrap();
                    assert!(prom.contains("fsl_rounds_started_total 3"), "{prom}");
                    // The accept pump itself is instrumented: our own
                    // hello frame is already on the counters.
                    assert!(prom.contains("fsl_pump_frames_total"), "{prom}");
                    assert!(crate::metrics::json::validate(&json), "{json}");
                }
                other => panic!("expected a Stats reply, got {:?} tag", wire_tag(&other)),
            }

            // An empty-cohort control handshake completes the deployment
            // (S1 needs no peer link), proving the scrape cost nothing.
            let ctrl = TcpTransport::connect(
                addr,
                &Hello {
                    party: 1,
                    role: Role::Control {
                        max_clients: 0,
                        m: 1024,
                        k: 16,
                        group: std::any::type_name::<u64>().into(),
                    },
                },
                &tcp,
            )
            .unwrap();
            let dep = accept.join().unwrap().unwrap();
            assert!(dep.eps.is_empty());
            assert!(dep.mux.is_none());
            drop(ctrl);
        });
    }

    /// Debug-print helper for unexpected reply variants (ServerReply has
    /// no Debug bound on G's payloads).
    fn wire_tag(reply: &ServerReply<u64>) -> &'static str {
        match reply {
            ServerReply::Ack => "Ack",
            ServerReply::Round { .. } => "Round",
            ServerReply::Verified { .. } => "Verified",
            ServerReply::Failed(_) => "Failed",
            ServerReply::Stats { .. } => "Stats",
        }
    }

    #[test]
    fn control_validation_catches_wiring_mistakes() {
        let opts = ServeOptions::new(0);
        let good = Hello {
            party: 0,
            role: Role::Control {
                max_clients: 2,
                m: 1024,
                k: 16,
                group: std::any::type_name::<u64>().into(),
            },
        };
        assert!(validate_control::<u64>(&good, &opts).is_ok());

        let swapped = Hello { party: 1, ..good.clone() };
        assert!(validate_control::<u64>(&swapped, &opts)
            .unwrap_err()
            .contains("party mismatch"));

        let wrong_group = Hello {
            party: 0,
            role: Role::Control {
                max_clients: 2,
                m: 1024,
                k: 16,
                group: std::any::type_name::<u128>().into(),
            },
        };
        assert!(validate_control::<u64>(&wrong_group, &opts)
            .unwrap_err()
            .contains("group mismatch"));

        let not_control = Hello { party: 0, role: Role::Peer };
        assert!(validate_control::<u64>(&not_control, &opts)
            .unwrap_err()
            .contains("control connection first"));

        // An unauthenticated handshake must never size an allocation:
        // an absurd max_clients is rejected before any slot vector.
        let oversized = Hello {
            party: 0,
            role: Role::Control {
                max_clients: u32::MAX,
                m: 1024,
                k: 16,
                group: std::any::type_name::<u64>().into(),
            },
        };
        assert!(validate_control::<u64>(&oversized, &opts)
            .unwrap_err()
            .contains("ceiling"));

        // Sanity: the version constant exists and is what frames carry
        // (version 3 added multiplexed client lanes).
        assert_eq!(TRANSPORT_VERSION, 3);
    }

    #[test]
    fn link_ceiling_respects_fd_limit() {
        // Whatever the platform reports, the effective ceiling never
        // exceeds the configured one and never collapses to zero.
        let mut opts = ServeOptions::new(0);
        opts.max_client_links = 4096;
        let eff = effective_link_ceiling(&opts);
        assert!(eff >= 1 && eff <= 4096, "effective ceiling {eff}");

        // A tiny configured ceiling passes through unchanged (every
        // realistic fd limit is far above it).
        opts.max_client_links = 2;
        assert_eq!(effective_link_ceiling(&opts), 2);

        // Zero is nonsense; it clamps up to one link.
        opts.max_client_links = 0;
        assert_eq!(effective_link_ceiling(&opts), 1);
    }

    #[test]
    fn admit_orders_and_rejects() {
        use std::net::{TcpListener, TcpStream};
        // Real sockets only as fd carriers: admit() writes acks into
        // them, the far ends just absorb the bytes.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut dial = || {
            let far = TcpStream::connect(addr).unwrap();
            let (near, _) = listener.accept().unwrap();
            (near, far)
        };
        let opts = ServeOptions::new(0);
        let mut pend = PendingDeployment {
            ctrl: None,
            control: None,
            direct: Vec::new(),
            filled: 0,
            lanes: Vec::new(),
            covered: Vec::new(),
            covered_count: 0,
            mode: None,
            inter: None,
            inter_raw: None,
            parked: Vec::new(),
        };

        // A lane arriving before control parks rather than dying.
        let (s, _keep1) = dial();
        admit::<u64>(
            &mut pend,
            64,
            s,
            Hello { party: 0, role: Role::ClientMux { lo: 0, count: 2 } },
            &opts,
        );
        assert_eq!(pend.parked.len(), 1);
        assert!(!complete(&pend, 0));

        // Control lands: the parked lane is admitted behind it.
        let (s, _keep2) = dial();
        admit::<u64>(
            &mut pend,
            64,
            s,
            Hello {
                party: 0,
                role: Role::Control {
                    max_clients: 4,
                    m: 1024,
                    k: 16,
                    group: std::any::type_name::<u64>().into(),
                },
            },
            &opts,
        );
        assert!(pend.control.is_some());
        assert_eq!(pend.parked.len(), 0);
        assert_eq!(pend.lanes.len(), 1);
        assert_eq!(pend.covered_count, 2);

        // An overlapping lane is rejected; a disjoint one completes the
        // cohort coverage.
        let (s, _keep3) = dial();
        admit::<u64>(
            &mut pend,
            64,
            s,
            Hello { party: 0, role: Role::ClientMux { lo: 1, count: 2 } },
            &opts,
        );
        assert_eq!(pend.lanes.len(), 1, "overlap must be rejected");
        let (s, _keep4) = dial();
        admit::<u64>(
            &mut pend,
            64,
            s,
            Hello { party: 0, role: Role::ClientMux { lo: 2, count: 2 } },
            &opts,
        );
        assert_eq!(pend.covered_count, 4);

        // A direct client link cannot join a mux deployment.
        let (s, _keep5) = dial();
        admit::<u64>(
            &mut pend,
            64,
            s,
            Hello { party: 0, role: Role::Client { id: 0 } },
            &opts,
        );
        assert_eq!(pend.filled, 0);
        assert_eq!(pend.mode, Some(LinkMode::Mux));

        // S_0 still waits on its peer link; once it lands, the
        // deployment is whole.
        assert!(!complete(&pend, 0));
        assert!(complete(&pend, 1));
        let (s, _keep6) = dial();
        admit::<u64>(&mut pend, 64, s, Hello { party: 0, role: Role::Peer }, &opts);
        assert!(complete(&pend, 0));
    }
}
