//! Standalone FSL server: one `S_0` or `S_1` as its own OS process,
//! serving a [`super::FslRuntimeBuilder::connect`] driver over framed TCP
//! (the `fsl serve` CLI subcommand is a thin wrapper around [`serve`]).
//!
//! One call to [`serve`] hosts one *deployment*: it accepts the driver's
//! control channel, the per-client data links, and (for `S_0`) the peer
//! server's exchange link, installs the driver's session, and then runs
//! the same command dispatch as the in-process server threads
//! ([`super::runtime`]'s `ServerHalf::handle`) until the driver shuts the
//! deployment down or disconnects. Connection-level mistakes — wrong
//! server address, payload-group mismatch, stale binary — are rejected at
//! the handshake with a readable reason sent back to the dialler.
//!
//! Accept order is driven by the dialler (every handshake is individually
//! acked before the driver opens the next connection): control first
//! (which announces how many client links follow), then the client links,
//! then — for `S_0` only — the peer link that `S_1` dials when the driver
//! commands it to.

use super::runtime::ServerHalf;
use super::snapshot::ServerSnapshot;
use super::wire::{self, ServerCmd, ServerReply};
use crate::group::Group;
use crate::metrics::trace::{self, Party, TraceRecorder, TraceSink};
use crate::net::transport::tcp::{TcpAcceptor, TcpOptions, TcpTransport};
use crate::net::transport::{BoxTransport, Hello, HelloAck, Role};
use crate::protocol::{udpf_ssa, AggregationEngine, RetrievalEngine, Sharding};
use anyhow::{bail, ensure, Result};
use std::path::PathBuf;
use std::time::Duration;

/// Knobs for one standalone server.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Which server this process is (0 = leader, 1 = worker).
    pub party: u8,
    /// Engine workers: an explicit count, or `0` for one per core — a
    /// standalone server owns its whole machine, unlike the co-located
    /// in-process pair.
    pub threads: usize,
    /// Bound on every data-link receive mid-round (a silent client or
    /// peer fails the round, not the deployment).
    pub data_timeout: Duration,
    /// Socket options (handshake timeout, write timeout).
    pub tcp: TcpOptions,
    /// Crash-recovery snapshot file. When set, the server persists its
    /// round-spanning state (session, U-DPF epoch keys, evictions) after
    /// every state-changing command, and restores it at startup if the
    /// file exists — a corrupt snapshot is a typed startup error, never a
    /// partial restore.
    pub snapshot: Option<PathBuf>,
}

impl ServeOptions {
    /// Defaults for `party` (auto engine width, 600 s data timeout).
    pub fn new(party: u8) -> Self {
        ServeOptions {
            party,
            threads: 0,
            data_timeout: Duration::from_secs(600),
            tcp: TcpOptions::default(),
            snapshot: None,
        }
    }
}

/// The control handshake's deployment shape.
struct ControlInfo {
    max_clients: usize,
    m: u64,
    k: u64,
}

/// Ceiling on a deployment's client links. The handshake is
/// unauthenticated, so its `max_clients` must be bounded *before* it
/// sizes any allocation (the same invariant the frame and message
/// decoders enforce) — and each link is a real socket, so anything near
/// this is file-descriptor-bound anyway.
const MAX_CLIENT_LINKS: u32 = 4096;

/// Host one deployment on `acceptor` and serve it to completion.
/// Returns when the driver commands shutdown or its control channel
/// closes; handshake-phase failures (bind-level, not per-connection)
/// return an error.
pub fn serve<G: Group>(acceptor: &TcpAcceptor, opts: &ServeOptions) -> Result<()> {
    // Load any prior snapshot *before* accepting connections: a corrupt
    // file must fail the restart loudly, not after a driver has dialled
    // in and committed to this process.
    let restored: Option<ServerSnapshot<G>> = match &opts.snapshot {
        Some(path) if path.exists() => {
            let snap = ServerSnapshot::<G>::load(path).map_err(|e| {
                anyhow::Error::new(e)
                    .context(format!("restoring server state from {}", path.display()))
            })?;
            ensure!(
                snap.party == opts.party,
                "snapshot {} belongs to S{} but this process serves S{}",
                path.display(),
                snap.party,
                opts.party
            );
            let ours = std::any::type_name::<G>();
            ensure!(
                snap.group == ours,
                "snapshot {} was written by a {} server, this one serves {ours}",
                path.display(),
                snap.group
            );
            Some(snap)
        }
        _ => None,
    };
    let (ctrl, control) = accept_control::<G>(acceptor, opts)?;
    let eps = accept_clients(acceptor, opts, control.max_clients)?;
    let inter = if opts.party == 0 {
        Some(accept_peer(acceptor, opts)?)
    } else {
        None
    };

    // The driver's first command installs the session it announced in the
    // control handshake (System Setup, Fig. 4 — run at deploy time).
    let first = ctrl
        .recv_timeout(opts.data_timeout)
        .map_err(|e| e.context("waiting for the driver's session install"))?;
    let session = match wire::decode_cmd::<G>(&first)? {
        ServerCmd::SetSession(s) => s,
        _ => {
            let _ = ctrl.send(wire::encode_reply::<G>(&ServerReply::Failed(
                "the first command must install the session".into(),
            )));
            bail!("driver's first command was not a session install");
        }
    };
    if session.params.m != control.m || session.params.k as u64 != control.k {
        let reason = format!(
            "installed session (m={}, k={}) does not match the control handshake \
             (m={}, k={})",
            session.params.m, session.params.k, control.m, control.k
        );
        let _ = ctrl.send(wire::encode_reply::<G>(&ServerReply::Failed(reason.clone())));
        bail!("{reason}");
    }

    let sharding = if opts.threads == 0 {
        Sharding::auto()
    } else {
        Sharding::new(opts.threads)
    };
    // One recorder per server process; `ServerHalf::handle` resets it at
    // round start and drains it into the round reply, so remote rounds
    // ship the same span stream the in-process runtime collects directly.
    let rec = TraceRecorder::shared(trace::DEFAULT_TRACE_CAPACITY);
    let sink = TraceSink::new(rec.clone(), Party::server(usize::from(opts.party)));
    let mut server = ServerHalf::<G> {
        party: opts.party,
        session,
        agg: AggregationEngine::with_sharding(sharding).with_trace(sink.clone()),
        ret: RetrievalEngine::with_sharding(sharding).with_trace(sink),
        trace: rec,
        eps,
        inter,
        weights: None,
        udpf: Vec::new(),
        udpf_links: Vec::new(),
        udpf_total: 0,
        dead: Vec::new(),
        timeout: opts.data_timeout,
    };

    // Adopt the snapshot's retained state — but only if the driver just
    // installed the *same* session the snapshot was taken under (same
    // encoded bytes). A different session means a new deployment: start
    // clean, and the first snapshot write below overwrites the old file.
    if let Some(snap) = restored {
        if snap.session == wire::encode_session(&server.session) {
            ensure!(
                snap.udpf.iter().all(|(l, _)| (*l as usize) < server.eps.len()),
                "snapshot references client links beyond this deployment's capacity"
            );
            server.udpf_total = snap.udpf_total;
            for (link, keys) in snap.udpf {
                server.udpf_links.push(link as usize);
                server.udpf.push(udpf_ssa::UdpfSsaServerKeys { keys });
            }
            server.dead = snap.dead;
        }
    }
    // Persist the adopted-or-fresh state before acking the install: from
    // the driver's point of view, an acked install is always recoverable.
    if let Some(path) = &opts.snapshot {
        snapshot_of(&server).write(path).map_err(|e| {
            anyhow::Error::new(e).context(format!("persisting state to {}", path.display()))
        })?;
    }
    ctrl.send(wire::encode_reply::<G>(&ServerReply::Ack))?;

    // The remote command loop — the TCP twin of `ServerHalf::run`.
    loop {
        let raw = match ctrl.recv() {
            Ok(raw) => raw,
            Err(_) => break, // driver gone: the deployment is over
        };
        let cmd = match wire::decode_cmd::<G>(&raw) {
            Ok(cmd) => cmd,
            Err(e) => {
                if ctrl
                    .send(wire::encode_reply::<G>(&ServerReply::Failed(e.to_string())))
                    .is_err()
                {
                    break;
                }
                continue;
            }
        };
        let reply = match cmd {
            ServerCmd::Shutdown => break,
            ServerCmd::DialPeer { addr } => {
                let hello = Hello {
                    party: 1 - opts.party,
                    role: Role::Peer,
                };
                match TcpTransport::connect(addr.as_str(), &hello, &opts.tcp) {
                    Ok(conn) => {
                        server.inter = Some(Box::new(conn));
                        ServerReply::Ack
                    }
                    Err(e) => ServerReply::Failed(format!("dialling peer at {addr}: {e}")),
                }
            }
            cmd => {
                // Rounds report the real S_0 ↔ S_1 bytes back to the
                // driver (which cannot see the peer link): reset the peer
                // meter at round start, stamp its sent-count into the
                // reply.
                let is_round = cmd.is_round();
                let changes_state = is_round || matches!(cmd, ServerCmd::SetSession(_));
                if is_round {
                    if let Some(inter) = &server.inter {
                        inter.meter().reset();
                    }
                }
                let mut reply = server
                    .handle(cmd)
                    .unwrap_or_else(|e| ServerReply::Failed(e.to_string()));
                if is_round {
                    if let ServerReply::Round { inter_sent, .. } = &mut reply {
                        *inter_sent =
                            server.inter.as_ref().map_or(0, |i| i.meter().sent());
                    }
                }
                // Snapshot-on-success, *before* the reply goes out: an
                // acked command is always recoverable, and a failed one
                // never persists tainted state.
                if changes_state && !matches!(reply, ServerReply::Failed(_)) {
                    if let Some(path) = &opts.snapshot {
                        if let Err(e) = snapshot_of(&server).write(path) {
                            reply = ServerReply::Failed(format!(
                                "persisting the recovery snapshot failed: {e}"
                            ));
                        }
                    }
                }
                reply
            }
        };
        if ctrl.send(wire::encode_reply(&reply)).is_err() {
            break;
        }
    }
    Ok(())
}

/// The snapshot of one server's current round-spanning state.
fn snapshot_of<G: Group>(server: &ServerHalf<G>) -> ServerSnapshot<G> {
    ServerSnapshot {
        party: server.party,
        group: std::any::type_name::<G>().to_string(),
        session: wire::encode_session(&server.session),
        udpf_total: server.udpf_total,
        udpf: server
            .udpf
            .iter()
            .zip(&server.udpf_links)
            .map(|(ks, link)| (*link as u32, ks.keys.clone()))
            .collect(),
        dead: server.dead.clone(),
    }
}

/// Accept the next connection that completes a handshake, bounded by
/// `opts.data_timeout` overall. Per-connection failures (a dropped
/// liveness probe, a stray port scan, a stale-binary hello) are
/// tolerated — the deployment must survive them — but the bound means a
/// driver that died mid-connect leaves the server with an error after
/// the timeout, never parked on a blocking accept forever.
fn next_conn(acceptor: &TcpAcceptor, opts: &ServeOptions) -> Result<(BoxTransport, Hello)> {
    let deadline = std::time::Instant::now() + opts.data_timeout;
    loop {
        let remaining = deadline.saturating_duration_since(std::time::Instant::now());
        if remaining.is_zero() {
            bail!(
                "gave up waiting for the deployment's connections after {:?} \
                 (did the driver die mid-connect?)",
                opts.data_timeout
            );
        }
        match acceptor.accept_timeout(remaining) {
            Ok(Some(pair)) => return Ok(pair),
            Ok(None) => {} // deadline trips on the next iteration
            Err(_probe) => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

/// Accept until a valid control connection arrives (rejecting strays
/// with a reasoned ack).
fn accept_control<G: Group>(
    acceptor: &TcpAcceptor,
    opts: &ServeOptions,
) -> Result<(BoxTransport, ControlInfo)> {
    loop {
        let (conn, hello) = next_conn(acceptor, opts)?;
        match validate_control::<G>(&hello, opts) {
            Ok(info) => {
                conn.send(HelloAck { party: opts.party, error: None }.encode())?;
                return Ok((conn, info));
            }
            Err(reason) => {
                let _ = conn.send(
                    HelloAck { party: opts.party, error: Some(reason) }.encode(),
                );
            }
        }
    }
}

fn validate_control<G: Group>(
    hello: &Hello,
    opts: &ServeOptions,
) -> std::result::Result<ControlInfo, String> {
    if hello.party != opts.party {
        return Err(format!(
            "party mismatch: dialled S{} but this process serves S{}",
            hello.party, opts.party
        ));
    }
    match &hello.role {
        Role::Control { max_clients, m, k, group } => {
            let ours = std::any::type_name::<G>();
            if group != ours {
                return Err(format!(
                    "payload group mismatch: driver runs {group}, this server serves {ours} \
                     (start it with the matching group=)"
                ));
            }
            if *max_clients > MAX_CLIENT_LINKS {
                return Err(format!(
                    "max_clients {max_clients} exceeds this server's ceiling of \
                     {MAX_CLIENT_LINKS} client links"
                ));
            }
            Ok(ControlInfo {
                max_clients: *max_clients as usize,
                m: *m,
                k: *k,
            })
        }
        other => Err(format!(
            "expected the driver's control connection first, got {other:?}"
        )),
    }
}

/// Accept exactly `n` client links, slotted by their handshake id
/// (rejecting strays and duplicates with a reasoned ack).
fn accept_clients(
    acceptor: &TcpAcceptor,
    opts: &ServeOptions,
    n: usize,
) -> Result<Vec<BoxTransport>> {
    let mut slots: Vec<Option<BoxTransport>> = (0..n).map(|_| None).collect();
    let mut filled = 0;
    while filled < n {
        let (conn, hello) = next_conn(acceptor, opts)?;
        let reason = match (&hello.role, hello.party == opts.party) {
            (_, false) => Some(format!(
                "party mismatch: dialled S{} but this process serves S{}",
                hello.party, opts.party
            )),
            (Role::Client { id }, true) => {
                let id = *id as usize;
                match slots.get_mut(id) {
                    None => Some(format!("client id {id} out of range (capacity {n})")),
                    Some(slot) => {
                        if slot.is_some() {
                            Some(format!("client id {id} already connected"))
                        } else {
                            conn.send(HelloAck { party: opts.party, error: None }.encode())?;
                            *slot = Some(conn);
                            filled += 1;
                            continue;
                        }
                    }
                }
            }
            (other, true) => Some(format!(
                "expected a client link ({filled}/{n} connected), got {other:?}"
            )),
        };
        let _ = conn.send(HelloAck { party: opts.party, error: reason }.encode());
    }
    // The loop above only exits once `filled == n`, so every slot is
    // `Some` — but a logic slip here must fail the accept loop with a
    // typed error, not panic the server process.
    let links: Vec<BoxTransport> = slots.into_iter().flatten().collect();
    ensure!(
        links.len() == n,
        "accept loop finished with {}/{n} client links connected",
        links.len()
    );
    Ok(links)
}

/// Accept the peer server's exchange link (S_0 side).
fn accept_peer(acceptor: &TcpAcceptor, opts: &ServeOptions) -> Result<BoxTransport> {
    loop {
        let (conn, hello) = next_conn(acceptor, opts)?;
        if hello.party == opts.party && hello.role == Role::Peer {
            conn.send(HelloAck { party: opts.party, error: None }.encode())?;
            return Ok(conn);
        }
        let _ = conn.send(
            HelloAck {
                party: opts.party,
                error: Some(format!(
                    "expected the peer server's exchange link, got {:?}",
                    hello.role
                )),
            }
            .encode(),
        );
    }
}

/// Convenience wrapper: bind `addr`, host one deployment, return when it
/// ends. This is what `fsl serve` calls.
pub fn serve_addr<G: Group>(addr: &str, opts: &ServeOptions) -> Result<()> {
    let acceptor = TcpAcceptor::bind(addr, opts.tcp.clone())
        .map_err(|e| e.context(format!("starting a server on {addr}")))?;
    serve::<G>(&acceptor, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::TRANSPORT_VERSION;

    #[test]
    fn control_validation_catches_wiring_mistakes() {
        let opts = ServeOptions::new(0);
        let good = Hello {
            party: 0,
            role: Role::Control {
                max_clients: 2,
                m: 1024,
                k: 16,
                group: std::any::type_name::<u64>().into(),
            },
        };
        assert!(validate_control::<u64>(&good, &opts).is_ok());

        let swapped = Hello { party: 1, ..good.clone() };
        assert!(validate_control::<u64>(&swapped, &opts)
            .unwrap_err()
            .contains("party mismatch"));

        let wrong_group = Hello {
            party: 0,
            role: Role::Control {
                max_clients: 2,
                m: 1024,
                k: 16,
                group: std::any::type_name::<u128>().into(),
            },
        };
        assert!(validate_control::<u64>(&wrong_group, &opts)
            .unwrap_err()
            .contains("group mismatch"));

        let not_control = Hello { party: 0, role: Role::Peer };
        assert!(validate_control::<u64>(&not_control, &opts)
            .unwrap_err()
            .contains("control connection first"));

        // An unauthenticated handshake must never size an allocation:
        // an absurd max_clients is rejected before any slot vector.
        let oversized = Hello {
            party: 0,
            role: Role::Control {
                max_clients: u32::MAX,
                m: 1024,
                k: 16,
                group: std::any::type_name::<u64>().into(),
            },
        };
        assert!(validate_control::<u64>(&oversized, &opts)
            .unwrap_err()
            .contains("ceiling"));

        // Sanity: the version constant exists and is what frames carry
        // (version 2 added upload deadlines and per-client outcomes).
        assert_eq!(TRANSPORT_VERSION, 2);
    }
}
