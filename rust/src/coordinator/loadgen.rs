//! `fsl loadgen` — a scale harness that drives two standalone `fsl
//! serve` processes with 10^4–10^6 *virtual* clients multiplexed over a
//! bounded pool of [`Role::ClientMux`] lane sockets.
//!
//! One lane socket carries the uploads of a contiguous virtual-id range
//! `[lo, lo + count)`; each upload frame is `[vid u32 LE][key upload]`,
//! exactly what the servers' readiness loop
//! (`ServerHalf::ssa_mux`) ingests. Every virtual client is
//! deterministic in `(seed, vid)`: its selections, deltas, straggle
//! decision and key material all derive from one seeded [`Rng`], so the
//! harness can regenerate the *expected* aggregate for the surviving
//! cohort after the round and check the reconstructed delta
//! bit-for-bit — no per-client state is retained while driving, which
//! is what lets a single driver process push a million clients.
//!
//! Fault injection reuses [`FaultPlan`]: `jitter` delays each lane's
//! sends on a deterministic per-lane spread, `drop_lanes` severs the
//! first N lanes mid-range (their tails become `Dropped`), and
//! `straggle` silences a deterministic fraction of virtual clients
//! (they become `StragglerCut` at the servers' upload deadline).
//!
//! Soak mode (`rounds > 1`) re-commands the same deployment round after
//! round, reusing the lane pool (the servers hand surviving lanes back
//! after every round), and records each round's wall time in an
//! `fsl_loadgen_round_seconds` histogram so the report carries
//! p50/p95/p99 latency instead of a single sample. Soak rounds assume
//! the deadline admits the whole cohort: a lane that is still buffered
//! at the cut would bleed its unread frames into the next round (the
//! final-round verification catches exactly that as a delta mismatch).
//!
//! The optional history hook appends one schema-versioned `loadgen`
//! datapoint (wall/gen/server times in `_ms` fields, peak driver RSS in
//! MB) plus one `loadgen_soak` datapoint (per-round p50/p95/p99, no
//! byte fields) to `artifacts/HISTORY.jsonl`, where `cargo xtask
//! bench-diff` gates regressions.

use super::runtime::{dial_with_retry, merge_outcomes, ClientOutcome, FslRuntimeBuilder};
use super::wire::{self, ServerCmd, ServerReply};
use crate::crypto::rng::Rng;
use crate::hashing::CuckooParams;
use crate::metrics::history;
use crate::metrics::json::JsonObj;
use crate::metrics::registry::{MetricsRegistry, Unit};
use crate::net::transport::tcp::{TcpOptions, TcpTransport};
use crate::net::transport::{BoxTransport, FaultPlan, Hello, Role, Transport};
use crate::protocol::{msg, ssa, Session, SessionParams};
use anyhow::{anyhow, bail, ensure, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How (and whether) the reconstructed delta is checked after the round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadgenVerify {
    /// No correctness check (huge cohorts where the O(completed · k)
    /// regeneration pass is the bottleneck).
    None,
    /// Regenerate every completed client's sparse update from `(seed,
    /// vid)` and compare the summed expectation to the delta (default).
    Expected,
    /// `Expected`, plus replay the completed cohort through an
    /// in-process [`FslRuntime`](super::FslRuntime) and require the two
    /// deployments' deltas to be bit-identical.
    Inproc,
}

/// Everything `fsl loadgen` needs to drive one multiplexed SSA round.
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// S0's listen address.
    pub s0: String,
    /// S1's listen address (must be able to dial `s0` for the peer link).
    pub s1: String,
    /// Virtual cohort size.
    pub clients: usize,
    /// Lane sockets per server (clamped to `[1, clients]`). Each lane
    /// gets a contiguous share of the virtual-id space.
    pub lanes: usize,
    /// Rounds to drive back-to-back over the same lane pool (soak mode).
    /// Every round re-uploads the full cohort; wall times feed the
    /// report's p50/p95/p99. Verification runs on the final round.
    pub rounds: usize,
    /// Model size (the session domain).
    pub m: u64,
    /// Submodel size (selections per client).
    pub k: usize,
    /// Seeds the session's cuckoo table and every virtual client.
    pub seed: u64,
    /// The servers' upload deadline: stragglers are cut, not waited on.
    pub deadline: Duration,
    /// Extra wait (beyond `deadline`) for the servers' round replies.
    pub reply_timeout: Duration,
    /// How long to keep retrying the initial dials.
    pub connect_window: Duration,
    /// Per-send delay, spread deterministically across lanes (lane `i`
    /// sleeps `jitter · (i + 1) / lanes` before each upload).
    pub jitter: Duration,
    /// Fraction of virtual clients that never upload (deterministic in
    /// `(seed, vid)`).
    pub straggle: f64,
    /// Sever the first N lanes mid-range (dropout injection).
    pub drop_lanes: usize,
    /// Post-round correctness check.
    pub verify: LoadgenVerify,
    /// Append a `loadgen` datapoint to this history file.
    pub history: Option<PathBuf>,
}

impl LoadgenOptions {
    pub fn new(s0: impl Into<String>, s1: impl Into<String>) -> Self {
        LoadgenOptions {
            s0: s0.into(),
            s1: s1.into(),
            clients: 10_000,
            lanes: 64,
            rounds: 1,
            m: 1 << 15,
            k: 64,
            seed: 7,
            deadline: Duration::from_secs(30),
            reply_timeout: Duration::from_secs(600),
            connect_window: Duration::from_secs(10),
            jitter: Duration::ZERO,
            straggle: 0.0,
            drop_lanes: 0,
            verify: LoadgenVerify::Expected,
            history: None,
        }
    }
}

/// What one loadgen round measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub clients: usize,
    pub lanes: usize,
    /// Rounds driven over the deployment.
    pub rounds: usize,
    /// Cohort-agreement outcome counts for the *final* round (both
    /// servers merged).
    pub completed: usize,
    pub straggler_cut: usize,
    pub dropped: usize,
    /// Uploads the lane threads actually wrote (an injected disconnect
    /// truncates its lane's range).
    pub sent: usize,
    /// Client key generation, summed over virtual clients (the paper's
    /// per-client Table-5 convention, scaled by the cohort).
    pub gen_time: Duration,
    /// S0's reported in-round server time (final round).
    pub server_time: Duration,
    /// Round command → both round replies decoded, summed over rounds.
    pub wall_time: Duration,
    /// Per-round wall-time quantiles from the
    /// `fsl_loadgen_round_seconds` histogram (for `rounds = 1` all
    /// three read the single round, up to log2-bucket quantisation).
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Payload bytes handed to the lane sockets.
    pub upload_bytes: u64,
    /// Peak resident set of the *driver* process (VmHWM). The servers'
    /// O(shard) bound is asserted separately by the streaming-ingest
    /// unit tests against their byte-accounted high-water marks.
    pub peak_rss_mb: f64,
    /// Whether the requested verification passed (`true` when skipped).
    pub verified: bool,
}

impl LoadgenReport {
    /// One JSON line for `--json` scripting.
    pub fn to_json(&self) -> String {
        let mut o = JsonObj::new();
        o.field_str("kind", "loadgen")
            .field_u64("clients", self.clients as u64)
            .field_u64("lanes", self.lanes as u64)
            .field_u64("rounds", self.rounds as u64)
            .field_u64("completed", self.completed as u64)
            .field_u64("straggler_cut", self.straggler_cut as u64)
            .field_u64("dropped", self.dropped as u64)
            .field_u64("sent", self.sent as u64)
            .field_f64("gen_ms", ms(self.gen_time), 3)
            .field_f64("server_ms", ms(self.server_time), 3)
            .field_f64("wall_ms", ms(self.wall_time), 3)
            .field_f64("p50_ms", self.p50_ms, 3)
            .field_f64("p95_ms", self.p95_ms, 3)
            .field_f64("p99_ms", self.p99_ms, 3)
            .field_f64("upload_mb", self.upload_bytes as f64 / 1e6, 3)
            .field_f64("peak_rss_mb", self.peak_rss_mb, 1)
            .field_bool("verified", self.verified);
        o.finish()
    }
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// One lane pair: the `[lo, lo + count)` range and its two sockets.
struct Lane {
    lo: u32,
    count: u32,
    s0: BoxTransport,
    s1: BoxTransport,
}

struct LaneStats {
    gen_nanos: u64,
    bytes: u64,
    sent: usize,
}

/// Every virtual client's randomness derives from `(seed, vid)` alone —
/// the golden-ratio multiply decorrelates adjacent ids.
fn client_rng(seed: u64, vid: u64) -> Rng {
    Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(vid.wrapping_add(1)))
}

/// Regenerate virtual client `vid`'s sparse update. Draw order must
/// match [`run_lane`] exactly: selections first, then everything else.
fn client_inputs(session: &Session, seed: u64, vid: u64) -> (Vec<u64>, Vec<u64>) {
    let mut rng = client_rng(seed, vid);
    let sel = rng.sample_distinct(session.params.k, session.params.m);
    let deltas = sel.iter().map(|&x| x.wrapping_add(1)).collect();
    (sel, deltas)
}

/// The straggle decision burns exactly one draw whether or not it
/// triggers, so the upload stream stays deterministic for the verifier.
fn is_straggler(rng: &mut Rng, frac: f64) -> bool {
    let draw = rng.gen_range(1 << 20);
    if frac <= 0.0 {
        return false;
    }
    draw < (frac.min(1.0) * (1u64 << 20) as f64) as u64
}

/// `[vid u32 LE][payload]` — the mux lanes' framing contract.
fn lane_frame(vid: u32, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&vid.to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Generate and send one lane's range. Returns the lane so its sockets
/// stay open (and its silent tail classifies as straggler, not dropout)
/// until the round replies are in.
fn run_lane(session: &Session, opts: &LoadgenOptions, lane: Lane) -> Result<(Lane, LaneStats)> {
    let mut stats = LaneStats { gen_nanos: 0, bytes: 0, sent: 0 };
    for vid in lane.lo..lane.lo.saturating_add(lane.count) {
        let mut rng = client_rng(opts.seed, u64::from(vid));
        let sel = rng.sample_distinct(session.params.k, session.params.m);
        let deltas: Vec<u64> = sel.iter().map(|&x| x.wrapping_add(1)).collect();
        if is_straggler(&mut rng, opts.straggle) {
            continue;
        }
        let t = Instant::now();
        let batch = ssa::client_update(session, &sel, &deltas, &mut rng)
            .map_err(|e| anyhow!("virtual client {vid}: {e}"))?;
        stats.gen_nanos = stats
            .gen_nanos
            .saturating_add(u64::try_from(t.elapsed().as_nanos()).unwrap_or(u64::MAX));
        // Short (msk-only) half to S1 first, then the long half to S0 —
        // the servers commit a client only once both halves landed, and
        // S1's acknowledgement stream is what lets S0 drain its held
        // window, so the msk half must never trail by a full lane.
        let short = lane_frame(vid, msg::encode_key_upload(&batch, 1, false));
        let long = lane_frame(vid, msg::encode_key_upload(&batch, 0, true));
        stats.bytes = stats.bytes.saturating_add((short.len() + long.len()) as u64);
        if lane.s1.send(short).is_err() || lane.s0.send(long).is_err() {
            // Severed (injected dropout or a dead server): the rest of
            // this range can never land — leave classification to the
            // servers and keep what sockets remain open.
            break;
        }
        stats.sent += 1;
    }
    Ok((lane, stats))
}

/// Drive one multiplexed SSA round end-to-end. See the module docs for
/// the wire shapes; the ordering mirrors the in-process driver: connect
/// everything, install the session on S1, let S1 dial the peer link,
/// install the session on S0, then command the round on both.
pub fn run_loadgen(opts: &LoadgenOptions) -> Result<LoadgenReport> {
    let n = opts.clients;
    ensure!(n >= 1, "loadgen needs at least one virtual client");
    ensure!(
        n <= wire::MAX_WIRE_COHORT,
        "clients = {n} exceeds the wire cohort cap of {}",
        wire::MAX_WIRE_COHORT
    );
    let k = opts.k.max(1);
    ensure!(
        k as u64 <= opts.m,
        "submodel k = {k} cannot exceed the model size m = {}",
        opts.m
    );
    ensure!(
        !opts.deadline.is_zero(),
        "loadgen rounds need a positive deadline (stragglers are cut, not waited on)"
    );
    let rounds = opts.rounds;
    ensure!(rounds >= 1, "loadgen needs at least one round to drive");
    let lanes = opts.lanes.clamp(1, n);
    ensure!(
        opts.drop_lanes <= lanes,
        "drop_lanes = {} exceeds the {lanes} lanes",
        opts.drop_lanes
    );
    let n_wire = u32::try_from(n).map_err(|_| anyhow!("clients = {n} overflows the wire"))?;
    let session = Session::new_full(SessionParams {
        m: opts.m,
        k,
        cuckoo: CuckooParams::default().with_seed(opts.seed),
    });

    // Control links (these drive the command loop), then the lane pool.
    let tcp = TcpOptions::default();
    let group = std::any::type_name::<u64>().to_string();
    let hello_ctrl = |party: u8| Hello {
        party,
        role: Role::Control {
            max_clients: n_wire,
            m: opts.m,
            k: k as u64,
            group: group.clone(),
        },
    };
    let ctrl0 = dial_with_retry(&opts.s0, &hello_ctrl(0), &tcp, opts.connect_window)?;
    let ctrl1 = dial_with_retry(&opts.s1, &hello_ctrl(1), &tcp, opts.connect_window)?;

    // Lane writes must not outlive the round: a server that cut its
    // stragglers stops reading, so a blocked lane send has to fail (the
    // lane breaks out, the socket stays open) instead of stalling the
    // driver behind the global 600 s default.
    let lane_tcp = TcpOptions {
        handshake_timeout: tcp.handshake_timeout,
        write_timeout: Some(opts.deadline + Duration::from_secs(5)),
    };
    let mut pairs = Vec::with_capacity(lanes);
    let mut lo = 0u32;
    for li in 0..lanes {
        let count_us = n / lanes + usize::from(li < n % lanes);
        let count = u32::try_from(count_us)
            .map_err(|_| anyhow!("lane {li} range of {count_us} clients overflows the wire"))?;
        let hello_lane = |party: u8| Hello {
            party,
            role: Role::ClientMux { lo, count },
        };
        let t0 = dial_with_retry(&opts.s0, &hello_lane(0), &lane_tcp, opts.connect_window)?;
        let t1 = dial_with_retry(&opts.s1, &hello_lane(1), &lane_tcp, opts.connect_window)?;
        let (mut b0, mut b1): (BoxTransport, BoxTransport) = (Box::new(t0), Box::new(t1));
        let mut plan = FaultPlan::new();
        let mut faulted = false;
        if !opts.jitter.is_zero() {
            plan = plan.delay(opts.jitter.mul_f64((li + 1) as f64 / lanes as f64));
            faulted = true;
        }
        if li < opts.drop_lanes {
            // One injector per dropped lane, budget shared across both
            // sockets: at two messages per upload it severs mid-range,
            // leaving a committed head and a dropped tail.
            plan = plan.disconnect_after_messages(u64::from(count));
            faulted = true;
        }
        if faulted {
            let inj = plan.injector();
            b0 = inj.wrap(b0);
            b1 = inj.wrap(b1);
        }
        pairs.push(Lane { lo, count, s0: b0, s1: b1 });
        lo = lo.saturating_add(count);
    }

    // Session install + peer link, in the in-process driver's order.
    let expect_ack = |ctrl: &TcpTransport, what: &str| -> Result<()> {
        let raw = ctrl
            .recv_timeout(opts.reply_timeout)
            .map_err(|e| e.context(format!("no reply while {what}")))?;
        match wire::decode_reply::<u64>(&raw)? {
            ServerReply::Ack => Ok(()),
            ServerReply::Failed(msg) => bail!("{what}: server refused: {msg}"),
            _ => bail!("{what}: unexpected reply type"),
        }
    };
    let arc = Arc::new(session.clone());
    ctrl1.send(wire::encode_cmd(&ServerCmd::<u64>::SetSession(arc.clone())))?;
    expect_ack(&ctrl1, "installing the session on S1")?;
    ctrl1.send(wire::encode_cmd(&ServerCmd::<u64>::DialPeer {
        addr: opts.s0.clone(),
    }))?;
    expect_ack(&ctrl1, "establishing the S0<->S1 peer link")?;
    ctrl0.send(wire::encode_cmd(&ServerCmd::<u64>::SetSession(arc)))?;
    expect_ack(&ctrl0, "installing the session on S0")?;

    // The rounds: command both servers, then let the lane threads race
    // the deadline. Worker (S1) first so its acknowledgement stream is
    // live by the time S0 starts committing. Soak mode repeats over the
    // same lane pool — the servers hand surviving lanes back after each
    // round. Replies: S0 reconstructs, S1 only reports outcomes; a
    // client survives only when *both* servers completed it.
    let deadline_nanos =
        u64::try_from(opts.deadline.as_nanos()).map_err(|_| anyhow!("deadline overflows u64"))?;
    let round_cmd = ServerCmd::<u64>::Ssa { n, deadline_nanos };
    let registry = MetricsRegistry::new();
    let round_hist = registry.histogram(
        "fsl_loadgen_round_seconds",
        "wall time of one driven loadgen round, command to both replies",
        Unit::Seconds,
    );
    let reply_window = opts.deadline + opts.reply_timeout;
    let round_reply = |ctrl: &TcpTransport,
                       who: &str|
     -> Result<(Duration, Option<Vec<u64>>, Vec<ClientOutcome>)> {
        let raw = ctrl
            .recv_timeout(reply_window)
            .map_err(|e| e.context(format!("waiting for {who}'s round reply")))?;
        match wire::decode_reply::<u64>(&raw)? {
            ServerReply::Round {
                server_time,
                delta,
                outcomes,
                ..
            } => Ok((server_time, delta, outcomes)),
            ServerReply::Failed(msg) => bail!("{who} failed the round: {msg}"),
            _ => bail!("{who}: unexpected round reply type"),
        }
    };

    let session_ref = &session;
    let mut gen_nanos = 0u64;
    let mut upload_bytes = 0u64;
    let mut sent = 0usize;
    let mut wall_total = Duration::ZERO;
    let mut last_round: Option<(Duration, Vec<u64>, Vec<ClientOutcome>)> = None;
    for round in 0..rounds {
        let wall0 = Instant::now();
        ctrl1.send(wire::encode_cmd(&round_cmd))?;
        ctrl0.send(wire::encode_cmd(&round_cmd))?;

        let mut kept: Vec<Lane> = Vec::with_capacity(lanes);
        let mut lane_err: Option<anyhow::Error> = None;
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(pairs.len());
            for lane in pairs {
                handles.push(scope.spawn(move || run_lane(session_ref, opts, lane)));
            }
            for h in handles {
                match h.join() {
                    Ok(Ok((lane, stats))) => {
                        gen_nanos = gen_nanos.saturating_add(stats.gen_nanos);
                        upload_bytes = upload_bytes.saturating_add(stats.bytes);
                        sent += stats.sent;
                        kept.push(lane);
                    }
                    Ok(Err(e)) => lane_err = Some(e),
                    Err(_) => lane_err = Some(anyhow!("a loadgen lane thread panicked")),
                }
            }
        });
        if let Some(e) = lane_err {
            return Err(e);
        }

        let (server_time, delta0, o0) = round_reply(&ctrl0, "S0")
            .map_err(|e| e.context(format!("round {round}")))?;
        let (_s1_time, _d1, o1) = round_reply(&ctrl1, "S1")
            .map_err(|e| e.context(format!("round {round}")))?;
        let wall = wall0.elapsed();
        round_hist.observe_duration(wall);
        wall_total = wall_total.saturating_add(wall);
        let delta = delta0
            .ok_or_else(|| anyhow!("S0's round {round} reply carried no delta"))?;
        ensure!(
            delta.len() == opts.m as usize,
            "round {round}: S0 reconstructed {} entries for an m = {} domain",
            delta.len(),
            opts.m
        );
        last_round = Some((server_time, delta, merge_outcomes(n, &o0, &o1)));
        pairs = kept;
    }
    // The lanes may drop now: the last round is over, classification is
    // done.
    drop(pairs);
    let Some((server_time, delta, merged)) = last_round else {
        bail!("loadgen drove zero rounds");
    };
    let (mut completed, mut straggler_cut, mut dropped) = (0usize, 0usize, 0usize);
    for o in &merged {
        match o {
            ClientOutcome::Completed => completed += 1,
            ClientOutcome::StragglerCut => straggler_cut += 1,
            ClientOutcome::Dropped => dropped += 1,
        }
    }

    let verified = match opts.verify {
        LoadgenVerify::None => true,
        LoadgenVerify::Expected => {
            verify_expected(&session, opts, &merged, &delta)?;
            true
        }
        LoadgenVerify::Inproc => {
            verify_expected(&session, opts, &merged, &delta)?;
            verify_inproc(&session, opts, &merged, &delta)?;
            true
        }
    };

    let _ = ctrl1.send(wire::encode_cmd(&ServerCmd::<u64>::Shutdown));
    let _ = ctrl0.send(wire::encode_cmd(&ServerCmd::<u64>::Shutdown));

    let report = LoadgenReport {
        clients: n,
        lanes,
        rounds,
        completed,
        straggler_cut,
        dropped,
        sent,
        gen_time: Duration::from_nanos(gen_nanos),
        server_time,
        wall_time: wall_total,
        p50_ms: round_hist.quantile_ms(0.50),
        p95_ms: round_hist.quantile_ms(0.95),
        p99_ms: round_hist.quantile_ms(0.99),
        upload_bytes,
        peak_rss_mb: peak_rss_mb(),
        verified,
    };
    if let Some(path) = &opts.history {
        history::append_with(path, "loadgen", |o| {
            o.field_u64("clients", report.clients as u64)
                .field_u64("lanes", report.lanes as u64)
                .field_u64("completed", report.completed as u64)
                .field_u64("straggler_cut", report.straggler_cut as u64)
                .field_u64("dropped", report.dropped as u64)
                .field_f64("gen_ms", ms(report.gen_time), 3)
                .field_f64("server_ms", ms(report.server_time), 3)
                .field_f64("wall_ms", ms(report.wall_time), 3)
                .field_f64("peak_rss_mb", report.peak_rss_mb, 1);
        })
        .map_err(|e| anyhow!("appending the loadgen datapoint to {}: {e}", path.display()))?;
        // The soak curve: per-round latency quantiles at this cohort
        // size. Deliberately free of `_bytes` fields — bench-diff fails
        // any byte growth, and a scale datapoint reports time, not
        // payload.
        history::append_with(path, "loadgen_soak", |o| {
            o.field_u64("clients", report.clients as u64)
                .field_u64("lanes", report.lanes as u64)
                .field_u64("rounds", report.rounds as u64)
                .field_u64("completed", report.completed as u64)
                .field_f64("p50_ms", report.p50_ms, 3)
                .field_f64("p95_ms", report.p95_ms, 3)
                .field_f64("p99_ms", report.p99_ms, 3);
        })
        .map_err(|e| anyhow!("appending the soak datapoint to {}: {e}", path.display()))?;
    }
    Ok(report)
}

/// Regenerate every completed client's sparse update and require the
/// reconstructed delta to equal their exact wrapping sum.
fn verify_expected(
    session: &Session,
    opts: &LoadgenOptions,
    outcomes: &[ClientOutcome],
    delta: &[u64],
) -> Result<()> {
    let mut expected = vec![0u64; session.params.m as usize];
    for (vid, o) in outcomes.iter().enumerate() {
        if *o != ClientOutcome::Completed {
            continue;
        }
        let (sel, dl) = client_inputs(session, opts.seed, vid as u64);
        for (&x, &d) in sel.iter().zip(&dl) {
            expected[x as usize] = expected[x as usize].wrapping_add(d);
        }
    }
    let mismatches = expected
        .iter()
        .zip(delta)
        .filter(|(e, d)| e != d)
        .count();
    ensure!(
        mismatches == 0,
        "reconstructed delta differs from the completed cohort's expected sum at \
         {mismatches} of {} positions",
        expected.len()
    );
    Ok(())
}

/// Replay the completed cohort through an in-process runtime and require
/// a bit-identical delta — the TCP deployment and the single-process
/// reference must compute the same aggregate.
fn verify_inproc(
    session: &Session,
    opts: &LoadgenOptions,
    outcomes: &[ClientOutcome],
    delta: &[u64],
) -> Result<()> {
    let survivors: Vec<(Vec<u64>, Vec<u64>)> = outcomes
        .iter()
        .enumerate()
        .filter(|(_, o)| **o == ClientOutcome::Completed)
        .map(|(vid, _)| client_inputs(session, opts.seed, vid as u64))
        .collect();
    if survivors.is_empty() {
        ensure!(
            delta.iter().all(|&x| x == 0),
            "no client completed, yet the reconstructed delta is non-zero"
        );
        return Ok(());
    }
    let mut rt = FslRuntimeBuilder::from_session(session.clone())
        .max_clients(survivors.len())
        .build::<u64>()?;
    let mut rng = Rng::new(opts.seed ^ 0x5EED);
    let res = rt.ssa(&survivors, &mut rng)?;
    rt.shutdown()?;
    ensure!(
        res.delta == delta,
        "the in-process runtime disagrees with the TCP deployment's delta for the same cohort"
    );
    Ok(())
}

/// Peak resident set of this process in MB (`VmHWM`, Linux); 0.0 where
/// procfs is unavailable.
fn peak_rss_mb() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            if let Some(tok) = rest.split_whitespace().next() {
                if let Ok(kb) = tok.parse::<f64>() {
                    return kb / 1024.0;
                }
            }
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_streams_are_deterministic() {
        let session = Session::new_full(SessionParams {
            m: 256,
            k: 8,
            cuckoo: CuckooParams::default().with_seed(3),
        });
        let (sel_a, dl_a) = client_inputs(&session, 42, 7);
        let (sel_b, dl_b) = client_inputs(&session, 42, 7);
        assert_eq!(sel_a, sel_b);
        assert_eq!(dl_a, dl_b);
        assert_eq!(sel_a.len(), 8);
        assert!(sel_a.iter().all(|&x| x < 256));
        assert!(dl_a.iter().zip(&sel_a).all(|(&d, &x)| d == x + 1));
        // Distinct clients must diverge (golden-ratio decorrelation).
        let (sel_c, _) = client_inputs(&session, 42, 8);
        assert_ne!(sel_a, sel_c);
    }

    #[test]
    fn straggle_decision_burns_one_draw_either_way() {
        // Same seed, different fractions: the *post-decision* stream
        // must be identical so the verifier can regenerate uploads.
        let mut a = client_rng(9, 4);
        let mut b = client_rng(9, 4);
        let _ = is_straggler(&mut a, 0.0);
        let _ = is_straggler(&mut b, 1.0);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn straggle_fraction_is_roughly_honoured() {
        let n = 10_000u64;
        let hits = (0..n)
            .filter(|&vid| {
                let mut rng = client_rng(1234, vid);
                is_straggler(&mut rng, 0.25)
            })
            .count();
        let frac = hits as f64 / n as f64;
        assert!(
            (0.2..0.3).contains(&frac),
            "straggle=0.25 silenced {frac:.3} of the cohort"
        );
    }

    #[test]
    fn lane_frames_lead_with_the_vid() {
        let f = lane_frame(0xDEAD_BEEF, vec![1, 2, 3]);
        assert_eq!(&f[..4], &0xDEAD_BEEFu32.to_le_bytes());
        assert_eq!(&f[4..], &[1, 2, 3]);
    }

    #[test]
    fn report_json_is_valid_and_ungated_on_bytes() {
        let report = LoadgenReport {
            clients: 10,
            lanes: 2,
            rounds: 3,
            completed: 8,
            straggler_cut: 1,
            dropped: 1,
            sent: 9,
            gen_time: Duration::from_millis(12),
            server_time: Duration::from_millis(34),
            wall_time: Duration::from_millis(56),
            p50_ms: 17.0,
            p95_ms: 19.0,
            p99_ms: 19.0,
            upload_bytes: 1_000,
            peak_rss_mb: 12.5,
            verified: true,
        };
        let json = report.to_json();
        assert!(crate::metrics::json::validate(&json), "{json}");
        assert!(json.contains("\"wall_ms\":56.000"));
        assert!(json.contains("\"rounds\":3"));
        assert!(json.contains("\"p95_ms\":19.000"));
        // The bench-diff gate fails any growth in `_bytes` metrics; a
        // scale report must never emit one (RSS is reported in MB).
        assert!(!json.contains("_bytes\""));
    }

    #[test]
    fn round_histogram_quantiles_cover_the_soak_fields() {
        let registry = MetricsRegistry::new();
        let h = registry.histogram(
            "fsl_loadgen_round_seconds",
            "wall time of one driven loadgen round, command to both replies",
            Unit::Seconds,
        );
        for ms in [10u64, 12, 15, 20, 90] {
            h.observe_duration(Duration::from_millis(ms));
        }
        let (p50, p95, p99) = (h.quantile_ms(0.50), h.quantile_ms(0.95), h.quantile_ms(0.99));
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // The tail observation (90 ms) must pull the high quantiles at
        // least one octave above the median's bucket.
        assert!(p99 >= 2.0 * p50, "{p50} vs {p99}");
    }
}
