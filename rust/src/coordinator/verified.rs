//! Malicious-model SSA round (§2.2 / §3.1): before aggregating, the two
//! servers run the sketching check on every client's bins and drop any
//! client whose upload is not a well-formed batch of point functions —
//! the ideal functionality's "selective vote" behaviour. Honest clients'
//! updates are aggregated exactly; a cheating client cannot poison
//! positions it did not legitimately vote for.
//!
//! Payloads live in 𝔽_p (sketching needs the field's multiplicative
//! structure, as in Boneh et al. \[9\]); the cross-server multiplication is
//! the idealised [`crate::sketch::SecureMul`] — the paper likewise omits
//! the sketch round from its evaluation.

use crate::crypto::field::Fp;
use crate::crypto::rng::Rng;
use crate::protocol::{ssa, AggregationEngine, Session};
use crate::sketch::{self, SecureMul};
use anyhow::{anyhow, Result};

/// Result of a verified round: the aggregate over accepted clients plus
/// the indices of rejected ones.
#[derive(Debug, Clone)]
pub struct VerifiedSsaResult {
    pub delta: Vec<Fp>,
    pub rejected: Vec<usize>,
}

/// Run one malicious-model SSA round in-process. `uploads[i]` is client
/// i's key batch (possibly adversarially malformed — construct it
/// directly rather than through `ssa::client_update` to attack).
///
/// One-shot wrapper: a persistent deployment verifies through a living
/// runtime instead — see [`super::FslRuntime::verified_ssa`].
#[deprecated(note = "build a coordinator::FslRuntime and call .verified_ssa(..)")]
pub fn run_verified_ssa_round(
    session: &Session,
    uploads: &[crate::dpf::MasterKeyBatch<Fp>],
    server_shared_seed: u64,
) -> Result<VerifiedSsaResult> {
    verify_and_aggregate(session, uploads, server_shared_seed)
}

/// The verification + aggregation core shared by the deprecated one-shot
/// wrapper and the runtime's command loop (`S_0` runs it — the sketch's
/// cross-server multiplication is the idealised [`SecureMul`], as in the
/// paper's evaluation, so the check itself is not split across threads).
pub(crate) fn verify_and_aggregate(
    session: &Session,
    uploads: &[crate::dpf::MasterKeyBatch<Fp>],
    server_shared_seed: u64,
) -> Result<VerifiedSsaResult> {
    let mut rng = Rng::new(server_shared_seed);
    let mut mul = SecureMul::new(server_shared_seed ^ SKETCH_TAG);
    let engine = AggregationEngine::serial();
    let mut rejected = Vec::new();
    let mut acc0 = vec![Fp::zero(); session.domain_size()];
    let mut acc1 = vec![Fp::zero(); session.domain_size()];
    for (i, batch) in uploads.iter().enumerate() {
        let keys0 = batch.server_keys(0);
        let keys1 = batch.server_keys(1);
        if keys0.len() != session.simple.num_bins() + session.params.cuckoo.sigma {
            rejected.push(i);
            continue;
        }
        if !sketch::verify_client_bins(session, &keys0, &keys1, &mut rng, &mut mul) {
            rejected.push(i);
            continue;
        }
        engine.aggregate_client_keys_into(session, &keys0, &mut acc0);
        engine.aggregate_client_keys_into(session, &keys1, &mut acc1);
    }
    if acc0.is_empty() {
        return Err(anyhow!("empty domain"));
    }
    Ok(VerifiedSsaResult {
        delta: ssa::reconstruct(&acc0, &acc1),
        rejected,
    })
}

/// Domain separator for the servers' shared sketching randomness.
const SKETCH_TAG: u64 = 0x53_4b_45_54_43_48; // "SKETCH"

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpf::{gen_batch_with_master, BinPoint};
    use crate::hashing::CuckooParams;
    use crate::protocol::{SessionParams, ssa};

    fn session() -> Session {
        Session::new_full(SessionParams {
            m: 512,
            k: 16,
            cuckoo: CuckooParams::default(),
        })
    }

    #[test]
    fn honest_clients_all_accepted() {
        let s = session();
        let mut rng = Rng::new(800);
        let mut uploads = Vec::new();
        let mut expected = vec![Fp::zero(); 512];
        for _ in 0..3 {
            let sel = rng.sample_distinct(16, 512);
            let dl: Vec<Fp> = sel.iter().map(|&x| Fp::new(x + 1)).collect();
            for (&i, d) in sel.iter().zip(&dl) {
                expected[i as usize] = expected[i as usize].add(*d);
            }
            uploads.push(ssa::client_update(&s, &sel, &dl, &mut rng).unwrap());
        }
        let res = verify_and_aggregate(&s, &uploads, 801).unwrap();
        assert!(res.rejected.is_empty());
        assert_eq!(res.delta, expected);
    }

    #[test]
    fn malicious_client_rejected_and_excluded() {
        let s = session();
        let mut rng = Rng::new(802);
        // Honest client.
        let sel = rng.sample_distinct(16, 512);
        let dl: Vec<Fp> = sel.iter().map(|_| Fp::new(7)).collect();
        let honest = ssa::client_update(&s, &sel, &dl, &mut rng).unwrap();
        let mut expected = vec![Fp::zero(); 512];
        for &i in &sel {
            expected[i as usize] = Fp::new(7);
        }
        // Malicious client: corrupt a first-level correction word of one
        // real key. Off the α-path both parties apply the (identically
        // corrupted) CW and still cancel, but ON the path only one party
        // applies it — every leaf under that node diverges, so the share
        // vector has a whole subtree of non-zeros instead of one point.
        let num_bins = s.simple.num_bins();
        let bins: Vec<BinPoint<Fp>> = (0..num_bins)
            .map(|j| {
                let depth = crate::dpf::depth_for(s.simple.bin(j).len().max(2));
                if j == 0 {
                    BinPoint { depth, point: Some((0, Fp::new(1000))) }
                } else {
                    BinPoint { depth, point: None }
                }
            })
            .collect();
        let mut evil = gen_batch_with_master(&bins, [9; 16], [13; 16]);
        evil.publics[0].cws[0].seed[5] ^= 0x40;

        let res = verify_and_aggregate(&s, &[honest, evil], 803).unwrap();
        assert_eq!(res.rejected, vec![1], "malicious client must be rejected");
        assert_eq!(res.delta, expected, "aggregate must exclude the cheater");
    }

    #[test]
    fn wrong_key_count_rejected() {
        let s = session();
        let mut rng = Rng::new(804);
        let sel = rng.sample_distinct(16, 512);
        let dl: Vec<Fp> = sel.iter().map(|_| Fp::one()).collect();
        let mut upload = ssa::client_update(&s, &sel, &dl, &mut rng).unwrap();
        upload.publics.pop(); // drop one bin
        let res = verify_and_aggregate(&s, &[upload], 805).unwrap();
        assert_eq!(res.rejected, vec![0]);
    }
}
