//! The persistent FSL runtime — one long-lived two-server deployment
//! serving many rounds (the paper's Fig. 1 loop as a *service*, not a
//! per-call thread spawn).
//!
//! The old coordinator exposed the round types as disconnected free
//! functions (`run_psr_round`, `run_ssa_round`, `run_verified_ssa_round`,
//! `run_psu_session`) that each rebuilt the [`crate::net`] topology,
//! respawned both server threads, and threaded 5–6 positional arguments —
//! no state survived between rounds. A deployment serving millions of
//! users amortises all of that: the [`FslRuntimeBuilder`] constructs one
//! [`FslRuntime`] that owns
//!
//! * the two server threads (`S_0` leader, `S_1` worker), each running a
//!   small command loop for its whole lifetime;
//! * the metered channel topology (clients ↔ both servers, `S_0 ↔ S_1`);
//! * one [`AggregationEngine`] + [`RetrievalEngine`] pair per server,
//!   built once from the configured width;
//! * the shared [`Session`] (replaceable mid-life: [`FslRuntime::psu_align`]
//!   installs a union-domain session on both living servers);
//! * in U-DPF key mode, each server's retained epoch key sets and the
//!   runtime-side client states, so later rounds upload `⌈log 𝔾⌉`-bit
//!   hints instead of fresh keys (§6 Table 2 row 3).
//!
//! Rounds are methods — [`FslRuntime::psr`], [`FslRuntime::ssa`],
//! [`FslRuntime::verified_ssa`], [`FslRuntime::psu_align`] — and every
//! one returns the same [`RoundReport`] (per-party bytes, gen/server/wall
//! times) instead of four differently-shaped result structs. Client
//! payloads travel the existing [`msg`] wire encodings over the metered
//! links; the control plane (round commands, session/weight installs) is
//! the typed [`wire::ServerCmd`]/[`wire::ServerReply`] protocol.
//!
//! **Transports.** Every link is a [`Transport`] behind the runtime, so
//! the same round drivers and the same server command loop run over two
//! deployments:
//!
//! * [`FslRuntimeBuilder::build`] — the historical single process: both
//!   servers as threads, links as latency/bandwidth-simulating in-process
//!   channels, control as typed `mpsc` (no serialisation — `Arc` payloads
//!   are shared, keeping this path bit-identical to the pre-transport
//!   code).
//! * [`FslRuntimeBuilder::connect`] — two standalone server processes
//!   (`fsl serve`, [`super::serve`]) over framed TCP: control commands
//!   are wire-encoded ([`wire`]), data links are per-client sockets, and
//!   the `S_0 ↔ S_1` exchange runs over a real peer connection.
//!
//! The old `run_*` functions survive as thin `#[deprecated]` one-shot
//! wrappers: build a runtime, run one round, drop it.

use super::config::FslConfig;
use super::verified;
use super::wire::{self, ServerCmd, ServerReply};
use crate::crypto::field::Fp;
use crate::crypto::rng::Rng;
use crate::dpf::MasterKeyBatch;
use crate::group::Group;
use crate::metrics::expo;
use crate::metrics::json::{self, JsonObj};
use crate::metrics::registry::{Counter, Gauge, MetricsRegistry};
use crate::metrics::trace::{self, Party, Phase, PhaseMetrics, Span, TraceRecorder, TraceSink};
use crate::metrics::CommMeter;
use crate::net::{self, LinkProfile};
use crate::net::reactor::{FramePump, PumpEvent, PumpMetrics};
use crate::net::transport::tcp::{TcpOptions, TcpTransport};
use crate::net::transport::{
    BoxTransport, FaultPlan, Hello, InProc, Role, Transport, TransportError,
};
use crate::protocol::aggregate::uploads_of;
use crate::protocol::{
    msg, psr, psu, ssa, udpf_ssa, AggregationEngine, RetrievalEngine, Session, SessionParams,
    Sharding,
};
use anyhow::{anyhow, bail, ensure, Result};
use crate::crypto::Sensitive;
use std::io::Write as _;
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default for how long the driver waits for a server reply before
/// declaring the runtime wedged (override with
/// [`FslRuntimeBuilder::reply_timeout`]). Generous: a round at paper
/// scale (m ≈ 2²⁵) finishes in seconds; only a protocol bug or a wedged
/// remote peer hits this.
const REPLY_TIMEOUT: Duration = Duration::from_secs(600);

/// Default bound on establishing one TCP connection's handshake in
/// [`FslRuntimeBuilder::connect`].
const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// A client's two data links, transport-agnostic.
struct Links {
    to_s0: BoxTransport,
    to_s1: BoxTransport,
}

/// Which round a [`RoundReport`] describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundKind {
    /// Private submodel retrieval (read path).
    Psr,
    /// Secure submodel aggregation (write path; fresh keys or U-DPF).
    Ssa,
    /// Malicious-model SSA with the sketching check.
    VerifiedSsa,
    /// PSU domain alignment (installs a union session).
    PsuAlign,
}

impl RoundKind {
    /// Stable machine-readable name (the `kind` field of
    /// [`RoundReport::to_json`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            RoundKind::Psr => "psr",
            RoundKind::Ssa => "ssa",
            RoundKind::VerifiedSsa => "verified_ssa",
            RoundKind::PsuAlign => "psu_align",
        }
    }
}

/// How one client fared in a round. Strict rounds (no upload deadline)
/// only ever produce `Completed` — any failure aborts the whole round
/// instead. Tolerant rounds ([`FslRuntimeBuilder::upload_deadline`])
/// record per-client fates and complete on the surviving cohort.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientOutcome {
    /// Upload arrived intact on both servers within the deadline.
    Completed,
    /// The client's link closed or its upload was malformed — or the
    /// *other* server failed to hear it (cohort agreement drops a client
    /// unless both servers heard it).
    Dropped,
    /// The client stayed silent past the upload deadline. Like `Dropped`
    /// it is evicted from every later round: its late bytes must never be
    /// mistaken for the next round's upload.
    StragglerCut,
}

impl ClientOutcome {
    /// Stable machine-readable name (the `outcomes` entries of
    /// [`RoundReport::to_json`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            ClientOutcome::Completed => "completed",
            ClientOutcome::Dropped => "dropped",
            ClientOutcome::StragglerCut => "straggler_cut",
        }
    }
}

/// Uniform per-round metering — the one result shape every round method
/// returns alongside its payload. Byte counters are *measured* wire bytes
/// from the channel meters (reset at round start, so each report covers
/// exactly one round), not model formulas.
#[derive(Debug, Clone)]
pub struct RoundReport {
    /// Which round produced this report.
    pub kind: RoundKind,
    /// Participating clients this round.
    pub clients: usize,
    /// Client → servers bytes (all clients, both servers).
    pub client_upload_bytes: u64,
    /// Servers → client bytes (answers, union broadcasts; 0 for SSA).
    pub client_download_bytes: u64,
    /// `S_0 ↔ S_1` bytes (forwarded publics, share vectors, PSU pools).
    pub server_exchange_bytes: u64,
    /// Client-side key/hint/blinding generation wall-clock (summed over
    /// clients, as the paper's per-client Table-5 numbers are).
    pub gen_time: Duration,
    /// Max of the two servers' compute wall-clocks.
    pub server_time: Duration,
    /// End-to-end round wall-clock as seen by the driver.
    pub wall_time: Duration,
    /// Per-client fates, indexed like the round's client slice. Strict
    /// rounds report every client `Completed` (a failure would have
    /// aborted the round instead).
    pub outcomes: Vec<ClientOutcome>,
    /// Per-phase spans from every participant (driver + both servers),
    /// party-tagged. Export with [`RoundReport::trace_json`] /
    /// [`RoundReport::write_trace`].
    pub spans: Vec<Span>,
    /// Spans the *driver-side* recorder discarded because its ring was
    /// full. Server-side drops surface through each server's own
    /// `fsl_trace_spans_dropped_count` registry gauge instead of the
    /// wire. Non-zero means `spans` under-reports the round.
    pub spans_dropped: u64,
}

impl RoundReport {
    /// Clients that completed this round (survivor count).
    pub fn completed(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| **o == ClientOutcome::Completed)
            .count()
    }

    /// Schema version stamped into every [`RoundReport::to_json`] line.
    /// Bump on any breaking field change.
    pub const JSON_SCHEMA: u64 = 1;

    /// One-line JSON rendering for machine consumption (the CLI's
    /// `--json` mode, multi-process CI assertions, dashboards). Times are
    /// fractional milliseconds; byte fields are exact; string fields are
    /// escaped by the shared [`crate::metrics::json`] writer.
    pub fn to_json(&self) -> String {
        let ms = |d: Duration| d.as_secs_f64() * 1e3;
        let mut o = JsonObj::new();
        o.field_u64("schema", Self::JSON_SCHEMA)
            .field_str("kind", self.kind.as_str())
            .field_u64("clients", self.clients as u64)
            .field_u64("client_upload_bytes", self.client_upload_bytes)
            .field_u64("client_download_bytes", self.client_download_bytes)
            .field_u64("server_exchange_bytes", self.server_exchange_bytes)
            .field_f64("gen_ms", ms(self.gen_time), 3)
            .field_f64("server_ms", ms(self.server_time), 3)
            .field_f64("wall_ms", ms(self.wall_time), 3)
            .field_raw(
                "outcomes",
                &json::array(self.outcomes.iter().map(|o| json::string(o.as_str()))),
            )
            .field_u64("spans", self.spans.len() as u64)
            .field_u64("spans_dropped", self.spans_dropped);
        o.finish()
    }

    /// This round's spans as a Chrome trace-event JSON document —
    /// loadable directly in Perfetto / `chrome://tracing`. Includes
    /// derived counter tracks (`ph:"C"`): per-party active-span depth
    /// and the driver's dropped-span count.
    pub fn trace_json(&self) -> String {
        let dropped = trace::counter_event(
            "fsl_trace_spans_dropped_count",
            0.0,
            Party::Client,
            self.spans_dropped,
        );
        trace::chrome_trace_json_with(&self.spans, &[dropped])
    }

    /// Write [`RoundReport::trace_json`] to `path` (the CLI's
    /// `trace=PATH` option), creating parent directories as needed.
    pub fn write_trace(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.trace_json())
    }
}

/// One server's live-metrics snapshot, rendered server-side in both
/// exposition formats (so the two renderings reflect the same atomic
/// registry snapshot). Returned by [`FslRuntime::stats`] and the `fsl
/// stats` CLI's scrape path.
#[derive(Debug, Clone)]
pub struct ServerStats {
    /// Prometheus text exposition format (0.0.4).
    pub prom: String,
    /// JSON document ([`crate::metrics::expo::render_json`]).
    pub json: String,
}

/// A PSR round's payload + metering.
#[derive(Debug, Clone)]
pub struct PsrOutcome<G: Group> {
    /// Retrieved weights in `selections` order, per client.
    pub submodels: Vec<Vec<G>>,
    pub report: RoundReport,
}

/// An SSA round's payload + metering.
#[derive(Debug, Clone)]
pub struct SsaOutcome<G: Group> {
    /// Reconstructed global update (sum over clients), domain-indexed.
    pub delta: Vec<G>,
    pub report: RoundReport,
}

/// A verified SSA round's payload + metering.
#[derive(Debug, Clone)]
pub struct VerifiedSsaOutcome {
    /// Aggregate over the accepted clients.
    pub delta: Vec<Fp>,
    /// Indices of rejected (malformed) clients.
    pub rejected: Vec<usize>,
    pub report: RoundReport,
}

/// A PSU alignment round's payload + metering. The new union session is
/// installed on the runtime — read it back via [`FslRuntime::session`].
#[derive(Debug, Clone)]
pub struct PsuOutcome {
    /// Size of the revealed union `|∪ s^(i)|` (the new domain size).
    pub union_len: usize,
    pub report: RoundReport,
}

/// Whether SSA rounds re-key every round or retain U-DPF epoch keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KeyMode {
    /// Fresh DPF keys every round (the basic protocol, Fig. 4).
    #[default]
    Fresh,
    /// Fixed-submodel U-DPF keys (§6 Table 2 row 3): the first
    /// [`FslRuntime::ssa`] call uploads full key sets that both servers
    /// retain; every later call uploads only `⌈log 𝔾⌉`-bit hints per
    /// bin. Requires the same clients (and selections) each round.
    Udpf,
}

/// How the builder obtains the session the runtime starts with.
enum SessionSpec {
    /// Dense full domain `{0..m}`.
    Full(SessionParams),
    /// PSU-union domain known up front (validated at build).
    Union(SessionParams, Vec<u64>),
    /// Adopt an existing session (full or union) as-is.
    Prebuilt(Session),
}

/// Typed builder for a [`FslRuntime`] — session parameters, domain mode
/// (full / PSU-union), simulated latency, engine width, client capacity,
/// and key mode (fresh / U-DPF) in one place. The payload mode (scalar
/// `u64`/`u128`, field `Fp`, or mega-element rows) is the `G` chosen at
/// [`FslRuntimeBuilder::build`].
pub struct FslRuntimeBuilder {
    spec: SessionSpec,
    latency: Duration,
    bandwidth: u64,
    threads: usize,
    max_clients: usize,
    key_mode: KeyMode,
    reply_timeout: Duration,
    connect_timeout: Duration,
    connect_retry: Duration,
    upload_deadline: Option<Duration>,
    faults: Vec<(usize, FaultPlan)>,
}

impl FslRuntimeBuilder {
    /// Full-domain runtime over `params`.
    pub fn new(params: SessionParams) -> Self {
        Self::with_spec(SessionSpec::Full(params))
    }

    /// Adopt an existing session (full-domain or PSU-union) as-is.
    pub fn from_session(session: Session) -> Self {
        Self::with_spec(SessionSpec::Prebuilt(session))
    }

    fn with_spec(spec: SessionSpec) -> Self {
        FslRuntimeBuilder {
            spec,
            latency: Duration::ZERO,
            bandwidth: 0,
            threads: 0,
            max_clients: 1,
            key_mode: KeyMode::Fresh,
            reply_timeout: REPLY_TIMEOUT,
            connect_timeout: CONNECT_TIMEOUT,
            connect_retry: Duration::ZERO,
            upload_deadline: None,
            faults: Vec::new(),
        }
    }

    /// Training-loop convenience: validate `cfg` and derive the session
    /// (top-k size from `cfg.compression`, cuckoo seed from `cfg.seed` as
    /// the training loop always has), latency, engine width, and client
    /// capacity from it. `m` is the flat model size.
    pub fn from_config(cfg: &FslConfig, m: u64) -> Result<Self> {
        cfg.validate()?;
        let k = ((m as f64 * cfg.compression).round() as usize).clamp(1, m as usize);
        let params = SessionParams {
            m,
            k,
            cuckoo: crate::hashing::CuckooParams {
                hash_seed: cfg.seed ^ 0xABCD,
                ..cfg.cuckoo
            },
        };
        let mut builder = Self::new(params)
            .latency(Duration::from_micros(cfg.latency_us))
            .bandwidth(cfg.bandwidth_bps)
            .threads(cfg.threads)
            .max_clients(cfg.participants());
        if let Some(deadline) = cfg.upload_deadline {
            builder = builder.upload_deadline(deadline);
        }
        Ok(builder)
    }

    /// Start from a PSU-union domain known up front (validated at build;
    /// to *compute* the union through the living servers instead, build a
    /// full-domain runtime and call [`FslRuntime::psu_align`]).
    pub fn union_domain(mut self, union: Vec<u64>) -> Self {
        self.spec = match self.spec {
            SessionSpec::Full(p) | SessionSpec::Union(p, _) => SessionSpec::Union(p, union),
            SessionSpec::Prebuilt(s) => SessionSpec::Union(s.params.clone(), union),
        };
        self
    }

    /// Simulated one-way channel latency (paper §7: ≈3 ms LAN).
    /// In-process only — real TCP links have real latency.
    pub fn latency(mut self, latency: Duration) -> Self {
        self.latency = latency;
        self
    }

    /// Simulated link bandwidth in bytes/second (`0` = unlimited, the
    /// default). With a finite bandwidth every simulated link charges
    /// transmit time per byte, so [`RoundReport`] wall times stay honest
    /// for large payloads. In-process only, like [`Self::latency`].
    pub fn bandwidth(mut self, bytes_per_sec: u64) -> Self {
        self.bandwidth = bytes_per_sec;
        self
    }

    /// How long round drivers wait for a server reply (or a data-link
    /// message) before declaring the runtime wedged and poisoning it.
    pub fn reply_timeout(mut self, timeout: Duration) -> Self {
        self.reply_timeout = timeout;
        self
    }

    /// Bound on each TCP connection handshake in [`Self::connect`].
    pub fn connect_timeout(mut self, timeout: Duration) -> Self {
        self.connect_timeout = timeout;
        self
    }

    /// Keep retrying refused/failed TCP dials for this long in
    /// [`Self::connect`] (exponential backoff, 100 ms doubling to a 2 s
    /// cap). `ZERO` (the default) means a single attempt. A typed
    /// handshake *rejection* (wrong party/group) is permanent and fails
    /// immediately regardless of the window. This is what lets a driver
    /// reconnect to servers that are still restarting from a snapshot.
    pub fn connect_retry(mut self, window: Duration) -> Self {
        self.connect_retry = window;
        self
    }

    /// Tolerate client dropouts and stragglers: bound every per-client
    /// upload receive by `deadline` and let rounds complete on the
    /// surviving cohort, recording per-client [`ClientOutcome`]s in the
    /// [`RoundReport`]. Without a deadline (the default) rounds are
    /// strict: any client failure aborts the round and poisons the
    /// runtime, the historical behaviour. `deadline` must be positive —
    /// the wire encodes "strict" as zero nanoseconds, so an explicit
    /// `Duration::ZERO` here is ambiguous and fails at build/connect.
    pub fn upload_deadline(mut self, deadline: Duration) -> Self {
        self.upload_deadline = Some(deadline);
        self
    }

    /// Reject the ambiguous zero deadline: the wire's `deadline_nanos`
    /// field uses `0` as the "strict round" sentinel, so an explicitly
    /// configured zero would silently come out the other side as "no
    /// deadline at all" instead of "drop everyone instantly".
    fn check_deadline(&self) -> Result<()> {
        ensure!(
            self.upload_deadline != Some(Duration::ZERO),
            "upload_deadline must be positive: zero is the wire's \"strict round\" sentinel \
             and would be silently read back as no deadline (omit upload_deadline for \
             strict rounds)"
        );
        Ok(())
    }

    /// Inject a deterministic [`FaultPlan`] on client `i`'s links (both
    /// directions share one byte/message budget, so a plan can cut a
    /// client *between* its two SSA uploads). Works identically over
    /// in-process channels and TCP sockets.
    pub fn client_fault(mut self, client: usize, plan: FaultPlan) -> Self {
        self.faults.push((client, plan));
        self
    }

    /// Engine workers per server: an explicit count, or `0` for the
    /// co-located-two-server default (half the cores each) — the
    /// [`Sharding::from_config`] convention shared with `FslConfig`.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Maximum clients any single round may bring (the channel topology
    /// is built once, at this capacity). Rounds may use fewer.
    pub fn max_clients(mut self, n: usize) -> Self {
        self.max_clients = n;
        self
    }

    /// SSA key mode: fresh per-round keys (default) or retained U-DPF
    /// epoch keys with hint-only later rounds.
    pub fn key_mode(mut self, mode: KeyMode) -> Self {
        self.key_mode = mode;
        self
    }

    /// Build the session this runtime starts with.
    fn make_session(spec: SessionSpec) -> Result<Session> {
        Ok(match spec {
            SessionSpec::Full(params) => Session::new_full(params),
            SessionSpec::Union(params, union) => Session::new_union(params, union)?,
            SessionSpec::Prebuilt(s) => s,
        })
    }

    /// Wrap each faulted client's links with one shared injector.
    fn apply_faults(links: Vec<Links>, faults: &[(usize, FaultPlan)]) -> Result<Vec<Links>> {
        for (i, _) in faults {
            ensure!(
                *i < links.len(),
                "fault plan targets client {i} but capacity is max_clients = {}",
                links.len()
            );
        }
        Ok(links
            .into_iter()
            .enumerate()
            .map(|(i, l)| match faults.iter().find(|(c, _)| *c == i) {
                Some((_, plan)) => {
                    let inj = plan.clone().injector();
                    Links {
                        to_s0: inj.wrap(l.to_s0),
                        to_s1: inj.wrap(l.to_s1),
                    }
                }
                None => l,
            })
            .collect())
    }

    /// Spawn the two server threads and hand back the living runtime.
    /// `G` fixes the payload group for the runtime's lifetime (scalar
    /// `u64`/`u128`, `Fp` for verified rounds, `MegaElem` for §6 rows).
    pub fn build<G: Group>(self) -> Result<FslRuntime<G>> {
        ensure!(
            self.max_clients >= 1,
            "runtime capacity must be at least one client (got max_clients = 0)"
        );
        self.check_deadline()?;
        let session = Arc::new(Self::make_session(self.spec)?);
        let profile = LinkProfile {
            latency: self.latency,
            bandwidth: self.bandwidth,
        };
        let (client_links, server_sides, (inter0, inter1)) =
            net::topology_profile(self.max_clients, profile);
        let (eps0, eps1): (Vec<_>, Vec<_>) = server_sides.into_iter().unzip();
        let inter_meters = vec![inter0.meter.clone(), inter1.meter.clone()];
        let sharding = Sharding::from_config(self.threads);

        let mut server_links = Vec::with_capacity(2);
        for (party, eps, inter) in [(0u8, eps0, inter0), (1u8, eps1, inter1)] {
            let (ctx, crx) = channel::<ServerCmd<G>>();
            let (rtx, rrx) = channel::<ServerReply<G>>();
            let rec = TraceRecorder::shared(trace::DEFAULT_TRACE_CAPACITY);
            let sink = TraceSink::new(rec.clone(), Party::server(usize::from(party)));
            let registry = MetricsRegistry::shared();
            rec.attach_metrics(PhaseMetrics::register(&registry));
            let metrics = ServerMetrics::register(&registry);
            let server = ServerHalf {
                party,
                session: session.clone(),
                agg: AggregationEngine::with_sharding(sharding).with_trace(sink.clone()),
                ret: RetrievalEngine::with_sharding(sharding).with_trace(sink),
                eps: eps
                    .into_iter()
                    .map(|e| Box::new(InProc(e)) as BoxTransport)
                    .collect(),
                inter: Some(Box::new(InProc(inter)) as BoxTransport),
                mux: None,
                weights: None,
                udpf: Vec::new(),
                udpf_links: Vec::new(),
                udpf_total: 0,
                dead: Vec::new(),
                timeout: self.reply_timeout,
                trace: rec,
                registry,
                metrics,
            };
            let handle = std::thread::Builder::new()
                .name(format!("fsl-server-{party}"))
                .spawn(move || server.run(crx, rtx))
                .map_err(|e| anyhow!("spawning server S{party}: {e}"))?;
            server_links.push(ServerLink::Local {
                cmd_tx: ctx,
                rep_rx: rrx,
                handle: Some(handle),
            });
        }
        let links = Self::apply_faults(
            client_links
                .into_iter()
                .map(|cl| Links {
                    to_s0: Box::new(InProc(cl.to_s0)) as BoxTransport,
                    to_s1: Box::new(InProc(cl.to_s1)) as BoxTransport,
                })
                .collect(),
            &self.faults,
        )?;
        let n = links.len();
        Ok(FslRuntime {
            session,
            key_mode: self.key_mode,
            links,
            inter_meters,
            server_links,
            reply_timeout: self.reply_timeout,
            upload_deadline: self.upload_deadline,
            dead: vec![false; n],
            weights_len: None,
            udpf_clients: Vec::new(),
            udpf_selections: Vec::new(),
            udpf_epoch: 0,
            poisoned: None,
            trace: TraceRecorder::shared(trace::DEFAULT_TRACE_CAPACITY),
        })
    }

    /// Connect to two standalone servers (`fsl serve`, hosted by
    /// [`crate::coordinator::serve_addr`]) listening at `s0_addr` /
    /// `s1_addr`, and hand back a runtime whose rounds run over framed
    /// TCP across three OS processes.
    ///
    /// Connection order matters and is handled here: the control channel
    /// and `max_clients` data links are dialled to each server (every
    /// handshake individually acked), then `S_1` is told to dial the
    /// `S_0 ↔ S_1` peer link at `s0_addr`, and finally the session is
    /// installed on both servers. The servers adopt this builder's
    /// session, key mode, and client capacity; `latency`/`bandwidth`
    /// simulation does not apply (real sockets have real latency), and
    /// neither does [`Self::threads`] — each `serve` process sets its
    /// own engine width at startup (`fsl serve threads=N`).
    ///
    /// The runtime owns the deployment: dropping it (or calling
    /// [`FslRuntime::shutdown`]) tells both server processes to exit.
    pub fn connect<G: Group>(self, s0_addr: &str, s1_addr: &str) -> Result<FslRuntime<G>> {
        ensure!(
            self.max_clients >= 1,
            "runtime capacity must be at least one client (got max_clients = 0)"
        );
        self.check_deadline()?;
        let session = Arc::new(Self::make_session(self.spec)?);
        let opts = TcpOptions {
            handshake_timeout: self.connect_timeout,
            write_timeout: Some(self.reply_timeout),
        };
        let n = self.max_clients;
        let group = std::any::type_name::<G>().to_string();
        let mut per_party: Vec<(BoxTransport, Vec<BoxTransport>)> = Vec::with_capacity(2);
        for (party, addr) in [(0u8, s0_addr), (1u8, s1_addr)] {
            let hello = Hello {
                party,
                role: Role::Control {
                    max_clients: wire_u32(n, "max_clients")?,
                    m: session.params.m,
                    k: session.params.k as u64,
                    group: group.clone(),
                },
            };
            let ctrl = dial_with_retry(addr, &hello, &opts, self.connect_retry)
                .map_err(|e| e.context(format!("control channel to S{party} at {addr}")))?;
            let mut eps: Vec<BoxTransport> = Vec::with_capacity(n);
            for id in 0..n {
                let link = dial_with_retry(
                    addr,
                    &Hello {
                        party,
                        role: Role::Client {
                            id: wire_u32(id, "client link id")?,
                        },
                    },
                    &opts,
                    self.connect_retry,
                )
                .map_err(|e| e.context(format!("client link {id} to S{party} at {addr}")))?;
                eps.push(Box::new(link) as BoxTransport);
            }
            per_party.push((Box::new(ctrl) as BoxTransport, eps));
        }
        let ((ctrl1, eps1), (ctrl0, eps0)) = match (per_party.pop(), per_party.pop()) {
            (Some(p1), Some(p0)) => (p1, p0),
            _ => bail!("deployment dialled fewer than two servers"),
        };
        let links = Self::apply_faults(
            eps0.into_iter()
                .zip(eps1)
                .map(|(to_s0, to_s1)| Links { to_s0, to_s1 })
                .collect(),
            &self.faults,
        )?;
        let mut rt = FslRuntime {
            session: session.clone(),
            key_mode: self.key_mode,
            links,
            // Remote: the S_0 ↔ S_1 link lives between the two server
            // processes — its bytes come back in the round replies.
            inter_meters: Vec::new(),
            server_links: vec![
                ServerLink::Remote { ctrl: ctrl0 },
                ServerLink::Remote { ctrl: ctrl1 },
            ],
            reply_timeout: self.reply_timeout,
            upload_deadline: self.upload_deadline,
            dead: vec![false; n],
            weights_len: None,
            udpf_clients: Vec::new(),
            udpf_selections: Vec::new(),
            udpf_epoch: 0,
            poisoned: None,
            trace: TraceRecorder::shared(trace::DEFAULT_TRACE_CAPACITY),
        };
        // S_1 first: S_0 is still blocked accepting the peer link, which
        // S_1 dials on DialPeer. Only then does S_0's command loop start.
        rt.command(1, ServerCmd::SetSession(session.clone()))?;
        rt.expect_ack(1, "installing the session on S1")?;
        rt.command(
            1,
            ServerCmd::DialPeer {
                addr: s0_addr.to_string(),
            },
        )?;
        rt.expect_ack(1, "establishing the S0<->S1 peer link")?;
        rt.command(0, ServerCmd::SetSession(session))?;
        rt.expect_ack(0, "installing the session on S0")?;
        Ok(rt)
    }
}

/// Narrow a count for the wire: the protocol's header fields are `u32`,
/// and an `as` cast would silently truncate an oversized 64-bit count
/// into a different, valid-looking value on the far side. `try_from`
/// turns overflow into a typed error instead (see the `cast-truncation`
/// fsl-lint rule covering this file and `wire.rs`).
fn wire_u32(value: usize, what: &str) -> Result<u32> {
    u32::try_from(value).map_err(|_| anyhow!("{what} = {value} exceeds the wire's u32 range"))
}

/// Dial one TCP link, retrying refused/failed connections with
/// exponential backoff for up to `window` (`ZERO` = single attempt).
/// A typed handshake rejection is permanent — retrying a wrong-party or
/// wrong-group dial can never succeed, so it fails immediately.
pub(crate) fn dial_with_retry(
    addr: &str,
    hello: &Hello,
    opts: &TcpOptions,
    window: Duration,
) -> Result<TcpTransport> {
    let deadline = Instant::now() + window;
    let mut backoff = Duration::from_millis(100);
    loop {
        match TcpTransport::connect(addr, hello, opts) {
            Ok(t) => return Ok(t),
            Err(e) => {
                let rejected =
                    matches!(TransportError::of(&e), Some(TransportError::Rejected(_)));
                let now = Instant::now();
                if rejected || now >= deadline {
                    return Err(e);
                }
                std::thread::sleep(backoff.min(deadline - now));
                backoff = (backoff * 2).min(Duration::from_secs(2));
            }
        }
    }
}

/// The driver's handle to one server: either a spawned thread driven
/// over typed channels (no serialisation — `Arc` payloads shared), or a
/// remote process driven over a wire-encoded control transport.
enum ServerLink<G: Group> {
    Local {
        cmd_tx: Sender<ServerCmd<G>>,
        rep_rx: Receiver<ServerReply<G>>,
        handle: Option<JoinHandle<()>>,
    },
    Remote {
        ctrl: BoxTransport,
    },
}

impl<G: Group> ServerLink<G> {
    fn command(&self, party: usize, cmd: ServerCmd<G>) -> Result<()> {
        match self {
            ServerLink::Local { cmd_tx, .. } => cmd_tx
                .send(cmd)
                .map_err(|_| anyhow!("server S{party} has shut down")),
            ServerLink::Remote { ctrl } => ctrl
                .send(wire::encode_cmd(&cmd))
                .map_err(|e| e.context(format!("sending a command to server S{party}"))),
        }
    }

    fn reply(&self, party: usize, timeout: Duration) -> Result<ServerReply<G>> {
        match self {
            ServerLink::Local { rep_rx, .. } => rep_rx
                .recv_timeout(timeout)
                .map_err(|e| anyhow!("no reply from server S{party}: {e}")),
            ServerLink::Remote { ctrl } => {
                let bytes = ctrl
                    .recv_timeout(timeout)
                    .map_err(|e| e.context(format!("no reply from server S{party}")))?;
                wire::decode_reply(&bytes)
            }
        }
    }

    /// Ask the server to exit. Returns true iff a *local* server thread
    /// panicked (a remote server exits in its own process; transport
    /// errors on a best-effort shutdown send are ignored).
    fn shutdown(&mut self) -> bool {
        match self {
            ServerLink::Local { cmd_tx, handle, .. } => {
                let _ = cmd_tx.send(ServerCmd::Shutdown);
                handle.take().map(|h| h.join().is_err()).unwrap_or(false)
            }
            ServerLink::Remote { ctrl } => {
                let _ = ctrl.send(wire::encode_cmd::<G>(&ServerCmd::Shutdown));
                false
            }
        }
    }
}

/// A persistent two-server FSL deployment. Construct through
/// [`FslRuntimeBuilder`]; round methods may be called any number of
/// times, in any order, against the same living servers — in-process
/// threads ([`FslRuntimeBuilder::build`]) or standalone TCP processes
/// ([`FslRuntimeBuilder::connect`]). Dropping the runtime shuts both
/// servers down (and joins local threads).
pub struct FslRuntime<G: Group> {
    session: Arc<Session>,
    key_mode: KeyMode,
    links: Vec<Links>,
    /// In-process `S_0 ↔ S_1` meters; empty against remote servers
    /// (whose exchange bytes come back in round replies).
    inter_meters: Vec<Arc<CommMeter>>,
    server_links: Vec<ServerLink<G>>,
    reply_timeout: Duration,
    /// `Some` = tolerant rounds: per-client upload receives are bounded
    /// by this deadline and rounds complete on the surviving cohort.
    upload_deadline: Option<Duration>,
    /// Clients evicted by an earlier tolerant round (their links may
    /// carry stale bytes): the driver never sends to or reads from them
    /// again, mirroring the servers' own eviction.
    dead: Vec<bool>,
    /// Driver-side record of the installed weight vector length (the
    /// vectors themselves live on the servers).
    weights_len: Option<usize>,
    /// U-DPF mode: per-client hint state retained across epochs.
    udpf_clients: Vec<udpf_ssa::UdpfSsaClient<G>>,
    /// U-DPF mode: each client's epoch-0 distinct selection set (the
    /// fixed-submodel contract, validated on every later round).
    udpf_selections: Vec<Vec<u64>>,
    /// U-DPF mode: next epoch number (0 = setup round).
    udpf_epoch: u64,
    /// Set when a server reply failed or timed out: the reply streams may
    /// be desynchronised, so every later round refuses to run.
    poisoned: Option<String>,
    /// Driver-side span recorder (client-party keygen/upload/reply
    /// spans); server spans arrive in the round replies and the two
    /// streams merge into [`RoundReport::spans`].
    trace: Arc<TraceRecorder>,
}

impl<G: Group> FslRuntime<G> {
    /// The session currently shared by both servers and all clients.
    pub fn session(&self) -> &Session {
        self.session.as_ref()
    }

    /// Client capacity the topology was built for.
    pub fn max_clients(&self) -> usize {
        self.links.len()
    }

    /// Snapshot both servers' live metric registries (index 0 = `S_0`,
    /// 1 = `S_1`), each rendered server-side in both exposition formats.
    /// Not a round: registry counters are read, never reset, so scraping
    /// between rounds never perturbs the next [`RoundReport`].
    pub fn stats(&mut self) -> Result<[ServerStats; 2]> {
        self.check_healthy()?;
        self.command_both(ServerCmd::Stats)?;
        let mut out: [ServerStats; 2] = std::array::from_fn(|_| ServerStats {
            prom: String::new(),
            json: String::new(),
        });
        let mut failure: Option<anyhow::Error> = None;
        // Drain BOTH replies even when the first fails (same invariant
        // as `ack_both`: a half-read reply stream shifts later rounds).
        for party in 0..2 {
            match self.reply(party) {
                Ok(ServerReply::Stats { prom, json }) => {
                    out[party] = ServerStats { prom, json };
                }
                Ok(other) => {
                    failure.get_or_insert(other.into_protocol_error("stats"));
                }
                Err(e) => {
                    failure.get_or_insert(e);
                }
            }
        }
        match failure {
            Some(e) => {
                self.poison(&e);
                Err(e)
            }
            None => Ok(out),
        }
    }

    /// Install the servers' weight vector (the PSR database), indexed by
    /// global model index — required before [`FslRuntime::psr`]. In a
    /// deployment this is the state the servers already hold; here the
    /// driver hands it over once and rounds reuse it.
    pub fn set_weights(&mut self, weights: Vec<G>) -> Result<()> {
        self.check_healthy()?;
        ensure!(
            weights.len() == self.session.params.m as usize,
            "weight vector has {} entries but the session's model size is m = {} \
             (PSR weights are indexed by global model index even on a union session)",
            weights.len(),
            self.session.params.m
        );
        let w = Arc::new(weights);
        self.weights_len = Some(w.len());
        for party in 0..2 {
            self.command(party, ServerCmd::SetWeights(w.clone()))?;
        }
        self.ack_both()
    }

    /// Replace the shared session on both living servers (a new round's
    /// public parameters — e.g. a re-seeded cuckoo table). Resets any
    /// retained U-DPF state, whose keys were built against the old table;
    /// an installed weight vector survives only if the new session keeps
    /// the same model size `m` (re-install it otherwise).
    pub fn set_session(&mut self, session: Session) -> Result<()> {
        self.install_session(Arc::new(session))
    }

    /// One PSR round: each of `clients` (a selection list per client)
    /// privately retrieves its submodel from the installed weight vector.
    pub fn psr(&mut self, clients: &[Vec<u64>], rng: &mut Rng) -> Result<PsrOutcome<G>> {
        let n = self.round_size(clients.len())?;
        ensure!(
            self.weights_len.is_some(),
            "no weight vector installed: call FslRuntime::set_weights before psr"
        );
        self.reset_meters();
        let wall = Instant::now();

        let t_gen = Instant::now();
        let mut ctxs = Vec::with_capacity(n);
        let mut batches = Vec::with_capacity(n);
        for (i, sel) in clients.iter().enumerate() {
            let s = self.trace.begin();
            let (ctx, batch) =
                psr::client_query::<G>(&self.session, sel, rng).map_err(|e| anyhow!("{e}"))?;
            self.trace.end(s, Phase::Keygen, Party::Client, trace::worker(i));
            ctxs.push(ctx);
            batches.push(batch);
        }
        let gen_time = t_gen.elapsed();

        self.command_both(ServerCmd::Psr {
            n,
            deadline_nanos: self.deadline_nanos(),
        })?;
        // From here on the servers are mid-round: any failure may leave
        // the reply/data streams desynchronised, so errors poison.
        let timeout = self.reply_timeout;
        let num_bins = self.session.simple.num_bins();
        if self.tolerant() {
            // Best-effort uploads, skipping evicted clients; a faulted
            // send is the client's own failure, not the round's.
            let up = self.trace.begin();
            for (i, (links, batch)) in self.links.iter().zip(&batches).enumerate() {
                if self.dead[i] {
                    continue;
                }
                let _ = links.to_s0.send(msg::encode_key_upload(batch, 0, true));
                let _ = links.to_s1.send(msg::encode_key_upload(batch, 1, true));
            }
            self.trace.end(up, Phase::Upload, Party::Client, None);
            // Learn the agreed cohort *before* reading answers: the
            // servers answer only agreed survivors, so waiting on a
            // dropped client's answer would wedge until the timeout.
            let (server_time, _, inter, outcomes, server_spans) = self.round_replies(n)?;
            let mg = self.trace.begin();
            let exchanged: Result<Vec<Vec<G>>> = (|| {
                let mut submodels = Vec::with_capacity(n);
                for i in 0..n {
                    if outcomes[i] != ClientOutcome::Completed {
                        submodels.push(Vec::new());
                        continue;
                    }
                    let links = &self.links[i];
                    let a0 = msg::decode_shares::<G>(&links.to_s0.recv_timeout(timeout)?)
                        .ok_or_else(|| anyhow!("bad S0 answer"))?;
                    let a1 = msg::decode_shares::<G>(&links.to_s1.recv_timeout(timeout)?)
                        .ok_or_else(|| anyhow!("bad S1 answer"))?;
                    submodels.push(psr::client_reconstruct(
                        &ctxs[i], num_bins, &clients[i], &a0, &a1,
                    ));
                }
                Ok(submodels)
            })();
            self.trace.end(mg, Phase::Merge, Party::Client, None);
            let submodels = self.poisoning(exchanged)?;
            self.absorb_outcomes(&outcomes);
            let report = self.report(
                RoundKind::Psr, n, gen_time, server_time, wall.elapsed(), inter, outcomes,
                server_spans,
            );
            return Ok(PsrOutcome { submodels, report });
        }
        let up = self.trace.begin();
        let sent: Result<()> = (|| {
            // PSR sends full key material to both servers (no forwarding —
            // the answer flows back on the same link).
            for (links, batch) in self.links.iter().zip(&batches) {
                links.to_s0.send(msg::encode_key_upload(batch, 0, true))?;
                links.to_s1.send(msg::encode_key_upload(batch, 1, true))?;
            }
            Ok(())
        })();
        self.trace.end(up, Phase::Upload, Party::Client, None);
        self.poisoning(sent)?;
        let mg = self.trace.begin();
        let exchanged: Result<Vec<Vec<G>>> = (|| {
            // Clients reconstruct from both servers' answers.
            let mut submodels = Vec::with_capacity(n);
            for ((links, ctx), sel) in self.links.iter().zip(&ctxs).zip(clients) {
                let a0 = msg::decode_shares::<G>(&links.to_s0.recv_timeout(timeout)?)
                    .ok_or_else(|| anyhow!("bad S0 answer"))?;
                let a1 = msg::decode_shares::<G>(&links.to_s1.recv_timeout(timeout)?)
                    .ok_or_else(|| anyhow!("bad S1 answer"))?;
                submodels.push(psr::client_reconstruct(ctx, num_bins, sel, &a0, &a1));
            }
            Ok(submodels)
        })();
        self.trace.end(mg, Phase::Merge, Party::Client, None);
        let submodels = self.poisoning(exchanged)?;
        let (server_time, _, inter, outcomes, server_spans) = self.round_replies(n)?;
        let report = self.report(
            RoundKind::Psr, n, gen_time, server_time, wall.elapsed(), inter, outcomes,
            server_spans,
        );
        Ok(PsrOutcome { submodels, report })
    }

    /// One SSA round: `clients[i] = (selections, deltas)`. In
    /// [`KeyMode::Fresh`] every round generates and ships fresh DPF keys;
    /// in [`KeyMode::Udpf`] the first round ships retained U-DPF key sets
    /// and every later round ships only per-bin hints (same clients and
    /// selections each round — the fixed-submodel scenario).
    pub fn ssa(&mut self, clients: &[(Vec<u64>, Vec<G>)], rng: &mut Rng) -> Result<SsaOutcome<G>> {
        match self.key_mode {
            KeyMode::Fresh => self.ssa_fresh(clients, rng),
            KeyMode::Udpf => self.ssa_udpf(clients, rng),
        }
    }

    fn ssa_fresh(
        &mut self,
        clients: &[(Vec<u64>, Vec<G>)],
        rng: &mut Rng,
    ) -> Result<SsaOutcome<G>> {
        let n = self.round_size(clients.len())?;
        self.reset_meters();
        let wall = Instant::now();

        let t_gen = Instant::now();
        let mut uploads = Vec::with_capacity(n);
        for (i, (sel, deltas)) in clients.iter().enumerate() {
            let s = self.trace.begin();
            uploads
                .push(ssa::client_update(&self.session, sel, deltas, rng)
                    .map_err(|e| anyhow!("{e}"))?);
            self.trace.end(s, Phase::Keygen, Party::Client, trace::worker(i));
        }
        let gen_time = t_gen.elapsed();

        self.command_both(ServerCmd::Ssa {
            n,
            deadline_nanos: self.deadline_nanos(),
        })?;
        // Long upload (master seed + publics) to the leader; short upload
        // (master seed only) to the worker — §4's efficiency trick, with
        // the publics forwarded S_0 → S_1 server-side. All the short
        // uploads go first: S_1 must never be left waiting on one while
        // S_0's forwarded publics fill the peer pipe — over real sockets
        // with finite kernel buffers the interleaved order can deadlock
        // at large m (driver → S_0 → inter → S_1 → driver cycle).
        let up = self.trace.begin();
        if self.tolerant() {
            for (i, (links, batch)) in self.links.iter().zip(&uploads).enumerate() {
                if self.dead[i] {
                    continue;
                }
                let _ = links.to_s1.send(msg::encode_key_upload(batch, 1, false));
            }
            for (i, (links, batch)) in self.links.iter().zip(&uploads).enumerate() {
                if self.dead[i] {
                    continue;
                }
                let _ = links.to_s0.send(msg::encode_key_upload(batch, 0, true));
            }
            self.trace.end(up, Phase::Upload, Party::Client, None);
        } else {
            let sent: Result<()> = (|| {
                for (links, batch) in self.links.iter().zip(&uploads) {
                    links.to_s1.send(msg::encode_key_upload(batch, 1, false))?;
                }
                for (links, batch) in self.links.iter().zip(&uploads) {
                    links.to_s0.send(msg::encode_key_upload(batch, 0, true))?;
                }
                Ok(())
            })();
            self.trace.end(up, Phase::Upload, Party::Client, None);
            self.poisoning(sent)?;
        }
        self.finish_ssa(RoundKind::Ssa, n, gen_time, wall)
    }

    fn ssa_udpf(
        &mut self,
        clients: &[(Vec<u64>, Vec<G>)],
        rng: &mut Rng,
    ) -> Result<SsaOutcome<G>> {
        let n = self.round_size(clients.len())?;
        let epoch = self.udpf_epoch;
        if epoch > 0 {
            ensure!(
                n == self.udpf_clients.len(),
                "U-DPF rounds must keep the client set fixed: epoch 0 had {} clients, \
                 this round brings {n} (rebuild the runtime or use KeyMode::Fresh)",
                self.udpf_clients.len()
            );
        }
        self.reset_meters();
        let wall = Instant::now();
        let t_gen = Instant::now();

        if epoch == 0 {
            // Setup round: full U-DPF key sets, retained by both servers.
            let mut keys0 = Vec::with_capacity(n);
            let mut keys1 = Vec::with_capacity(n);
            self.udpf_clients.clear();
            for (i, (sel, deltas)) in clients.iter().enumerate() {
                let s = self.trace.begin();
                let (state, k0, k1) = udpf_ssa::client_setup(&self.session, sel, deltas, rng)
                    .map_err(|e| anyhow!("{e}"))?;
                self.trace.end(s, Phase::Keygen, Party::Client, trace::worker(i));
                self.udpf_clients.push(state);
                keys0.push(k0);
                keys1.push(k1);
            }
            self.udpf_selections = clients.iter().map(|(sel, _)| distinct_sorted(sel)).collect();
            let gen_time = t_gen.elapsed();
            self.command_both(ServerCmd::UdpfSetup {
                n,
                deadline_nanos: self.deadline_nanos(),
            })?;
            let up = self.trace.begin();
            if self.tolerant() {
                for (i, ((links, k0), k1)) in
                    self.links.iter().zip(&keys0).zip(&keys1).enumerate()
                {
                    if self.dead[i] {
                        continue;
                    }
                    let _ = links.to_s0.send(msg::encode_udpf_keys(&k0.keys));
                    let _ = links.to_s1.send(msg::encode_udpf_keys(&k1.keys));
                }
                self.trace.end(up, Phase::Upload, Party::Client, None);
            } else {
                let sent: Result<()> = (|| {
                    for ((links, k0), k1) in self.links.iter().zip(&keys0).zip(&keys1) {
                        links.to_s0.send(msg::encode_udpf_keys(&k0.keys))?;
                        links.to_s1.send(msg::encode_udpf_keys(&k1.keys))?;
                    }
                    Ok(())
                })();
                self.trace.end(up, Phase::Upload, Party::Client, None);
                self.poisoning(sent)?;
            }
            // Advance only once the round succeeded: a failed setup (or a
            // crashed server) leaves the epoch untouched, so a recovered
            // deployment retries the *same* epoch.
            let out = self.finish_ssa(RoundKind::Ssa, n, gen_time, wall)?;
            self.udpf_epoch = 1;
            Ok(out)
        } else {
            // Hint round: one ⌈log 𝔾⌉-bit CW per bin/stash slot. The
            // retained keys fix each client's cuckoo placement, so the
            // selection sets must match epoch 0 exactly (evicted clients
            // are exempt — they no longer participate).
            for (i, ((sel, _), fixed)) in clients.iter().zip(&self.udpf_selections).enumerate() {
                if *self.dead.get(i).unwrap_or(&false) {
                    continue;
                }
                ensure!(
                    distinct_sorted(sel) == *fixed,
                    "U-DPF rounds keep selections fixed: client {i}'s selection set changed \
                     since epoch 0 (rebuild the runtime or use KeyMode::Fresh)"
                );
            }
            let mut all_hints = Vec::with_capacity(n);
            for (i, (state, (sel, deltas))) in self.udpf_clients.iter().zip(clients).enumerate() {
                let s = self.trace.begin();
                all_hints.push(state.epoch_hints(&self.session, sel, deltas, epoch));
                self.trace.end(s, Phase::Keygen, Party::Client, trace::worker(i));
            }
            let gen_time = t_gen.elapsed();
            self.command_both(ServerCmd::UdpfEpoch {
                n,
                epoch,
                deadline_nanos: self.deadline_nanos(),
            })?;
            let up = self.trace.begin();
            if self.tolerant() {
                for (i, (links, hints)) in self.links.iter().zip(&all_hints).enumerate() {
                    if self.dead[i] {
                        continue;
                    }
                    let encoded = msg::encode_hints(hints);
                    let _ = links.to_s0.send(encoded.clone());
                    let _ = links.to_s1.send(encoded);
                }
                self.trace.end(up, Phase::Upload, Party::Client, None);
            } else {
                let sent: Result<()> = (|| {
                    for (links, hints) in self.links.iter().zip(&all_hints) {
                        let encoded = msg::encode_hints(hints);
                        links.to_s0.send(encoded.clone())?;
                        links.to_s1.send(encoded)?;
                    }
                    Ok(())
                })();
                self.trace.end(up, Phase::Upload, Party::Client, None);
                self.poisoning(sent)?;
            }
            let out = self.finish_ssa(RoundKind::Ssa, n, gen_time, wall)?;
            self.udpf_epoch = epoch + 1;
            Ok(out)
        }
    }

    /// One malicious-model SSA round (§2.2/§3.1): `S_0` sketches every
    /// client's bins (the cross-server multiplication is the idealised
    /// [`crate::sketch::SecureMul`], as in the paper's evaluation) and
    /// aggregates only the accepted clients. Uploads are raw key batches
    /// so adversarial (malformed) clients can be injected directly.
    pub fn verified_ssa(
        &mut self,
        uploads: Vec<MasterKeyBatch<Fp>>,
        server_shared_seed: u64,
    ) -> Result<VerifiedSsaOutcome> {
        self.check_healthy()?;
        let n = uploads.len();
        self.reset_meters();
        let wall = Instant::now();
        self.command(
            0,
            ServerCmd::VerifiedSsa {
                uploads: Arc::new(uploads),
                seed: server_shared_seed,
            },
        )?;
        match self.reply(0) {
            Ok(ServerReply::Verified {
                result,
                server_time,
            }) => {
                let wall_time = wall.elapsed();
                // Verified rounds run wholly on the leader: no S_0 ↔ S_1
                // traffic either locally or remotely.
                let report = self.report(
                    RoundKind::VerifiedSsa,
                    n,
                    Duration::ZERO,
                    server_time,
                    wall_time,
                    0,
                    vec![ClientOutcome::Completed; n],
                    Vec::new(),
                );
                Ok(VerifiedSsaOutcome {
                    delta: result.delta,
                    rejected: result.rejected,
                    report,
                })
            }
            Ok(other) => {
                let e = other.into_protocol_error("verified SSA");
                self.poison(&e);
                Err(e)
            }
            Err(e) => {
                self.poison(&e);
                Err(e)
            }
        }
    }

    /// One PSU round (§6 Table 2 row 2): clients blind + pad their
    /// selection sets, `S_0` shuffles the pooled multiset, `S_1`
    /// deduplicates and broadcasts the blinded union, clients unblind —
    /// then the union-domain session is built and installed on both
    /// living servers, so every later round's Θ (and key sizes) shrink.
    /// `key` is the clients' shared blinding key the servers never see.
    pub fn psu_align(
        &mut self,
        key: &[u8; 16],
        client_sets: &[Vec<u64>],
        rng: &mut Rng,
    ) -> Result<PsuOutcome> {
        let n = self.round_size(client_sets.len())?;
        ensure!(n >= 1, "PSU alignment needs at least one client set");
        let (m, k) = (self.session.params.m, self.session.params.k);
        for (cid, set) in client_sets.iter().enumerate() {
            ensure!(
                set.len() <= k,
                "client {cid} brings {} selections but the session pads PSU sets to k = {k}",
                set.len()
            );
        }
        self.reset_meters();
        let wall = Instant::now();

        let t_gen = Instant::now();
        for (cid, (links, set)) in self.links.iter().zip(client_sets).enumerate() {
            let s = self.trace.begin();
            let blinded = psu::client_blind(key, m, k, cid as u64, set);
            links.to_s0.send(msg::encode_indices(&blinded))?;
            self.trace.end(s, Phase::Keygen, Party::Client, trace::worker(cid));
        }
        let gen_time = t_gen.elapsed();

        let shuffle_seed = rng.next_u64();
        self.command_both(ServerCmd::PsuAlign { n, shuffle_seed })?;

        // S_1 broadcasts the blinded union to every client; all unblind
        // to the same set, so only the first broadcast is unblinded (the
        // rest are drained for the metering). Post-command failures
        // poison: the broadcast stream may be half-consumed.
        let timeout = self.reply_timeout;
        let exchanged: Result<Vec<u64>> = (|| {
            let mut union: Option<Vec<u64>> = None;
            for links in &self.links[..n] {
                let blinded_union = msg::decode_indices(&links.to_s1.recv_timeout(timeout)?)
                    .ok_or_else(|| anyhow!("bad union broadcast"))?;
                if union.is_none() {
                    union = Some(psu::client_unblind(key, m, k, &blinded_union));
                }
            }
            union.ok_or_else(|| anyhow!("PSU round served no clients"))
        })();
        let union = self.poisoning(exchanged)?;
        let (server_time, _, inter, outcomes, server_spans) = self.round_replies(n)?;
        let union_len = union.len();
        let session = Session::new_union(self.session.params.clone(), union)?;
        self.install_session(Arc::new(session))?;
        let report = self.report(
            RoundKind::PsuAlign, n, gen_time, server_time, wall.elapsed(), inter, outcomes,
            server_spans,
        );
        Ok(PsuOutcome { union_len, report })
    }

    /// Shut both servers down (joining local threads; telling remote
    /// processes to exit). Dropping the runtime does the same; this form
    /// surfaces a panicked local server as an error instead of
    /// swallowing it.
    pub fn shutdown(mut self) -> Result<()> {
        let mut panicked = false;
        for link in &mut self.server_links {
            panicked |= link.shutdown();
        }
        ensure!(!panicked, "a server thread panicked during shutdown");
        Ok(())
    }

    // ---- internals -----------------------------------------------------

    /// Validate a round's client count against capacity (an empty round
    /// is legal and yields an empty/zero result, as the one-shot
    /// functions always did).
    fn round_size(&self, n: usize) -> Result<usize> {
        self.check_healthy()?;
        ensure!(
            n <= self.links.len(),
            "round brings {n} clients but the runtime was built for max_clients = {} \
             (raise FslRuntimeBuilder::max_clients)",
            self.links.len()
        );
        Ok(n)
    }

    /// Refuse to serve once a reply failure may have desynchronised the
    /// command/reply streams.
    fn check_healthy(&self) -> Result<()> {
        match &self.poisoned {
            Some(cause) => Err(anyhow!(
                "runtime poisoned by an earlier server failure ({cause}); \
                 build a fresh FslRuntime"
            )),
            None => Ok(()),
        }
    }

    /// Record the first reply-level failure.
    fn poison(&mut self, cause: &anyhow::Error) {
        self.poisoned.get_or_insert_with(|| cause.to_string());
    }

    /// Shared tail of every SSA variant: collect both replies, take the
    /// leader's delta, assemble the report.
    fn finish_ssa(
        &mut self,
        kind: RoundKind,
        n: usize,
        gen_time: Duration,
        wall: Instant,
    ) -> Result<SsaOutcome<G>> {
        let (server_time, delta, inter, outcomes, server_spans) = self.round_replies(n)?;
        let delta = self.poisoning(delta.ok_or_else(|| anyhow!("leader sent no delta")))?;
        self.absorb_outcomes(&outcomes);
        let report = self.report(
            kind, n, gen_time, server_time, wall.elapsed(), inter, outcomes, server_spans,
        );
        Ok(SsaOutcome { delta, report })
    }

    /// Whether rounds run in dropout-tolerant mode.
    fn tolerant(&self) -> bool {
        self.upload_deadline.is_some()
    }

    /// The wire form of the upload deadline (`0` = strict).
    fn deadline_nanos(&self) -> u64 {
        self.upload_deadline.map(|d| d.as_nanos() as u64).unwrap_or(0)
    }

    /// Evict every non-completed client: its link may hold late bytes
    /// that must never be read as a later round's upload. Mirrors the
    /// servers' own eviction, keeping all three parties consistent.
    fn absorb_outcomes(&mut self, outcomes: &[ClientOutcome]) {
        for (i, o) in outcomes.iter().enumerate() {
            if *o != ClientOutcome::Completed {
                if let Some(d) = self.dead.get_mut(i) {
                    *d = true;
                }
            }
        }
    }

    /// Pass a mid-round result through, poisoning the runtime on failure:
    /// once the servers have been commanded, an aborted round can leave
    /// the data/reply streams half-consumed.
    fn poisoning<T>(&mut self, res: Result<T>) -> Result<T> {
        match res {
            Ok(v) => Ok(v),
            Err(e) => {
                self.poison(&e);
                Err(e)
            }
        }
    }

    fn command(&self, party: usize, cmd: ServerCmd<G>) -> Result<()> {
        self.server_links[party].command(party, cmd)
    }

    fn command_both(&self, cmd: ServerCmd<G>) -> Result<()> {
        self.command(0, cmd.clone())?;
        self.command(1, cmd)
    }

    fn reply(&self, party: usize) -> Result<ServerReply<G>> {
        self.server_links[party].reply(party, self.reply_timeout)
    }

    /// Await a single Ack (connect-time sequencing, before any round has
    /// run — a failure is a hard error, with nothing to poison yet).
    fn expect_ack(&self, party: usize, what: &str) -> Result<()> {
        match self.reply(party)? {
            ServerReply::Ack => Ok(()),
            other => Err(other.into_protocol_error(what)),
        }
    }

    fn ack_both(&mut self) -> Result<()> {
        let mut failure: Option<anyhow::Error> = None;
        // Drain BOTH replies even when the first fails: a half-read reply
        // stream would silently shift every later round out of phase.
        for party in 0..2 {
            match self.reply(party) {
                Ok(ServerReply::Ack) => {}
                Ok(other) => {
                    failure.get_or_insert(other.into_protocol_error("install"));
                }
                Err(e) => {
                    failure.get_or_insert(e);
                }
            }
        }
        match failure {
            Some(e) => {
                self.poison(&e);
                Err(e)
            }
            None => Ok(()),
        }
    }

    /// Collect one round reply per server (draining both even on
    /// failure): max server time, the leader's optional delta, the
    /// servers' summed `S_0 ↔ S_1` bytes (remote deployments only —
    /// in-process replies carry 0 and the driver reads its own meters),
    /// the merged per-client outcomes (filled to all-`Completed` for
    /// strict rounds, whose replies carry none), and both servers'
    /// party-tagged phase spans.
    fn round_replies(
        &mut self,
        n: usize,
    ) -> Result<(Duration, Option<Vec<G>>, u64, Vec<ClientOutcome>, Vec<Span>)> {
        let rp = self.trace.begin();
        let mut max_time = Duration::ZERO;
        let mut delta = None;
        let mut inter = 0u64;
        let mut per_party: [Vec<ClientOutcome>; 2] = [Vec::new(), Vec::new()];
        let mut server_spans = Vec::new();
        let mut failure: Option<anyhow::Error> = None;
        for party in 0..2 {
            match self.reply(party) {
                Ok(ServerReply::Round { server_time, delta: d, inter_sent, outcomes, spans }) => {
                    max_time = max_time.max(server_time);
                    delta = delta.or(d);
                    inter += inter_sent;
                    per_party[party] = outcomes;
                    server_spans.extend(spans);
                }
                Ok(other) => {
                    failure.get_or_insert(other.into_protocol_error("round"));
                }
                Err(e) => {
                    failure.get_or_insert(e);
                }
            }
        }
        self.trace.end(rp, Phase::Reply, Party::Client, None);
        match failure {
            Some(e) => {
                self.poison(&e);
                Err(e)
            }
            None => {
                let [o0, o1] = per_party;
                Ok((max_time, delta, inter, merge_outcomes(n, &o0, &o1), server_spans))
            }
        }
    }

    fn install_session(&mut self, session: Arc<Session>) -> Result<()> {
        self.check_healthy()?;
        for party in 0..2 {
            self.command(party, ServerCmd::SetSession(session.clone()))?;
        }
        self.ack_both()?;
        // The weight vector is indexed by global model index: it stays
        // valid across a domain change (PSU union) but not across a model
        // resize — the servers drop it in that case, and so do we.
        if self.weights_len.is_some_and(|len| len != session.params.m as usize) {
            self.weights_len = None;
        }
        self.session = session;
        // Retained U-DPF keys were built against the old table.
        self.udpf_clients.clear();
        self.udpf_selections.clear();
        self.udpf_epoch = 0;
        Ok(())
    }

    /// Zero every link meter (and the driver's span ring) so the next
    /// report covers one round.
    fn reset_meters(&self) {
        for links in &self.links {
            links.to_s0.meter().reset();
            links.to_s1.meter().reset();
        }
        for meter in &self.inter_meters {
            meter.reset();
        }
        self.trace.reset();
    }

    #[allow(clippy::too_many_arguments)]
    fn report(
        &self,
        kind: RoundKind,
        n: usize,
        gen_time: Duration,
        server_time: Duration,
        wall_time: Duration,
        reply_inter_bytes: u64,
        outcomes: Vec<ClientOutcome>,
        server_spans: Vec<Span>,
    ) -> RoundReport {
        // Verified rounds take uploads directly (no client links), so `n`
        // may exceed the topology's capacity — clamp the meter slice.
        let links = &self.links[..n.min(self.links.len())];
        // Driver spans (client party) first, then the servers' — the
        // Chrome export keys lanes off each span's own party tag, so
        // concatenation order only affects readers of the raw list.
        let mut spans = self.trace.drain();
        spans.extend(server_spans);
        // `drain` preserves the drop counter (only `reset` zeroes it),
        // so this reads the whole round's overflow.
        let spans_dropped = self.trace.dropped();
        RoundReport {
            kind,
            clients: n,
            client_upload_bytes: links
                .iter()
                .map(|l| l.to_s0.meter().sent() + l.to_s1.meter().sent())
                .sum(),
            client_download_bytes: links
                .iter()
                .map(|l| l.to_s0.meter().recv() + l.to_s1.meter().recv())
                .sum(),
            // In-process: read the driver-owned inter-link meters.
            // Remote: the link lives between the two server processes, so
            // its per-round bytes come back in the round replies.
            server_exchange_bytes: if self.inter_meters.is_empty() {
                reply_inter_bytes
            } else {
                self.inter_meters.iter().map(|m| m.sent()).sum()
            },
            gen_time,
            server_time,
            wall_time,
            outcomes,
            spans,
            spans_dropped,
        }
    }

    /// Extract the driver-side U-DPF continuity state — client hint
    /// states, the fixed selection sets, the next epoch number, and the
    /// eviction record — so a *new* runtime (typically one reconnected to
    /// servers restarted from snapshots) can resume the session where
    /// this one stopped. Works on a poisoned runtime: that is exactly the
    /// recovery case. The state is consumed from this runtime.
    pub fn export_udpf_state(&mut self) -> UdpfDriverState<G> {
        UdpfDriverState {
            clients: std::mem::take(&mut self.udpf_clients),
            selections: std::mem::take(&mut self.udpf_selections),
            epoch: self.udpf_epoch,
            dead: self.dead.clone(),
        }
    }

    /// Adopt a previously exported U-DPF driver state into this (fresh)
    /// runtime. The servers it is connected to must hold the matching
    /// retained key sets — restarted `fsl serve` processes restore them
    /// from their snapshots. The next [`FslRuntime::ssa`] call then runs
    /// the epoch the interrupted session was about to run (or retries the
    /// one it failed).
    pub fn resume_udpf(&mut self, state: UdpfDriverState<G>) -> Result<()> {
        self.check_healthy()?;
        ensure!(
            self.key_mode == KeyMode::Udpf,
            "resume_udpf needs KeyMode::Udpf (this runtime re-keys every round)"
        );
        ensure!(
            self.udpf_epoch == 0 && self.udpf_clients.is_empty(),
            "resume_udpf only applies to a fresh runtime (this one already ran U-DPF rounds)"
        );
        ensure!(
            state.clients.len() <= self.links.len(),
            "exported state spans {} clients but this runtime was built for max_clients = {}",
            state.clients.len(),
            self.links.len()
        );
        for (i, d) in state.dead.iter().enumerate() {
            if let Some(slot) = self.dead.get_mut(i) {
                *slot |= *d;
            }
        }
        self.udpf_clients = state.clients;
        self.udpf_selections = state.selections;
        self.udpf_epoch = state.epoch;
        Ok(())
    }
}

/// Driver-side U-DPF continuity state, moved between runtimes by
/// [`FslRuntime::export_udpf_state`] / [`FslRuntime::resume_udpf`]
/// across a server crash + snapshot restore.
pub struct UdpfDriverState<G: Group> {
    clients: Vec<udpf_ssa::UdpfSsaClient<G>>,
    selections: Vec<Vec<u64>>,
    epoch: u64,
    dead: Vec<bool>,
}

impl<G: Group> UdpfDriverState<G> {
    /// The epoch the resumed session will run next.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Clients the state spans (the fixed U-DPF cohort).
    pub fn clients(&self) -> usize {
        self.clients.len()
    }
}

/// Merge the two servers' per-client outcome vectors: a client completed
/// only if both servers agreed it did; an explicit `Dropped` from either
/// side wins over `StragglerCut`. Strict rounds reply with empty vectors,
/// which merge to all-`Completed`.
pub(crate) fn merge_outcomes(
    n: usize,
    o0: &[ClientOutcome],
    o1: &[ClientOutcome],
) -> Vec<ClientOutcome> {
    let get = |v: &[ClientOutcome], i: usize| {
        v.get(i).copied().unwrap_or(ClientOutcome::Completed)
    };
    (0..n)
        .map(|i| match (get(o0, i), get(o1, i)) {
            (ClientOutcome::Completed, ClientOutcome::Completed) => ClientOutcome::Completed,
            (a, b) if a == ClientOutcome::Dropped || b == ClientOutcome::Dropped => {
                ClientOutcome::Dropped
            }
            _ => ClientOutcome::StragglerCut,
        })
        .collect()
}

impl<G: Group> Drop for FslRuntime<G> {
    fn drop(&mut self) {
        for link in &mut self.server_links {
            let _ = link.shutdown();
        }
    }
}

/// A selection list reduced to its distinct sorted set (the identity SSA
/// aggregates under — duplicate selections sum their deltas).
fn distinct_sorted(sel: &[u64]) -> Vec<u64> {
    let mut s = sel.to_vec();
    s.sort_unstable();
    s.dedup();
    s
}

/// Pre-registered handles for one server half's operational counters —
/// round lifecycle, per-client fates, the mux leader's held-upload
/// window, and trace-ring overflow. Registered once at server
/// construction so round hot paths only touch atomics, never the
/// registry lock.
pub(crate) struct ServerMetrics {
    pub(crate) rounds_started: Counter,
    pub(crate) rounds_completed: Counter,
    pub(crate) rounds_failed: Counter,
    pub(crate) clients_completed: Counter,
    pub(crate) clients_dropped: Counter,
    pub(crate) clients_straggler_cut: Counter,
    /// High-water mark of leader-held upload bytes awaiting `HAVE`
    /// (mux SSA only; stays 0 on direct-link deployments and on `S_1`).
    pub(crate) held_window_bytes: Gauge,
    /// Spans this server's recorder discarded on ring overflow.
    pub(crate) spans_dropped: Gauge,
}

impl ServerMetrics {
    pub(crate) fn register(reg: &MetricsRegistry) -> Self {
        let outcome = |val| {
            reg.counter_with(
                "fsl_client_outcomes_total",
                &[("outcome", val)],
                "Per-client round fates, by outcome",
            )
        };
        ServerMetrics {
            rounds_started: reg.counter(
                "fsl_rounds_started_total",
                "Round commands dispatched to this server",
            ),
            rounds_completed: reg.counter(
                "fsl_rounds_completed_total",
                "Round commands that replied successfully",
            ),
            rounds_failed: reg.counter(
                "fsl_rounds_failed_total",
                "Round commands that replied Failed",
            ),
            clients_completed: outcome("completed"),
            clients_dropped: outcome("dropped"),
            clients_straggler_cut: outcome("straggler_cut"),
            held_window_bytes: reg.gauge(
                "fsl_mux_held_window_bytes",
                "High-water mark of leader-held upload bytes awaiting peer HAVE",
            ),
            spans_dropped: reg.gauge(
                "fsl_trace_spans_dropped_count",
                "Spans discarded by this server's trace ring on overflow",
            ),
        }
    }

    /// Bump the per-outcome counters for one round's client fates.
    pub(crate) fn observe_outcomes(&self, outcomes: &[ClientOutcome]) {
        for o in outcomes {
            match o {
                ClientOutcome::Completed => self.clients_completed.inc(),
                ClientOutcome::Dropped => self.clients_dropped.inc(),
                ClientOutcome::StragglerCut => self.clients_straggler_cut.inc(),
            }
        }
    }
}

/// One server's state: its engines, data links, and retained
/// round-spanning state (weights, U-DPF keys, session). Transport-
/// agnostic: the in-process runtime spawns it on a thread over simulated
/// links ([`FslRuntimeBuilder::build`]); a standalone TCP server
/// ([`super::serve`]) builds one over accepted socket links and drives
/// [`ServerHalf::handle`] from its remote command loop.
pub(crate) struct ServerHalf<G: Group> {
    pub(crate) party: u8,
    pub(crate) session: Arc<Session>,
    pub(crate) agg: AggregationEngine,
    pub(crate) ret: RetrievalEngine,
    /// Per-client data links (this server's side of every client link).
    pub(crate) eps: Vec<BoxTransport>,
    /// The `S_0 ↔ S_1` exchange link. Always `Some` in-process; a
    /// standalone `S_1` starts without one until the driver's `DialPeer`.
    pub(crate) inter: Option<BoxTransport>,
    /// Multiplexed client lanes (a scale deployment accepted by
    /// [`super::serve`]). When set, `eps` is empty and SSA rounds ingest
    /// `[vid || upload]` frames from the lanes through a [`FramePump`]
    /// instead of one blocking receive per client link.
    pub(crate) mux: Option<MuxCohort>,
    /// Installed PSR database (global-model-indexed).
    pub(crate) weights: Option<Arc<Vec<G>>>,
    /// Retained U-DPF key sets, one per *surviving* client (U-DPF mode).
    pub(crate) udpf: Vec<udpf_ssa::UdpfSsaServerKeys<G>>,
    /// Link index of each retained key set (tolerant rounds shrink
    /// `udpf` as clients drop; this keeps slots addressable).
    pub(crate) udpf_links: Vec<usize>,
    /// Client count of the U-DPF setup round (epoch commands still quote
    /// the full cohort size).
    pub(crate) udpf_total: usize,
    /// Clients evicted by an earlier tolerant round: never read from (or
    /// written to) again — their links may hold stale late bytes.
    pub(crate) dead: Vec<bool>,
    /// Bound on every data-link receive (a silent client or peer fails
    /// the round instead of wedging the server forever).
    pub(crate) timeout: Duration,
    /// This server's span ring, shared with its engines' [`TraceSink`]s.
    /// Reset at the start of every round command; drained into the
    /// `Round` reply so driver-side reports carry both servers' spans
    /// over either transport.
    pub(crate) trace: Arc<TraceRecorder>,
    /// This server's live metric registry: phase histograms (teed from
    /// `trace`), transport meters, pump gauges, round counters. Shared
    /// with the scrape path ([`ServerCmd::Stats`], `Role::Stats`), which
    /// only ever snapshots it.
    pub(crate) registry: Arc<MetricsRegistry>,
    /// Pre-registered round/outcome handles into `registry`.
    pub(crate) metrics: ServerMetrics,
}

/// One accepted multiplexed lane: a single socket carrying the uploads
/// of virtual clients `lo .. lo + count`, each as a `[vid u32 LE ||
/// upload]` frame. `stream` goes `None` when the lane dies (closed,
/// expired, or protocol-violating mid-round); its range stays recorded
/// so later rounds report those ids `Dropped` instead of waiting on
/// them.
pub(crate) struct MuxLane {
    pub(crate) stream: Option<TcpStream>,
    pub(crate) lo: u32,
    pub(crate) count: u32,
}

/// A multiplexed deployment's client side: the lanes covering the
/// cohort, the reactor's byte budget, and a raw clone of the `S_0 ↔ S_1`
/// stream (the round's pump must own the only reader of that socket).
pub(crate) struct MuxCohort {
    pub(crate) lanes: Vec<MuxLane>,
    /// The control handshake's `max_clients`: how many virtual ids the
    /// lanes address.
    pub(crate) cohort: usize,
    /// Byte budget shared by the pump's partial frames and the leader's
    /// held-upload window — the round's working-memory bound.
    pub(crate) budget: usize,
    /// Raw clone of the peer exchange stream (same socket the boxed
    /// [`ServerHalf::inter`] transport wraps). `S_0` gets it at accept
    /// time, `S_1` when `DialPeer` lands.
    pub(crate) inter_stream: Option<TcpStream>,
    /// High-water mark of leader-held upload bytes awaiting the peer's
    /// `HAVE`, across rounds — what the streaming-ingest bound tests
    /// assert against.
    pub(crate) peak_held_bytes: usize,
    /// High-water mark of the round pumps' partial-frame bytes.
    pub(crate) peak_pump_bytes: usize,
}

/// Pump tag of the `S_0 ↔ S_1` stream in a multiplexed round (lanes use
/// their index as tag, so the sentinel can never collide).
const MUX_INTER_TAG: u64 = u64::MAX;

/// `S_1 → S_0`: "this client's short upload (master seed) is in" — the
/// leader may commit the client and forward its publics.
const MUX_HAVE: u8 = 1;
/// `S_0 → S_1`: a committed client's forwarded publics (zeroed seed,
/// same two-server privacy rule as the direct path).
const MUX_FWD: u8 = 2;
/// `S_0 → S_1`: the round's committed id list; TCP ordering guarantees
/// every forward precedes it.
const MUX_DONE: u8 = 3;
/// `S_1 → S_0`: the aggregated share vector, ending the round.
const MUX_SHARES: u8 = 4;

/// Outgoing peer bytes for a multiplexed round. The round's pump owns
/// the only reader of every socket and must keep polling, so peer sends
/// must never block: frames queue here and drain with non-blocking
/// writes each loop iteration (registering the shared socket with the
/// pump put it in non-blocking mode).
struct TxQueue {
    buf: Vec<u8>,
    off: usize,
}

impl TxQueue {
    fn new() -> Self {
        TxQueue { buf: Vec::new(), off: 0 }
    }

    /// Frame `payload` and append it to the backlog.
    fn queue(&mut self, payload: &[u8]) {
        self.buf.extend_from_slice(&msg::frame(payload));
    }

    /// Bytes queued but not yet accepted by the socket.
    fn backlog(&self) -> usize {
        self.buf.len() - self.off
    }

    /// Write as much of the backlog as the socket accepts right now.
    fn flush(&mut self, stream: &mut TcpStream) -> Result<()> {
        while self.off < self.buf.len() {
            match stream.write(&self.buf[self.off..]) {
                Ok(0) => bail!("peer closed the exchange link mid-round"),
                Ok(wrote) => self.off += wrote,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => bail!("peer exchange write failed: {e}"),
            }
        }
        if self.off == self.buf.len() {
            self.buf.clear();
            self.off = 0;
        } else if self.off > (1 << 20) {
            // Reclaim the drained prefix so a long round's queue stays
            // lean even when the peer reads slowly.
            self.buf.drain(..self.off);
            self.off = 0;
        }
        Ok(())
    }
}

/// One multiplexed round's reactor state, torn down (lanes handed back,
/// peer stream restored to blocking) whether the round succeeds or not.
struct MuxRound {
    pump: FramePump,
    tx_stream: TcpStream,
    tx: TxQueue,
    lane_dead: Vec<bool>,
    lane_of: Vec<Option<usize>>,
    budget: usize,
    held_peak: usize,
}

/// Parse one `[vid u32 LE || upload]` lane frame, validating the vid
/// against the cohort and the lane that announced it. `want_publics`
/// selects the leader's long decode (publics required) over the
/// worker's short one. `None` = protocol violation; the caller kills
/// the whole lane.
fn mux_lane_frame<G: Group>(
    payload: &[u8],
    n: usize,
    lane_of: &[Option<usize>],
    li: usize,
    want_publics: bool,
) -> Option<(usize, msg::KeyUpload<G>)> {
    let vid = match payload.get(..4) {
        Some(&[a, b, c, d]) => u32::from_le_bytes([a, b, c, d]) as usize,
        _ => return None,
    };
    if vid >= n || lane_of.get(vid).copied().flatten() != Some(li) {
        return None;
    }
    let up = msg::decode_key_upload::<G>(payload.get(4..)?)?;
    if want_publics && up.publics.is_none() {
        return None;
    }
    Some((vid, up))
}

/// Parse the `vid` field of a peer `HAVE`/`FWD` frame.
fn mux_vid(bytes: Option<&[u8]>) -> Result<usize> {
    match bytes {
        Some(&[a, b, c, d]) => Ok(u32::from_le_bytes([a, b, c, d]) as usize),
        _ => bail!("malformed peer frame: truncated client id"),
    }
}

/// Pause or resume every live lane (the peer stream never pauses).
fn set_lanes_paused(pump: &mut FramePump, lane_dead: &[bool], paused: bool) {
    for (li, dead) in lane_dead.iter().enumerate() {
        if !dead {
            pump.set_paused(li as u64, paused);
        }
    }
}

impl<G: Group> ServerHalf<G> {
    /// The in-process command loop: block for a command, serve it, reply,
    /// repeat until shutdown. A failed round replies `Failed` and keeps
    /// the server alive for the next command.
    fn run(mut self, cmd_rx: Receiver<ServerCmd<G>>, rep_tx: Sender<ServerReply<G>>) {
        while let Ok(cmd) = cmd_rx.recv() {
            if matches!(cmd, ServerCmd::Shutdown) {
                break;
            }
            let reply = self
                .handle(cmd)
                .unwrap_or_else(|e| ServerReply::Failed(e.to_string()));
            if rep_tx.send(reply).is_err() {
                break; // driver gone
            }
        }
    }

    /// Serve one command — the dispatch shared by the in-process loop and
    /// the standalone TCP server's loop. `Shutdown` and `DialPeer` are
    /// loop-level concerns and never reach this in-process; a stray
    /// `DialPeer` here is a protocol error.
    pub(crate) fn handle(&mut self, cmd: ServerCmd<G>) -> Result<ServerReply<G>> {
        // A remote driver's client count arrives off the wire: bound it
        // before any round slices `self.eps[..n]` — a failed round must
        // reply `Failed`, never panic the server.
        if let Some(n) = cmd.client_count() {
            ensure!(
                n <= self.cohort_capacity(),
                "S{}: round brings {n} clients but this deployment's capacity is {}",
                self.party,
                self.cohort_capacity()
            );
        }
        let is_round = cmd.is_round();
        if is_round {
            self.metrics.rounds_started.inc();
        }
        // One span stream per command: round handlers (and the engines
        // they share the recorder with) record into a freshly reset ring,
        // and whatever they recorded rides back in the `Round` reply —
        // identically over typed channels and the TCP wire.
        self.trace.reset();
        let result = self.dispatch(cmd);
        // Gauge, not counter: `reset` above zeroed the ring's drop count,
        // so this reads exactly the last command's overflow.
        self.metrics.spans_dropped.set(self.trace.dropped());
        match result {
            Ok(mut reply) => {
                if let ServerReply::Round { spans, outcomes, .. } = &mut reply {
                    self.metrics.observe_outcomes(outcomes);
                    *spans = self.trace.drain();
                }
                if is_round {
                    self.metrics.rounds_completed.inc();
                }
                Ok(reply)
            }
            Err(e) => {
                if is_round {
                    self.metrics.rounds_failed.inc();
                }
                Err(e)
            }
        }
    }

    /// Snapshot this server's registry, rendered both ways. The one
    /// handler behind every scrape path: [`ServerCmd::Stats`] (in-process
    /// and idle TCP command loop) and the out-of-band `Role::Stats`
    /// responder a standalone server runs mid-round.
    pub(crate) fn stats_reply(&self) -> ServerReply<G> {
        let snaps = self.registry.snapshot();
        ServerReply::Stats {
            prom: expo::render_prom(&snaps),
            json: expo::render_json(&snaps),
        }
    }

    /// How many clients one round may bring: the announced multiplexed
    /// cohort, or the number of direct per-client links.
    fn cohort_capacity(&self) -> usize {
        match &self.mux {
            Some(mux) => mux.cohort,
            None => self.eps.len(),
        }
    }

    fn dispatch(&mut self, cmd: ServerCmd<G>) -> Result<ServerReply<G>> {
        // Multiplexed deployments carry uploads as `[vid || upload]` lane
        // frames, which only the SSA ingest loop understands. Every other
        // round shape still requires direct per-client links.
        if self.mux.is_some() && cmd.is_round() && !matches!(cmd, ServerCmd::Ssa { .. }) {
            bail!(
                "S{}: only SSA rounds are supported over multiplexed client \
                 lanes (dial direct per-client links for PSR/PSU/U-DPF)",
                self.party
            );
        }
        match cmd {
            ServerCmd::Shutdown => Err(anyhow!(
                "S{}: shutdown is handled by the command loop",
                self.party
            )),
            ServerCmd::DialPeer { .. } => Err(anyhow!(
                "S{}: dial-peer only applies to a standalone TCP server \
                 (the in-process runtime wires its topology directly)",
                self.party
            )),
            ServerCmd::Ping => Ok(ServerReply::Ack),
            ServerCmd::Stats => Ok(self.stats_reply()),
            ServerCmd::SetSession(s) => {
                // Weights are indexed by global model index: a session
                // with a different m invalidates them.
                if self.weights.as_ref().is_some_and(|w| w.len() != s.params.m as usize) {
                    self.weights = None;
                }
                self.session = s;
                self.udpf.clear();
                self.udpf_links.clear();
                self.udpf_total = 0;
                Ok(ServerReply::Ack)
            }
            ServerCmd::SetWeights(w) => {
                self.weights = Some(w);
                Ok(ServerReply::Ack)
            }
            ServerCmd::Ssa { n, deadline_nanos } => {
                if self.mux.is_some() {
                    self.ssa_mux(n, opt_deadline(deadline_nanos))
                } else {
                    self.ssa(n, opt_deadline(deadline_nanos))
                }
            }
            ServerCmd::Psr { n, deadline_nanos } => self.psr(n, opt_deadline(deadline_nanos)),
            ServerCmd::UdpfSetup { n, deadline_nanos } => {
                self.udpf_setup(n, opt_deadline(deadline_nanos))
            }
            ServerCmd::UdpfEpoch { n, epoch, deadline_nanos } => {
                self.udpf_epoch(n, epoch, opt_deadline(deadline_nanos))
            }
            ServerCmd::VerifiedSsa { uploads, seed } => self.verified(&uploads, seed),
            ServerCmd::PsuAlign { n, shuffle_seed } => self.psu_align(n, shuffle_seed),
        }
    }

    /// The `S_0 ↔ S_1` link, which every exchange step needs.
    fn inter(&self) -> Result<&dyn Transport> {
        self.inter
            .as_deref()
            .ok_or_else(|| anyhow!("S{}: no peer link established", self.party))
    }

    /// This server's span party tag.
    fn side(&self) -> Party {
        Party::server(usize::from(self.party))
    }

    /// Receive one upload per client, bounded by the per-client
    /// `deadline`, classifying each: decoded within the deadline →
    /// `Completed`; silence past the deadline → `StragglerCut`; a closed
    /// link or malformed bytes → `Dropped`. Evicted clients are skipped
    /// without waiting.
    fn recv_cohort<T>(
        &mut self,
        n: usize,
        deadline: Duration,
        decode: impl Fn(&[u8]) -> Option<T>,
    ) -> (Vec<Option<T>>, Vec<ClientOutcome>) {
        if self.dead.len() < n {
            self.dead.resize(n, false);
        }
        let mut items = Vec::with_capacity(n);
        let mut outcomes = Vec::with_capacity(n);
        for i in 0..n {
            if self.dead[i] {
                items.push(None);
                outcomes.push(ClientOutcome::Dropped);
                continue;
            }
            let outcome = match self.eps[i].recv_timeout(deadline) {
                Ok(raw) => match decode(&raw) {
                    Some(v) => {
                        items.push(Some(v));
                        outcomes.push(ClientOutcome::Completed);
                        continue;
                    }
                    None => ClientOutcome::Dropped,
                },
                Err(e) if TransportError::is_timeout(&e) => ClientOutcome::StragglerCut,
                Err(_) => ClientOutcome::Dropped,
            };
            items.push(None);
            outcomes.push(outcome);
        }
        (items, outcomes)
    }

    /// Agree the surviving cohort with the peer: both servers exchange
    /// their locally-completed index lists over the `S_0 ↔ S_1` link and
    /// intersect them. A client either server missed is demoted to
    /// `Dropped`; every non-completed client is evicted for good (a
    /// straggler's late bytes must never desync its link). Returns the
    /// agreed indices, identical on both servers.
    fn agree_cohort(&mut self, outcomes: &mut [ClientOutcome]) -> Result<Vec<usize>> {
        let mine: Vec<u64> = outcomes
            .iter()
            .enumerate()
            .filter(|(_, o)| **o == ClientOutcome::Completed)
            .map(|(i, _)| i as u64)
            .collect();
        let theirs = {
            let inter = self.inter()?;
            inter.send(msg::encode_indices(&mine))?;
            msg::decode_indices(&inter.recv_timeout(self.timeout)?)
                .ok_or_else(|| anyhow!("S{}: bad survivor list from peer", self.party))?
        };
        let mut agreed = Vec::new();
        for (i, o) in outcomes.iter_mut().enumerate() {
            if *o == ClientOutcome::Completed && !theirs.contains(&(i as u64)) {
                *o = ClientOutcome::Dropped;
            }
            if *o == ClientOutcome::Completed {
                agreed.push(i);
            } else if let Some(d) = self.dead.get_mut(i) {
                *d = true;
            }
        }
        Ok(agreed)
    }

    /// Fresh-key SSA. `S_0` (leader) receives long uploads, forwards the
    /// publics to `S_1`, aggregates, reconstructs from `S_1`'s share
    /// vector. `S_1` (worker) receives short uploads + forwarded publics,
    /// aggregates, ships its shares. With a `deadline` the round is
    /// dropout-tolerant: both servers classify every client, agree the
    /// surviving cohort, and aggregate only the survivors.
    fn ssa(&mut self, n: usize, deadline: Option<Duration>) -> Result<ServerReply<G>> {
        if let Some(d) = deadline {
            return self.ssa_tolerant(n, d);
        }
        if self.party == 0 {
            let up_span = self.trace.begin();
            let mut batches = Vec::with_capacity(n);
            for (i, ep) in self.eps[..n].iter().enumerate() {
                let up = msg::decode_key_upload::<G>(&ep.recv_timeout(self.timeout)?)
                    .ok_or_else(|| anyhow!("S0: bad client upload"))?;
                let publics = up.publics.ok_or_else(|| anyhow!("S0: no publics"))?;
                // Forward only the *public* parts: the client's S_0 master
                // seed must never reach S_1 (two-server privacy), so the
                // forwarded envelope carries a zeroed seed, which S_1
                // discards (its seed came in the client's short upload).
                let mut batch = MasterKeyBatch::<G> {
                    msk: [Sensitive::new([0u8; 16]), Sensitive::new([0u8; 16])],
                    publics,
                };
                let mut fwd = wire_u32(i, "client index")?.to_le_bytes().to_vec();
                fwd.extend(msg::encode_key_upload(&batch, 0, true));
                self.inter()?.send(fwd)?;
                batch.msk = [Sensitive::new(up.msk), Sensitive::new(up.msk)];
                batches.push(batch);
            }
            self.trace.end(up_span, Phase::Upload, self.side(), None);
            let kg = self.trace.begin();
            let ups = uploads_of(&batches, 0);
            self.trace.end(kg, Phase::Keygen, self.side(), None);
            let t = Instant::now();
            let acc0 = self.agg.aggregate_publics(&self.session, 0, &ups);
            let server_time = t.elapsed();
            let mg = self.trace.begin();
            let share1 = msg::decode_shares::<G>(&self.inter()?.recv_timeout(self.timeout)?)
                .ok_or_else(|| anyhow!("S0: bad share vector"))?;
            let delta = ssa::reconstruct(&acc0, &share1);
            self.trace.end(mg, Phase::Merge, self.side(), None);
            let rp = self.trace.begin();
            self.trace.end(rp, Phase::Reply, self.side(), None);
            Ok(ServerReply::Round {
                server_time,
                delta: Some(delta),
                inter_sent: 0,
                outcomes: Vec::new(),
                spans: Vec::new(),
            })
        } else {
            let up_span = self.trace.begin();
            let mut msks = Vec::with_capacity(n);
            for ep in &self.eps[..n] {
                let up = msg::decode_key_upload::<G>(&ep.recv_timeout(self.timeout)?)
                    .ok_or_else(|| anyhow!("S1: bad client upload"))?;
                msks.push(up.msk);
            }
            // Public parts forwarded by S_0, tagged with client index.
            let mut publics: Vec<Option<_>> = (0..n).map(|_| None).collect();
            for _ in 0..n {
                let raw = self.inter()?.recv_timeout(self.timeout)?;
                let idx = match raw.get(..4) {
                    Some(&[a, b, c, d]) => u32::from_le_bytes([a, b, c, d]) as usize,
                    _ => bail!("S1: short forward"),
                };
                let slot = publics
                    .get_mut(idx)
                    .ok_or_else(|| anyhow!("S1: bad client index {idx}"))?;
                let up = msg::decode_key_upload::<G>(&raw[4..])
                    .ok_or_else(|| anyhow!("S1: bad forwarded publics"))?;
                *slot = Some(up.publics.ok_or_else(|| anyhow!("S1: no publics"))?);
            }
            self.trace.end(up_span, Phase::Upload, self.side(), None);
            let kg = self.trace.begin();
            let batches: Vec<MasterKeyBatch<G>> = publics
                .into_iter()
                .enumerate()
                .zip(&msks)
                .map(|((i, p), msk)| {
                    Ok(MasterKeyBatch {
                        msk: [Sensitive::new(*msk), Sensitive::new(*msk)],
                        publics: p.ok_or_else(|| anyhow!("S1: missing {i}"))?,
                    })
                })
                .collect::<Result<_>>()?;
            let ups = uploads_of(&batches, 1);
            self.trace.end(kg, Phase::Keygen, self.side(), None);
            let t = Instant::now();
            let acc1 = self.agg.aggregate_publics(&self.session, 1, &ups);
            let server_time = t.elapsed();
            let rp = self.trace.begin();
            self.inter()?.send(msg::encode_shares(&acc1))?;
            self.trace.end(rp, Phase::Reply, self.side(), None);
            Ok(ServerReply::Round {
                server_time,
                delta: None,
                inter_sent: 0,
                outcomes: Vec::new(),
                spans: Vec::new(),
            })
        }
    }

    /// Dropout-tolerant SSA: buffer the whole cohort's uploads (bounded
    /// per client by `deadline`), agree the survivors with the peer, then
    /// run the §4 aggregation over the survivors only. Unlike the strict
    /// path, `S_0` forwards no publics until agreement — a half-forwarded
    /// dropped client would leave the peer stream ambiguous.
    fn ssa_tolerant(&mut self, n: usize, deadline: Duration) -> Result<ServerReply<G>> {
        if self.party == 0 {
            let up_span = self.trace.begin();
            let (mut items, mut outcomes) = self.recv_cohort(n, deadline, |raw| {
                let up = msg::decode_key_upload::<G>(raw)?;
                up.publics.as_ref()?;
                Some(up)
            });
            let agreed = self.agree_cohort(&mut outcomes)?;
            self.trace.end(up_span, Phase::Upload, self.side(), None);
            let kg = self.trace.begin();
            let mut batches = Vec::with_capacity(agreed.len());
            for &i in &agreed {
                let up = items[i]
                    .take()
                    .ok_or_else(|| anyhow!("S0: agreed cohort references a missing upload"))?;
                let publics = up
                    .publics
                    .ok_or_else(|| anyhow!("S0: agreed upload lost its publics"))?;
                // Forward only the *public* parts: the client's S_0 master
                // seed must never reach S_1 (two-server privacy), so the
                // forwarded envelope carries a zeroed seed.
                let mut batch = MasterKeyBatch::<G> {
                    msk: [Sensitive::new([0u8; 16]), Sensitive::new([0u8; 16])],
                    publics,
                };
                let mut fwd = wire_u32(i, "client index")?.to_le_bytes().to_vec();
                fwd.extend(msg::encode_key_upload(&batch, 0, true));
                self.inter()?.send(fwd)?;
                batch.msk = [Sensitive::new(up.msk), Sensitive::new(up.msk)];
                batches.push(batch);
            }
            let ups = uploads_of(&batches, 0);
            self.trace.end(kg, Phase::Keygen, self.side(), None);
            let t = Instant::now();
            let acc0 = self.agg.aggregate_publics(&self.session, 0, &ups);
            let server_time = t.elapsed();
            let mg = self.trace.begin();
            let share1 = msg::decode_shares::<G>(&self.inter()?.recv_timeout(self.timeout)?)
                .ok_or_else(|| anyhow!("S0: bad share vector"))?;
            let delta = ssa::reconstruct(&acc0, &share1);
            self.trace.end(mg, Phase::Merge, self.side(), None);
            let rp = self.trace.begin();
            self.trace.end(rp, Phase::Reply, self.side(), None);
            Ok(ServerReply::Round {
                server_time,
                delta: Some(delta),
                inter_sent: 0,
                outcomes,
                spans: Vec::new(),
            })
        } else {
            let up_span = self.trace.begin();
            let (mut msks, mut outcomes) =
                self.recv_cohort(n, deadline, |raw| msg::decode_key_upload::<G>(raw).map(|u| u.msk));
            let agreed = self.agree_cohort(&mut outcomes)?;
            // S_0 forwards exactly the agreed clients' publics, tagged
            // with their original link index.
            let mut publics: Vec<Option<_>> = (0..n).map(|_| None).collect();
            for _ in 0..agreed.len() {
                let raw = self.inter()?.recv_timeout(self.timeout)?;
                let idx = match raw.get(..4) {
                    Some(&[a, b, c, d]) => u32::from_le_bytes([a, b, c, d]) as usize,
                    _ => bail!("S1: short forward"),
                };
                ensure!(
                    agreed.contains(&idx),
                    "S1: forwarded publics for non-agreed client {idx}"
                );
                let up = msg::decode_key_upload::<G>(&raw[4..])
                    .ok_or_else(|| anyhow!("S1: bad forwarded publics"))?;
                publics[idx] = Some(up.publics.ok_or_else(|| anyhow!("S1: no publics"))?);
            }
            self.trace.end(up_span, Phase::Upload, self.side(), None);
            let kg = self.trace.begin();
            let batches: Vec<MasterKeyBatch<G>> = agreed
                .iter()
                .map(|&i| {
                    let msk = msks[i]
                        .take()
                        .ok_or_else(|| anyhow!("S1: agreed cohort references a missing seed"))?;
                    Ok(MasterKeyBatch {
                        msk: [Sensitive::new(msk), Sensitive::new(msk)],
                        publics: publics[i].take().ok_or_else(|| anyhow!("S1: missing {i}"))?,
                    })
                })
                .collect::<Result<_>>()?;
            let ups = uploads_of(&batches, 1);
            self.trace.end(kg, Phase::Keygen, self.side(), None);
            let t = Instant::now();
            let acc1 = self.agg.aggregate_publics(&self.session, 1, &ups);
            let server_time = t.elapsed();
            let rp = self.trace.begin();
            self.inter()?.send(msg::encode_shares(&acc1))?;
            self.trace.end(rp, Phase::Reply, self.side(), None);
            Ok(ServerReply::Round {
                server_time,
                delta: None,
                inter_sent: 0,
                outcomes,
                spans: Vec::new(),
            })
        }
    }

    /// Fresh-key SSA over multiplexed lanes, readiness-driven: both
    /// servers pump `[vid || upload]` frames off the lanes as they
    /// complete. `S_1` stores each short upload's seed (O(cohort · 16 B))
    /// and tells `S_0` with a `HAVE`; `S_0` holds a long upload only
    /// until the matching `HAVE` arrives, then *commits* the client —
    /// forwards the publics (zeroed seed) and streams the batch into its
    /// running aggregate — so working memory stays O(domain + budget)
    /// instead of O(cohort · upload). At the deadline `S_0` cuts the
    /// stragglers, ships the committed id list (`DONE`), and `S_1`
    /// answers with its share vector.
    ///
    /// A deadline is mandatory: a scale round must cut its stragglers,
    /// never wait on 10⁵ sockets one by one.
    fn ssa_mux(&mut self, n: usize, deadline: Option<Duration>) -> Result<ServerReply<G>> {
        let deadline = deadline.ok_or_else(|| {
            anyhow!(
                "S{}: multiplexed rounds require an upload deadline \
                 (stragglers must be cut, not waited on)",
                self.party
            )
        })?;
        // Take the cohort out so the round can mutate lane bookkeeping
        // while borrowing `self`'s engines; always put it back — a failed
        // round must keep the deployment's lane state.
        let mut mux = self
            .mux
            .take()
            .ok_or_else(|| anyhow!("S{}: no multiplexed cohort", self.party))?;
        let round_deadline = Instant::now() + deadline;
        let result = match self.mux_round(n, &mut mux, round_deadline) {
            Ok(mut round) => {
                let out = if self.party == 0 {
                    self.ssa_mux_leader(n, round_deadline, &mut round)
                } else {
                    self.ssa_mux_worker(n, round_deadline, &mut round)
                };
                Self::mux_teardown(&mut mux, &mut round);
                out
            }
            Err(e) => Err(e),
        };
        self.mux = Some(mux);
        result
    }

    /// Register the live lanes (tag = lane index) and the peer stream
    /// (tag = [`MUX_INTER_TAG`]) into a fresh pump for one round.
    fn mux_round(
        &self,
        n: usize,
        mux: &mut MuxCohort,
        round_deadline: Instant,
    ) -> Result<MuxRound> {
        // The budget must always admit the round's largest frame — the
        // share vector (which dwarfs any single forwarded upload) — or
        // the exchange itself would park forever.
        let shares_frame = 64 + self.session.domain_size().saturating_mul(G::byte_len());
        let budget = mux.budget.max(2 * shares_frame).max(1 << 16);
        let mut pump = FramePump::new(budget);
        // Re-registration is idempotent, so per-round pumps keep feeding
        // the same cumulative counters across rounds.
        pump.set_metrics(PumpMetrics::register(&self.registry));
        let inter = mux.inter_stream.as_ref().ok_or_else(|| {
            anyhow!("S{}: no peer stream for the multiplexed round", self.party)
        })?;
        let rx = inter
            .try_clone()
            .map_err(|e| anyhow!("cloning the peer stream for the pump: {e}"))?;
        let tx_stream = inter
            .try_clone()
            .map_err(|e| anyhow!("cloning the peer stream for sends: {e}"))?;
        // The peer stream registers *first*: sweeps visit sources in
        // registration order and stop at the per-batch emission cap, so
        // a lane flood must never be able to starve the exchange frames
        // (HAVE / FWD / DONE / SHARES) that drain the commit window.
        // Registering `rx` also flips the shared socket non-blocking —
        // exactly what the TxQueue's writes on `tx_stream` expect.
        pump.register(rx, MUX_INTER_TAG, None)
            .map_err(|e| e.context("registering the peer stream with the round pump"))?;
        let mut lane_dead = vec![true; mux.lanes.len()];
        let mut lane_of: Vec<Option<usize>> = vec![None; n];
        for (li, lane) in mux.lanes.iter_mut().enumerate() {
            let Some(stream) = lane.stream.take() else { continue };
            pump.register(stream, li as u64, Some(round_deadline))
                .map_err(|e| e.context("registering a client lane with the round pump"))?;
            lane_dead[li] = false;
            let lo = lane.lo as usize;
            for slot in lane_of.iter_mut().skip(lo).take(lane.count as usize) {
                *slot = Some(li);
            }
        }
        Ok(MuxRound {
            pump,
            tx_stream,
            tx: TxQueue::new(),
            lane_dead,
            lane_of,
            budget,
            held_peak: 0,
        })
    }

    /// Hand surviving lanes back to the cohort, restore the peer stream
    /// to blocking, and record the round's high-water marks.
    fn mux_teardown(mux: &mut MuxCohort, r: &mut MuxRound) {
        for (li, lane) in mux.lanes.iter_mut().enumerate() {
            if let Some(stream) = r.pump.deregister(li as u64) {
                lane.stream = Some(stream);
            }
        }
        drop(r.pump.deregister(MUX_INTER_TAG));
        mux.peak_held_bytes = mux.peak_held_bytes.max(r.held_peak);
        mux.peak_pump_bytes = mux.peak_pump_bytes.max(r.pump.peak_in_flight());
    }

    fn ssa_mux_leader(
        &mut self,
        n: usize,
        round_deadline: Instant,
        r: &mut MuxRound,
    ) -> Result<ServerReply<G>> {
        let up_span = self.trace.begin();
        let mut acc0 = vec![G::zero(); self.session.domain_size()];
        let mut server_time = Duration::ZERO;
        let mut peer_has = vec![false; n];
        let mut held: Vec<Option<(msg::KeyUpload<G>, usize)>> = (0..n).map(|_| None).collect();
        let mut committed = vec![false; n];
        let mut committed_count = 0usize;
        let mut held_bytes = 0usize;
        let mut held_count = 0usize;
        // Clients whose upload is held *and* whose `HAVE` arrived: ready
        // to commit as soon as the outgoing backlog has room.
        let mut pending: Vec<usize> = Vec::new();
        let mut paused = false;
        let mut ready: Vec<MasterKeyBatch<G>> = Vec::new();

        // Ingest until the whole cohort committed or the deadline cuts
        // the stragglers.
        loop {
            r.tx.flush(&mut r.tx_stream)?;
            let now = Instant::now();
            if now >= round_deadline || committed_count == n {
                break;
            }
            // Only the peer stream left and nothing holdable in flight:
            // no upload can ever commit, so don't wait out the deadline.
            if r.pump.len() <= 1 && held_count == 0 && pending.is_empty() {
                break;
            }
            let wait = Duration::from_millis(5).min(round_deadline - now);
            for ev in r.pump.poll(wait) {
                match ev {
                    PumpEvent::Frame { tag: MUX_INTER_TAG, payload } => {
                        match payload.first() {
                            Some(&MUX_HAVE) => {
                                let vid = mux_vid(payload.get(1..5))?;
                                ensure!(vid < n, "S0: peer HAVE for out-of-range client {vid}");
                                if !peer_has[vid] {
                                    peer_has[vid] = true;
                                    if held[vid].is_some() && !committed[vid] {
                                        pending.push(vid);
                                    }
                                }
                            }
                            _ => bail!("S0: unexpected peer frame during ingest"),
                        }
                    }
                    PumpEvent::Frame { tag, payload } => {
                        let li = tag as usize;
                        let Some((vid, up)) =
                            mux_lane_frame::<G>(&payload, n, &r.lane_of, li, true)
                        else {
                            // Malformed frame or a vid outside the lane's
                            // range: a protocol violation kills the lane.
                            r.lane_dead[li] = true;
                            drop(r.pump.deregister(tag));
                            continue;
                        };
                        if committed[vid] || held[vid].is_some() {
                            continue; // duplicate upload: first one wins
                        }
                        let size = payload.len();
                        held_bytes += size;
                        held_count += 1;
                        r.held_peak = r.held_peak.max(held_bytes);
                        self.metrics.held_window_bytes.set_max(held_bytes as u64);
                        held[vid] = Some((up, size));
                        if peer_has[vid] {
                            pending.push(vid);
                        }
                    }
                    PumpEvent::Closed { tag } | PumpEvent::Expired { tag } => {
                        if tag == MUX_INTER_TAG {
                            bail!("S0: lost the peer exchange link mid-round");
                        }
                        r.lane_dead[tag as usize] = true;
                    }
                }
            }
            // Commit every peer-confirmed held upload while the outgoing
            // backlog stays within budget (the bound that keeps a slow
            // peer from turning held uploads into unbounded queued
            // forwards).
            while let Some(&vid) = pending.last() {
                if r.tx.backlog() > r.budget {
                    break;
                }
                pending.pop();
                let Some((up, size)) = held[vid].take() else { continue };
                held_bytes -= size;
                held_count -= 1;
                let publics = up
                    .publics
                    .ok_or_else(|| anyhow!("S0: held upload lost its publics"))?;
                // Forward only the *public* parts: the client's S_0
                // master seed must never reach S_1 (two-server privacy),
                // so the forwarded envelope carries a zeroed seed.
                let mut batch = MasterKeyBatch::<G> {
                    msk: [Sensitive::new([0u8; 16]), Sensitive::new([0u8; 16])],
                    publics,
                };
                let mut fwd = vec![MUX_FWD];
                fwd.extend_from_slice(&wire_u32(vid, "client index")?.to_le_bytes());
                fwd.extend(msg::encode_key_upload(&batch, 0, true));
                if let Some(inter) = &self.inter {
                    inter.meter().record_send(fwd.len());
                }
                r.tx.queue(&fwd);
                batch.msk = [Sensitive::new(up.msk), Sensitive::new(up.msk)];
                ready.push(batch);
                committed[vid] = true;
                committed_count += 1;
            }
            // Stream this batch's commits into the running aggregate: one
            // engine pass per poll iteration, so the shard threads fan
            // out once per batch instead of once per client.
            if !ready.is_empty() {
                let ig = self.trace.begin();
                let t = Instant::now();
                let ups = uploads_of(&ready, 0);
                self.agg.aggregate_publics_into(&self.session, 0, &ups, &mut acc0);
                server_time += t.elapsed();
                self.trace.end(ig, Phase::Ingest, self.side(), None);
                ready.clear();
            }
            // Lane backpressure: a full held window stops reading new
            // uploads (kernel flow control pushes back on the senders);
            // reading resumes once commits drain half of it.
            if !paused && held_bytes >= r.budget {
                paused = true;
                set_lanes_paused(&mut r.pump, &r.lane_dead, true);
            } else if paused && held_bytes <= r.budget / 2 {
                paused = false;
                set_lanes_paused(&mut r.pump, &r.lane_dead, false);
            }
        }
        self.trace.end(up_span, Phase::Upload, self.side(), None);

        // The cut: stop reading lanes (a straggler's late bytes stay in
        // the kernel buffer) and tell the peer which clients committed —
        // TCP ordering guarantees it sees every forward first.
        set_lanes_paused(&mut r.pump, &r.lane_dead, true);
        let committed_ids: Vec<u64> = committed
            .iter()
            .enumerate()
            .filter(|(_, c)| **c)
            .map(|(i, _)| i as u64)
            .collect();
        let mut done = vec![MUX_DONE];
        done.extend(msg::encode_indices(&committed_ids));
        if let Some(inter) = &self.inter {
            inter.meter().record_send(done.len());
        }
        r.tx.queue(&done);

        // Await the share vector through the pump — it owns the only
        // reader of the peer socket, and a blocking read around it could
        // split a frame.
        let mg = self.trace.begin();
        let shares_deadline = Instant::now() + self.timeout;
        let share1: Vec<G> = 'shares: loop {
            r.tx.flush(&mut r.tx_stream)?;
            ensure!(
                Instant::now() < shares_deadline,
                "S0: timed out waiting for the peer's share vector"
            );
            for ev in r.pump.poll(Duration::from_millis(5)) {
                match ev {
                    PumpEvent::Frame { tag: MUX_INTER_TAG, payload } => {
                        match payload.first() {
                            Some(&MUX_SHARES) => {
                                break 'shares msg::decode_shares::<G>(&payload[1..])
                                    .ok_or_else(|| anyhow!("S0: bad share vector"))?;
                            }
                            // A seed that landed after the cut: too late.
                            Some(&MUX_HAVE) => {}
                            _ => bail!("S0: unexpected peer frame while awaiting shares"),
                        }
                    }
                    PumpEvent::Closed { tag } | PumpEvent::Expired { tag } => {
                        if tag == MUX_INTER_TAG {
                            bail!("S0: lost the peer exchange link awaiting shares");
                        }
                        r.lane_dead[tag as usize] = true;
                    }
                    PumpEvent::Frame { .. } => {} // paused lanes emit none
                }
            }
        };
        ensure!(
            share1.len() == acc0.len(),
            "S0: peer share vector has {} elements, expected {}",
            share1.len(),
            acc0.len()
        );
        let delta = ssa::reconstruct(&acc0, &share1);
        self.trace.end(mg, Phase::Merge, self.side(), None);

        let outcomes: Vec<ClientOutcome> = (0..n)
            .map(|vid| {
                if committed[vid] {
                    ClientOutcome::Completed
                } else {
                    match r.lane_of[vid] {
                        Some(li) if !r.lane_dead[li] => ClientOutcome::StragglerCut,
                        _ => ClientOutcome::Dropped,
                    }
                }
            })
            .collect();
        let rp = self.trace.begin();
        self.trace.end(rp, Phase::Reply, self.side(), None);
        Ok(ServerReply::Round {
            server_time,
            delta: Some(delta),
            inter_sent: 0,
            outcomes,
            spans: Vec::new(),
        })
    }

    fn ssa_mux_worker(
        &mut self,
        n: usize,
        round_deadline: Instant,
        r: &mut MuxRound,
    ) -> Result<ServerReply<G>> {
        let up_span = self.trace.begin();
        let mut acc1 = vec![G::zero(); self.session.domain_size()];
        let mut server_time = Duration::ZERO;
        // The worker's only per-client state: the short upload's seed.
        let mut msks: Vec<Option<[u8; 16]>> = vec![None; n];
        let mut committed = vec![false; n];
        let mut ready: Vec<MasterKeyBatch<G>> = Vec::new();
        // The leader's DONE only ships after its deadline; allow the
        // reply timeout on top before declaring the peer lost.
        let give_up = round_deadline + self.timeout;
        let mut done: Option<Vec<u64>> = None;
        let done_ids = loop {
            r.tx.flush(&mut r.tx_stream)?;
            ensure!(
                Instant::now() < give_up,
                "S1: never received the peer's commit list"
            );
            for ev in r.pump.poll(Duration::from_millis(5)) {
                match ev {
                    PumpEvent::Frame { tag: MUX_INTER_TAG, payload } => {
                        match payload.first() {
                            Some(&MUX_FWD) => {
                                let vid = mux_vid(payload.get(1..5))?;
                                ensure!(
                                    vid < n,
                                    "S1: forwarded publics for out-of-range client {vid}"
                                );
                                let up = msg::decode_key_upload::<G>(&payload[5..])
                                    .ok_or_else(|| anyhow!("S1: bad forwarded publics"))?;
                                let publics = up.publics.ok_or_else(|| {
                                    anyhow!("S1: forwarded upload has no publics")
                                })?;
                                // The leader commits only after our HAVE,
                                // so the seed must already be stored.
                                let msk = msks[vid].ok_or_else(|| {
                                    anyhow!(
                                        "S1: forward for client {vid} whose seed never arrived"
                                    )
                                })?;
                                if !committed[vid] {
                                    committed[vid] = true;
                                    ready.push(MasterKeyBatch {
                                        msk: [Sensitive::new(msk), Sensitive::new(msk)],
                                        publics,
                                    });
                                }
                            }
                            Some(&MUX_DONE) => {
                                done = Some(
                                    msg::decode_indices(&payload[1..]).ok_or_else(|| {
                                        anyhow!("S1: bad commit list from peer")
                                    })?,
                                );
                            }
                            _ => bail!("S1: unexpected peer frame during ingest"),
                        }
                    }
                    PumpEvent::Frame { tag, payload } => {
                        let li = tag as usize;
                        let Some((vid, up)) =
                            mux_lane_frame::<G>(&payload, n, &r.lane_of, li, false)
                        else {
                            r.lane_dead[li] = true;
                            drop(r.pump.deregister(tag));
                            continue;
                        };
                        if msks[vid].is_none() {
                            msks[vid] = Some(up.msk);
                            let mut have = vec![MUX_HAVE];
                            have.extend_from_slice(
                                &wire_u32(vid, "client index")?.to_le_bytes(),
                            );
                            if let Some(inter) = &self.inter {
                                inter.meter().record_send(have.len());
                            }
                            r.tx.queue(&have);
                        }
                    }
                    PumpEvent::Closed { tag } | PumpEvent::Expired { tag } => {
                        if tag == MUX_INTER_TAG {
                            bail!("S1: lost the peer exchange link mid-round");
                        }
                        r.lane_dead[tag as usize] = true;
                    }
                }
            }
            // Aggregate this batch's forwards before honouring DONE: the
            // commit list only ever names already-forwarded clients.
            if !ready.is_empty() {
                let ig = self.trace.begin();
                let t = Instant::now();
                let ups = uploads_of(&ready, 1);
                self.agg.aggregate_publics_into(&self.session, 1, &ups, &mut acc1);
                server_time += t.elapsed();
                self.trace.end(ig, Phase::Ingest, self.side(), None);
                ready.clear();
            }
            if let Some(ids) = done.take() {
                break ids;
            }
        };
        self.trace.end(up_span, Phase::Upload, self.side(), None);
        let mut listed = vec![false; n];
        for &id in &done_ids {
            let id = id as usize;
            ensure!(
                id < n && committed[id],
                "S1: peer committed client {id} it never forwarded"
            );
            listed[id] = true;
        }

        // Ship the share vector and drain it fully — the round ends here.
        let rp = self.trace.begin();
        let mut shares = vec![MUX_SHARES];
        shares.extend(msg::encode_shares(&acc1));
        if let Some(inter) = &self.inter {
            inter.meter().record_send(shares.len());
        }
        r.tx.queue(&shares);
        let flush_deadline = Instant::now() + self.timeout;
        while r.tx.backlog() > 0 {
            ensure!(
                Instant::now() < flush_deadline,
                "S1: timed out shipping the share vector"
            );
            r.tx.flush(&mut r.tx_stream)?;
            if r.tx.backlog() > 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        self.trace.end(rp, Phase::Reply, self.side(), None);

        let outcomes: Vec<ClientOutcome> = (0..n)
            .map(|vid| {
                if listed[vid] {
                    ClientOutcome::Completed
                } else {
                    match r.lane_of[vid] {
                        Some(li) if !r.lane_dead[li] => ClientOutcome::StragglerCut,
                        _ => ClientOutcome::Dropped,
                    }
                }
            })
            .collect();
        Ok(ServerReply::Round {
            server_time,
            delta: None,
            inter_sent: 0,
            outcomes,
            spans: Vec::new(),
        })
    }

    /// PSR: decode the whole batch, answer it through one shard plan,
    /// ship each client its answer on the same link. With a `deadline`
    /// the round is dropout-tolerant: only the agreed surviving cohort
    /// is answered.
    fn psr(&mut self, n: usize, deadline: Option<Duration>) -> Result<ServerReply<G>> {
        let weights = self
            .weights
            .clone()
            .ok_or_else(|| anyhow!("S{}: no weights installed", self.party))?;
        if let Some(d) = deadline {
            let up_span = self.trace.begin();
            let (mut items, mut outcomes) = self.recv_cohort(n, d, |raw| {
                let up = msg::decode_key_upload::<G>(raw)?;
                up.publics.as_ref()?;
                Some(up)
            });
            let agreed = self.agree_cohort(&mut outcomes)?;
            self.trace.end(up_span, Phase::Upload, self.side(), None);
            let kg = self.trace.begin();
            let batches: Vec<MasterKeyBatch<G>> = agreed
                .iter()
                .map(|&i| {
                    let up = items[i]
                        .take()
                        .ok_or_else(|| anyhow!("S{}: agreed cohort references a missing upload", self.party))?;
                    let publics = up
                        .publics
                        .ok_or_else(|| anyhow!("S{}: agreed upload lost its publics", self.party))?;
                    Ok(MasterKeyBatch {
                        msk: [Sensitive::new(up.msk), Sensitive::new(up.msk)],
                        publics,
                    })
                })
                .collect::<Result<_>>()?;
            let uploads = uploads_of(&batches, self.party);
            self.trace.end(kg, Phase::Keygen, self.side(), None);
            let t = Instant::now();
            let answers = self
                .ret
                .answer_publics(&self.session, &weights, self.party, &uploads);
            let server_time = t.elapsed();
            // Best-effort answers: a client that died after uploading
            // loses its answer, not the round.
            let rp = self.trace.begin();
            for (&i, ans) in agreed.iter().zip(&answers) {
                let _ = self.eps[i].send(msg::encode_shares(ans));
            }
            self.trace.end(rp, Phase::Reply, self.side(), None);
            return Ok(ServerReply::Round {
                server_time,
                delta: None,
                inter_sent: 0,
                outcomes,
                spans: Vec::new(),
            });
        }
        let up_span = self.trace.begin();
        let mut batches = Vec::with_capacity(n);
        for ep in &self.eps[..n] {
            let up = msg::decode_key_upload::<G>(&ep.recv_timeout(self.timeout)?)
                .ok_or_else(|| anyhow!("S{}: bad upload", self.party))?;
            let publics = up
                .publics
                .ok_or_else(|| anyhow!("S{}: no publics", self.party))?;
            batches.push(MasterKeyBatch::<G> {
                msk: [Sensitive::new(up.msk), Sensitive::new(up.msk)],
                publics,
            });
        }
        self.trace.end(up_span, Phase::Upload, self.side(), None);
        let kg = self.trace.begin();
        let uploads = uploads_of(&batches, self.party);
        self.trace.end(kg, Phase::Keygen, self.side(), None);
        let t = Instant::now();
        let answers = self
            .ret
            .answer_publics(&self.session, &weights, self.party, &uploads);
        let server_time = t.elapsed();
        let rp = self.trace.begin();
        for (ep, ans) in self.eps[..n].iter().zip(&answers) {
            ep.send(msg::encode_shares(ans))?;
        }
        self.trace.end(rp, Phase::Reply, self.side(), None);
        Ok(ServerReply::Round {
            server_time,
            delta: None,
            inter_sent: 0,
            outcomes: Vec::new(),
            spans: Vec::new(),
        })
    }

    /// U-DPF setup: retain each client's key set, then aggregate epoch 0.
    /// Tolerant rounds retain only the agreed survivors' key sets (the
    /// fixed U-DPF cohort for every later epoch).
    fn udpf_setup(&mut self, n: usize, deadline: Option<Duration>) -> Result<ServerReply<G>> {
        self.udpf.clear();
        self.udpf_links.clear();
        self.udpf_total = n;
        if let Some(d) = deadline {
            let up_span = self.trace.begin();
            let (mut items, mut outcomes) =
                self.recv_cohort(n, d, |raw| msg::decode_udpf_keys::<G>(raw));
            let agreed = self.agree_cohort(&mut outcomes)?;
            for &i in &agreed {
                let keys = items[i]
                    .take()
                    .ok_or_else(|| anyhow!("S{}: agreed cohort references a missing key set", self.party))?;
                self.udpf.push(udpf_ssa::UdpfSsaServerKeys { keys });
                self.udpf_links.push(i);
            }
            self.trace.end(up_span, Phase::Upload, self.side(), None);
            return self.udpf_aggregate(0, outcomes);
        }
        let up_span = self.trace.begin();
        for ep in &self.eps[..n] {
            let keys = msg::decode_udpf_keys::<G>(&ep.recv_timeout(self.timeout)?)
                .ok_or_else(|| anyhow!("S{}: bad U-DPF key upload", self.party))?;
            self.udpf.push(udpf_ssa::UdpfSsaServerKeys { keys });
        }
        self.trace.end(up_span, Phase::Upload, self.side(), None);
        self.udpf_links = (0..n).collect();
        self.udpf_aggregate(0, Vec::new())
    }

    /// U-DPF epoch: apply each client's hints to its retained keys, then
    /// aggregate at the new epoch. Tolerant rounds drop retained key sets
    /// whose client died (the cohort only ever shrinks).
    fn udpf_epoch(&mut self, n: usize, epoch: u64, deadline: Option<Duration>) -> Result<ServerReply<G>> {
        if let Some(d) = deadline {
            ensure!(
                n == self.udpf_total,
                "S{}: U-DPF setup had {} clients but this epoch quotes {n}",
                self.party,
                self.udpf_total
            );
            if self.dead.len() < n {
                self.dead.resize(n, false);
            }
            // Every slot not retained (or already evicted) is Dropped
            // without any wait; live slots get the per-client deadline.
            let up_span = self.trace.begin();
            let mut outcomes = vec![ClientOutcome::Dropped; n];
            let mut fresh_hints: Vec<Option<Vec<crate::udpf::Hint<G>>>> =
                (0..self.udpf.len()).map(|_| None).collect();
            for (slot, &link) in self.udpf_links.iter().enumerate() {
                if self.dead[link] {
                    continue;
                }
                match self.eps[link].recv_timeout(d) {
                    Ok(raw) => match msg::decode_hints::<G>(&raw) {
                        Some(h)
                            if h.len() == self.udpf[slot].keys.len()
                                && h.iter().all(|x| x.epoch == epoch) =>
                        {
                            fresh_hints[slot] = Some(h);
                            outcomes[link] = ClientOutcome::Completed;
                        }
                        _ => {}
                    },
                    Err(e) if TransportError::is_timeout(&e) => {
                        outcomes[link] = ClientOutcome::StragglerCut;
                    }
                    Err(_) => {}
                }
            }
            self.agree_cohort(&mut outcomes)?;
            self.trace.end(up_span, Phase::Upload, self.side(), None);
            // Applying hints derives the epoch's fresh key material from
            // the retained sets — the server-side share of "keygen".
            let kg = self.trace.begin();
            let old = std::mem::take(&mut self.udpf);
            let old_links = std::mem::take(&mut self.udpf_links);
            for ((mut retained, link), hints) in
                old.into_iter().zip(old_links).zip(fresh_hints)
            {
                if outcomes[link] == ClientOutcome::Completed {
                    let hints = hints.ok_or_else(|| {
                        anyhow!("S{}: completed client {link} lost its hints", self.party)
                    })?;
                    retained.apply_hints(&hints);
                    self.udpf.push(retained);
                    self.udpf_links.push(link);
                }
            }
            self.trace.end(kg, Phase::Keygen, self.side(), None);
            return self.udpf_aggregate(epoch, outcomes);
        }
        ensure!(
            n == self.udpf.len(),
            "S{}: {} retained key sets but {n} hint uploads",
            self.party,
            self.udpf.len()
        );
        let up_span = self.trace.begin();
        let mut all_hints = Vec::with_capacity(n);
        for (ep, retained) in self.eps[..n].iter().zip(&self.udpf) {
            let hints = msg::decode_hints::<G>(&ep.recv_timeout(self.timeout)?)
                .ok_or_else(|| anyhow!("S{}: bad hint upload", self.party))?;
            ensure!(
                hints.len() == retained.keys.len(),
                "S{}: hint count {} != key count {}",
                self.party,
                hints.len(),
                retained.keys.len()
            );
            ensure!(
                hints.iter().all(|h| h.epoch == epoch),
                "S{}: hint epoch mismatch (expected {epoch})",
                self.party
            );
            all_hints.push(hints);
        }
        self.trace.end(up_span, Phase::Upload, self.side(), None);
        let kg = self.trace.begin();
        for (retained, hints) in self.udpf.iter_mut().zip(&all_hints) {
            retained.apply_hints(hints);
        }
        self.trace.end(kg, Phase::Keygen, self.side(), None);
        self.udpf_aggregate(epoch, Vec::new())
    }

    /// Shared U-DPF aggregation tail: evaluate the retained keys at
    /// `epoch`; worker ships shares, leader reconstructs.
    fn udpf_aggregate(
        &mut self,
        epoch: u64,
        outcomes: Vec<ClientOutcome>,
    ) -> Result<ServerReply<G>> {
        let t = Instant::now();
        let acc = udpf_ssa::server_aggregate(&self.agg, &self.session, &self.udpf, epoch);
        let server_time = t.elapsed();
        if self.party == 1 {
            let rp = self.trace.begin();
            self.inter()?.send(msg::encode_shares(&acc))?;
            self.trace.end(rp, Phase::Reply, self.side(), None);
            Ok(ServerReply::Round {
                server_time,
                delta: None,
                inter_sent: 0,
                outcomes,
                spans: Vec::new(),
            })
        } else {
            let mg = self.trace.begin();
            let share1 = msg::decode_shares::<G>(&self.inter()?.recv_timeout(self.timeout)?)
                .ok_or_else(|| anyhow!("S0: bad share vector"))?;
            let delta = ssa::reconstruct(&acc, &share1);
            self.trace.end(mg, Phase::Merge, self.side(), None);
            let rp = self.trace.begin();
            self.trace.end(rp, Phase::Reply, self.side(), None);
            Ok(ServerReply::Round {
                server_time,
                delta: Some(delta),
                inter_sent: 0,
                outcomes,
                spans: Vec::new(),
            })
        }
    }

    /// Malicious-model round: the leader runs the sketch-and-aggregate
    /// core (the cross-server multiplication is idealised, so the check
    /// is not split across the two threads — §3.1, as evaluated).
    fn verified(&mut self, uploads: &[MasterKeyBatch<Fp>], seed: u64) -> Result<ServerReply<G>> {
        ensure!(self.party == 0, "verified rounds run on the leader");
        let t = Instant::now();
        let result = verified::verify_and_aggregate(&self.session, uploads, seed)?;
        Ok(ServerReply::Verified {
            result,
            server_time: t.elapsed(),
        })
    }

    /// PSU: `S_0` pools + shuffles the blinded multisets and forwards;
    /// `S_1` deduplicates and broadcasts the blinded union.
    fn psu_align(&mut self, n: usize, shuffle_seed: u64) -> Result<ServerReply<G>> {
        let t = Instant::now();
        if self.party == 0 {
            let mut pooled = Vec::new();
            for ep in &self.eps[..n] {
                let blinded = msg::decode_indices(&ep.recv_timeout(self.timeout)?)
                    .ok_or_else(|| anyhow!("S0: bad blinded set"))?;
                pooled.extend(blinded);
            }
            let shuffled = psu::server0_shuffle(pooled, &mut Rng::new(shuffle_seed));
            self.inter()?.send(msg::encode_indices(&shuffled))?;
        } else {
            let pooled = msg::decode_indices(&self.inter()?.recv_timeout(self.timeout)?)
                .ok_or_else(|| anyhow!("S1: bad pooled multiset"))?;
            let union = psu::server1_dedup(pooled);
            let encoded = msg::encode_indices(&union);
            for ep in &self.eps[..n] {
                ep.send(encoded.clone())?;
            }
        }
        Ok(ServerReply::Round {
            server_time: t.elapsed(),
            delta: None,
            inter_sent: 0,
            outcomes: Vec::new(),
            spans: Vec::new(),
        })
    }
}

/// The wire form of a per-round upload deadline: `0` = strict.
fn opt_deadline(nanos: u64) -> Option<Duration> {
    (nanos > 0).then(|| Duration::from_nanos(nanos))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::CuckooParams;

    fn params(m: u64, k: usize) -> SessionParams {
        SessionParams {
            m,
            k,
            cuckoo: CuckooParams::default(),
        }
    }

    #[test]
    fn builder_rejects_zero_capacity_and_bad_unions() {
        let err = FslRuntimeBuilder::new(params(256, 8))
            .max_clients(0)
            .build::<u64>()
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_clients"), "{err}");
        let err = FslRuntimeBuilder::new(params(256, 8))
            .union_domain(vec![9, 3])
            .build::<u64>()
            .unwrap_err()
            .to_string();
        assert!(err.contains("strictly ascending"), "{err}");
    }

    #[test]
    fn psr_requires_weights_with_actionable_error() {
        let mut rt = FslRuntimeBuilder::new(params(256, 8)).build::<u64>().unwrap();
        let mut rng = Rng::new(1);
        let err = rt
            .psr(&[vec![1, 2, 3]], &mut rng)
            .unwrap_err()
            .to_string();
        assert!(err.contains("set_weights"), "{err}");
    }

    #[test]
    fn capacity_overflow_is_an_error_not_a_hang() {
        let mut rt = FslRuntimeBuilder::new(params(256, 8))
            .max_clients(2)
            .build::<u64>()
            .unwrap();
        let mut rng = Rng::new(2);
        let clients: Vec<(Vec<u64>, Vec<u64>)> =
            (0..3).map(|c| (vec![c], vec![c + 1])).collect();
        let err = rt.ssa(&clients, &mut rng).unwrap_err().to_string();
        assert!(err.contains("max_clients"), "{err}");
        // The runtime stays usable after the rejected round.
        assert!(rt.ssa(&clients[..2], &mut rng).is_ok());
        rt.shutdown().unwrap();
    }

    /// In-process scrape: after one SSA round both servers' registries
    /// expose round counters and phase histograms in valid Prometheus
    /// exposition, and scraping never perturbs the next round.
    #[test]
    fn stats_snapshot_after_round_is_valid_exposition() {
        let mut rt = FslRuntimeBuilder::new(params(256, 8))
            .max_clients(2)
            .build::<u64>()
            .unwrap();
        let mut rng = Rng::new(3);
        let clients: Vec<(Vec<u64>, Vec<u64>)> =
            (0..2).map(|c| (vec![c], vec![c + 1])).collect();
        rt.ssa(&clients, &mut rng).unwrap();
        let [s0, s1] = rt.stats().unwrap();
        for stats in [&s0, &s1] {
            expo::validate_prom(&stats.prom).unwrap();
            assert!(stats.prom.contains("fsl_rounds_started_total 1"), "{}", stats.prom);
            assert!(stats.prom.contains("fsl_rounds_completed_total 1"), "{}", stats.prom);
            assert!(stats.prom.contains("fsl_phase_seconds"), "{}", stats.prom);
            assert!(json::validate(&stats.json), "{}", stats.json);
        }
        // A second round after the scrape still works and accumulates.
        rt.ssa(&clients, &mut rng).unwrap();
        let [s0, _] = rt.stats().unwrap();
        assert!(s0.prom.contains("fsl_rounds_completed_total 2"), "{}", s0.prom);
        rt.shutdown().unwrap();
    }

    #[test]
    fn weight_length_mismatch_is_an_error() {
        let mut rt = FslRuntimeBuilder::new(params(256, 8)).build::<u64>().unwrap();
        let err = rt.set_weights(vec![0u64; 100]).unwrap_err().to_string();
        assert!(err.contains("m = 256"), "{err}");
    }

    /// Regression: an explicit `upload_deadline(Duration::ZERO)` used to
    /// travel the wire as the strict-round sentinel `deadline_nanos = 0`
    /// and silently come out as "no deadline".
    #[test]
    fn zero_upload_deadline_is_rejected_at_build_and_connect() {
        let err = FslRuntimeBuilder::new(params(256, 8))
            .upload_deadline(Duration::ZERO)
            .build::<u64>()
            .unwrap_err()
            .to_string();
        assert!(err.contains("upload_deadline"), "{err}");
        let err = FslRuntimeBuilder::new(params(256, 8))
            .upload_deadline(Duration::ZERO)
            .connect::<u64>("127.0.0.1:1", "127.0.0.1:1")
            .unwrap_err()
            .to_string();
        assert!(err.contains("upload_deadline"), "{err}");
        // A positive deadline still builds.
        let rt = FslRuntimeBuilder::new(params(256, 8))
            .upload_deadline(Duration::from_millis(50))
            .build::<u64>()
            .unwrap();
        rt.shutdown().unwrap();
    }

    #[test]
    fn wire_u32_rejects_overflow_instead_of_truncating() {
        assert_eq!(wire_u32(7, "x").unwrap(), 7);
        let big = u32::MAX as usize + 1;
        let err = wire_u32(big, "max_clients").unwrap_err().to_string();
        assert!(err.contains("max_clients"), "{err}");
        assert!(err.contains("u32"), "{err}");
    }

    /// Golden output: the machine-readable report line is a stable,
    /// schema-versioned contract (CI's python asserts parse it).
    #[test]
    fn round_report_json_golden() {
        let report = RoundReport {
            kind: RoundKind::Ssa,
            clients: 3,
            client_upload_bytes: 100,
            client_download_bytes: 0,
            server_exchange_bytes: 42,
            gen_time: Duration::from_micros(1500),
            server_time: Duration::from_micros(2500),
            wall_time: Duration::from_millis(5),
            outcomes: vec![ClientOutcome::Completed, ClientOutcome::Dropped],
            spans: vec![Span {
                phase: Phase::Eval,
                party: Party::S0,
                worker: Some(0),
                start_ns: 0,
                dur_ns: 10,
            }],
            spans_dropped: 0,
        };
        assert_eq!(
            report.to_json(),
            "{\"schema\":1,\"kind\":\"ssa\",\"clients\":3,\"client_upload_bytes\":100,\
             \"client_download_bytes\":0,\"server_exchange_bytes\":42,\"gen_ms\":1.500,\
             \"server_ms\":2.500,\"wall_ms\":5.000,\
             \"outcomes\":[\"completed\",\"dropped\"],\"spans\":1,\"spans_dropped\":0}"
        );
        assert!(json::validate(&report.to_json()));
        assert!(json::validate(&report.trace_json()));
        // The Chrome export carries the derived dropped-span counter
        // track alongside the span events.
        assert!(report.trace_json().contains("fsl_trace_spans_dropped_count"));
    }
}
