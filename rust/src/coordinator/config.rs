//! Coordinator configuration (the CLI maps straight onto this).

use crate::hashing::CuckooParams;

/// End-to-end FSL training configuration.
#[derive(Clone, Debug)]
pub struct FslConfig {
    /// Total clients in the population.
    pub num_clients: usize,
    /// Fraction of clients sampled per round (the paper: 10% MNIST/CIFAR,
    /// 100% TREC).
    pub participation: f64,
    /// Global communication rounds.
    pub rounds: usize,
    /// Local SGD iterations per round (paper: 1 MNIST/CIFAR, 2 TREC).
    pub local_iters: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// Learning-rate decay applied every `lr_decay_every` rounds.
    pub lr_decay: f32,
    pub lr_decay_every: usize,
    /// Top-k compression rate c = k/m.
    pub compression: f64,
    /// Cuckoo parameters shared by all parties.
    pub cuckoo: CuckooParams,
    /// Master seed for all round randomness.
    pub seed: u64,
    /// Simulated one-way channel latency in microseconds (paper: ≈3ms).
    pub latency_us: u64,
    /// Evaluate test accuracy every this many rounds (0 = never).
    pub eval_every: usize,
    /// Server aggregation workers per server (0 = default: half the
    /// available cores each, since the two servers aggregate concurrently
    /// in-process; the paper enables multi-threading for all
    /// experiments, §7.2).
    pub threads: usize,
}

impl Default for FslConfig {
    fn default() -> Self {
        FslConfig {
            num_clients: 10,
            participation: 1.0,
            rounds: 50,
            local_iters: 1,
            lr: 0.05,
            lr_decay: 0.99,
            lr_decay_every: 10,
            compression: 0.10,
            cuckoo: CuckooParams::default(),
            seed: 42,
            latency_us: 0,
            eval_every: 10,
            threads: 0,
        }
    }
}

impl FslConfig {
    /// Participants per round (≥ 1).
    pub fn participants(&self) -> usize {
        ((self.num_clients as f64 * self.participation).round() as usize)
            .clamp(1, self.num_clients)
    }

    /// Learning rate at a given round.
    pub fn lr_at(&self, round: usize) -> f32 {
        let decays = if self.lr_decay_every == 0 {
            0
        } else {
            round / self.lr_decay_every
        };
        self.lr * self.lr_decay.powi(decays as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn participants_clamped() {
        let mut c = FslConfig::default();
        c.num_clients = 100;
        c.participation = 0.1;
        assert_eq!(c.participants(), 10);
        c.participation = 0.0;
        assert_eq!(c.participants(), 1);
        c.participation = 2.0;
        assert_eq!(c.participants(), 100);
    }

    #[test]
    fn lr_decay_schedule() {
        let mut c = FslConfig::default();
        c.lr = 0.1;
        c.lr_decay = 0.5;
        c.lr_decay_every = 10;
        assert_eq!(c.lr_at(0), 0.1);
        assert_eq!(c.lr_at(9), 0.1);
        assert_eq!(c.lr_at(10), 0.05);
        assert_eq!(c.lr_at(25), 0.025);
    }
}
