//! Coordinator configuration (the CLI maps straight onto this).

use crate::hashing::CuckooParams;
use anyhow::{anyhow, Result};

/// End-to-end FSL training configuration.
#[derive(Clone, Debug)]
pub struct FslConfig {
    /// Total clients in the population.
    pub num_clients: usize,
    /// Fraction of clients sampled per round (the paper: 10% MNIST/CIFAR,
    /// 100% TREC).
    pub participation: f64,
    /// Global communication rounds.
    pub rounds: usize,
    /// Local SGD iterations per round (paper: 1 MNIST/CIFAR, 2 TREC).
    pub local_iters: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// Learning-rate decay applied every `lr_decay_every` rounds.
    pub lr_decay: f32,
    pub lr_decay_every: usize,
    /// Top-k compression rate c = k/m.
    pub compression: f64,
    /// Cuckoo parameters shared by all parties.
    pub cuckoo: CuckooParams,
    /// Master seed for all round randomness.
    pub seed: u64,
    /// Simulated one-way channel latency in microseconds (paper: ≈3ms).
    pub latency_us: u64,
    /// Simulated link bandwidth in bytes/second (0 = unlimited). With a
    /// finite value every simulated link also charges transmit time per
    /// byte, so round wall times stay honest for large payloads.
    pub bandwidth_bps: u64,
    /// Evaluate test accuracy every this many rounds (0 = never).
    pub eval_every: usize,
    /// Server aggregation workers per server (0 = default: half the
    /// available cores each, since the two servers aggregate concurrently
    /// in-process; the paper enables multi-threading for all
    /// experiments, §7.2).
    pub threads: usize,
    /// Tolerant-round upload deadline: `Some(d)` bounds every per-client
    /// upload receive by `d` and lets rounds complete on the surviving
    /// cohort; `None` (the default) keeps rounds strict. Must be positive
    /// when set — the wire encodes "strict" as zero nanoseconds, so an
    /// explicit zero is ambiguous and rejected by [`Self::validate`].
    pub upload_deadline: Option<std::time::Duration>,
}

impl Default for FslConfig {
    fn default() -> Self {
        FslConfig {
            num_clients: 10,
            participation: 1.0,
            rounds: 50,
            local_iters: 1,
            lr: 0.05,
            lr_decay: 0.99,
            lr_decay_every: 10,
            compression: 0.10,
            cuckoo: CuckooParams::default(),
            seed: 42,
            latency_us: 0,
            bandwidth_bps: 0,
            eval_every: 10,
            threads: 0,
            upload_deadline: None,
        }
    }
}

impl FslConfig {
    /// Check the configuration for values that would make a run
    /// meaningless or panic deep inside a round. Called by
    /// [`super::FslRuntimeBuilder::from_config`], [`super::run_fsl_training`],
    /// and the CLI before any work starts, so a typo like `c=0` fails with
    /// an actionable message instead of a cuckoo-table panic ten layers
    /// down.
    pub fn validate(&self) -> Result<()> {
        if self.num_clients == 0 {
            return Err(anyhow!(
                "num_clients must be nonzero: the round loop samples participants \
                 from the client population (CLI: clients=N)"
            ));
        }
        if self.rounds == 0 {
            return Err(anyhow!(
                "rounds must be nonzero: zero global rounds trains nothing (CLI: rounds=N)"
            ));
        }
        if !(self.participation > 0.0 && self.participation <= 1.0) {
            return Err(anyhow!(
                "participation must be in (0, 1], got {}: it is the fraction of \
                 clients sampled per round (the paper uses 0.1 for MNIST/CIFAR, 1.0 for TREC)",
                self.participation
            ));
        }
        if !(self.compression > 0.0 && self.compression <= 1.0) {
            return Err(anyhow!(
                "compression must be in (0, 1], got {}: it is the top-k rate c = k/m \
                 (CLI: c=0.1 keeps 10% of the weights)",
                self.compression
            ));
        }
        if self.upload_deadline == Some(std::time::Duration::ZERO) {
            return Err(anyhow!(
                "upload_deadline must be positive when set: the wire encodes \"strict \
                 round\" as zero nanoseconds, so an explicit zero would be silently read \
                 back as no deadline (leave upload_deadline unset for strict rounds)"
            ));
        }
        Ok(())
    }

    /// Participants per round (≥ 1).
    pub fn participants(&self) -> usize {
        ((self.num_clients as f64 * self.participation).round() as usize)
            .clamp(1, self.num_clients)
    }

    /// Learning rate at a given round.
    pub fn lr_at(&self, round: usize) -> f32 {
        let decays = if self.lr_decay_every == 0 {
            0
        } else {
            round / self.lr_decay_every
        };
        self.lr * self.lr_decay.powi(decays as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn participants_clamped() {
        let mut c = FslConfig::default();
        c.num_clients = 100;
        c.participation = 0.1;
        assert_eq!(c.participants(), 10);
        c.participation = 0.0;
        assert_eq!(c.participants(), 1);
        c.participation = 2.0;
        assert_eq!(c.participants(), 100);
    }

    #[test]
    fn validation_catches_out_of_range_values() {
        assert!(FslConfig::default().validate().is_ok());
        let cases: [(&str, fn(&mut FslConfig)); 7] = [
            ("num_clients", |c| c.num_clients = 0),
            ("rounds", |c| c.rounds = 0),
            ("participation", |c| c.participation = 0.0),
            ("participation", |c| c.participation = 1.5),
            ("compression", |c| c.compression = 0.0),
            ("compression", |c| c.compression = f64::NAN),
            ("upload_deadline", |c| {
                c.upload_deadline = Some(std::time::Duration::ZERO)
            }),
        ];
        for (field, poke) in cases {
            let mut cfg = FslConfig::default();
            poke(&mut cfg);
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains(field), "error {err:?} should mention {field}");
        }
    }

    #[test]
    fn lr_decay_schedule() {
        let mut c = FslConfig::default();
        c.lr = 0.1;
        c.lr_decay = 0.5;
        c.lr_decay_every = 10;
        assert_eq!(c.lr_at(0), 0.1);
        assert_eq!(c.lr_at(9), 0.1);
        assert_eq!(c.lr_at(10), 0.05);
        assert_eq!(c.lr_at(25), 0.025);
    }
}
