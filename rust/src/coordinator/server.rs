//! One-shot SSA round wrappers over the persistent runtime.
//!
//! The threaded round itself — `S_0` leader receiving long uploads and
//! forwarding publics, `S_1` worker aggregating and shipping its share
//! vector — lives in the [`super::runtime`] command loop now. The
//! functions here are kept for compatibility: each builds a runtime, runs
//! one round, and drops it, which is exactly the per-call cost the
//! persistent API exists to amortise.

use super::runtime::FslRuntimeBuilder;
use crate::group::Group;
use crate::protocol::aggregate::AggregationEngine;
use crate::protocol::Session;
use anyhow::Result;
use std::time::Duration;

/// Everything measured in one SSA round.
#[derive(Debug, Clone)]
pub struct SsaRoundResult<G: Group> {
    /// Reconstructed global update (sum over clients), domain-indexed.
    pub delta: Vec<G>,
    /// Client → S_b upload bytes (all clients, both servers; the paper's
    /// Table-6 quantity divided by n).
    pub client_upload_bytes: u64,
    /// S_0 → S_1 forwarded public parts + S_1 → S_0 share vector.
    pub server_exchange_bytes: u64,
    /// Wall-clock of client DPF key generation (sum over clients).
    pub gen_time: Duration,
    /// Max of the two servers' evaluate+aggregate wall-clocks.
    pub server_time: Duration,
}

/// [`run_ssa_round_with`] under a default multi-threaded engine (the
/// paper enables multi-threading for all experiments, §7.2). The two
/// server threads aggregate *concurrently* on one machine here, so each
/// gets half the cores — `server_time` then measures one server's real
/// throughput instead of 2× oversubscription.
#[deprecated(note = "build a persistent coordinator::FslRuntime and call .ssa(..)")]
pub fn run_ssa_round<G: Group>(
    session: &Session,
    clients: &[(Vec<u64>, Vec<G>)],
    rng: &mut crate::crypto::rng::Rng,
    latency: Duration,
) -> Result<SsaRoundResult<G>> {
    // (Deprecated items may call each other without tripping the lint.)
    run_ssa_round_with(session, clients, rng, latency, &AggregationEngine::per_coloc_server())
}

/// Run one SSA round: `clients[i] = (selections, deltas)`. Returns the
/// reconstructed update. One-shot wrapper: spawns a fresh runtime (two
/// server threads, metered topology), serves a single round through it,
/// and tears it down.
#[deprecated(note = "build a persistent coordinator::FslRuntime and call .ssa(..)")]
pub fn run_ssa_round_with<G: Group>(
    session: &Session,
    clients: &[(Vec<u64>, Vec<G>)],
    rng: &mut crate::crypto::rng::Rng,
    latency: Duration,
    engine: &AggregationEngine,
) -> Result<SsaRoundResult<G>> {
    let mut rt = FslRuntimeBuilder::from_session(session.clone())
        .latency(latency)
        .threads(engine.threads())
        .max_clients(clients.len().max(1))
        .build::<G>()?;
    let out = rt.ssa(clients, rng)?;
    Ok(SsaRoundResult {
        delta: out.delta,
        client_upload_bytes: out.report.client_upload_bytes,
        server_exchange_bytes: out.report.server_exchange_bytes,
        gen_time: out.report.gen_time,
        server_time: out.report.server_time,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FslRuntimeBuilder;
    use crate::crypto::rng::Rng;
    use crate::hashing::CuckooParams;
    use crate::protocol::SessionParams;

    fn ssa_once(
        session: &Session,
        clients: &[(Vec<u64>, Vec<u64>)],
        rng: &mut Rng,
        threads: usize,
    ) -> crate::coordinator::SsaOutcome<u64> {
        let mut rt = FslRuntimeBuilder::from_session(session.clone())
            .threads(threads)
            .max_clients(clients.len())
            .build::<u64>()
            .unwrap();
        rt.ssa(clients, rng).unwrap()
    }

    #[test]
    fn threaded_round_matches_direct_aggregation() {
        let session = Session::new_full(SessionParams {
            m: 1 << 10,
            k: 32,
            cuckoo: CuckooParams::default(),
        });
        let mut rng = Rng::new(150);
        let clients: Vec<(Vec<u64>, Vec<u64>)> = (0..4)
            .map(|c| {
                let sel = rng.sample_distinct(32, 1 << 10);
                let deltas = sel.iter().map(|&x| x * 7 + c).collect();
                (sel, deltas)
            })
            .collect();
        let mut expected = vec![0u64; 1 << 10];
        for (sel, deltas) in &clients {
            for (&i, &d) in sel.iter().zip(deltas) {
                expected[i as usize] = expected[i as usize].wrapping_add(d);
            }
        }
        let res = ssa_once(&session, &clients, &mut rng, 0);
        assert_eq!(res.delta, expected);
        assert!(res.report.client_upload_bytes > 0);
        assert!(res.report.server_exchange_bytes > 0);
    }

    #[test]
    fn engine_width_does_not_change_the_result() {
        let session = Session::new_full(SessionParams {
            m: 1 << 9,
            k: 16,
            cuckoo: CuckooParams::default(),
        });
        let clients: Vec<(Vec<u64>, Vec<u64>)> = {
            let mut rng = Rng::new(152);
            (0..3)
                .map(|c| {
                    let sel = rng.sample_distinct(16, 1 << 9);
                    let deltas = sel.iter().map(|&x| x + c).collect();
                    (sel, deltas)
                })
                .collect()
        };
        let mut deltas = Vec::new();
        for threads in [1usize, 8] {
            let mut rng = Rng::new(153);
            deltas.push(ssa_once(&session, &clients, &mut rng, threads).delta);
        }
        assert_eq!(deltas[0], deltas[1]);
    }

    #[test]
    fn upload_bytes_track_paper_formula() {
        // Measured wire bytes ≈ paper-model bits / 8 (within envelope
        // overhead: headers, adaptive depths).
        let session = Session::new_full(SessionParams {
            m: 1 << 12,
            k: 128,
            cuckoo: CuckooParams::default(),
        });
        let mut rng = Rng::new(151);
        let sel = rng.sample_distinct(128, 1 << 12);
        let deltas: Vec<u64> = vec![1; 128];
        let res = ssa_once(&session, &[(sel, deltas)], &mut rng, 0);
        let paper_bits = session.simple.num_bins() * (session.log_theta() * 130 + 64) + 256;
        let measured_bits = res.report.client_upload_bytes as f64 * 8.0;
        let model_bits = paper_bits as f64;
        assert!(
            measured_bits < model_bits * 1.15 && measured_bits > model_bits * 0.5,
            "measured {measured_bits} vs model {model_bits}"
        );
    }

    /// The retained equivalence check against the deprecated one-shot
    /// wrapper: same session + same rng stream ⇒ bit-identical delta and
    /// identical byte metering, whichever API served the round.
    #[test]
    #[allow(deprecated)]
    fn deprecated_wrapper_matches_the_runtime() {
        let session = Session::new_full(SessionParams {
            m: 1 << 9,
            k: 16,
            cuckoo: CuckooParams::default(),
        });
        let clients: Vec<(Vec<u64>, Vec<u64>)> = {
            let mut rng = Rng::new(154);
            (0..3)
                .map(|c| {
                    let sel = rng.sample_distinct(16, 1 << 9);
                    let deltas = sel.iter().map(|&x| x * 3 + c).collect();
                    (sel, deltas)
                })
                .collect()
        };
        let legacy = {
            let mut rng = Rng::new(155);
            run_ssa_round(&session, &clients, &mut rng, Duration::ZERO).unwrap()
        };
        let modern = {
            let mut rng = Rng::new(155);
            ssa_once(&session, &clients, &mut rng, 0)
        };
        assert_eq!(legacy.delta, modern.delta);
        assert_eq!(legacy.client_upload_bytes, modern.report.client_upload_bytes);
        assert_eq!(legacy.server_exchange_bytes, modern.report.server_exchange_bytes);
    }
}
