//! The two-server SSA round over metered channels.
//!
//! `S_0` is the leader: it receives each client's long upload (master
//! seed + public parts), forwards the public parts to `S_1` over the
//! inter-server channel, aggregates its shares, receives `S_1`'s share
//! vector and reconstructs `Δw`. `S_1` is the worker: short uploads
//! (master seed only) from clients, public parts from `S_0`.

use crate::dpf::{MasterKeyBatch, PublicPart};
use crate::group::Group;
use crate::net;
use crate::protocol::aggregate::{uploads_of, AggregationEngine};
use crate::protocol::msg;
use crate::protocol::{ssa, Session};
use anyhow::{anyhow, Result};
use std::time::{Duration, Instant};

/// Everything measured in one SSA round.
#[derive(Debug, Clone)]
pub struct SsaRoundResult<G: Group> {
    /// Reconstructed global update (sum over clients), domain-indexed.
    pub delta: Vec<G>,
    /// Client → S_b upload bytes (all clients, both servers; the paper's
    /// Table-6 quantity divided by n).
    pub client_upload_bytes: u64,
    /// S_0 → S_1 forwarded public parts + S_1 → S_0 share vector.
    pub server_exchange_bytes: u64,
    /// Wall-clock of client DPF key generation (sum over clients).
    pub gen_time: Duration,
    /// Max of the two servers' evaluate+aggregate wall-clocks.
    pub server_time: Duration,
}

/// [`run_ssa_round_with`] under a default multi-threaded engine (the
/// paper enables multi-threading for all experiments, §7.2). The two
/// server threads aggregate *concurrently* on one machine here, so each
/// gets half the cores — `server_time` then measures one server's real
/// throughput instead of 2× oversubscription.
pub fn run_ssa_round<G: Group>(
    session: &Session,
    clients: &[(Vec<u64>, Vec<G>)],
    rng: &mut crate::crypto::rng::Rng,
    latency: Duration,
) -> Result<SsaRoundResult<G>> {
    run_ssa_round_with(session, clients, rng, latency, &AggregationEngine::per_coloc_server())
}

/// Run one SSA round: `clients[i] = (selections, deltas)`. Returns the
/// reconstructed update. Spawns the two server threads, drives the
/// clients on the caller thread (Fig. 1 topology, channels metered); both
/// servers aggregate through `engine` (zero-copy publics path).
pub fn run_ssa_round_with<G: Group>(
    session: &Session,
    clients: &[(Vec<u64>, Vec<G>)],
    rng: &mut crate::crypto::rng::Rng,
    latency: Duration,
    engine: &AggregationEngine,
) -> Result<SsaRoundResult<G>> {
    let n = clients.len();
    let (client_links, server_sides, inter) = net::topology(n, latency);
    let (inter0, inter1) = inter;
    // Split the per-client server endpoints so S_1's half can move into
    // its thread (mpsc receivers are !Sync).
    let (eps0, eps1): (Vec<_>, Vec<_>) = server_sides.into_iter().unzip();

    let t_gen = Instant::now();
    let mut uploads = Vec::with_capacity(n);
    for (sel, deltas) in clients {
        uploads.push(ssa::client_update(session, sel, deltas, rng).map_err(|e| anyhow!("{e}"))?);
    }
    let gen_time = t_gen.elapsed();

    // Clients ship their messages (driver thread = the client side).
    for (links, batch) in client_links.iter().zip(&uploads) {
        links.to_s0.send(msg::encode_key_upload(batch, 0, true))?;
        links.to_s1.send(msg::encode_key_upload(batch, 1, false))?;
    }
    let client_upload_bytes: u64 = client_links
        .iter()
        .map(|l| l.to_s0.meter.sent() + l.to_s1.meter.sent())
        .sum();

    let result = std::thread::scope(|scope| -> Result<(Vec<G>, Duration, Duration, u64)> {
        // S_1: worker.
        let s1 = scope.spawn(move || -> Result<(Vec<G>, Duration, u64)> {
            let inter1 = inter1;
            let mut msks = Vec::with_capacity(n);
            for ep1 in &eps1 {
                let up = msg::decode_key_upload::<G>(&ep1.recv()?)
                    .ok_or_else(|| anyhow!("S1: bad client upload"))?;
                msks.push(up.msk);
            }
            // Public parts forwarded by S_0, tagged with client index.
            let mut publics: Vec<Option<Vec<PublicPart<G>>>> = (0..n).map(|_| None).collect();
            for _ in 0..n {
                let raw = inter1.recv()?;
                let idx = u32::from_le_bytes(raw[..4].try_into().unwrap()) as usize;
                let slot = publics
                    .get_mut(idx)
                    .ok_or_else(|| anyhow!("S1: bad client index {idx}"))?;
                let up = msg::decode_key_upload::<G>(&raw[4..])
                    .ok_or_else(|| anyhow!("S1: bad forwarded publics"))?;
                *slot = Some(up.publics.ok_or_else(|| anyhow!("S1: no publics"))?);
            }
            let batches: Vec<MasterKeyBatch<G>> = publics
                .into_iter()
                .enumerate()
                .zip(&msks)
                .map(|((i, p), msk)| {
                    Ok(MasterKeyBatch {
                        msk: [*msk, *msk],
                        publics: p.ok_or_else(|| anyhow!("S1: missing {i}"))?,
                    })
                })
                .collect::<Result<_>>()?;
            let t = Instant::now();
            let acc = engine.aggregate_publics(session, 1, &uploads_of(&batches, 1));
            let server_time = t.elapsed();
            inter1.send(msg::encode_shares(&acc))?;
            Ok((acc, server_time, inter1.meter.sent()))
        });

        // S_0: leader (runs on this thread).
        let mut batches = Vec::with_capacity(n);
        for (i, ep0) in eps0.iter().enumerate() {
            let raw = ep0.recv()?;
            let up = msg::decode_key_upload::<G>(&raw)
                .ok_or_else(|| anyhow!("S0: bad client upload"))?;
            let publics = up.publics.ok_or_else(|| anyhow!("S0: no publics"))?;
            // Forward the public parts to S_1.
            let batch = crate::dpf::MasterKeyBatch::<G> {
                msk: [up.msk, up.msk],
                publics,
            };
            let mut fwd = (i as u32).to_le_bytes().to_vec();
            fwd.extend(msg::encode_key_upload(&batch, 0, true));
            inter0.send(fwd)?;
            batches.push(batch);
        }
        let t = Instant::now();
        let acc0 = engine.aggregate_publics(session, 0, &uploads_of(&batches, 0));
        let s0_time = t.elapsed();

        let share1 = msg::decode_shares::<G>(&inter0.recv()?)
            .ok_or_else(|| anyhow!("S0: bad share vector"))?;
        let (share1_check, s1_time, s1_sent) = s1.join().map_err(|_| anyhow!("S1 panicked"))??;
        debug_assert_eq!(share1, share1_check);
        let delta = ssa::reconstruct(&acc0, &share1);
        let exchange = inter0.meter.sent() + s1_sent;
        Ok((delta, s0_time, s1_time, exchange))
    })?;

    let (delta, s0_time, s1_time, server_exchange_bytes) = result;
    Ok(SsaRoundResult {
        delta,
        client_upload_bytes,
        server_exchange_bytes,
        gen_time,
        server_time: s0_time.max(s1_time),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;
    use crate::hashing::CuckooParams;
    use crate::protocol::SessionParams;

    #[test]
    fn threaded_round_matches_direct_aggregation() {
        let session = Session::new_full(SessionParams {
            m: 1 << 10,
            k: 32,
            cuckoo: CuckooParams::default(),
        });
        let mut rng = Rng::new(150);
        let clients: Vec<(Vec<u64>, Vec<u64>)> = (0..4)
            .map(|c| {
                let sel = rng.sample_distinct(32, 1 << 10);
                let deltas = sel.iter().map(|&x| x * 7 + c).collect();
                (sel, deltas)
            })
            .collect();
        let mut expected = vec![0u64; 1 << 10];
        for (sel, deltas) in &clients {
            for (&i, &d) in sel.iter().zip(deltas) {
                expected[i as usize] = expected[i as usize].wrapping_add(d);
            }
        }
        let res = run_ssa_round(&session, &clients, &mut rng, Duration::ZERO).unwrap();
        assert_eq!(res.delta, expected);
        assert!(res.client_upload_bytes > 0);
        assert!(res.server_exchange_bytes > 0);
    }

    #[test]
    fn engine_width_does_not_change_the_result() {
        let session = Session::new_full(SessionParams {
            m: 1 << 9,
            k: 16,
            cuckoo: CuckooParams::default(),
        });
        let clients: Vec<(Vec<u64>, Vec<u64>)> = {
            let mut rng = Rng::new(152);
            (0..3)
                .map(|c| {
                    let sel = rng.sample_distinct(16, 1 << 9);
                    let deltas = sel.iter().map(|&x| x + c).collect();
                    (sel, deltas)
                })
                .collect()
        };
        let mut deltas = Vec::new();
        for threads in [1usize, 8] {
            let mut rng = Rng::new(153);
            let res = run_ssa_round_with(
                &session,
                &clients,
                &mut rng,
                Duration::ZERO,
                &AggregationEngine::new(threads),
            )
            .unwrap();
            deltas.push(res.delta);
        }
        assert_eq!(deltas[0], deltas[1]);
    }

    #[test]
    fn upload_bytes_track_paper_formula() {
        // Measured wire bytes ≈ paper-model bits / 8 (within envelope
        // overhead: headers, adaptive depths).
        let session = Session::new_full(SessionParams {
            m: 1 << 12,
            k: 128,
            cuckoo: CuckooParams::default(),
        });
        let mut rng = Rng::new(151);
        let sel = rng.sample_distinct(128, 1 << 12);
        let deltas: Vec<u64> = vec![1; 128];
        let res = run_ssa_round(&session, &[(sel, deltas)], &mut rng, Duration::ZERO).unwrap();
        let paper_bits = session.simple.num_bins() * (session.log_theta() * 130 + 64) + 256;
        let measured_bits = res.client_upload_bytes as f64 * 8.0;
        let model_bits = paper_bits as f64;
        assert!(
            measured_bits < model_bits * 1.15 && measured_bits > model_bits * 0.5,
            "measured {measured_bits} vs model {model_bits}"
        );
    }
}
