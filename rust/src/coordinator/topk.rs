//! Top-k sparsification (Aji & Heafield \[1\], the paper's §7 submodel
//! selection strategy).

/// Indices of the `k` largest-magnitude entries, ascending. Uses a
/// partial selection (`select_nth_unstable`) — O(m) expected, not a sort.
pub fn top_k_magnitude(delta: &[f32], k: usize) -> Vec<u64> {
    let k = k.min(delta.len());
    if k == 0 {
        return Vec::new();
    }
    if k == delta.len() {
        return (0..delta.len() as u64).collect();
    }
    let mut idx: Vec<u32> = (0..delta.len() as u32).collect();
    let kth = delta.len() - k;
    idx.select_nth_unstable_by(kth, |&a, &b| {
        delta[a as usize]
            .abs()
            .partial_cmp(&delta[b as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut out: Vec<u64> = idx[kth..].iter().map(|&i| i as u64).collect();
    out.sort_unstable();
    out
}

/// Group-structured top-k for mega-elements (§7.4): score each τ-wide
/// group by the sum of absolute values, return the top `k_groups` group
/// indices, ascending.
pub fn top_k_groups(delta: &[f32], tau: usize, k_groups: usize) -> Vec<u64> {
    let n_groups = delta.len().div_ceil(tau);
    let scores: Vec<f32> = (0..n_groups)
        .map(|g| {
            delta[g * tau..((g + 1) * tau).min(delta.len())]
                .iter()
                .map(|v| v.abs())
                .sum()
        })
        .collect();
    top_k_magnitude(&scores, k_groups.min(n_groups))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_largest_magnitudes() {
        let d = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 4.0];
        assert_eq!(top_k_magnitude(&d, 3), vec![1, 3, 5]);
        assert_eq!(top_k_magnitude(&d, 1), vec![1]);
    }

    #[test]
    fn edge_cases() {
        let d = vec![1.0f32, 2.0];
        assert_eq!(top_k_magnitude(&d, 0), Vec::<u64>::new());
        assert_eq!(top_k_magnitude(&d, 2), vec![0, 1]);
        assert_eq!(top_k_magnitude(&d, 5), vec![0, 1]);
        assert_eq!(top_k_magnitude(&[], 3), Vec::<u64>::new());
    }

    #[test]
    fn group_scoring() {
        // groups of 3: |sums| = [0.6, 9.0, 0.3]
        let d = vec![0.1f32, 0.2, 0.3, -3.0, 3.0, 3.0, 0.1, 0.1, 0.1];
        assert_eq!(top_k_groups(&d, 3, 1), vec![1]);
        assert_eq!(top_k_groups(&d, 3, 2), vec![0, 1]);
    }

    #[test]
    fn ragged_tail_group() {
        let d = vec![0.0f32, 0.0, 0.0, 0.0, 10.0]; // tau=2 → 3 groups
        assert_eq!(top_k_groups(&d, 2, 1), vec![2]);
    }
}
