//! Synthetic datasets standing in for MNIST / CIFAR10 / TREC (§7.3).
//!
//! This environment has no network access, so the accuracy experiments
//! run on deterministic generators with the *shapes* of the paper's
//! tasks: a 784-feature 10-class image task (class-conditional Gaussians
//! over random class prototypes — learnable but not trivial) and a
//! 6-class bag-of-words text task with the TREC census of Table 9
//! (8,256-word vocabulary, per-client vocabulary skew). What the
//! experiments measure — the top-k-compression-vs-accuracy *curve* — is
//! preserved; absolute accuracies are task-specific (see DESIGN.md §5).

mod image;
mod partition;
mod text;

pub use image::{ImageDataset, IMAGE_CLASSES, IMAGE_DIM};
pub use partition::partition_iid;
pub use text::{TextDataset, TrecCensus};
