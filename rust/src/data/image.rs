//! MNIST-shaped synthetic image task.

use crate::crypto::rng::Rng;

/// Feature dimension (28×28 flattened).
pub const IMAGE_DIM: usize = 784;
/// Number of classes.
pub const IMAGE_CLASSES: usize = 10;

/// A labelled dataset of flat f32 feature vectors.
#[derive(Clone, Debug)]
pub struct ImageDataset {
    pub x: Vec<f32>,
    pub y: Vec<u8>,
    pub n: usize,
}

impl ImageDataset {
    /// Class-conditional Gaussians: each class has a sparse random
    /// prototype (digit-stroke-like support) plus noise; `difficulty`
    /// scales the noise (1.0 ≈ a task where a linear model plateaus
    /// below an MLP, mirroring MNIST's headroom structure).
    ///
    /// NOTE: prototypes are seeded by `seed` — a train set and its test
    /// set MUST share the seed (use [`ImageDataset::synthesize_split`])
    /// or they are different classification tasks.
    pub fn synthesize(n: usize, seed: u64, difficulty: f32) -> Self {
        Self::synthesize_split(n, 0, seed, difficulty).0
    }

    /// Generate a (train, test) pair drawn from the *same* class
    /// prototypes — the supported way to get a held-out set.
    pub fn synthesize_split(
        n_train: usize,
        n_test: usize,
        seed: u64,
        difficulty: f32,
    ) -> (Self, Self) {
        let mut rng = Rng::new(seed);
        // Class prototypes: ~15% active pixels, values in [0.4, 1.0].
        let mut prototypes = vec![0f32; IMAGE_CLASSES * IMAGE_DIM];
        for c in 0..IMAGE_CLASSES {
            for d in 0..IMAGE_DIM {
                if rng.gen_f64() < 0.15 {
                    prototypes[c * IMAGE_DIM + d] = 0.4 + 0.6 * rng.gen_f64() as f32;
                }
            }
        }
        let train = Self::draw(&prototypes, n_train, &mut rng, difficulty);
        let test = Self::draw(&prototypes, n_test, &mut rng, difficulty);
        (train, test)
    }

    fn draw(prototypes: &[f32], n: usize, rng: &mut Rng, difficulty: f32) -> Self {
        let mut x = vec![0f32; n * IMAGE_DIM];
        let mut y = vec![0u8; n];
        for i in 0..n {
            let c = rng.gen_range(IMAGE_CLASSES as u64) as usize;
            y[i] = c as u8;
            for d in 0..IMAGE_DIM {
                let base = prototypes[c * IMAGE_DIM + d];
                let noise = rng.gen_normal() as f32 * 0.35 * difficulty;
                x[i * IMAGE_DIM + d] = (base + noise).clamp(0.0, 1.0);
            }
        }
        ImageDataset { x, y, n }
    }

    /// One example's features.
    pub fn features(&self, i: usize) -> &[f32] {
        &self.x[i * IMAGE_DIM..(i + 1) * IMAGE_DIM]
    }

    /// Assemble a batch `(x, y_onehot)` from example indices.
    pub fn batch(&self, idx: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut bx = Vec::with_capacity(idx.len() * IMAGE_DIM);
        let mut by = vec![0f32; idx.len() * IMAGE_CLASSES];
        for (row, &i) in idx.iter().enumerate() {
            bx.extend_from_slice(self.features(i));
            by[row * IMAGE_CLASSES + self.y[i] as usize] = 1.0;
        }
        (bx, by)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let a = ImageDataset::synthesize(100, 7, 1.0);
        let b = ImageDataset::synthesize(100, 7, 1.0);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.len(), 100 * IMAGE_DIM);
        assert!(a.y.iter().all(|&c| (c as usize) < IMAGE_CLASSES));
        // All ten classes present in 100 draws (w.h.p. with this seed).
        let classes: std::collections::HashSet<_> = a.y.iter().collect();
        assert!(classes.len() >= 8);
    }

    #[test]
    fn features_bounded() {
        let d = ImageDataset::synthesize(50, 8, 1.0);
        assert!(d.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn batch_onehot() {
        let d = ImageDataset::synthesize(10, 9, 1.0);
        let (bx, by) = d.batch(&[0, 3]);
        assert_eq!(bx.len(), 2 * IMAGE_DIM);
        assert_eq!(by.len(), 2 * IMAGE_CLASSES);
        assert_eq!(by.iter().filter(|&&v| v == 1.0).count(), 2);
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-prototype accuracy must be far above chance — the task
        // is learnable by construction.
        let d = ImageDataset::synthesize(500, 10, 1.0);
        let mut means = vec![0f32; IMAGE_CLASSES * IMAGE_DIM];
        let mut counts = [0usize; IMAGE_CLASSES];
        for i in 0..d.n {
            let c = d.y[i] as usize;
            counts[c] += 1;
            for (m, v) in means[c * IMAGE_DIM..(c + 1) * IMAGE_DIM]
                .iter_mut()
                .zip(d.features(i))
            {
                *m += v;
            }
        }
        for c in 0..IMAGE_CLASSES {
            for m in &mut means[c * IMAGE_DIM..(c + 1) * IMAGE_DIM] {
                *m /= counts[c].max(1) as f32;
            }
        }
        let correct = (0..d.n)
            .filter(|&i| {
                let f = d.features(i);
                let best = (0..IMAGE_CLASSES)
                    .min_by(|&a, &b| {
                        let da: f32 = means[a * IMAGE_DIM..(a + 1) * IMAGE_DIM]
                            .iter()
                            .zip(f)
                            .map(|(m, v)| (m - v).powi(2))
                            .sum();
                        let db: f32 = means[b * IMAGE_DIM..(b + 1) * IMAGE_DIM]
                            .iter()
                            .zip(f)
                            .map(|(m, v)| (m - v).powi(2))
                            .sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                best == d.y[i] as usize
            })
            .count();
        assert!(correct as f64 / d.n as f64 > 0.9, "{correct}/500");
    }
}
