//! TREC-shaped synthetic text task (Table 9 census).

use crate::crypto::rng::Rng;

/// The paper's Table 9: TREC statistics.
#[derive(Clone, Copy, Debug)]
pub struct TrecCensus {
    pub vocab: usize,
    pub classes: usize,
    pub clients: usize,
    pub train_samples: usize,
    pub test_samples: usize,
    pub words_per_client: usize,
    pub samples_per_client: usize,
}

impl Default for TrecCensus {
    fn default() -> Self {
        TrecCensus {
            vocab: 8256,
            classes: 6,
            clients: 4,
            train_samples: 5452,
            test_samples: 500,
            words_per_client: 3365,
            samples_per_client: 1363,
        }
    }
}

/// Bag-of-words dataset with per-client vocabulary skew: each client sees
/// a ~words_per_client subset of the vocabulary — exactly the structure
/// that makes *submodel* (embedding-row) learning effective.
#[derive(Clone, Debug)]
pub struct TextDataset {
    pub census: TrecCensus,
    /// Sparse examples: (client, label, word ids).
    pub examples: Vec<(usize, u8, Vec<u32>)>,
    /// Per-client vocabulary (sorted word ids).
    pub client_vocab: Vec<Vec<u32>>,
    /// Held-out test set: (label, word ids).
    pub test: Vec<(u8, Vec<u32>)>,
}

impl TextDataset {
    /// Deterministic synthesis. Class signal: each class owns a band of
    /// "topic" words; an example draws most tokens from its class band
    /// (within the client's vocabulary) plus common filler words.
    pub fn synthesize(census: TrecCensus, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let band = census.vocab / census.classes;

        // Per-client vocabulary: a random subset, biased to include some
        // of every class band (so every client can learn every class).
        let mut client_vocab = Vec::with_capacity(census.clients);
        for _ in 0..census.clients {
            let mut v = rng.sample_distinct(census.words_per_client, census.vocab as u64);
            v.sort_unstable();
            client_vocab.push(v.iter().map(|&x| x as u32).collect::<Vec<u32>>());
        }

        let sample = |rng: &mut Rng, vocab: &[u32], label: usize, len: usize| -> Vec<u32> {
            let lo = (label * band) as u32;
            let hi = ((label + 1) * band) as u32;
            // Words of this client's vocab inside the class band.
            let in_band: Vec<u32> = vocab.iter().copied().filter(|&w| w >= lo && w < hi).collect();
            let mut words = Vec::with_capacity(len);
            for _ in 0..len {
                if !in_band.is_empty() && rng.gen_f64() < 0.7 {
                    words.push(in_band[rng.gen_range(in_band.len() as u64) as usize]);
                } else {
                    words.push(vocab[rng.gen_range(vocab.len() as u64) as usize]);
                }
            }
            words
        };

        let mut examples = Vec::with_capacity(census.clients * census.samples_per_client);
        for (c, vocab) in client_vocab.iter().enumerate() {
            for _ in 0..census.samples_per_client {
                let label = rng.gen_range(census.classes as u64) as usize;
                let len = 6 + rng.gen_range(10) as usize;
                examples.push((c, label as u8, sample(&mut rng, vocab, label, len)));
            }
        }
        // Test set over the full vocabulary.
        let full: Vec<u32> = (0..census.vocab as u32).collect();
        let mut test = Vec::with_capacity(census.test_samples);
        for _ in 0..census.test_samples {
            let label = rng.gen_range(census.classes as u64) as usize;
            let len = 6 + rng.gen_range(10) as usize;
            test.push((label as u8, sample(&mut rng, &full, label, len)));
        }
        TextDataset {
            census,
            examples,
            client_vocab,
            test,
        }
    }

    /// A client's examples.
    pub fn client_examples(&self, client: usize) -> impl Iterator<Item = &(usize, u8, Vec<u32>)> {
        self.examples.iter().filter(move |(c, _, _)| *c == client)
    }

    /// Assemble a dense bag-of-words batch `(bow, y_onehot)` from
    /// examples (count encoding, matching the L2 `embbag` input).
    pub fn batch(&self, items: &[(u8, Vec<u32>)]) -> (Vec<f32>, Vec<f32>) {
        let v = self.census.vocab;
        let c = self.census.classes;
        let mut bow = vec![0f32; items.len() * v];
        let mut y = vec![0f32; items.len() * c];
        for (row, (label, words)) in items.iter().enumerate() {
            for &w in words {
                bow[row * v + w as usize] += 1.0;
            }
            y[row * c + *label as usize] = 1.0;
        }
        (bow, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_census() -> TrecCensus {
        TrecCensus {
            vocab: 600,
            classes: 6,
            clients: 4,
            train_samples: 400,
            test_samples: 60,
            words_per_client: 250,
            samples_per_client: 100,
        }
    }

    #[test]
    fn census_shapes() {
        let d = TextDataset::synthesize(small_census(), 11);
        assert_eq!(d.client_vocab.len(), 4);
        assert_eq!(d.examples.len(), 400);
        assert_eq!(d.test.len(), 60);
        for v in &d.client_vocab {
            assert_eq!(v.len(), 250);
            assert!(v.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn client_examples_use_client_vocab() {
        let d = TextDataset::synthesize(small_census(), 12);
        for (c, _, words) in &d.examples {
            let vocab = &d.client_vocab[*c];
            assert!(words.iter().all(|w| vocab.binary_search(w).is_ok()));
        }
    }

    #[test]
    fn labels_correlate_with_bands() {
        let d = TextDataset::synthesize(small_census(), 13);
        let band = 600 / 6;
        let mut hits = 0usize;
        let mut total = 0usize;
        for (_, label, words) in &d.examples {
            for &w in words {
                total += 1;
                if (w as usize) / band == *label as usize {
                    hits += 1;
                }
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.5, "class signal too weak: {frac}");
    }

    #[test]
    fn default_census_matches_table9() {
        let c = TrecCensus::default();
        assert_eq!(c.vocab, 8256);
        assert_eq!(c.clients, 4);
        assert_eq!(c.train_samples, 5452);
        assert_eq!(c.samples_per_client, 1363);
    }

    #[test]
    fn batch_encoding() {
        let d = TextDataset::synthesize(small_census(), 14);
        let items = vec![(2u8, vec![5u32, 5, 9])];
        let (bow, y) = d.batch(&items);
        assert_eq!(bow[5], 2.0);
        assert_eq!(bow[9], 1.0);
        assert_eq!(y[2], 1.0);
        assert_eq!(y.iter().sum::<f32>(), 1.0);
    }
}
