//! Federated data partitioning (the paper follows McMahan et al. \[33\]:
//! shuffle, then split evenly across clients — IID).

use crate::crypto::rng::Rng;

/// Shuffle `n` example indices and split them evenly across `clients`.
/// Remainder examples go to the first clients (sizes differ by ≤ 1).
pub fn partition_iid(n: usize, clients: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(clients > 0);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let base = n / clients;
    let extra = n % clients;
    let mut out = Vec::with_capacity(clients);
    let mut off = 0;
    for c in 0..clients {
        let take = base + usize::from(c < extra);
        out.push(idx[off..off + take].to_vec());
        off += take;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_everything_once() {
        let mut rng = Rng::new(140);
        let parts = partition_iid(103, 10, &mut rng);
        assert_eq!(parts.len(), 10);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // Sizes differ by at most one.
        let sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
        assert!(sizes.iter().all(|&s| s == 10 || s == 11));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = partition_iid(50, 5, &mut Rng::new(1));
        let b = partition_iid(50, 5, &mut Rng::new(1));
        let c = partition_iid(50, 5, &mut Rng::new(2));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
