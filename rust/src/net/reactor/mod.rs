//! Readiness-driven connection multiplexer: the std-only reactor under
//! the standalone server's accept loop and the streaming mux-SSA ingest.
//!
//! The container ships no epoll/kqueue binding, so "readiness" here is a
//! level-triggered sweep over non-blocking sockets: every registered
//! stream is drained until `WouldBlock`, and the pump sleeps in short
//! increments only when a whole sweep moved nothing. That is the same
//! poll discipline the old `accept_timeout` used for a single listener,
//! generalised to any number of in-flight connections — one thread can
//! carry a handshake burst or a 10^6-virtual-client upload fan-in
//! without a thread (or an fd-sized buffer) per peer.
//!
//! Three properties the rounds lean on:
//!
//! * **Frame reassembly.** Each source owns a tiny state machine: a
//!   7-byte [`msg`] frame header, then the payload. Partial reads park
//!   mid-frame and resume on the next sweep, so interleaved slow writers
//!   cost memory proportional to *their declared frames*, not time.
//! * **Backpressure budget.** The sum of all in-progress payload buffers
//!   is capped by the pump's byte budget. A source whose declared frame
//!   does not fit waits (unread, in the kernel's receive buffer — TCP
//!   flow control pushes back on the sender) until completed frames are
//!   handed to the caller and their bytes release. A sweep also stops
//!   *emitting* once a budget's worth of completed frames is out, so one
//!   [`FramePump::poll`] batch hands the caller O(budget) bytes — a
//!   caller that holds frames across batches (the mux ingest's commit
//!   window) bounds its memory by reacting between batches, no matter
//!   how much a flooding cohort has queued in the kernel. A slow-loris
//!   cohort can therefore stall *itself*, never the server's memory.
//! * **Deadlines.** Every source can carry a deadline; a source that has
//!   not completed a frame by then yields [`PumpEvent::Expired`] and is
//!   dropped. This is what cuts handshake slow-loris connections and
//!   upload stragglers without per-connection timer threads.
//!
//! The pump is deliberately read-only: replies and forwards go out
//! through the existing blocking [`crate::net::transport`] handles,
//! whose peers always drain their own ends through a pump of their own.

use crate::metrics::registry::{Counter, Gauge, MetricsRegistry};
use crate::protocol::msg;
use anyhow::{Context, Result};
use std::io::Read;
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Live-registry handles for one pump, attached via
/// [`FramePump::set_metrics`]. Registration is idempotent per
/// registry, so re-attaching each mux round keeps the counters
/// cumulative while gauges track the current pump.
#[derive(Clone)]
pub struct PumpMetrics {
    open_sources: Gauge,
    parked_bytes: Gauge,
    inflight_peak: Gauge,
    frames: Counter,
    frame_bytes: Counter,
    polls: Counter,
}

impl PumpMetrics {
    /// Register (or look up) the pump metric family on `registry`.
    pub fn register(registry: &MetricsRegistry) -> Self {
        PumpMetrics {
            open_sources: registry.gauge(
                "fsl_pump_open_sources_count",
                "Streams currently registered on the frame pump",
            ),
            parked_bytes: registry.gauge(
                "fsl_pump_parked_bytes",
                "Declared payload bytes waiting for budget headroom",
            ),
            inflight_peak: registry.gauge(
                "fsl_pump_inflight_peak_bytes",
                "High-water mark of summed in-progress payload buffers",
            ),
            frames: registry.counter(
                "fsl_pump_frames_total",
                "Completed frames handed to the caller",
            ),
            frame_bytes: registry.counter(
                "fsl_pump_frame_bytes",
                "Payload bytes of completed frames",
            ),
            polls: registry.counter("fsl_pump_polls_total", "Pump poll batches"),
        }
    }
}

/// How long one idle sweep sleeps before re-polling its sources. Short
/// enough that handshake latency stays imperceptible, long enough that
/// an idle accept phase is not a hot spin.
const SWEEP_SLEEP: Duration = Duration::from_millis(1);

/// What a sweep observed on one source.
#[derive(Debug)]
pub enum PumpEvent {
    /// One complete frame's payload (the frame header already stripped
    /// and its bytes released from the budget — the caller owns them).
    Frame { tag: u64, payload: Vec<u8> },
    /// The source closed, reset, or sent bytes that do not parse as a
    /// frame. The source has been dropped from the pump.
    Closed { tag: u64 },
    /// The source's deadline passed before a frame completed. The source
    /// has been dropped from the pump.
    Expired { tag: u64 },
}

impl PumpEvent {
    /// The source the event belongs to.
    pub fn tag(&self) -> u64 {
        match self {
            PumpEvent::Frame { tag, .. }
            | PumpEvent::Closed { tag }
            | PumpEvent::Expired { tag } => *tag,
        }
    }
}

/// Per-source frame-reassembly state.
enum ReadState {
    /// Collecting the fixed-size frame header.
    Header { buf: [u8; msg::FRAME_HEADER_LEN], got: usize },
    /// Header parsed but the payload does not fit the budget yet: the
    /// bytes wait in the kernel buffer until the pump can afford them.
    Parked { len: usize },
    /// Collecting `buf.len()` payload bytes (charged against the budget).
    Payload { buf: Vec<u8>, got: usize },
}

struct Source {
    tag: u64,
    stream: TcpStream,
    state: ReadState,
    deadline: Option<Instant>,
    /// Paused sources are skipped by sweeps (the ingest layer's own
    /// backpressure: stop reading uploads while its commit window is
    /// full) but still expire on their deadline.
    paused: bool,
}

/// The readiness pump: registered non-blocking streams in, completed
/// frames out.
pub struct FramePump {
    sources: Vec<Source>,
    budget: usize,
    in_flight: usize,
    peak_in_flight: usize,
    metrics: Option<PumpMetrics>,
}

impl FramePump {
    /// A pump whose in-progress payload buffers never exceed `budget`
    /// bytes in total. Frames larger than the whole budget can never
    /// complete and close their source (a protocol violation, same as a
    /// frame beyond [`msg::MAX_FRAME_LEN`]).
    pub fn new(budget: usize) -> Self {
        FramePump {
            sources: Vec::new(),
            budget: budget.max(msg::FRAME_HEADER_LEN),
            in_flight: 0,
            peak_in_flight: 0,
            metrics: None,
        }
    }

    /// Attach live-registry instrumentation (see [`PumpMetrics`]).
    pub fn set_metrics(&mut self, metrics: PumpMetrics) {
        metrics.open_sources.set(self.sources.len() as u64);
        metrics.inflight_peak.set_max(self.peak_in_flight as u64);
        self.metrics = Some(metrics);
    }

    fn note_sources(&self) {
        if let Some(m) = &self.metrics {
            m.open_sources.set(self.sources.len() as u64);
        }
    }

    fn note_parked(&self, len: usize, entering: bool) {
        if let Some(m) = &self.metrics {
            if entering {
                m.parked_bytes.add(len as u64);
            } else {
                m.parked_bytes.sub(len as u64);
            }
        }
    }

    fn note_peak(&self) {
        if let Some(m) = &self.metrics {
            m.inflight_peak.set_max(self.peak_in_flight as u64);
        }
    }

    /// Register `stream` under `tag` (made non-blocking here). Tags are
    /// caller-chosen and must be unique among live sources.
    pub fn register(
        &mut self,
        stream: TcpStream,
        tag: u64,
        deadline: Option<Instant>,
    ) -> Result<()> {
        stream
            .set_nonblocking(true)
            .context("making a pump source non-blocking")?;
        self.sources.push(Source {
            tag,
            stream,
            state: ReadState::Header { buf: [0; msg::FRAME_HEADER_LEN], got: 0 },
            deadline,
            paused: false,
        });
        self.note_sources();
        Ok(())
    }

    /// Remove `tag` and return its stream restored to blocking mode (for
    /// wrapping in a regular transport once its handshake frame is in).
    /// Any partial payload charge is refunded.
    pub fn deregister(&mut self, tag: u64) -> Option<TcpStream> {
        let at = self.sources.iter().position(|s| s.tag == tag)?;
        let src = self.sources.swap_remove(at);
        match &src.state {
            ReadState::Payload { buf, .. } => {
                self.in_flight = self.in_flight.saturating_sub(buf.len());
            }
            ReadState::Parked { len } => self.note_parked(*len, false),
            ReadState::Header { .. } => {}
        }
        self.note_sources();
        let _ = src.stream.set_nonblocking(false);
        Some(src.stream)
    }

    /// Replace `tag`'s deadline (`None` = no deadline).
    pub fn set_deadline(&mut self, tag: u64, deadline: Option<Instant>) {
        if let Some(src) = self.sources.iter_mut().find(|s| s.tag == tag) {
            src.deadline = deadline;
        }
    }

    /// Pause or resume sweeping `tag` (paused sources keep their kernel
    /// buffer and their deadline, they are just not read).
    pub fn set_paused(&mut self, tag: u64, paused: bool) {
        if let Some(src) = self.sources.iter_mut().find(|s| s.tag == tag) {
            src.paused = paused;
        }
    }

    /// True when `tag` is still registered.
    pub fn contains(&self, tag: u64) -> bool {
        self.sources.iter().any(|s| s.tag == tag)
    }

    /// Number of live sources.
    pub fn len(&self) -> usize {
        self.sources.len()
    }

    /// True when no sources remain.
    pub fn is_empty(&self) -> bool {
        self.sources.is_empty()
    }

    /// High-water mark of the summed in-progress payload buffers — the
    /// streaming-ingest memory-bound tests assert on this.
    pub fn peak_in_flight(&self) -> usize {
        self.peak_in_flight
    }

    /// Sweep the sources until at least one event is ready or `max_wait`
    /// passes; an empty vec means a quiet timeout. Sources that closed,
    /// expired, or completed frames are reported once each; closed and
    /// expired sources are dropped from the pump.
    pub fn poll(&mut self, max_wait: Duration) -> Vec<PumpEvent> {
        if let Some(m) = &self.metrics {
            m.polls.inc();
        }
        let deadline = Instant::now() + max_wait;
        loop {
            let events = self.sweep();
            if !events.is_empty() {
                return events;
            }
            if Instant::now() >= deadline || self.sources.is_empty() {
                return events;
            }
            std::thread::sleep(SWEEP_SLEEP.min(max_wait));
        }
    }

    /// One pass over every source: drain readable bytes, emit completed
    /// frames, expire and drop dead sources.
    fn sweep(&mut self) -> Vec<PumpEvent> {
        let now = Instant::now();
        let mut events = Vec::new();
        let mut emitted = 0usize;
        let mut i = 0;
        while i < self.sources.len() {
            // Budget-parked sources retry here: earlier handoffs in this
            // same sweep may have freed room.
            let parked_len = match &self.sources[i].state {
                ReadState::Parked { len } => Some(*len),
                _ => None,
            };
            if let Some(len) = parked_len {
                if self.in_flight + len <= self.budget {
                    self.sources[i].state =
                        ReadState::Payload { buf: vec![0u8; len], got: 0 };
                    self.in_flight += len;
                    self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
                    self.note_parked(len, false);
                    self.note_peak();
                }
            }
            let fate = if self.sources[i].paused {
                SourceFate::Keep
            } else {
                self.drain_source(i, &mut events, &mut emitted)
            };
            let expired = matches!(fate, SourceFate::Keep)
                && self.sources[i].deadline.is_some_and(|d| now >= d);
            match (fate, expired) {
                (SourceFate::Keep, false) => i += 1,
                (SourceFate::Keep, true) => {
                    events.push(PumpEvent::Expired { tag: self.sources[i].tag });
                    self.drop_source(i);
                }
                (SourceFate::Closed, _) => {
                    events.push(PumpEvent::Closed { tag: self.sources[i].tag });
                    self.drop_source(i);
                }
            }
            if emitted >= self.budget {
                // Batch cap: let the caller absorb (and release) what is
                // already out before any source delivers more. Remaining
                // sources keep their kernel buffers and are swept next
                // pass.
                break;
            }
        }
        events
    }

    /// Read source `i` until `WouldBlock` or the sweep's emission cap,
    /// pushing every completed frame. Each iteration takes the
    /// reassembly state out of the source, works on the owned value, and
    /// puts the successor state back.
    fn drain_source(
        &mut self,
        i: usize,
        events: &mut Vec<PumpEvent>,
        emitted: &mut usize,
    ) -> SourceFate {
        loop {
            let fresh = ReadState::Header { buf: [0; msg::FRAME_HEADER_LEN], got: 0 };
            let state = std::mem::replace(&mut self.sources[i].state, fresh);
            match state {
                // Still over budget: revisit on the next sweep.
                ReadState::Parked { len } => {
                    self.sources[i].state = ReadState::Parked { len };
                    return SourceFate::Keep;
                }
                ReadState::Header { mut buf, mut got } => {
                    if got < buf.len() {
                        match self.sources[i].stream.read(&mut buf[got..]) {
                            Ok(0) => return SourceFate::Closed,
                            Ok(n) => got += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                self.sources[i].state = ReadState::Header { buf, got };
                                return SourceFate::Keep;
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                                self.sources[i].state = ReadState::Header { buf, got };
                                continue;
                            }
                            Err(_) => return SourceFate::Closed,
                        }
                    }
                    if got < buf.len() {
                        self.sources[i].state = ReadState::Header { buf, got };
                        continue;
                    }
                    let len = match msg::frame_payload_len(&buf[..]) {
                        Ok(len) => len,
                        // Bad magic/version/length: protocol violation.
                        Err(_) => return SourceFate::Closed,
                    };
                    if len > self.budget {
                        // Can never fit: treat like a malformed frame.
                        return SourceFate::Closed;
                    }
                    if self.in_flight + len > self.budget {
                        self.sources[i].state = ReadState::Parked { len };
                        self.note_parked(len, true);
                        return SourceFate::Keep;
                    }
                    self.in_flight += len;
                    self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
                    self.note_peak();
                    self.sources[i].state =
                        ReadState::Payload { buf: vec![0u8; len], got: 0 };
                }
                ReadState::Payload { mut buf, mut got } => {
                    if got < buf.len() {
                        match self.sources[i].stream.read(&mut buf[got..]) {
                            Ok(0) => {
                                self.in_flight = self.in_flight.saturating_sub(buf.len());
                                return SourceFate::Closed;
                            }
                            Ok(n) => got += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                self.sources[i].state = ReadState::Payload { buf, got };
                                return SourceFate::Keep;
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                                self.sources[i].state = ReadState::Payload { buf, got };
                                continue;
                            }
                            Err(_) => {
                                self.in_flight = self.in_flight.saturating_sub(buf.len());
                                return SourceFate::Closed;
                            }
                        }
                    }
                    // Zero-length frames complete without a payload read,
                    // so this check runs even when no byte moved above.
                    if got < buf.len() {
                        self.sources[i].state = ReadState::Payload { buf, got };
                        continue;
                    }
                    let len = buf.len();
                    self.in_flight = self.in_flight.saturating_sub(len);
                    *emitted += len;
                    if let Some(m) = &self.metrics {
                        m.frames.inc();
                        m.frame_bytes.add(len as u64);
                    }
                    events.push(PumpEvent::Frame { tag: self.sources[i].tag, payload: buf });
                    if *emitted >= self.budget {
                        return SourceFate::Keep;
                    }
                    // The replacement state is already a fresh header.
                }
            }
        }
    }

    fn drop_source(&mut self, i: usize) {
        let src = self.sources.swap_remove(i);
        match &src.state {
            ReadState::Payload { buf, .. } => {
                self.in_flight = self.in_flight.saturating_sub(buf.len());
            }
            ReadState::Parked { len } => self.note_parked(*len, false),
            ReadState::Header { .. } => {}
        }
        self.note_sources();
    }
}

enum SourceFate {
    Keep,
    Closed,
}

/// Capped exponential backoff for accept-error loops: a port-scan burst
/// or a transient `EMFILE` must not turn the accept loop into a hot
/// spin, and must not sleep past the phase's overall deadline either.
#[derive(Debug, Clone)]
pub struct Backoff {
    next: Duration,
    cap: Duration,
}

impl Backoff {
    /// Start at `base`, double per failure, never exceed `cap`.
    pub fn new(base: Duration, cap: Duration) -> Self {
        Backoff { next: base.max(Duration::from_millis(1)), cap }
    }

    /// Sleep for the current step (clamped to `remaining`) and escalate.
    pub fn sleep(&mut self, remaining: Duration) {
        std::thread::sleep(self.next.min(remaining));
        self.next = (self.next * 2).min(self.cap);
    }

    /// The duration the next [`Backoff::sleep`] would wait.
    pub fn peek(&self) -> Duration {
        self.next
    }

    /// Drop back to fast polling after a success.
    pub fn reset(&mut self, base: Duration) {
        self.next = base.max(Duration::from_millis(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn reassembles_interleaved_partial_frames() {
        let (mut a_w, a_r) = pair();
        let (mut b_w, b_r) = pair();
        let mut pump = FramePump::new(1 << 20);
        pump.register(a_r, 1, None).unwrap();
        pump.register(b_r, 2, None).unwrap();

        let fa = msg::frame(&vec![0xAA; 300]);
        let fb = msg::frame(&vec![0xBB; 5]);
        // Interleave partial writes: a's header, b's whole frame, a's rest.
        a_w.write_all(&fa[..4]).unwrap();
        b_w.write_all(&fb).unwrap();
        let ev = pump.poll(Duration::from_secs(2));
        assert!(
            matches!(&ev[..], [PumpEvent::Frame { tag: 2, payload }] if payload == &vec![0xBB; 5]),
            "{ev:?}"
        );
        a_w.write_all(&fa[4..]).unwrap();
        let ev = pump.poll(Duration::from_secs(2));
        assert!(
            matches!(&ev[..], [PumpEvent::Frame { tag: 1, payload }] if payload.len() == 300),
            "{ev:?}"
        );
        // Several frames queued on one source all surface.
        a_w.write_all(&msg::frame(&[1])).unwrap();
        a_w.write_all(&msg::frame(&[2, 2])).unwrap();
        a_w.flush().unwrap();
        let mut got = Vec::new();
        while got.len() < 2 {
            for e in pump.poll(Duration::from_secs(2)) {
                if let PumpEvent::Frame { payload, .. } = e {
                    got.push(payload);
                }
            }
        }
        assert_eq!(got, vec![vec![1], vec![2, 2]]);
    }

    #[test]
    fn budget_parks_second_source_until_first_hands_off() {
        let (mut a_w, a_r) = pair();
        let (mut b_w, b_r) = pair();
        let mut pump = FramePump::new(1000);
        pump.register(a_r, 1, None).unwrap();
        pump.register(b_r, 2, None).unwrap();

        // a declares 800 bytes but stalls; b's full 800-byte frame must
        // wait — together they would break the 1000-byte budget.
        let fa = msg::frame(&vec![0xAA; 800]);
        a_w.write_all(&fa[..msg::FRAME_HEADER_LEN + 10]).unwrap();
        b_w.write_all(&msg::frame(&vec![0xBB; 800])).unwrap();
        let ev = pump.poll(Duration::from_millis(120));
        assert!(ev.is_empty(), "{ev:?}");
        assert!(pump.peak_in_flight() <= 1000, "{}", pump.peak_in_flight());

        // a completes → its buffer is handed off → b gets its turn.
        a_w.write_all(&fa[msg::FRAME_HEADER_LEN + 10..]).unwrap();
        let mut tags = Vec::new();
        while tags.len() < 2 {
            for e in pump.poll(Duration::from_secs(2)) {
                match e {
                    PumpEvent::Frame { tag, payload } => {
                        assert_eq!(payload.len(), 800);
                        tags.push(tag);
                    }
                    other => panic!("{other:?}"),
                }
            }
        }
        assert_eq!(tags, vec![1, 2]);
        assert!(pump.peak_in_flight() <= 1000, "{}", pump.peak_in_flight());
    }

    #[test]
    fn one_poll_batch_never_emits_more_than_the_budget() {
        let (mut w, r) = pair();
        let mut pump = FramePump::new(1000);
        pump.register(r, 1, None).unwrap();
        // Ten 400-byte frames queued in the kernel at once: the cap
        // trips at 1000 emitted bytes, so a batch carries at most three.
        for _ in 0..10 {
            w.write_all(&msg::frame(&vec![7u8; 400])).unwrap();
        }
        w.flush().unwrap();
        let mut total = 0;
        while total < 10 {
            let ev = pump.poll(Duration::from_secs(2));
            assert!(!ev.is_empty(), "frames are queued, the poll must move");
            assert!(ev.len() <= 3, "{} frames in one batch", ev.len());
            for e in ev {
                match e {
                    PumpEvent::Frame { tag: 1, payload } => {
                        assert_eq!(payload.len(), 400);
                        total += 1;
                    }
                    other => panic!("{other:?}"),
                }
            }
        }
    }

    #[test]
    fn oversize_frame_closes_the_source() {
        let (mut w, r) = pair();
        let mut pump = FramePump::new(100);
        pump.register(r, 7, None).unwrap();
        w.write_all(&msg::frame(&vec![0; 101])).unwrap();
        let ev = pump.poll(Duration::from_secs(2));
        assert!(matches!(&ev[..], [PumpEvent::Closed { tag: 7 }]), "{ev:?}");
        assert!(pump.is_empty());
    }

    #[test]
    fn slow_loris_expires_on_deadline() {
        let (mut w, r) = pair();
        let mut pump = FramePump::new(1 << 16);
        let deadline = Instant::now() + Duration::from_millis(50);
        pump.register(r, 9, Some(deadline)).unwrap();
        // A trickle that never completes a frame.
        w.write_all(&[msg::FRAME_MAGIC[0]]).unwrap();
        let t0 = Instant::now();
        let ev = pump.poll(Duration::from_secs(5));
        assert!(matches!(&ev[..], [PumpEvent::Expired { tag: 9 }]), "{ev:?}");
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert!(pump.is_empty());
    }

    #[test]
    fn closed_peer_is_reported_once_and_dropped() {
        let (w, r) = pair();
        let mut pump = FramePump::new(1 << 16);
        pump.register(r, 3, None).unwrap();
        drop(w);
        let ev = pump.poll(Duration::from_secs(2));
        assert!(matches!(&ev[..], [PumpEvent::Closed { tag: 3 }]), "{ev:?}");
        assert!(pump.is_empty());
        assert!(pump.poll(Duration::from_millis(10)).is_empty());
    }

    #[test]
    fn paused_sources_are_not_read() {
        let (mut w, r) = pair();
        let mut pump = FramePump::new(1 << 16);
        pump.register(r, 4, None).unwrap();
        pump.set_paused(4, true);
        w.write_all(&msg::frame(&[5, 5, 5])).unwrap();
        assert!(pump.poll(Duration::from_millis(60)).is_empty());
        pump.set_paused(4, false);
        let ev = pump.poll(Duration::from_secs(2));
        assert!(
            matches!(&ev[..], [PumpEvent::Frame { tag: 4, payload }] if payload == &[5, 5, 5]),
            "{ev:?}"
        );
    }

    #[test]
    fn deregister_restores_blocking_and_refunds_budget() {
        let (mut w, r) = pair();
        let mut pump = FramePump::new(1000);
        pump.register(r, 6, None).unwrap();
        let f = msg::frame(&vec![1u8; 500]);
        w.write_all(&f[..msg::FRAME_HEADER_LEN + 5]).unwrap();
        assert!(pump.poll(Duration::from_millis(60)).is_empty());
        let stream = pump.deregister(6).unwrap();
        assert!(pump.is_empty());
        // Budget refunded: a fresh source can use the whole budget again.
        let (mut w2, r2) = pair();
        pump.register(r2, 8, None).unwrap();
        w2.write_all(&msg::frame(&vec![2u8; 900])).unwrap();
        let ev = pump.poll(Duration::from_secs(2));
        assert!(
            matches!(&ev[..], [PumpEvent::Frame { tag: 8, payload }] if payload.len() == 900),
            "{ev:?}"
        );
        drop(stream);
    }

    /// Attached `PumpMetrics` track sources, frames, bytes, and the
    /// parked/peak gauges across a park-and-release cycle.
    #[test]
    fn pump_metrics_follow_register_park_and_frames() {
        let reg = MetricsRegistry::shared();
        let (mut a_w, a_r) = pair();
        let (mut b_w, b_r) = pair();
        let mut pump = FramePump::new(1000);
        pump.set_metrics(PumpMetrics::register(&reg));
        pump.register(a_r, 1, None).unwrap();
        pump.register(b_r, 2, None).unwrap();
        assert_eq!(reg.gauge("fsl_pump_open_sources_count", "").get(), 2);

        // a stalls mid-frame holding 800 budget bytes; b's 800-byte
        // frame must park.
        let fa = msg::frame(&vec![0xAA; 800]);
        a_w.write_all(&fa[..msg::FRAME_HEADER_LEN + 10]).unwrap();
        b_w.write_all(&msg::frame(&vec![0xBB; 800])).unwrap();
        assert!(pump.poll(Duration::from_millis(120)).is_empty());
        assert_eq!(reg.gauge("fsl_pump_parked_bytes", "").get(), 800);

        a_w.write_all(&fa[msg::FRAME_HEADER_LEN + 10..]).unwrap();
        let mut frames = 0;
        while frames < 2 {
            for e in pump.poll(Duration::from_secs(2)) {
                assert!(matches!(e, PumpEvent::Frame { .. }), "{e:?}");
                frames += 1;
            }
        }
        assert_eq!(reg.counter("fsl_pump_frames_total", "").get(), 2);
        assert_eq!(reg.counter("fsl_pump_frame_bytes", "").get(), 1600);
        assert_eq!(reg.gauge("fsl_pump_parked_bytes", "").get(), 0);
        let peak = reg.gauge("fsl_pump_inflight_peak_bytes", "").get();
        assert!((800..=1000).contains(&peak), "{peak}");
        assert!(reg.counter("fsl_pump_polls_total", "").get() >= 2);

        let _ = pump.deregister(1);
        let _ = pump.deregister(2);
        assert_eq!(reg.gauge("fsl_pump_open_sources_count", "").get(), 0);
    }

    #[test]
    fn backoff_escalates_and_caps() {
        let mut b = Backoff::new(Duration::from_millis(1), Duration::from_millis(8));
        let steps: Vec<Duration> = (0..6)
            .map(|_| {
                let s = b.peek();
                b.sleep(Duration::ZERO); // clamped: no real sleeping in tests
                s
            })
            .collect();
        assert_eq!(
            steps,
            [1, 2, 4, 8, 8, 8].map(Duration::from_millis).to_vec()
        );
        b.reset(Duration::from_millis(1));
        assert_eq!(b.peek(), Duration::from_millis(1));
    }
}
