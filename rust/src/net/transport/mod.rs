//! Pluggable transports: "a bidirectional metered byte channel".
//!
//! The whole protocol stack moves length-delimited byte messages built by
//! [`crate::protocol::msg`]; the [`Transport`] trait abstracts *what
//! carries them* so the [`crate::coordinator::FslRuntime`] can run the
//! same rounds over
//!
//! * [`InProc`] — the latency/bandwidth-simulating in-process
//!   [`Endpoint`] (the historical single-process deployment), or
//! * [`tcp::TcpTransport`] — real framed TCP sockets between independent
//!   OS processes (the paper's §7 topology for real).
//!
//! [`Listener`] is the accepting side: a server binds one, accepts
//! connections, and learns from each connection's [`Hello`] handshake
//! whether it is the driver's control channel, a client data link, or the
//! peer server. The handshake is versioned and magic-tagged so a
//! mis-dialled or stale-binary connection fails immediately with a
//! readable error, not a hang or a decode failure mid-round.

pub mod fault;
pub mod tcp;

pub use fault::{FaultInjector, FaultPlan};

use crate::metrics::CommMeter;
use crate::net::Endpoint;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;
use std::time::Duration;

/// The typed failure vocabulary every transport maps its native errors
/// into, so the runtime and tests can match on variants instead of error
/// strings. Both [`InProc`] and [`tcp::TcpTransport`] attach one of these
/// as the root cause of every timeout/disconnect `anyhow::Error`;
/// recover it with [`TransportError::of`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// No message arrived (or could be written) within the deadline.
    Timeout,
    /// The peer closed its end — a crashed process, a dropped endpoint,
    /// or a reset socket.
    Closed,
    /// The accepting side deliberately refused the handshake. Permanent:
    /// retrying the same dial cannot succeed.
    Rejected(String),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Timeout => write!(f, "timed out waiting for a frame"),
            TransportError::Closed => write!(f, "connection closed by peer"),
            TransportError::Rejected(reason) => write!(f, "connection rejected: {reason}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl TransportError {
    /// Walk an `anyhow` error's cause chain looking for the transport
    /// error underneath any amount of added context.
    pub fn of(err: &anyhow::Error) -> Option<&TransportError> {
        err.chain().find_map(|cause| cause.downcast_ref())
    }

    /// True when `err` is rooted in a transport timeout.
    pub fn is_timeout(err: &anyhow::Error) -> bool {
        matches!(Self::of(err), Some(TransportError::Timeout))
    }

    /// True when `err` is rooted in a closed peer.
    pub fn is_closed(err: &anyhow::Error) -> bool {
        matches!(Self::of(err), Some(TransportError::Closed))
    }
}

/// Point-in-time view of a transport's byte meters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MeterSnapshot {
    /// Bytes sent through this transport since the last reset.
    pub sent: u64,
    /// Bytes received since the last reset.
    pub recv: u64,
    /// Messages transferred in either direction since the last reset
    /// (each send and each recv counts one).
    pub messages: u64,
}

/// A bidirectional, metered, message-oriented byte channel.
///
/// Implementations preserve message boundaries (one `send` is one `recv`
/// on the far side) and meter every transfer through a [`CommMeter`].
/// What the meter counts is the implementation's wire truth: the
/// in-process channel counts payload bytes, TCP counts payload plus its
/// frame header — so per-transport byte reports stay honest rather than
/// artificially identical.
pub trait Transport: Send {
    /// Send one message.
    fn send(&self, msg: Vec<u8>) -> Result<()>;
    /// Receive the next message, blocking indefinitely.
    fn recv(&self) -> Result<Vec<u8>>;
    /// Receive the next message, failing if none arrives within `timeout`.
    fn recv_timeout(&self, timeout: Duration) -> Result<Vec<u8>>;
    /// This transport's byte meter (shared, resettable).
    fn meter(&self) -> &Arc<CommMeter>;
    /// Snapshot the meter's current counters.
    fn snapshot(&self) -> MeterSnapshot {
        let m = self.meter();
        MeterSnapshot {
            sent: m.sent(),
            recv: m.recv(),
            messages: m.messages(),
        }
    }
}

/// Boxed transport — the form the runtime and servers hold links in.
pub type BoxTransport = Box<dyn Transport>;

/// The in-process transport: a latency/bandwidth-simulating
/// [`Endpoint`] behind the [`Transport`] trait. Byte-for-byte identical
/// to using the endpoint directly — the trait adds no envelope.
pub struct InProc(pub Endpoint);

impl Transport for InProc {
    fn send(&self, msg: Vec<u8>) -> Result<()> {
        self.0.send(msg)
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.0.recv()
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Vec<u8>> {
        self.0.recv_timeout(timeout)
    }

    fn meter(&self) -> &Arc<CommMeter> {
        &self.0.meter
    }
}

// ---- handshake ---------------------------------------------------------

/// Handshake magic — the first bytes a dialler sends on any connection.
pub const TRANSPORT_MAGIC: [u8; 4] = *b"FSLT";
/// Handshake/transport protocol version. Bump on incompatible changes to
/// the hello, ack, or control-plane encodings. Version 2 added per-round
/// upload deadlines to round commands and per-client outcomes to round
/// replies; version 3 added multiplexed client links ([`Role::ClientMux`])
/// carrying a contiguous range of virtual clients over one socket.
/// (The [`Role::Stats`] scrape role was added under version 3 without a
/// bump: it introduces a new role *tag*, which old servers already
/// reject cleanly as unknown, and changes no existing encoding.)
pub const TRANSPORT_VERSION: u16 = 3;

/// What a dialling connection claims to be.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Role {
    /// The driver's control channel. Carries the deployment shape so the
    /// server can size its accept loop and reject mismatched payloads:
    /// `max_clients` data links will follow, rounds run over a session
    /// with model size `m` and submodel size `k`, and round payloads are
    /// group `group` (the driver's `G` type name — both sides must be
    /// built from the same crate version, which [`TRANSPORT_VERSION`]
    /// guards).
    Control {
        max_clients: u32,
        m: u64,
        k: u64,
        group: String,
    },
    /// Client `id`'s data link (one per client per server).
    Client { id: u32 },
    /// The other server's `S_0 ↔ S_1` exchange link.
    Peer,
    /// A multiplexed client link: one socket carrying the uploads of the
    /// `count` virtual clients `[lo, lo + count)`. Every data frame on a
    /// mux link is prefixed with the 4-byte LE virtual-client id it
    /// belongs to. This is how a loadgen-scale cohort (10^4–10^6 virtual
    /// clients) fits a bounded socket pool instead of one fd per client.
    ClientMux { lo: u32, count: u32 },
    /// A metrics scrape connection (`fsl stats`). Served out-of-band by
    /// the standalone server's stats responder — never enters the round
    /// state machine, so a scrape cannot perturb lanes mid-round. The
    /// ack echoes the *dialler's* `party` byte (a scraper addresses a
    /// socket, not a party).
    Stats,
}

/// The versioned handshake a dialler opens every connection with: magic,
/// version, which server it believes it dialled, and its [`Role`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    /// The party (0 or 1) the dialler intends to talk to — lets a server
    /// reject a driver that swapped its two addresses.
    pub party: u8,
    pub role: Role,
}

impl Hello {
    /// Serialise: magic + version + party + role tag + role fields.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32);
        out.extend_from_slice(&TRANSPORT_MAGIC);
        out.extend_from_slice(&TRANSPORT_VERSION.to_le_bytes());
        out.push(self.party);
        match &self.role {
            Role::Control {
                max_clients,
                m,
                k,
                group,
            } => {
                out.push(0);
                out.extend_from_slice(&max_clients.to_le_bytes());
                out.extend_from_slice(&m.to_le_bytes());
                out.extend_from_slice(&k.to_le_bytes());
                out.extend_from_slice(&(group.len() as u32).to_le_bytes());
                out.extend_from_slice(group.as_bytes());
            }
            Role::Client { id } => {
                out.push(1);
                out.extend_from_slice(&id.to_le_bytes());
            }
            Role::Peer => out.push(2),
            Role::ClientMux { lo, count } => {
                out.push(3);
                out.extend_from_slice(&lo.to_le_bytes());
                out.extend_from_slice(&count.to_le_bytes());
            }
            Role::Stats => out.push(4),
        }
        out
    }

    /// Parse an encoded hello, with actionable errors for foreign traffic
    /// (wrong magic) and version skew.
    pub fn decode(bytes: &[u8]) -> Result<Hello> {
        let magic = bytes
            .get(..4)
            .ok_or_else(|| anyhow!("handshake shorter than its magic"))?;
        if magic != TRANSPORT_MAGIC {
            bail!(
                "bad handshake magic {magic:02x?}: the peer is not an fsl transport \
                 (expected {TRANSPORT_MAGIC:02x?})"
            );
        }
        let version = read_u16(bytes, 4)?;
        if version != TRANSPORT_VERSION {
            bail!(
                "handshake version {version} but this build speaks {TRANSPORT_VERSION}: \
                 rebuild both sides from the same source"
            );
        }
        let party = *bytes.get(6).ok_or_else(short)?;
        let role = match *bytes.get(7).ok_or_else(short)? {
            0 => {
                let max_clients = read_u32(bytes, 8)?;
                let m = read_u64(bytes, 12)?;
                let k = read_u64(bytes, 20)?;
                let glen = read_u32(bytes, 28)? as usize;
                let group = std::str::from_utf8(bytes.get(32..32 + glen).ok_or_else(short)?)
                    .map_err(|_| anyhow!("handshake group name is not UTF-8"))?
                    .to_string();
                Role::Control {
                    max_clients,
                    m,
                    k,
                    group,
                }
            }
            1 => Role::Client {
                id: read_u32(bytes, 8)?,
            },
            2 => Role::Peer,
            3 => Role::ClientMux {
                lo: read_u32(bytes, 8)?,
                count: read_u32(bytes, 12)?,
            },
            4 => Role::Stats,
            t => bail!("unknown handshake role tag {t}"),
        };
        Ok(Hello { party, role })
    }
}

fn short() -> anyhow::Error {
    anyhow!("truncated handshake")
}

/// Bounds-checked little-endian reads for handshake parsing: `short()` on
/// truncation, with no panicking conversion left on the success path.
fn read_u16(bytes: &[u8], at: usize) -> Result<u16> {
    match bytes.get(at..at + 2) {
        Some(&[a, b]) => Ok(u16::from_le_bytes([a, b])),
        _ => Err(short()),
    }
}

fn read_u32(bytes: &[u8], at: usize) -> Result<u32> {
    match bytes.get(at..at + 4) {
        Some(&[a, b, c, d]) => Ok(u32::from_le_bytes([a, b, c, d])),
        _ => Err(short()),
    }
}

fn read_u64(bytes: &[u8], at: usize) -> Result<u64> {
    match bytes.get(at..at + 8) {
        Some(&[a, b, c, d, e, f, g, h]) => Ok(u64::from_le_bytes([a, b, c, d, e, f, g, h])),
        _ => Err(short()),
    }
}

/// The accepting side's handshake reply: its party id and, on rejection,
/// why (so the dialler's error says "party mismatch", not "EOF").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HelloAck {
    pub party: u8,
    /// `None` = accepted; `Some(reason)` = rejected (connection closes).
    pub error: Option<String>,
}

impl HelloAck {
    /// Serialise: magic + version + party + status + error string.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        out.extend_from_slice(&TRANSPORT_MAGIC);
        out.extend_from_slice(&TRANSPORT_VERSION.to_le_bytes());
        out.push(self.party);
        match &self.error {
            None => out.push(0),
            Some(e) => {
                out.push(1);
                out.extend_from_slice(&(e.len() as u32).to_le_bytes());
                out.extend_from_slice(e.as_bytes());
            }
        }
        out
    }

    /// Parse an encoded ack (validating magic *and* version — a
    /// version-skewed server's ack must fail with the rebuild message,
    /// not be misparsed into a garbled rejection).
    pub fn decode(bytes: &[u8]) -> Result<HelloAck> {
        if bytes.get(..4).ok_or_else(short)? != TRANSPORT_MAGIC {
            bail!("bad handshake-ack magic: the peer is not an fsl transport");
        }
        let version = read_u16(bytes, 4)?;
        if version != TRANSPORT_VERSION {
            bail!(
                "handshake-ack version {version} but this build speaks {TRANSPORT_VERSION}: \
                 rebuild both sides from the same source"
            );
        }
        let party = *bytes.get(6).ok_or_else(short)?;
        let error = match *bytes.get(7).ok_or_else(short)? {
            0 => None,
            _ => {
                let len = read_u32(bytes, 8)? as usize;
                Some(
                    String::from_utf8_lossy(bytes.get(12..12 + len).ok_or_else(short)?)
                        .into_owned(),
                )
            }
        };
        Ok(HelloAck { party, error })
    }
}

/// The accepting half of a transport: yields connections tagged with the
/// dialler's (already magic/version-validated) [`Hello`]. Role validation
/// and the [`HelloAck`] are the accepting *server's* job — the listener
/// cannot know which roles are still expected.
pub trait Listener: Send {
    /// Block until the next connection completes its handshake.
    fn accept(&self) -> Result<(BoxTransport, Hello)>;
}

// ---- in-process listener (trait-completeness + tests) ------------------

/// In-process [`Listener`]: accepts connections made through the paired
/// [`InProcConnector`]. Exists so the trait pair is exercised end-to-end
/// without sockets; the runtime's single-process builder wires its
/// topology directly (same endpoints, no accept loop).
pub struct InProcListener {
    rx: std::sync::mpsc::Receiver<(InProc, Hello)>,
}

/// Dialling half of [`InProcListener`]. Cloneable across threads.
#[derive(Clone)]
pub struct InProcConnector {
    tx: std::sync::mpsc::Sender<(InProc, Hello)>,
    profile: crate::net::LinkProfile,
}

/// Create a connected in-process listener/connector pair whose links all
/// share `profile`.
pub fn in_proc_listener(
    profile: crate::net::LinkProfile,
) -> (InProcListener, InProcConnector) {
    let (tx, rx) = std::sync::mpsc::channel();
    (InProcListener { rx }, InProcConnector { tx, profile })
}

impl InProcConnector {
    /// Open a new link, announcing `hello` to the accepting side.
    pub fn connect(&self, hello: Hello) -> Result<InProc> {
        let (a, b) = crate::net::pair_profile(self.profile);
        self.tx
            .send((InProc(b), hello))
            .map_err(|_| anyhow!("in-process listener has shut down"))?;
        Ok(InProc(a))
    }
}

impl Listener for InProcListener {
    fn accept(&self) -> Result<(BoxTransport, Hello)> {
        let (conn, hello) = self
            .rx
            .recv()
            .map_err(|_| anyhow!("all in-process connectors dropped"))?;
        Ok((Box::new(conn), hello))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkProfile;

    #[test]
    fn hello_roundtrips_every_role() {
        for hello in [
            Hello {
                party: 0,
                role: Role::Control {
                    max_clients: 7,
                    m: 1 << 20,
                    k: 512,
                    group: "u64".into(),
                },
            },
            Hello { party: 1, role: Role::Client { id: 3 } },
            Hello { party: 0, role: Role::Peer },
            Hello { party: 1, role: Role::ClientMux { lo: 4096, count: 1 << 16 } },
            Hello { party: 0, role: Role::Stats },
        ] {
            assert_eq!(Hello::decode(&hello.encode()).unwrap(), hello);
        }
    }

    #[test]
    fn hello_rejects_foreign_and_stale_traffic() {
        let err = Hello::decode(b"GET / HTTP/1.1\r\n").unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        let mut stale = Hello { party: 0, role: Role::Peer }.encode();
        stale[4] = 99; // version
        let err = Hello::decode(&stale).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
        for cut in 0..stale.len() {
            assert!(Hello::decode(&stale[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn ack_roundtrips() {
        for ack in [
            HelloAck { party: 1, error: None },
            HelloAck { party: 0, error: Some("party mismatch".into()) },
        ] {
            assert_eq!(HelloAck::decode(&ack.encode()).unwrap(), ack);
        }
        // A version-skewed ack is rejected with the rebuild message, not
        // misparsed into a garbled party/status.
        let mut stale = HelloAck { party: 1, error: None }.encode();
        stale[4] = 9;
        let err = HelloAck::decode(&stale).unwrap_err().to_string();
        assert!(err.contains("version 9"), "{err}");
    }

    #[test]
    fn in_proc_listener_pairs_connections() {
        let (listener, connector) = in_proc_listener(LinkProfile::latency_only(Duration::ZERO));
        let h = std::thread::spawn(move || {
            let (conn, hello) = listener.accept().unwrap();
            assert_eq!(hello.role, Role::Client { id: 5 });
            let got = conn.recv().unwrap();
            conn.send(got.iter().rev().copied().collect()).unwrap();
        });
        let conn = connector
            .connect(Hello { party: 0, role: Role::Client { id: 5 } })
            .unwrap();
        conn.send(vec![1, 2, 3]).unwrap();
        assert_eq!(conn.recv().unwrap(), vec![3, 2, 1]);
        assert_eq!(conn.snapshot().sent, 3);
        assert_eq!(conn.snapshot().recv, 3);
        h.join().unwrap();
    }
}
