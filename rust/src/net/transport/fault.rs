//! Deterministic fault injection for transports.
//!
//! A [`FaultPlan`] describes how one client's links misbehave — added
//! send latency, going silent after a byte budget, or disconnecting
//! mid-upload — and [`FaultInjector::wrap`] applies the plan to any
//! [`BoxTransport`], so the same failure scenario runs unchanged over
//! the in-process channels and real TCP sockets. One injector is shared
//! across all of a client's links: its byte/message budgets span the
//! client's whole upload, which is what lets a plan cut a client *between*
//! its short (to `S_1`) and long (to `S_0`) SSA messages and exercise the
//! servers' cohort agreement.
//!
//! Faults are injected on the *send* side only: a disconnect drops the
//! wrapped transport (closing the socket / channel, so the far side sees
//! [`TransportError::Closed`]), a mute swallows the message (the far side
//! sees silence and classifies the client a straggler).

use super::{BoxTransport, MeterSnapshot, Transport, TransportError};
use crate::metrics::CommMeter;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A deterministic misbehaviour script for one client's links.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Sleep this long before every send (a slow client / congested path).
    pub send_delay: Option<Duration>,
    /// After this many bytes have been offered for sending, swallow all
    /// further sends: the client believes it is uploading, the servers
    /// see silence (a straggler).
    pub mute_after_bytes: Option<u64>,
    /// After this many bytes have been offered for sending, drop the
    /// underlying transport: the servers see a closed link (a crash).
    pub disconnect_after_bytes: Option<u64>,
    /// Disconnect after this many whole messages have been sent.
    pub disconnect_after_messages: Option<u64>,
}

impl FaultPlan {
    /// An empty plan (no faults).
    pub fn new() -> Self {
        Self::default()
    }

    /// Add latency to every send.
    pub fn delay(mut self, d: Duration) -> Self {
        self.send_delay = Some(d);
        self
    }

    /// Go silent once `bytes` bytes have been offered for sending.
    pub fn mute_after(mut self, bytes: u64) -> Self {
        self.mute_after_bytes = Some(bytes);
        self
    }

    /// Disconnect once `bytes` bytes have been offered for sending.
    pub fn disconnect_after(mut self, bytes: u64) -> Self {
        self.disconnect_after_bytes = Some(bytes);
        self
    }

    /// Disconnect after `messages` whole messages have been sent.
    pub fn disconnect_after_messages(mut self, messages: u64) -> Self {
        self.disconnect_after_messages = Some(messages);
        self
    }

    /// Turn the plan into an injector whose budgets are shared by every
    /// transport it wraps.
    pub fn injector(self) -> FaultInjector {
        FaultInjector {
            shared: Arc::new(FaultShared {
                plan: self,
                sent_bytes: AtomicU64::new(0),
                sent_messages: AtomicU64::new(0),
                alive: AtomicBool::new(true),
            }),
        }
    }
}

struct FaultShared {
    plan: FaultPlan,
    sent_bytes: AtomicU64,
    sent_messages: AtomicU64,
    alive: AtomicBool,
}

/// Applies one [`FaultPlan`] to any number of transports, with shared
/// byte/message budgets (clone freely; clones share state).
#[derive(Clone)]
pub struct FaultInjector {
    shared: Arc<FaultShared>,
}

impl FaultInjector {
    /// Wrap a transport so it follows this injector's plan.
    pub fn wrap(&self, inner: BoxTransport) -> BoxTransport {
        let meter = Arc::clone(inner.meter());
        Box::new(FaultTransport {
            inner: Mutex::new(Some(inner)),
            meter,
            shared: Arc::clone(&self.shared),
        })
    }
}

/// A transport decorated with injected faults. The meter is the wrapped
/// transport's own (cloned at wrap time so reports survive a simulated
/// disconnect); swallowed sends are deliberately unmetered — they never
/// crossed the wire.
struct FaultTransport {
    inner: Mutex<Option<BoxTransport>>,
    meter: Arc<CommMeter>,
    shared: Arc<FaultShared>,
}

impl FaultTransport {
    /// Lock the wrapped link. A poisoned mutex means some thread panicked
    /// mid-operation; the `Option` inside is still coherent (it only ever
    /// holds a whole transport or `None`), so recover the guard instead of
    /// cascading the panic — a torn underlying transport surfaces its own
    /// [`TransportError::Closed`] on the next send/recv.
    fn link(&self) -> std::sync::MutexGuard<'_, Option<BoxTransport>> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Drop the wrapped transport, closing the underlying socket/channel.
    fn sever(&self) -> anyhow::Error {
        self.shared.alive.store(false, Ordering::SeqCst);
        *self.link() = None;
        TransportError::Closed.into()
    }
}

impl Transport for FaultTransport {
    fn send(&self, msg: Vec<u8>) -> Result<()> {
        let plan = &self.shared.plan;
        if let Some(d) = plan.send_delay {
            std::thread::sleep(d);
        }
        if !self.shared.alive.load(Ordering::SeqCst) {
            return Err(self.sever());
        }
        let bytes = self
            .shared
            .sent_bytes
            .fetch_add(msg.len() as u64, Ordering::SeqCst)
            + msg.len() as u64;
        let messages = self.shared.sent_messages.fetch_add(1, Ordering::SeqCst) + 1;
        if plan.disconnect_after_bytes.is_some_and(|b| bytes > b)
            || plan.disconnect_after_messages.is_some_and(|m| messages > m)
        {
            return Err(self.sever());
        }
        if plan.mute_after_bytes.is_some_and(|b| bytes > b) {
            return Ok(()); // swallowed: the far side sees a straggler
        }
        match &*self.link() {
            Some(t) => t.send(msg),
            None => Err(TransportError::Closed.into()),
        }
    }

    fn recv(&self) -> Result<Vec<u8>> {
        if !self.shared.alive.load(Ordering::SeqCst) {
            return Err(self.sever());
        }
        match &*self.link() {
            Some(t) => t.recv(),
            None => Err(TransportError::Closed.into()),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Vec<u8>> {
        if !self.shared.alive.load(Ordering::SeqCst) {
            return Err(self.sever());
        }
        match &*self.link() {
            Some(t) => t.recv_timeout(timeout),
            None => Err(TransportError::Closed.into()),
        }
    }

    fn meter(&self) -> &Arc<CommMeter> {
        &self.meter
    }

    fn snapshot(&self) -> MeterSnapshot {
        MeterSnapshot {
            sent: self.meter.sent(),
            recv: self.meter.recv(),
            messages: self.meter.messages(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::InProc;
    use crate::net::{self};

    fn wrapped_pair(plan: FaultPlan) -> (BoxTransport, net::Endpoint) {
        let (a, b) = net::pair(Duration::ZERO);
        let inj = plan.injector();
        (inj.wrap(Box::new(InProc(a))), b)
    }

    #[test]
    fn disconnect_after_bytes_severs_both_directions() {
        let (t, peer) = wrapped_pair(FaultPlan::new().disconnect_after(4));
        t.send(vec![1, 2, 3]).unwrap();
        assert_eq!(peer.recv().unwrap(), vec![1, 2, 3]);
        let err = t.send(vec![4, 5]).unwrap_err();
        assert!(TransportError::is_closed(&err), "{err:?}");
        // The wrapped endpoint was dropped: the peer now sees Closed too.
        let err = peer.recv().unwrap_err();
        assert!(TransportError::is_closed(&err), "{err:?}");
        // And our own later receives fail closed rather than hanging.
        assert!(TransportError::is_closed(&t.recv().unwrap_err()));
    }

    #[test]
    fn disconnect_after_messages_counts_whole_sends() {
        let (t, peer) = wrapped_pair(FaultPlan::new().disconnect_after_messages(2));
        t.send(vec![9]).unwrap();
        t.send(vec![9, 9]).unwrap();
        assert!(TransportError::is_closed(&t.send(vec![9]).unwrap_err()));
        assert_eq!(peer.recv().unwrap(), vec![9]);
        assert_eq!(peer.recv().unwrap(), vec![9, 9]);
        assert!(peer.recv().is_err());
    }

    #[test]
    fn mute_swallows_without_closing() {
        let (t, peer) = wrapped_pair(FaultPlan::new().mute_after(2));
        t.send(vec![1, 2]).unwrap();
        t.send(vec![3, 4]).unwrap(); // swallowed
        assert_eq!(peer.recv().unwrap(), vec![1, 2]);
        let err = peer.recv_timeout(Duration::from_millis(30)).unwrap_err();
        assert!(TransportError::is_timeout(&err), "{err:?}");
        // Metering reflects only what crossed the wire.
        assert_eq!(t.snapshot().sent, 2);
    }

    #[test]
    fn budgets_span_all_wrapped_links() {
        let (a0, b0) = net::pair(Duration::ZERO);
        let (a1, b1) = net::pair(Duration::ZERO);
        let inj = FaultPlan::new().disconnect_after(3).injector();
        let l0 = inj.wrap(Box::new(InProc(a0)));
        let l1 = inj.wrap(Box::new(InProc(a1)));
        l0.send(vec![1, 2, 3]).unwrap();
        // The second link's first send already exceeds the shared budget.
        assert!(TransportError::is_closed(&l1.send(vec![4]).unwrap_err()));
        assert_eq!(b0.recv().unwrap(), vec![1, 2, 3]);
        assert!(b1.recv().is_err());
    }
}
