//! Framed TCP transport: the real-socket implementation of
//! [`Transport`]/[`Listener`].
//!
//! TCP is a byte stream with no message boundaries, so every message —
//! handshake included — travels as one [`crate::protocol::msg`] frame
//! (magic + version + length + payload, bounded by
//! [`crate::protocol::msg::MAX_FRAME_LEN`]). The per-connection
//! [`CommMeter`] counts *wire* bytes (payload plus frame header): byte
//! reports over TCP reflect what actually crossed the socket, which is
//! the honest comparison against the header-less in-process channels.
//!
//! A connection opens with a [`Hello`] handshake and waits for the
//! accepting server's [`HelloAck`], so dialling the wrong server, a stale
//! binary, or a non-fsl port fails with a readable error before any
//! protocol traffic moves.

use super::{BoxTransport, Hello, HelloAck, Listener, Transport, TransportError};
use crate::metrics::CommMeter;
use crate::protocol::msg;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Socket knobs shared by both ends of a connection.
#[derive(Debug, Clone)]
pub struct TcpOptions {
    /// How long a handshake side waits for the other's hello/ack.
    pub handshake_timeout: Duration,
    /// Kernel-level write timeout for every frame (None = block forever).
    pub write_timeout: Option<Duration>,
}

impl Default for TcpOptions {
    fn default() -> Self {
        TcpOptions {
            handshake_timeout: Duration::from_secs(10),
            write_timeout: Some(Duration::from_secs(600)),
        }
    }
}

/// One framed TCP connection. Reads and writes go through independent
/// cloned handles (full duplex), each behind its own lock so a transport
/// can be driven from the trait's `&self` methods.
pub struct TcpTransport {
    reader: Mutex<TcpStream>,
    writer: Mutex<TcpStream>,
    meter: Arc<CommMeter>,
}

impl TcpTransport {
    /// Wrap an accepted or connected stream (applies `opts`, disables
    /// Nagle — the protocol is strictly request/response and latency
    /// matters more than tinygram counts).
    pub fn from_stream(stream: TcpStream, opts: &TcpOptions) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        stream
            .set_write_timeout(opts.write_timeout)
            .context("set_write_timeout")?;
        let reader = stream.try_clone().context("cloning stream for reads")?;
        Ok(TcpTransport {
            reader: Mutex::new(reader),
            writer: Mutex::new(stream),
            meter: CommMeter::shared(),
        })
    }

    /// A fresh OS-level clone of the underlying stream (another fd on the
    /// same socket). The readiness reactor registers these clones so it
    /// can poll a connection non-blockingly while the transport keeps its
    /// own blocking handles for framed sends.
    pub fn stream_clone(&self) -> Result<TcpStream> {
        let stream = self
            .reader
            .lock()
            .map_err(|_| anyhow::Error::new(TransportError::Closed).context("tcp reader poisoned"))?;
        stream.try_clone().context("cloning stream for the reactor")
    }

    /// Dial `addr`, run the `hello` handshake, and wait for the server's
    /// ack — every step (the TCP connection itself included: a
    /// black-holed address must not block for the OS's multi-minute SYN
    /// retry default) bounded by `opts.handshake_timeout`. A rejecting
    /// server closes the connection after its ack, and the reason it
    /// sent becomes this function's error.
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        hello: &Hello,
        opts: &TcpOptions,
    ) -> Result<Self> {
        let resolved = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr:?}"))?
            .next()
            .ok_or_else(|| anyhow!("{addr:?} resolved to no address"))?;
        let stream = TcpStream::connect_timeout(&resolved, opts.handshake_timeout)
            .with_context(|| format!("connecting to {addr:?}"))?;
        let conn = Self::from_stream(stream, opts)?;
        conn.send(hello.encode())
            .map_err(|e| e.context(format!("sending handshake to {addr:?}")))?;
        let ack_bytes = conn
            .recv_timeout(opts.handshake_timeout)
            .map_err(|e| e.context(format!("waiting for handshake ack from {addr:?}")))?;
        let ack = HelloAck::decode(&ack_bytes)?;
        if let Some(reason) = ack.error {
            // Typed as Rejected so reconnect/backoff paths know this is
            // permanent — a deliberate refusal, not a flaky network.
            let ctx = format!(
                "server S{} at {addr:?} rejected the connection: {reason}",
                ack.party
            );
            return Err(anyhow::Error::new(TransportError::Rejected(reason)).context(ctx));
        }
        if ack.party != hello.party {
            bail!(
                "dialled S{} at {addr:?} but a server identifying as S{} answered: \
                 the two server addresses are probably swapped",
                hello.party,
                ack.party
            );
        }
        Ok(conn)
    }

    /// Read exactly one frame off `stream`. On a read timeout the stream
    /// may be left mid-frame — callers treat a timeout as fatal for the
    /// connection (the runtime poisons itself), never as retryable.
    fn read_frame(stream: &mut TcpStream, meter: &CommMeter) -> Result<Vec<u8>> {
        let mut header = [0u8; msg::FRAME_HEADER_LEN];
        stream.read_exact(&mut header).map_err(map_io)?;
        let len = msg::frame_payload_len(&header)?;
        let mut payload = vec![0u8; len];
        stream.read_exact(&mut payload).map_err(map_io)?;
        meter.record_recv(msg::FRAME_HEADER_LEN + len);
        Ok(payload)
    }

    fn recv_with(&self, timeout: Option<Duration>) -> Result<Vec<u8>> {
        // A poisoned lock means a peer thread panicked mid-read; the stream
        // may be mid-frame, so surface the typed close instead of a panic.
        let mut stream = self
            .reader
            .lock()
            .map_err(|_| anyhow::Error::new(TransportError::Closed).context("tcp reader poisoned"))?;
        stream.set_read_timeout(timeout).context("set_read_timeout")?;
        let out = Self::read_frame(&mut stream, &self.meter);
        // Best-effort restore so a later plain recv() blocks again.
        let _ = stream.set_read_timeout(None);
        out
    }
}

/// Map IO failures to the typed [`TransportError`] vocabulary (EOF and
/// resets = peer closed; a read timeout names itself so runtime poisoning
/// messages stay actionable).
fn map_io(e: std::io::Error) -> anyhow::Error {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::UnexpectedEof
        | ErrorKind::ConnectionReset
        | ErrorKind::ConnectionAborted
        | ErrorKind::BrokenPipe => TransportError::Closed.into(),
        ErrorKind::WouldBlock | ErrorKind::TimedOut => TransportError::Timeout.into(),
        _ => anyhow!("tcp read failed: {e}"),
    }
}

impl Transport for TcpTransport {
    fn send(&self, payload: Vec<u8>) -> Result<()> {
        if payload.len() > msg::MAX_FRAME_LEN {
            bail!(
                "message of {} bytes exceeds the {}-byte frame ceiling",
                payload.len(),
                msg::MAX_FRAME_LEN
            );
        }
        let framed = msg::frame(&payload);
        // As with the reader: a panicked writer thread may have torn a
        // frame, so the link is unusable — report it as closed.
        let mut stream = self
            .writer
            .lock()
            .map_err(|_| anyhow::Error::new(TransportError::Closed).context("tcp writer poisoned"))?;
        stream.write_all(&framed).map_err(|e| match e.kind() {
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                anyhow::Error::new(TransportError::Timeout).context("timed out writing a frame")
            }
            std::io::ErrorKind::BrokenPipe | std::io::ErrorKind::ConnectionReset => {
                TransportError::Closed.into()
            }
            _ => anyhow!("tcp write failed: {e}"),
        })?;
        self.meter.record_send(framed.len());
        Ok(())
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.recv_with(None)
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Vec<u8>> {
        self.recv_with(Some(timeout))
    }

    fn meter(&self) -> &Arc<CommMeter> {
        &self.meter
    }
}

/// The accepting side: wraps a bound [`TcpListener`], yielding one
/// handshake-validated [`TcpTransport`] per [`Listener::accept`].
pub struct TcpAcceptor {
    listener: TcpListener,
    opts: TcpOptions,
}

impl TcpAcceptor {
    /// Wrap an already-bound listener (bind to port 0 for an ephemeral
    /// port, then read it back with [`TcpAcceptor::local_addr`]).
    pub fn new(listener: TcpListener, opts: TcpOptions) -> Self {
        TcpAcceptor { listener, opts }
    }

    /// Bind `addr` and wrap the listener.
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(addr: A, opts: TcpOptions) -> Result<Self> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr:?}"))?;
        Ok(Self::new(listener, opts))
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Run the dialler's handshake on a freshly accepted stream.
    fn handshake(
        &self,
        stream: TcpStream,
        from: std::net::SocketAddr,
    ) -> Result<(BoxTransport, Hello)> {
        let conn = TcpTransport::from_stream(stream, &self.opts)?;
        let hello_bytes = conn
            .recv_timeout(self.opts.handshake_timeout)
            .map_err(|e| e.context(format!("waiting for handshake from {from}")))?;
        let hello = Hello::decode(&hello_bytes)
            .map_err(|e| e.context(format!("handshake from {from}")))?;
        Ok((Box::new(conn), hello))
    }

    /// Accept one raw stream without blocking and without running the
    /// handshake: returns `Ok(None)` when no connection is pending. The
    /// reactor-driven accept loop uses this so a dialler that connects
    /// but never sends its hello (a slow-loris) parks in the frame pump
    /// under its own deadline instead of wedging the accept thread.
    pub fn accept_raw(&self) -> Result<Option<(TcpStream, std::net::SocketAddr)>> {
        self.listener
            .set_nonblocking(true)
            .context("set_nonblocking")?;
        let accepted = self.listener.accept();
        let _ = self.listener.set_nonblocking(false);
        match accepted {
            Ok((stream, from)) => Ok(Some((stream, from))),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(anyhow::Error::new(e).context("tcp accept")),
        }
    }

    /// The acceptor's socket options (shared with every accepted stream).
    pub fn options(&self) -> &TcpOptions {
        &self.opts
    }

    /// Like [`Listener::accept`] but bounded: returns `Ok(None)` if no
    /// connection *arrives* within `timeout` (a server waiting out its
    /// accept phase must notice a vanished driver instead of parking on
    /// a blocking accept forever). The listener is polled nonblocking
    /// for the wait and restored after; the accepted stream is put back
    /// into blocking mode before its handshake (it can inherit the
    /// listener's nonblocking state on some platforms).
    pub fn accept_timeout(&self, timeout: Duration) -> Result<Option<(BoxTransport, Hello)>> {
        let deadline = std::time::Instant::now() + timeout;
        self.listener
            .set_nonblocking(true)
            .context("set_nonblocking")?;
        let accepted = loop {
            match self.listener.accept() {
                Ok(pair) => break Ok(Some(pair)),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if std::time::Instant::now() >= deadline {
                        break Ok(None);
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => break Err(e),
            }
        };
        let _ = self.listener.set_nonblocking(false);
        match accepted.context("tcp accept")? {
            None => Ok(None),
            Some((stream, from)) => {
                stream
                    .set_nonblocking(false)
                    .context("restoring blocking mode")?;
                self.handshake(stream, from).map(Some)
            }
        }
    }
}

impl Listener for TcpAcceptor {
    /// Accept the next connection and read its hello. Magic/version are
    /// validated here; *role* validation (and sending the [`HelloAck`])
    /// is the server's job, which knows what it still expects.
    fn accept(&self) -> Result<(BoxTransport, Hello)> {
        let (stream, from) = self.listener.accept().context("tcp accept")?;
        self.handshake(stream, from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::Role;

    fn loopback_acceptor() -> TcpAcceptor {
        TcpAcceptor::bind("127.0.0.1:0", TcpOptions::default()).unwrap()
    }

    #[test]
    fn framed_roundtrip_over_loopback() {
        let acceptor = loopback_acceptor();
        let addr = acceptor.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (conn, hello) = acceptor.accept().unwrap();
            assert_eq!(hello.role, Role::Peer);
            conn.send(HelloAck { party: 0, error: None }.encode()).unwrap();
            let m = conn.recv().unwrap();
            conn.send(m.iter().map(|b| b ^ 0xff).collect()).unwrap();
            // Message boundaries survive the stream: two sends, two recvs.
            conn.send(vec![1]).unwrap();
            conn.send(vec![2, 2]).unwrap();
        });
        let conn = TcpTransport::connect(
            addr,
            &Hello { party: 0, role: Role::Peer },
            &TcpOptions::default(),
        )
        .unwrap();
        conn.send(vec![0x0f, 0xf0]).unwrap();
        assert_eq!(conn.recv().unwrap(), vec![0xf0, 0x0f]);
        assert_eq!(conn.recv().unwrap(), vec![1]);
        assert_eq!(conn.recv().unwrap(), vec![2, 2]);
        // Wire metering counts the frame header too.
        let snap = conn.snapshot();
        assert_eq!(
            snap.sent as usize,
            2 * msg::FRAME_HEADER_LEN + Hello { party: 0, role: Role::Peer }.encode().len() + 2
        );
        server.join().unwrap();
    }

    #[test]
    fn recv_timeout_on_wedged_peer() {
        let acceptor = loopback_acceptor();
        let addr = acceptor.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (conn, _hello) = acceptor.accept().unwrap();
            conn.send(HelloAck { party: 1, error: None }.encode()).unwrap();
            // Wedge: hold the connection open, send nothing.
            std::thread::sleep(Duration::from_millis(400));
        });
        let conn = TcpTransport::connect(
            addr,
            &Hello { party: 1, role: Role::Peer },
            &TcpOptions::default(),
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let err = conn.recv_timeout(Duration::from_millis(100)).unwrap_err();
        assert!(TransportError::is_timeout(&err), "not typed Timeout: {err:?}");
        assert!(err.to_string().contains("timed out"), "{err}");
        assert!(t0.elapsed() < Duration::from_millis(350));
        server.join().unwrap();
    }

    #[test]
    fn rejected_handshake_carries_the_reason() {
        let acceptor = loopback_acceptor();
        let addr = acceptor.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (conn, _hello) = acceptor.accept().unwrap();
            conn.send(
                HelloAck { party: 0, error: Some("party mismatch: dialled S1".into()) }.encode(),
            )
            .unwrap();
        });
        let err = TcpTransport::connect(
            addr,
            &Hello { party: 0, role: Role::Peer },
            &TcpOptions::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("party mismatch"), "{err}");
        // Typed as a permanent rejection (reconnect loops must not retry).
        assert!(
            matches!(TransportError::of(&err), Some(TransportError::Rejected(r)) if r.contains("party mismatch")),
            "not typed Rejected: {err:?}"
        );
        server.join().unwrap();
    }

    #[test]
    fn non_fsl_peer_fails_fast() {
        // A "server" that talks something else entirely: the dialler's
        // ack wait must fail on the frame magic, not hang or misparse.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut buf = [0u8; 64];
            let _ = stream.read(&mut buf);
            let _ = stream.write_all(b"HTTP/1.1 400 Bad Request\r\n\r\n");
        });
        let err = TcpTransport::connect(
            addr,
            &Hello { party: 0, role: Role::Peer },
            &TcpOptions::default(),
        )
        .unwrap_err();
        let chain = format!("{err:?}"); // Debug shows the whole cause chain
        assert!(chain.contains("magic"), "{chain}");
        server.join().unwrap();
    }
}
