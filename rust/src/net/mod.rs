//! Simulated secure P2P channels (§2 assumes authenticated encrypted
//! channels client↔S0, client↔S1, S0↔S1; §7 runs on a ≈3ms LAN).
//!
//! In-process `mpsc` channels carry length-delimited byte messages, meter
//! every transfer through [`crate::metrics::CommMeter`], and optionally
//! inject the paper's LAN latency so end-to-end round times are honest.

use crate::metrics::CommMeter;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

/// One endpoint of a bidirectional metered channel.
pub struct Endpoint {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    pub meter: Arc<CommMeter>,
    latency: Duration,
}

impl Endpoint {
    /// Send a message (blocking enqueue + simulated one-way latency).
    pub fn send(&self, msg: Vec<u8>) -> anyhow::Result<()> {
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
        self.meter.record_send(msg.len());
        self.tx
            .send(msg)
            .map_err(|_| anyhow::anyhow!("channel closed"))
    }

    /// Receive the next message (blocking).
    pub fn recv(&self) -> anyhow::Result<Vec<u8>> {
        let msg = self
            .rx
            .recv()
            .map_err(|_| anyhow::anyhow!("channel closed"))?;
        self.meter.record_recv(msg.len());
        Ok(msg)
    }

    /// Receive with a timeout (failure-injection tests).
    pub fn recv_timeout(&self, timeout: Duration) -> anyhow::Result<Vec<u8>> {
        let msg = self.rx.recv_timeout(timeout)?;
        self.meter.record_recv(msg.len());
        Ok(msg)
    }
}

/// Create a connected pair of endpoints with independent meters.
pub fn pair(latency: Duration) -> (Endpoint, Endpoint) {
    let (txa, rxb) = channel();
    let (txb, rxa) = channel();
    (
        Endpoint {
            tx: txa,
            rx: rxa,
            meter: CommMeter::shared(),
            latency,
        },
        Endpoint {
            tx: txb,
            rx: rxb,
            meter: CommMeter::shared(),
            latency,
        },
    )
}

/// The full §2 topology for one client: channels to both servers plus the
/// server↔server channel. Returned as (client side, server0 side,
/// server1 side) endpoint bundles.
pub struct ClientLinks {
    pub to_s0: Endpoint,
    pub to_s1: Endpoint,
}

/// Build the three-party channel set for `n` clients.
pub fn topology(
    n: usize,
    latency: Duration,
) -> (Vec<ClientLinks>, Vec<(Endpoint, Endpoint)>, (Endpoint, Endpoint)) {
    let mut clients = Vec::with_capacity(n);
    let mut server_sides = Vec::with_capacity(n);
    for _ in 0..n {
        let (c0, s0) = pair(latency);
        let (c1, s1) = pair(latency);
        clients.push(ClientLinks { to_s0: c0, to_s1: c1 });
        server_sides.push((s0, s1));
    }
    let inter = pair(latency);
    (clients, server_sides, inter)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_metering() {
        let (a, b) = pair(Duration::ZERO);
        a.send(vec![1, 2, 3]).unwrap();
        assert_eq!(b.recv().unwrap(), vec![1, 2, 3]);
        b.send(vec![9; 10]).unwrap();
        assert_eq!(a.recv().unwrap().len(), 10);
        assert_eq!(a.meter.sent(), 3);
        assert_eq!(a.meter.recv(), 10);
        assert_eq!(b.meter.sent(), 10);
        assert_eq!(b.meter.recv(), 3);
    }

    #[test]
    fn cross_thread() {
        let (a, b) = pair(Duration::ZERO);
        let h = std::thread::spawn(move || {
            let m = b.recv().unwrap();
            b.send(m.iter().map(|x| x * 2).collect()).unwrap();
        });
        a.send(vec![5, 6]).unwrap();
        assert_eq!(a.recv().unwrap(), vec![10, 12]);
        h.join().unwrap();
    }

    #[test]
    fn timeout_on_silence() {
        let (a, _b) = pair(Duration::ZERO);
        assert!(a.recv_timeout(Duration::from_millis(10)).is_err());
    }

    #[test]
    fn topology_shape() {
        let (clients, servers, _inter) = topology(3, Duration::ZERO);
        assert_eq!(clients.len(), 3);
        assert_eq!(servers.len(), 3);
        clients[0].to_s0.send(vec![1]).unwrap();
        assert_eq!(servers[0].0.recv().unwrap(), vec![1]);
        clients[2].to_s1.send(vec![2]).unwrap();
        assert_eq!(servers[2].1.recv().unwrap(), vec![2]);
    }
}
