//! Simulated secure P2P channels (§2 assumes authenticated encrypted
//! channels client↔S0, client↔S1, S0↔S1; §7 runs on a ≈3ms LAN).
//!
//! In-process `mpsc` channels carry length-delimited byte messages, meter
//! every transfer through [`crate::metrics::CommMeter`], and optionally
//! inject the paper's LAN latency *and* a finite link bandwidth so
//! end-to-end round times are honest even for multi-megabyte payloads.
//!
//! The [`transport`] submodule abstracts "a bidirectional metered byte
//! channel" behind the [`transport::Transport`] trait, with this module's
//! [`Endpoint`] as the in-process implementation and
//! [`transport::tcp`] as the real-socket one.

pub mod reactor;
pub mod transport;

use crate::metrics::CommMeter;
use std::cell::Cell;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Simulated characteristics of one directed link: propagation latency
/// plus serialisation bandwidth. `bandwidth = 0` means "infinite" (a
/// message occupies the pipe for no time), which is the historical
/// behaviour of [`pair`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkProfile {
    /// One-way propagation latency (paper §7: ≈3 ms LAN).
    pub latency: Duration,
    /// Link bandwidth in bytes/second; `0` = unlimited.
    pub bandwidth: u64,
}

impl LinkProfile {
    /// Latency-only profile (unlimited bandwidth).
    pub fn latency_only(latency: Duration) -> Self {
        LinkProfile {
            latency,
            bandwidth: 0,
        }
    }

    /// How long `len` bytes occupy the pipe.
    fn transmit_time(&self, len: usize) -> Duration {
        if self.bandwidth == 0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(len as f64 / self.bandwidth as f64)
        }
    }
}

/// A message in flight, stamped with its simulated delivery deadline.
struct Envelope {
    deliver_at: Instant,
    payload: Vec<u8>,
}

/// One endpoint of a bidirectional metered channel.
pub struct Endpoint {
    tx: Sender<Envelope>,
    rx: Receiver<Envelope>,
    pub meter: Arc<CommMeter>,
    profile: LinkProfile,
    /// When this endpoint's *outgoing* pipe frees up: consecutive sends on
    /// a finite-bandwidth link serialise (each transmission starts only
    /// once the previous one has fully left the sender), which is what
    /// makes large-payload wall times honest. `Cell` suffices — an
    /// endpoint is owned by exactly one thread.
    tx_free_at: Cell<Option<Instant>>,
}

impl Endpoint {
    /// Send a message: enqueue immediately, stamped with a delivery
    /// deadline `departure + latency`, where `departure` accounts for the
    /// link bandwidth (the pipe transmits messages back-to-back, never in
    /// parallel). The deadline is slept by the *receiver* (residually, in
    /// [`Self::recv`]) — sleeping here on the sender thread would
    /// serialise what the network does in parallel: a client sending to
    /// S_0 then S_1 would pay 2× one-way latency instead of overlapping
    /// the two transfers.
    pub fn send(&self, msg: Vec<u8>) -> anyhow::Result<()> {
        let now = Instant::now();
        let start = match self.tx_free_at.get() {
            Some(free) if free > now => free,
            _ => now,
        };
        let departure = start + self.profile.transmit_time(msg.len());
        self.tx_free_at.set(Some(departure));
        let deliver_at = departure + self.profile.latency;
        self.meter.record_send(msg.len());
        self.tx
            .send(Envelope {
                deliver_at,
                payload: msg,
            })
            .map_err(|_| anyhow::Error::new(transport::TransportError::Closed))
    }

    /// Sleep out whatever remains of the envelope's simulated flight time,
    /// then meter and hand over the payload.
    fn deliver(&self, env: Envelope) -> Vec<u8> {
        let now = Instant::now();
        if env.deliver_at > now {
            std::thread::sleep(env.deliver_at - now);
        }
        self.meter.record_recv(env.payload.len());
        env.payload
    }

    /// Receive the next message (blocking until its delivery deadline).
    pub fn recv(&self) -> anyhow::Result<Vec<u8>> {
        let env = self
            .rx
            .recv()
            .map_err(|_| anyhow::Error::new(transport::TransportError::Closed))?;
        Ok(self.deliver(env))
    }

    /// Receive with a timeout (failure injection / straggler deadlines).
    /// The timeout bounds the wait for a message to be *sent*; once one is
    /// in flight, its residual simulated latency is still slept before
    /// delivery. Fails with a typed [`transport::TransportError`] —
    /// `Timeout` when the deadline lapses, `Closed` when the sender is
    /// gone — matching the TCP transport's vocabulary.
    pub fn recv_timeout(&self, timeout: Duration) -> anyhow::Result<Vec<u8>> {
        use std::sync::mpsc::RecvTimeoutError;
        let env = self.rx.recv_timeout(timeout).map_err(|e| {
            anyhow::Error::new(match e {
                RecvTimeoutError::Timeout => transport::TransportError::Timeout,
                RecvTimeoutError::Disconnected => transport::TransportError::Closed,
            })
        })?;
        Ok(self.deliver(env))
    }
}

/// Create a connected pair of endpoints with independent meters
/// (latency-only; see [`pair_profile`] for bandwidth-limited links).
pub fn pair(latency: Duration) -> (Endpoint, Endpoint) {
    pair_profile(LinkProfile::latency_only(latency))
}

/// Create a connected pair of endpoints under a full link profile.
pub fn pair_profile(profile: LinkProfile) -> (Endpoint, Endpoint) {
    let (txa, rxb) = channel();
    let (txb, rxa) = channel();
    (
        Endpoint {
            tx: txa,
            rx: rxa,
            meter: CommMeter::shared(),
            profile,
            tx_free_at: Cell::new(None),
        },
        Endpoint {
            tx: txb,
            rx: rxb,
            meter: CommMeter::shared(),
            profile,
            tx_free_at: Cell::new(None),
        },
    )
}

/// The full §2 topology for one client: channels to both servers plus the
/// server↔server channel. Returned as (client side, server0 side,
/// server1 side) endpoint bundles.
pub struct ClientLinks {
    pub to_s0: Endpoint,
    pub to_s1: Endpoint,
}

/// Build the three-party channel set for `n` clients (latency-only).
pub fn topology(
    n: usize,
    latency: Duration,
) -> (Vec<ClientLinks>, Vec<(Endpoint, Endpoint)>, (Endpoint, Endpoint)) {
    topology_profile(n, LinkProfile::latency_only(latency))
}

/// Build the three-party channel set for `n` clients under a full link
/// profile (every link — client↔server and S_0↔S_1 — gets the same
/// latency and bandwidth, the paper's symmetric-LAN assumption).
pub fn topology_profile(
    n: usize,
    profile: LinkProfile,
) -> (Vec<ClientLinks>, Vec<(Endpoint, Endpoint)>, (Endpoint, Endpoint)) {
    let mut clients = Vec::with_capacity(n);
    let mut server_sides = Vec::with_capacity(n);
    for _ in 0..n {
        let (c0, s0) = pair_profile(profile);
        let (c1, s1) = pair_profile(profile);
        clients.push(ClientLinks { to_s0: c0, to_s1: c1 });
        server_sides.push((s0, s1));
    }
    let inter = pair_profile(profile);
    (clients, server_sides, inter)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_metering() {
        let (a, b) = pair(Duration::ZERO);
        a.send(vec![1, 2, 3]).unwrap();
        assert_eq!(b.recv().unwrap(), vec![1, 2, 3]);
        b.send(vec![9; 10]).unwrap();
        assert_eq!(a.recv().unwrap().len(), 10);
        assert_eq!(a.meter.sent(), 3);
        assert_eq!(a.meter.recv(), 10);
        assert_eq!(b.meter.sent(), 10);
        assert_eq!(b.meter.recv(), 3);
    }

    #[test]
    fn cross_thread() {
        let (a, b) = pair(Duration::ZERO);
        let h = std::thread::spawn(move || {
            let m = b.recv().unwrap();
            b.send(m.iter().map(|x| x * 2).collect()).unwrap();
        });
        a.send(vec![5, 6]).unwrap();
        assert_eq!(a.recv().unwrap(), vec![10, 12]);
        h.join().unwrap();
    }

    #[test]
    fn latency_overlaps_across_links() {
        // A client sending to S_0 then S_1 must NOT pay 2× the one-way
        // latency: sends enqueue immediately (deadline-stamped) and the
        // receivers sleep only the residual flight time.
        // Generous latency so the <2× bound has a wide margin against
        // scheduler stalls on loaded CI runners.
        let lat = Duration::from_millis(150);
        let (c0, s0) = pair(lat);
        let (c1, s1) = pair(lat);
        let t0 = Instant::now();
        c0.send(vec![1]).unwrap();
        c1.send(vec![2]).unwrap();
        assert!(
            t0.elapsed() < lat,
            "send must not block on simulated latency"
        );
        s0.recv().unwrap();
        s1.recv().unwrap();
        let total = t0.elapsed();
        assert!(total >= lat, "one-way latency must still be paid: {total:?}");
        assert!(
            total < lat * 2,
            "latencies of parallel links must overlap: {total:?}"
        );
    }

    #[test]
    fn bandwidth_charges_transmit_time() {
        // 100 kB at 1 MB/s ⇒ ≥100 ms on the wire, even with zero latency.
        let (a, b) = pair_profile(LinkProfile {
            latency: Duration::ZERO,
            bandwidth: 1_000_000,
        });
        let t0 = Instant::now();
        a.send(vec![0u8; 100_000]).unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(50),
            "send must not block on simulated transmission"
        );
        b.recv().unwrap();
        assert!(
            t0.elapsed() >= Duration::from_millis(95),
            "transmit time must be paid by delivery: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn bandwidth_serialises_consecutive_sends() {
        // Two 50 kB messages on a 1 MB/s pipe occupy it back-to-back:
        // the second delivery lands ≥100 ms in, not ≥50 ms.
        let (a, b) = pair_profile(LinkProfile {
            latency: Duration::ZERO,
            bandwidth: 1_000_000,
        });
        let t0 = Instant::now();
        a.send(vec![0u8; 50_000]).unwrap();
        a.send(vec![0u8; 50_000]).unwrap();
        b.recv().unwrap();
        let first = t0.elapsed();
        b.recv().unwrap();
        let second = t0.elapsed();
        assert!(first >= Duration::from_millis(45), "{first:?}");
        assert!(second >= Duration::from_millis(95), "{second:?}");
    }

    #[test]
    fn zero_bandwidth_means_unlimited() {
        let (a, b) = pair_profile(LinkProfile {
            latency: Duration::ZERO,
            bandwidth: 0,
        });
        let t0 = Instant::now();
        a.send(vec![0u8; 1_000_000]).unwrap();
        b.recv().unwrap();
        assert!(t0.elapsed() < Duration::from_millis(250));
    }

    #[test]
    fn timeout_on_silence() {
        let (a, _b) = pair(Duration::ZERO);
        assert!(a.recv_timeout(Duration::from_millis(10)).is_err());
    }

    #[test]
    fn topology_shape() {
        let (clients, servers, _inter) = topology(3, Duration::ZERO);
        assert_eq!(clients.len(), 3);
        assert_eq!(servers.len(), 3);
        clients[0].to_s0.send(vec![1]).unwrap();
        assert_eq!(servers[0].0.recv().unwrap(), vec![1]);
        clients[2].to_s1.send(vec![2]).unwrap();
        assert_eq!(servers[2].1.recv().unwrap(), vec![2]);
    }
}
