//! Cuckoo hashing + aligned simple hashing — the probabilistic batch code
//! (§3.2) that reduces multi-query PIR to one DPF per bin (§4).
//!
//! Both tables are built with the *same* public hash functions
//! (`h_1..h_η : Z_m → Z_B`), which guarantees the alignment invariant the
//! protocols rely on: if the client's cuckoo table stores element `u` in
//! bin `j`, then `u ∈ T_simple[j]`.

mod cuckoo;
mod params;
mod simple;

pub use cuckoo::{CuckooError, CuckooTable};
pub use params::{scale_factor_for, CuckooParams};
pub use simple::SimpleTable;
