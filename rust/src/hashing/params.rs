//! Cuckoo parameterisation (ε scale factor, η hash count, σ stash size).

/// Parameters shared by all parties in a round (Table 1: ε, η, σ).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CuckooParams {
    /// Scale factor ε > 1: the table has `B = ⌈ε·k⌉` bins.
    pub epsilon: f64,
    /// Number of hash functions η (the paper uses η = 3 throughout).
    pub eta: usize,
    /// Stash size σ (experiments run stash-less, σ = 0).
    pub sigma: usize,
    /// Public seed from which all parties derive the η hash functions.
    pub hash_seed: u64,
    /// Maximum eviction chain length before an element goes to the stash.
    pub max_kicks: usize,
}

impl Default for CuckooParams {
    fn default() -> Self {
        CuckooParams {
            epsilon: 1.27,
            eta: 3,
            sigma: 0,
            hash_seed: 0xf5_1a_9b_03,
            max_kicks: 500,
        }
    }
}

impl CuckooParams {
    /// Number of bins for `k` inserted elements.
    pub fn num_bins(&self, k: usize) -> usize {
        ((self.epsilon * k as f64).ceil() as usize).max(1)
    }

    /// Builder-style override of ε.
    pub fn with_epsilon(mut self, epsilon: f64) -> Self {
        self.epsilon = epsilon;
        self
    }

    /// Builder-style override of σ.
    pub fn with_sigma(mut self, sigma: usize) -> Self {
        self.sigma = sigma;
        self
    }

    /// Builder-style override of the public hash seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.hash_seed = seed;
        self
    }
}

/// The paper's Table 3: empirically calibrated scale factor per input
/// size, keeping the (stash-less) failure probability ≤ 2^-κ (κ = 40).
/// `benches/table3_scale_factor.rs` re-derives these by measurement.
pub fn scale_factor_for(input_size: usize) -> f64 {
    match input_size {
        0..=1_048_576 => 1.25,          // ≤ 2^20 (paper: 1.25 / 1.25 / 1.27)
        ..=33_554_432 => 1.28,          // ≤ 2^25 (paper: 1.28)
        _ => 1.30,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_count_rounds_up() {
        let p = CuckooParams::default().with_epsilon(1.25);
        assert_eq!(p.num_bins(4), 5);
        assert_eq!(p.num_bins(100), 125);
        assert_eq!(p.num_bins(1), 2);
        assert_eq!(p.num_bins(0), 1);
    }

    #[test]
    fn table3_bands() {
        assert_eq!(scale_factor_for(1 << 10), 1.25);
        assert_eq!(scale_factor_for(1 << 15), 1.25);
        assert_eq!(scale_factor_for(1 << 25), 1.28);
    }
}
