//! Client-side cuckoo hash table with optional stash.

use super::params::CuckooParams;
use crate::crypto::hash::{derive_hash_fns, HashFn};
use crate::crypto::rng::Rng;

/// Cuckoo insertion failure: the eviction chain exceeded `max_kicks` and
/// the stash was already full.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CuckooError {
    /// The element left homeless when insertion gave up.
    pub element: u64,
}

impl std::fmt::Display for CuckooError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cuckoo insertion failed for element {}", self.element)
    }
}

impl std::error::Error for CuckooError {}

/// A client's cuckoo table over its k selected indices. Each occupied bin
/// holds exactly one element; overflow goes to the σ-slot stash.
#[derive(Clone, Debug)]
pub struct CuckooTable {
    bins: Vec<Option<u64>>,
    stash: Vec<u64>,
    fns: Vec<HashFn>,
    params: CuckooParams,
}

impl CuckooTable {
    /// Build a table with `B = ⌈ε·|elements|⌉` bins and insert all of
    /// `elements` (distinct `u64`s < m). Eviction choices are randomised
    /// by `rng` so failure-probability experiments can re-sample.
    pub fn build(
        elements: &[u64],
        params: &CuckooParams,
        rng: &mut Rng,
    ) -> Result<Self, CuckooError> {
        Self::build_with_bins(elements, params.num_bins(elements.len()), params, rng)
    }

    /// Build with an explicit bin count — REQUIRED whenever the table must
    /// align with a shared simple table sized from the session's `k`
    /// (a client selecting fewer than `k` indices must still use the
    /// session's `B`, or the hash ranges diverge and alignment breaks).
    pub fn build_with_bins(
        elements: &[u64],
        num_bins: usize,
        params: &CuckooParams,
        rng: &mut Rng,
    ) -> Result<Self, CuckooError> {
        let fns = derive_hash_fns(params.hash_seed, params.eta, num_bins as u64);
        let mut table = CuckooTable {
            bins: vec![None; num_bins],
            stash: Vec::with_capacity(params.sigma),
            fns,
            params: *params,
        };
        for &e in elements {
            table.insert(e, rng)?;
        }
        Ok(table)
    }

    fn insert(&mut self, element: u64, rng: &mut Rng) -> Result<(), CuckooError> {
        let mut cur = element;
        for _ in 0..self.params.max_kicks {
            // Take the first empty candidate bin, if any.
            for d in 0..self.params.eta {
                let j = self.fns[d].eval(cur) as usize;
                if self.bins[j].is_none() {
                    self.bins[j] = Some(cur);
                    return Ok(());
                }
            }
            // All candidates occupied: evict a random one.
            let d = rng.gen_range(self.params.eta as u64) as usize;
            let j = self.fns[d].eval(cur) as usize;
            let evicted = self.bins[j].replace(cur).expect("occupied bin");
            cur = evicted;
        }
        if self.stash.len() < self.params.sigma {
            self.stash.push(cur);
            Ok(())
        } else {
            Err(CuckooError { element: cur })
        }
    }

    /// Bin contents (`None` ⇒ dummy bin).
    pub fn bins(&self) -> &[Option<u64>] {
        &self.bins
    }

    /// Stash contents (≤ σ elements).
    pub fn stash(&self) -> &[u64] {
        &self.stash
    }

    /// Number of bins B.
    pub fn num_bins(&self) -> usize {
        self.bins.len()
    }

    /// The η candidate bins of an element (deduplicated, order-preserving).
    pub fn candidate_bins(&self, element: u64) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.params.eta);
        for f in &self.fns {
            let j = f.eval(element) as usize;
            if !out.contains(&j) {
                out.push(j);
            }
        }
        out
    }

    /// Where an element landed: `Some(Ok(bin))`, `Some(Err(stash_slot))`,
    /// or `None` if absent.
    pub fn locate(&self, element: u64) -> Option<Result<usize, usize>> {
        for f in &self.fns {
            let j = f.eval(element) as usize;
            if self.bins[j] == Some(element) {
                return Some(Ok(j));
            }
        }
        self.stash.iter().position(|&e| e == element).map(Err)
    }

    /// The shared hash functions (aligned with the simple table).
    pub fn hash_fns(&self) -> &[HashFn] {
        &self.fns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build_ok(k: usize, seed: u64) -> CuckooTable {
        let params = CuckooParams::default();
        let mut rng = Rng::new(seed);
        let elements: Vec<u64> = rng.sample_distinct(k, (k as u64) * 100);
        CuckooTable::build(&elements, &params, &mut rng).expect("cuckoo build")
    }

    #[test]
    fn every_element_lands_in_a_candidate_bin() {
        let params = CuckooParams::default();
        let mut rng = Rng::new(60);
        let elements: Vec<u64> = rng.sample_distinct(500, 50_000);
        let t = CuckooTable::build(&elements, &params, &mut rng).unwrap();
        for &e in &elements {
            match t.locate(e).expect("present") {
                Ok(bin) => assert!(t.candidate_bins(e).contains(&bin)),
                Err(_) => panic!("unexpected stash use"),
            }
        }
    }

    #[test]
    fn bins_hold_at_most_one() {
        let t = build_ok(1000, 61);
        let occupied = t.bins().iter().filter(|b| b.is_some()).count();
        let stash = t.stash().len();
        assert_eq!(occupied + stash, 1000);
        // No duplicates.
        let mut seen = std::collections::HashSet::new();
        for b in t.bins().iter().flatten() {
            assert!(seen.insert(*b));
        }
    }

    #[test]
    fn bin_count_follows_epsilon() {
        let t = build_ok(1000, 62);
        assert_eq!(t.num_bins(), (1.27f64 * 1000.0).ceil() as usize);
    }

    #[test]
    fn stash_catches_overflow() {
        // Absurdly small table (ε near 1, η = 2) forces stash use.
        let params = CuckooParams {
            epsilon: 1.0,
            eta: 2,
            sigma: 8,
            hash_seed: 7,
            max_kicks: 50,
        };
        let mut rng = Rng::new(63);
        let elements: Vec<u64> = (0..64).collect();
        let t = CuckooTable::build(&elements, &params, &mut rng).unwrap();
        // Everything still locatable.
        for &e in &elements {
            assert!(t.locate(e).is_some());
        }
        assert!(!t.stash().is_empty(), "expected stash pressure");
    }

    #[test]
    fn failure_without_stash_is_reported() {
        let params = CuckooParams {
            epsilon: 1.0,
            eta: 2,
            sigma: 0,
            hash_seed: 7,
            max_kicks: 20,
        };
        let mut rng = Rng::new(64);
        let elements: Vec<u64> = (0..512).collect();
        assert!(CuckooTable::build(&elements, &params, &mut rng).is_err());
    }

    #[test]
    fn default_params_never_fail_small_scale() {
        // Empirical stand-in for the κ=40 failure bound at small k: 200
        // independent builds, zero failures.
        let params = CuckooParams::default();
        for seed in 0..200 {
            let mut rng = Rng::new(seed);
            let elements = rng.sample_distinct(300, 1 << 15);
            assert!(
                CuckooTable::build(&elements, &params, &mut rng).is_ok(),
                "seed {seed}"
            );
        }
    }
}
