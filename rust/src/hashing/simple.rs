//! Server-side simple hashing, aligned with the clients' cuckoo tables.
//!
//! Every index in the domain `{0..m}` (or, with the PSU optimisation, in
//! the revealed union set) is inserted into *all* of its η candidate bins,
//! so whatever bin a client's cuckoo table picked for element `u`, the
//! servers' bin `j` contains `u` at a well-defined position `pos_j(u)`.

use super::params::CuckooParams;
use crate::crypto::hash::{derive_hash_fns, HashFn};

/// The shared simple table: bin `j` lists the domain elements hashing to
/// `j` under any of the η functions (deduplicated per bin, sorted by
/// insertion order = domain order, so every party computes identical
/// positions).
#[derive(Clone, Debug)]
pub struct SimpleTable {
    bins: Vec<Vec<u64>>,
    fns: Vec<HashFn>,
}

impl SimpleTable {
    /// Build over an explicit domain (ascending, distinct). `num_bins`
    /// must equal the clients' cuckoo bin count for alignment.
    pub fn build(domain: impl Iterator<Item = u64>, num_bins: usize, params: &CuckooParams) -> Self {
        assert!(params.eta <= 8, "η > 8 unsupported");
        let fns = derive_hash_fns(params.hash_seed, params.eta, num_bins as u64);
        let mut bins: Vec<Vec<u64>> = vec![Vec::new(); num_bins];
        for x in domain {
            let mut placed: [usize; 8] = [usize::MAX; 8];
            let mut np = 0;
            for f in &fns {
                let j = f.eval(x) as usize;
                // An element whose hashes collide occupies the bin once
                // (the paper's Figure 2 note on element "2").
                if !placed[..np].contains(&j) {
                    bins[j].push(x);
                    placed[np] = j;
                    np += 1;
                }
            }
        }
        // Canonical per-bin order (ascending) regardless of iteration
        // order, so every party computes identical positions.
        for b in &mut bins {
            b.sort_unstable();
            b.dedup();
        }
        SimpleTable { bins, fns }
    }

    /// Build over the full model domain `{0..m}`.
    pub fn build_full(m: u64, num_bins: usize, params: &CuckooParams) -> Self {
        Self::build(0..m, num_bins, params)
    }

    /// Bin contents.
    pub fn bin(&self, j: usize) -> &[u64] {
        &self.bins[j]
    }

    /// Number of bins.
    pub fn num_bins(&self) -> usize {
        self.bins.len()
    }

    /// Maximum bin size Θ (Table 4).
    pub fn max_bin_size(&self) -> usize {
        self.bins.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Position of `x` within bin `j` (the client's `pos_j`).
    pub fn position(&self, j: usize, x: u64) -> Option<usize> {
        // Bins are in ascending domain order → binary search.
        self.bins[j].binary_search(&x).ok()
    }

    /// The η candidate bins of `x` (deduplicated, order-preserving) —
    /// mirrors [`super::CuckooTable::candidate_bins`].
    pub fn candidate_bins(&self, x: u64) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.fns.len());
        for f in &self.fns {
            let j = f.eval(x) as usize;
            if !out.contains(&j) {
                out.push(j);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;
    use crate::hashing::CuckooTable;

    #[test]
    fn every_domain_element_in_its_candidate_bins() {
        let params = CuckooParams::default();
        let t = SimpleTable::build_full(1 << 10, 256, &params);
        for x in 0..(1u64 << 10) {
            for j in t.candidate_bins(x) {
                assert!(t.position(j, x).is_some(), "{x} missing from bin {j}");
            }
        }
    }

    #[test]
    fn bins_are_sorted_and_deduped() {
        let params = CuckooParams::default();
        let t = SimpleTable::build_full(4096, 512, &params);
        for j in 0..t.num_bins() {
            let b = t.bin(j);
            assert!(b.windows(2).all(|w| w[0] < w[1]), "bin {j} unsorted/dup");
        }
    }

    #[test]
    fn alignment_with_cuckoo() {
        // The invariant both protocols rely on: whatever bin the cuckoo
        // table chose for u, the simple table's same-numbered bin holds u.
        let params = CuckooParams::default();
        let mut rng = Rng::new(70);
        let k = 200;
        let m = 1u64 << 12;
        let elements = rng.sample_distinct(k, m);
        let cuckoo = CuckooTable::build(&elements, &params, &mut rng).unwrap();
        let simple = SimpleTable::build_full(m, cuckoo.num_bins(), &params);
        for (j, slot) in cuckoo.bins().iter().enumerate() {
            if let Some(u) = slot {
                assert!(
                    simple.position(j, *u).is_some(),
                    "cuckoo bin {j} element {u} not in simple bin"
                );
            }
        }
    }

    #[test]
    fn subset_domain_shrinks_theta() {
        // The PSU optimisation: a smaller domain gives smaller Θ.
        let params = CuckooParams::default();
        let full = SimpleTable::build_full(1 << 12, 128, &params);
        let union: Vec<u64> = (0..(1u64 << 12)).step_by(8).collect();
        let small = SimpleTable::build(union.into_iter(), 128, &params);
        assert!(small.max_bin_size() < full.max_bin_size());
    }
}
