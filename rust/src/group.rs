//! Finite Abelian groups 𝔾 used as DPF payloads.
//!
//! The paper works over an arbitrary finite Abelian group 𝔾 with
//! `l = ⌈log|𝔾|⌉` bits per weight (the evaluation uses `l = 128`). We
//! provide `Z_{2^64}` and `Z_{2^128}` (wrapping integer rings) plus a
//! fixed-width "mega-element" vector group for the §6 grouping
//! optimisation (τ weights share one DPF payload).

/// An additively written finite Abelian group, usable as a DPF output.
///
/// `convert` is the BGI16 `Convert` map: it deterministically stretches a
/// λ-bit PRG seed into a pseudorandom group element (for vector groups the
/// seed is expanded with AES-CTR).
pub trait Group: Clone + PartialEq + Eq + std::fmt::Debug + Send + Sync + 'static {
    /// The identity element.
    fn zero() -> Self;
    /// Group operation.
    fn add(&self, other: &Self) -> Self;
    /// Inverse.
    fn neg(&self) -> Self;
    /// `self + (-other)`.
    fn sub(&self, other: &Self) -> Self {
        self.add(&other.neg())
    }
    /// In-place add (hot path: server-side aggregation).
    fn add_assign(&mut self, other: &Self) {
        *self = self.add(other);
    }
    /// BGI16 `Convert`: seed ↦ pseudorandom group element.
    fn convert(seed: &[u8; 16]) -> Self;
    /// Ring multiplication (component-wise for vector groups). Used by the
    /// PSR servers' inner product `Σ_x w_x · [f(x)]_b`, which is linear in
    /// the share because multiplication distributes over addition.
    fn ring_mul(&self, other: &Self) -> Self;
    /// Multiplicative identity of the ring (all-ones for vector groups) —
    /// the PSR payload `β = 1`.
    fn one() -> Self;
    /// Bit width `⌈log|𝔾|⌉` for communication accounting.
    fn bit_len() -> usize;
    /// Byte width of the wire encoding.
    fn byte_len() -> usize {
        Self::bit_len().div_ceil(8)
    }
    /// Serialise to exactly [`Group::byte_len`] bytes.
    fn encode(&self, out: &mut Vec<u8>);
    /// Deserialise from exactly [`Group::byte_len`] bytes.
    fn decode(bytes: &[u8]) -> Option<Self>;
    /// Conditional negation: `(-1)^t · self`.
    fn cneg(&self, t: bool) -> Self {
        if t {
            self.neg()
        } else {
            self.clone()
        }
    }
}

impl Group for u64 {
    fn zero() -> Self {
        0
    }
    fn add(&self, other: &Self) -> Self {
        self.wrapping_add(*other)
    }
    fn neg(&self) -> Self {
        self.wrapping_neg()
    }
    fn ring_mul(&self, other: &Self) -> Self {
        self.wrapping_mul(*other)
    }
    fn one() -> Self {
        1
    }
    fn convert(seed: &[u8; 16]) -> Self {
        u64::from_le_bytes(seed[..8].try_into().unwrap())
    }
    fn bit_len() -> usize {
        64
    }
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn decode(bytes: &[u8]) -> Option<Self> {
        Some(u64::from_le_bytes(bytes.get(..8)?.try_into().ok()?))
    }
}

impl Group for u128 {
    fn zero() -> Self {
        0
    }
    fn add(&self, other: &Self) -> Self {
        self.wrapping_add(*other)
    }
    fn neg(&self) -> Self {
        self.wrapping_neg()
    }
    fn ring_mul(&self, other: &Self) -> Self {
        self.wrapping_mul(*other)
    }
    fn one() -> Self {
        1
    }
    fn convert(seed: &[u8; 16]) -> Self {
        u128::from_le_bytes(*seed)
    }
    fn bit_len() -> usize {
        128
    }
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn decode(bytes: &[u8]) -> Option<Self> {
        Some(u128::from_le_bytes(bytes.get(..16)?.try_into().ok()?))
    }
}

/// Mega-element group (§6): τ = `T` weights grouped into one payload, each
/// a `Z_{2^64}` coordinate. Component-wise addition.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct MegaElem<const T: usize>(pub [u64; T]);

impl<const T: usize> Default for MegaElem<T> {
    fn default() -> Self {
        MegaElem([0u64; T])
    }
}

impl<const T: usize> Group for MegaElem<T> {
    fn zero() -> Self {
        MegaElem([0u64; T])
    }
    fn add(&self, other: &Self) -> Self {
        let mut out = [0u64; T];
        for i in 0..T {
            out[i] = self.0[i].wrapping_add(other.0[i]);
        }
        MegaElem(out)
    }
    fn neg(&self) -> Self {
        let mut out = [0u64; T];
        for i in 0..T {
            out[i] = self.0[i].wrapping_neg();
        }
        MegaElem(out)
    }
    fn ring_mul(&self, other: &Self) -> Self {
        let mut out = [0u64; T];
        for i in 0..T {
            out[i] = self.0[i].wrapping_mul(other.0[i]);
        }
        MegaElem(out)
    }
    fn one() -> Self {
        MegaElem([1u64; T])
    }
    fn add_assign(&mut self, other: &Self) {
        for i in 0..T {
            self.0[i] = self.0[i].wrapping_add(other.0[i]);
        }
    }
    fn convert(seed: &[u8; 16]) -> Self {
        // Expand the λ-bit seed to τ·64 bits with AES-CTR (PRG stream).
        let mut out = [0u64; T];
        let stream = crate::crypto::prg::expand_stream(seed, T * 8);
        for (i, chunk) in stream.chunks_exact(8).enumerate().take(T) {
            out[i] = u64::from_le_bytes(chunk.try_into().unwrap());
        }
        MegaElem(out)
    }
    fn bit_len() -> usize {
        64 * T
    }
    fn encode(&self, out: &mut Vec<u8>) {
        for v in &self.0 {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn decode(bytes: &[u8]) -> Option<Self> {
        let mut out = [0u64; T];
        for i in 0..T {
            out[i] = u64::from_le_bytes(bytes.get(i * 8..i * 8 + 8)?.try_into().ok()?);
        }
        Some(MegaElem(out))
    }
}

/// Fixed-point encoding of an `f32` weight update into `Z_{2^64}`.
///
/// Additive aggregation over the ring matches float summation up to the
/// quantisation step `2^-FRAC`. The coordinator uses this to move model
/// deltas through the SSA protocol losslessly w.r.t. the fixed-point grid
/// (the paper's scheme is *lossless* over 𝔾; floats enter only at the
/// learning layer).
pub const FRAC_BITS: u32 = 24;

/// Encode a float into the ring (two's-complement fixed point).
pub fn fixed_encode(x: f32) -> u64 {
    let scaled = (x as f64 * f64::from(1u32 << FRAC_BITS)).round() as i64;
    scaled as u64
}

/// Decode a ring element back to a float.
pub fn fixed_decode(x: u64) -> f32 {
    (x as i64) as f64 as f32 / f64::from(1u32 << FRAC_BITS) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_group_laws() {
        let a = 0xdead_beef_u64;
        let b = 0x1234_5678_u64;
        assert_eq!(a.add(&b), b.add(&a));
        assert_eq!(a.add(&a.neg()), 0);
        assert_eq!(a.sub(&b).add(&b), a);
        assert_eq!(u64::zero().add(&a), a);
    }

    #[test]
    fn u128_group_laws() {
        let a = u128::MAX - 5;
        let b = 77u128;
        assert_eq!(a.add(&b), b.add(&a));
        assert_eq!(a.add(&a.neg()), 0);
        assert_eq!(a.cneg(true), a.neg());
        assert_eq!(a.cneg(false), a);
    }

    #[test]
    fn mega_elem_group_laws() {
        let a = MegaElem::<4>([1, u64::MAX, 3, 4]);
        let b = MegaElem::<4>([5, 6, 7, 8]);
        assert_eq!(a.add(&b), b.add(&a));
        assert_eq!(a.add(&a.neg()), MegaElem::zero());
        let mut c = a;
        c.add_assign(&b);
        assert_eq!(c, a.add(&b));
        assert_eq!(MegaElem::<4>::bit_len(), 256);
    }

    #[test]
    fn convert_is_deterministic_and_seed_sensitive() {
        let s1 = [7u8; 16];
        let mut s2 = s1;
        s2[0] ^= 1;
        assert_eq!(u64::convert(&s1), u64::convert(&s1));
        assert_ne!(
            MegaElem::<8>::convert(&s1),
            MegaElem::<8>::convert(&s2)
        );
    }

    #[test]
    fn fixed_point_roundtrip() {
        for &x in &[0.0f32, 1.5, -2.25, 0.125, -1000.0, 3.0e4] {
            let d = fixed_decode(fixed_encode(x));
            assert!((d - x).abs() < 1e-4, "{x} -> {d}");
        }
        // Additive homomorphism on the grid.
        let a = fixed_encode(1.25);
        let b = fixed_encode(-0.75);
        assert!((fixed_decode(a.add(&b)) - 0.5).abs() < 1e-6);
    }
}
