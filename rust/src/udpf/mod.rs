//! Updatable DPF (§5) — the fixed-submodel optimisation.
//!
//! A U-DPF lets a client move its keys from `f_{α,β}` to `f_{α,β'}` by
//! sending each server a *hint* of only `⌈log 𝔾⌉` bits, instead of fresh
//! `depth·(λ+2)+λ+⌈log 𝔾⌉`-bit keys. The construction swaps the BGI16
//! leaf `Convert(s)` for a random-oracle hash `H(s, e)` keyed by the epoch
//! `e`, so the final correction word can be recomputed (and *only* it
//! changes) per epoch:
//!
//! `CW^{(n+1)}_e ← (−1)^{t_1} · (β_e − H(s_0, e) + H(s_1, e))`.
//!
//! Replaying an old `CW^{(n+1)}` against a new epoch yields garbage, and
//! each epoch's leaf masks `H(s_b, e)` are fresh, which is exactly why the
//! plain DPF's `Convert` (epoch-independent) fails the §5 security game.

use crate::crypto::prg::{expand_one, Seed};
use crate::crypto::Sensitive;
use crate::dpf::{gen as dpf_gen, DpfKey};
use crate::group::Group;
use sha2::{Digest, Sha256};

/// Random oracle `H : {0,1}^λ × ℕ → 𝔾` (SHA-256 → seed → `Convert`).
pub fn ro_hash<G: Group>(seed: &Seed, epoch: u64) -> G {
    let mut h = Sha256::new();
    h.update(b"fsl-udpf-ro");
    h.update(seed);
    h.update(epoch.to_le_bytes());
    let digest = h.finalize();
    let mut s = [0u8; 16];
    s.copy_from_slice(&digest[..16]);
    G::convert(&s)
}

/// One party's updatable DPF key: a standard key whose output correction
/// word is interpreted against the epoch-keyed oracle.
///
/// Not `Debug` — it carries a root seed (`SECRET_TYPES` manifest).
#[derive(Clone)]
pub struct UdpfKey<G: Group> {
    pub inner: DpfKey<G>,
}

/// Client-side state retained across epochs: the two final seeds and the
/// final control bit of party 1 (needed to aim the next hint).
///
/// Not `Debug` — the leaf seeds let anyone forge epoch hints
/// (`SECRET_TYPES` manifest).
#[derive(Clone)]
pub struct UdpfClientState {
    /// Party 0's final on-path seed (redacted, zeroized on drop).
    pub leaf_seed0: Sensitive<Seed>,
    /// Party 1's final on-path seed (redacted, zeroized on drop).
    pub leaf_seed1: Sensitive<Seed>,
    pub t1: bool,
}

/// The per-epoch update hint — `⌈log 𝔾⌉` bits on the wire.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hint<G: Group> {
    pub epoch: u64,
    pub cw_out: G,
}

impl<G: Group> Hint<G> {
    /// Wire size in bits (the `k·l` per-round cost of §6's U-DPF row).
    pub fn size_bits(&self) -> usize {
        G::bit_len()
    }
}

/// `Gen(1^λ, α, β)` for epoch 0. Returns both keys plus the client state
/// used by [`next_hint`].
pub fn gen<G: Group>(
    depth: usize,
    alpha: u64,
    beta: &G,
    s0: Seed,
    s1: Seed,
) -> (UdpfKey<G>, UdpfKey<G>, UdpfClientState) {
    // Reuse the DPF tree walk, then recompute the final CW against H(·, 0).
    let (mut k0, mut k1) = dpf_gen::<G>(depth, alpha, beta, s0, s1);
    let state = walk_to_leaf_state(&k0, &k1, alpha);
    let cw = beta
        .sub(&ro_hash::<G>(&state.leaf_seed0, 0))
        .add(&ro_hash::<G>(&state.leaf_seed1, 0))
        .cneg(state.t1);
    k0.cw_out = cw.clone();
    k1.cw_out = cw;
    (UdpfKey { inner: k0 }, UdpfKey { inner: k1 }, state)
}

fn walk_to_leaf_state<G: Group>(k0: &DpfKey<G>, k1: &DpfKey<G>, alpha: u64) -> UdpfClientState {
    // The client knows both keys; replay the two walks along α to recover
    // the final seeds/control bits (identical to what Gen computed).
    let walk = |k: &DpfKey<G>| {
        let mut s = *k.root_seed;
        let mut t = k.party == 1;
        for level in 0..k.depth {
            let bit = (alpha >> (k.depth - 1 - level)) & 1 == 1;
            let child = expand_one(&s, bit);
            let cw = &k.cws[level];
            s = child.seed;
            let mut ct = child.t;
            if t {
                for i in 0..16 {
                    s[i] ^= cw.seed[i];
                }
                ct ^= if bit { cw.t_right } else { cw.t_left };
            }
            t = ct;
        }
        (s, t)
    };
    let (s0, _t0) = walk(k0);
    let (s1, t1) = walk(k1);
    UdpfClientState {
        leaf_seed0: Sensitive::new(s0),
        leaf_seed1: Sensitive::new(s1),
        t1,
    }
}

/// `Next(k_0, k_1, β', e)` — client computes the epoch-`e` hint.
pub fn next_hint<G: Group>(state: &UdpfClientState, beta: &G, epoch: u64) -> Hint<G> {
    Hint {
        epoch,
        cw_out: beta
            .sub(&ro_hash::<G>(&state.leaf_seed0, epoch))
            .add(&ro_hash::<G>(&state.leaf_seed1, epoch))
            .cneg(state.t1),
    }
}

/// `Update(k_b, hint, e)` — server swaps in the new output CW.
pub fn update<G: Group>(key: &mut UdpfKey<G>, hint: &Hint<G>) {
    key.inner.cw_out = hint.cw_out.clone();
}

/// `Eval(b, k_b, x, e)` — as DPF eval but with the epoch-keyed leaf hash.
pub fn eval<G: Group>(key: &UdpfKey<G>, x: u64, epoch: u64) -> G {
    let k = &key.inner;
    let mut s = *k.root_seed;
    let mut t = k.party == 1;
    for level in 0..k.depth {
        let bit = (x >> (k.depth - 1 - level)) & 1 == 1;
        let child = expand_one(&s, bit);
        let cw = &k.cws[level];
        s = child.seed;
        let mut ct = child.t;
        if t {
            for i in 0..16 {
                s[i] ^= cw.seed[i];
            }
            ct ^= if bit { cw.t_right } else { cw.t_left };
        }
        t = ct;
    }
    let mut v = ro_hash::<G>(&s, epoch);
    if t {
        v.add_assign(&k.cw_out);
    }
    v.cneg(k.party == 1)
}

/// Full-domain evaluation for epoch `e` (server-side SSA path).
pub fn full_eval<G: Group>(key: &UdpfKey<G>, num_points: usize, epoch: u64) -> Vec<G> {
    use crate::crypto::prg::double;
    let k = &key.inner;
    let mut frontier: Vec<(Seed, bool)> = vec![(*k.root_seed, k.party == 1)];
    for level in 0..k.depth {
        let cw = &k.cws[level];
        let span = 1usize << (k.depth - level - 1);
        let needed = num_points.div_ceil(span).max(1);
        let mut next = Vec::with_capacity((frontier.len() * 2).min(needed + 1));
        'outer: for (s, t) in &frontier {
            let (l, r) = double(s);
            for (bit, child) in [(false, l), (true, r)] {
                if next.len() >= needed {
                    break 'outer;
                }
                let mut cs = child.seed;
                let mut ct = child.t;
                if *t {
                    for i in 0..16 {
                        cs[i] ^= cw.seed[i];
                    }
                    ct ^= if bit { cw.t_right } else { cw.t_left };
                }
                next.push((cs, ct));
            }
        }
        frontier = next;
    }
    frontier
        .iter()
        .take(num_points)
        .map(|(s, t)| {
            let mut v = ro_hash::<G>(s, epoch);
            if *t {
                v.add_assign(&k.cw_out);
            }
            v.cneg(k.party == 1)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;

    #[test]
    fn epoch0_correctness() {
        let mut rng = Rng::new(30);
        let beta = 4242u64;
        let (k0, k1, _st) = gen(8, 55, &beta, rng.gen_seed(), rng.gen_seed());
        for x in 0..256u64 {
            let sum = eval(&k0, x, 0).add(&eval(&k1, x, 0));
            assert_eq!(sum, if x == 55 { beta } else { 0 });
        }
    }

    #[test]
    fn update_moves_beta_keeps_alpha() {
        let mut rng = Rng::new(31);
        let (mut k0, mut k1, st) = gen(8, 99, &7u64, rng.gen_seed(), rng.gen_seed());
        for epoch in 1..6u64 {
            let beta_e = 1000 + epoch;
            let hint = next_hint(&st, &beta_e, epoch);
            assert_eq!(hint.size_bits(), 64);
            update(&mut k0, &hint);
            update(&mut k1, &hint);
            for x in [0u64, 98, 99, 100, 255] {
                let sum = eval(&k0, x, epoch).add(&eval(&k1, x, epoch));
                assert_eq!(sum, if x == 99 { beta_e } else { 0 }, "epoch {epoch} x {x}");
            }
        }
    }

    #[test]
    fn stale_cw_with_new_epoch_is_garbage() {
        // Evaluating epoch 1 against the epoch-0 CW must NOT reconstruct β
        // at α (this is the property the plain-Convert construction lacks).
        let mut rng = Rng::new(32);
        let (k0, k1, _st) = gen(8, 10, &5u64, rng.gen_seed(), rng.gen_seed());
        let sum = eval(&k0, 10, 1).add(&eval(&k1, 10, 1));
        assert_ne!(sum, 5);
        // Off-path points still cancel (their leaves agree bit-for-bit).
        assert_eq!(eval(&k0, 11, 1).add(&eval(&k1, 11, 1)), 0);
    }

    #[test]
    fn full_eval_matches_pointwise() {
        let mut rng = Rng::new(33);
        let (mut k0, _k1, st) = gen(9, 300, &1u64, rng.gen_seed(), rng.gen_seed());
        let hint = next_hint(&st, &77u64, 3);
        update(&mut k0, &hint);
        let fe = full_eval(&k0, 400, 3);
        for x in [0u64, 150, 300, 399] {
            assert_eq!(fe[x as usize], eval(&k0, x, 3));
        }
    }

    #[test]
    fn hints_differ_across_epochs() {
        let mut rng = Rng::new(34);
        let (_k0, _k1, st) = gen(8, 4, &9u64, rng.gen_seed(), rng.gen_seed());
        assert_ne!(next_hint(&st, &9u64, 1), next_hint(&st, &9u64, 2));
    }
}
