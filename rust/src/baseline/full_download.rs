//! Trivial PSR baseline: download the entire model.
//!
//! §2's non-triviality yardstick for retrieval — `m·⌈log 𝔾⌉` downlink
//! bits, zero uplink (beyond the request). The paper notes FL clients are
//! usually uplink-constrained, which is why PSR matters less than SSA.

use crate::group::Group;

/// Downlink bits to ship the whole weight vector.
pub fn download_bits<G: Group>(m: usize) -> usize {
    m * G::bit_len()
}

/// The trivial protocol itself (returns a copy — the client "selects
/// locally").
pub fn retrieve_all<G: Group>(weights: &[G]) -> Vec<G> {
    weights.to_vec()
}

/// Client-side local selection after the trivial download.
pub fn select_local<G: Group>(downloaded: &[G], selections: &[u64]) -> Vec<G> {
    selections
        .iter()
        .map(|&s| downloaded[s as usize].clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trivial_selection() {
        let w: Vec<u64> = (0..100).map(|i| i * 3).collect();
        let d = retrieve_all(&w);
        assert_eq!(select_local(&d, &[0, 7, 99]), vec![0, 21, 297]);
        assert_eq!(download_bits::<u128>(1 << 20) / 8 / 1024 / 1024, 16);
    }
}
