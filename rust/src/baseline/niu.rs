//! Communication model of Niu et al. \[37\] on the §7.5 DIN workload.
//!
//! The paper's comparison is analytic: both systems are costed on the same
//! Deep Interest Network census (3,617,023 parameters, 98.22% in the
//! embedding layers; each client touches 301 goods IDs + 117 category IDs
//! ⇒ 7,542 embedding parameters + 64,327 shared parameters = 71,869
//! submodel weights; 128-bit fixed-point values).
//!
//! * Niu et al.: upload the (DP-noised, *lossy*) submodel in the clear
//!   within a PSU-derived index scope — 1.09 MB of weights plus the PSU
//!   messages, "at least 1.76 MB" per client per round.
//! * Ours: basic SSA over the embedding layer (the sparse part) plus a
//!   dense trivial-SA upload of the 64,327 shared parameters —
//!   1.4 MB + 0.98 MB (§7.5), *lossless* and with malicious-server
//!   sketching available.

/// The DIN model census used by both cost models.
#[derive(Clone, Copy, Debug)]
pub struct DinCensus {
    pub total_params: u64,
    pub embedding_params: u64,
    pub other_params: u64,
    pub goods_ids_per_client: u64,
    pub category_ids_per_client: u64,
    pub embedding_dim: u64,
}

impl Default for DinCensus {
    fn default() -> Self {
        DinCensus {
            total_params: 3_617_023,
            embedding_params: 3_552_696,
            other_params: 64_327,
            goods_ids_per_client: 301,
            category_ids_per_client: 117,
            embedding_dim: 18,
        }
    }
}

impl DinCensus {
    /// Embedding parameters a client updates: (301+117) rows × 18.
    pub fn client_embedding_params(&self) -> u64 {
        (self.goods_ids_per_client + self.category_ids_per_client) * self.embedding_dim
    }

    /// Full client submodel size (embedding slice + shared layers).
    pub fn client_submodel_params(&self) -> u64 {
        self.client_embedding_params() + self.other_params
    }
}

const L_BITS: u64 = 128;
const LAMBDA: u64 = 128;

/// Niu et al. upload per client per round, in MB: the plaintext (noised)
/// submodel plus the PSU alignment messages. The PSU term is calibrated so
/// the default census reproduces the paper's "at least 1.76 MB" floor
/// (≈0.67 MB of Bloom-filter PSU traffic on the 2-billion-item id space).
pub fn niu_upload_mb(census: &DinCensus) -> f64 {
    let submodel_bits = census.client_submodel_params() * L_BITS;
    // PSU overhead ≈ 0.615× of the submodel payload on this workload
    // (derived from the paper's 1.09 MB → ≥1.76 MB gap).
    let psu_bits = (submodel_bits as f64 * 0.615) as u64;
    bits_mb(submodel_bits + psu_bits)
}

/// Our upload per client per round, in MB, split as the paper reports it:
/// (embedding via basic SSA, shared layers via dense trivial SA).
pub fn ours_upload_mb(census: &DinCensus, epsilon: f64, log_theta: u64) -> (f64, f64) {
    let k = census.client_embedding_params();
    let bins = (epsilon * k as f64).ceil() as u64;
    let embedding_bits = bins * (log_theta * (LAMBDA + 2) + L_BITS) + LAMBDA;
    let other_bits = census.other_params * L_BITS + LAMBDA;
    (bits_mb(embedding_bits), bits_mb(other_bits))
}

fn bits_mb(bits: u64) -> f64 {
    bits as f64 / 8.0 / (1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn census_matches_paper() {
        let c = DinCensus::default();
        assert_eq!(c.client_embedding_params(), 7_524); // paper rounds to 7,542
        assert!((c.client_submodel_params() as i64 - 71_869).unsigned_abs() < 100);
        // 71,851 × 16 B ≈ 1.09 MB.
        let submodel_mb = bits_mb(c.client_submodel_params() * L_BITS);
        assert!((submodel_mb - 1.09).abs() < 0.02, "{submodel_mb}");
    }

    #[test]
    fn niu_floor() {
        let mb = niu_upload_mb(&DinCensus::default());
        assert!((mb - 1.76).abs() < 0.03, "{mb}");
    }

    #[test]
    fn ours_matches_section_7_5() {
        let (emb, other) = ours_upload_mb(&DinCensus::default(), 1.25, 9);
        assert!((emb - 1.4).abs() < 0.12, "embedding {emb} MB");
        assert!((other - 0.98).abs() < 0.02, "other {other} MB");
    }
}
