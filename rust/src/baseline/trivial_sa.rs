//! Trivial full-model two-server secure aggregation.
//!
//! Each client expands its sparse update to the dense length-`m` vector,
//! masks it with `PRG(seed)`, and uploads the seed (λ bits) to `S_0` and
//! the masked vector (`m·l` bits) to `S_1`. The servers' shares sum to the
//! client's dense update. Upload: `m·⌈log 𝔾⌉ + λ` bits — the Table 6
//! "Secure Aggregation" row and the non-triviality yardstick of §6.

use crate::crypto::prg::{expand_stream, Seed};
use crate::group::Group;

/// A client's trivial-SA upload: λ-bit seed to `S_0`, dense masked vector
/// to `S_1`.
pub struct TrivialUpload<G: Group> {
    pub seed: Seed,
    pub masked: Vec<G>,
}

/// Expand the PRG share `S_0` reconstructs from the seed.
pub fn seed_share<G: Group>(seed: &Seed, m: usize) -> Vec<G> {
    let stream = expand_stream(seed, m * 16);
    (0..m)
        .map(|i| {
            let mut s = [0u8; 16];
            s.copy_from_slice(&stream[i * 16..(i + 1) * 16]);
            G::convert(&s)
        })
        .collect()
}

/// Build a client's upload from its sparse update.
pub fn client_upload<G: Group>(
    m: usize,
    selections: &[u64],
    deltas: &[G],
    seed: Seed,
) -> TrivialUpload<G> {
    let mut dense = vec![G::zero(); m];
    for (&i, d) in selections.iter().zip(deltas) {
        dense[i as usize].add_assign(d);
    }
    let mask = seed_share::<G>(&seed, m);
    let masked = dense
        .iter()
        .zip(&mask)
        .map(|(v, r)| v.sub(r))
        .collect();
    TrivialUpload { seed, masked }
}

/// Upload size in bits: `m·⌈log 𝔾⌉ + λ`.
pub fn upload_bits<G: Group>(m: usize) -> usize {
    m * G::bit_len() + 128
}

/// Server-side aggregation: `S_0` sums PRG shares, `S_1` sums masked
/// vectors; reconstruction adds the two.
pub fn aggregate<G: Group>(m: usize, uploads: &[TrivialUpload<G>]) -> Vec<G> {
    let mut s0 = vec![G::zero(); m];
    let mut s1 = vec![G::zero(); m];
    for u in uploads {
        for (acc, v) in s0.iter_mut().zip(seed_share::<G>(&u.seed, m)) {
            acc.add_assign(&v);
        }
        for (acc, v) in s1.iter_mut().zip(&u.masked) {
            acc.add_assign(v);
        }
    }
    s0.iter().zip(&s1).map(|(a, b)| a.add(b)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;

    #[test]
    fn dense_aggregation_correct() {
        let m = 256;
        let mut rng = Rng::new(130);
        let mut expected = vec![0u64; m];
        let uploads: Vec<TrivialUpload<u64>> = (0..4)
            .map(|_| {
                let sel = rng.sample_distinct(10, m as u64);
                let deltas: Vec<u64> = sel.iter().map(|&x| x + 1).collect();
                for (&i, &d) in sel.iter().zip(&deltas) {
                    expected[i as usize] = expected[i as usize].wrapping_add(d);
                }
                client_upload(m, &sel, &deltas, rng.gen_seed())
            })
            .collect();
        assert_eq!(aggregate(m, &uploads), expected);
    }

    #[test]
    fn masked_vector_is_not_plaintext() {
        let m = 128;
        let mut rng = Rng::new(131);
        let sel = vec![3u64];
        let deltas = vec![42u64];
        let up = client_upload::<u64>(m, &sel, &deltas, rng.gen_seed());
        let zeros = up.masked.iter().filter(|v| **v == 0).count();
        assert!(zeros < 3, "mask failed: {zeros} zeros");
    }

    #[test]
    fn paper_upload_formula() {
        // Table 6 anchor: m = 2^15, l = 128 ⇒ 0.5 MB.
        let bits = upload_bits::<u128>(1 << 15);
        let mb = crate::metrics::bits_to_mb(bits);
        assert!((mb - 0.5).abs() < 0.01, "{mb} MB");
    }
}
