//! Baselines the paper compares against.
//!
//! * [`trivial_sa`] — the "naïve secure aggregation protocol in the
//!   two-server setting" of Table 6: dense additive masking of the full
//!   model (`m·l + λ` bits of client upload).
//! * [`full_download`] — the trivial PIR answer to PSR: ship all of `w`.
//! * [`niu`] — communication cost model of Niu et al. \[37\] on the DIN
//!   recommendation workload (§7.5).

pub mod full_download;
pub mod niu;
pub mod trivial_sa;
