//! Seeded byte-level fuzzing support for the codec test suites.
//!
//! The crate has no external fuzzing dependency, so this module supplies
//! the two things a sustained codec fuzz harness needs and nothing more:
//! a deterministic mutation engine over a corpus of valid encodings
//! (truncate / bit-flip / overwrite / insert / duplicate-splice), and an
//! environment knob (`FSL_FUZZ_CASES`) so CI smoke runs stay bounded
//! while a long local soak can crank the case count up without touching
//! code. Everything is driven by [`crate::crypto::rng::Rng`], so a
//! failing case reproduces from its printed seed alone.

use crate::crypto::rng::Rng;

/// The environment variable that overrides the per-test case count.
pub const CASES_ENV: &str = "FSL_FUZZ_CASES";

/// A deterministic fuzz-case generator: every sequence of calls is a
/// pure function of the construction seed.
pub struct Fuzzer {
    rng: Rng,
}

impl Fuzzer {
    /// A generator whose whole output stream is fixed by `seed`.
    pub fn new(seed: u64) -> Self {
        Fuzzer {
            rng: Rng::new(seed),
        }
    }

    /// The number of cases a fuzz test should run: `FSL_FUZZ_CASES` when
    /// set to a positive integer, `default` otherwise. CI smoke jobs set
    /// a small bound; local soaks raise it.
    pub fn cases_from_env(default: usize) -> usize {
        std::env::var(CASES_ENV)
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(default)
    }

    /// Draw one `u64` from the generator (exposed so tests can derive
    /// seeds, sizes, and choices from the same deterministic stream).
    pub fn next_u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// A uniformly random byte string of length `0..=max_len` — the
    /// "pure garbage" side of the harness, for decoders that must reject
    /// arbitrary input without panicking.
    pub fn blob(&mut self, max_len: usize) -> Vec<u8> {
        let len = self.rng.gen_range(max_len as u64 + 1) as usize;
        (0..len).map(|_| self.rng.next_u64() as u8).collect()
    }

    /// One structured mutation of `base`: truncate, flip a bit,
    /// overwrite a byte, insert a byte, or duplicate an internal span.
    /// Always returns bytes different from `base` (mutations that would
    /// be identity — e.g. duplicating an empty span — are re-drawn as a
    /// bit flip), so hash-protected codecs can assert outright rejection.
    pub fn mutate(&mut self, base: &[u8]) -> Vec<u8> {
        if base.is_empty() {
            // Nothing to mutate structurally; grow instead.
            return vec![self.rng.next_u64() as u8];
        }
        let len = base.len() as u64;
        match self.rng.gen_range(5) {
            // Truncate to a strict prefix (possibly empty).
            0 => base[..self.rng.gen_range(len) as usize].to_vec(),
            // Flip one bit in place.
            1 => self.flip_bit(base),
            // Overwrite one byte with a value guaranteed to differ.
            2 => {
                let mut out = base.to_vec();
                let at = self.rng.gen_range(len) as usize;
                out[at] ^= 1 + (self.rng.next_u64() % 255) as u8;
                out
            }
            // Insert one random byte at a random position.
            3 => {
                let mut out = base.to_vec();
                let at = self.rng.gen_range(len + 1) as usize;
                out.insert(at, self.rng.next_u64() as u8);
                out
            }
            // Duplicate a random internal span after itself.
            _ => {
                let start = self.rng.gen_range(len) as usize;
                let end = start + 1 + self.rng.gen_range(len - start as u64) as usize;
                let mut out = base.to_vec();
                let span: Vec<u8> = base[start..end].to_vec();
                out.splice(end..end, span);
                out
            }
        }
    }

    fn flip_bit(&mut self, base: &[u8]) -> Vec<u8> {
        let mut out = base.to_vec();
        let at = self.rng.gen_range(base.len() as u64) as usize;
        out[at] ^= 1 << self.rng.gen_range(8);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutations_are_deterministic_per_seed() {
        let base: Vec<u8> = (0..64).collect();
        let run = |seed| {
            let mut f = Fuzzer::new(seed);
            (0..32).map(|_| f.mutate(&base)).collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9), "same seed must replay the same cases");
        assert_ne!(run(9), run(10), "different seeds must diverge");
    }

    #[test]
    fn mutations_always_differ_from_the_base() {
        let base: Vec<u8> = (0..17).map(|i| i * 3).collect();
        let mut f = Fuzzer::new(1234);
        for _ in 0..2000 {
            assert_ne!(f.mutate(&base), base);
        }
    }

    #[test]
    fn mutating_empty_input_grows_it() {
        let mut f = Fuzzer::new(7);
        assert!(!f.mutate(&[]).is_empty());
    }

    #[test]
    fn blobs_respect_the_length_bound() {
        let mut f = Fuzzer::new(5);
        for _ in 0..200 {
            assert!(f.blob(33).len() <= 33);
        }
    }
}
