//! Artifact execution.
//!
//! The original seed targeted the PJRT C API through the `xla` bindings:
//! each `*.hlo.txt` artifact was parsed, compiled once, and invoked from
//! the round loop. Those bindings cannot be vendored into this offline
//! workspace, so execution is served by the pure-Rust **reference
//! backend** ([`super::reference`]) — the same operation graphs as the L2
//! JAX definitions, validated against `jax.grad` (see
//! `python/tests/test_kernels.py` for the Python-side oracle tests).
//!
//! The artifact *manifest* contract is unchanged: when
//! `artifacts/manifest.json` exists (written by `python -m compile.aot`),
//! its shapes and metadata drive validation; when it does not, the
//! built-in manifest mirroring `aot.py` is used, so a clean checkout
//! works with no Python step.

use super::artifact::ArtifactManifest;
use super::reference;
use anyhow::{anyhow, Result};

/// Output of one training-step invocation.
#[derive(Debug)]
pub struct TrainStep {
    /// Mean cross-entropy over the batch.
    pub loss: f32,
    /// Flat parameter gradient (same length as the parameter vector).
    pub grad: Vec<f32>,
}

/// The runtime: a parsed manifest plus the reference compute backend.
///
/// `Executor` is `Sync`; the two server threads share one instance.
pub struct Executor {
    manifest: ArtifactManifest,
}

impl Executor {
    /// Open an artifact directory. A missing `manifest.json` falls back
    /// to the built-in manifest (identical to what `aot.py` writes); a
    /// *malformed* one is an error — silent fallback would mask a broken
    /// artifact build.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref();
        let manifest = if dir.join("manifest.json").exists() {
            ArtifactManifest::load(dir)?
        } else {
            ArtifactManifest::builtin(dir)
        };
        Ok(Executor { manifest })
    }

    /// The parsed (or built-in) manifest.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    fn embbag_dims(&self, name: &str) -> reference::EmbbagDims {
        let d = reference::EmbbagDims::default_census();
        reference::EmbbagDims {
            vocab: self
                .manifest
                .int(name, "vocab")
                .map(|v| v as usize)
                .unwrap_or(d.vocab),
            emb_dim: self
                .manifest
                .int(name, "emb_dim")
                .map(|v| v as usize)
                .unwrap_or(d.emb_dim),
            classes: self
                .manifest
                .int(name, "classes")
                .map(|v| v as usize)
                .unwrap_or(d.classes),
            ..d
        }
    }

    /// Run a `*_grad` training-step artifact: `(flat, x, y1h) → (loss,
    /// grad)`.
    pub fn train_step(&self, name: &str, flat: &[f32], x: &[f32], y1h: &[f32]) -> Result<TrainStep> {
        let meta = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} missing"))?;
        let shapes = &meta.arg_shapes;
        anyhow::ensure!(shapes.len() == 3, "{name}: expected 3 args");
        anyhow::ensure!(shapes[1].len() == 2 && shapes[2].len() == 2, "{name}: rank-2 batches");
        anyhow::ensure!(flat.len() == shapes[0].iter().product::<usize>(), "{name}: params len");
        anyhow::ensure!(x.len() == shapes[1].iter().product::<usize>(), "{name}: x len");
        anyhow::ensure!(y1h.len() == shapes[2].iter().product::<usize>(), "{name}: y len");
        let batch = shapes[1][0];

        let (loss, grad) = if name.starts_with("mlp") {
            anyhow::ensure!(
                flat.len() == reference::mlp_num_params()
                    && shapes[1][1] == reference::MLP_LAYERS[0].0
                    && shapes[2][1] == reference::MLP_LAYERS[2].1,
                "{name}: shapes do not match the MLP architecture"
            );
            reference::mlp_grad(flat, x, y1h, batch)
        } else if name.starts_with("embbag") {
            let dims = self.embbag_dims(name);
            anyhow::ensure!(
                flat.len() == dims.num_params()
                    && shapes[1][1] == dims.vocab
                    && shapes[2][1] == dims.classes,
                "{name}: shapes do not match the embedding-bag architecture"
            );
            reference::embbag_grad(&dims, flat, x, y1h, batch)
        } else {
            return Err(anyhow!("{name}: no reference implementation for this artifact"));
        };
        Ok(TrainStep { loss, grad })
    }

    /// Run an `*_infer` artifact: `(flat, x) → logits` (row-major,
    /// `batch × classes`).
    pub fn infer(&self, name: &str, flat: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let meta = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} missing"))?;
        let shapes = &meta.arg_shapes;
        anyhow::ensure!(shapes.len() == 2, "{name}: expected 2 args");
        anyhow::ensure!(shapes[1].len() == 2, "{name}: rank-2 batch");
        anyhow::ensure!(flat.len() == shapes[0].iter().product::<usize>(), "{name}: params len");
        anyhow::ensure!(x.len() == shapes[1].iter().product::<usize>(), "{name}: x len");
        let batch = shapes[1][0];

        if name.starts_with("mlp") {
            anyhow::ensure!(
                flat.len() == reference::mlp_num_params()
                    && shapes[1][1] == reference::MLP_LAYERS[0].0,
                "{name}: shapes do not match the MLP architecture"
            );
            Ok(reference::mlp_forward(flat, x, batch))
        } else if name.starts_with("embbag") {
            let dims = self.embbag_dims(name);
            anyhow::ensure!(
                flat.len() == dims.num_params() && shapes[1][1] == dims.vocab,
                "{name}: shapes do not match the embedding-bag architecture"
            );
            Ok(reference::embbag_forward(&dims, flat, x, batch))
        } else {
            Err(anyhow!("{name}: no reference implementation for this artifact"))
        }
    }

    /// Run the `binned_ip` server artifact on one `(BINS, THETA)` slab.
    /// Inputs are row-major u64 slabs; output is the per-bin answer.
    pub fn binned_ip(&self, weights_slab: &[u64], share_slab: &[u64]) -> Result<Vec<u64>> {
        let (bins, theta) = self.binned_ip_shape()?;
        let expect = bins * theta;
        anyhow::ensure!(weights_slab.len() == expect, "weights slab size");
        anyhow::ensure!(share_slab.len() == expect, "share slab size");
        Ok(reference::binned_ip(weights_slab, share_slab, bins, theta))
    }

    /// Slab geometry of the `binned_ip` artifact: (bins, theta).
    pub fn binned_ip_shape(&self) -> Result<(usize, usize)> {
        Ok((
            self.manifest.int("binned_ip", "bins")? as usize,
            self.manifest.int("binned_ip", "theta")? as usize,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_fallback_without_artifacts() {
        let exec = Executor::new("/definitely/no/artifacts/here").unwrap();
        assert!(exec.manifest().builtin);
        assert_eq!(exec.manifest().int("mlp_grad", "params").unwrap(), 1_863_690);
        assert_eq!(exec.binned_ip_shape().unwrap(), (2048, 32));
    }

    #[test]
    fn malformed_manifest_is_an_error_not_a_fallback() {
        let dir = std::env::temp_dir().join("fsl_bad_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
        assert!(Executor::new(&dir).is_err());
    }
}
