//! PJRT execution of the AOT artifacts.
//!
//! One `PjRtClient` per process; each artifact compiles once
//! (`HloModuleProto::from_text_file` → `XlaComputation` → compile) and is
//! then invoked from the round loop with concrete literals.

use super::artifact::ArtifactManifest;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// Output of one training-step invocation.
#[derive(Debug)]
pub struct TrainStep {
    pub loss: f32,
    pub grad: Vec<f32>,
}

/// The PJRT runtime: client + compiled executables, keyed by artifact
/// name. Compilation is lazy and cached; `Executor` is `Sync` so the two
/// server threads can share one instance.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    compiled: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Executor {
    /// Create a CPU PJRT client over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Executor {
            client,
            manifest,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        // Compile on first use.
        {
            let mut cache = self.compiled.lock().unwrap();
            if !cache.contains_key(name) {
                let path = self.manifest.hlo_path(name)?;
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
                cache.insert(name.to_string(), exe);
            }
        }
        let cache = self.compiled.lock().unwrap();
        let exe = cache.get(name).expect("just inserted");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        Ok(result)
    }

    /// Run a `*_grad` training-step artifact: `(flat, x, y1h) → (loss,
    /// grad)`.
    pub fn train_step(&self, name: &str, flat: &[f32], x: &[f32], y1h: &[f32]) -> Result<TrainStep> {
        let meta = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} missing"))?;
        let shapes = &meta.arg_shapes;
        anyhow::ensure!(shapes.len() == 3, "{name}: expected 3 args");
        anyhow::ensure!(flat.len() == shapes[0][0], "{name}: params len");
        anyhow::ensure!(x.len() == shapes[1].iter().product::<usize>(), "{name}: x len");
        anyhow::ensure!(y1h.len() == shapes[2].iter().product::<usize>(), "{name}: y len");

        let lit_flat = xla::Literal::vec1(flat);
        let lit_x = xla::Literal::vec1(x)
            .reshape(&[shapes[1][0] as i64, shapes[1][1] as i64])
            .context("reshape x")?;
        let lit_y = xla::Literal::vec1(y1h)
            .reshape(&[shapes[2][0] as i64, shapes[2][1] as i64])
            .context("reshape y")?;

        let out = self.run(name, &[lit_flat, lit_x, lit_y])?;
        let (loss_lit, grad_lit) = out.to_tuple2().map_err(|e| anyhow!("tuple2: {e:?}"))?;
        let loss = loss_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let grad = grad_lit.to_vec::<f32>().map_err(|e| anyhow!("grad: {e:?}"))?;
        Ok(TrainStep { loss, grad })
    }

    /// Run the `binned_ip` server artifact on one `(BINS, THETA)` slab.
    /// Inputs are row-major u64 slabs; output is the per-bin answer.
    pub fn binned_ip(&self, weights_slab: &[u64], share_slab: &[u64]) -> Result<Vec<u64>> {
        let bins = self.manifest.int("binned_ip", "bins")? as i64;
        let theta = self.manifest.int("binned_ip", "theta")? as i64;
        let expect = (bins * theta) as usize;
        anyhow::ensure!(weights_slab.len() == expect, "weights slab size");
        anyhow::ensure!(share_slab.len() == expect, "share slab size");
        let w = xla::Literal::vec1(weights_slab)
            .reshape(&[bins, theta])
            .context("reshape w")?;
        let s = xla::Literal::vec1(share_slab)
            .reshape(&[bins, theta])
            .context("reshape s")?;
        let out = self.run("binned_ip", &[w, s])?;
        let ans = out.to_tuple1().map_err(|e| anyhow!("tuple1: {e:?}"))?;
        ans.to_vec::<u64>().map_err(|e| anyhow!("answers: {e:?}"))
    }

    /// Run an `*_infer` artifact: `(flat, x) → logits` (row-major,
    /// `batch × classes`).
    pub fn infer(&self, name: &str, flat: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let meta = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} missing"))?;
        let shapes = meta.arg_shapes.clone();
        anyhow::ensure!(shapes.len() == 2, "{name}: expected 2 args");
        anyhow::ensure!(flat.len() == shapes[0][0], "{name}: params len");
        anyhow::ensure!(x.len() == shapes[1].iter().product::<usize>(), "{name}: x len");
        let lit_flat = xla::Literal::vec1(flat);
        let lit_x = xla::Literal::vec1(x)
            .reshape(&[shapes[1][0] as i64, shapes[1][1] as i64])
            .context("reshape x")?;
        let out = self.run(name, &[lit_flat, lit_x])?;
        let logits = out.to_tuple1().map_err(|e| anyhow!("tuple1: {e:?}"))?;
        logits.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))
    }

    /// Slab geometry of the `binned_ip` artifact: (bins, theta).
    pub fn binned_ip_shape(&self) -> Result<(usize, usize)> {
        Ok((
            self.manifest.int("binned_ip", "bins")? as usize,
            self.manifest.int("binned_ip", "theta")? as usize,
        ))
    }
}

#[cfg(test)]
mod tests {
    // Executor tests live in rust/tests/runtime_integration.rs — they need
    // the artifacts built by `make artifacts`.
}
