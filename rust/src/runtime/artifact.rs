//! Artifact manifest parsing.
//!
//! `aot.py` writes `manifest.json` next to the `*.hlo.txt` files. The
//! vendored crate set has no serde façade, so we parse the (flat,
//! machine-generated) JSON with a minimal tokenizer — enough for the
//! schema we ourselves emit, rejecting anything unexpected.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Metadata for one artifact.
#[derive(Clone, Debug, Default)]
pub struct ArtifactMeta {
    /// HLO text filename relative to the artifact directory.
    pub file: String,
    /// Artifact kind: `train_step`, `infer`, or `server_ip`.
    pub kind: String,
    /// Flat key/value metadata (ints kept as i64).
    pub ints: BTreeMap<String, i64>,
    /// Row-major shape of each positional argument.
    pub arg_shapes: Vec<Vec<usize>>,
    /// Dtype name of each positional argument (e.g. `float32`).
    pub arg_dtypes: Vec<String>,
}

/// The parsed manifest: artifact name → metadata.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    /// Directory the artifacts live in (or were expected in).
    pub dir: PathBuf,
    /// Artifact name → metadata.
    pub entries: BTreeMap<String, ArtifactMeta>,
    /// True when this is the built-in manifest (no `manifest.json` on
    /// disk — the reference executor needs no HLO files).
    pub builtin: bool,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
        let value = json::parse(&mut json::Lexer::new(&text))?;
        let top = value.as_object().ok_or_else(|| anyhow!("manifest: expected object"))?;
        let mut entries = BTreeMap::new();
        for (name, v) in top {
            let obj = v
                .as_object()
                .ok_or_else(|| anyhow!("manifest[{name}]: expected object"))?;
            let mut meta = ArtifactMeta::default();
            for (k, v) in obj {
                match (k.as_str(), v) {
                    ("file", json::Value::Str(s)) => meta.file = s.clone(),
                    ("kind", json::Value::Str(s)) => meta.kind = s.clone(),
                    ("arg_shapes", json::Value::Arr(rows)) => {
                        for row in rows {
                            let dims = row
                                .as_arr()
                                .ok_or_else(|| anyhow!("arg_shapes: expected array"))?
                                .iter()
                                .map(|d| d.as_i64().map(|x| x as usize))
                                .collect::<Option<Vec<_>>>()
                                .ok_or_else(|| anyhow!("arg_shapes: expected ints"))?;
                            meta.arg_shapes.push(dims);
                        }
                    }
                    ("arg_dtypes", json::Value::Arr(items)) => {
                        for it in items {
                            if let json::Value::Str(s) = it {
                                meta.arg_dtypes.push(s.clone());
                            }
                        }
                    }
                    (_, json::Value::Num(n)) => {
                        meta.ints.insert(k.clone(), *n as i64);
                    }
                    (_, json::Value::Arr(_) | json::Value::Str(_)) => {} // other metadata: ignored
                    _ => {}
                }
            }
            entries.insert(name.clone(), meta);
        }
        Ok(ArtifactManifest {
            dir,
            entries,
            builtin: false,
        })
    }

    /// The built-in manifest — byte-for-byte the same schema `aot.py`
    /// writes for the default model census, so a clean checkout runs
    /// with no Python step. Shapes/metadata per artifact:
    ///
    /// | artifact       | kind        | key facts                          |
    /// |----------------|-------------|------------------------------------|
    /// | `mlp_grad`     | train_step  | 1,863,690 params, batch 50         |
    /// | `mlp_infer`    | infer       | 10 classes                         |
    /// | `embbag_grad`  | train_step  | 150,214 params, batch 64, V=8256   |
    /// | `embbag_infer` | infer       | 6 classes                          |
    /// | `binned_ip`    | server_ip   | 2048 × 32 slab                     |
    pub fn builtin(dir: impl AsRef<Path>) -> Self {
        const MLP_PARAMS: i64 = 1_863_690;
        const MLP_BATCH: i64 = 50;
        const EMB_PARAMS: i64 = 150_214;
        const EMB_BATCH: i64 = 64;
        const EMB_VOCAB: i64 = 8_256;
        const EMB_DIM: i64 = 18;
        const IP_BINS: i64 = 2_048;
        const IP_THETA: i64 = 32;

        fn f32v(n: usize) -> Vec<String> {
            vec!["float32".to_string(); n]
        }
        fn put(
            entries: &mut BTreeMap<String, ArtifactMeta>,
            name: &str,
            kind: &str,
            ints: &[(&str, i64)],
            arg_shapes: Vec<Vec<usize>>,
            arg_dtypes: Vec<String>,
        ) {
            entries.insert(
                name.to_string(),
                ArtifactMeta {
                    file: format!("{name}.hlo.txt"),
                    kind: kind.to_string(),
                    ints: ints.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
                    arg_shapes,
                    arg_dtypes,
                },
            );
        }
        let mut entries = BTreeMap::new();
        put(
            &mut entries,
            "mlp_grad",
            "train_step",
            &[("params", MLP_PARAMS), ("batch", MLP_BATCH)],
            vec![
                vec![MLP_PARAMS as usize],
                vec![MLP_BATCH as usize, 784],
                vec![MLP_BATCH as usize, 10],
            ],
            f32v(3),
        );
        put(
            &mut entries,
            "mlp_infer",
            "infer",
            &[("params", MLP_PARAMS), ("batch", MLP_BATCH), ("classes", 10)],
            vec![vec![MLP_PARAMS as usize], vec![MLP_BATCH as usize, 784]],
            f32v(2),
        );
        put(
            &mut entries,
            "embbag_grad",
            "train_step",
            &[
                ("params", EMB_PARAMS),
                ("batch", EMB_BATCH),
                ("vocab", EMB_VOCAB),
                ("emb_dim", EMB_DIM),
                ("embedding_params", EMB_VOCAB * EMB_DIM),
            ],
            vec![
                vec![EMB_PARAMS as usize],
                vec![EMB_BATCH as usize, EMB_VOCAB as usize],
                vec![EMB_BATCH as usize, 6],
            ],
            f32v(3),
        );
        put(
            &mut entries,
            "embbag_infer",
            "infer",
            &[
                ("params", EMB_PARAMS),
                ("batch", EMB_BATCH),
                ("vocab", EMB_VOCAB),
                ("emb_dim", EMB_DIM),
                ("classes", 6),
            ],
            vec![
                vec![EMB_PARAMS as usize],
                vec![EMB_BATCH as usize, EMB_VOCAB as usize],
            ],
            f32v(2),
        );
        put(
            &mut entries,
            "binned_ip",
            "server_ip",
            &[("bins", IP_BINS), ("theta", IP_THETA)],
            vec![
                vec![IP_BINS as usize, IP_THETA as usize],
                vec![IP_BINS as usize, IP_THETA as usize],
            ],
            vec!["uint64".to_string(); 2],
        );
        ArtifactManifest {
            dir: dir.as_ref().to_path_buf(),
            entries,
            builtin: true,
        }
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let meta = self
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?;
        Ok(self.dir.join(&meta.file))
    }

    /// Integer metadata field.
    pub fn int(&self, name: &str, key: &str) -> Result<i64> {
        self.entries
            .get(name)
            .and_then(|m| m.ints.get(key))
            .copied()
            .ok_or_else(|| anyhow!("manifest[{name}].{key} missing"))
    }
}

/// Minimal JSON parser (objects / arrays / strings / numbers / null-bool),
/// sufficient for the machine-written manifest.
mod json {
    use anyhow::{anyhow, Result};

    #[derive(Debug, Clone)]
    pub enum Value {
        Obj(Vec<(String, Value)>),
        Arr(Vec<Value>),
        Str(String),
        Num(f64),
        Bool(#[allow(dead_code)] bool),
        Null,
    }

    impl Value {
        pub fn as_object(&self) -> Option<&[(String, Value)]> {
            match self {
                Value::Obj(o) => Some(o),
                _ => None,
            }
        }
        pub fn as_arr(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(a) => Some(a),
                _ => None,
            }
        }
        pub fn as_i64(&self) -> Option<i64> {
            match self {
                Value::Num(n) => Some(*n as i64),
                _ => None,
            }
        }
    }

    pub struct Lexer<'a> {
        s: &'a [u8],
        pos: usize,
    }

    impl<'a> Lexer<'a> {
        pub fn new(s: &'a str) -> Self {
            Lexer { s: s.as_bytes(), pos: 0 }
        }
        fn skip_ws(&mut self) {
            while self.pos < self.s.len() && self.s[self.pos].is_ascii_whitespace() {
                self.pos += 1;
            }
        }
        fn peek(&mut self) -> Option<u8> {
            self.skip_ws();
            self.s.get(self.pos).copied()
        }
        fn bump(&mut self) -> Option<u8> {
            let c = self.peek()?;
            self.pos += 1;
            Some(c)
        }
        fn expect(&mut self, c: u8) -> Result<()> {
            match self.bump() {
                Some(got) if got == c => Ok(()),
                got => Err(anyhow!("expected {:?}, got {:?} at {}", c as char, got, self.pos)),
            }
        }
        fn string(&mut self) -> Result<String> {
            self.expect(b'"')?;
            let mut out = String::new();
            loop {
                match self.s.get(self.pos).copied() {
                    Some(b'"') => {
                        self.pos += 1;
                        return Ok(out);
                    }
                    Some(b'\\') => {
                        self.pos += 1;
                        match self.s.get(self.pos).copied() {
                            Some(b'n') => out.push('\n'),
                            Some(b't') => out.push('\t'),
                            Some(c) => out.push(c as char),
                            None => return Err(anyhow!("eof in escape")),
                        }
                        self.pos += 1;
                    }
                    Some(c) => {
                        out.push(c as char);
                        self.pos += 1;
                    }
                    None => return Err(anyhow!("eof in string")),
                }
            }
        }
    }

    pub fn parse(lex: &mut Lexer) -> Result<Value> {
        match lex.peek().ok_or_else(|| anyhow!("unexpected eof"))? {
            b'{' => {
                lex.bump();
                let mut obj = Vec::new();
                if lex.peek() == Some(b'}') {
                    lex.bump();
                    return Ok(Value::Obj(obj));
                }
                loop {
                    let key = lex.string()?;
                    lex.expect(b':')?;
                    obj.push((key, parse(lex)?));
                    match lex.bump() {
                        Some(b',') => continue,
                        Some(b'}') => return Ok(Value::Obj(obj)),
                        c => return Err(anyhow!("bad object sep {c:?}")),
                    }
                }
            }
            b'[' => {
                lex.bump();
                let mut arr = Vec::new();
                if lex.peek() == Some(b']') {
                    lex.bump();
                    return Ok(Value::Arr(arr));
                }
                loop {
                    arr.push(parse(lex)?);
                    match lex.bump() {
                        Some(b',') => continue,
                        Some(b']') => return Ok(Value::Arr(arr)),
                        c => return Err(anyhow!("bad array sep {c:?}")),
                    }
                }
            }
            b'"' => Ok(Value::Str(lex.string()?)),
            b't' => {
                lex.pos += 4;
                Ok(Value::Bool(true))
            }
            b'f' => {
                lex.pos += 5;
                Ok(Value::Bool(false))
            }
            b'n' => {
                lex.pos += 4;
                Ok(Value::Null)
            }
            _ => {
                lex.skip_ws();
                let start = lex.pos;
                while lex
                    .s
                    .get(lex.pos)
                    .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
                {
                    lex.pos += 1;
                }
                let txt = std::str::from_utf8(&lex.s[start..lex.pos])?;
                Ok(Value::Num(txt.parse()?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_manifest_shape() {
        let dir = std::env::temp_dir().join("fsl_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"mlp_grad": {"file": "mlp_grad.hlo.txt", "kind": "train_step",
                 "params": 1863690, "batch": 50,
                 "arg_shapes": [[1863690], [50, 784], [50, 10]],
                 "arg_dtypes": ["float32", "float32", "float32"],
                 "inputs": ["flat_params", "x", "y_onehot"],
                 "outputs": ["loss", "grad"]}}"#,
        )
        .unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.int("mlp_grad", "params").unwrap(), 1_863_690);
        assert_eq!(m.entries["mlp_grad"].arg_shapes[1], vec![50, 784]);
        assert_eq!(m.entries["mlp_grad"].kind, "train_step");
        assert!(m.hlo_path("mlp_grad").unwrap().ends_with("mlp_grad.hlo.txt"));
        assert!(m.hlo_path("nope").is_err());
    }

    #[test]
    fn rejects_missing_manifest() {
        assert!(ArtifactManifest::load("/nonexistent/dir").is_err());
    }
}
