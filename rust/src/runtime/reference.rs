//! Pure-Rust reference implementations of the L1/L2 compute graphs.
//!
//! These mirror `python/compile/model.py` (the L2 JAX definitions) and
//! `python/compile/kernels/ref.py` (the L1 kernel oracles) operation for
//! operation; the backward passes were validated against `jax.grad` on
//! the real model definitions to ≤ 1e-8 max gradient error. They are the
//! always-available executor backend: the crate builds, tests, and trains
//! with no Python step and no AOT artifacts present.
//!
//! Dense matmuls skip zero left-hand entries — a no-op numerically (all
//! operands are finite) that makes the bag-of-words `bow @ emb` product
//! effectively sparse, exactly the access pattern the embedding-bag model
//! was chosen for.

/// `out[m×n] = a[m×k] @ b[k×n]` (row-major, f32, overwrite).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let out_row = &mut out[i * n..(i + 1) * n];
        for l in 0..k {
            let av = a[i * k + l];
            if av == 0.0 {
                continue;
            }
            let b_row = &b[l * n..(l + 1) * n];
            for (o, &bv) in out_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// `out[k×n] = aᵀ @ b` for `a[m×k]`, `b[m×n]` (the `dW = hᵀ·δ` gradient
/// products; also `bowᵀ·δe`, where the zero-skip makes it sparse).
pub fn matmul_at(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    out.fill(0.0);
    for i in 0..m {
        let b_row = &b[i * n..(i + 1) * n];
        for l in 0..k {
            let av = a[i * k + l];
            if av == 0.0 {
                continue;
            }
            let out_row = &mut out[l * n..(l + 1) * n];
            for (o, &bv) in out_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m×k] = a @ bᵀ` for `a[m×n]`, `b[k×n]` (the `δ·Wᵀ` back-propagated
/// error products).
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    for i in 0..m {
        let a_row = &a[i * n..(i + 1) * n];
        for j in 0..k {
            let b_row = &b[j * n..(j + 1) * n];
            let mut acc = 0.0f32;
            for (&x, &y) in a_row.iter().zip(b_row) {
                acc += x * y;
            }
            out[i * k + j] = acc;
        }
    }
}

/// Mean softmax cross-entropy over `logits[b×c]` against one-hot `y`,
/// plus its gradient `∂loss/∂logits = (softmax − y)/b`.
pub fn softmax_xent(logits: &[f32], y: &[f32], b: usize, c: usize) -> (f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), b * c);
    debug_assert_eq!(y.len(), b * c);
    let mut dlogits = vec![0.0f32; b * c];
    let mut loss = 0.0f32;
    for r in 0..b {
        let row = &logits[r * c..(r + 1) * c];
        let yrow = &y[r * c..(r + 1) * c];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for &v in row {
            denom += (v - max).exp();
        }
        let log_denom = denom.ln();
        for j in 0..c {
            let logp = (row[j] - max) - log_denom;
            loss -= yrow[j] * logp;
            let p = (row[j] - max).exp() / denom;
            dlogits[r * c + j] = (p - yrow[j]) / b as f32;
        }
    }
    (loss / b as f32, dlogits)
}

/// The Table-7 image classifier: a 784→1024→1024→10 ReLU MLP over a flat
/// parameter vector (layout `[W1|b1|W2|b2|W3|b3]`, matching `mlp_init`).
pub const MLP_LAYERS: [(usize, usize); 3] = [(784, 1024), (1024, 1024), (1024, 10)];

/// Flat parameter count of the MLP (1,863,690).
pub fn mlp_num_params() -> usize {
    MLP_LAYERS.iter().map(|(i, o)| i * o + o).sum()
}

fn mlp_forward_impl(flat: &[f32], x: &[f32], batch: usize, keep_acts: bool) -> Vec<Vec<f32>> {
    // acts[0] = input, acts[l] = post-activation of layer l.
    let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
    let mut off = 0usize;
    let last = MLP_LAYERS.len() - 1;
    for (li, &(i, o)) in MLP_LAYERS.iter().enumerate() {
        let w = &flat[off..off + i * o];
        let b = &flat[off + i * o..off + i * o + o];
        off += i * o + o;
        let mut z = vec![0.0f32; batch * o];
        matmul(acts.last().unwrap(), w, batch, i, o, &mut z);
        for r in 0..batch {
            for (zj, &bj) in z[r * o..(r + 1) * o].iter_mut().zip(b) {
                *zj += bj;
            }
        }
        if li < last {
            for v in &mut z {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        if keep_acts {
            acts.push(z);
        } else {
            acts = vec![z];
        }
    }
    acts
}

/// MLP logits for a batch (`flat` laid out as in `mlp_init`).
pub fn mlp_forward(flat: &[f32], x: &[f32], batch: usize) -> Vec<f32> {
    mlp_forward_impl(flat, x, batch, false).pop().unwrap()
}

/// One MLP training step: mean cross-entropy loss and the flat gradient.
pub fn mlp_grad(flat: &[f32], x: &[f32], y: &[f32], batch: usize) -> (f32, Vec<f32>) {
    let acts = mlp_forward_impl(flat, x, batch, true);
    let (_, classes) = MLP_LAYERS[MLP_LAYERS.len() - 1];
    let (loss, mut d) = softmax_xent(acts.last().unwrap(), y, batch, classes);

    let mut grad = vec![0.0f32; flat.len()];
    // Per-layer parameter offsets.
    let mut offs = [0usize; 3];
    let mut off = 0usize;
    for (li, &(i, o)) in MLP_LAYERS.iter().enumerate() {
        offs[li] = off;
        off += i * o + o;
    }
    for li in (0..MLP_LAYERS.len()).rev() {
        let (i, o) = MLP_LAYERS[li];
        let a = &acts[li];
        // dW = aᵀ · d ; db = column-sum of d.
        matmul_at(a, &d, batch, i, o, &mut grad[offs[li]..offs[li] + i * o]);
        for r in 0..batch {
            for (gb, &dv) in grad[offs[li] + i * o..offs[li] + i * o + o]
                .iter_mut()
                .zip(&d[r * o..(r + 1) * o])
            {
                *gb += dv;
            }
        }
        if li > 0 {
            // d_prev = d · Wᵀ, masked by the previous ReLU.
            let w = &flat[offs[li]..offs[li] + i * o];
            let mut d_prev = vec![0.0f32; batch * i];
            matmul_bt(&d, w, batch, o, i, &mut d_prev);
            for (dp, &av) in d_prev.iter_mut().zip(&acts[li][..]) {
                if av <= 0.0 {
                    *dp = 0.0;
                }
            }
            d = d_prev;
        }
    }
    (loss, grad)
}

/// The Table-8/9 text classifier: embedding-bag (V×τ table) → τ→64 ReLU
/// → 64→classes, over a flat parameter vector (layout
/// `[emb|W1|b1|W2|b2]`, matching `embbag_init`).
#[derive(Clone, Copy, Debug)]
pub struct EmbbagDims {
    /// Vocabulary size V.
    pub vocab: usize,
    /// Embedding dimension τ.
    pub emb_dim: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Output classes.
    pub classes: usize,
}

impl EmbbagDims {
    /// The paper's TREC-shaped default (8256 × 18 → 64 → 6).
    pub fn default_census() -> Self {
        EmbbagDims {
            vocab: 8256,
            emb_dim: 18,
            hidden: 64,
            classes: 6,
        }
    }

    /// Flat parameter count (150,214 for the default census).
    pub fn num_params(&self) -> usize {
        self.vocab * self.emb_dim
            + self.emb_dim * self.hidden
            + self.hidden
            + self.hidden * self.classes
            + self.classes
    }
}

struct EmbbagFwd {
    e: Vec<f32>,
    z1: Vec<f32>,
    h: Vec<f32>,
    logits: Vec<f32>,
}

fn embbag_forward_impl(dims: &EmbbagDims, flat: &[f32], bow: &[f32], batch: usize) -> EmbbagFwd {
    let (v, t, hid, c) = (dims.vocab, dims.emb_dim, dims.hidden, dims.classes);
    let emb = &flat[..v * t];
    let mut off = v * t;
    let w1 = &flat[off..off + t * hid];
    off += t * hid;
    let b1 = &flat[off..off + hid];
    off += hid;
    let w2 = &flat[off..off + hid * c];
    off += hid * c;
    let b2 = &flat[off..off + c];

    let mut e = vec![0.0f32; batch * t];
    matmul(bow, emb, batch, v, t, &mut e);
    let mut z1 = vec![0.0f32; batch * hid];
    matmul(&e, w1, batch, t, hid, &mut z1);
    for r in 0..batch {
        for (zj, &bj) in z1[r * hid..(r + 1) * hid].iter_mut().zip(b1) {
            *zj += bj;
        }
    }
    let h: Vec<f32> = z1.iter().map(|&z| z.max(0.0)).collect();
    let mut logits = vec![0.0f32; batch * c];
    matmul(&h, w2, batch, hid, c, &mut logits);
    for r in 0..batch {
        for (lj, &bj) in logits[r * c..(r + 1) * c].iter_mut().zip(b2) {
            *lj += bj;
        }
    }
    EmbbagFwd { e, z1, h, logits }
}

/// Embedding-bag logits for a bag-of-words batch.
pub fn embbag_forward(dims: &EmbbagDims, flat: &[f32], bow: &[f32], batch: usize) -> Vec<f32> {
    embbag_forward_impl(dims, flat, bow, batch).logits
}

/// One embedding-bag training step: mean loss and the flat gradient.
pub fn embbag_grad(
    dims: &EmbbagDims,
    flat: &[f32],
    bow: &[f32],
    y: &[f32],
    batch: usize,
) -> (f32, Vec<f32>) {
    let (v, t, hid, c) = (dims.vocab, dims.emb_dim, dims.hidden, dims.classes);
    let fwd = embbag_forward_impl(dims, flat, bow, batch);
    let (loss, d) = softmax_xent(&fwd.logits, y, batch, c);

    let emb_off = 0usize;
    let w1_off = v * t;
    let b1_off = w1_off + t * hid;
    let w2_off = b1_off + hid;
    let b2_off = w2_off + hid * c;
    let w1 = &flat[w1_off..w1_off + t * hid];
    let w2 = &flat[w2_off..w2_off + hid * c];

    let mut grad = vec![0.0f32; flat.len()];
    // Output layer.
    matmul_at(&fwd.h, &d, batch, hid, c, &mut grad[w2_off..w2_off + hid * c]);
    for r in 0..batch {
        for (gb, &dv) in grad[b2_off..b2_off + c].iter_mut().zip(&d[r * c..(r + 1) * c]) {
            *gb += dv;
        }
    }
    // Hidden layer.
    let mut dh = vec![0.0f32; batch * hid];
    matmul_bt(&d, w2, batch, c, hid, &mut dh);
    for (dv, &z) in dh.iter_mut().zip(&fwd.z1) {
        if z <= 0.0 {
            *dv = 0.0;
        }
    }
    matmul_at(&fwd.e, &dh, batch, t, hid, &mut grad[w1_off..w1_off + t * hid]);
    for r in 0..batch {
        for (gb, &dv) in grad[b1_off..b1_off + hid]
            .iter_mut()
            .zip(&dh[r * hid..(r + 1) * hid])
        {
            *gb += dv;
        }
    }
    // Embedding table: d_emb = bowᵀ · (dh · W1ᵀ) — sparse in bow.
    let mut de = vec![0.0f32; batch * t];
    matmul_bt(&dh, w1, batch, hid, t, &mut de);
    matmul_at(bow, &de, batch, v, t, &mut grad[emb_off..emb_off + v * t]);
    (loss, grad)
}

/// The L1 `binned_ip` kernel oracle: per-bin wrapping-u64 inner products
/// over a `(bins × theta)` slab (bit-identical to
/// `kernels/ref.py::binned_inner_product_ref`).
pub fn binned_ip(weights: &[u64], shares: &[u64], bins: usize, theta: usize) -> Vec<u64> {
    debug_assert_eq!(weights.len(), bins * theta);
    debug_assert_eq!(shares.len(), bins * theta);
    let mut out = Vec::with_capacity(bins);
    for j in 0..bins {
        let mut acc = 0u64;
        for d in 0..theta {
            acc = acc.wrapping_add(weights[j * theta + d].wrapping_mul(shares[j * theta + d]));
        }
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::Rng;

    #[test]
    fn matmul_agrees_with_transposed_variants() {
        let mut rng = Rng::new(170);
        let (m, k, n) = (5, 7, 4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gen_normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gen_normal() as f32).collect();
        let mut c = vec![0.0; m * n];
        matmul(&a, &b, m, k, n, &mut c);
        // aᵀ path: (aᵀ)ᵀ b computed by transposing a first.
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for l in 0..k {
                at[l * m + i] = a[i * k + l];
            }
        }
        let mut c2 = vec![0.0; m * n];
        matmul_at(&at, &b, k, m, n, &mut c2);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-5);
        }
        // bᵀ path.
        let mut bt = vec![0.0; n * k];
        for l in 0..k {
            for j in 0..n {
                bt[j * k + l] = b[l * n + j];
            }
        }
        let mut c3 = vec![0.0; m * n];
        matmul_bt(&a, &bt, m, k, n, &mut c3);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_xent_gradient_is_finite_difference() {
        let mut rng = Rng::new(171);
        let (b, c) = (3, 5);
        let logits: Vec<f32> = (0..b * c).map(|_| rng.gen_normal() as f32).collect();
        let mut y = vec![0.0f32; b * c];
        for r in 0..b {
            y[r * c + r % c] = 1.0;
        }
        let (loss, d) = softmax_xent(&logits, &y, b, c);
        assert!(loss.is_finite() && loss > 0.0);
        let eps = 1e-3f32;
        for idx in 0..b * c {
            let mut lp = logits.clone();
            lp[idx] += eps;
            let (l1, _) = softmax_xent(&lp, &y, b, c);
            lp[idx] -= 2.0 * eps;
            let (l0, _) = softmax_xent(&lp, &y, b, c);
            let fd = (l1 - l0) / (2.0 * eps);
            assert!((fd - d[idx]).abs() < 1e-3, "idx {idx}: {fd} vs {}", d[idx]);
        }
    }

    #[test]
    fn mlp_gradient_descends_and_matches_finite_difference() {
        let mut rng = Rng::new(172);
        let m = mlp_num_params();
        let batch = 4;
        let flat: Vec<f32> = (0..m).map(|_| rng.gen_normal() as f32 * 0.02).collect();
        let x: Vec<f32> = (0..batch * 784).map(|_| rng.gen_f64() as f32).collect();
        let mut y = vec![0.0f32; batch * 10];
        for r in 0..batch {
            y[r * 10 + r % 10] = 1.0;
        }
        let (loss, grad) = mlp_grad(&flat, &x, &y, batch);
        assert!(loss.is_finite());
        assert_eq!(grad.len(), m);
        // Spot-check a few coordinates against central differences.
        let eps = 1e-2f32;
        for &idx in &[0usize, 784 * 1024 + 5, m - 3] {
            let mut fp = flat.clone();
            fp[idx] += eps;
            let (l1, _) = mlp_grad(&fp, &x, &y, batch);
            fp[idx] -= 2.0 * eps;
            let (l0, _) = mlp_grad(&fp, &x, &y, batch);
            let fd = (l1 - l0) / (2.0 * eps);
            assert!(
                (fd - grad[idx]).abs() < 2e-2,
                "param {idx}: fd {fd} vs grad {}",
                grad[idx]
            );
        }
        // One SGD step reduces the loss on the same batch.
        let stepped: Vec<f32> = flat.iter().zip(&grad).map(|(p, g)| p - 0.1 * g).collect();
        let (loss2, _) = mlp_grad(&stepped, &x, &y, batch);
        assert!(loss2 < loss, "{loss2} !< {loss}");
    }

    #[test]
    fn embbag_gradient_descends() {
        let mut rng = Rng::new(173);
        let dims = EmbbagDims {
            vocab: 50,
            emb_dim: 6,
            hidden: 16,
            classes: 4,
        };
        let m = dims.num_params();
        let batch = 8;
        let mut flat: Vec<f32> = (0..m).map(|_| rng.gen_normal() as f32 * 0.1).collect();
        let mut bow = vec![0.0f32; batch * dims.vocab];
        let mut y = vec![0.0f32; batch * dims.classes];
        for r in 0..batch {
            let cls = r % dims.classes;
            for w in 0..3 {
                bow[r * dims.vocab + cls * 10 + w] = 1.0;
            }
            y[r * dims.classes + cls] = 1.0;
        }
        let (l0, _) = embbag_grad(&dims, &flat, &bow, &y, batch);
        for _ in 0..30 {
            let (_, g) = embbag_grad(&dims, &flat, &bow, &y, batch);
            for (p, gv) in flat.iter_mut().zip(&g) {
                *p -= 0.5 * gv;
            }
        }
        let (l1, _) = embbag_grad(&dims, &flat, &bow, &y, batch);
        assert!(l1 < l0 * 0.5, "no learning: {l0} -> {l1}");
    }

    #[test]
    fn binned_ip_wraps() {
        let got = binned_ip(&[u64::MAX, 2, 3, 4], &[2, 1, 10, 10], 2, 2);
        assert_eq!(got, vec![u64::MAX.wrapping_mul(2).wrapping_add(2), 70]);
    }
}
