//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! request path. Python never runs here — `make artifacts` is the only
//! python invocation in the whole system.

mod artifact;
mod executor;

pub use artifact::{ArtifactManifest, ArtifactMeta};
pub use executor::{Executor, TrainStep};
