//! L1/L2 artifact runtime.
//!
//! The L2 model (JAX) and its L1 compute hot-spots (Pallas) are AOT-
//! lowered to HLO text by `python -m compile.aot` ("`make artifacts`"),
//! which also writes `manifest.json` describing every artifact's shapes
//! and metadata. Python never runs on the round path.
//!
//! Execution backends:
//!
//! * [`reference`] — always available: pure-Rust implementations of the
//!   same compute graphs, validated against `jax.grad`. Used for all
//!   execution in this offline workspace; a clean checkout needs no
//!   Python step (a missing `manifest.json` falls back to
//!   [`ArtifactManifest::builtin`]).
//! * PJRT — the seed design compiled the HLO artifacts through the `xla`
//!   crate's PJRT CPU client. Those bindings need system libraries that
//!   cannot be vendored offline; re-enabling them is an executor-level
//!   swap behind the same [`Executor`] API (see README "AOT artifacts").

mod artifact;
mod executor;
pub mod reference;

pub use artifact::{ArtifactManifest, ArtifactMeta};
pub use executor::{Executor, TrainStep};
