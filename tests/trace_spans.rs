//! End-to-end checks on the per-phase round tracing: every party of an
//! SSA round emits the expected span stream, the TCP transport reports
//! the same span shape as the in-process one, the recorder ring stays
//! bounded under span pressure, and the Chrome trace export is valid
//! JSON with the documented lane layout.

use fsl::coordinator::{serve, FslRuntimeBuilder, RoundReport, ServeOptions};
use fsl::crypto::rng::Rng;
use fsl::hashing::CuckooParams;
use fsl::metrics::json;
use fsl::metrics::trace::{Party, Phase, Span, TraceRecorder, TraceSink};
use fsl::net::transport::tcp::{TcpAcceptor, TcpOptions};
use fsl::protocol::{psr, RetrievalEngine, Session, SessionParams};
use std::net::TcpListener;

const THREADS: usize = 4;
const CLIENTS: usize = 3;

fn session() -> Session {
    Session::new_full(SessionParams {
        m: 1 << 12,
        k: 64,
        cuckoo: CuckooParams::default().with_seed(0x7AC3),
    })
}

/// One strict SSA round through the given runtime, identical inputs for
/// every caller (fixed rng seed).
fn run_ssa(mut rt: fsl::coordinator::FslRuntime<u64>) -> (RoundReport, Vec<u64>) {
    let mut rng = Rng::new(0xDECAF);
    let m = 1u64 << 12;
    let weights: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
    rt.set_weights(weights).expect("set_weights");
    let updates: Vec<(Vec<u64>, Vec<u64>)> = (0..CLIENTS)
        .map(|c| {
            let sel = rng.sample_distinct(64, m);
            let dl = sel.iter().map(|&x| x * 7 + c as u64 + 1).collect();
            (sel, dl)
        })
        .collect();
    let out = rt.ssa(&updates, &mut rng).expect("ssa round");
    rt.shutdown().expect("shutdown");
    (out.report, out.delta)
}

fn inproc_runtime() -> fsl::coordinator::FslRuntime<u64> {
    FslRuntimeBuilder::from_session(session())
        .threads(THREADS)
        .max_clients(CLIENTS)
        .build::<u64>()
        .expect("in-proc build")
}

fn of_party(spans: &[Span], party: Party) -> Vec<Span> {
    spans.iter().copied().filter(|s| s.party == party).collect()
}

fn of_phase(spans: &[Span], phase: Phase) -> Vec<Span> {
    spans.iter().copied().filter(|s| s.phase == phase).collect()
}

fn end_ns(s: &Span) -> u64 {
    s.start_ns + s.dur_ns
}

#[test]
fn inproc_ssa_round_traces_every_phase_for_every_party() {
    let (report, _) = run_ssa(inproc_runtime());
    assert!(!report.spans.is_empty(), "round produced no spans");

    // Driver lane: one keygen per client (worker = client index), then
    // the upload and the reply wait (SSA has no driver-side merge — the
    // leader returns the reconstructed delta whole).
    let client = of_party(&report.spans, Party::Client);
    let keygens = of_phase(&client, Phase::Keygen);
    let mut client_ids: Vec<Option<u32>> = keygens.iter().map(|s| s.worker).collect();
    client_ids.sort();
    let want: Vec<Option<u32>> = (0..CLIENTS as u32).map(Some).collect();
    assert_eq!(client_ids, want, "driver keygen spans must cover the cohort");
    for phase in [Phase::Upload, Phase::Reply] {
        assert_eq!(
            of_phase(&client, phase).len(),
            1,
            "driver should record exactly one {} span",
            phase.as_str()
        );
    }

    // Server lanes: upload → keygen → per-worker evals → merges → reply,
    // in that order on each server's own clock.
    for party in [Party::S0, Party::S1] {
        let spans = of_party(&report.spans, party);
        let tag = party.as_str();
        let uploads = of_phase(&spans, Phase::Upload);
        let evals = of_phase(&spans, Phase::Eval);
        let merges = of_phase(&spans, Phase::Merge);
        let replies = of_phase(&spans, Phase::Reply);
        assert_eq!(uploads.len(), 1, "{tag}: one upload span");
        assert_eq!(replies.len(), 1, "{tag}: one reply span");
        assert!(!merges.is_empty(), "{tag}: at least one merge span");

        // Every shard worker shows up in the eval lane.
        let mut workers: Vec<Option<u32>> = evals.iter().map(|s| s.worker).collect();
        workers.sort();
        workers.dedup();
        let want: Vec<Option<u32>> = (0..THREADS as u32).map(Some).collect();
        assert_eq!(workers, want, "{tag}: eval spans must cover all {THREADS} workers");

        // Phase ordering within the party's own monotonic clock.
        let upload_end = end_ns(&uploads[0]);
        for e in &evals {
            assert!(
                e.start_ns >= upload_end,
                "{tag}: eval starts before the upload finished"
            );
        }
        let last_eval_end = evals.iter().map(end_ns).max().expect("evals nonempty");
        for m in &merges {
            assert!(
                end_ns(m) >= last_eval_end,
                "{tag}: a merge ends before the last eval"
            );
        }
        let last_merge_end = merges.iter().map(end_ns).max().expect("merges nonempty");
        assert!(
            end_ns(&replies[0]) >= last_merge_end,
            "{tag}: the reply ends before the last merge"
        );
    }
}

#[test]
fn tcp_round_reports_the_same_span_shape_as_inproc() {
    let (inproc_report, inproc_delta) = run_ssa(inproc_runtime());

    let spawn = |party: u8| {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
        let addr = listener.local_addr().expect("local addr").to_string();
        let handle = std::thread::spawn(move || {
            let acceptor = TcpAcceptor::new(listener, TcpOptions::default());
            let mut opts = ServeOptions::new(party);
            opts.threads = THREADS;
            serve::<u64>(&acceptor, &opts).expect("serve");
        });
        (addr, handle)
    };
    let (addr0, h0) = spawn(0);
    let (addr1, h1) = spawn(1);
    let rt = FslRuntimeBuilder::from_session(session())
        .max_clients(CLIENTS)
        .connect::<u64>(&addr0, &addr1)
        .expect("tcp connect");
    let (tcp_report, tcp_delta) = run_ssa(rt);
    h0.join().expect("S0 thread");
    h1.join().expect("S1 thread");

    assert_eq!(inproc_delta, tcp_delta, "transport must not change the result");

    // Same spans, modulo timing: the (party, phase, worker) multiset is
    // identical whether the servers run in-thread or behind sockets.
    let shape = |report: &RoundReport| {
        let mut v: Vec<(u64, u8, Option<u32>)> = report
            .spans
            .iter()
            .map(|s| (s.party.pid(), s.phase as u8, s.worker))
            .collect();
        v.sort();
        v
    };
    assert_eq!(
        shape(&inproc_report),
        shape(&tcp_report),
        "TCP and in-proc rounds must report the same span stream"
    );
}

#[test]
fn recorder_ring_stays_bounded_under_engine_pressure() {
    // A deliberately tiny ring behind a real sharded engine: the round
    // still completes, the ring never exceeds its capacity, and the
    // recorder owns up to what it evicted.
    let session = session();
    let mut rng = Rng::new(0x0B0B);
    let m = 1u64 << 12;
    let weights: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
    let keys: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let sel = rng.sample_distinct(64, m);
            let (_ctx, batch) =
                psr::client_query::<u64>(&session, &sel, &mut rng).expect("cuckoo build");
            batch.server_keys(0)
        })
        .collect();

    let rec = TraceRecorder::shared(2);
    let engine = RetrievalEngine::new(THREADS)
        .with_trace(TraceSink::new(rec.clone(), Party::S0));
    let sharded = engine.answer_batch_keys(&session, &weights, &keys);
    let serial = RetrievalEngine::serial().answer_batch_keys(&session, &weights, &keys);
    assert_eq!(sharded, serial, "tracing must not change answers");

    // 4 eval spans + 1 merge span went in; only 2 fit.
    assert_eq!(rec.len(), 2);
    assert_eq!(rec.dropped(), 3);
    let spans = rec.drain();
    assert!(spans.iter().all(|s| s.party == Party::S0));
}

#[test]
fn trace_export_is_valid_chrome_json() {
    let (report, _) = run_ssa(inproc_runtime());
    let trace = report.trace_json();
    assert!(json::validate(&trace), "trace export must be valid JSON");

    // The documented lane layout: one process_name metadata record per
    // party, and X-events for the round phases on the right pids.
    for party in ["client", "s0", "s1"] {
        assert!(
            trace.contains(&format!("\"name\":\"{party}\"")),
            "missing process_name lane for {party}"
        );
    }
    for phase in ["keygen", "upload", "eval", "merge", "reply"] {
        assert!(
            trace.contains(&format!("\"name\":\"{phase}\"")),
            "missing {phase} X-event"
        );
    }
    assert!(trace.contains("\"ph\":\"X\""));
    assert!(trace.contains("\"ph\":\"M\""));

    // write_trace produces the same document on disk.
    let path = std::env::temp_dir().join(format!("fsl_trace_{}.json", std::process::id()));
    report.write_trace(&path).expect("write trace");
    let on_disk = std::fs::read_to_string(&path).expect("read trace back");
    assert_eq!(on_disk, trace);
    let _ = std::fs::remove_file(&path);
}
